"""Open-loop load generator: seeded Poisson/trace arrivals -> goodput.

The closed-loop serving benches measure what the engine can do when
requests politely wait their turn; production traffic doesn't wait. This
harness replaces them for serving-quality questions: arrivals are drawn
from a seeded Poisson process (or replayed from a trace file), requests
are submitted at those times regardless of engine backlog, and the
report is what users experience — TTFT/ITL p50/p99, SLO attainment, and
goodput (tokens/s counted ONLY for requests that met their deadline) —
plus the achieved-vs-peak MFU/HBM figures from the roofline-wired step
tracker. Results merge into benchmarks/BENCH_goodput.json.

Determinism: `poisson_arrivals(rate, n, seed)` is reproducible across
runs and machines (numpy Generator, fixed seed), prompts are seeded
Markov-stream slices, and decoding is greedy, so two runs of the same
command line produce identical token streams (wall-clock latencies
differ, tokens don't).

`--http` additionally drives the SAME workload through the asyncio SSE
front end (in-process server, real sockets, arrivals enforced by the
client) and asserts the streamed tokens are identical to the engine
path — the open-loop twin of the CI smoke test.

`--chaos SEED` runs the same workload twice — fault-free oracle, then
under a deterministic injected fault schedule — and asserts the chaos
run's surviving requests stream bitwise-identical greedy tokens while
the watchdog/quarantine/requeue paths demonstrably fired.

`--shared-prefix` swaps the iid prompts for a multi-tenant shape:
`--prefix-count` fixed system prompts of `--prefix-len` tokens,
Zipf-weighted (`--zipf-a`) so a few prompts dominate, each followed by
a fresh per-user tail. Seeded -> the same command line replays the
same prompt mix. Combine with `--prefix-cache` (implies a paged
`--kv-format`) to measure shared-prompt KV reuse under open-loop load,
or with `--chaos` to hammer the refcounted allocator invariants.

Usage:
  PYTHONPATH=src python benchmarks/loadgen.py --rate 8 --requests 24 \
      --slo-ttft 2.0 --slo-itl 0.5 [--speculate 3 --draft-bits 3] \
      [--adaptive] [--http] [--chaos 7 --chaos-rate 0.15] \
      [--out benchmarks/BENCH_goodput.json]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
from pathlib import Path
from typing import List, Optional

import numpy as np

sys.path.insert(0, str(Path(__file__).parent))          # for run.py helpers
from run import _merge_bench_json, _trained_small_lm    # noqa: E402

from repro.serve import (AdaptiveDraftPolicy, GenRequest, SLO, ServeEngine,
                         goodput_report, latency_summary,
                         prefix_cache_report)


def poisson_arrivals(rate: float, n: int, seed: int = 0) -> List[float]:
    """n arrival offsets (seconds) of a Poisson process with `rate`
    requests/s: iid exponential gaps, cumsum'd. Seeded -> bitwise
    reproducible across runs."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, size=n)
    return [float(t) for t in np.cumsum(gaps)]


def trace_arrivals(path: str) -> List[float]:
    """Arrival offsets from a trace file: JSON list, or one float per
    line. Offsets are from run start, must be non-decreasing."""
    text = Path(path).read_text()
    try:
        times = json.loads(text)
    except ValueError:
        times = [float(x) for x in text.split()]
    return [float(t) for t in times]


def build_requests(cfg, n: int, prompt_lens: List[int], max_new: int,
                   seed: int, deadline_s: Optional[float] = None
                   ) -> List[GenRequest]:
    """Seeded mixed-length greedy requests over the model's vocab."""
    rng = np.random.default_rng(seed + 1)
    reqs = []
    for i in range(n):
        plen = prompt_lens[i % len(prompt_lens)]
        prompt = [int(t) for t in rng.integers(1, cfg.vocab_size,
                                               size=plen)]
        reqs.append(GenRequest(prompt=prompt, max_new=max_new,
                               deadline_s=deadline_s))
    return reqs


def build_shared_prefix_requests(cfg, n: int, n_prefixes: int,
                                 prefix_len: int, tail_lens: List[int],
                                 max_new: int, seed: int,
                                 zipf_a: float = 1.5,
                                 deadline_s: Optional[float] = None
                                 ) -> List[GenRequest]:
    """Multi-tenant prompt shape: `n_prefixes` fixed system prompts,
    each request Zipf-samples one (rank-k prompt drawn with weight
    1/k^a — a few prompts dominate, as in production) and appends a
    fresh per-user tail. Fully seeded -> the same (seed, knobs) tuple
    replays the identical prompt mix."""
    rng = np.random.default_rng(seed + 1)
    prefixes = [[int(t) for t in rng.integers(1, cfg.vocab_size,
                                              size=prefix_len)]
                for _ in range(n_prefixes)]
    w = 1.0 / np.arange(1, n_prefixes + 1, dtype=np.float64) ** zipf_a
    w /= w.sum()
    reqs = []
    for i in range(n):
        k = int(rng.choice(n_prefixes, p=w))
        tail = [int(t) for t in rng.integers(
            1, cfg.vocab_size, size=tail_lens[i % len(tail_lens)])]
        reqs.append(GenRequest(prompt=prefixes[k] + tail, max_new=max_new,
                               deadline_s=deadline_s))
    return reqs


def _http_check(engine: ServeEngine, reqs: List[GenRequest],
                arrivals: List[float], ref_tokens: List[List[int]],
                seed: int) -> dict:
    """Open-loop over real sockets: fire the same workload at the asyncio
    SSE front end at the same arrival offsets, assert token identity."""
    import asyncio
    from repro.serve.frontend import AsyncServeFrontend, sse_generate

    async def drive():
        async def one(req, delay):
            await asyncio.sleep(delay)
            return await sse_generate("127.0.0.1", fe.port, {
                "prompt": req.prompt, "max_new": req.max_new,
                "deadline_s": req.deadline_s})
        fe = AsyncServeFrontend(engine, seed=seed)
        async with fe:
            frames = await asyncio.gather(
                *[one(r, t) for r, t in zip(reqs, arrivals)])
        return [[f["token"] for f in fs if "token" in f] for fs in frames]

    toks = asyncio.run(drive())
    identical = toks == ref_tokens
    assert identical, "SSE open-loop tokens diverged from engine path"
    return {"http_tokens_identical": identical, "http_requests": len(toks)}


def run_loadgen(rate: float = 8.0, n_requests: int = 24, seed: int = 0,
                prompt_lens: List[int] = (8, 24, 48), max_new: int = 24,
                slo_ttft_s: float = 2.0, slo_itl_s: float = 0.5,
                deadline_s: Optional[float] = None,
                trace: Optional[str] = None, n_slots: int = 4,
                prefill_chunk: int = 16, spec_k: int = 0,
                draft_bits: int = 0, adaptive: bool = False,
                http: bool = False, track=True,
                chaos_seed: Optional[int] = None, chaos_rate: float = 0.1,
                queue_cap: Optional[int] = None,
                shared_prefix: bool = False, n_prefixes: int = 3,
                prefix_len: int = 48, zipf_a: float = 1.5,
                kv_format: Optional[str] = None, page_size: int = 16,
                kv_pages: int = 0, prefix_cache: bool = False,
                out_path: Optional[str] = None) -> dict:
    cfg, params, data = _trained_small_lm()
    if prefix_cache and not kv_format:
        kv_format = "paged"          # the cache shares pages of the pool
    if kv_format:
        cfg = dataclasses.replace(cfg, kv_format=kv_format,
                                  kv_page_size=page_size,
                                  kv_pages=kv_pages)
    if draft_bits:
        # low-bit-prefix drafts need the nested bitstream weight layout:
        # quantize the trained LM to 4-bit lut4_nested (RTN is enough for
        # a serving-shape bench) so draft passes stream 3 of 4 bit-planes
        import jax.numpy as jnp
        from repro.core import QuantConfig
        from repro.core.policy import PrecisionPolicy
        from repro.models.quantized import quantize_model_ptq
        pol = PrecisionPolicy(qcfg=QuantConfig(bits=4), fmt="lut4_nested",
                              method="rtn")
        params, _ = quantize_model_ptq(
            params, cfg, {k: jnp.asarray(v)
                          for k, v in data.batch_at(0).items()},
            policy=pol)
    policy = AdaptiveDraftPolicy(queue_hi=2, queue_lo=0,
                                 wait_hi_s=slo_ttft_s / 2,
                                 wait_lo_s=slo_ttft_s / 8) \
        if adaptive else None
    engine = ServeEngine(params, cfg, max_len=128, n_slots=n_slots,
                         prefill_chunk=prefill_chunk, spec_k=spec_k,
                         draft_bits=draft_bits, adaptive=policy,
                         prefix_cache=prefix_cache)
    if shared_prefix:
        reqs = build_shared_prefix_requests(
            cfg, n_requests, n_prefixes, prefix_len, list(prompt_lens),
            max_new, seed, zipf_a=zipf_a, deadline_s=deadline_s)
    else:
        reqs = build_requests(cfg, n_requests, list(prompt_lens), max_new,
                              seed, deadline_s)
    arrivals = trace_arrivals(trace) if trace else \
        poisson_arrivals(rate, n_requests, seed)
    if len(arrivals) < n_requests:
        raise SystemExit(f"trace has {len(arrivals)} arrivals "
                         f"< {n_requests} requests")

    # warm the serving jits off-clock (compile time would otherwise be
    # charged to the first arrivals' TTFT and dominate the p99); bypass
    # the adaptive gate so the draft/verify jits compile here too, not
    # inside the measured run's first pressure spike
    engine.adaptive = None
    engine.serve(build_requests(cfg, min(n_slots, n_requests),
                                list(prompt_lens), 4, seed + 7), seed=seed)
    engine.adaptive = policy
    faults = None
    oracle_tokens = None
    if chaos_seed is not None:
        # fault-free oracle first: the chaos run's SURVIVING requests
        # (finish_reason eos/length) must emit bitwise-identical greedy
        # tokens — quarantine/requeue replays deterministically, retries
        # never double-sample, NaN rounds roll back cleanly
        from repro.serve.faults import chaos_injector
        oracle = engine.serve(reqs, seed=seed, arrival_times=arrivals)
        oracle_tokens = [r.tokens for r in oracle]
        faults = chaos_injector(chaos_seed, rate=chaos_rate,
                                paged=engine.paged)
    results = engine.serve(reqs, seed=seed, arrival_times=arrivals,
                           track=track, faults=faults, queue_cap=queue_cap)
    stats = engine.last_stats
    slo = SLO(ttft_s=slo_ttft_s, itl_s=slo_itl_s)
    report = {
        "arrivals": {"process": "trace" if trace else "poisson",
                     "rate_req_per_s": None if trace else rate,
                     "seed": seed, "n_requests": n_requests,
                     "span_s": arrivals[n_requests - 1]},
        "workload": {"prompt_lens": list(prompt_lens), "max_new": max_new,
                     "n_slots": n_slots, "prefill_chunk": prefill_chunk,
                     "spec_k": spec_k, "draft_bits": draft_bits,
                     "adaptive": adaptive, "kv_format": kv_format,
                     "shared_prefix": ({"n_prefixes": n_prefixes,
                                        "prefix_len": prefix_len,
                                        "zipf_a": zipf_a}
                                       if shared_prefix else None)},
        "latency": latency_summary(results),
        "goodput": goodput_report(results, slo, wall_s=stats["wall_s"]),
        "engine": {k: stats[k] for k in
                   ("wall_s", "step_tok_per_s", "decode_tok_per_s",
                    "chunk_tokens", "prefills", "spec_rounds",
                    "accept_rate") if k in stats},
    }
    if adaptive:
        report["engine"].update(
            adaptive_rounds=stats["adaptive_rounds"],
            adaptive_flips=stats["adaptive_flips"])
    pc = prefix_cache_report(stats)
    if pc is not None:
        report["prefix_cache"] = pc
    if track:
        report["hw"] = stats["hw"]
    if http:
        report["http"] = _http_check(engine, reqs, arrivals,
                                     [r.tokens for r in results], seed)
    if chaos_seed is not None:
        survivors = [i for i, r in enumerate(results)
                     if r.finish_reason in ("eos", "length")]
        diverged = [i for i in survivors
                    if results[i].tokens != oracle_tokens[i]]
        assert not diverged, \
            f"chaos survivors diverged from fault-free oracle: {diverged}"
        flt = stats["faults"]
        injected = flt["injected"]
        assert sum(injected.values()) > 0, \
            "chaos run injected no faults — raise --chaos-rate or --requests"
        assert flt["step_retries"] + flt["requeues"] + flt["cancels"] > 0, \
            "chaos faults injected but engine recovery paths never exercised"
        report["faults"] = {
            "chaos_seed": chaos_seed, "chaos_rate": chaos_rate,
            "queue_cap": queue_cap, **flt,
            "survivors": len(survivors), "n_requests": n_requests,
            "survivor_tokens_identical": True,
        }
    path = Path(out_path or Path(__file__).parent / "BENCH_goodput.json")
    key = ("chaos" if chaos_seed is not None else "open_loop") \
        + ("_spec_adaptive" if adaptive else "_spec" if spec_k else "") \
        + ("_shared_prefix" if shared_prefix else "")
    _merge_bench_json(path, {key: report})
    summary = {"ttft_p99_s": report["latency"]["ttft_s"]["p99"],
               "itl_p99_s": report["latency"]["itl_s"]["p99"],
               "slo_attainment": report["goodput"]["slo_attainment"],
               "goodput_tok_per_s": report["goodput"]["goodput_tok_per_s"],
               "hbm_util_pct_p50":
               report["hw"]["hbm_util_pct"]["p50"] if track else None}
    if pc is not None:
        summary.update(prefix_hits=pc["prefix_hits"],
                       prefix_hit_rate=round(pc["hit_rate"], 3),
                       pages_shared=pc["pages_shared"],
                       cow_copies=pc["cow_copies"])
    if chaos_seed is not None:
        f = report["faults"]
        summary.update(survivors=f"{f['survivors']}/{n_requests}",
                       survivor_tokens_identical=True,
                       step_retries=f["step_retries"],
                       quarantines=f["quarantines"],
                       requeues=f["requeues"], sheds=f["sheds"],
                       cancels=f["cancels"])
    print(json.dumps(summary, indent=1))
    return report


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--rate", type=float, default=8.0,
                    help="Poisson arrival rate, requests/s")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--prompt-lens", type=int, nargs="+",
                    default=[8, 24, 48])
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--slo-ttft", type=float, default=2.0,
                    help="TTFT SLO seconds (goodput accounting)")
    ap.add_argument("--slo-itl", type=float, default=0.5,
                    help="max inter-token latency SLO seconds")
    ap.add_argument("--deadline", type=float, default=None,
                    help="per-request decode deadline (engine-enforced)")
    ap.add_argument("--trace", type=str, default=None,
                    help="arrival trace file instead of Poisson")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prefill-chunk", type=int, default=16)
    ap.add_argument("--speculate", type=int, default=0, metavar="K")
    ap.add_argument("--draft-bits", type=int, default=0,
                    choices=(0, 2, 3))
    ap.add_argument("--adaptive", action="store_true",
                    help="load-adaptive draft precision policy")
    ap.add_argument("--http", action="store_true",
                    help="also drive the SSE front end, check identity")
    ap.add_argument("--no-track", action="store_true",
                    help="skip the MFU/HBM step tracker")
    ap.add_argument("--chaos", type=int, default=None, metavar="SEED",
                    help="chaos mode: run a fault-free oracle, then the "
                         "same workload under a deterministic fault "
                         "schedule seeded by SEED; asserts survivors' "
                         "tokens are bitwise the oracle's and recovery "
                         "paths actually fired")
    ap.add_argument("--chaos-rate", type=float, default=0.1,
                    help="per-step fault probability for --chaos")
    ap.add_argument("--queue-cap", type=int, default=0,
                    help="arrived-queue depth before shedding; 0 = off")
    ap.add_argument("--shared-prefix", action="store_true",
                    help="Zipf-sampled shared system prompts + per-user "
                         "tails (tail lengths from --prompt-lens)")
    ap.add_argument("--prefix-count", type=int, default=3,
                    help="number of distinct system prompts")
    ap.add_argument("--prefix-len", type=int, default=48,
                    help="system-prompt length in tokens")
    ap.add_argument("--zipf-a", type=float, default=1.5,
                    help="Zipf exponent for prompt popularity")
    ap.add_argument("--kv-format", type=str, default=None,
                    choices=("full", "int8", "paged", "paged_int8"))
    ap.add_argument("--page-size", type=int, default=16,
                    help="KV page size when --kv-format is paged")
    ap.add_argument("--kv-pages", type=int, default=0,
                    help="page-pool size; 0 = dense equivalent")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="page-granular prefix caching (implies a "
                         "paged --kv-format)")
    ap.add_argument("--out", type=str, default=None)
    a = ap.parse_args(argv)
    run_loadgen(rate=a.rate, n_requests=a.requests, seed=a.seed,
                prompt_lens=a.prompt_lens, max_new=a.max_new,
                slo_ttft_s=a.slo_ttft, slo_itl_s=a.slo_itl,
                deadline_s=a.deadline, trace=a.trace, n_slots=a.slots,
                prefill_chunk=a.prefill_chunk, spec_k=a.speculate,
                draft_bits=a.draft_bits, adaptive=a.adaptive,
                http=a.http, track=not a.no_track,
                chaos_seed=a.chaos, chaos_rate=a.chaos_rate,
                queue_cap=a.queue_cap or None,
                shared_prefix=a.shared_prefix, n_prefixes=a.prefix_count,
                prefix_len=a.prefix_len, zipf_a=a.zipf_a,
                kv_format=a.kv_format, page_size=a.page_size,
                kv_pages=a.kv_pages, prefix_cache=a.prefix_cache,
                out_path=a.out)


if __name__ == "__main__":
    main()
