"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. The offline container has no
WikiText/C4 and no GPU, so fidelity experiments run on synthetic corpora
(heavy-tailed weights + outlier-feature activations, matching the paper's
Fig. 1b setting) and the latency table is roofline-derived for the TPU
target (wall-clock on this CPU is reported for the harness itself, not as
TPU performance). Mapping to paper artifacts: DESIGN.md §7.
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (QuantConfig, compute_h, ganq_quantize,
                        gptq_reconstruct, layer_objective, precondition,
                        rtn_reconstruct, storage_bytes)
from repro.data.synthetic import MarkovStream


def _t(fn, *args, reps=3):
    fn(*args)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6, out


def _row(name, us, derived):
    print(f"{name},{us:.1f},{derived}")


def _llm_like_layer(seed, m=256, n=256, p=1024, outlier_cols=4,
                    w_outliers=0):
    """Heavy-tailed W + activation-outlier X (paper Fig. 1b regime)."""
    rng = np.random.default_rng(seed)
    w = (rng.standard_t(df=4, size=(m, n)) * 0.02).astype(np.float32)
    if w_outliers:
        r = rng.integers(0, m, size=w_outliers)
        c = rng.integers(0, n, size=w_outliers)
        w[r, c] += rng.choice([-1., 1.], w_outliers) * 1.0
    x = rng.normal(size=(n, p)).astype(np.float32)
    scale = np.ones(n, np.float32)
    scale[rng.choice(n, outlier_cols, replace=False)] = 30.0
    x *= scale[:, None]
    return jnp.asarray(w), compute_h(jnp.asarray(x))


# ------------------------------------------------------------- Table 1

def bench_table1_storage():
    for mn, expect in ((2048, 25.78), (4096, 25.39), (8192, 25.20)):
        s = storage_bytes(mn, mn, bits=4)
        _row(f"table1_storage_m{mn}", 0.0,
             f"lut_pct={s['lut_pct_of_fp16']:.2f} (paper {expect})")


# ------------------------------------------------------------- Table 2

def bench_table2_layer_error():
    """Layer-output error at 4/3 bits, 5-seed mean: RTN/AWQ/GPTQ (uniform
    grids), SqueezeLLM (sensitivity k-means LUT), GANQ (full-H LUT)."""
    from repro.core import quantize_linear
    for bits in (4, 3):
        methods = ("rtn", "awq", "gptq", "squeezellm", "ganq",
                   "ganq_fixed")
        errs = {m: [] for m in methods}
        us = {m: 0.0 for m in methods}
        for seed in range(5):
            w, h = _llm_like_layer(seed)
            for m in methods:
                precond = "fixed" if m == "ganq_fixed" else "adaptive"
                real_m = "ganq" if m == "ganq_fixed" else m
                cfg = QuantConfig(bits=bits, iters=8, precondition=precond)
                us[m], res = _t(
                    lambda m=real_m: quantize_linear(w, h, cfg, m))
                if m == "awq":
                    # awq layer stores the scaled-domain grid; its pipeline
                    # err_history is already vs the true H
                    errs[m].append(float(res.err_history[-1]))
                else:
                    errs[m].append(float(layer_objective(
                        w, res.layer.dequantize(), h)))
        base = np.mean(errs["rtn"])
        for m in methods:
            _row(f"table2_layer_err_{m}_{bits}bit", us[m],
                 f"err={np.mean(errs[m]):.4f} rel_rtn="
                 f"{np.mean(errs[m]) / base:.4f}")


_E2E_CACHE = {}


def _trained_small_lm():
    if "model" in _E2E_CACHE:
        return _E2E_CACHE["model"]
    from repro.configs import get_config, reduce_config
    from repro.train.loop import Trainer, TrainerConfig
    from repro.train.optimizer import OptConfig
    import dataclasses, tempfile
    cfg = dataclasses.replace(reduce_config(get_config("deepseek-7b")),
                              n_layers=4, d_model=128, n_heads=8,
                              n_kv_heads=8, head_dim=16, d_ff=256,
                              vocab_size=1024)
    data = MarkovStream(cfg.vocab_size, batch=8, seq=64, seed=11)
    tcfg = TrainerConfig(steps=150, ckpt_every=1000, log_every=1000,
                         ckpt_dir=tempfile.mkdtemp())
    tr = Trainer(cfg, data, tcfg,
                 opt_cfg=OptConfig(lr=8e-3, warmup_steps=15, total_steps=150,
                                   weight_decay=0.0))
    tr.run()
    params, _, _ = tr.init_or_restore()
    _E2E_CACHE["model"] = (cfg, params, data)
    return _E2E_CACHE["model"]


def _ppl(params, cfg, batch):
    from repro.models import forward_logits
    logits = forward_logits(params, batch, cfg).astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, batch["labels"][..., None],
                               axis=-1)[..., 0]
    return float(jnp.exp(jnp.mean(logz - gold)))


def _sensitivity_profile():
    """Per-(group, width) sensitivity of the trained small LM, measured
    once per process (three PTQ passes + one fp pass) and shared by the
    frontier and mixed-precision-serving benches."""
    if "profile" in _E2E_CACHE:
        return _E2E_CACHE["profile"]
    from repro.core import profile_sensitivity
    cfg, params, data = _trained_small_lm()
    calib_stream = MarkovStream(cfg.vocab_size, batch=32, seq=128, seed=11)
    calib = {k: jnp.asarray(v)
             for k, v in calib_stream.batch_at(900).items()}
    prof = profile_sensitivity(
        params, cfg, calib, widths=(2, 3, 4),
        qcfg=QuantConfig(bits=4, iters=8, precondition="fixed"),
        arch="small-lm")
    _E2E_CACHE["profile"] = prof
    return prof


def _code_bpw(report):
    """Code (checkpoint-stream) bits/weight of a PTQ report — the budget
    axis of the precision search; fp layers count at their dtype width."""
    total_b = sum((r.bits if r.bits is not None else r.bits_per_weight)
                  * r.n_weights for r in report.values())
    total_w = sum(r.n_weights for r in report.values())
    return total_b / max(total_w, 1)


def _eval_ppl(qp, cfg, data, n=16):
    """Held-out ppl averaged over n eval batches — single-batch draws on
    the toy model have ~0.3% noise, enough to scramble nearby frontier
    points."""
    return float(np.mean([
        _ppl(qp, cfg, {k: jnp.asarray(v)
                       for k, v in data.batch_at(901 + i).items()})
        for i in range(n)]))


def bench_table2_e2e_ppl():
    """Perplexity of a TRAINED small LM after sequential PTQ — the paper's
    Table 2 protocol end-to-end (synthetic corpus; calib 32x128 tokens).

    Note (EXPERIMENTS.md): a 150-step toy model has near-Gaussian weights,
    so at 4-bit all error-compensating methods sit within noise of fp16 —
    the paper's premise (heavy-tailed weights, Fig. 1b) does not hold for
    it. The ranking GANQ < GPTQ < RTN emerges exactly where quantization
    pressure is high (2-bit here; 3/4-bit on real heavy-tailed LLMs, cf.
    bench_table2_layer_error which uses heavy-tailed W)."""
    from repro.models.quantized import quantize_model_ptq
    cfg, params, data = _trained_small_lm()
    calib_stream = MarkovStream(cfg.vocab_size, batch=32, seq=128, seed=11)
    calib = {k: jnp.asarray(v)
             for k, v in calib_stream.batch_at(900).items()}
    evalb = {k: jnp.asarray(v) for k, v in data.batch_at(901).items()}
    ppl_fp = _ppl(params, cfg, evalb)
    _row("table2_e2e_ppl_fp16", 0.0, f"ppl={ppl_fp:.3f}")
    for bits in (4, 3, 2):
        for method in ("rtn", "gptq", "ganq"):
            qcfg = QuantConfig(bits=bits, iters=8, precondition="fixed")
            t0 = time.perf_counter()
            qp, _ = quantize_model_ptq(params, cfg, calib, qcfg, method)
            us = (time.perf_counter() - t0) * 1e6
            ppl = _ppl(qp, cfg, evalb)
            _row(f"table2_e2e_ppl_{method}_{bits}bit", us,
                 f"ppl={ppl:.3f} gap={ppl - ppl_fp:+.3f}")


# ------------------------------------------------------------- Table 5

def bench_table5_outliers():
    """GANQ vs GANQ* (outlier split + full rows) on outlier-heavy W."""
    for bits in (4, 3):
        deltas = []
        us = 0.0
        for seed in range(3):
            w, h = _llm_like_layer(100 + seed, w_outliers=256)
            base = ganq_quantize(w, h=h, cfg=QuantConfig(
                bits=bits, iters=6, precondition="fixed"))
            t0 = time.perf_counter()
            star = ganq_quantize(w, h=h, cfg=QuantConfig(
                bits=bits, iters=6, precondition="fixed",
                outlier_ratio=0.01, full_rows=2))
            us = (time.perf_counter() - t0) * 1e6
            e0 = float(layer_objective(w, base.layer.dequantize(), h))
            e1 = float(layer_objective(w, star.layer.dequantize(), h))
            deltas.append(e1 / e0)
        _row(f"table5_ganq_star_{bits}bit", us,
             f"err_ratio_vs_ganq={np.mean(deltas):.4f} (<1 = GANQ* wins)")


# ------------------------------------------------------------- Table 6

def bench_table6_decode_speedup():
    """Roofline-derived decode speedup on the TPU target (batch-1 decode is
    weight-bytes-bound; paper measures 2.24x/2.57x on RTX4090)."""
    from repro.configs import get_config
    for arch in ("deepseek-7b", "granite-3-8b"):
        cfg = get_config(arch)
        n_params = cfg.param_count()
        bytes_fp16 = 2.0 * n_params
        for bits in (4, 3):
            levels = 1 << bits
            d, f = cfg.d_model, cfg.d_ff
            per_layer_rows = cfg.q_dim + 2 * cfg.kv_dim + d + 3 * f
            lut_rows = per_layer_rows * cfg.n_layers
            bytes_q = bits / 8 * n_params + 2 * levels * lut_rows
            speedup = bytes_fp16 / bytes_q
            _row(f"table6_decode_speedup_{arch}_{bits}bit", 0.0,
                 f"weight_bytes_ratio={speedup:.2f}x "
                 f"(paper RTX4090: 2.24x@4b / 2.57x@3b incl. overheads)")


def bench_lut_kernels(out_path=None):
    """LUT-mpGEMM layout sweep: bits x {nibble-packed, true bitstream} x
    p in {1, 8, 32} decode widths, plus fused grouped-QKV vs its
    sequential 3-launch baseline. Emits BENCH_kernels.json with the
    HBM bytes each variant streams (from `vmem_plan`'s layout-aware
    accounting — the TPU-relevant signal) next to interpret-mode wall
    time (harness timing only, not TPU perf)."""
    import json
    from pathlib import Path
    from repro.core.formats import get_format
    from repro.core.packing import pack_bits, pack_nibbles
    from repro.kernels.ops import lut_linear, lut_linear_grouped, vmem_plan
    from repro.kernels.tune import BlockPlan
    from repro.core.types import QuantizedLinear

    rng = np.random.default_rng(0)
    m, n = 256, 256
    # pin tile sizes explicitly: the committed numbers must not depend on
    # whatever tuned plans happen to sit in this machine's on-disk cache
    blocks = BlockPlan(128, 512, 128)
    results = {"shape": {"m": m, "n": n}, "blocks": blocks.as_kwargs(),
               "mpgemm": [], "grouped_qkv": []}
    for bits in (3, 4):
        codes = jnp.asarray(rng.integers(0, 1 << bits,
                                         size=(m, n)).astype(np.uint8))
        t = jnp.asarray(rng.normal(size=(m, 1 << bits)).astype(np.float32))
        # nibble container vs true bitstream of the SAME codes; at 4-bit
        # the two layouts are byte-identical (one row, flagged below) —
        # the contrast only exists at sub-nibble widths
        layouts = [("packed", pack_nibbles(codes), "lut4_packed")]
        if bits != 4:
            layouts.append(("bitstream", pack_bits(codes, bits),
                            "lut3_packed"))
        for p in (1, 8, 32):
            x = jnp.asarray(rng.normal(size=(n, p)).astype(np.float32))
            for lname, cc, fmt in layouts:
                us, _ = _t(lambda cc=cc, fmt=fmt: lut_linear(
                    cc, t, x, bits=bits, fmt=fmt, blocks=blocks))
                plan = vmem_plan(m, n, p, bits, fmt=fmt,
                                 x_dtype=jnp.float32, book_dtype=jnp.float32)
                row = {"bits": bits, "layout": lname, "p": p, "us": us,
                       "codes_bytes": plan["codes_bytes"],
                       "total_bytes": plan["total_bytes"]}
                if bits == 4:
                    row["layout"] = "packed==bitstream"
                results["mpgemm"].append(row)
                _row(f"lut_kernel_b{bits}_{row['layout']}_p{p}", us,
                     f"codes_bytes={plan['codes_bytes']:.0f} "
                     f"total_bytes={plan['total_bytes']:.0f}")
    # fused grouped QKV (GQA 4:1:1) vs three sequential launches
    for bits, fmt in ((4, "lut4_packed"), (3, "lut3_packed")):
        f = get_format(fmt)
        dims = (256, 64, 64)                    # q_dim, kv_dim, kv_dim
        layers = []
        for i, mi in enumerate(dims):
            c = jnp.asarray(rng.integers(0, 1 << bits,
                                         size=(mi, n)).astype(np.uint8))
            tb = jnp.asarray(rng.normal(size=(mi, 1 << bits))
                             .astype(np.float32))
            layers.append(f.encode(QuantizedLinear(codes=c, codebook=tb,
                                                   bits=bits)))
        for p in (1, 8, 32):
            x = jnp.asarray(rng.normal(size=(n, p)).astype(np.float32))
            us_seq, _ = _t(lambda: [lut_linear(l.codes, l.codebook, x,
                                               bits=bits, fmt=fmt,
                                               blocks=blocks)
                                    for l in layers])
            us_grp, _ = _t(lambda: lut_linear_grouped(layers, x,
                                                      blocks=blocks))
            seq_plans = [vmem_plan(mi, n, p, bits, fmt=fmt,
                                   x_dtype=jnp.float32) for mi in dims]
            grp_plan = vmem_plan(sum(dims), n, p, bits, fmt=fmt,
                                 x_dtype=jnp.float32,
                                 groups=sum(dims) // 64)
            row = {"bits": bits, "fmt": fmt, "p": p,
                   "us_sequential": us_seq, "us_grouped": us_grp,
                   "codes_bytes_sequential":
                       sum(pl["codes_bytes"] for pl in seq_plans),
                   "codes_bytes_grouped": grp_plan["codes_bytes"],
                   "x_bytes_sequential":
                       sum(pl["x_bytes"] for pl in seq_plans),
                   "x_bytes_grouped": grp_plan["x_bytes"],
                   "total_bytes_sequential":
                       sum(pl["total_bytes"] for pl in seq_plans),
                   "total_bytes_grouped": grp_plan["total_bytes"]}
            results["grouped_qkv"].append(row)
            _row(f"lut_grouped_qkv_b{bits}_p{p}", us_grp,
                 f"seq_us={us_seq:.1f} "
                 f"x_bytes {row['x_bytes_sequential']:.0f}->"
                 f"{row['x_bytes_grouped']:.0f} "
                 f"codes_bytes={row['codes_bytes_grouped']:.0f}")
    path = Path(out_path or Path(__file__).parent / "BENCH_kernels.json")
    path.write_text(json.dumps(results, indent=1))
    return results


def bench_table6_kernel_walltime():
    """LUT-mpGEMM kernel wall time (interpret mode — harness timing only)."""
    from repro.kernels.ops import lut_linear
    from repro.kernels.ref import lut_matmul_ref
    from repro.core.packing import pack_nibbles
    rng = np.random.default_rng(0)
    m, n, p = 512, 512, 8
    codes = jnp.asarray(rng.integers(0, 16, size=(m, n)).astype(np.uint8))
    t = jnp.asarray(rng.normal(size=(m, 16)).astype(np.float32))
    x = jnp.asarray(rng.normal(size=(n, p)).astype(np.float32))
    us_ref, _ = _t(lambda: lut_matmul_ref(codes, t, x))
    _row("table6_kernel_xla_ref", us_ref, f"m={m} n={n} p={p}")
    us_pal, _ = _t(lambda: lut_linear(codes, t, x, bits=4))
    _row("table6_kernel_pallas_interpret", us_pal,
         "interpret-mode (CPU emulation; not TPU perf)")
    packed = pack_nibbles(codes)
    us_pk, _ = _t(lambda: lut_linear(packed, t, x, bits=4, packed=True))
    _row("table6_kernel_pallas_packed", us_pk, "0.5B/weight HBM layout")


# ------------------------------------------------------ §4.3 serving


def bench_serving_throughput():
    """Continuous-batching decode throughput under mixed-length Poisson
    arrivals, quantized vs fp weights — the paper's §4.3 deployment regime
    driven by the slot engine (CPU wall numbers benchmark the harness;
    relative q-vs-fp and slot occupancy are the signal)."""
    from repro.models.quantized import quantize_model_ptq
    from repro.serve.engine import GenRequest, ServeEngine
    cfg, params, data = _trained_small_lm()
    calib = {k: jnp.asarray(v) for k, v in data.batch_at(800).items()}
    qparams, _ = quantize_model_ptq(
        params, cfg, calib, QuantConfig(bits=4, iters=4,
                                        precondition="fixed"), "ganq")
    rng = np.random.default_rng(42)
    toks = data.batch_at(801)["tokens"]
    n_req, rate = 8, 4.0                       # req/s Poisson arrivals
    reqs = [GenRequest(prompt=toks[i % toks.shape[0],
                                   :int(rng.integers(6, 20))].tolist(),
                       max_new=8) for i in range(n_req)]
    arrivals = np.cumsum(rng.exponential(1.0 / rate, size=n_req)).tolist()
    for name, p in (("fp", params), ("ganq4", qparams)):
        engine = ServeEngine(p, cfg, max_len=64, n_slots=4)
        engine.serve(reqs)    # warm: prefill jits per distinct prompt length
        res = engine.serve(reqs, arrival_times=arrivals)
        st = engine.last_stats
        n_tok = sum(len(r.tokens) for r in res)
        _row(f"serve_poisson_{name}", st["wall_s"] * 1e6,
             f"decode_tok_s={st['decode_tok_per_s']:.1f} tokens={n_tok} "
             f"slot_reuses={st['slot_reuses']} rate={rate}/s")


def bench_paged_serving(out_path=None):
    """Paged vs contiguous KV cache on a mixed 32–2048-token workload
    (page_size=64): the paged pool is sized well below the dense
    n_slots x max_len equivalent, so the committed BENCH_serving.json
    tracks the serving memory/throughput trajectory — KV bytes allocated,
    decode tok/s, pool occupancy — like BENCH_kernels.json does for the
    kernels. Greedy tokens must be identical across the two layouts (the
    same check the tier-1 equivalence tests enforce)."""
    import dataclasses
    import json
    from pathlib import Path
    from repro.serve.engine import GenRequest, ServeEngine
    cfg, params, _ = _trained_small_lm()
    page_size, max_new, n_slots = 64, 8, 4
    max_len = 2048 + page_size
    long_data = MarkovStream(cfg.vocab_size, batch=1, seq=2048, seed=5)
    toks = long_data.batch_at(0)["tokens"][0]
    # mixed lengths, few distinct values (one prefill compile per length)
    lengths = [32, 128, 2048, 32, 128, 32, 128, 32]
    reqs = [GenRequest(prompt=toks[:l].tolist(), max_new=max_new)
            for l in lengths]
    # pool sized to the workload's concurrent peak + margin — well under
    # the dense equivalent n_slots * ceil(max_len / page_size)
    kv_pages = 56
    dense_pages = n_slots * (-(-max_len // page_size))
    cfg_paged = dataclasses.replace(cfg, kv_format="paged",
                                    kv_page_size=page_size,
                                    kv_pages=kv_pages)
    results = {"scenario": {
        "prompt_lengths": lengths, "max_new": max_new, "n_slots": n_slots,
        "max_len": max_len, "page_size": page_size, "kv_pages": kv_pages,
        "dense_equivalent_pages": dense_pages}}
    tokens = {}
    for name, c in (("contiguous", cfg), ("paged", cfg_paged)):
        engine = ServeEngine(params, c, max_len=max_len, n_slots=n_slots)
        engine.serve(reqs)          # warm: prefill jit per distinct length
        res = engine.serve(reqs, track=True)
        st = engine.last_stats
        tokens[name] = [r.tokens for r in res]
        row = {"kv_cache_bytes": st["kv_cache_bytes"],
               "decode_tok_per_s": round(st["decode_tok_per_s"], 2),
               "decode_steps": st["decode_steps"],
               "evictions": st.get("evictions", 0),
               "mfu_pct_p50": st["hw"]["mfu_pct"]["p50"],
               "hbm_util_pct_p50": st["hw"]["hbm_util_pct"]["p50"]}
        if name == "paged":
            row["peak_pages_in_use"] = st["peak_pages_in_use"]
        results[name] = row
        _row(f"paged_serving_{name}", st["wall_s"] * 1e6,
             f"kv_bytes={st['kv_cache_bytes']} "
             f"decode_tok_s={st['decode_tok_per_s']:.1f} "
             f"mfu_p50={row['mfu_pct_p50']:.2f}% "
             f"hbm_p50={row['hbm_util_pct_p50']:.2f}%")
    results["tokens_identical"] = tokens["contiguous"] == tokens["paged"]
    results["kv_bytes_ratio"] = round(
        results["paged"]["kv_cache_bytes"]
        / results["contiguous"]["kv_cache_bytes"], 4)
    assert results["tokens_identical"], "paged decode diverged!"
    _row("paged_serving_kv_ratio", 0.0,
         f"paged/contiguous={results['kv_bytes_ratio']:.3f} "
         f"tokens_identical={results['tokens_identical']}")
    path = Path(out_path or Path(__file__).parent / "BENCH_serving.json")
    _merge_bench_json(path, results)
    return results


def _merge_bench_json(path, updates):
    """BENCH_serving.json carries several scenarios; each bench refreshes
    only its own keys."""
    import json
    data = {}
    if path.exists():
        try:
            data = json.loads(path.read_text())
        except ValueError:
            data = {}
    data.update(updates)
    path.write_text(json.dumps(data, indent=1))


def bench_chunked_prefill_ttft(out_path=None):
    """TTFT / stall scenario: a 2048-token prompt arrives while 8 slots
    are mid-decode. Legacy whole-prompt-prefill admission
    (prefill_chunk=0) freezes every in-flight stream for the entire
    prefill; the unified token-budget step (prefill_chunk=64) interleaves
    the prompt's chunks with the decode lanes, so no stream ever waits
    more than one budget step. Greedy tokens must be identical across the
    two admission modes. Records each mode's long-prompt TTFT, the
    in-flight streams' p50/p99/max inter-token latency, and the scheduler
    gap counter into BENCH_serving.json (wall numbers benchmark this CPU
    harness; the *ratio* between modes is the signal)."""
    from pathlib import Path
    from repro.serve.engine import GenRequest, ServeEngine
    cfg, params, data = _trained_small_lm()
    n_slots, max_new_short = 9, 32
    plen_long, chunk = 2048, 64
    max_len = plen_long + 64
    long_toks = MarkovStream(cfg.vocab_size, batch=1, seq=plen_long,
                             seed=9).batch_at(0)["tokens"][0]
    short_toks = data.batch_at(802)["tokens"]
    rng = np.random.default_rng(7)
    reqs = [GenRequest(prompt=short_toks[i % short_toks.shape[0],
                                         :int(rng.integers(10, 22))].tolist(),
                       max_new=max_new_short) for i in range(8)]
    reqs.append(GenRequest(prompt=long_toks.tolist(), max_new=8))
    arrivals = [0.0] * 8 + [0.3]          # the long prompt lands mid-decode
    results = {"ttft_scenario": {
        "n_decoding_slots": 8, "long_prompt": plen_long,
        "prefill_chunk": chunk, "short_max_new": max_new_short}}
    tokens = {}
    for mode, pc in (("whole_prefill", 0), ("chunked", chunk)):
        engine = ServeEngine(params, cfg, max_len=max_len, n_slots=n_slots,
                             prefill_chunk=pc)
        engine.serve(reqs, arrival_times=arrivals)   # warm jits off-clock
        res = engine.serve(reqs, arrival_times=arrivals)
        gaps = [b - a for r in res[:8]
                for a, b in zip(r.token_times, r.token_times[1:])]
        gaps.sort()
        st = engine.last_stats
        tokens[mode] = [r.tokens for r in res]
        row = {
            "ttft_long_s": round(res[8].prefill_s, 4),
            "short_intertoken_p50_s": round(gaps[len(gaps) // 2], 4),
            "short_intertoken_p99_s": round(gaps[int(len(gaps) * 0.99)], 4),
            "short_intertoken_max_s": round(gaps[-1], 4),
            "max_decode_gap_steps": st["max_decode_gap_steps"],
            "chunk_tokens": st["chunk_tokens"],
            "prefill_jit_shapes": len(engine._prefill_jits),
        }
        results[mode] = row
        _row(f"chunked_ttft_{mode}", st["wall_s"] * 1e6,
             f"ttft_long={row['ttft_long_s']:.3f}s "
             f"p99_intertoken={row['short_intertoken_p99_s']:.3f}s "
             f"max_stall={row['short_intertoken_max_s']:.3f}s")
    results["tokens_identical"] = \
        tokens["whole_prefill"] == tokens["chunked"]
    assert results["tokens_identical"], "chunked admission diverged!"
    results["stall_ratio_whole_over_chunked"] = round(
        results["whole_prefill"]["short_intertoken_max_s"]
        / max(results["chunked"]["short_intertoken_max_s"], 1e-9), 2)
    _row("chunked_ttft_stall_ratio", 0.0,
         f"whole/chunked max-stall="
         f"{results['stall_ratio_whole_over_chunked']:.2f}x "
         f"tokens_identical={results['tokens_identical']}")
    path = Path(out_path or Path(__file__).parent / "BENCH_serving.json")
    _merge_bench_json(path, {"chunked_prefill_ttft": results})
    return results


def bench_speculative(out_path=None):
    """Self-speculative serving on nested-bitstream draft weights: the
    trained small LM is quantized to the 4-bit `lut4_nested` layout and
    served at spec_k in {0, 2, 4} with 3-bit drafts on a mixed-length
    greedy workload. Tracks accepted tok/s and step tok/s against the
    spec_k=0 baseline (PR 5's unified token-budget step), the measured
    accept rate, and the code-bytes-read ratio of a draft pass vs a full
    pass (ceil(n*3/8) / ceil(n*4/8) per row — the nested format's whole
    point). Greedy tokens must be identical at every spec_k."""
    from pathlib import Path
    from repro.core import QuantConfig
    from repro.core.policy import PrecisionPolicy
    from repro.core.packing import code_stream_bytes
    from repro.core.types import QuantizedExperts, QuantizedLinear
    from repro.core.formats import get_format
    from repro.models.quantized import quantize_model_ptq
    from repro.serve.engine import GenRequest, ServeEngine
    cfg, params, data = _trained_small_lm()
    pol = PrecisionPolicy(qcfg=QuantConfig(bits=4), fmt="lut4_nested",
                          method="rtn")
    qp, _ = quantize_model_ptq(params, cfg,
                               {k: jnp.asarray(v)
                                for k, v in data.batch_at(0).items()},
                               policy=pol)

    # weight-stream bytes a draft pass reads vs a full pass, over every
    # nested container (the shared-bitstream prefix property)
    full_b = draft_b = 0

    def visit(node):
        nonlocal full_b, draft_b
        if isinstance(node, (QuantizedLinear, QuantizedExperts)):
            f = get_format(node.fmt)
            if not f.draft_bits:
                return
            n = node.n_cols
            rows = int(np.prod(node.codes.shape[:-1]))
            full_b += rows * code_stream_bytes(n, 4)
            draft_b += rows * code_stream_bytes(n, f.draft_bits)
    jax.tree.map(visit, qp,
                 is_leaf=lambda x: isinstance(x, (QuantizedLinear,
                                                  QuantizedExperts)))
    bytes_ratio = draft_b / max(full_b, 1)

    n_slots, max_new, max_len = 4, 24, 192
    lengths = [16, 48, 96, 16, 48, 16]
    toks = MarkovStream(cfg.vocab_size, batch=1, seq=96,
                        seed=5).batch_at(0)["tokens"][0]
    reqs = [GenRequest(prompt=toks[:l].tolist(), max_new=max_new)
            for l in lengths]
    results = {"scenario": {
        "prompt_lengths": lengths, "max_new": max_new, "n_slots": n_slots,
        "draft_bits": 3, "quant": "rtn@4bit lut4_nested",
        "draft_code_bytes_over_full": round(bytes_ratio, 4)}}
    tokens = {}
    for k in (0, 2, 4):
        engine = ServeEngine(qp, cfg, max_len=max_len, n_slots=n_slots,
                             spec_k=k, draft_bits=3 if k else 0)
        engine.serve(reqs)                         # warm the jits
        res = engine.serve(reqs, track=True)
        st = engine.last_stats
        tokens[k] = [r.tokens for r in res]
        # per speculative round the weight reads are k draft passes at
        # the prefix width + 1 verify at full width, vs k+1 full passes
        round_ratio = (k * bytes_ratio + 1) / (k + 1)
        row = {"step_tok_per_s": round(st["step_tok_per_s"], 2),
               "accepted_tok_per_s": round(st["accepted_tok_per_s"], 2),
               "accept_rate": round(st["accept_rate"], 4),
               "spec_rounds": st["spec_rounds"],
               "drafted_tokens": st["drafted_tokens"],
               "weight_bytes_read_vs_baseline": round(round_ratio, 4),
               "mfu_pct_p50": st["hw"]["mfu_pct"]["p50"],
               "hbm_util_pct_p50": st["hw"]["hbm_util_pct"]["p50"]}
        results[f"spec_k_{k}"] = row
        _row(f"speculative_k{k}", st["wall_s"] * 1e6,
             f"step_tok_s={row['step_tok_per_s']:.1f} "
             f"accepted_tok_s={row['accepted_tok_per_s']:.1f} "
             f"accept_rate={row['accept_rate']:.2f} "
             f"hbm_p50={row['hbm_util_pct_p50']:.2f}%")
    results["tokens_identical"] = (tokens[0] == tokens[2] == tokens[4])
    assert results["tokens_identical"], "speculative decode diverged!"
    _row("speculative_bytes_ratio", 0.0,
         f"draft/full={bytes_ratio:.3f} "
         f"tokens_identical={results['tokens_identical']}")
    path = Path(out_path or Path(__file__).parent / "BENCH_serving.json")
    _merge_bench_json(path, {"speculative": results})
    return results


# -------------------------------------------- mixed-precision policy


def bench_mixed_precision_serving(out_path=None):
    """Uniform 4-bit vs hand-mixed 3-bit-MLP/4-bit-attention vs the
    SEARCHED policy (`core.bitsearch`, budget 3.0 code bits/weight),
    reporting bits/weight, decode throughput and ppl side by side — the
    serving-side counterpart of bench_policy_frontier's quality curve.
    Merges a "mixed_precision" section into BENCH_serving.json."""
    from pathlib import Path
    from repro.core import (LayerRule, PrecisionPolicy, parse_policy,
                            search_policy)
    from repro.models.quantized import (model_storage_report,
                                        quantize_model_ptq)
    from repro.serve.engine import GenRequest, ServeEngine
    cfg, params, data = _trained_small_lm()
    calib = {k: jnp.asarray(v) for k, v in data.batch_at(800).items()}
    base = QuantConfig(bits=4, iters=4, precondition="fixed")
    searched = search_policy(_sensitivity_profile(), budget=3.0)
    scenarios = (
        ("uniform4", PrecisionPolicy.uniform(base)),
        ("mixed_3mlp_4attn", PrecisionPolicy(
            qcfg=base, rules=(LayerRule(pattern="*/mlp/*", bits=3),))),
        ("searched_b3.0", parse_policy(searched.spec, base)),
    )
    rng = np.random.default_rng(42)
    toks = data.batch_at(801)["tokens"]
    reqs = [GenRequest(prompt=toks[i % toks.shape[0],
                                   :int(rng.integers(6, 20))].tolist(),
                       max_new=8) for i in range(8)]
    section = {"searched_spec": searched.spec,
               "searched_budget_bits_per_weight": searched.budget}
    for name, policy in scenarios:
        qp, report = quantize_model_ptq(params, cfg, calib, policy=policy)
        rep = model_storage_report(qp, report)
        engine = ServeEngine(qp, cfg, max_len=64, n_slots=4)
        engine.serve(reqs)      # warm: prefill jits per prompt length
        engine.serve(reqs)
        st = engine.last_stats
        ppl = _eval_ppl(qp, cfg, data)
        section[name] = {
            "code_bits_per_weight": round(_code_bpw(report), 4),
            "storage_bits_per_weight": round(rep["bits_per_weight"], 4),
            "decode_tok_per_s": round(st["decode_tok_per_s"], 2),
            "ppl": round(ppl, 4)}
        _row(f"mixed_policy_{name}", st["wall_s"] * 1e6,
             f"bits_per_weight={rep['bits_per_weight']:.2f} "
             f"decode_tok_s={st['decode_tok_per_s']:.1f} "
             f"ppl={ppl:.3f}")
    path = Path(out_path or Path(__file__).parent / "BENCH_serving.json")
    _merge_bench_json(path, {"mixed_precision": section})
    return section


def bench_policy_frontier(out_path=None):
    """Measured ppl-vs-bits/weight frontier of the precision search
    (paper claim closed loop): the searched allocation at several
    budgets vs uniform 2/3/4-bit vs the hand-mixed 3-MLP/4-attn policy,
    each point quantized with the SAME sequential pipeline and evaluated
    on the held-out batch. Also proves the spec round-trip in anger: the
    headline searched policy is served twice — once straight from the
    search (--auto-policy path) and once from its emitted spec string
    (--policy path) — and the greedy tokens must be bitwise identical.
    Writes BENCH_quality.json.

    Budget semantics: code (checkpoint-stream) bits/weight. On this toy
    model (n = 128/256 input columns) the fp32 codebooks add 1-4 b/w of
    storage overhead that real-scale rows amortize away, so storage
    bits/weight are recorded alongside but budgets are set on code bits
    (see README "Automatic precision search")."""
    from pathlib import Path
    from repro.core import (LayerRule, PrecisionPolicy, parse_policy,
                            search_policy)
    from repro.core.formats import packed_linear_fmt
    from repro.models.quantized import (model_storage_report,
                                        quantize_model_ptq)
    from repro.serve.engine import GenRequest, ServeEngine
    cfg, params, data = _trained_small_lm()
    calib_stream = MarkovStream(cfg.vocab_size, batch=32, seq=128, seed=11)
    calib = {k: jnp.asarray(v)
             for k, v in calib_stream.batch_at(900).items()}
    base = QuantConfig(bits=4, iters=8, precondition="fixed")
    prof = _sensitivity_profile()

    points = {}

    def run_point(name, policy, extra=None):
        qp, report = quantize_model_ptq(params, cfg, calib, policy=policy)
        rep = model_storage_report(qp, report)
        pt = {"code_bits_per_weight": round(_code_bpw(report), 4),
              "storage_bits_per_weight": round(rep["bits_per_weight"], 4),
              "ppl": round(_eval_ppl(qp, cfg, data), 4)}
        pt.update(extra or {})
        points[name] = pt
        _row(f"policy_frontier_{name}", 0.0,
             f"code_bpw={pt['code_bits_per_weight']:.3f} "
             f"storage_bpw={pt['storage_bits_per_weight']:.2f} "
             f"ppl={pt['ppl']:.3f}")
        return qp

    for b in (2, 3, 4):
        qcfg_b = QuantConfig(bits=b, iters=8, precondition="fixed")
        run_point(f"uniform{b}", PrecisionPolicy.uniform(
            qcfg_b, fmt=packed_linear_fmt(b)))
    run_point("mixed_3mlp_4attn", PrecisionPolicy(
        qcfg=base, rules=(LayerRule(pattern="*/mlp/*", bits=3),)))

    searched = {}
    for budget in (2.6, 3.0, 3.4):
        res = search_policy(prof, budget=budget)
        searched[budget] = res
        run_point(f"searched_b{budget}", parse_policy(res.spec, base),
                  extra={"budget": budget, "spec": res.spec,
                         "predicted_err": round(res.total_err, 4)})

    # spec round-trip in anger: auto-policy path vs --policy path must
    # serve bitwise-identical greedy tokens (headline budget 3.0)
    res = searched[3.0]
    rng = np.random.default_rng(42)
    toks = data.batch_at(801)["tokens"]
    reqs = [GenRequest(prompt=toks[i % toks.shape[0],
                                   :int(rng.integers(6, 20))].tolist(),
                       max_new=8) for i in range(8)]
    served = []
    for policy in (parse_policy(res.spec, base),          # auto path
                   parse_policy(str(res.spec), base)):    # emitted string
        qp, _ = quantize_model_ptq(params, cfg, calib, policy=policy)
        engine = ServeEngine(qp, cfg, max_len=64, n_slots=4)
        served.append([r.tokens for r in engine.serve(reqs)])
    tokens_identical = served[0] == served[1]
    assert tokens_identical, "searched spec round-trip diverged!"

    # acceptance: some searched point at budget <= 3.5 dominates
    # uniform 3-bit (<= code bits/weight AND lower ppl)
    uni3 = points["uniform3"]
    dominating = [
        n for n, pt in points.items()
        if n.startswith("searched") and pt.get("budget", 99) <= 3.5
        and pt["code_bits_per_weight"] <= uni3["code_bits_per_weight"]
        and pt["ppl"] < uni3["ppl"]]
    results = {"policy_frontier": {
        "points": points,
        "tokens_identical_auto_vs_policy": tokens_identical,
        "searched_dominates_uniform3": dominating,
        "eval": {"batches": "seed-11 stream, 16-batch mean @901..916",
                 "calib": "32x128 @900", "iters": 8},
    }}
    _row("policy_frontier_acceptance", 0.0,
         f"dominating={dominating} tokens_identical={tokens_identical}")
    assert dominating, (
        "no searched point dominates uniform 3-bit", points)
    path = Path(out_path or Path(__file__).parent / "BENCH_quality.json")
    _merge_bench_json(path, results)
    return results


def bench_chunk_sweep_mfu(out_path=None):
    """Revisit the `prefill_chunk` latency/throughput knob with the MFU
    tracker: sweep the chunk size over an open-loop mixed-length
    workload and report, per chunk size, TTFT p99 (bigger chunks admit
    prompts in fewer steps) against step-level MFU / HBM utilization
    (bigger chunks also pack more lanes per fixed-shape step, amortizing
    the weight stream). The roofline-wired tracker turns each step's
    wall time into achieved-vs-peak percentages, so the knob's cost is
    read in % of hardware rather than raw microseconds. Greedy tokens
    must be identical at every chunk size. Merges into
    BENCH_goodput.json."""
    from pathlib import Path
    from loadgen import poisson_arrivals
    from repro.serve import percentile
    from repro.serve.engine import GenRequest, ServeEngine
    cfg, params, data = _trained_small_lm()
    n_req, max_new = 12, 12
    toks = data.batch_at(803)["tokens"]
    reqs = lambda: [GenRequest(prompt=toks[i % toks.shape[0],
                                           :int(rng2.integers(8, 48))]
                               .tolist(), max_new=max_new)
                    for i in range(n_req)]
    arrivals = poisson_arrivals(rate=16.0, n=n_req, seed=13)
    sweep = {}
    tokens = {}
    for chunk in (8, 16, 32, 64):
        rng2 = np.random.default_rng(5)     # same prompts per chunk size
        engine = ServeEngine(params, cfg, max_len=128, n_slots=4,
                             prefill_chunk=chunk)
        engine.serve(reqs(), arrival_times=arrivals)   # warm jits
        rng2 = np.random.default_rng(5)
        res = engine.serve(reqs(), arrival_times=arrivals, track=True)
        st = engine.last_stats
        tokens[chunk] = [r.tokens for r in res]
        ttfts = [r.prefill_s for r in res]
        row = {
            "ttft_p50_s": round(percentile(ttfts, 50), 4),
            "ttft_p99_s": round(percentile(ttfts, 99), 4),
            "step_tok_per_s": round(st["step_tok_per_s"], 1),
            "mfu_pct_p50": st["hw"]["mfu_pct"]["p50"],
            "hbm_util_pct_p50": st["hw"]["hbm_util_pct"]["p50"],
            "step_bytes": st["hw"]["step_bytes"]["mixed"],
            "token_budget": st["token_budget"],
        }
        sweep[f"chunk_{chunk}"] = row
        _row(f"chunk_sweep_{chunk}", st["wall_s"] * 1e6,
             f"ttft_p99={row['ttft_p99_s']:.3f}s "
             f"mfu_p50={row['mfu_pct_p50']:.2f}% "
             f"hbm_p50={row['hbm_util_pct_p50']:.2f}%")
    first = tokens[8]
    assert all(t == first for t in tokens.values()), \
        "chunk size changed greedy tokens!"
    sweep["tokens_identical_across_chunks"] = True
    path = Path(out_path or Path(__file__).parent / "BENCH_goodput.json")
    _merge_bench_json(path, {"chunk_sweep": sweep})
    return sweep


def bench_degradation(out_path=None):
    """Graceful-degradation curve: the SAME open-loop workload served
    under increasing injected fault rates (step faults, NaN logits,
    stragglers, client cancels — the `chaos_injector` schedule). The
    robustness claims this bench pins down: (1) surviving requests'
    greedy tokens are bitwise the fault-free run's at EVERY rate
    (quarantine/requeue replays deterministically, watchdog retries
    never double-sample); (2) goodput bends rather than cliffs — it
    stays nonzero at the highest rate and at least one request always
    completes (the engine never deadlocks or collapses). Merges the
    rate -> goodput/SLO-attainment/survivor curve into
    BENCH_goodput.json."""
    from pathlib import Path
    from loadgen import build_requests, poisson_arrivals
    from repro.serve import SLO, goodput_report
    from repro.serve.engine import ServeEngine
    from repro.serve.faults import chaos_injector
    cfg, params, _ = _trained_small_lm()
    n_req, max_new, lens = 12, 16, [8, 24, 48]
    engine = ServeEngine(params, cfg, max_len=128, n_slots=4,
                         prefill_chunk=16)
    reqs = build_requests(cfg, n_req, lens, max_new, seed=3)
    arrivals = poisson_arrivals(16.0, n_req, seed=3)
    engine.serve(build_requests(cfg, 4, lens, 4, seed=10))    # warm jits
    slo = SLO(ttft_s=2.0, itl_s=0.5)
    rates = (0.0, 0.03, 0.08, 0.15)
    curve = {"scenario": {"n_requests": n_req, "max_new": max_new,
                          "prompt_lens": lens, "arrival_rate_req_s": 16.0,
                          "fault_rates": list(rates), "chaos_seed": 11}}
    oracle = None
    for rate in rates:
        faults = chaos_injector(11, rate=rate, paged=engine.paged) \
            if rate else None
        res = engine.serve(reqs, arrival_times=arrivals, faults=faults)
        st = engine.last_stats
        if oracle is None:
            oracle = [r.tokens for r in res]
        survivors = [i for i, r in enumerate(res)
                     if r.finish_reason in ("eos", "length")]
        diverged = [i for i in survivors if res[i].tokens != oracle[i]]
        assert not diverged, \
            f"rate {rate}: survivors diverged from oracle: {diverged}"
        good = goodput_report(res, slo, wall_s=st["wall_s"])
        flt = st["faults"]
        row = {"survivors": len(survivors), "n_requests": n_req,
               "goodput_tok_per_s": round(good["goodput_tok_per_s"], 2),
               "slo_attainment": round(good["slo_attainment"], 4),
               "step_retries": flt["step_retries"],
               "quarantines": flt["quarantines"],
               "requeues": flt["requeues"], "poisoned": flt["poisoned"],
               "cancels": flt["cancels"],
               "survivor_tokens_identical": True}
        curve[f"rate_{rate}"] = row
        _row(f"degradation_rate_{rate}", st["wall_s"] * 1e6,
             f"survivors={len(survivors)}/{n_req} "
             f"goodput={row['goodput_tok_per_s']:.1f}tok/s "
             f"slo={row['slo_attainment']:.0%} "
             f"retries={flt['step_retries']} "
             f"requeues={flt['requeues']}")
    # graceful, not cliff-to-zero: even the harshest rate keeps serving
    worst = curve[f"rate_{rates[-1]}"]
    assert worst["survivors"] >= 1, "fault storm killed every request"
    assert worst["goodput_tok_per_s"] > 0, "goodput cliffed to zero"
    assert all(curve[f"rate_{r}"]["goodput_tok_per_s"] > 0
               for r in rates), "a fault rate zeroed goodput"
    path = Path(out_path or Path(__file__).parent / "BENCH_goodput.json")
    _merge_bench_json(path, {"degradation": curve})
    return curve


# ------------------------------------------------------------- Table 7

def bench_prefix_cache(out_path=None):
    """Hot-prefix serving: six requests share a 160-token system prompt
    (10 full pages at page_size 16) — one cold, three exact repeats,
    two with fresh 24-token user tails. With the prefix cache on, the
    repeats map the cached pages into their page table and admission
    skips straight to the final prompt token (one 1-token lane instead
    of ten 16-token chunks); the tailed requests prefill only their
    tails. Asserts greedy tokens are bitwise identical across cache-on
    / cache-off / contiguous-oracle engines, the hit-token accounting
    is exact, and fully-cached TTFT is >=5x below the cache-off repeat.
    Records TTFT and throughput into BENCH_goodput.json."""
    import dataclasses
    from pathlib import Path
    from repro.serve.engine import GenRequest, ServeEngine
    cfg, params, _ = _trained_small_lm()
    ps, plen, tail_len, max_new = 16, 160, 24, 16
    hot = MarkovStream(cfg.vocab_size, batch=1, seq=plen,
                       seed=31).batch_at(0)["tokens"][0].tolist()
    tails = MarkovStream(cfg.vocab_size, batch=2, seq=tail_len,
                         seed=32).batch_at(0)["tokens"]
    reqs = ([GenRequest(prompt=hot, max_new=max_new) for _ in range(4)] +
            [GenRequest(prompt=hot + tails[i].tolist(), max_new=max_new)
             for i in range(2)])
    cfgp = dataclasses.replace(cfg, kv_format="paged", kv_page_size=ps,
                               kv_pages=0)
    results, tokens, ttft = {}, {}, {}
    for mode, (c, on) in (("cache_on", (cfgp, True)),
                          ("cache_off", (cfgp, False)),
                          ("contiguous", (cfg, False))):
        engine = ServeEngine(params, c, max_len=256, n_slots=1,
                             prefill_chunk=ps, prefix_cache=on)
        # warm jits off-clock: the repeated prompt makes the warm-up
        # session hit its own deposit, so the COW page-copy jit compiles
        # here too, not inside the first measured full-hit admission
        engine.serve([reqs[0], reqs[0]])
        res = engine.serve(reqs)
        st = engine.last_stats
        tokens[mode] = [r.tokens for r in res]
        ttft[mode] = [round(r.prefill_s, 4) for r in res]
        row = {"ttft_s": ttft[mode], "wall_s": round(st["wall_s"], 3),
               "decode_tok_per_s": round(st["decode_tok_per_s"], 1),
               "chunk_tokens": st.get("chunk_tokens", 0)}
        if "prefix_cache" in st:
            row["prefix_cache"] = st["prefix_cache"]
        results[mode] = row
        _row(f"prefix_cache_{mode}", st["wall_s"] * 1e6,
             f"ttft_cold={ttft[mode][0]:.3f}s "
             f"ttft_repeat={ttft[mode][1]:.3f}s "
             f"chunk_tokens={row['chunk_tokens']}")
    assert tokens["cache_on"] == tokens["cache_off"] == tokens["contiguous"]
    pc = results["cache_on"]["prefix_cache"]
    # 3 exact repeats skip to the last prompt token (plen-1 each); the 2
    # tailed requests skip the whole 160-token prefix
    assert pc["prefix_hits"] == 5 and pc["prefix_misses"] == 1, pc
    assert pc["prefix_hit_tokens"] == 3 * (plen - 1) + 2 * plen, pc
    assert results["cache_on"]["chunk_tokens"] == \
        plen + 3 * 1 + 2 * tail_len, results["cache_on"]["chunk_tokens"]
    warm = np.mean(ttft["cache_on"][1:4])        # fully-cached admissions
    cold = np.mean(ttft["cache_off"][1:4])       # same requests, no cache
    speedup = cold / max(warm, 1e-9)
    assert speedup >= 5.0, \
        f"fully-cached TTFT speedup {speedup:.1f}x < 5x (warm {warm:.4f}s" \
        f" vs cold {cold:.4f}s)"
    results["ttft_speedup_fully_cached"] = round(float(speedup), 1)
    results["tokens_identical"] = True
    results["workload"] = {"prefix_len": plen, "page_size": ps,
                           "tail_len": tail_len, "max_new": max_new,
                           "requests": len(reqs)}
    _row("prefix_cache_speedup", 0.0,
         f"fully-cached TTFT {speedup:.1f}x lower "
         f"(warm {warm * 1e3:.1f}ms vs cold {cold * 1e3:.1f}ms), "
         f"hit_tokens={pc['prefix_hit_tokens']}")
    path = Path(out_path or Path(__file__).parent / "BENCH_goodput.json")
    _merge_bench_json(path, {"prefix_cache": results})
    return results


def bench_table7_precondition():
    """Preconditioning ablation: fixed-lambda sweep vs adaptive (App. A)."""
    w, h = _llm_like_layer(7)
    results = {}
    for name, cfg in [
        ("lam0.5", QuantConfig(iters=6, precondition="fixed", damp=0.5)),
        ("lam0.01", QuantConfig(iters=6, precondition="fixed", damp=0.01)),
        ("lam1e-4", QuantConfig(iters=6, precondition="fixed", damp=1e-4)),
        ("adaptive", QuantConfig(iters=6, precondition="adaptive")),
    ]:
        res = ganq_quantize(w, h=h, cfg=cfg)
        results[name] = float(layer_objective(w, res.layer.dequantize(), h))
    base = min(results.values())
    for name, err in results.items():
        _row(f"table7_precond_{name}", 0.0,
             f"err={err:.4f} rel_best={err / base:.3f}")


# ------------------------------------------------------------- Fig 1b

def bench_fig1b_weight_stats():
    rng = np.random.default_rng(0)
    w = rng.standard_t(df=4, size=100_000) * 0.02
    g = rng.normal(size=100_000) * w.std()
    kurt = lambda a: float(((a - a.mean()) ** 4).mean() / a.var() ** 2)
    _row("fig1b_kurtosis", 0.0,
         f"heavy_tailed={kurt(w):.1f} gaussian={kurt(g):.1f} "
         "(>3 motivates non-uniform codebooks)")


# ------------------------------------------------------------- §4.4 cost

def bench_quant_cost():
    """Quantization wall time per layer (paper §4.4: ~1h for 7B, K=10)."""
    w, h = _llm_like_layer(3, m=512, n=512, p=2048)
    for name, fn in [
        ("rtn", lambda: rtn_reconstruct(w, 4)),
        ("gptq", lambda: gptq_reconstruct(w, h, 4)),
        ("ganq_k10", lambda: ganq_quantize(
            w, h=h, cfg=QuantConfig(bits=4, iters=10))),
    ]:
        us, _ = _t(fn, reps=1)
        _row(f"quant_cost_{name}_512x512", us, "per-layer wall (CPU)")


_ALL_BENCHES = [
    "bench_table1_storage",
    "bench_table2_layer_error",
    "bench_table2_e2e_ppl",
    "bench_table5_outliers",
    "bench_table6_decode_speedup",
    "bench_table6_kernel_walltime",
    "bench_lut_kernels",
    "bench_serving_throughput",
    "bench_paged_serving",
    "bench_chunked_prefill_ttft",
    "bench_speculative",
    "bench_mixed_precision_serving",
    "bench_policy_frontier",
    "bench_chunk_sweep_mfu",
    "bench_degradation",
    "bench_prefix_cache",
    "bench_table7_precondition",
    "bench_fig1b_weight_stats",
    "bench_quant_cost",
]


def main(argv=None) -> None:
    """Run all benches, or only the names passed on the CLI
    (e.g. `python benchmarks/run.py bench_lut_kernels`)."""
    import sys
    names = (argv if argv is not None else sys.argv[1:]) or _ALL_BENCHES
    unknown = [n for n in names if n not in _ALL_BENCHES]
    if unknown:
        raise SystemExit(f"unknown bench(es) {unknown}; "
                         f"available: {_ALL_BENCHES}")
    print("name,us_per_call,derived")
    for name in names:
        globals()[name]()


if __name__ == "__main__":
    main()
