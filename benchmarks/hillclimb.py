import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimb driver: measures named variants of the three chosen
cells (hypothesis -> change -> measure loop; log in EXPERIMENTS.md §Perf).

  PYTHONPATH=src python -m benchmarks.hillclimb --cell A --variant q4
"""
import argparse
import json
import sys

import jax

from repro.launch.mesh import make_production_mesh
from repro.roofline.analysis import cell_roofline
from repro.sharding.compat import make_mesh


def mesh_of(shape_str):
    if shape_str == "16x16":
        return make_production_mesh(), "16x16"
    dims = tuple(int(x) for x in shape_str.split("x"))
    assert dims[0] * dims[1] == 256
    return make_mesh(dims, ("data", "model")), shape_str


# cell -> (arch, shape); variants below
CELLS = {
    "A": ("deepseek-7b", "decode_32k"),
    "B": ("gemma3-1b", "train_4k"),
    "C": ("qwen3-14b", "train_4k"),
    "C2": ("qwen3-moe-30b-a3b", "train_4k"),
    "D": ("whisper-medium", "train_4k"),
}

VARIANTS = {
    # name: dict(mesh=..., quantized=..., bits=..., remat=...)
    "baseline": dict(),
    "q4": dict(quantized=True, bits=4),
    "q3": dict(quantized=True, bits=3),
    "kv8": dict(kv_quant=True),
    "q4_kv8": dict(quantized=True, bits=4, kv_quant=True),
    "remat_dots": dict(remat="dots"),
    "remat_none": dict(remat="none"),
    "mesh64x4": dict(mesh="64x4"),
    "mesh32x8": dict(mesh="32x8"),
    "mesh64x4_dots": dict(mesh="64x4", remat="dots"),
    "mesh32x8_dots": dict(mesh="32x8", remat="dots"),
    "mesh128x2": dict(mesh="128x2"),
    "mesh128x2_dots": dict(mesh="128x2", remat="dots"),
    "mesh256x1": dict(mesh="256x1"),
}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, choices=list(CELLS))
    ap.add_argument("--variant", required=True, choices=list(VARIANTS))
    ap.add_argument("--out", default="results/hillclimb.jsonl")
    args = ap.parse_args(argv)
    arch, shape = CELLS[args.cell]
    v = VARIANTS[args.variant]
    mesh, mesh_name = mesh_of(v.get("mesh", "16x16"))
    r = cell_roofline(arch, shape, mesh, mesh_name,
                      variant=f"{args.cell}:{args.variant}",
                      quantized=v.get("quantized", False),
                      bits=v.get("bits", 4),
                      remat=v.get("remat", "full"),
                      kv_quant=v.get("kv_quant", False))
    rec = {k: val for k, val in r.to_dict().items() if k != "per_layer"}
    rec["cell"] = args.cell
    print(json.dumps(rec))
    with open(args.out, "a") as f:
        f.write(json.dumps(r.to_dict() | {"cell": args.cell}) + "\n")
    return 0


if __name__ == "__main__":
    sys.exit(main())
