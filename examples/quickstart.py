"""Quickstart: GANQ-quantize one linear layer, compare against RTN/GPTQ.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import (QuantConfig, compute_h, ganq_quantize,
                        gptq_reconstruct, layer_objective, rtn_reconstruct)

# A layer in the paper's regime: heavy-tailed weights (Fig. 1b),
# activation outlier features (LLM hidden states).
rng = np.random.default_rng(0)
m, n, p = 512, 512, 2048
W = jnp.asarray((rng.standard_t(df=4, size=(m, n)) * 0.02).astype(np.float32))
X = rng.normal(size=(n, p)).astype(np.float32)
X[rng.choice(n, 6, replace=False)] *= 30.0          # outlier features
H = compute_h(jnp.asarray(X))

print(f"layer {m}x{n}, {p} calibration tokens")
err_rtn = float(layer_objective(W, rtn_reconstruct(W, 4), H))
err_gptq = float(layer_objective(W, gptq_reconstruct(W, H, 4), H))
print(f"RTN  4-bit layer error : {err_rtn:12.2f}")
print(f"GPTQ 4-bit layer error : {err_gptq:12.2f}")

res = ganq_quantize(W, h=H, cfg=QuantConfig(bits=4, iters=10))
err_ganq = float(layer_objective(W, res.layer.dequantize(), H))
print(f"GANQ 4-bit layer error : {err_ganq:12.2f}  "
      f"({err_rtn / err_ganq:.1f}x better than RTN)")
print("GANQ objective per alternating iteration (eq. 1):")
print("  ", np.array2string(np.asarray(res.err_history), precision=1))
print(f"storage: {res.layer.storage_bits_per_weight():.2f} bits/weight "
      "(codes + per-row fp16 LUT)")
