"""End-to-end PTQ: train a small LM, quantize it layer-by-layer with the
sequential GANQ pipeline, compare perplexity across methods and bit-widths —
then run a mixed-precision `PrecisionPolicy` (3-bit MLPs / 4-bit attention)
through the same pipeline.

    PYTHONPATH=src python examples/quantize_llm.py
    PYTHONPATH=src python examples/quantize_llm.py --report-out report.json
"""
import argparse
import dataclasses
import tempfile

import jax.numpy as jnp

from repro.configs import get_config, reduce_config
from repro.core import LayerRule, PrecisionPolicy, QuantConfig, save_report

ap = argparse.ArgumentParser()
ap.add_argument("--report-out", default=None, metavar="JSON",
                help="write the mixed-precision pass's per-layer "
                     "LayerQuantReport dict as JSON (inspectable offline; "
                     "feeds bitsearch warm starts)")
cli = ap.parse_args()
from repro.data.synthetic import MarkovStream
from repro.models import forward_logits
from repro.models.quantized import model_storage_report, quantize_model_ptq
from repro.train.loop import Trainer, TrainerConfig
from repro.train.optimizer import OptConfig
import jax


def ppl(params, cfg, batch):
    logits = forward_logits(params, batch, cfg).astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, batch["labels"][..., None],
                               axis=-1)[..., 0]
    return float(jnp.exp(jnp.mean(logz - gold)))


cfg = dataclasses.replace(reduce_config(get_config("deepseek-7b")),
                          n_layers=4, d_model=128, n_heads=8, n_kv_heads=8,
                          head_dim=16, d_ff=256, vocab_size=1024)
data = MarkovStream(cfg.vocab_size, batch=8, seq=64, seed=11)
print("training a small LM (150 steps)…")
tr = Trainer(cfg, data, TrainerConfig(steps=150, ckpt_every=1000,
                                      ckpt_dir=tempfile.mkdtemp()),
             opt_cfg=OptConfig(lr=8e-3, warmup_steps=15, total_steps=150,
                               weight_decay=0.0))
tr.run()
params, _, _ = tr.init_or_restore()

calib = {k: jnp.asarray(v) for k, v in
         MarkovStream(cfg.vocab_size, 32, 128, seed=11).batch_at(900).items()}
evalb = {k: jnp.asarray(v) for k, v in data.batch_at(901).items()}
print(f"fp16 baseline ppl: {ppl(params, cfg, evalb):.3f}")
for bits in (4, 3, 2):
    for method in ("rtn", "gptq", "ganq"):
        qcfg = QuantConfig(bits=bits, iters=8, precondition="fixed")
        qp, report = quantize_model_ptq(params, cfg, calib, qcfg, method)
        rep = model_storage_report(qp)
        print(f"{method:5s} {bits}-bit: ppl {ppl(qp, cfg, evalb):7.3f}   "
              f"{rep['bits_per_weight']:.2f} bits/weight "
              f"({len(report)} linears)")

# mixed precision: one pass, per-layer bits by sublayer type
policy = PrecisionPolicy(
    qcfg=QuantConfig(bits=4, iters=8, precondition="fixed"),
    rules=(LayerRule(pattern="*/mlp/*", bits=3),))
qp, report = quantize_model_ptq(params, cfg, calib, policy=policy)
rep = model_storage_report(qp, report)
print(f"mixed 3-bit-mlp/4-bit-attn: ppl {ppl(qp, cfg, evalb):7.3f}   "
      f"{rep['bits_per_weight']:.2f} bits/weight")
for name, r in list(rep["per_layer"].items())[:7]:
    print(f"  {name:24s} {r['bits']}-bit {r['fmt']:12s} "
          f"{r['bits_per_weight']:5.2f} b/w  err {r['err']:.4f}")
if cli.report_out:
    save_report(report, cli.report_out,
                extra={"policy": "*/mlp/*=3", "method": "ganq",
                       "bits_per_weight": rep["bits_per_weight"]})
    print(f"per-layer report written to {cli.report_out}")
