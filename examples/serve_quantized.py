"""Serve a GANQ-quantized model with batched requests — the paper's
deployment scenario (end-to-end driver).

    PYTHONPATH=src python examples/serve_quantized.py
"""
import time

import jax
import jax.numpy as jnp

import tempfile

from repro.configs import get_config, reduce_config
from repro.core import QuantConfig
from repro.data.synthetic import MarkovStream
from repro.models.quantized import quantize_model_ptq
from repro.serve.engine import GenRequest, ServeEngine
from repro.train.loop import Trainer, TrainerConfig
from repro.train.optimizer import OptConfig

cfg = reduce_config(get_config("deepseek-7b"))
data = MarkovStream(cfg.vocab_size, batch=8, seq=64, seed=0)
print("training briefly so generations are non-degenerate…")
tr = Trainer(cfg, data, TrainerConfig(steps=120, ckpt_every=1000,
                                      ckpt_dir=tempfile.mkdtemp()),
             opt_cfg=OptConfig(lr=1e-2, warmup_steps=10, total_steps=120,
                               weight_decay=0.0))
tr.run()
params, _, _ = tr.init_or_restore()
calib = {k: jnp.asarray(v) for k, v in data.batch_at(500).items()}

print("quantizing (GANQ, 4-bit, sequential layer-wise)…")
qparams, _ = quantize_model_ptq(params, cfg, calib,
                                QuantConfig(bits=4, iters=4,
                                            precondition="fixed"), "ganq")

engine = ServeEngine(qparams, cfg, max_len=128, n_slots=4)
# continuous batching: mixed prompt lengths, no grouping required
toks = data.batch_at(1)["tokens"]
lens = [16, 12, 20, 16, 9, 14, 16, 11]
reqs = [GenRequest(prompt=toks[i, :lens[i]].tolist(), max_new=24,
                   temperature=0.0) for i in range(8)]
t0 = time.time()
results = engine.serve(reqs)
dt = time.time() - t0
n_tok = sum(len(r.tokens) for r in results)
st = engine.last_stats
print(f"served {len(reqs)} requests, {n_tok} tokens in {dt:.2f}s "
      f"({n_tok / dt:.1f} tok/s wall, {st['decode_tok_per_s']:.1f} decode "
      f"tok/s, {st['slot_reuses']} slot reuses, 1 CPU core)")
for i, r in enumerate(results[:2]):
    print(f"req{i}: {r.tokens[:12]}…")

# parity: fp16 engine greedy tokens vs quantized engine
fp = ServeEngine(params, cfg, max_len=128, n_slots=4).serve(reqs)
agree = sum(a == b for r1, r2 in zip(results, fp)
            for a, b in zip(r1.tokens, r2.tokens))
total = sum(len(r.tokens) for r in fp)
print(f"greedy-token agreement with fp16: {agree}/{total} "
      f"({100.0 * agree / total:.1f}%)")
