"""Train a small LM for a few hundred steps with the fault-tolerant loop
(checkpoint/restart + straggler monitor + schedule).

    PYTHONPATH=src python examples/train_small.py
"""
import dataclasses
import tempfile

from repro.configs import get_config, reduce_config
from repro.data.synthetic import MarkovStream
from repro.train.loop import Trainer, TrainerConfig
from repro.train.optimizer import OptConfig

cfg = dataclasses.replace(reduce_config(get_config("gemma3-1b")),
                          d_model=128, n_heads=8, n_kv_heads=1, head_dim=16,
                          d_ff=512, vocab_size=2048)
data = MarkovStream(cfg.vocab_size, batch=8, seq=128, seed=3)
tcfg = TrainerConfig(steps=200, ckpt_every=50, log_every=20,
                     ckpt_dir=tempfile.mkdtemp(), remat="none")
trainer = Trainer(cfg, data, tcfg,
                  opt_cfg=OptConfig(lr=6e-3, warmup_steps=20,
                                    total_steps=200, weight_decay=0.0))
res = trainer.run()
print("entropy floor (nats):", round(data.entropy_floor(), 3))
for m in trainer.metrics_log:
    print(f"step {m['step']:4d}  loss {m['loss']:.4f}  "
          f"lr {m['lr']:.2e}  {m['sec'] * 1e3:.1f} ms/step")
print(f"loss {res['first_loss']:.3f} -> {res['final_loss']:.3f} "
      f"({res['steps_run']} steps, ckpts kept: {trainer.ckpt.all_steps()})")
