"""Self-speculative decoding: nested bitstreams, rollback, token identity.

The nested `lut4_nested` format orders each row's codebook so the high
bit-planes of every code form a valid coarser codebook: a draft pass
streams only the leading ceil(n*draft_bits/8) bytes of the shared
bitstream, the verify pass reads the full stream, and storage counts the
stream ONCE. The serving round (k draft passes + one k+1-lane verify +
bitwise rollback of rejected cache writes) must leave greedy outputs
token-identical to non-speculative serving across every cache format.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduce_config
from repro.core import QuantConfig, get_cache_format, quantize_linear
from repro.core.cache_formats import restore_cells, snapshot_cells
from repro.core.codebook import nested_codebooks
from repro.core.formats import get_format, nested_linear_fmt
from repro.core.packing import (code_stream_bytes, nested_stream_cols,
                                unpack_bits_nested)
from repro.core.policy import PrecisionPolicy, parse_policy
from repro.data.synthetic import MarkovStream
from repro.kernels.ops import lut_linear, vmem_plan
from repro.models import init_params
from repro.models.quantized import model_storage_report, quantize_model_ptq
from repro.serve.engine import GenRequest, ServeEngine
from repro.serve.scheduler import PageAllocator


def _setup(arch="deepseek-7b"):
    cfg = reduce_config(get_config(arch))
    params = init_params(jax.random.PRNGKey(0), cfg)
    data = MarkovStream(cfg.vocab_size, batch=4, seq=32, seed=0)
    return cfg, params, data


def _nested_layer(m=16, n=24, seed=0, fmt="lut4_nested"):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=(m, n)).astype(np.float32))
    h = jnp.eye(n, dtype=jnp.float32)
    res = quantize_linear(w, h, QuantConfig(bits=4), "rtn")
    return get_format(fmt).encode(res.layer)


# ------------------------------------------------------- format + kernels

@pytest.mark.parametrize("fmt,db", [("lut4_nested", 3),
                                    ("lut4_nested_d2", 2)])
def test_nested_reencode_preserves_decode(fmt, db):
    """Re-ordering the codebook + splitting the stream must not change the
    decoded weights; re-encoding is idempotent; the draft prefix is a
    contiguous sub-stream decoding against the coarse codebook."""
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(8, 21)).astype(np.float32))
    res = quantize_linear(w, jnp.eye(21, dtype=jnp.float32),
                          QuantConfig(bits=4), "rtn")
    base = res.layer
    f = get_format(fmt)
    assert f.draft_bits == db and nested_linear_fmt(db) == fmt
    lay = f.encode(base)
    assert lay.fmt == fmt
    np.testing.assert_array_equal(np.asarray(f.dequantize(lay)),
                                  np.asarray(base.dequantize()))
    again = f.encode(lay)                        # idempotent
    np.testing.assert_array_equal(np.asarray(again.codes),
                                  np.asarray(lay.codes))
    # the draft view: leading ceil(n*db/8) bytes decode at width db
    n = 21
    hi_cols = code_stream_bytes(n, db)
    assert lay.codes.shape[1] == sum(nested_stream_cols(n, 4, db))
    assert nested_stream_cols(n, 4, db)[0] == hi_cols
    d_codes, d_book = f.draft_view(lay)
    assert d_codes.shape == (8, n) and d_book.shape[1] == 1 << db
    full_codes = unpack_bits_nested(lay.codes, 4, db, n)
    np.testing.assert_array_equal(np.asarray(d_codes),
                                  np.asarray(full_codes) >> (4 - db))
    np.testing.assert_array_equal(
        np.asarray(d_book),
        np.asarray(nested_codebooks(lay.codebook, db)))
    # prefix slice really is byte-contiguous: draft decode only touches
    # the first hi_cols columns
    np.testing.assert_array_equal(
        np.asarray(unpack_bits_nested(
            jnp.concatenate([lay.codes[:, :hi_cols],
                             jnp.zeros_like(lay.codes[:, hi_cols:])], 1),
            4, db, n)) >> (4 - db),
        np.asarray(d_codes))


@pytest.mark.parametrize("db", [2, 3])
def test_nested_lut_linear_full_and_draft_parity(db):
    """`lut_linear` on the nested layout: the full path matches the dense
    decode matmul bitwise-close; the draft path matches the coarse-book
    matmul; XLA and Pallas(interpret) agree."""
    fmt = nested_linear_fmt(db)
    lay = _nested_layer(m=16, n=24, fmt=fmt)
    f = get_format(fmt)
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(24, 3)).astype(np.float32))
    want_full = np.asarray(f.dequantize(lay) @ x)
    d_codes, d_book = f.draft_view(lay)
    want_draft = np.asarray(
        jnp.take_along_axis(d_book, d_codes.astype(jnp.int32), axis=1) @ x)
    for pallas in (False, True):
        got = lut_linear(lay.codes, lay.codebook, x, bits=4, fmt=fmt,
                         use_pallas=pallas)
        np.testing.assert_allclose(np.asarray(got), want_full,
                                   rtol=1e-5, atol=1e-5)
        gotd = lut_linear(lay.codes, lay.codebook, x, bits=4, fmt=fmt,
                          use_pallas=pallas, draft_bits=db)
        np.testing.assert_allclose(np.asarray(gotd), want_draft,
                                   rtol=1e-5, atol=1e-5)


def test_nested_storage_counts_stream_once():
    """Satellite: honest accounting. The draft prefix is a VIEW of the
    shared bitstream, not a second copy — code payload is exactly 4
    bits/weight (0.5 B/wt), and the draft pass reads ceil(n*db/8) bytes
    per row."""
    m, n, db = 16, 24, 3
    lay = _nested_layer(m=m, n=n)
    f = get_format("lut4_nested")
    total, count = f.storage_bits(lay)
    assert count == m * n
    book_bits = lay.codebook.size * lay.codebook.dtype.itemsize * 8
    assert total - book_bits == 4 * count        # stream counted ONCE
    # physical row = hi plane bytes + lo plane bytes, nothing duplicated
    assert lay.codes.shape == (m, code_stream_bytes(n, db)
                               + code_stream_bytes(n, 4 - db))
    # the kernel's draft plan reads exactly the prefix bytes per row
    plan_full = vmem_plan(m, n, 4, 4, block_m=m, block_k=n, block_p=4,
                          fmt="lut4_nested")
    plan_draft = vmem_plan(m, n, 4, 4, block_m=m, block_k=n, block_p=4,
                           fmt="lut4_nested", draft_bits=db)
    assert plan_draft["codes_bytes"] == m * code_stream_bytes(n, db)
    assert plan_full["codes_bytes"] == m * code_stream_bytes(n, 4)
    # whole-model report: nested bits/weight == the plain packed layout's
    # (same payload), never payload + prefix
    cfg, params, data = _setup()
    pol = PrecisionPolicy(qcfg=QuantConfig(bits=4), fmt="lut4_nested",
                          method="rtn")
    qp, _ = quantize_model_ptq(params, cfg, data.batch_at(0), policy=pol)
    pol_p = PrecisionPolicy(qcfg=QuantConfig(bits=4), fmt="lut4_packed",
                            method="rtn")
    qp_p, _ = quantize_model_ptq(params, cfg, data.batch_at(0),
                                 policy=pol_p)
    rep = model_storage_report(qp)
    assert rep["bits_per_weight"] == pytest.approx(
        model_storage_report(qp_p)["bits_per_weight"])


def test_policy_draft_entry_selects_nested_format():
    pol = parse_policy("draft=3,kv=paged", QuantConfig(bits=4))
    assert pol.draft_bits == 3 and pol.fmt == "lut4_nested"
    assert pol.kv_fmt == "paged"
    pol2 = parse_policy("draft=2", QuantConfig(bits=4))
    assert pol2.fmt == "lut4_nested_d2"


# ------------------------------------------------------- rollback property

@pytest.mark.parametrize("kv", ["full", "int8", "paged", "paged_int8"])
def test_rollback_cache_bitwise_identical(kv):
    """Property: random accept/reject rounds through snapshot/write/restore
    leave the cache bitwise identical to a twin that only ever received
    the accepted writes (paged formats under PageAllocator churn)."""
    cfg, _, _ = _setup()
    ps, n_pages, n_slots, width, k = 4, 24, 3, 32, 3
    paged = kv.startswith("paged")
    cfgk = dataclasses.replace(cfg, kv_format=kv, kv_page_size=ps,
                               kv_pages=n_pages)
    f = get_cache_format(kv)
    spec = f.init(n_slots, width, cfgk, jnp.float32)
    oracle = f.init(n_slots, width, cfgk, jnp.float32)
    spec = {"units": [], "tail": [spec]}
    oracle = {"units": [], "tail": [oracle]}
    alloc = PageAllocator(n_pages, ps, n_slots, width // ps) if paged \
        else None
    rng = np.random.default_rng(3)
    pos = np.zeros(n_slots, np.int64)
    kv_shape = (n_slots, 1, cfg.n_kv_heads, cfg.head_dim)

    def write(tree, p_np, active):
        knew = jnp.asarray(rng.normal(size=kv_shape).astype(np.float32))
        vnew = jnp.asarray(rng.normal(size=kv_shape).astype(np.float32))
        pages = None if alloc is None else jnp.asarray(alloc.table())
        st = f.write(tree["tail"][0], knew, vnew, jnp.asarray(p_np),
                     active=jnp.asarray(active), pages=pages)
        return {"units": [], "tail": [st]}, (knew, vnew)

    for _ in range(12):
        for i in range(n_slots):       # out of headroom: recycle the slot
            if pos[i] + k + 1 > width - 1:   # (finish + readmission)
                if alloc is not None:
                    alloc.release(i)
                pos[i] = 0
        n_acc = rng.integers(0, k + 2, size=n_slots)   # accepted per slot
        if alloc is not None:
            for i in range(n_slots):
                assert alloc.ensure(i, int(pos[i]) + k + 1)
            alloc.check()
        pages = None if alloc is None else jnp.asarray(alloc.table())
        slots = np.repeat(np.arange(n_slots, dtype=np.int32), k + 1)
        cells = np.concatenate(
            [pos[i] + 1 + np.arange(k + 1) for i in range(n_slots)]
        ).astype(np.int32)
        snap = snapshot_cells(spec, jnp.asarray(slots), jnp.asarray(cells),
                              pages=pages)
        writes = []
        for j in range(k + 1):                     # speculative writes: ALL
            spec, rows = write(spec, pos + 1 + j, np.ones(n_slots, bool))
            writes.append(rows)
        for j in range(k + 1):                     # oracle: accepted only
            knew, vnew = writes[j]
            active = jnp.asarray(j < n_acc)
            st = f.write(oracle["tail"][0], knew, vnew,
                         jnp.asarray(pos + 1 + j), active=active,
                         pages=pages)
            oracle = {"units": [], "tail": [st]}
        keep = np.concatenate([np.arange(k + 1) >= n_acc[i]
                               for i in range(n_slots)])
        spec = restore_cells(spec, snap, jnp.asarray(slots),
                             jnp.asarray(cells), jnp.asarray(keep),
                             pages=pages)
        for key in spec["tail"][0].data:
            a = np.asarray(spec["tail"][0].data[key])
            b = np.asarray(oracle["tail"][0].data[key])
            if paged:          # the scratch page (last pool row) is the
                a, b = a[:n_pages], b[:n_pages]   # designated trash bin
            np.testing.assert_array_equal(a, b, err_msg=key)
        pos += n_acc
        if alloc is not None and rng.random() < 0.3:
            i = int(rng.integers(0, n_slots))      # churn: evict + readmit
            alloc.release(i)
            pos[i] = 0
            alloc.check()

    # duplicated writes must overwrite each other deterministically only
    # for distinct cells — the engine guarantees k+1 <= ring width
    assert k + 1 <= width


# ------------------------------------------------------------ engine guards

def test_moe_spec_guard_rejects_dropping_configs():
    """Satellite: spec_k > 0 over a dropping MoE must be refused at
    construction — the k+1-lane verify dispatch could drop tokens and
    silently break token identity."""
    cfg, params, _ = _setup("qwen3-moe-30b-a3b")
    # reduced config has capacity_factor >= n_experts: constructs fine
    eng = ServeEngine(params, cfg, max_len=32, n_slots=2, spec_k=2)
    assert eng.spec_k == 2
    tight = dataclasses.replace(cfg, capacity_factor=1.25)
    ServeEngine(params, tight, max_len=32, n_slots=2)      # plain: fine
    with pytest.raises(ValueError, match="dropping-MoE"):
        ServeEngine(params, tight, max_len=32, n_slots=2, spec_k=2)


def test_recurrent_and_ring_fallbacks():
    cfg, params, _ = _setup("rwkv6-7b")
    eng = ServeEngine(params, cfg, max_len=32, n_slots=2, spec_k=3)
    assert eng.spec_k == 0 and "recurrent" in eng.spec_fallback
    cfg2, params2, _ = _setup("gemma3-1b")     # sliding-window 'local'
    w = min(32, cfg2.sliding_window)
    eng2 = ServeEngine(params2, cfg2, max_len=32, n_slots=2, spec_k=w + 4)
    assert eng2.spec_k == w - 1                # ring cells must be distinct


# -------------------------------------------------------- token identity

def _serve_pair(cfg, params, k, draft_bits, reqs, n_slots=2, max_len=64):
    base = ServeEngine(params, cfg, max_len=max_len, n_slots=n_slots,
                       prefill_chunk=8)
    r0 = base.serve(reqs, seed=0)
    eng = ServeEngine(params, cfg, max_len=max_len, n_slots=n_slots,
                      prefill_chunk=8, spec_k=k, draft_bits=draft_bits)
    rk = eng.serve(reqs, seed=0)
    for a, b in zip(r0, rk):
        assert a.tokens == b.tokens, (a.tokens, b.tokens)
    return eng.last_stats


def _reqs(cfg, n=3, max_new=10):
    data = MarkovStream(cfg.vocab_size, batch=n, seq=32, seed=0)
    toks = np.asarray(data.batch_at(0)["tokens"])
    return [GenRequest(prompt=list(map(int, toks[i, :7 + 4 * i])),
                       max_new=max_new, temperature=0.0) for i in range(n)]


@pytest.mark.parametrize("kv", ["full", "int8", "paged", "paged_int8"])
@pytest.mark.parametrize("k", [2, 4])
def test_greedy_token_identity_all_cache_formats(kv, k):
    """Speculative greedy serving is token-identical to spec_k=0 on every
    attention cache layout (exact drafts isolate the round/rollback
    machinery from draft quality)."""
    cfg, params, _ = _setup()
    cfg = dataclasses.replace(cfg, kv_format=kv)
    st = _serve_pair(cfg, params, k, 0, _reqs(cfg))
    assert st["spec_rounds"] > 0
    assert st["accept_rate"] == 1.0            # exact drafts always match
    assert st["accepted_tok_per_s"] > 0
    assert st["spec_k"] == k


@pytest.mark.parametrize("kv", ["full", "paged_int8"])
def test_greedy_token_identity_moe(kv):
    """Second config, with experts: the k+1-lane verify routes through the
    no-drop-guarded MoE dispatch and must stay token-identical."""
    cfg, params, _ = _setup("qwen3-moe-30b-a3b")
    cfg = dataclasses.replace(cfg, kv_format=kv)
    st = _serve_pair(cfg, params, 3, 0, _reqs(cfg))
    assert st["spec_rounds"] > 0 and st["accept_rate"] == 1.0


def test_greedy_token_identity_nested_quantized_drafts():
    """The real thing: 4-bit nested-quantized model drafting at 3-bit
    prefix width — outputs stay token-identical and some (not necessarily
    all) drafts are accepted."""
    cfg, params, data = _setup()
    pol = PrecisionPolicy(qcfg=QuantConfig(bits=4), fmt="lut4_nested",
                          method="rtn")
    qp, _ = quantize_model_ptq(params, cfg, data.batch_at(0), policy=pol)
    st = _serve_pair(cfg, qp, 3, 3, _reqs(cfg))
    assert st["spec_rounds"] > 0
    assert st["drafted_tokens"] > 0
    assert 0.0 <= st["accept_rate"] <= 1.0
    assert st["spec_draft_bits"] == 3


def test_sliding_window_ring_rollback_identity():
    """Rejected draft writes on a contiguous sliding-window ring clobber
    LIVE history cells — identity here proves the bitwise rollback (and
    the pre-verify residue restore) actually work."""
    cfg, params, _ = _setup("gemma3-1b")
    st = _serve_pair(cfg, params, 3, 0, _reqs(cfg))
    assert st["spec_rounds"] > 0 and st["accept_rate"] == 1.0
