"""CacheFormat registry + paged KV cache: layouts, allocator, scheduler.

Token-equivalence tests drive the full continuous-batching engine on the
paged formats and compare greedy outputs request-by-request against the
contiguous reference path — across every cache variant (full fp, int8 KV,
sliding-window ring + RG-LRU state, RWKV-6 state).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduce_config
from repro.core import (CacheState, available_cache_formats, contiguous_cfg,
                        get_cache_format, kv_cache_bytes, kv_format_of,
                        parse_policy, QuantConfig)
from repro.data.synthetic import MarkovStream
from repro.models import init_params
from repro.serve.engine import GenRequest, ServeEngine
from repro.serve.scheduler import GenRequest as SchedRequest
from repro.serve.scheduler import PageAllocator, SlotScheduler


def _setup(arch="deepseek-7b"):
    cfg = reduce_config(get_config(arch))
    params = init_params(jax.random.PRNGKey(0), cfg)
    data = MarkovStream(cfg.vocab_size, batch=4, seq=32, seed=0)
    return cfg, params, data


# ----------------------------------------------------------------- registry

def test_registry_has_all_variants():
    for name in ("full", "int8", "paged", "paged_int8", "rwkv_state",
                 "rglru_state", "cross_kv"):
        assert name in available_cache_formats()
    assert get_cache_format("paged").backing == "full"
    assert get_cache_format("paged_int8").backing == "int8"
    with pytest.raises(KeyError):
        get_cache_format("nope")


def test_kv_format_resolution_and_policy_spec():
    cfg, _, _ = _setup()
    assert kv_format_of(cfg) == "full"
    assert kv_format_of(dataclasses.replace(cfg, kv_quant_bits=8)) == "int8"
    assert kv_format_of(dataclasses.replace(cfg, kv_format="paged")) \
        == "paged"
    # one policy spec carries weights AND cache layout
    pol = parse_policy("mlp=3,attn=4,kv=paged_int8", QuantConfig(bits=4))
    assert pol.kv_fmt == "paged_int8"
    assert len(pol.rules) == 2
    cfg2 = pol.apply_kv_format(cfg)
    assert kv_format_of(cfg2) == "paged_int8"
    assert contiguous_cfg(cfg2).kv_format == "int8"
    with pytest.raises(KeyError):
        parse_policy("kv=bogus", QuantConfig())
    with pytest.raises(AssertionError):
        parse_policy("kv=rwkv_state", QuantConfig())   # not an attn cache
    with pytest.raises(AssertionError):
        parse_policy("kv=cross_kv", QuantConfig())     # not selectable
    with pytest.raises(AssertionError):                # config path too
        kv_format_of(dataclasses.replace(cfg, kv_format="cross_kv"))


def test_paged_write_read_matches_contiguous():
    """Single-layer oracle: the paged container's gathered view must hold
    exactly what the contiguous ring holds for the same writes."""
    cfg, _, _ = _setup()
    ps, n_pages, b, steps = 4, 6, 2, 9
    cfgp = dataclasses.replace(cfg, kv_format="paged", kv_page_size=ps,
                               kv_pages=n_pages)
    full = get_cache_format("full")
    paged = get_cache_format("paged")
    c_full = full.init(b, 16, cfg, jnp.float32)
    c_paged = paged.init(b, 16, cfgp, jnp.float32)
    # slot 0 owns pages [5,3,1], slot 1 owns [0,2,4] (deliberately shuffled)
    pages = jnp.asarray([[5, 3, 1, -1], [0, 2, 4, -1]], jnp.int32)
    rng = np.random.default_rng(0)
    for t in range(steps):
        k = jnp.asarray(rng.normal(size=(b, 1, cfg.n_kv_heads,
                                         cfg.head_dim)).astype(np.float32))
        v = jnp.asarray(rng.normal(size=k.shape).astype(np.float32))
        pos = jnp.full((b,), t, jnp.int32)
        c_full = full.write(c_full, k, v, pos)
        c_paged = paged.write(c_paged, k, v, pos, pages=pages)
    kf, vf = full.read(c_full, jnp.float32)
    kp, vp = paged.read(c_paged, jnp.float32, pages=pages)
    np.testing.assert_allclose(np.asarray(kp[:, :steps]),
                               np.asarray(kf[:, :steps]))
    np.testing.assert_allclose(np.asarray(vp[:, :steps]),
                               np.asarray(vf[:, :steps]))
    pos = jnp.full((b,), steps - 1, jnp.int32)
    visf = full.visible(c_full, pos, "causal", 0)
    visp = paged.visible(c_paged, pos, "causal", 0, pages=pages)
    np.testing.assert_array_equal(np.asarray(visp[:, :steps]),
                                  np.asarray(visf[:, :steps]))
    assert not np.asarray(visp[:, steps:]).any()   # unwritten/unmapped


def test_inactive_paged_write_lands_on_scratch():
    cfg, _, _ = _setup()
    cfgp = dataclasses.replace(cfg, kv_format="paged", kv_page_size=4,
                               kv_pages=2)
    paged = get_cache_format("paged")
    c = paged.init(2, 8, cfgp, jnp.float32)
    pages = jnp.asarray([[0, -1], [1, -1]], jnp.int32)
    k = jnp.ones((2, 1, cfg.n_kv_heads, cfg.head_dim), jnp.float32)
    active = jnp.asarray([True, False])
    c = paged.write(c, k, k, jnp.zeros((2,), jnp.int32), active=active,
                    pages=pages)
    pool = np.asarray(c["k_pages"])
    assert pool[0, 0].any()            # active slot wrote its page
    assert not pool[1].any()           # inactive slot's page untouched
    assert pool[2, 0].any()            # ... the write went to scratch


# ------------------------------------------------------------ page allocator

def test_page_allocator_property_churn():
    """Free + uniquely-owned + shared(refs>=2) + quarantined pages always
    partition range(n_pages) across random admit/share/COW/evict/
    quarantine churn; no page leaked or double-owned; table rows mirror
    ownership (None -> -1 during windowed release)."""
    rng = np.random.default_rng(7)
    alloc = PageAllocator(n_pages=13, page_size=4, n_slots=3,
                          max_pages_per_slot=5)
    cache_held = []                    # refcounts held by a prefix cache
    for step in range(500):
        op = rng.integers(0, 8)
        slot = int(rng.integers(0, 3))
        if op == 0:
            alloc.alloc(slot, int(rng.integers(1, 4)))
        elif op == 1:
            alloc.ensure(slot, int(rng.integers(0, 20)))
        elif op == 2:
            # deposit before release: cache keeps a ref on the pages
            for pg in alloc.owned[slot]:
                if pg not in cache_held:
                    cache_held.append(pg)
                    alloc.cache_hold(pg)
            alloc.release(slot)
        elif op == 3 and cache_held and not alloc.owned[slot]:
            # prefix hit: map a run of cache-held pages into an idle slot
            n = int(rng.integers(1, min(len(cache_held), 5) + 1))
            alloc.share(slot, cache_held[:n])
        elif op == 4 and alloc.owned[slot] and alloc.available:
            # COW a random owned page (no-op unless actually shared)
            j = int(rng.integers(0, len(alloc.owned[slot])))
            alloc.cow(slot, j)
        elif op == 5 and cache_held:
            # cache-tier eviction drops a ref; page frees iff unshared
            pg = cache_held.pop(int(rng.integers(0, len(cache_held))))
            alloc.cache_drop(pg)
        elif op == 6:
            alloc.quarantine_free_pages(int(rng.integers(1, 3)))
        else:
            alloc.restore_quarantined()
        alloc.check()                  # the invariant
        part = alloc.partition()
        assert sorted(part["free"] + part["unique"] + part["shared"]
                      + part["quarantined"]) == list(range(13))
        assert all(alloc.refs[p] >= 2 for p in part["shared"])
        t = alloc.table()
        for i in range(3):
            owned = alloc.owned[i]
            assert list(t[i, :len(owned)]) == \
                [-1 if p is None else p for p in owned]
            assert (t[i, len(owned):] == -1).all()
    alloc.restore_quarantined()
    for pg in cache_held:
        alloc.cache_drop(pg)
    for slot in range(3):
        alloc.release(slot)
    alloc.check()
    assert alloc.available == 13       # everything returned to the pool


def test_page_allocator_bounds():
    alloc = PageAllocator(n_pages=4, page_size=8, n_slots=2,
                          max_pages_per_slot=3)
    assert alloc.alloc(0, 3)
    assert not alloc.alloc(0, 1)       # per-slot cap
    assert not alloc.alloc(1, 2)       # pool exhausted (1 free)
    assert alloc.alloc(1, 1)
    assert alloc.available == 0
    assert alloc.release(0) == 3
    assert alloc.available == 3
    assert alloc.ensure(1, 15)         # pos 15 -> 2 pages total
    assert len(alloc.owned[1]) == 2
    alloc.check()


# ------------------------------------------------------------ EDF scheduler

def test_edf_admission_orders_by_deadline():
    s = SlotScheduler(n_slots=1, max_len=32)
    r_none = SchedRequest(prompt=[1], max_new=1)
    r_late = SchedRequest(prompt=[2], max_new=1, deadline_s=9.0)
    r_soon = SchedRequest(prompt=[3], max_new=1, deadline_s=1.0)
    for r in (r_none, r_late, r_soon):
        s.submit(r)
    assert s.next_ready(0.0) is r_soon     # earliest deadline first
    assert s.next_ready(0.0) is r_late
    assert s.next_ready(0.0) is r_none     # deadline-free sorts last


def test_edf_respects_arrival_times():
    s = SlotScheduler(n_slots=1, max_len=32)
    r_future = SchedRequest(prompt=[1], max_new=1, deadline_s=0.5,
                            arrival_s=10.0)
    r_now = SchedRequest(prompt=[2], max_new=1, deadline_s=5.0)
    s.submit(r_future)
    s.submit(r_now)
    assert s.next_ready(0.0) is r_now      # unarrived EDF winner waits
    assert s.next_ready(0.0) is None
    assert s.next_ready(11.0) is r_future


def test_paged_chunk_scheduling_reserves_and_evicts_lower_priority():
    """Pages reserve per CHUNK as prompts are laned (not per prompt at
    admission); a higher-priority slot's chunk evicts a strictly-lower-
    priority active slot when the pool runs dry, an equal-priority one
    stalls without starving co-scheduled streams."""
    alloc = PageAllocator(n_pages=4, page_size=8, n_slots=2,
                          max_pages_per_slot=4)
    s = SlotScheduler(n_slots=2, max_len=32, alloc=alloc)
    low = SchedRequest(prompt=[1] * 20, max_new=4, priority=0)
    s.submit(low)
    req = s.next_ready(0.0, slot=0)
    assert req is low and len(alloc.owned[0]) == 0   # admission: no pages
    s.admit_chunked(0, req, now_s=0.0)
    lanes = s.schedule_step(budget=32, chunk_cap=32, now_s=0.0)
    assert lanes["n_chunk"] == 20                    # whole prompt laned
    assert len(alloc.owned[0]) == 3                  # chunk reserved 3 pages
    s.record_scheduled(np.asarray([5, 0]), now_s=0.0)
    assert s.slots[0].tokens == [5]
    # an equal-priority peer cannot evict: it binds but its chunk stalls
    # while slot 0's decode lane keeps running every step
    peer = SchedRequest(prompt=[2] * 20, max_new=4, priority=0)
    s.submit(peer)
    s.admit_chunked(1, s.next_ready(0.0, slot=1), now_s=0.0)
    lanes = s.schedule_step(budget=32, chunk_cap=32, now_s=0.1)
    assert lanes["n_decode"] == 1 and lanes["n_chunk"] == 0
    assert len(alloc.owned[1]) == 0
    s.record_scheduled(np.asarray([6, 0]), now_s=0.1)
    # a higher-priority request's chunk evicts the low-priority decoder
    s.evict(1, now_s=0.2)                            # free the peer's slot
    vip = SchedRequest(prompt=[3] * 20, max_new=4, priority=1,
                       deadline_s=1.0)
    s.submit(vip)
    assert s.next_ready(0.2, slot=1) is vip          # EDF: deadline first
    s.admit_chunked(1, vip, now_s=0.2)
    lanes = s.schedule_step(budget=32, chunk_cap=32, now_s=0.2)
    assert lanes["n_chunk"] == 20                    # vip's chunk laned
    assert s.slots[0] is None and s.evictions >= 2   # low evicted for pages
    assert low in s.queue                            # preempted: requeued
    assert len(alloc.owned[1]) == 3
    alloc.check()


# ---------------------------------------- paged vs contiguous equivalence

def _paged_equiv(arch, base_cfg_tf, paged_fmt, page_size=8, kv_pages=0,
                 batch_at=3):
    cfg, params, data = _setup(arch)
    cfg = base_cfg_tf(cfg)
    cfgp = dataclasses.replace(cfg, kv_format=paged_fmt,
                               kv_page_size=page_size, kv_pages=kv_pages)
    toks = data.batch_at(batch_at)["tokens"]
    reqs = [GenRequest(prompt=toks[i, :l].tolist(), max_new=m)
            for i, (l, m) in enumerate([(8, 4), (12, 3), (6, 4)])]
    eng_p = ServeEngine(params, cfgp, max_len=48, n_slots=2)
    eng_c = ServeEngine(params, cfg, max_len=48, n_slots=2)
    res_p = eng_p.serve(reqs)
    res_c = eng_c.serve(reqs)
    for a, b in zip(res_p, res_c):
        assert a.tokens == b.tokens, (a.tokens, b.tokens)
    return eng_p


def test_paged_equivalence_full():
    eng = _paged_equiv("deepseek-7b", lambda c: c, "paged")
    assert eng.last_stats["peak_pages_in_use"] >= 1
    assert eng.last_stats["evictions"] == 0


def test_paged_equivalence_int8():
    _paged_equiv("deepseek-7b",
                 lambda c: dataclasses.replace(c, kv_quant_bits=8),
                 "paged_int8")


def test_paged_equivalence_ring_and_rglru():
    """recurrentgemma: sliding-window ('local') attention + RG-LRU state —
    the paged window is mask-enforced, state formats ride along."""
    _paged_equiv("recurrentgemma-2b", lambda c: c, "paged", batch_at=6)


def test_paged_equivalence_rwkv_state():
    """rwkv6: attention-free — the paged config must be a no-op for pure
    recurrent-state caches."""
    _paged_equiv("rwkv6-7b", lambda c: c, "paged", batch_at=9)


def test_paged_pressure_eviction_token_identical():
    """A pool far below the dense equivalent forces preemption by
    recompute; greedy tokens must still match the contiguous reference and
    no page may leak."""
    cfg, params, data = _setup()
    cfgp = dataclasses.replace(cfg, kv_format="paged", kv_page_size=4,
                               kv_pages=7)
    toks = data.batch_at(5)["tokens"]
    reqs = [GenRequest(prompt=toks[0, :8].tolist(), max_new=10),
            GenRequest(prompt=toks[1, :9].tolist(), max_new=10, priority=1),
            GenRequest(prompt=toks[2, :8].tolist(), max_new=6)]
    eng_p = ServeEngine(params, cfgp, max_len=64, n_slots=2)
    res_p = eng_p.serve(reqs)
    assert eng_p.last_stats["evictions"] >= 1
    assert res_p[1].evictions == 0        # priority-1 request never evicted
    eng_c = ServeEngine(params, cfg, max_len=64, n_slots=2)
    for a, b in zip(res_p, eng_c.serve(reqs)):
        assert a.tokens == b.tokens, (a.tokens, b.tokens)


def test_paged_pool_smaller_than_dense():
    """kv_pages below the dense equivalent must shrink reported KV bytes."""
    cfg, params, data = _setup()
    toks = data.batch_at(2)["tokens"]
    reqs = [GenRequest(prompt=toks[i, :8].tolist(), max_new=3)
            for i in range(2)]
    dense = ServeEngine(params, cfg, max_len=64, n_slots=4)
    dense.serve(reqs)
    cfgp = dataclasses.replace(cfg, kv_format="paged", kv_page_size=8,
                               kv_pages=8)     # 64 tokens vs 4*64 dense
    paged = ServeEngine(params, cfgp, max_len=64, n_slots=4)
    paged.serve(reqs)
    assert paged.last_stats["kv_cache_bytes"] \
        < dense.last_stats["kv_cache_bytes"] / 2


# --------------------------------------------------- grouped format splits

def test_split_format_groups_mixed():
    from repro.core.formats import get_format
    from repro.core.types import QuantizedLinear
    from repro.kernels.ops import split_format_groups
    from repro.models.linears import linear_apply, linear_apply_grouped
    from repro.sharding.context import LOCAL
    rng = np.random.default_rng(0)
    n = 64

    def mk(m, bits, fmt):
        c = jnp.asarray(rng.integers(0, 1 << bits,
                                     size=(m, n)).astype(np.uint8))
        t = jnp.asarray(rng.normal(size=(m, 1 << bits)).astype(np.float32))
        return get_format(fmt).encode(
            QuantizedLinear(codes=c, codebook=t, bits=bits))

    # mixed 4-bit wq / 3-bit wk+wv: the k/v pair must still fuse
    ws = [mk(128, 4, "lut4_packed"), mk(32, 3, "lut3_packed"),
          mk(32, 3, "lut3_packed")]
    groups = split_format_groups(ws)
    assert sorted(sum(groups, [])) == [0, 1, 2]
    assert [1, 2] in groups
    # uniform formats: one fused group; dense members stay singletons
    ws_u = [mk(128, 4, "lut4_packed"), mk(32, 4, "lut4_packed"),
            mk(32, 4, "lut4_packed")]
    assert split_format_groups(ws_u) == [[0, 1, 2]]
    ws_d = [jnp.zeros((n, 16)), mk(32, 4, "lut4_packed"),
            mk(32, 4, "lut4_packed")]
    assert split_format_groups(ws_d) == [[0], [1, 2]]
    # numerics: sub-grouped fused == fully sequential
    x = jnp.asarray(rng.normal(size=(2, 5, n)).astype(np.float32))
    ctx = LOCAL.with_lut_backend("pallas")
    fused = linear_apply_grouped(ws, x, ctx=ctx)
    for a, b in zip(fused, (linear_apply(w, x, ctx=ctx) for w in ws)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)


# -------------------------------------------------------------- bookkeeping

def test_kv_cache_bytes_counts_kv_only():
    from repro.models.transformer import init_stack_cache
    cfg, _, _ = _setup("recurrentgemma-2b")    # local attn + rglru state
    cache = init_stack_cache(2, 16, cfg, jnp.bfloat16)
    total = kv_cache_bytes(cache)
    states = [s for s in jax.tree.leaves(
        cache, is_leaf=lambda x: isinstance(x, CacheState))
        if isinstance(s, CacheState)]
    kv_leaf_bytes = sum(
        leaf.size * leaf.dtype.itemsize
        for st in states if get_cache_format(st.fmt).kv
        for leaf in st.data.values())
    assert total == kv_leaf_bytes
    assert total > 0
