"""Per-architecture smoke tests (reduced configs, CPU): one forward/train
step asserting output shapes + finiteness, plus prefill/decode parity."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, list_archs, reduce_config
from repro.models import (decode_step, forward_logits, init_params,
                          init_serve_cache, prefill, train_loss)

B, S = 2, 32
KEY = jax.random.PRNGKey(0)


def make_batch(cfg, rng, s=S):
    if cfg.frontend == "patches":
        return {"embeds": jnp.asarray(
                    rng.normal(size=(B, s, cfg.d_model)).astype(np.float32)),
                "positions": jnp.tile(jnp.arange(s)[None, None], (3, B, 1)),
                "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, s)))}
    if cfg.frontend == "frames":
        return {"frames": jnp.asarray(
                    rng.normal(size=(B, s, cfg.d_model)).astype(np.float32)),
                "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, s))),
                "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, s)))}
    return {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, s))),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, s)))}


@pytest.mark.parametrize("arch", list_archs())
def test_train_step_smoke(arch):
    cfg = reduce_config(get_config(arch))
    params = init_params(KEY, cfg)
    batch = make_batch(cfg, np.random.default_rng(0))
    loss, grads = jax.value_and_grad(
        lambda p: train_loss(p, batch, cfg))(params)
    assert jnp.isfinite(loss), arch
    leaves = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in leaves), arch
    logits = forward_logits(params, batch, cfg)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", [a for a in list_archs()
                                  if get_config(a).frontend == "tokens"])
def test_prefill_decode_parity(arch):
    """logits from (prefill S tokens, then decode token S) must match the
    teacher-forced forward over S+1 tokens."""
    cfg = reduce_config(get_config(arch))
    params = init_params(KEY, cfg)
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S + 1)))
    full = forward_logits(params, {"tokens": toks}, cfg)

    logits_p, cache = prefill(params, {"tokens": toks[:, :S]}, cfg,
                              cache_len=S + 8)
    np.testing.assert_allclose(np.asarray(logits_p),
                               np.asarray(full[:, S - 1]),
                               rtol=1e-3, atol=1e-4)
    pos = jnp.full((B,), S, jnp.int32)
    logits_d, _ = decode_step(params, cache, toks[:, S], pos, cfg)
    np.testing.assert_allclose(np.asarray(logits_d), np.asarray(full[:, S]),
                               rtol=1e-3, atol=1e-4)


def test_decode_chain_matches_forward_rwkv():
    """Multi-step decode must track the chunked-parallel forward (state
    handoff across chunks + steps)."""
    cfg = reduce_config(get_config("rwkv6-7b"))
    params = init_params(KEY, cfg)
    rng = np.random.default_rng(3)
    n_extra = 4
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S + n_extra)))
    full = forward_logits(params, {"tokens": toks}, cfg)
    _, cache = prefill(params, {"tokens": toks[:, :S]}, cfg)
    for i in range(n_extra):
        pos = jnp.full((B,), S + i, jnp.int32)
        logits_d, cache = decode_step(params, cache, toks[:, S + i], pos, cfg)
        np.testing.assert_allclose(np.asarray(logits_d),
                                   np.asarray(full[:, S + i]),
                                   rtol=1e-3, atol=1e-4)


def test_sliding_window_ring_cache_parity():
    """Hybrid arch (local attn + rglru): decode with ring caches must match
    teacher forcing even after the window wraps."""
    cfg = reduce_config(get_config("recurrentgemma-2b"))
    assert cfg.sliding_window < S  # ensure wrap actually happens
    params = init_params(KEY, cfg)
    rng = np.random.default_rng(4)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S + 3)))
    full = forward_logits(params, {"tokens": toks}, cfg)
    _, cache = prefill(params, {"tokens": toks[:, :S]}, cfg, cache_len=S + 8)
    for i in range(3):
        pos = jnp.full((B,), S + i, jnp.int32)
        logits_d, cache = decode_step(params, cache, toks[:, S + i], pos, cfg)
        np.testing.assert_allclose(np.asarray(logits_d),
                                   np.asarray(full[:, S + i]),
                                   rtol=1e-3, atol=1e-4)


def test_whisper_decode_matches_teacher_forcing():
    cfg = reduce_config(get_config("whisper-medium"))
    params = init_params(KEY, cfg)
    rng = np.random.default_rng(5)
    batch = make_batch(cfg, rng)
    full = forward_logits(params, batch, cfg)
    cache = init_serve_cache(params, batch, B, S + 4, cfg)
    for i in range(4):
        pos = jnp.full((B,), i, jnp.int32)
        logits_d, cache = decode_step(params, cache, batch["tokens"][:, i],
                                      pos, cfg)
        np.testing.assert_allclose(np.asarray(logits_d),
                                   np.asarray(full[:, i]),
                                   rtol=1e-3, atol=1e-4)


def test_rwkv_chunk_invariance():
    """Chunked parallel evaluation must be chunk-size invariant."""
    from repro.models.rwkv6 import (init_rwkv_state, init_rwkv_time_mix,
                                    rwkv_time_mix)
    cfg = reduce_config(get_config("rwkv6-7b"))
    p = init_rwkv_time_mix(KEY, cfg, jnp.float32)
    x = jnp.asarray(np.random.default_rng(6)
                    .normal(size=(B, 32, cfg.d_model)).astype(np.float32))
    st = init_rwkv_state(B, cfg, jnp.float32)
    y1, (_, s1) = rwkv_time_mix(p, x, (st["tm_shift"], st["wkv"]), cfg,
                                chunk=32)
    y2, (_, s2) = rwkv_time_mix(p, x, (st["tm_shift"], st["wkv"]), cfg,
                                chunk=8)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=1e-4, atol=1e-5)


def test_rglru_scan_matches_step():
    from repro.models.rglru import (init_rglru, init_rglru_state, rglru_scan,
                                    rglru_step)
    cfg = reduce_config(get_config("recurrentgemma-2b"))
    p = init_rglru(KEY, cfg, jnp.float32)
    x = jnp.asarray(np.random.default_rng(7)
                    .normal(size=(B, 16, cfg.lru_width)).astype(np.float32))
    h0 = jnp.zeros((B, cfg.lru_width), jnp.float32)
    y_scan, h_last = rglru_scan(p, x, h0)
    h = h0
    ys = []
    for t in range(16):
        y1, h = rglru_step(p, x[:, t:t + 1], h)
        ys.append(y1)
    y_seq = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_scan), np.asarray(y_seq),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(h_last), np.asarray(h),
                               rtol=1e-4, atol=1e-5)


def test_moe_matches_dense_reference():
    """Capacity dispatch must equal the brute-force per-token expert mix
    when capacity is ample (no drops)."""
    from repro.models.moe import init_moe, moe_apply
    from repro.models.common import activation
    cfg = reduce_config(get_config("qwen3-moe-30b-a3b"))
    p = init_moe(KEY, cfg, jnp.float32)
    x = jnp.asarray(np.random.default_rng(8)
                    .normal(size=(1, 16, cfg.d_model)).astype(np.float32))
    y, aux = moe_apply(p, x, cfg)
    assert y.shape == x.shape and bool(jnp.all(jnp.isfinite(y)))
    assert float(aux) > 0.0

    # brute force: every token through every expert, weighted by top-k probs
    xf = x.reshape(-1, cfg.d_model)
    logits = xf @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    top_p, top_i = jax.lax.top_k(probs, cfg.top_k)
    top_p = top_p / jnp.sum(top_p, -1, keepdims=True)
    act = activation(cfg.act)
    all_out = jnp.einsum(
        "ecf,efd->ecd",
        act(jnp.einsum("td,edf->etf", xf, p["w_gate"]))
        * jnp.einsum("td,edf->etf", xf, p["w_up"]),
        p["w_down"])                                   # (E, T, d)
    y_ref = jnp.zeros_like(xf)
    for kk in range(cfg.top_k):
        y_ref += top_p[:, kk, None] * all_out[top_i[:, kk],
                                              jnp.arange(xf.shape[0])]
    np.testing.assert_allclose(np.asarray(y.reshape(-1, cfg.d_model)),
                               np.asarray(y_ref), rtol=1e-4, atol=1e-5)


def test_param_count_math():
    """MoE active params far below total; dense equal."""
    moe_cfg = get_config("qwen3-moe-30b-a3b")
    assert moe_cfg.active_param_count() < 0.3 * moe_cfg.param_count()
    dense = get_config("deepseek-7b")
    assert dense.active_param_count() == dense.param_count()
    # sanity: deepseek-7b should be ~7B
    assert 6e9 < dense.param_count() < 8e9, dense.param_count()


def test_int8_kv_cache_decode_parity():
    """int8 KV cache (beyond-paper serve optimization) must track the bf16
    cache decode closely."""
    import dataclasses
    cfg = reduce_config(get_config("deepseek-7b"))
    cfg8 = dataclasses.replace(cfg, kv_quant_bits=8)
    params = init_params(KEY, cfg)
    rng = np.random.default_rng(21)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S + 2)))
    _, cache16 = prefill(params, {"tokens": toks[:, :S]}, cfg, cache_len=S + 4)
    _, cache8 = prefill(params, {"tokens": toks[:, :S]}, cfg8,
                        cache_len=S + 4)
    assert cache8["units"][0]["k"].dtype == jnp.int8
    for i in range(2):
        pos = jnp.full((B,), S + i, jnp.int32)
        l16, cache16 = decode_step(params, cache16, toks[:, S + i], pos, cfg)
        l8, cache8 = decode_step(params, cache8, toks[:, S + i], pos, cfg8)
        np.testing.assert_allclose(np.asarray(l8), np.asarray(l16),
                                   rtol=0.1, atol=0.05)
        # top-1 greedy token agreement
        assert bool(jnp.all(jnp.argmax(l8, -1) == jnp.argmax(l16, -1)))
