"""Page-granular prefix caching: hashing, refcounted sharing, COW,
eviction-into-cache, and the warm == cold == contiguous-oracle token
identity that makes the cache invisible to users.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduce_config
from repro.core import CacheState, contiguous_cfg, get_cache_format
from repro.data.synthetic import MarkovStream
from repro.models import init_params
from repro.serve.engine import GenRequest, ServeEngine
from repro.serve.scheduler import (PageAllocator, PrefixCache, PrefixHasher,
                                   SlotScheduler)


def _setup(arch="deepseek-7b"):
    cfg = reduce_config(get_config(arch))
    params = init_params(jax.random.PRNGKey(0), cfg)
    data = MarkovStream(cfg.vocab_size, batch=4, seq=32, seed=0)
    return cfg, params, data


# ------------------------------------------------------------------ hashing

def test_prefix_hasher_chain_and_keying():
    h = PrefixHasher(4, b"fp")
    toks = list(range(12))
    hs = h.page_hashes(toks)
    assert len(hs) == 3
    assert h.page_hashes(toks) == hs                 # deterministic
    assert h.page_hashes(toks + [99]) == hs          # partial page ignored
    assert h.page_hashes(toks[:8]) == hs[:2]         # chain is prefix-stable
    # page j's digest depends on every earlier token, not just its own
    bent = [7] + toks[1:]
    assert h.page_hashes(bent)[2] != hs[2]
    # a different model/policy fingerprint keys a disjoint hash space
    assert PrefixHasher(4, b"other").page_hashes(toks) != hs
    assert PrefixHasher(3, b"fp").page_hashes(toks) != hs[:1]


# ------------------------------------------- cache + allocator unit behavior

def test_prefix_cache_lookup_deposit_share_evict():
    alloc = PageAllocator(n_pages=8, page_size=4, n_slots=2,
                          max_pages_per_slot=4)
    hasher = PrefixHasher(4, b"t")
    pc = PrefixCache(alloc, hasher)
    hs = hasher.page_hashes(list(range(12)))
    assert alloc.alloc(0, 3)
    pc.deposit(hs, alloc.owned[0][:3])
    alloc.release(0)
    alloc.check()
    held = list(pc.entries.values())
    assert all(alloc.refs[p] == 1 for p in held)     # cache-only holds
    assert pc.lookup(hs) == held                     # longest leading run
    assert pc.lookup(hasher.page_hashes(list(range(8)) + [99, 98, 97, 96])) \
        == held[:2]
    assert pc.lookup(hasher.page_hashes([5, 6, 7, 8])) == []
    # a shared mapping pins the pages against cache-tier eviction
    alloc.share(1, held)
    assert pc.evict_lru(3) == 0
    alloc.release(1)
    assert pc.evict_lru(2) == 2                      # LRU first, refs-1 only
    alloc.check()
    assert pc.evictions == 2 and pc.pages == 1
    assert pc.clear() == 1
    alloc.check()
    assert alloc.available == 8


def test_prefix_cache_capacity_bound():
    alloc = PageAllocator(n_pages=8, page_size=2, n_slots=1,
                          max_pages_per_slot=8)
    hasher = PrefixHasher(2, b"t")
    pc = PrefixCache(alloc, hasher, capacity_pages=2)
    hs = hasher.page_hashes(list(range(8)))
    assert alloc.alloc(0, 4)
    pc.deposit(hs, alloc.owned[0][:4])
    assert pc.pages <= 2                             # oldest spilled
    alloc.release(0)
    alloc.check()


def test_cow_remaps_only_shared_pages():
    alloc = PageAllocator(n_pages=6, page_size=4, n_slots=2,
                          max_pages_per_slot=3)
    assert alloc.alloc(0, 2)
    a, b = alloc.owned[0]
    assert alloc.cow(0, 0) is None                   # exclusive: no copy
    alloc.share(1, [a, b])
    src, dst = alloc.cow(1, 1)
    assert (src, dst) == (b, alloc.owned[1][1])
    assert alloc.owned[0] == [a, b] and alloc.refs[b] == 1
    assert alloc.refs[dst] == 1
    alloc.check()


# ------------------------------------------------------- device page copies

@pytest.mark.parametrize("fmt_name", ["paged", "paged_int8"])
def test_copy_page_clones_all_pools(fmt_name):
    cfg, _, _ = _setup()
    cfgp = dataclasses.replace(cfg, kv_format=fmt_name, kv_page_size=4,
                               kv_pages=5)
    fmt = get_cache_format(fmt_name)
    c = fmt.init(1, 8, cfgp, jnp.float32)
    pages = jnp.asarray([[2, -1]], jnp.int32)
    rng = np.random.default_rng(3)
    for t in range(3):
        k = jnp.asarray(rng.normal(size=(1, 1, cfg.n_kv_heads,
                                         cfg.head_dim)).astype(np.float32))
        c = fmt.write(c, k, -k, jnp.asarray([t], jnp.int32), pages=pages)
    c2 = fmt.copy_page(c, 2, 4)
    for key, pool in c2.data.items():
        np.testing.assert_array_equal(np.asarray(pool[4]),
                                      np.asarray(pool[2]))
        np.testing.assert_array_equal(np.asarray(pool[:4]),
                                      np.asarray(c.data[key][:4]))
    # reads through the remapped table see identical bytes
    kp, vp = fmt.read(c2, jnp.float32, pages=jnp.asarray([[4, -1]],
                                                         jnp.int32))
    ko, vo = fmt.read(c2, jnp.float32, pages=pages)
    np.testing.assert_array_equal(np.asarray(kp), np.asarray(ko))
    np.testing.assert_array_equal(np.asarray(vp), np.asarray(vo))


# ------------------------------------------------- engine gating + identity

def test_prefix_cache_requires_paged_kv():
    cfg, params, _ = _setup()
    with pytest.raises(ValueError, match="paged"):
        ServeEngine(params, cfg, max_len=32, prefix_cache=True)


def test_prefix_cache_rejects_recurrent_state():
    cfg, params, _ = _setup("rwkv6-7b")
    cfgp = dataclasses.replace(cfg, kv_format="paged", kv_page_size=4)
    with pytest.raises(ValueError, match="recurrent|attn"):
        ServeEngine(params, cfgp, max_len=32, prefix_cache=True)


def _identity_run(fmt_name):
    """Cold-then-warm shared-prompt serve, cache-on vs cache-off vs the
    contiguous oracle; returns (cache-on stats, results)."""
    cfg, params, data = _setup()
    toks = data.batch_at(5)["tokens"]
    shared = toks[0, :16].tolist()                  # 4 full pages at ps=4
    reqs = [GenRequest(prompt=shared + toks[1, :5].tolist(), max_new=6),
            GenRequest(prompt=shared, max_new=6),   # exact repeat: full hit
            GenRequest(prompt=shared + toks[2, :3].tolist(), max_new=6)]
    cfgp = dataclasses.replace(cfg, kv_format=fmt_name, kv_page_size=4,
                               kv_pages=0)
    warm = ServeEngine(params, cfgp, max_len=64, n_slots=1, prefill_chunk=4,
                       prefix_cache=True)
    res_w = warm.serve(reqs)
    cold = ServeEngine(params, cfgp, max_len=64, n_slots=1, prefill_chunk=4)
    res_c = cold.serve(reqs)
    oracle = ServeEngine(params, contiguous_cfg(cfgp), max_len=64,
                         n_slots=1, prefill_chunk=4)
    res_o = oracle.serve(reqs)
    for w, c, o in zip(res_w, res_c, res_o):
        assert w.tokens == c.tokens == o.tokens, (w.tokens, c.tokens,
                                                  o.tokens)
    st = warm.last_stats
    assert st["chunk_tokens"] < cold.last_stats["chunk_tokens"]
    return st, res_w


def test_warm_cold_oracle_identity_paged():
    st, _ = _identity_run("paged")
    pc = st["prefix_cache"]
    assert pc["prefix_hits"] == 2 and pc["prefix_misses"] == 1
    # repeat skips to token 15 of 16 (COW of the final shared page);
    # the tailed request skips all 16 prefix tokens
    assert pc["prefix_hit_tokens"] == 15 + 16
    assert pc["cow_copies"] >= 1 and pc["cow_applied"] >= 1
    assert pc["pages_shared"] >= 8


def test_warm_cold_oracle_identity_paged_int8():
    st, _ = _identity_run("paged_int8")
    assert st["prefix_cache"]["prefix_hits"] == 2


def test_eviction_into_cache_feeds_readmission():
    """Preemption now deposits the victim's prefilled pages instead of
    discarding them: under page pressure with repeated prompts, greedy
    tokens still match the contiguous oracle and the cache records both
    deposits and hits while the allocator invariant holds."""
    cfg, params, data = _setup()
    toks = data.batch_at(5)["tokens"]
    shared = toks[0, :12].tolist()
    reqs = [GenRequest(prompt=shared, max_new=8),
            GenRequest(prompt=toks[1, :9].tolist(), max_new=8, priority=1),
            GenRequest(prompt=shared, max_new=6)]
    cfgp = dataclasses.replace(cfg, kv_format="paged", kv_page_size=4,
                               kv_pages=9)
    eng = ServeEngine(params, cfgp, max_len=64, n_slots=2,
                      prefix_cache=True)
    res = eng.serve(reqs)
    st = eng.last_stats
    pc = st["prefix_cache"]
    assert pc["cache_deposits"] >= 1
    assert pc["prefix_hits"] >= 1
    oracle = ServeEngine(params, cfg, max_len=64, n_slots=2)
    for a, b in zip(res, oracle.serve(reqs)):
        assert a.tokens == b.tokens, (a.tokens, b.tokens)


def test_mid_pass_eviction_deposits_only_written_pages():
    """Eviction-into-cache must key on the WRITTEN watermark, not `fed`:
    schedule_step bumps fed at lane-scheduling time, before the step
    runs, so a slot evicted after laning (e.g. by a higher-priority
    peer's chunk reservation in the same pass) has pages its lanes never
    wrote — writes route to scratch once the table row clears. Those
    pages must never reach the cache, or later shared-prefix admissions
    read garbage KV. Once record_scheduled confirms the step ran, the
    same eviction deposits the chunk's full pages."""

    def fresh(prompt_len):
        alloc = PageAllocator(n_pages=8, page_size=4, n_slots=2,
                              max_pages_per_slot=4)
        pc = PrefixCache(alloc, PrefixHasher(4, b"t"))
        s = SlotScheduler(n_slots=2, max_len=32, alloc=alloc,
                          prefix_cache=pc)
        req = GenRequest(prompt=list(range(prompt_len)), max_new=4)
        s.admit_chunked(0, req, now_s=0.0)
        lanes = s.schedule_step(budget=16, chunk_cap=8, now_s=0.0)
        assert lanes is not None and s.slots[0].fed == min(8, prompt_len)
        return alloc, pc, s

    # evicted between laning and the step: fed == 8 but nothing written
    alloc, pc, s = fresh(12)
    s.evict(0, now_s=0.0)
    assert pc.deposits == 0 and pc.pages == 0
    alloc.check()
    # the fed == plen flavor: prefilling flips False with tokens still
    # empty, which must not deposit the whole (unwritten) prompt
    alloc, pc, s = fresh(8)
    assert not s.slots[0].prefilling and not s.slots[0].tokens
    s.evict(0, now_s=0.0)
    assert pc.deposits == 0 and pc.pages == 0
    alloc.check()
    # after record_scheduled the step's writes are real: deposit proceeds
    alloc, pc, s = fresh(12)
    s.record_scheduled(np.zeros(2, np.int32), now_s=0.1)
    assert s.slots[0].written == 8
    s.evict(0, now_s=0.1)
    assert pc.deposits == 2 and pc.pages == 2        # both full pages
    alloc.check()


def test_cache_is_first_eviction_tier():
    """Refcount-0 cache entries are reclaimed before any live slot is
    preempted: a workload that fits only if the cache yields its pages
    must complete with cache_evictions > 0 and evictions == 0."""
    cfg, params, data = _setup()
    toks = data.batch_at(5)["tokens"]
    reqs = [GenRequest(prompt=toks[0, :16].tolist(), max_new=4),
            GenRequest(prompt=toks[1, :16].tolist(), max_new=4),
            GenRequest(prompt=toks[2, :16].tolist(), max_new=4)]
    cfgp = dataclasses.replace(cfg, kv_format="paged", kv_page_size=4,
                               kv_pages=7)
    eng = ServeEngine(params, cfgp, max_len=64, n_slots=1,
                      prefix_cache=True)
    res = eng.serve(reqs)
    st = eng.last_stats
    assert st["prefix_cache"]["cache_evictions"] >= 1
    assert st["evictions"] == 0
    oracle = ServeEngine(params, cfg, max_len=64, n_slots=1)
    for a, b in zip(res, oracle.serve(reqs)):
        assert a.tokens == b.tokens
