"""Bitstream LUT-mpGEMM kernel, grouped-projection fusion and block-size
autotuner (interpret mode — kernel bodies execute in Python on CPU)."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.formats import get_format
from repro.core.packing import (code_stream_bytes, pack_bits, pack_bits_np,
                                unpack_bits)
from repro.core.types import QuantizedLinear
from repro.kernels import ref
from repro.kernels.lut_mpgemm import (lut_matmul_bitstream,
                                      lut_matmul_grouped, phase_split)
from repro.kernels.ops import (groupable_layers, lut_linear,
                               lut_linear_grouped, vmem_plan)
from repro.kernels import tune


def _mk(seed, m, n, p, bits):
    rng = np.random.default_rng(seed)
    codes = rng.integers(0, 1 << bits, size=(m, n)).astype(np.uint8)
    t = (rng.normal(size=(m, 1 << bits)) * 0.05).astype(np.float32)
    x = rng.normal(size=(n, p)).astype(np.float32)
    return jnp.asarray(codes), jnp.asarray(t), jnp.asarray(x)


def _q(seed, m, n, bits, fmt):
    codes, t, _ = _mk(seed, m, n, 1, bits)
    lay = QuantizedLinear(codes=codes, codebook=t, bits=bits)
    return get_format(fmt).encode(lay)


# n not divisible by the phase count (8 for 3-bit, 4 for 2-bit) and ragged
# m/p exercise the zero-padded partial-group tail of the byte stream
SHAPES = [(128, 256, 64), (96, 130, 33), (8, 16, 4), (64, 512, 128),
          (130, 96, 17), (1, 64, 1), (33, 7, 5), (16, 9, 3)]


@pytest.mark.parametrize("m,n,p", SHAPES)
@pytest.mark.parametrize("bits", [2, 3, 4])
def test_bitstream_matches_ref(m, n, p, bits):
    codes, t, x = _mk(0, m, n, p, bits)
    packed = jnp.asarray(pack_bits_np(np.asarray(codes), bits))
    assert packed.shape == (m, code_stream_bytes(n, bits))
    y = lut_matmul_bitstream(packed, t, x, bits=bits, interpret=True)
    yref = ref.lut_matmul_ref(codes, t, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yref),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("bits", [2, 3, 4])
def test_pack_bits_jnp_matches_np(bits):
    rng = np.random.default_rng(1)
    codes = rng.integers(0, 1 << bits, size=(9, 37)).astype(np.uint8)
    want = pack_bits_np(codes, bits)
    got = np.asarray(pack_bits(jnp.asarray(codes), bits))
    np.testing.assert_array_equal(got, want)
    np.testing.assert_array_equal(
        np.asarray(unpack_bits(jnp.asarray(want), bits, 37)), codes)


@pytest.mark.parametrize("bm,bk,bp", [(32, 64, 16), (128, 512, 128),
                                      (16, 32, 8)])
def test_bitstream_block_invariance(bm, bk, bp):
    codes, t, x = _mk(3, 70, 150, 40, 3)
    packed = jnp.asarray(pack_bits_np(np.asarray(codes), 3))
    y = lut_matmul_bitstream(packed, t, x, bits=3, block_m=bm, block_k=bk,
                             block_p=bp, interpret=True)
    yref = ref.lut_matmul_ref(codes, t, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yref),
                               rtol=1e-4, atol=1e-4)


def test_phase_split():
    assert phase_split(3) == (3, 8)
    assert phase_split(4) == (1, 2)
    assert phase_split(2) == (1, 4)
    assert phase_split(8) == (1, 1)
    assert phase_split(5) == (5, 8)
    assert phase_split(6) == (3, 4)
    assert phase_split(7) == (7, 8)


# widths beyond the packed-format set {2,3,4}: the kernel is bit-parametric
# (g = sb/gcd(sb,8) byte planes, ph = 8/gcd(sb,8) phases), and the
# precision search may allocate {5,6,8} via the unpacked 'lut' container —
# these parity proofs are what gates them into bitsearch.PROVEN_WIDTHS.
# Shapes stay small: interpret-mode decode runs 2^bits-1 compare-selects.
@pytest.mark.parametrize("m,n,p", [(16, 24, 4), (8, 41, 3), (4, 9, 2)])
@pytest.mark.parametrize("bits", [5, 6, 8])
def test_bitstream_wide_widths_match_ref(m, n, p, bits):
    codes, t, x = _mk(11, m, n, p, bits)
    packed = jnp.asarray(pack_bits_np(np.asarray(codes), bits))
    assert packed.shape == (m, code_stream_bytes(n, bits))
    y = lut_matmul_bitstream(packed, t, x, bits=bits, interpret=True)
    yref = ref.lut_matmul_ref(codes, t, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yref),
                               rtol=1e-4, atol=1e-4)


def test_allocator_width_gate_matches_kernel_proofs():
    """bitsearch.candidate_fmt accepts exactly the widths proven above
    (packed {2,3,4} + unpacked {5,6,8}) and rejects the rest by name."""
    from repro.core.bitsearch import PROVEN_WIDTHS, candidate_fmt
    assert set(PROVEN_WIDTHS) == {2, 3, 4, 5, 6, 8}
    assert candidate_fmt(2) == "lut2_packed"
    assert candidate_fmt(3) == "lut3_packed"
    assert candidate_fmt(4) == "lut4_packed"
    for b in (5, 6, 8):
        assert candidate_fmt(b) == "lut"
    for b in (1, 7, 9, 16):
        with pytest.raises(ValueError, match="parity"):
            candidate_fmt(b)


def test_lut2_packed_streams_checkpoint_bytes():
    """The 2-bit container mirrors lut3_packed: exactly ceil(n/4) code
    bytes per row, vmem_plan agrees, serving matches the reference."""
    m, n, p = 32, 45, 6
    lay = _q(7, m, n, 2, "lut2_packed")
    assert lay.codes.shape == (m, code_stream_bytes(n, 2)) == (m, 12)
    plan = vmem_plan(m, n, 8, 2, fmt="lut2_packed")
    assert plan["codes_bytes"] == m * code_stream_bytes(n, 2)
    assert plan["codes_bytes"] < vmem_plan(m, n, 8, 2,
                                           fmt="lut4_packed")["codes_bytes"]
    codes, t, x = _mk(7, m, n, p, 2)
    yref = ref.lut_matmul_ref(lay.unpacked_codes(), lay.codebook, x)
    for use_pallas in (True, False):
        y = lut_linear(lay.codes, lay.codebook, x, bits=2,
                       fmt="lut2_packed", use_pallas=use_pallas)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yref),
                                   rtol=1e-4, atol=1e-4)


def test_lut3_packed_streams_checkpoint_bytes():
    """Acceptance: a lut3_packed layer holds EXACTLY ceil(n*3/8) code
    bytes per row in-graph, and vmem_plan/roofline accounting agrees."""
    m, n = 48, 100
    lay = _q(5, m, n, 3, "lut3_packed")
    assert lay.codes.shape == (m, code_stream_bytes(n, 3)) == (m, 38)
    plan = vmem_plan(m, n, 8, 3, fmt="lut3_packed")
    assert plan["codes_bytes"] == m * code_stream_bytes(n, 3)
    # the nibble container would stream 33% more on the same layer
    plan4 = vmem_plan(m, n, 8, 3, fmt="lut4_packed")
    assert plan["codes_bytes"] < plan4["codes_bytes"]
    # serving matmul on the bitstream matches the unpacked reference
    codes, t, x = _mk(5, m, n, 8, 3)
    y = lut_linear(lay.codes, lay.codebook, x, bits=3, fmt="lut3_packed")
    yref = ref.lut_matmul_ref(lay.unpacked_codes(), lay.codebook, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yref),
                               rtol=1e-4, atol=1e-4)


def test_narrow_codes_in_wider_stream():
    """2-bit codes riding the 3-bit container ('lut3_packed' accepts
    bits <= 3): the pallas route must decode at the container's stream
    width, not the code width, and agree with the xla reference."""
    m, n, p = 16, 40, 5
    rng = np.random.default_rng(9)
    codes = jnp.asarray(rng.integers(0, 4, size=(m, n)).astype(np.uint8))
    t = jnp.asarray(rng.normal(size=(m, 4)).astype(np.float32))
    x = jnp.asarray(rng.normal(size=(n, p)).astype(np.float32))
    lay = get_format("lut3_packed").encode(
        QuantizedLinear(codes=codes, codebook=t, bits=2))
    yref = ref.lut_matmul_ref(codes, t, x)
    for use_pallas in (True, False):
        y = lut_linear(lay.codes, t, x, bits=2, fmt="lut3_packed",
                       use_pallas=use_pallas)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yref),
                                   rtol=1e-4, atol=1e-4)


# ----------------------------------------------------------------- grouped

@pytest.mark.parametrize("fmt,bits", [("lut", 4), ("lut4_packed", 4),
                                      ("lut3_packed", 3)])
def test_grouped_matches_sequential(fmt, bits):
    """Fused multi-projection launch == per-layer kernels to fp32
    tolerance, including unequal output widths (GQA-style Q vs K/V)."""
    n, p = 96, 11
    layers = [_q(s, m, n, bits, fmt) for s, m in ((0, 64), (1, 16), (2, 16))]
    x = jnp.asarray(np.random.default_rng(3)
                    .normal(size=(n, p)).astype(np.float32))
    assert groupable_layers(layers)
    ys = lut_linear_grouped(layers, x)
    for lay, y in zip(layers, ys):
        yref = ref.lut_matmul_ref(lay.unpacked_codes(), lay.codebook, x)
        assert y.shape == (lay.shape[0], p)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yref),
                                   rtol=1e-4, atol=1e-4)


def test_grouped_fallback_conditions():
    a = _q(0, 32, 64, 4, "lut4_packed")
    b = _q(1, 16, 64, 4, "lut4_packed")
    assert groupable_layers([a, b])
    assert not groupable_layers([a])                       # singleton
    assert not groupable_layers([a, _q(2, 16, 64, 3, "lut3_packed")])
    assert not groupable_layers([a, _q(3, 16, 32, 4, "lut4_packed")])
    assert not groupable_layers([a, jnp.zeros((64, 16))])  # dense member
    sparse = QuantizedLinear(codes=a.codes, codebook=a.codebook, bits=4,
                             fmt="lut", n_cols=64,
                             sparse_idx=jnp.zeros((32, 1), jnp.int32),
                             sparse_val=jnp.zeros((32, 1), jnp.float32))
    assert not groupable_layers([sparse, sparse])          # side payload
    assert not groupable_layers([a, _q(5, 9, 64, 4, "lut4_packed")])  # gcd<8
    # extreme row ratios (MQA-style 256:8) exceed MAX_GROUPS: the kernel
    # would keep 33 code tiles + accumulators VMEM-resident -> sequential
    wide = _q(6, 256, 64, 4, "lut4_packed")
    assert not groupable_layers([wide, _q(7, 8, 64, 4, "lut4_packed")])


def test_grouped_linear_apply_matches_unfused():
    """models.linears.linear_apply_grouped: fused pallas path equals the
    per-layer xla path on a shared input, bias included."""
    from repro.models.linears import linear_apply, linear_apply_grouped
    from repro.sharding.context import LOCAL
    rng = np.random.default_rng(7)
    n = 48
    layers = []
    for s, m in ((0, 32), (1, 8), (2, 8)):
        lay = _q(s, m, n, 3, "lut3_packed")
        lay.bias = jnp.asarray(rng.normal(size=(m,)).astype(np.float32))
        layers.append(lay)
    x = jnp.asarray(rng.normal(size=(2, 5, n)).astype(np.float32))
    ctx = LOCAL.with_lut_backend("pallas")
    ys = linear_apply_grouped(layers, x, ctx=ctx)
    for lay, y in zip(layers, ys):
        want = linear_apply(lay, x, ctx=LOCAL)             # xla reference
        np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------------- tuner

def test_tuner_cache_roundtrip(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path / "blocks.json"))
    tune.clear_cache()
    plan = tune.autotune(16, 32, 4, 4, "lut4_packed", reps=1,
                         max_candidates=2)
    assert plan.us > 0
    assert tune.lookup(16, 32, 4, 4, "lut4_packed") == plan
    # a fresh process (cleared memory cache) reloads from disk
    tune.clear_cache()
    loaded = tune.lookup(16, 32, 4, 4, "lut4_packed")
    assert loaded is not None
    assert loaded.as_kwargs() == plan.as_kwargs()
    # lut_linear consumes the tuned plan without error
    codes, t, x = _mk(0, 16, 32, 4, 4)
    packed = get_format("lut4_packed").encode(
        QuantizedLinear(codes=codes, codebook=t, bits=4))
    y = lut_linear(packed.codes, t, x, bits=4, fmt="lut4_packed")
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(ref.lut_matmul_ref(codes, t, x)),
                               rtol=1e-4, atol=1e-4)
    tune.clear_cache()


def test_tune_model_covers_grouped_launches(tmp_path, monkeypatch):
    """serve --autotune must populate the group-tagged keys the fused
    Q/K/V / gate/up serving path looks up, not just per-layer keys."""
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path / "blocks.json"))
    tune.clear_cache()
    params = {"attn": {"wq": _q(0, 32, 64, 4, "lut4_packed"),
                       "wk": _q(1, 16, 64, 4, "lut4_packed"),
                       "wv": _q(2, 16, 64, 4, "lut4_packed")},
              "mlp": {"w_gate": _q(3, 24, 64, 4, "lut4_packed"),
                      "w_up": _q(4, 24, 64, 4, "lut4_packed"),
                      "w_down": _q(5, 64, 24, 4, "lut4_packed")}}
    plans = tune.tune_model(params, p=4, reps=1)
    # grouped keys: QKV (m_total=64, G=4) and gate/up (m_total=48, G=2)
    qkv_key = tune.plan_key(64, 64, 4, 4, "lut4_packed", groups=4)
    glu_key = tune.plan_key(48, 64, 4, 4, "lut4_packed", groups=2)
    assert qkv_key in plans and glu_key in plans
    assert tune.lookup(64, 64, 4, 4, "lut4_packed", groups=4) is not None
    # per-layer keys are tuned too (w_down serves unfused)
    assert tune.plan_key(64, 24, 4, 4, "lut4_packed") in plans
    # the grouped serving entry runs with the tuned plan
    x = jnp.asarray(np.random.default_rng(6)
                    .normal(size=(64, 4)).astype(np.float32))
    ys = lut_linear_grouped([params["attn"][k] for k in ("wq", "wk", "wv")],
                            x)
    for lay, y in zip((params["attn"][k] for k in ("wq", "wk", "wv")), ys):
        yref = ref.lut_matmul_ref(lay.unpacked_codes(), lay.codebook, x)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yref),
                                   rtol=1e-4, atol=1e-4)
    tune.clear_cache()


def test_tuner_feasibility_filter():
    """Every candidate must fit the VMEM budget; a tiny budget collapses
    the candidate set."""
    cands = tune.candidate_plans(4096, 4096, 256, 4, "lut4_packed")
    assert cands
    for c in cands:
        plan = vmem_plan(4096, 4096, 256, 4, c.block_m, c.block_k,
                         c.block_p, fmt="lut4_packed")
        assert plan["vmem_bytes"] <= tune.VMEM_BUDGET_BYTES
    tight = tune.candidate_plans(4096, 4096, 256, 4, "lut4_packed",
                                 vmem_budget=64 * 1024)
    assert len(tight) < len(cands)


def test_vmem_plan_layout_aware():
    """Satellite: vmem_plan derives bytes from the actual container
    layout and dtypes instead of hardcoding 4-bit/fp16."""
    m, n, p = 512, 512, 8
    p3 = vmem_plan(m, n, p, 3, fmt="lut3_packed")
    p4 = vmem_plan(m, n, p, 4, fmt="lut4_packed")
    pu = vmem_plan(m, n, p, 4, fmt="lut")
    assert p3["codes_bytes"] == m * code_stream_bytes(n, 3)
    assert p4["codes_bytes"] == m * n // 2
    assert pu["codes_bytes"] == m * n
    # fp32 codebooks are 4 bytes/entry (not the fp16 the paper assumes)
    assert p4["lut_bytes"] == m * 16 * 4
    assert vmem_plan(m, n, p, 4, fmt="lut4_packed",
                     book_dtype=jnp.float16)["lut_bytes"] == m * 16 * 2
    # grouped: codes bytes unchanged, X streamed once per unit row-block
    g = vmem_plan(3 * m, n, p, 4, groups=3, fmt="lut4_packed")
    s = vmem_plan(m, n, p, 4, fmt="lut4_packed")
    assert g["codes_bytes"] == 3 * s["codes_bytes"]
    assert g["x_bytes"] == s["x_bytes"]