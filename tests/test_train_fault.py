"""Fault tolerance: checkpoint/restart, failure injection, stragglers,
elastic re-mesh planning, data-pipeline determinism."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduce_config
from repro.data.synthetic import MarkovStream
from repro.models import init_params
from repro.train.checkpoint import CheckpointManager
from repro.train.fault import (ElasticPlan, FailureInjector, HostFailure,
                               StragglerMonitor)
from repro.train.loop import Trainer, TrainerConfig
from repro.train.optimizer import OptConfig, init_opt_state


def small_cfg():
    return reduce_config(get_config("deepseek-7b"))


def test_checkpoint_roundtrip_and_integrity(tmp_path):
    cfg = small_cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    opt = init_opt_state(params)
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    mgr.save(10, {"params": params, "opt": opt})
    assert mgr.latest_step() == 10
    restored = mgr.restore(10, {"params": params, "opt": opt})
    for a, b in zip(jax.tree.leaves(params),
                    jax.tree.leaves(restored["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # step counter round-trips too
    assert int(restored["opt"].step) == int(opt.step)


def test_checkpoint_keep_k_and_corruption(tmp_path):
    cfg = small_cfg()
    params = {"w": jnp.ones((4, 4))}
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    for s in (1, 2, 3):
        mgr.save(s, params)
    assert mgr.all_steps() == [2, 3]
    # corrupt a file -> restore must fail loudly
    d = os.path.join(str(tmp_path), "step_00000003")
    fn = [f for f in os.listdir(d) if f.endswith(".npy")][0]
    arr = np.load(os.path.join(d, fn))
    np.save(os.path.join(d, fn), arr + 1)
    with pytest.raises(IOError, match="corruption"):
        mgr.restore(3, params)


def test_async_save_visible_after_wait(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=True)
    mgr.save(5, {"w": jnp.arange(8.0)})
    mgr.wait()
    assert mgr.latest_step() == 5


def test_train_restart_resumes_identically(tmp_path):
    """Run A: 8 uninterrupted steps. Run B: crash at step 5, restart,
    finish. Final losses must match bit-for-bit (step-keyed data +
    checkpointed state)."""
    cfg = small_cfg()
    data = MarkovStream(cfg.vocab_size, batch=2, seq=16, seed=7)
    tcfg_a = TrainerConfig(steps=8, ckpt_every=4, log_every=100,
                           ckpt_dir=str(tmp_path / "a"))
    res_a = Trainer(cfg, data, tcfg_a).run()

    tcfg_b = TrainerConfig(steps=8, ckpt_every=4, log_every=100,
                           ckpt_dir=str(tmp_path / "b"), sync_ckpt=True)
    trainer_b = Trainer(cfg, data, tcfg_b,
                        injector=FailureInjector(fail_at=(5,)))
    with pytest.raises(HostFailure):
        trainer_b.run()
    # restart (fresh Trainer object = fresh process)
    res_b = Trainer(cfg, data, tcfg_b).run()
    assert res_b["resumed_from"] == 4
    assert res_a["final_loss"] == pytest.approx(res_b["final_loss"],
                                                rel=1e-6)


def test_training_actually_learns(tmp_path):
    cfg = small_cfg()
    data = MarkovStream(cfg.vocab_size, batch=8, seq=64, seed=1)
    tcfg = TrainerConfig(steps=60, ckpt_every=60, log_every=100,
                         ckpt_dir=str(tmp_path))
    res = Trainer(cfg, data, tcfg,
                  opt_cfg=OptConfig(lr=1e-2, warmup_steps=10, total_steps=60,
                                    weight_decay=0.0)).run()
    assert res["final_loss"] < res["first_loss"] - 1.0, res


def test_straggler_monitor_flags_slow_host():
    mon = StragglerMonitor(n_hosts=8, threshold=1.5, patience=3)
    times = np.ones(8)
    flagged = []
    for step in range(6):
        t = times.copy()
        t[3] = 4.0 if step >= 2 else 1.0   # host 3 degrades at step 2
        flagged += mon.record(t)
    assert flagged == [3]


def test_straggler_monitor_no_false_positives():
    mon = StragglerMonitor(n_hosts=16, threshold=1.8, patience=3)
    rng = np.random.default_rng(0)
    for _ in range(50):
        assert mon.record(1.0 + 0.1 * rng.random(16)) == []


def test_elastic_plan_keeps_divisibility():
    plan = ElasticPlan(old_dp=16, lost_hosts=3)
    assert plan.new_dp == 8               # largest divisor of 16 <= 13
    assert plan.accumulation_factor == 2  # global batch preserved
    plan2 = ElasticPlan(old_dp=16, lost_hosts=0)
    assert plan2.new_dp == 16 and plan2.accumulation_factor == 1


def test_data_pipeline_step_keyed_determinism():
    d1 = MarkovStream(1000, batch=2, seq=16, seed=3)
    d2 = MarkovStream(1000, batch=2, seq=16, seed=3)
    b1 = d1.batch_at(17)
    b2 = d2.batch_at(17)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])


def test_grad_accumulation_matches_full_batch(tmp_path):
    cfg = small_cfg()
    data = MarkovStream(cfg.vocab_size, batch=4, seq=16, seed=5)
    t1 = TrainerConfig(steps=2, ckpt_every=99, ckpt_dir=str(tmp_path / "x"),
                       accum=1)
    t2 = TrainerConfig(steps=2, ckpt_every=99, ckpt_dir=str(tmp_path / "y"),
                       accum=2)
    r1 = Trainer(cfg, data, t1).run()
    r2 = Trainer(cfg, data, t2).run()
    assert r1["final_loss"] == pytest.approx(r2["final_loss"], rel=2e-3)
