"""Unified token-budget step: chunked prefill piggybacked on decode.

Chunked-vs-whole prefill greedy token-equivalence across every cache
format (full / int8 / paged / paged_int8 / rwkv_state / rglru_state) via
the slot engine, the one-compile property of the fixed-shape step, the
no-decode-gap guarantee while long prompts admit, sliding-window page
release under churn, and the WeightFormat-owned quantized sharding rules.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduce_config
from repro.data.synthetic import MarkovStream
from repro.models import init_params
from repro.serve.engine import GenRequest, ServeEngine
from repro.serve.scheduler import PageAllocator, SlotScheduler
from repro.serve.scheduler import GenRequest as SchedRequest


def _setup(arch="deepseek-7b"):
    cfg = reduce_config(get_config(arch))
    params = init_params(jax.random.PRNGKey(0), cfg)
    data = MarkovStream(cfg.vocab_size, batch=4, seq=32, seed=0)
    return cfg, params, data


# ----------------------------------- chunked == whole-prompt, every format

def _chunked_equiv(arch, cfg_tf, batch_at=3, prefill_chunk=4, max_len=48):
    """Engine with a small prefill chunk (prompts span several steps) must
    emit greedy tokens identical to the whole-prompt-prefill oracle
    (`generate_batch`), request by request."""
    cfg, params, data = _setup(arch)
    cfg = cfg_tf(cfg)
    toks = data.batch_at(batch_at)["tokens"]
    reqs = [GenRequest(prompt=toks[i, :l].tolist(), max_new=m)
            for i, (l, m) in enumerate([(9, 4), (12, 3), (6, 4)])]
    eng = ServeEngine(params, cfg, max_len=max_len, n_slots=2,
                      prefill_chunk=prefill_chunk)
    cont = eng.serve(reqs)
    for r, c in zip(reqs, cont):
        ref = eng.generate_batch(
            [GenRequest(prompt=r.prompt, max_new=r.max_new)])
        assert c.tokens == ref[0].tokens, (c.tokens, ref[0].tokens)
    return eng


def test_chunked_equivalence_full():
    eng = _chunked_equiv("deepseek-7b", lambda c: c)
    assert eng.last_stats["chunk_tokens"] > 0
    assert eng.last_stats["max_decode_gap_steps"] <= 1


def test_chunked_equivalence_int8():
    _chunked_equiv("deepseek-7b",
                   lambda c: dataclasses.replace(c, kv_quant_bits=8))


def test_chunked_equivalence_paged():
    _chunked_equiv("deepseek-7b", lambda c: dataclasses.replace(
        c, kv_format="paged", kv_page_size=8))


def test_chunked_equivalence_paged_int8():
    _chunked_equiv("deepseek-7b", lambda c: dataclasses.replace(
        c, kv_format="paged_int8", kv_page_size=8))


def test_chunked_equivalence_ring_and_rglru():
    """recurrentgemma: sliding-window ring + RG-LRU state — recurrent
    chunk-stepped prefill and the windowed ring share the step."""
    _chunked_equiv("recurrentgemma-2b", lambda c: c, batch_at=6)


def test_chunked_equivalence_rglru_paged():
    _chunked_equiv("recurrentgemma-2b", lambda c: dataclasses.replace(
        c, kv_format="paged", kv_page_size=4), batch_at=6)


def test_chunked_equivalence_rwkv():
    _chunked_equiv("rwkv6-7b", lambda c: c, batch_at=9)


def test_chunked_matches_legacy_whole_prefill_admission():
    """prefill_chunk=0 keeps the legacy per-length-jit whole-prompt
    admission (the stall baseline): same requests, same greedy tokens."""
    cfg, params, data = _setup()
    toks = data.batch_at(4)["tokens"]
    reqs = [GenRequest(prompt=toks[i, :l].tolist(), max_new=3)
            for i, l in enumerate([8, 12, 6])]
    legacy = ServeEngine(params, cfg, max_len=48, n_slots=2, prefill_chunk=0)
    chunked = ServeEngine(params, cfg, max_len=48, n_slots=2,
                          prefill_chunk=4)
    a = legacy.serve(reqs)
    b = chunked.serve(reqs)
    for x, y in zip(a, b):
        assert x.tokens == y.tokens, (x.tokens, y.tokens)
    assert legacy.last_stats["chunk_tokens"] == 0
    assert len(legacy._prefill_jits) > 0       # the compile cost chunking kills
    assert len(chunked._prefill_jits) == 0


# --------------------------------------------- one compile, any length mix

def test_unified_step_compiles_once_across_prompt_lengths():
    """The token-budget step is ONE static shape: serving wildly different
    prompt-length mixes must not add compiles (no per-length buckets)."""
    cfg, params, data = _setup()
    eng = ServeEngine(params, cfg, max_len=64, n_slots=2, prefill_chunk=8)
    toks = data.batch_at(5)["tokens"]
    eng.serve([GenRequest(prompt=toks[0, :6].tolist(), max_new=2)])
    if not hasattr(eng._mixed, "_cache_size"):
        pytest.skip("jit cache introspection unavailable")
    assert eng._mixed._cache_size() == 1
    eng.serve([GenRequest(prompt=toks[i % 4, :l].tolist(), max_new=2)
               for i, l in enumerate([5, 11, 17, 23, 9])])
    eng.serve([GenRequest(prompt=toks[0, :31].tolist(), max_new=2)])
    assert eng._mixed._cache_size() == 1       # still the one signature
    assert len(eng._prefill_jits) == 0


# ------------------------------------------------- admission never stalls

def test_long_admission_no_decode_gap_and_token_identical():
    """A long prompt admitted while other slots decode: every in-flight
    stream still samples every step (gap == 1 budget step) and greedy
    tokens equal the whole-prompt oracle."""
    cfg, params, data = _setup()
    long_data = MarkovStream(cfg.vocab_size, batch=1, seq=96, seed=3)
    long_prompt = long_data.batch_at(0)["tokens"][0, :80].tolist()
    toks = data.batch_at(7)["tokens"]
    reqs = [GenRequest(prompt=toks[0, :8].tolist(), max_new=12),
            GenRequest(prompt=toks[1, :6].tolist(), max_new=12),
            GenRequest(prompt=long_prompt, max_new=4)]
    eng = ServeEngine(params, cfg, max_len=128, n_slots=3, prefill_chunk=16)
    # the long prompt arrives once the short ones are mid-decode
    eng.serve(reqs)                            # warm the jit off the clock
    res = eng.serve(reqs, arrival_times=[0.0, 0.0, 0.25])
    assert eng.last_stats["max_decode_gap_steps"] <= 1
    assert eng.last_stats["chunk_tokens"] >= len(long_prompt)
    for r, c in zip(reqs, res):
        ref = eng.generate_batch(
            [GenRequest(prompt=r.prompt, max_new=r.max_new)])
        assert c.tokens == ref[0].tokens, (c.tokens, ref[0].tokens)


def test_scheduler_decode_lanes_every_step():
    """Scheduler-level gap guarantee: with budget >= n_slots, every
    decoding slot lanes exactly once per step while a prompt chunks."""
    s = SlotScheduler(n_slots=3, max_len=256)
    s.admit(0, SchedRequest(prompt=[1, 2], max_new=50), first_token=7,
            now_s=0.0, prefill_s=0.0)
    s.admit(1, SchedRequest(prompt=[3], max_new=50), first_token=8,
            now_s=0.0, prefill_s=0.0)
    s.admit_chunked(2, SchedRequest(prompt=list(range(100)), max_new=4),
                    now_s=0.0)
    for step in range(10):
        lanes = s.schedule_step(budget=3 + 16, chunk_cap=16, now_s=0.1)
        nd = lanes["n_decode"]
        # slots 0/1 decode every step; slot 2 joins them once its 100-token
        # prompt finishes chunking (6 x 16 + 4 after step 6)
        assert nd == (2 if step <= 6 else 3)
        assert sorted(lanes["slots"][:nd].tolist()) == [0, 1, 2][:nd]
        chunk = int(lanes["active"].sum()) - nd
        assert chunk == (16 if step < 6 else (4 if step == 6 else 0))
        sampled = np.asarray([11 + step, 12 + step, 13 + step])
        s.record_scheduled(sampled, now_s=0.1 * (step + 1))
    assert s.max_decode_gap == 1
    # slot 2 sampled its first token the step its last chunk emitted, then
    # decoded to max_new=4 and finished
    done = [r for r in s.results.values() if len(r.tokens) == 4]
    assert len(done) == 1 and done[0].prefill_s > 0


# ------------------------------------------- sliding-window page release

def test_window_page_release_paged_local_only():
    """recurrentgemma (all attention is sliding-window): paged serving
    releases pages that slid out of the window, stays token-identical to
    the contiguous twin, and the allocator invariant holds."""
    cfg, params, _ = _setup("recurrentgemma-2b")
    long_data = MarkovStream(cfg.vocab_size, batch=1, seq=64, seed=4)
    toks = long_data.batch_at(0)["tokens"][0]
    reqs = [GenRequest(prompt=toks[:40].tolist(), max_new=8),
            GenRequest(prompt=toks[:25].tolist(), max_new=8)]
    cfgp = dataclasses.replace(cfg, kv_format="paged", kv_page_size=4)
    eng_p = ServeEngine(params, cfgp, max_len=64, n_slots=2,
                        prefill_chunk=8)
    assert eng_p.release_window == cfg.sliding_window
    res_p = eng_p.serve(reqs)
    assert eng_p.last_stats["pages_released_by_window"] > 0
    eng_c = ServeEngine(params, cfg, max_len=64, n_slots=2, prefill_chunk=8)
    for a, b in zip(res_p, eng_c.serve(reqs)):
        assert a.tokens == b.tokens, (a.tokens, b.tokens)
    # a model with any global-attention layer must NOT release
    cfg_g, params_g, _ = _setup("deepseek-7b")
    cfg_gp = dataclasses.replace(cfg_g, kv_format="paged", kv_page_size=8)
    assert ServeEngine(params_g, cfg_gp, max_len=64).release_window is None


def test_page_allocator_window_release_churn():
    """Invariant under admit/grow/window-release/release churn: no page
    leaked or double-owned, released holes map to -1 in the table."""
    rng = np.random.default_rng(11)
    alloc = PageAllocator(n_pages=17, page_size=4, n_slots=3,
                          max_pages_per_slot=8)
    pos = [0, 0, 0]
    for _ in range(600):
        op = rng.integers(0, 4)
        slot = int(rng.integers(0, 3))
        if op == 0:
            alloc.alloc(slot, int(rng.integers(1, 3)))
        elif op == 1:
            pos[slot] = int(rng.integers(0, 32))
            alloc.ensure(slot, pos[slot])
        elif op == 2:
            alloc.release_window(slot, pos[slot], window=8)
        else:
            alloc.release(slot)
            pos[slot] = 0
        alloc.check()
        t = alloc.table()
        for i in range(3):
            for j, p in enumerate(alloc.owned[i]):
                assert t[i, j] == (-1 if p is None else p)
    assert alloc.available + alloc.in_use == 17


# ------------------------------- WeightFormat-owned quantized sharding

def test_quantized_partition_specs_live_on_weight_format():
    """`spec_for_param`'s FlattenedIndexKey switch moved onto
    `WeightFormat.partition_spec`: codes are transposed vs the dense rule,
    codebook/sparse shard the out dim, full fp rows replicate — and the
    spec tree flattens leaf-for-leaf with the parameter tree."""
    from jax.sharding import PartitionSpec as P
    from repro.core import QuantConfig
    from repro.models.quantized import quantize_model_ptq
    from repro.sharding.partition import param_specs

    cfg, params, data = _setup()
    calib = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}
    qp, _ = quantize_model_ptq(
        params, cfg, calib,
        QuantConfig(bits=4, iters=2, precondition="fixed",
                    outlier_ratio=0.01, full_rows=1), "ganq")
    specs = param_specs(qp, "model")
    flat_p = jax.tree_util.tree_flatten_with_path(qp)[0]
    flat_s = jax.tree.leaves(specs)
    assert len(flat_p) == len(flat_s)
    by_path = {"/".join(str(getattr(k, "key", getattr(k, "idx", "")))
                        for k in path): (leaf, spec)
               for (path, leaf), spec in zip(flat_p, flat_s)}
    # wq: dense rule (None, tp); container children are unit-stacked
    codes, s_codes = by_path["stack/units/0/attn/wq/0"]
    assert s_codes == P(None, "model", None)       # (U, m, n): out first
    book, s_book = by_path["stack/units/0/attn/wq/1"]
    assert book.shape[-1] == 16 and s_book == P(None, "model", None)
    # w_down: dense rule (tp, None) -> codes shard the in (column) dim
    _, s_down = by_path["stack/units/0/mlp/w_down/0"]
    assert s_down == P(None, None, "model")
    _, s_down_book = by_path["stack/units/0/mlp/w_down/1"]
    assert s_down_book == P(None, None, None)      # out replicated
    # sparse outliers follow the out dim; full rows replicate
    _, s_sp = by_path["stack/units/0/attn/wq/2"]
    assert s_sp == P(None, "model", None)
    _, s_fr = by_path["stack/units/0/attn/wq/4"]
    assert s_fr == P()
