"""Unit + property tests for the GANQ core algorithm (paper Alg. 1)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (QuantConfig, assign_nearest, compute_h, ganq_quantize,
                        gptq_reconstruct, layer_objective, precondition,
                        rtn_reconstruct, s_step, t_step)
from repro.core.precondition import safe_cholesky


def make_problem(seed, m=32, n=48, p=128, corr=True):
    rng = np.random.default_rng(seed)
    w = (rng.standard_t(df=4, size=(m, n)) * 0.02).astype(np.float32)
    if corr:
        u = rng.normal(size=(n, 8)).astype(np.float32)
        z = rng.normal(size=(8, p)).astype(np.float32)
        x = u @ z + 0.1 * rng.normal(size=(n, p)).astype(np.float32)
    else:
        x = rng.normal(size=(n, p)).astype(np.float32)
    return jnp.asarray(w), compute_h(jnp.asarray(x))


# ------------------------------------------------------------- preconditioning

@given(st.integers(0, 1000), st.integers(4, 24))
@settings(max_examples=20, deadline=None)
def test_precondition_adaptive_is_spd(seed, n):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, 3)).astype(np.float32)  # rank-deficient H
    h = jnp.asarray(x @ x.T)
    hp = precondition(h, "adaptive")
    ev = np.linalg.eigvalsh(np.asarray(hp))
    assert ev.min() > 0, ev.min()


@given(st.integers(0, 1000))
@settings(max_examples=10, deadline=None)
def test_safe_cholesky_finite(seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(16, 2)).astype(np.float32)
    h = jnp.asarray(x @ x.T)
    for mode in ("adaptive", "fixed"):
        l = safe_cholesky(h, mode)
        assert bool(jnp.all(jnp.isfinite(l)))


# --------------------------------------------------------------------- S-step

def test_s_step_identity_h_is_nearest_codebook():
    """With H = I (L = I), back-substitution has no feedback: the code of each
    element must be the plain nearest codebook entry."""
    w, _ = make_problem(0)
    t = jnp.sort(jnp.asarray(np.random.default_rng(0).normal(size=(w.shape[0], 16))
                             .astype(np.float32)), axis=1)
    l = jnp.eye(w.shape[1], dtype=jnp.float32)
    codes, wq = s_step(w, t, l)
    expected = assign_nearest(w, t)
    np.testing.assert_array_equal(np.asarray(codes), np.asarray(expected))
    np.testing.assert_allclose(np.asarray(wq),
                               np.asarray(jnp.take_along_axis(t, expected, 1)),
                               rtol=1e-6)


def test_s_step_improves_on_nearest_assignment():
    """Residual feedback must not be worse than feedback-free assignment under
    the true objective (greedy, but on correlated H it wins clearly)."""
    w, h = make_problem(3)
    hp = precondition(h, "fixed", 0.01)
    l = jnp.linalg.cholesky(hp)
    from repro.core import init_codebook
    t = init_codebook(w, 4, "quantile")
    codes_near = assign_nearest(w, t)
    wq_near = jnp.take_along_axis(t, codes_near, 1)
    _, wq_bs = s_step(w, t, l)
    e_near = float(layer_objective(w, wq_near, hp))
    e_bs = float(layer_objective(w, wq_bs, hp))
    assert e_bs <= e_near * 1.001, (e_bs, e_near)


def test_s_step_codes_in_range():
    w, h = make_problem(4)
    l = safe_cholesky(h)
    from repro.core import init_codebook
    for bits in (3, 4):
        t = init_codebook(w, bits, "quantile")
        codes, _ = s_step(w, t, l)
        assert int(codes.min()) >= 0 and int(codes.max()) < (1 << bits)


# --------------------------------------------------------------------- T-step

@given(st.integers(0, 500))
@settings(max_examples=8, deadline=None)
def test_t_step_never_increases_objective(seed):
    """Given fixed codes, the closed-form T update is the least-squares optimum
    — guaranteed no worse than the previous codebook (paper eq. 7)."""
    w, h = make_problem(seed, m=16, n=24, p=64)
    hp = precondition(h, "fixed", 0.01)
    from repro.core import init_codebook
    t0 = init_codebook(w, 3, "quantile")
    codes = assign_nearest(w, t0)
    wq0 = jnp.take_along_axis(t0, codes, 1)
    e0 = float(layer_objective(w, wq0, hp))
    t1 = t_step(w, hp, codes, t0)
    wq1 = jnp.take_along_axis(t1, codes, 1)
    e1 = float(layer_objective(w, wq1, hp))
    assert e1 <= e0 * (1 + 1e-4), (e1, e0)


def test_t_step_keeps_unused_entries():
    w, h = make_problem(7, m=8, n=16, p=32)
    hp = precondition(h, "fixed", 0.01)
    t0 = jnp.tile(jnp.linspace(-1, 1, 8, dtype=jnp.float32), (8, 1))
    codes = jnp.zeros((8, 16), jnp.int32)  # only code 0 used
    t1 = t_step(w, hp, codes, t0)
    np.testing.assert_allclose(np.asarray(t1[:, 1:]), np.asarray(t0[:, 1:]),
                               rtol=1e-6)


# ----------------------------------------------------------------- end-to-end

def test_ganq_beats_rtn_and_gptq_on_correlated_h():
    w, h = make_problem(11, m=48, n=64, p=256)
    res = ganq_quantize(w, h=h, cfg=QuantConfig(bits=4, iters=8,
                                                precondition="fixed"))
    e_ganq = float(layer_objective(w, res.layer.dequantize(), h))
    e_rtn = float(layer_objective(w, rtn_reconstruct(w, 4), h))
    e_gptq = float(layer_objective(w, gptq_reconstruct(w, h, 4), h))
    assert e_ganq < e_rtn, (e_ganq, e_rtn)
    assert e_ganq < e_gptq, (e_ganq, e_gptq)


def test_ganq_err_history_decreases_overall():
    w, h = make_problem(13)
    res = ganq_quantize(w, h=h, cfg=QuantConfig(bits=4, iters=6))
    hist = np.asarray(res.err_history)
    assert hist[-1] <= hist[0]
    assert np.all(np.isfinite(hist))


def test_ganq_3bit_and_outliers():
    """Table 5's claim holds in its own regime: rows with extreme outliers
    that stretch the codebook range (paper Fig. 1b)."""
    w, h = make_problem(17)
    rng = np.random.default_rng(170)
    w = np.array(w)  # writable copy
    rows = rng.integers(0, w.shape[0], size=w.shape[0])
    cols = rng.integers(0, w.shape[1], size=w.shape[0])
    w[rows, cols] += rng.choice([-1.0, 1.0], size=w.shape[0]) * 1.5  # ~75x sigma
    w = jnp.asarray(w)
    base = ganq_quantize(w, h=h, cfg=QuantConfig(bits=3, iters=6,
                                                 precondition="fixed"))
    star = ganq_quantize(w, h=h, cfg=QuantConfig(bits=3, iters=6,
                                                 precondition="fixed",
                                                 outlier_ratio=0.04))
    e_base = float(layer_objective(w, base.layer.dequantize(), h))
    e_star = float(layer_objective(w, star.layer.dequantize(), h))
    assert e_star < e_base, (e_star, e_base)  # Table 5's claim


def test_ganq_full_rows_kept_exact():
    w, h = make_problem(19)
    res = ganq_quantize(w, h=h, cfg=QuantConfig(bits=4, iters=2, full_rows=3))
    wq = np.asarray(res.layer.dequantize())
    idx = np.asarray(res.layer.full_row_idx)
    np.testing.assert_allclose(wq[idx], np.asarray(w)[idx], rtol=1e-6)


def test_ganq_act_order_roundtrip():
    """Column permutation must be undone — codes must decode consistently."""
    w, h = make_problem(23)
    res = ganq_quantize(w, h=h, cfg=QuantConfig(bits=4, iters=4, act_order=True,
                                                precondition="fixed"))
    e = float(layer_objective(w, res.layer.dequantize(), h))
    e_rtn = float(layer_objective(w, rtn_reconstruct(w, 4), h))
    assert e < e_rtn


def test_ganq_from_x_equals_from_h():
    rng = np.random.default_rng(29)
    w = jnp.asarray(rng.normal(size=(16, 24)).astype(np.float32))
    x = jnp.asarray(rng.normal(size=(24, 64)).astype(np.float32))
    r1 = ganq_quantize(w, x=x, cfg=QuantConfig(iters=2))
    r2 = ganq_quantize(w, h=compute_h(x), cfg=QuantConfig(iters=2))
    np.testing.assert_array_equal(np.asarray(r1.layer.codes),
                                  np.asarray(r2.layer.codes))


def test_ganq_rejects_bad_args():
    w = jnp.zeros((4, 4))
    with pytest.raises(ValueError):
        ganq_quantize(w)
    with pytest.raises(ValueError):
        ganq_quantize(w, h=jnp.eye(4), x=jnp.zeros((4, 8)))
