"""Auto-precision search: sensitivity profiler, budgeted allocator,
spec emitter round-trip over every registered config's real layer names."""
import itertools
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs, reduce_config
from repro.core import (LayerQuantReport, QuantConfig, parse_policy)
from repro.core.bitsearch import (FP_KEY, AutoSpec, SensitivityProfile,
                                  allocation_groups, candidate_fmt,
                                  emit_policy_spec, escape_pattern,
                                  load_report, model_layer_names,
                                  parse_auto_spec, profile_sensitivity,
                                  save_report, search_policy)

KEY = jax.random.PRNGKey(0)
QCFG = QuantConfig(bits=4, iters=2, precondition="fixed")


def synth_profile(cfg, widths=(2, 3, 4), include_fp=True, err_fn=None):
    """Fabricate a SensitivityProfile over a config's real group
    structure (no PTQ) for allocator/emitter tests."""
    groups = allocation_groups(cfg)
    gdesc, entries = {}, {}
    for gi, g in enumerate(groups):
        n_w = 1000 + 10 * gi
        gdesc[g.key] = {"suffix": g.suffix, "members": g.members,
                        "param_paths": g.param_paths, "n_weights": n_w,
                        "shape": [16, 16]}
        per = {}
        for b in widths:
            err = (err_fn(g.key, b) if err_fn
                   else (1 + gi) * 100.0 / (b * b))
            per[str(b)] = {"err": err, "bits_per_weight": b + 1.0,
                           "fmt": candidate_fmt(b), "bits": b,
                           "weight_bytes": n_w * b / 8.0}
        if include_fp:
            per[FP_KEY] = {"err": 0.0, "bits_per_weight": 32.0,
                           "fmt": "dense", "bits": None,
                           "weight_bytes": n_w * 4.0}
        entries[g.key] = per
    return SensitivityProfile(arch="synthetic", groups=gdesc,
                              entries=entries, meta={"decode_p": 8})


# ------------------------------------------------------------- escaping

def test_escape_pattern_literal_anchoring():
    """Escaped literals full-match exactly their name: no substring
    capture (layer3 vs layer13), no segment shorthand."""
    pol = parse_policy(f"{escape_pattern('layer3/mlp/w_up')}=2,"
                       f"{escape_pattern('layer13/mlp/w_up')}=3", QCFG)
    assert pol.resolve("layer3/mlp/w_up").qcfg.bits == 2
    assert pol.resolve("layer13/mlp/w_up").qcfg.bits == 3
    # unrelated names fall through to the default
    assert pol.resolve("layer31/mlp/w_up").qcfg.bits == 4


@pytest.mark.parametrize("name", [
    "layer3/mlp/w_up", "weird*name/w", "q?mark/w", "br[acket/w",
    "mix*?/[all]/w", "[leading/w", "enc0/attn/wq",
])
def test_escape_pattern_adversarial_names(name):
    import fnmatch
    pat = escape_pattern(name)
    assert fnmatch.fnmatchcase(name, pat), (name, pat)
    # near-miss names must NOT match (superstring / substring attacks)
    for other in (f"x{name}", f"{name}x", name.replace("/", "//")):
        assert not fnmatch.fnmatchcase(other, pat), (other, pat)


def test_escape_pattern_rejects_grammar_breakers():
    with pytest.raises(ValueError):
        escape_pattern("has=equals/w")
    with pytest.raises(ValueError):
        escape_pattern("has,comma/w")


# --------------------------------------------------- groups + roundtrip

def test_allocation_groups_respect_stacking():
    """Unit-layer groups span every unit sharing a stacked position;
    whisper sides group whole; all capture names covered exactly once."""
    cfg = reduce_config(get_config("deepseek-7b"))
    groups = allocation_groups(cfg)
    for g in groups:
        if g.key.startswith("unit"):
            assert len(g.members) == cfg.n_layers // 1 or len(g.members) > 1
    names = model_layer_names(cfg)
    covered = [m for g in groups for m in g.members]
    assert sorted(covered) == sorted(names)
    assert len(set(covered)) == len(covered)

    wcfg = reduce_config(get_config("whisper-medium"))
    wgroups = allocation_groups(wcfg)
    sides = {g.key.split(":")[0] for g in wgroups}
    assert sides == {"enc", "dec"}
    assert any(g.suffix.startswith("xattn/") for g in wgroups
               if g.key.startswith("dec"))
    wnames = model_layer_names(wcfg)
    assert sorted(m for g in wgroups for m in g.members) == sorted(wnames)


@pytest.mark.parametrize("arch", list_archs())
def test_policy_roundtrip_all_configs(arch):
    """parse_policy(emit(alloc)) resolves every real capture name AND
    param-tree path of every registered config back to the original
    allocation — the spec round-trip guarantee."""
    cfg = reduce_config(get_config(arch))
    prof = synth_profile(cfg)
    groups = allocation_groups(cfg)
    assert groups, arch
    # cycle widths across groups so same-suffix groups disagree wherever
    # the config allows it (exercises the literal-rule fallback)
    cycle = itertools.cycle(["2", "3", "4", FP_KEY])
    choice = {g.key: next(cycle) for g in groups}
    spec = emit_policy_spec(prof, choice)
    pol = parse_policy(spec, QCFG)
    for g in groups:
        want = choice[g.key]
        for name in g.members + g.param_paths:
            r = pol.resolve(name)
            got = FP_KEY if r.keep_fp else str(r.qcfg.bits)
            assert got == want, (arch, name, got, want, spec)


def test_roundtrip_survives_reparse_of_emitted_spec():
    """emit -> parse -> emit (same choices) is a fixed point."""
    cfg = reduce_config(get_config("deepseek-7b"))
    prof = synth_profile(cfg)
    res = search_policy(prof, budget=3.0)
    pol = parse_policy(res.spec, QCFG)
    for gkey, wkey in res.choice.items():
        for name in prof.groups[gkey]["members"]:
            r = pol.resolve(name)
            got = FP_KEY if r.keep_fp else str(r.qcfg.bits)
            assert got == wkey


def test_emitted_spec_drives_abstract_quantize():
    """The dry-run transform resolves the emitted spec identically to
    the live pipeline (param-tree paths, stacked leaves)."""
    from repro.core.types import QuantizedLinear
    from repro.models.model import abstract_params
    from repro.models.quantized import abstract_quantize
    cfg = reduce_config(get_config("deepseek-7b"))
    prof = synth_profile(cfg)
    groups = allocation_groups(cfg)
    choice = {g.key: ("2" if "mlp" in g.suffix else "4") for g in groups}
    spec = emit_policy_spec(prof, choice)
    sds = abstract_quantize(abstract_params(cfg), cfg,
                            policy=parse_policy(spec, QCFG))
    units = sds["stack"]["units"][0]
    assert units["mlp"]["w_up"].bits == 2
    assert units["attn"]["wq"].bits == 4
    assert isinstance(units["mlp"]["w_up"], QuantizedLinear)


def test_emit_kv_draft_passthrough():
    cfg = reduce_config(get_config("deepseek-7b"))
    prof = synth_profile(cfg)
    choice = {g.key: "4" for g in allocation_groups(cfg)}
    spec = emit_policy_spec(prof, choice, kv="paged_int8", draft=3)
    assert "kv=paged_int8" in spec and "draft=3" in spec
    pol = parse_policy(spec, QCFG)
    assert pol.kv_fmt == "paged_int8"
    assert pol.draft_bits == 3


# ------------------------------------------------------------ allocator

def test_search_respects_budget_and_picks_known_optimum():
    cfg = reduce_config(get_config("deepseek-7b"))
    # one group far more sensitive than the rest: at a budget of 3.0 the
    # optimum parks everything else low to buy it width
    groups = allocation_groups(cfg)
    hot = groups[0].key

    def err_fn(key, b):
        base = 1e4 if key == hot else 1.0
        return base / (2.0 ** b)
    prof = synth_profile(cfg, err_fn=err_fn, include_fp=False)
    res = search_policy(prof, budget=3.0, include_fp=False)
    total_w = prof.total_weights()
    used = sum(int(k) * prof.groups[g]["n_weights"]
               for g, k in res.choice.items())
    assert used / total_w <= 3.0 + 1e-9
    assert res.choice[hot] == "4"
    # and it beats uniform 3-bit (which is feasible) on summed error
    uni_err = sum(prof.entries[g.key]["3"]["err"] for g in groups)
    assert res.total_err <= uni_err


def test_search_infeasible_budget_raises_with_minimum():
    cfg = reduce_config(get_config("deepseek-7b"))
    prof = synth_profile(cfg, widths=(3, 4), include_fp=False)
    with pytest.raises(ValueError, match="minimum achievable"):
        search_policy(prof, budget=1.0, widths=(3, 4), include_fp=False)


def test_search_rejects_unproven_widths():
    cfg = reduce_config(get_config("deepseek-7b"))
    prof = synth_profile(cfg)
    with pytest.raises(ValueError, match="parity"):
        search_policy(prof, budget=3.0, widths=(3, 7))


def test_search_cost_modes_agree_on_direction():
    """All cost modes produce feasible allocations; storage mode charges
    the codebook so its achieved code-bits are <= the bits mode's."""
    cfg = reduce_config(get_config("deepseek-7b"))
    prof = synth_profile(cfg, include_fp=False)
    r_bits = search_policy(prof, budget=3.0, cost="bits", include_fp=False)
    r_stor = search_policy(prof, budget=3.0, cost="storage",
                           include_fp=False)
    r_byte = search_policy(prof, budget=3.0, cost="bytes", include_fp=False)
    r_meas = search_policy(prof, budget=3.0, cost="measured",
                           include_fp=False)
    assert r_bits.bits_per_weight <= 3.0 + 1e-9
    assert r_stor.bits_per_weight <= r_bits.bits_per_weight + 1e-9
    for r in (r_byte, r_meas):
        assert set(r.choice) == set(r_bits.choice)
    with pytest.raises(ValueError, match="cost mode"):
        search_policy(prof, budget=3.0, cost="nope")


# ----------------------------------------------------- auto-spec parser

def test_parse_auto_spec():
    a = parse_auto_spec("budget=3.4")
    assert a == AutoSpec(budget=3.4)
    a = parse_auto_spec("budget=3,cost=storage,cands=2+3+4,fp=0,"
                        "kv=paged_int8,draft=2")
    assert a.cost == "storage" and a.widths == (2, 3, 4)
    assert a.include_fp is False and a.kv == "paged_int8" and a.draft == 2
    with pytest.raises(ValueError, match="budget"):
        parse_auto_spec("cost=bits")
    with pytest.raises(ValueError, match="unknown"):
        parse_auto_spec("budget=3,bogus=1")
    with pytest.raises(ValueError, match="key=value"):
        parse_auto_spec("budget=3,oops")


# ------------------------------------------- profiler + IO (real model)

def test_profile_search_roundtrip_real_model(tmp_path):
    """End to end on a real reduced model: profile via the PTQ report
    path, search, emit, save/load, warm-start equality."""
    from repro.data.synthetic import MarkovStream
    from repro.models import init_params
    cfg = reduce_config(get_config("deepseek-7b"))
    params = init_params(KEY, cfg)
    data = MarkovStream(cfg.vocab_size, batch=2, seq=32, seed=0)
    batch = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}
    prof = profile_sensitivity(params, cfg, batch, widths=(2, 3),
                               qcfg=QCFG, arch="deepseek-7b")
    assert set(prof.widths()) == {FP_KEY, "2", "3"}
    for gkey, per in prof.entries.items():
        assert prof.groups[gkey]["n_weights"] > 0
        # wider is better: monotone err in width per group
        assert per["3"]["err"] <= per["2"]["err"]
        assert per[FP_KEY]["err"] == 0.0
        assert per["2"]["fmt"] == "lut2_packed"
        assert per["2"]["weight_bytes"] > 0
    path = tmp_path / "prof.json"
    prof.save(str(path))
    loaded = SensitivityProfile.load(str(path))
    assert loaded.entries == prof.entries
    assert loaded.groups == prof.groups
    # warm start: no params needed beyond the covered widths -> equal
    warm = profile_sensitivity(params, cfg, batch, widths=(2, 3),
                               qcfg=QCFG, warm=loaded, arch="deepseek-7b")
    assert warm.entries == prof.entries
    res = search_policy(prof, budget=2.5, widths=(2, 3), include_fp=False)
    assert res.bits_per_weight <= 2.5 + 1e-9
    assert res.spec
    # schema guard
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps({"schema": 99}))
    with pytest.raises(ValueError, match="schema"):
        SensitivityProfile.load(str(bad))


def test_report_json_roundtrip(tmp_path):
    rep = {"layer0/attn/wq": LayerQuantReport(
        err=1.5, bits_per_weight=4.5, bits=4, fmt="lut4_packed",
        method="ganq", n_weights=4096, shape=(64, 64)),
        "layer0/mlp/w_up": LayerQuantReport(
        err=0.0, bits_per_weight=32.0, bits=None, fmt="dense",
        method="none", n_weights=128, shape=(16, 8))}
    path = tmp_path / "report.json"
    save_report(rep, str(path), extra={"arch": "x"})
    d = json.loads(path.read_text())
    assert d["arch"] == "x"
    back = load_report(str(path))
    assert back == rep
    assert back["layer0/attn/wq"].shape == (64, 64)
    assert float(back["layer0/attn/wq"]) == 1.5
