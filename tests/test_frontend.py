"""Async SSE front end + reentrant session: token identity, streaming.

The contract under test is the tentpole's: `ServeEngine.serve()` (closed
loop), manual `start()`/`step()` session driving, and the asyncio SSE
front end are three drivers over ONE control flow, so greedy tokens must
be identical across all of them for the same seed — and the streamed
token events must carry every token exactly once, in order, with
strictly increasing timestamps.
"""
import asyncio

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduce_config
from repro.models import init_params
from repro.serve.engine import GenRequest, ServeEngine
from repro.serve.frontend import AsyncServeFrontend, fetch_json, sse_generate


def _setup():
    cfg = reduce_config(get_config("deepseek-7b"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _reqs():
    return [GenRequest(prompt=[1, 2, 3, 4, 5], max_new=6),
            GenRequest(prompt=[7, 8, 9], max_new=5),
            GenRequest(prompt=[3, 1, 4, 1, 5, 9, 2, 6], max_new=4)]


def test_session_step_matches_serve():
    """Manual submit/step driving reproduces serve() results and streams
    every token as an ordered event."""
    cfg, params = _setup()
    eng = ServeEngine(params, cfg, max_len=64, n_slots=2, prefill_chunk=8)
    ref = eng.serve(_reqs(), seed=0)

    sess = eng.start(seed=0)
    uids = [sess.submit(r) for r in _reqs()]
    events = []
    while not sess.done():
        events.append(sess.step())
    flat = [e for step in events for e in step]
    assert max(len(s) for s in events) >= 1       # events arrive per step
    for uid, r in zip(uids, ref):
        toks = [e.token for e in flat if e.uid == uid and not e.done]
        assert toks == r.tokens
        idxs = [e.index for e in flat if e.uid == uid and not e.done]
        assert idxs == list(range(len(r.tokens)))
        ts = [e.t_s for e in flat if e.uid == uid]
        assert ts == sorted(ts)
        terminal = [e for e in flat if e.uid == uid and e.done]
        assert len(terminal) == 1
        assert terminal[0].finish_reason == r.finish_reason
        assert sess.results[uid].tokens == r.tokens
    st = sess.stats()
    assert st["decode_tokens"] > 0 and st["prefills"] == 3


def test_frontend_sse_identity_and_metrics():
    """CI smoke contract: >=3 concurrent mixed-length SSE streams produce
    exactly the closed-loop engine's greedy tokens, and /v1/metrics
    reports nonzero TTFT/ITL percentiles and an achieved-bandwidth
    figure."""
    cfg, params = _setup()
    eng = ServeEngine(params, cfg, max_len=64, n_slots=2, prefill_chunk=8)
    ref = eng.serve(_reqs(), seed=0)

    async def drive():
        async with AsyncServeFrontend(eng, seed=0, track=True) as fe:
            frames = await asyncio.gather(*[
                sse_generate("127.0.0.1", fe.port,
                             {"prompt": r.prompt, "max_new": r.max_new})
                for r in _reqs()])
            metrics = await fetch_json("127.0.0.1", fe.port, "/v1/metrics")
            health = await fetch_json("127.0.0.1", fe.port, "/healthz")
        return frames, metrics, health

    frames, metrics, health = asyncio.run(drive())
    assert health == {"ok": True}
    for fs, r in zip(frames, ref):
        toks = [f["token"] for f in fs if "token" in f]
        assert toks == r.tokens
        final = fs[-1]
        assert final["done"] and final["finish_reason"] == r.finish_reason
        assert final["n_tokens"] == len(r.tokens)
        assert final["ttft_s"] > 0
    lat = metrics["latency"]
    assert lat["ttft_s"]["p99"] > 0 and lat["itl_s"]["p50"] > 0
    assert metrics["goodput"]["n_requests"] == 3
    assert metrics["goodput"]["slo_attainment"] == 1.0  # SLO() = no limits
    hw = metrics["engine"]["hw"]
    assert hw["achieved_hbm_gbps"]["p50"] > 0
    assert 0 < hw["hbm_util_pct"]["p50"] and hw["mfu_pct"]["p50"] > 0
    assert hw["step_bytes"]["mixed"] > 0


def test_frontend_streams_while_decoding():
    """Tokens arrive incrementally (streaming, not buffered-at-end): the
    first SSE frame lands before the request's terminal frame by
    construction; check frame timestamps span multiple engine steps."""
    cfg, params = _setup()
    eng = ServeEngine(params, cfg, max_len=64, n_slots=2, prefill_chunk=8)

    async def drive():
        async with AsyncServeFrontend(eng, seed=0) as fe:
            return await sse_generate(
                "127.0.0.1", fe.port, {"prompt": [1, 2, 3], "max_new": 8})

    frames = asyncio.run(drive())
    toks = [f for f in frames if "token" in f]
    assert len(toks) == 8
    ts = [f["t_s"] for f in toks]
    assert ts == sorted(ts) and ts[0] < ts[-1]


def test_frontend_open_loop_poisson_identity():
    """Seeded Poisson arrivals through real sockets match the engine's
    open-loop serve() on the same arrival offsets (loadgen's identity
    contract, miniature)."""
    cfg, params = _setup()
    eng = ServeEngine(params, cfg, max_len=64, n_slots=2, prefill_chunk=8)
    rng = np.random.default_rng(3)
    arrivals = np.cumsum(rng.exponential(1 / 40.0, size=3)).tolist()
    ref = eng.serve(_reqs(), seed=0, arrival_times=arrivals)

    async def drive():
        async def one(req, delay):
            await asyncio.sleep(delay)
            return await sse_generate("127.0.0.1", fe.port,
                                      {"prompt": req.prompt,
                                       "max_new": req.max_new})
        fe = AsyncServeFrontend(eng, seed=0)
        async with fe:
            return await asyncio.gather(
                *[one(r, t) for r, t in zip(_reqs(), arrivals)])

    frames = asyncio.run(drive())
    toks = [[f["token"] for f in fs if "token" in f] for fs in frames]
    assert toks == [r.tokens for r in ref]


def test_loadgen_poisson_reproducible():
    import sys
    from pathlib import Path
    sys.path.insert(0, str(Path(__file__).resolve().parent.parent
                           / "benchmarks"))
    from loadgen import poisson_arrivals
    a = poisson_arrivals(8.0, 16, seed=5)
    b = poisson_arrivals(8.0, 16, seed=5)
    c = poisson_arrivals(8.0, 16, seed=6)
    assert a == b and a != c
    assert all(x < y for x, y in zip(a, a[1:]))   # strictly increasing
    assert len(a) == 16 and a[0] > 0
