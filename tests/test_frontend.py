"""Async SSE front end + reentrant session: token identity, streaming.

The contract under test is the tentpole's: `ServeEngine.serve()` (closed
loop), manual `start()`/`step()` session driving, and the asyncio SSE
front end are three drivers over ONE control flow, so greedy tokens must
be identical across all of them for the same seed — and the streamed
token events must carry every token exactly once, in order, with
strictly increasing timestamps.
"""
import asyncio

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduce_config
from repro.models import init_params
from repro.serve.engine import GenRequest, ServeEngine
from repro.serve.frontend import (AsyncServeFrontend, fetch_json, post_json,
                                  sse_generate)


def _setup():
    cfg = reduce_config(get_config("deepseek-7b"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _reqs():
    return [GenRequest(prompt=[1, 2, 3, 4, 5], max_new=6),
            GenRequest(prompt=[7, 8, 9], max_new=5),
            GenRequest(prompt=[3, 1, 4, 1, 5, 9, 2, 6], max_new=4)]


def test_session_step_matches_serve():
    """Manual submit/step driving reproduces serve() results and streams
    every token as an ordered event."""
    cfg, params = _setup()
    eng = ServeEngine(params, cfg, max_len=64, n_slots=2, prefill_chunk=8)
    ref = eng.serve(_reqs(), seed=0)

    sess = eng.start(seed=0)
    uids = [sess.submit(r) for r in _reqs()]
    events = []
    while not sess.done():
        events.append(sess.step())
    flat = [e for step in events for e in step]
    assert max(len(s) for s in events) >= 1       # events arrive per step
    for uid, r in zip(uids, ref):
        toks = [e.token for e in flat if e.uid == uid and not e.done]
        assert toks == r.tokens
        idxs = [e.index for e in flat if e.uid == uid and not e.done]
        assert idxs == list(range(len(r.tokens)))
        ts = [e.t_s for e in flat if e.uid == uid]
        assert ts == sorted(ts)
        terminal = [e for e in flat if e.uid == uid and e.done]
        assert len(terminal) == 1
        assert terminal[0].finish_reason == r.finish_reason
        assert sess.results[uid].tokens == r.tokens
    st = sess.stats()
    assert st["decode_tokens"] > 0 and st["prefills"] == 3


def test_frontend_sse_identity_and_metrics():
    """CI smoke contract: >=3 concurrent mixed-length SSE streams produce
    exactly the closed-loop engine's greedy tokens, and /v1/metrics
    reports nonzero TTFT/ITL percentiles and an achieved-bandwidth
    figure."""
    cfg, params = _setup()
    eng = ServeEngine(params, cfg, max_len=64, n_slots=2, prefill_chunk=8)
    ref = eng.serve(_reqs(), seed=0)

    async def drive():
        async with AsyncServeFrontend(eng, seed=0, track=True) as fe:
            frames = await asyncio.gather(*[
                sse_generate("127.0.0.1", fe.port,
                             {"prompt": r.prompt, "max_new": r.max_new})
                for r in _reqs()])
            metrics = await fetch_json("127.0.0.1", fe.port, "/v1/metrics")
            health = await fetch_json("127.0.0.1", fe.port, "/healthz")
        return frames, metrics, health

    frames, metrics, health = asyncio.run(drive())
    assert health == {"ok": True}
    for fs, r in zip(frames, ref):
        toks = [f["token"] for f in fs if "token" in f]
        assert toks == r.tokens
        final = fs[-1]
        assert final["done"] and final["finish_reason"] == r.finish_reason
        assert final["n_tokens"] == len(r.tokens)
        assert final["ttft_s"] > 0
    lat = metrics["latency"]
    assert lat["ttft_s"]["p99"] > 0 and lat["itl_s"]["p50"] > 0
    assert metrics["goodput"]["n_requests"] == 3
    assert metrics["goodput"]["slo_attainment"] == 1.0  # SLO() = no limits
    hw = metrics["engine"]["hw"]
    assert hw["achieved_hbm_gbps"]["p50"] > 0
    assert 0 < hw["hbm_util_pct"]["p50"] and hw["mfu_pct"]["p50"] > 0
    assert hw["step_bytes"]["mixed"] > 0


def test_frontend_streams_while_decoding():
    """Tokens arrive incrementally (streaming, not buffered-at-end): the
    first SSE frame lands before the request's terminal frame by
    construction; check frame timestamps span multiple engine steps."""
    cfg, params = _setup()
    eng = ServeEngine(params, cfg, max_len=64, n_slots=2, prefill_chunk=8)

    async def drive():
        async with AsyncServeFrontend(eng, seed=0) as fe:
            return await sse_generate(
                "127.0.0.1", fe.port, {"prompt": [1, 2, 3], "max_new": 8})

    frames = asyncio.run(drive())
    toks = [f for f in frames if "token" in f]
    assert len(toks) == 8
    ts = [f["t_s"] for f in toks]
    assert ts == sorted(ts) and ts[0] < ts[-1]


def test_frontend_open_loop_poisson_identity():
    """Seeded Poisson arrivals through real sockets match the engine's
    open-loop serve() on the same arrival offsets (loadgen's identity
    contract, miniature)."""
    cfg, params = _setup()
    eng = ServeEngine(params, cfg, max_len=64, n_slots=2, prefill_chunk=8)
    rng = np.random.default_rng(3)
    arrivals = np.cumsum(rng.exponential(1 / 40.0, size=3)).tolist()
    ref = eng.serve(_reqs(), seed=0, arrival_times=arrivals)

    async def drive():
        async def one(req, delay):
            await asyncio.sleep(delay)
            return await sse_generate("127.0.0.1", fe.port,
                                      {"prompt": req.prompt,
                                       "max_new": req.max_new})
        fe = AsyncServeFrontend(eng, seed=0)
        async with fe:
            return await asyncio.gather(
                *[one(r, t) for r, t in zip(_reqs(), arrivals)])

    frames = asyncio.run(drive())
    toks = [[f["token"] for f in fs if "token" in f] for fs in frames]
    assert toks == [r.tokens for r in ref]


# ------------------------------------------------------ robustness rim

def test_frontend_malformed_requests_400():
    """Every malformed body gets a 400 + JSON error BEFORE touching the
    shared driver thread — and the server keeps serving good requests
    afterwards (the original bug: a bad body crashed the driver)."""
    cfg, params = _setup()
    eng = ServeEngine(params, cfg, max_len=64, n_slots=2, prefill_chunk=8)
    ref = eng.serve([_reqs()[0]], seed=0)
    bad_bodies = [
        b"{not json",                                  # not JSON
        b"[1, 2, 3]",                                  # not an object
        {"max_new": 4},                                # missing prompt
        {"prompt": []},                                # empty prompt
        {"prompt": "hello"},                           # wrong type
        {"prompt": [1, "x"]},                          # non-int token
        {"prompt": [1, cfg.vocab_size + 5]},           # out of vocab
        {"prompt": list(range(1, 70))},                # >= max_len
        {"prompt": [1, 2], "max_new": 0},              # bad max_new
        {"prompt": [1, 2], "temperature": -1},         # bad temperature
        {"prompt": [1, 2], "timeout_s": 0},            # bad timeout
        {"prompt": [1, 2], "max_new": "many"},         # non-numeric
        {"prompt": [1, 2], "frobnicate": 1},           # unknown field
    ]

    async def drive():
        async with AsyncServeFrontend(eng, seed=0) as fe:
            statuses = []
            for body in bad_bodies:
                status, payload = await post_json(
                    "127.0.0.1", fe.port, "/v1/generate", body)
                statuses.append(status)
                assert "error" in payload, payload
            # the driver thread survived all of that: a good request
            # still streams the exact engine tokens
            frames = await sse_generate(
                "127.0.0.1", fe.port,
                {"prompt": _reqs()[0].prompt, "max_new": _reqs()[0].max_new})
            metrics = await fetch_json("127.0.0.1", fe.port, "/v1/metrics")
        return statuses, frames, metrics

    statuses, frames, metrics = asyncio.run(drive())
    assert statuses == [400] * len(bad_bodies)
    assert [f["token"] for f in frames if "token" in f] == ref[0].tokens
    fr = metrics["frontend"]
    assert fr["rejected_400"] == len(bad_bodies)
    assert fr["requests"] == 1 and fr["driver_errors"] == 0


def test_publish_slow_client_policy():
    """Driver-side backpressure valve, unit-tested (loopback OS socket
    buffers absorb small streams, so the real-socket path can't fill an
    SSE queue deterministically): a stream whose queue is at
    `sse_queue_max` is disconnected, its request cancelled ON the driver
    thread, its transport aborted — and the later ConnectionError in its
    handler must NOT double-count as a plain client disconnect."""
    class FakeSession:
        def __init__(self):
            self.cancelled = []

        def cancel(self, uid):
            self.cancelled.append(uid)
            return True

    class FakeLoop:
        def __init__(self):
            self.calls = []

        def call_soon_threadsafe(self, fn, *a):
            self.calls.append((fn, a))
            fn(*a)

    class FakeTransport:
        def __init__(self):
            self.aborted = False

        def abort(self):
            self.aborted = True

    from repro.serve.scheduler import TokenEvent
    fe = AsyncServeFrontend(object(), sse_queue_max=2)
    fe.session = FakeSession()
    fe._loop = FakeLoop()
    slow_q, fast_q = asyncio.Queue(), asyncio.Queue()
    for _ in range(2):                     # slow client: at the bound
        slow_q.put_nowait(object())
    fe._streams = {5: slow_q, 6: fast_q}
    tr = FakeTransport()
    fe._transports[5] = tr
    fe._publish([TokenEvent(5, 11, 0.1, 3), TokenEvent(6, 12, 0.1, 3)])
    assert fe.counters["slow_client_disconnects"] == 1
    assert 5 not in fe._streams and 5 in fe._dropped
    assert fe.session.cancelled == [5]     # freed on the driver thread
    assert tr.aborted
    assert slow_q.qsize() == 2             # the overflow event was dropped
    assert fast_q.qsize() == 1             # healthy stream still fed
    fe._client_gone(5)                     # handler sees ConnectionError
    assert fe.counters["client_disconnects"] == 0   # no double count
    fe._client_gone(6)
    assert fe.counters["client_disconnects"] == 1


def test_frontend_client_disconnect_cancels_request():
    """A client that vanishes mid-stream (socket close -> EOF watcher)
    gets its request cancelled: slot freed, finish_reason='cancelled',
    partial tokens kept — and the engine keeps serving others."""
    import json as _json
    cfg, params = _setup()
    eng = ServeEngine(params, cfg, max_len=64, n_slots=2, prefill_chunk=8)

    async def drive():
        async with AsyncServeFrontend(eng, seed=0) as fe:
            reader, writer = await asyncio.open_connection(
                "127.0.0.1", fe.port)
            body = _json.dumps({"prompt": [1, 2, 3], "max_new": 40}
                               ).encode()
            writer.write(
                b"POST /v1/generate HTTP/1.1\r\nHost: x\r\n"
                b"Content-Type: application/json\r\n"
                + f"Content-Length: {len(body)}\r\n\r\n".encode() + body)
            await writer.drain()
            await reader.readuntil(b"\r\n\r\n")
            await reader.readline()            # at least one token frame
            writer.close()                     # client walks away
            await writer.wait_closed()
            for _ in range(300):               # wait for the cancel
                m = await fetch_json("127.0.0.1", fe.port, "/v1/metrics")
                if m["engine"]["faults"]["cancels"] >= 1:
                    break
                await asyncio.sleep(0.02)
            else:
                raise AssertionError("disconnect never cancelled request")
            # engine unharmed: a fresh stream completes normally
            frames = await sse_generate("127.0.0.1", fe.port,
                                        {"prompt": [7, 8, 9], "max_new": 4})
            m = await fetch_json("127.0.0.1", fe.port, "/v1/metrics")
        return frames, m

    frames, metrics = asyncio.run(drive())
    assert frames[-1]["done"] and frames[-1]["finish_reason"] == "length"
    assert metrics["frontend"]["client_disconnects"] == 1
    assert metrics["engine"]["faults"]["cancels"] == 1


def test_frontend_graceful_drain_and_503():
    """stop() drains: the in-flight stream finishes cleanly while NEW
    posts are refused with 503 — then the server closes."""
    cfg, params = _setup()
    eng = ServeEngine(params, cfg, max_len=64, n_slots=2, prefill_chunk=8)

    async def drive():
        fe = AsyncServeFrontend(eng, seed=0)
        await fe.start()
        stream = asyncio.create_task(sse_generate(
            "127.0.0.1", fe.port, {"prompt": [1, 2, 3], "max_new": 32}))
        await asyncio.sleep(0.3)               # let it start decoding
        stop = asyncio.create_task(fe.stop())
        await asyncio.sleep(0.05)
        if not stop.done():                    # still draining: 503
            status, payload = await post_json(
                "127.0.0.1", fe.port, "/v1/generate",
                {"prompt": [4, 5], "max_new": 4})
            assert status == 503 and payload["error"] == "draining"
            assert fe.counters["rejected_503"] == 1
        frames = await stream
        await stop
        return frames

    frames = asyncio.run(drive())
    # drained, not killed: the full stream arrived with a clean finish
    assert [f for f in frames if "token" in f]
    assert frames[-1]["done"] and frames[-1]["finish_reason"] == "length"


def test_frontend_queue_cap_503_overload():
    """Past `queue_cap` arrived-queue depth a new POST gets a fast 503
    (the engine-side shed valve backs this up for anything that races
    past the check)."""
    cfg, params = _setup()
    eng = ServeEngine(params, cfg, max_len=64, n_slots=1, prefill_chunk=8)

    async def drive():
        async with AsyncServeFrontend(eng, seed=0, queue_cap=1) as fe:
            # A occupies the single slot; B queues (depth 1 == cap)
            a = asyncio.create_task(sse_generate(
                "127.0.0.1", fe.port, {"prompt": [1, 2, 3],
                                       "max_new": 48}))
            await asyncio.sleep(0.3)
            b = asyncio.create_task(sse_generate(
                "127.0.0.1", fe.port, {"prompt": [4, 5, 6],
                                       "max_new": 4}))
            for _ in range(300):    # wait until B is queued behind A
                m = await fetch_json("127.0.0.1", fe.port, "/v1/metrics")
                if m["frontend"]["open_streams"] >= 2:
                    break
                await asyncio.sleep(0.01)
            else:
                raise AssertionError("B never reached the queue")
            status, payload = await post_json(   # C: refused at the door
                "127.0.0.1", fe.port, "/v1/generate",
                {"prompt": [7, 8], "max_new": 4})
            assert status == 503 and payload["error"] == "overloaded"
            fa, fb = await a, await b
        return fa, fb

    fa, fb = asyncio.run(drive())
    assert fa[-1]["done"] and fa[-1]["finish_reason"] == "length"
    assert fb[-1]["done"]                      # B eventually served


def test_loadgen_poisson_reproducible():
    import sys
    from pathlib import Path
    sys.path.insert(0, str(Path(__file__).resolve().parent.parent
                           / "benchmarks"))
    from loadgen import poisson_arrivals
    a = poisson_arrivals(8.0, 16, seed=5)
    b = poisson_arrivals(8.0, 16, seed=5)
    c = poisson_arrivals(8.0, 16, seed=6)
    assert a == b and a != c
    assert all(x < y for x, y in zip(a, a[1:]))   # strictly increasing
    assert len(a) == 16 and a[0] > 0
