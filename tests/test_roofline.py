"""Roofline analysis unit tests: HLO parsers validated on known graphs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.analysis import (collective_wire_bytes, tpu_bytes_accessed,
                                     _shape_bytes)


def _compile(f, *sds):
    return jax.jit(f).lower(*sds).compile()


def test_shape_bytes():
    assert _shape_bytes("f32[128,256]") == 128 * 256 * 4
    assert _shape_bytes("bf16[8]{0}") == 16
    assert _shape_bytes("(f32[2,2], bf16[4])") == 16 + 8
    assert _shape_bytes("pred[]") == 0 or _shape_bytes("pred[]") >= 0


def test_walker_elementwise_fusion():
    """tanh(x*2)+1 reads x once, writes once: 2 * nbytes."""
    n = 1 << 20
    c = _compile(lambda x: jnp.tanh(x * 2) + 1,
                 jax.ShapeDtypeStruct((n,), jnp.float32))
    b = tpu_bytes_accessed(c.as_text())
    assert abs(b - 2 * 4 * n) / (2 * 4 * n) < 0.05, b


def test_walker_matmul():
    """x @ y: read both, write out."""
    m = 512
    sds = jax.ShapeDtypeStruct((m, m), jnp.float32)
    c = _compile(lambda x, y: x @ y, sds, sds)
    b = tpu_bytes_accessed(c.as_text())
    ideal = 3 * m * m * 4
    assert abs(b - ideal) / ideal < 0.2, (b, ideal)


def test_walker_bf16_matmul_not_inflated():
    """XLA:CPU upcasts bf16 dots to f32 (convert+copy chains); the walker
    must charge bf16-native traffic like a TPU MXU."""
    m = 512
    sds = jax.ShapeDtypeStruct((m, m), jnp.bfloat16)
    c = _compile(lambda x, y: (x @ y), sds, sds)
    b = tpu_bytes_accessed(c.as_text())
    ideal = 3 * m * m * 2
    from repro.sharding.compat import cost_analysis
    raw = cost_analysis(c).get("bytes accessed")
    assert b <= raw  # never exceeds raw HLO accounting
    assert b < 2.0 * ideal, (b, ideal, raw)


def test_walker_inplace_cache_update():
    """.at[idx].set of one row into a big donated buffer must cost O(row),
    not O(buffer) (TPU in-place DUS/scatter)."""
    big, row = 1 << 16, 256

    def f(cache, upd, idx):
        return cache.at[idx].set(upd)

    c = jax.jit(f, donate_argnums=(0,)).lower(
        jax.ShapeDtypeStruct((big, row), jnp.float32),
        jax.ShapeDtypeStruct((row,), jnp.float32),
        jax.ShapeDtypeStruct((), jnp.int32)).compile()
    b = tpu_bytes_accessed(c.as_text())
    assert b < 50 * row * 4, b          # orders below big*row*4 = 64 MB


def test_collective_parser_on_psum():
    import subprocess, sys, os, textwrap
    src = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.roofline.analysis import collective_wire_bytes
        from repro.sharding.compat import make_mesh, set_mesh
        mesh = make_mesh((8,), ("d",))
        n = 1 << 16
        def f(x):
            return jax.lax.with_sharding_constraint(
                x.sum(0, keepdims=True), NamedSharding(mesh, P()))
        with set_mesh(mesh):
            c = jax.jit(f, in_shardings=NamedSharding(mesh, P("d", None)),
                        out_shardings=NamedSharding(mesh, P())).lower(
                jax.ShapeDtypeStruct((8, n), jnp.float32)).compile()
        total, by_kind = collective_wire_bytes(c.as_text())
        # all-reduce of n f32 over 8 devices: ring 2*(7/8)*4n
        ideal = 2 * (7 / 8) * 4 * n
        assert by_kind, c.as_text()[:500]
        assert abs(total - ideal) / ideal < 0.3, (total, ideal, by_kind)
        print("coll parser OK", total)
    """)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ, PYTHONPATH=os.path.join(root, "src"))
    proc = subprocess.run([sys.executable, "-c", src], env=env,
                          capture_output=True, text=True, timeout=300)
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_roofline_terms_positive_smoke():
    """End-to-end roofline on a tiny mesh/config via subprocess."""
    import subprocess, sys, os, textwrap
    src = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, repro.configs as RC
        from repro.launch.mesh import make_test_mesh
        from repro.roofline.analysis import cell_roofline
        import repro.launch.cells as C
        C.SHAPES = dict(C.SHAPES)
        C.SHAPES["train_4k"] = dict(kind="train", seq=128, batch=8)
        RC._REGISTRY["gemma3-1b"] = RC.reduce_config(RC.get_config("gemma3-1b"))
        mesh = make_test_mesh((2, 4), ("data", "model"))
        r = cell_roofline("gemma3-1b", "train_4k", mesh, "2x4")
        assert r.compute_s > 0 and r.memory_s > 0, r
        assert r.dominant in ("compute", "memory", "collective")
        assert 0 < r.useful_ratio < 20
        print("roofline smoke OK", r.dominant)
    """)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ, PYTHONPATH=os.path.join(root, "src"))
    proc = subprocess.run([sys.executable, "-c", src], env=env,
                          capture_output=True, text=True, timeout=400)
    assert proc.returncode == 0, proc.stdout + proc.stderr


import os  # noqa: E402  (used inside subprocess tests)
