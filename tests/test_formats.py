"""WeightFormat registry: encode->dequantize round-trips, packed/unpacked
equivalence, storage accounting from real dtypes, policy resolution."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (ExecPolicy, LayerRule, PrecisionPolicy, QuantConfig,
                        available_formats, get_format, packed_linear_fmt)
from repro.core.formats import dtype_bits, outlier_k
from repro.core.types import QuantizedExperts, QuantizedLinear


def _layer(seed, m, n, bits, book_dtype=np.float32):
    rng = np.random.default_rng(seed)
    codes = jnp.asarray(rng.integers(0, 1 << bits, (m, n)).astype(np.uint8))
    book = jnp.asarray(np.sort(rng.normal(size=(m, 1 << bits)), axis=1)
                       .astype(book_dtype))
    return QuantizedLinear(codes=codes, codebook=book, bits=bits)


def _experts(seed, e, m, n, bits):
    rng = np.random.default_rng(seed)
    codes = jnp.asarray(rng.integers(0, 1 << bits,
                                     (e, m, n)).astype(np.uint8))
    book = jnp.asarray(rng.normal(size=(e, m, 1 << bits)).astype(np.float32))
    return QuantizedExperts(codes=codes, codebook=book, bits=bits, n_cols=n)


def test_registry_contents():
    for fmt in ("dense", "lut", "lut_sparse", "lut4_packed", "lut3_packed",
                "experts", "experts_packed"):
        assert fmt in available_formats()
    with pytest.raises(KeyError):
        get_format("no_such_format")


@pytest.mark.parametrize("bits,fmt", [(4, "lut"), (3, "lut"),
                                      (4, "lut4_packed"),
                                      (3, "lut3_packed")])
@pytest.mark.parametrize("n", [64, 33])
def test_linear_roundtrip(bits, fmt, n):
    """encode -> dequantize reproduces the canonical dequantization."""
    base = _layer(0, 24, n, bits)
    want = np.asarray(get_format("lut").dequantize(base))
    enc = get_format(fmt).encode(base)
    assert enc.fmt == fmt and enc.shape == (24, n)
    got = np.asarray(get_format(fmt).dequantize(enc))
    np.testing.assert_array_equal(got, want)
    # container-level delegation agrees
    np.testing.assert_array_equal(np.asarray(enc.dequantize()), want)


@pytest.mark.parametrize("fmt,cols", [("lut4_packed", 28),
                                      ("lut3_packed", 21)])
def test_packed_unpacked_codes_equivalent(fmt, cols):
    """Packed and unpacked layouts of the same codes produce identical
    matmuls on both backends. lut3_packed holds the TRUE bitstream:
    ceil(56*3/8) = 21 bytes per row, not the 28-byte nibble container."""
    bits = get_format(fmt).bits
    base = _layer(1, 40, 56, bits)
    enc = get_format(fmt).encode(base)
    assert enc.codes.shape == (40, cols)
    rng = np.random.default_rng(2)
    x2 = jnp.asarray(rng.normal(size=(5, 56)).astype(np.float32))
    y_ref = np.asarray(get_format("lut").apply(base, x2, backend="xla"))
    for backend in ("xla", "pallas"):
        y = np.asarray(get_format(fmt).apply(enc, x2, backend=backend))
        np.testing.assert_allclose(y, y_ref, rtol=1e-5, atol=1e-5)
    # unpacked pallas too
    y = np.asarray(get_format("lut").apply(base, x2, backend="pallas"))
    np.testing.assert_allclose(y, y_ref, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("fmt", ["experts", "experts_packed"])
def test_experts_roundtrip(fmt):
    base = _experts(3, 4, 16, 22, 4)
    want = np.asarray(get_format("experts").dequantize(base))
    enc = get_format(fmt).encode(base)
    assert enc.fmt == fmt
    got = np.asarray(get_format(fmt).dequantize(enc))
    np.testing.assert_array_equal(got, want)
    # einsum-layout container dequantize: (E, n, m) transpose + cast
    d = np.asarray(enc.dequantize(jnp.float32))
    np.testing.assert_array_equal(d, np.swapaxes(want, 1, 2))


def test_storage_bits_from_real_dtypes():
    """Codebook entries are counted at their ACTUAL dtype width; codes at
    the checkpoint bitstream width; experts included."""
    for book_dtype, want_entry_bits in ((np.float32, 32), (np.float16, 16)):
        lay = _layer(5, 8, 64, 4, book_dtype)
        total, count = get_format("lut").storage_bits(lay)
        assert count == 8 * 64
        assert total == 4 * count + 8 * 16 * want_entry_bits
    # packed 3-bit counts true 3 bits/weight, not the in-graph nibble
    lay3 = get_format("lut3_packed").encode(_layer(6, 8, 64, 3))
    total, count = get_format("lut3_packed").storage_bits(lay3)
    assert count == 8 * 64 and total == 3 * count + 8 * 8 * 32
    # experts
    ex = _experts(7, 3, 8, 16, 4)
    total, count = get_format("experts").storage_bits(ex)
    assert count == 3 * 8 * 16
    assert total == 4 * count + 3 * 8 * 16 * 32
    # sparse outliers: value dtype + index dtype per entry
    rng = np.random.default_rng(8)
    lay = _layer(9, 8, 32, 4)
    lay.sparse_idx = jnp.asarray(rng.integers(0, 32, (8, 2)).astype(np.int32))
    lay.sparse_val = jnp.asarray(rng.normal(size=(8, 2)).astype(np.float32))
    lay.fmt = "lut_sparse"
    total, count = get_format("lut_sparse").storage_bits(lay)
    assert total == 4 * 8 * 32 + 8 * 16 * 32 + 8 * 2 * (32 + 32)


def test_unit_stacked_storage_accounting():
    """Stacked-unit leaves ((U, m, n) codes) count U*m*n weights."""
    lays = [_layer(s, 8, 32, 4) for s in range(3)]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *lays)
    total, count = get_format("lut").storage_bits(stacked)
    one_t, one_c = get_format("lut").storage_bits(lays[0])
    assert count == 3 * one_c and total == 3 * one_t


def test_dense_format_and_exec_policy():
    w = jnp.asarray(np.random.default_rng(0).normal(size=(16, 8))
                    .astype(np.float32))
    total, count = get_format("dense").storage_bits(w)
    assert count == 128 and total == 128 * 32
    x2 = jnp.ones((2, 16), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(get_format("dense").apply(w, x2)), np.asarray(x2 @ w))
    assert ExecPolicy().lut_backend == "xla"
    with pytest.raises(AssertionError):
        ExecPolicy(lut_backend="cuda")


def test_policy_first_match_wins_and_expert_mapping():
    pol = PrecisionPolicy(
        qcfg=QuantConfig(bits=4),
        rules=(LayerRule(pattern="*/moe/w_down", keep_fp=True),
               LayerRule(pattern="*/moe/*", bits=3, fmt="lut3_packed")))
    assert pol.resolve("layer0/moe/w_down").keep_fp
    r = pol.resolve("layer0/moe/w_up")
    assert r.qcfg.bits == 3
    assert get_format(r.fmt).expert_fmt == "experts3_packed"
    assert get_format("lut4_packed").expert_fmt == "experts_packed"
    assert get_format("lut").expert_fmt == "experts"
    assert get_format("lut_sparse").expert_fmt == "experts"
    assert get_format("dense").expert_fmt is None
    assert pol.resolve("layer0/attn/wq").qcfg.bits == 4
    assert packed_linear_fmt(3) == "lut3_packed"
    assert packed_linear_fmt(4) == "lut4_packed"


def test_segment_patterns_do_not_cross_match():
    """Bare CLI patterns match whole path segments: 'attn' must not
    capture cross-attention ('xattn') layers."""
    from repro.core import parse_policy
    pol = parse_policy("attn=3,xattn=4", QuantConfig(bits=8))
    assert pol.resolve("dec0/attn/wq").qcfg.bits == 3
    assert pol.resolve("dec0/xattn/wq").qcfg.bits == 4
    assert pol.resolve("dec0/mlp/w_up").qcfg.bits == 8
    # glob-free subpath entries still match as substrings
    pol2 = parse_policy("mlp/w_down=fp", QuantConfig(bits=4))
    assert pol2.resolve("layer1/mlp/w_down").keep_fp
    assert not pol2.resolve("layer1/mlp/w_up").keep_fp


def test_experts_sparse_outliers_roundtrip():
    """GANQ* sparse fields on stacked experts survive pack/unpack and are
    applied at decode; storage accounts them."""
    rng = np.random.default_rng(21)
    base = _experts(20, 2, 6, 10, 4)
    base.sparse_idx = jnp.asarray(rng.integers(0, 10, (2, 6, 2))
                                  .astype(np.int32))
    base.sparse_val = jnp.asarray(rng.normal(size=(2, 6, 2))
                                  .astype(np.float32))
    base.full_row_idx = jnp.asarray(rng.integers(0, 6, (2, 1))
                                    .astype(np.int32))
    base.full_row_val = jnp.asarray(rng.normal(size=(2, 1, 10))
                                    .astype(np.float32))
    want = np.asarray(get_format("experts").dequantize(base))
    # full rows overwrite, sparse adds elsewhere: spot-check full rows
    for e in range(2):
        fi = int(base.full_row_idx[e, 0])
        np.testing.assert_array_equal(want[e, fi],
                                      np.asarray(base.full_row_val[e, 0]))
    enc = get_format("experts_packed").encode(base)
    got = np.asarray(get_format("experts_packed").dequantize(enc))
    np.testing.assert_array_equal(got, want)
    plain_total, count = get_format("experts").storage_bits(
        _experts(20, 2, 6, 10, 4))
    total, count2 = get_format("experts").storage_bits(base)
    assert count2 == count
    assert total == plain_total + 2 * 6 * 2 * (32 + 32) + 2 * 1 * 32 \
        + 2 * 1 * 10 * 32
    assert outlier_k(64, 0.05) == 3


def test_experts_encode_no_silent_relabel():
    """Re-tagging packed expert codes as unpacked must fail loudly, not
    decode garbage."""
    base = _experts(11, 2, 4, 8, 4)
    packed = get_format("experts_packed").encode(base)
    with pytest.raises(AssertionError):
        get_format("experts").encode(packed)
    # same-layout re-encode stays fine
    again = get_format("experts_packed").encode(packed)
    np.testing.assert_array_equal(np.asarray(again.codes),
                                  np.asarray(packed.codes))


def test_sparse_layer_survives_packed_policy():
    """GANQ* sparse-outlier layers fall back to 'lut_sparse' under a packed
    policy format instead of aborting the PTQ pass."""
    from repro.core import compute_h
    from repro.models.quantized import _quantize_one
    from repro.core.policy import ResolvedQuant
    rng = np.random.default_rng(12)
    w = jnp.asarray(rng.standard_t(df=3, size=(16, 32)).astype(np.float32))
    h = compute_h(jnp.asarray(rng.normal(size=(16, 64)).astype(np.float32)))
    qcfg = QuantConfig(bits=4, iters=2, precondition="fixed",
                       outlier_ratio=0.05)
    r = ResolvedQuant(qcfg=qcfg, method="ganq", fmt="lut4_packed")
    layer, rep = _quantize_one(w, h, r)        # w is (d_in=16, d_out=32)
    assert layer.fmt == "lut_sparse" and rep.fmt == "lut_sparse"
    assert layer.sparse_val is not None
    # without outliers the packed request is honored
    r2 = ResolvedQuant(qcfg=QuantConfig(bits=4, iters=2,
                                        precondition="fixed"),
                       method="ganq", fmt="lut4_packed")
    layer2, _ = _quantize_one(w, h, r2)
    assert layer2.fmt == "lut4_packed"


def test_dtype_bits():
    assert dtype_bits(jnp.float32) == 32
    assert dtype_bits(jnp.bfloat16) == 16
    assert dtype_bits(jnp.uint8) == 8
