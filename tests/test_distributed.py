"""Multi-device tests (8 fake CPU devices via subprocess: jax locks the
device count at first init, so each scenario runs in its own process)."""
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_worker(body: str, timeout=480):
    src = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import numpy as np
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.sharding import compat as _compat
        if not hasattr(jax, "set_mesh"):
            jax.set_mesh = _compat.set_mesh
    """) + textwrap.dedent(body)
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    proc = subprocess.run([sys.executable, "-c", src], env=env,
                          capture_output=True, text=True, timeout=timeout)
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nERR:\n{proc.stderr}"
    return proc.stdout


def test_sharded_ganq_matches_single_device():
    """Row-parallel GANQ over the model axis == single-device GANQ."""
    run_worker("""
        from repro.core import QuantConfig, compute_h, ganq_quantize
        from repro.core.distributed import quantize_layer_sharded
        from repro.launch.mesh import make_test_mesh
        rng = np.random.default_rng(0)
        m, n = 32, 48
        w = jnp.asarray((rng.standard_t(df=4, size=(m, n)) * .05).astype(np.float32))
        u = rng.normal(size=(n, 8)).astype(np.float32)
        x = jnp.asarray((u @ rng.normal(size=(8, 128))).astype(np.float32))
        h = compute_h(x)
        cfg = QuantConfig(bits=4, iters=3, precondition="fixed")
        mesh = make_test_mesh((2, 4), ("data", "model"))
        codes_s, t_s, _ = quantize_layer_sharded(mesh, w, h, cfg)
        ref = ganq_quantize(w, h=h, cfg=cfg)
        # row-block quantile inits differ from global? no: per-row quantiles
        # -> identical math per row regardless of blocking
        np.testing.assert_array_equal(np.asarray(codes_s),
                                      np.asarray(ref.layer.codes))
        np.testing.assert_allclose(np.asarray(t_s),
                                   np.asarray(ref.layer.codebook), rtol=1e-5)
        print("sharded ganq OK")
    """)


def test_compute_h_sharded_psum():
    run_worker("""
        from repro.core.distributed import compute_h_sharded
        from repro.launch.mesh import make_test_mesh
        mesh = make_test_mesh((8,), ("data",))
        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.normal(size=(64, 12)).astype(np.float32))
        h_fn = compute_h_sharded(mesh)
        with jax.set_mesh(mesh):
            xs = jax.device_put(x, NamedSharding(mesh, P("data", None)))
            h = h_fn(xs)
        np.testing.assert_allclose(np.asarray(h), np.asarray(x.T @ x),
                                   rtol=1e-4, atol=1e-4)
        print("H psum OK")
    """)


def test_spmd_train_step_matches_local():
    """Sharded train loss on the 2x4 mesh == single-device loss."""
    run_worker("""
        from repro.configs import get_config, reduce_config
        from repro.models import init_params, train_loss
        from repro.launch.mesh import make_test_mesh
        from repro.launch.steps import make_ctx, batch_shardings
        from repro.sharding.partition import param_shardings
        from repro.data.synthetic import MarkovStream
        cfg = reduce_config(get_config("deepseek-7b"))
        mesh = make_test_mesh((2, 4), ("data", "model"))
        ctx = make_ctx(mesh, cfg)
        params = init_params(jax.random.PRNGKey(0), cfg)
        data = MarkovStream(cfg.vocab_size, batch=4, seq=32, seed=0)
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}
        loss_local = train_loss(params, batch, cfg)
        with jax.set_mesh(mesh):
            p_sh = jax.device_put(params, param_shardings(params, mesh))
            b_sh = jax.device_put(batch, batch_shardings(cfg, mesh))
            loss_spmd = jax.jit(lambda p, b: train_loss(p, b, cfg, ctx))(p_sh, b_sh)
        np.testing.assert_allclose(float(loss_local), float(loss_spmd),
                                   rtol=2e-4)
        print("spmd loss OK", float(loss_spmd))
    """)


def test_moe_expert_parallel_matches_local():
    run_worker("""
        import dataclasses
        from repro.configs import get_config, reduce_config
        from repro.models.moe import init_moe, moe_apply
        from repro.launch.mesh import make_test_mesh
        from repro.sharding.context import ShardCtx
        cfg = reduce_config(get_config("qwen3-moe-30b-a3b"))
        mesh = make_test_mesh((2, 4), ("data", "model"))
        p = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=(4, 16, cfg.d_model)).astype(np.float32))
        y_local, _ = moe_apply(p, x, cfg)   # all experts on one device
        ctx = ShardCtx(mesh=mesh, dp_axes=("data",), tp_axis="model", ep=True)
        with jax.set_mesh(mesh):
            y_ep, _ = jax.jit(lambda p, x: moe_apply(p, x, cfg, ctx))(p, x)
        # EP capacity is per-DP-shard: with ample capacity_factor the results
        # must agree exactly
        np.testing.assert_allclose(np.asarray(y_local), np.asarray(y_ep),
                                   rtol=2e-4, atol=2e-5)
        print("EP moe OK")
    """)


def test_compressed_train_step_runs_and_reduces_bytes():
    run_worker("""
        from repro.configs import get_config, reduce_config
        from repro.data.synthetic import MarkovStream
        from repro.models import init_params
        from repro.launch.mesh import make_test_mesh
        from repro.train.grad_compress import (make_compressed_train_step,
                                               init_error_state,
                                               compressed_bytes_ratio)
        from repro.train.optimizer import OptConfig, init_opt_state
        cfg = reduce_config(get_config("deepseek-7b"))
        mesh = make_test_mesh((8,), ("data",))
        step = make_compressed_train_step(cfg, mesh, OptConfig(lr=1e-3),
                                          rank=4, remat="none")
        params = init_params(jax.random.PRNGKey(0), cfg)
        opt = init_opt_state(params)
        err = init_error_state(params)
        data = MarkovStream(cfg.vocab_size, batch=8, seq=32, seed=0)
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}
        key = jax.random.PRNGKey(1)
        with jax.set_mesh(mesh):
            jstep = jax.jit(step)
            losses = []
            for i in range(3):
                b = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
                params, opt, err, m = jstep(params, opt, err, key, b)
                losses.append(float(m["loss"]))
        assert all(np.isfinite(l) for l in losses), losses
        shapes = [p.shape for p in jax.tree.leaves(params)]
        ratio = compressed_bytes_ratio(shapes, rank=4)
        assert ratio < 0.7, ratio   # collective bytes reduced >30%
        print("compressed step OK", losses, "bytes ratio", ratio)
    """)


def test_elastic_reshard_restore():
    """Checkpoint written under a 4x2 mesh restores onto a 2x4 mesh."""
    run_worker("""
        import tempfile
        from repro.configs import get_config, reduce_config
        from repro.models import init_params
        from repro.launch.mesh import make_test_mesh
        from repro.sharding.partition import param_shardings
        from repro.train.checkpoint import CheckpointManager
        cfg = reduce_config(get_config("deepseek-7b"))
        params = init_params(jax.random.PRNGKey(0), cfg)
        mesh_a = make_test_mesh((4, 2), ("data", "model"))
        with jax.set_mesh(mesh_a):
            pa = jax.device_put(params, param_shardings(params, mesh_a))
        d = tempfile.mkdtemp()
        mgr = CheckpointManager(d, async_save=False)
        mgr.save(1, pa)
        mesh_b = make_test_mesh((2, 4), ("data", "model"))
        with jax.set_mesh(mesh_b):
            pb = mgr.restore(1, params,
                             shardings=param_shardings(params, mesh_b))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(pb)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        print("elastic reshard OK")
    """)


def test_mini_dryrun_8dev():
    """The dry-run path itself on a small mesh: lower+compile+analyses."""
    run_worker("""
        from repro.launch.cells import build_cell, lower_cell
        from repro.launch.mesh import make_test_mesh
        import dataclasses, repro.launch.cells as C
        mesh = make_test_mesh((2, 4), ("data", "model"))
        # shrink the shape so the 8-device CPU compile stays cheap
        C.SHAPES = dict(C.SHAPES)
        C.SHAPES["train_4k"] = dict(kind="train", seq=128, batch=8)
        C.SHAPES["decode_32k"] = dict(kind="decode", seq=256, batch=8)
        for arch in ("gemma3-1b", "rwkv6-7b"):
            import repro.configs as RC
            real = RC.get_config(arch)
            small = RC.reduce_config(real)
            object.__setattr__  # configs frozen; patch registry instead
            RC._REGISTRY[arch] = small
            for shape in ("train_4k", "decode_32k"):
                cell = build_cell(arch, shape, mesh)
                comp = lower_cell(cell, mesh).compile()
                from repro.sharding.compat import cost_analysis
                assert cost_analysis(comp).get("flops", 0) > 0
                ma = comp.memory_analysis()
                assert ma.temp_size_in_bytes >= 0
                print(arch, shape, "OK")
    """)
