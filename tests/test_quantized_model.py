"""End-to-end PTQ: sequential pipeline, LUT serving parity, method ranking,
mixed-precision policies through the WeightFormat registry."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduce_config
from repro.core import LayerRule, PrecisionPolicy, QuantConfig, parse_policy
from repro.data.synthetic import MarkovStream
from repro.models import decode_step, forward_logits, init_params, prefill
from repro.models.quantized import (abstract_quantize, model_storage_report,
                                    quantize_model_ptq)
from repro.models.model import abstract_params
from repro.sharding.context import LOCAL

KEY = jax.random.PRNGKey(0)


def _ppl(params, cfg, batch):
    logits = forward_logits(params, batch, cfg).astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, batch["labels"][..., None],
                               axis=-1)[..., 0]
    return float(jnp.exp(jnp.mean(logz - gold)))


@pytest.mark.parametrize("arch", ["deepseek-7b", "qwen3-moe-30b-a3b",
                                  "rwkv6-7b", "recurrentgemma-2b",
                                  "whisper-medium"])
def test_ptq_pipeline_quantizes_and_stays_close(arch):
    cfg = reduce_config(get_config(arch))
    params = init_params(KEY, cfg)
    data = MarkovStream(cfg.vocab_size, batch=2, seq=32, seed=0,
                        frontend=cfg.frontend, d_model=cfg.d_model)
    batch = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}
    qcfg = QuantConfig(bits=4, iters=3, precondition="fixed")
    qparams, report = quantize_model_ptq(params, cfg, batch, qcfg, "ganq")
    assert report, "no layers quantized"
    rep = model_storage_report(qparams, report)
    assert rep["quantized_weights"] > 0
    # honest accounting from the REAL dtypes: reduced configs (n=64..128)
    # pay a large fp32-codebook overhead per row (4 + 32*16/64 = 12 b/w on
    # the narrowest layers); real-scale layers amortize it to ~bits+eps
    assert rep["bits_per_weight"] < 13.0, rep
    # every quantized linear reports bits and error
    assert all(np.isfinite(r["err"]) and r["bits_per_weight"] > 0
               for r in rep["per_layer"].values()), rep["per_layer"]
    # quantized model still runs and is finite
    eval_batch = {k: jnp.asarray(v) for k, v in data.batch_at(1).items()}
    ppl_fp = _ppl(params, cfg, eval_batch)
    ppl_q = _ppl(qparams, cfg, eval_batch)
    assert np.isfinite(ppl_q)
    assert ppl_q < ppl_fp * 3.0, (ppl_fp, ppl_q)  # same ballpark (random net)


def test_ptq_method_ranking_layer_errors():
    """GANQ layer errors <= GPTQ <= RTN on average (paper Table 2 ordering),
    measured on the same sequential pipeline."""
    cfg = reduce_config(get_config("deepseek-7b"))
    params = init_params(KEY, cfg)
    data = MarkovStream(cfg.vocab_size, batch=2, seq=64, seed=0)
    batch = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}
    errs = {}
    for method in ("rtn", "gptq", "ganq"):
        qcfg = QuantConfig(bits=3, iters=4, precondition="fixed")
        _, report = quantize_model_ptq(params, cfg, batch, qcfg, method)
        vals = [float(v) for v in report.values() if np.isfinite(float(v))]
        errs[method] = float(np.mean(vals))
    assert errs["ganq"] <= errs["gptq"] * 1.05, errs
    assert errs["ganq"] < errs["rtn"], errs


def test_quantized_decode_serving_parity():
    """Quantized model must serve: prefill+decode equals its own
    teacher-forced forward (exactness of the LUT serving path, xla backend)."""
    cfg = reduce_config(get_config("deepseek-7b"))
    params = init_params(KEY, cfg)
    data = MarkovStream(cfg.vocab_size, batch=2, seq=33, seed=0)
    batch = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}
    qcfg = QuantConfig(bits=4, iters=2, precondition="fixed")
    qparams, _ = quantize_model_ptq(
        params, cfg, {"tokens": batch["tokens"][:, :32]}, qcfg, "ganq")
    toks = batch["tokens"]
    full = forward_logits(qparams, {"tokens": toks}, cfg)
    _, cache = prefill(qparams, {"tokens": toks[:, :32]}, cfg, cache_len=40)
    pos = jnp.full((2,), 32, jnp.int32)
    logits_d, _ = decode_step(qparams, cache, toks[:, 32], pos, cfg)
    np.testing.assert_allclose(np.asarray(logits_d), np.asarray(full[:, 32]),
                               rtol=1e-3, atol=1e-4)


def test_lut_backends_agree_on_model():
    """xla take_along_axis path vs pallas interpret kernel path; the
    backend is an explicit ExecPolicy on ShardCtx — no global state."""
    cfg = reduce_config(get_config("deepseek-7b"))
    params = init_params(KEY, cfg)
    data = MarkovStream(cfg.vocab_size, batch=1, seq=16, seed=0)
    batch = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}
    qcfg = QuantConfig(bits=4, iters=2, precondition="fixed")
    qparams, _ = quantize_model_ptq(params, cfg, batch, qcfg, "ganq")
    out_x = forward_logits(qparams, batch, cfg)            # default: xla
    out_p = forward_logits(qparams, batch, cfg,
                           LOCAL.with_lut_backend("pallas"))
    np.testing.assert_allclose(np.asarray(out_x, np.float32),
                               np.asarray(out_p, np.float32),
                               rtol=2e-3, atol=2e-4)


def test_mixed_precision_policy_pipeline():
    """3-bit MLP / 4-bit attention / fp w_down policy: per-layer bits land
    where the rules say, fp-kept weights stay raw arrays, and the model
    still forwards finite."""
    cfg = reduce_config(get_config("deepseek-7b"))
    params = init_params(KEY, cfg)
    data = MarkovStream(cfg.vocab_size, batch=2, seq=32, seed=0)
    batch = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}
    policy = PrecisionPolicy(
        qcfg=QuantConfig(bits=4, iters=2, precondition="fixed"),
        rules=(LayerRule(pattern="*/mlp/w_down", keep_fp=True),
               LayerRule(pattern="*/mlp/*", bits=3)))
    qparams, report = quantize_model_ptq(params, cfg, batch, policy=policy)
    for name, r in report.items():
        if name.endswith("mlp/w_down"):
            assert r.bits is None and r.fmt == "dense", (name, r)
        elif "/mlp/" in name:
            assert r.bits == 3, (name, r)
        else:
            assert r.bits == 4, (name, r)
    # fp-kept weights are untouched raw arrays
    w_down = qparams["stack"]["units"][0]["mlp"]["w_down"]
    assert isinstance(w_down, jnp.ndarray)
    # mixed model serves: greedy decode parity against its own forward
    toks = batch["tokens"]
    full = forward_logits(qparams, {"tokens": toks}, cfg)
    _, cache = prefill(qparams, {"tokens": toks[:, :31]}, cfg, cache_len=40)
    pos = jnp.full((2,), 31, jnp.int32)
    logits_d, _ = decode_step(qparams, cache, toks[:, 31], pos, cfg)
    np.testing.assert_allclose(np.asarray(logits_d), np.asarray(full[:, 31]),
                               rtol=1e-3, atol=1e-4)
    # mixed bits/weight sits strictly between uniform 3- and 4-bit
    rep = model_storage_report(qparams, report)
    u4, _ = quantize_model_ptq(params, cfg, batch,
                               QuantConfig(bits=4, iters=2,
                                           precondition="fixed"))
    r4 = model_storage_report(u4)
    assert rep["bits_per_weight"] < r4["bits_per_weight"], (rep, r4)


def test_uniform_policy_identical_to_legacy_args():
    """PrecisionPolicy.uniform(qcfg) must reproduce the legacy
    (qcfg, method) call bit-for-bit — same codes, same codebooks."""
    cfg = reduce_config(get_config("deepseek-7b"))
    params = init_params(KEY, cfg)
    data = MarkovStream(cfg.vocab_size, batch=1, seq=16, seed=0)
    batch = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}
    qcfg = QuantConfig(bits=4, iters=2, precondition="fixed")
    qp_legacy, _ = quantize_model_ptq(params, cfg, batch, qcfg, "ganq")
    qp_policy, _ = quantize_model_ptq(
        params, cfg, batch, policy=PrecisionPolicy.uniform(qcfg, "ganq"))
    for a, b in zip(jax.tree.leaves(qp_legacy), jax.tree.leaves(qp_policy)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_parse_policy_spec():
    base = QuantConfig(bits=4, iters=2)
    pol = parse_policy("mlp=3,attn=4@lut4_packed,head=fp", base)
    r = pol.resolve("layer0/mlp/w_up")
    assert r.qcfg.bits == 3 and r.fmt == "lut"
    r = pol.resolve("layer0/attn/wq")
    assert r.qcfg.bits == 4 and r.fmt == "lut4_packed"
    assert pol.resolve("head").keep_fp
    assert pol.resolve("layer0/tm/wr").qcfg.bits == 4   # default


@pytest.mark.parametrize("arch", ["deepseek-7b", "qwen3-moe-30b-a3b"])
def test_bitstream_policy_serves_and_mirrors_abstract(arch):
    """Uniform 3-bit 'lut3_packed' policy: every quantized linear holds
    the TRUE ceil(n*3/8)-byte bitstream (MoE experts included via
    'experts3_packed'), the dry-run SDS mirrors it exactly, and the
    pallas bitstream + grouped-projection serving path agrees with the
    xla reference on whole-model logits."""
    from repro.core.packing import code_stream_bytes
    from repro.core.types import QuantizedExperts, QuantizedLinear
    cfg = reduce_config(get_config(arch))
    params = init_params(KEY, cfg)
    data = MarkovStream(cfg.vocab_size, batch=1, seq=16, seed=0)
    batch = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}
    policy = PrecisionPolicy(
        qcfg=QuantConfig(bits=3, iters=1, precondition="fixed"),
        fmt="lut3_packed")
    qp, report = quantize_model_ptq(params, cfg, batch, policy=policy)

    def check(leaf):
        if isinstance(leaf, (QuantizedLinear, QuantizedExperts)):
            assert leaf.fmt in ("lut3_packed", "experts3_packed"), leaf.fmt
            assert leaf.codes.shape[-1] == code_stream_bytes(leaf.n_cols, 3)
    jax.tree.map(check, qp, is_leaf=lambda l: isinstance(
        l, (QuantizedLinear, QuantizedExperts)))
    sds = abstract_quantize(abstract_params(cfg), cfg, policy=policy,
                            book_dtype=jnp.float32)
    real = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), qp)
    assert (jax.tree_util.tree_structure(sds)
            == jax.tree_util.tree_structure(real))
    for a, b in zip(jax.tree.leaves(sds), jax.tree.leaves(real)):
        assert (a.shape, a.dtype) == (b.shape, b.dtype), (a, b)
    out_x = forward_logits(qp, batch, cfg)
    out_p = forward_logits(qp, batch, cfg, LOCAL.with_lut_backend("pallas"))
    np.testing.assert_allclose(np.asarray(out_x, np.float32),
                               np.asarray(out_p, np.float32),
                               rtol=2e-3, atol=2e-4)


def test_moe_experts_keep_sparse_outliers():
    """GANQ* outlier fields survive expert stacking: the served expert
    weights include the sparse correction (not silently dropped)."""
    cfg = reduce_config(get_config("qwen3-moe-30b-a3b"))
    params = init_params(KEY, cfg)
    data = MarkovStream(cfg.vocab_size, batch=2, seq=32, seed=0)
    batch = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}
    qcfg = QuantConfig(bits=4, iters=2, precondition="fixed",
                       outlier_ratio=0.05)
    qparams, report = quantize_model_ptq(params, cfg, batch, qcfg, "ganq")
    moe = qparams["stack"]["units"][0]["moe"]
    for wname in ("w_gate", "w_up", "w_down"):
        assert moe[wname].sparse_val is not None, wname
    # storage accounts the outlier fp payload (> plain 4-bit + codebook)
    rep = model_storage_report(qparams, report)
    assert rep["bits_per_weight"] > 4.0
    out = forward_logits(qparams, batch, cfg)
    assert bool(jnp.isfinite(out).all())


@pytest.mark.parametrize("arch,fmt", [("deepseek-7b", "lut"),
                                      ("qwen3-moe-30b-a3b", "lut4_packed")])
def test_abstract_matches_real_with_outliers(arch, fmt):
    """GANQ* (outlier split + full rows): dry-run SDS still mirrors real
    output exactly — sparse leaves included, MoE experts included, and a
    packed policy format falls back identically on both paths."""
    cfg = reduce_config(get_config(arch))
    params = init_params(KEY, cfg)
    data = MarkovStream(cfg.vocab_size, batch=1, seq=16, seed=0)
    batch = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}
    qcfg = QuantConfig(bits=4, iters=1, precondition="fixed",
                       outlier_ratio=0.05, full_rows=2)
    policy = PrecisionPolicy(qcfg=qcfg, fmt=fmt)
    qparams, _ = quantize_model_ptq(params, cfg, batch, policy=policy)
    sds = abstract_quantize(abstract_params(cfg), cfg, policy=policy,
                            book_dtype=jnp.float32)
    real = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        qparams)
    assert (jax.tree_util.tree_structure(sds)
            == jax.tree_util.tree_structure(real))
    for a, b in zip(jax.tree.leaves(sds), jax.tree.leaves(real)):
        assert (a.shape, a.dtype) == (b.shape, b.dtype), (a, b)


def test_abstract_quantize_matches_real_quantize_structure():
    """Dry-run SDS tree must EXACTLY mirror a real quantized tree —
    structure, leaf shapes and dtypes — for uniform and mixed policies."""
    cfg = reduce_config(get_config("deepseek-7b"))
    params = init_params(KEY, cfg)
    data = MarkovStream(cfg.vocab_size, batch=1, seq=16, seed=0)
    batch = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}
    policies = [
        (dict(bits=4, packed=False, book_dtype=jnp.float32), None),
        (dict(policy=PrecisionPolicy(
            qcfg=QuantConfig(bits=4, iters=1),
            rules=(LayerRule(pattern="*/mlp/*", bits=3),)),
            book_dtype=jnp.float32),
         PrecisionPolicy(qcfg=QuantConfig(bits=4, iters=1),
                         rules=(LayerRule(pattern="*/mlp/*", bits=3),))),
    ]
    for abs_kwargs, policy in policies:
        sds = abstract_quantize(abstract_params(cfg), cfg, **abs_kwargs)
        qparams, _ = quantize_model_ptq(
            params, cfg, batch, QuantConfig(bits=4, iters=1), "ganq",
            policy=policy)
        real = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                            qparams)
        assert (jax.tree_util.tree_structure(sds)
                == jax.tree_util.tree_structure(real))
        for a, b in zip(jax.tree.leaves(sds), jax.tree.leaves(real)):
            assert (a.shape, a.dtype) == (b.shape, b.dtype), (a, b)
