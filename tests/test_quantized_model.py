"""End-to-end PTQ: sequential pipeline, LUT serving parity, method ranking."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduce_config
from repro.core import QuantConfig
from repro.data.synthetic import MarkovStream
from repro.models import (decode_step, forward_logits, init_params, prefill,
                          set_lut_backend)
from repro.models.quantized import (abstract_quantize, model_storage_report,
                                    quantize_model_ptq)
from repro.models.model import abstract_params

KEY = jax.random.PRNGKey(0)


def _ppl(params, cfg, batch):
    logits = forward_logits(params, batch, cfg).astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, batch["labels"][..., None],
                               axis=-1)[..., 0]
    return float(jnp.exp(jnp.mean(logz - gold)))


@pytest.mark.parametrize("arch", ["deepseek-7b", "qwen3-moe-30b-a3b",
                                  "rwkv6-7b", "recurrentgemma-2b",
                                  "whisper-medium"])
def test_ptq_pipeline_quantizes_and_stays_close(arch):
    cfg = reduce_config(get_config(arch))
    params = init_params(KEY, cfg)
    data = MarkovStream(cfg.vocab_size, batch=2, seq=32, seed=0,
                        frontend=cfg.frontend, d_model=cfg.d_model)
    batch = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}
    qcfg = QuantConfig(bits=4, iters=3, precondition="fixed")
    qparams, report = quantize_model_ptq(params, cfg, batch, qcfg, "ganq")
    assert report, "no layers quantized"
    rep = model_storage_report(qparams)
    assert rep["quantized_weights"] > 0
    assert rep["bits_per_weight"] < 9.0, rep
    # quantized model still runs and is finite
    eval_batch = {k: jnp.asarray(v) for k, v in data.batch_at(1).items()}
    ppl_fp = _ppl(params, cfg, eval_batch)
    ppl_q = _ppl(qparams, cfg, eval_batch)
    assert np.isfinite(ppl_q)
    assert ppl_q < ppl_fp * 3.0, (ppl_fp, ppl_q)  # same ballpark (random net)


def test_ptq_method_ranking_layer_errors():
    """GANQ layer errors <= GPTQ <= RTN on average (paper Table 2 ordering),
    measured on the same sequential pipeline."""
    cfg = reduce_config(get_config("deepseek-7b"))
    params = init_params(KEY, cfg)
    data = MarkovStream(cfg.vocab_size, batch=2, seq=64, seed=0)
    batch = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}
    errs = {}
    for method in ("rtn", "gptq", "ganq"):
        qcfg = QuantConfig(bits=3, iters=4, precondition="fixed")
        _, report = quantize_model_ptq(params, cfg, batch, qcfg, method)
        vals = [v for v in report.values() if np.isfinite(v)]
        errs[method] = float(np.mean(vals))
    assert errs["ganq"] <= errs["gptq"] * 1.05, errs
    assert errs["ganq"] < errs["rtn"], errs


def test_quantized_decode_serving_parity():
    """Quantized model must serve: prefill+decode equals its own
    teacher-forced forward (exactness of the LUT serving path, xla backend)."""
    cfg = reduce_config(get_config("deepseek-7b"))
    params = init_params(KEY, cfg)
    data = MarkovStream(cfg.vocab_size, batch=2, seq=33, seed=0)
    batch = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}
    qcfg = QuantConfig(bits=4, iters=2, precondition="fixed")
    qparams, _ = quantize_model_ptq(
        params, cfg, {"tokens": batch["tokens"][:, :32]}, qcfg, "ganq")
    toks = batch["tokens"]
    full = forward_logits(qparams, {"tokens": toks}, cfg)
    _, cache = prefill(qparams, {"tokens": toks[:, :32]}, cfg, cache_len=40)
    pos = jnp.full((2,), 32, jnp.int32)
    logits_d, _ = decode_step(qparams, cache, toks[:, 32], pos, cfg)
    np.testing.assert_allclose(np.asarray(logits_d), np.asarray(full[:, 32]),
                               rtol=1e-3, atol=1e-4)


def test_lut_backends_agree_on_model():
    """xla take_along_axis path vs pallas interpret kernel path."""
    cfg = reduce_config(get_config("deepseek-7b"))
    params = init_params(KEY, cfg)
    data = MarkovStream(cfg.vocab_size, batch=1, seq=16, seed=0)
    batch = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}
    qcfg = QuantConfig(bits=4, iters=2, precondition="fixed")
    qparams, _ = quantize_model_ptq(params, cfg, batch, qcfg, "ganq")
    set_lut_backend("xla")
    out_x = forward_logits(qparams, batch, cfg)
    try:
        set_lut_backend("pallas")
        out_p = forward_logits(qparams, batch, cfg)
    finally:
        set_lut_backend("xla")
    np.testing.assert_allclose(np.asarray(out_x, np.float32),
                               np.asarray(out_p, np.float32),
                               rtol=2e-3, atol=2e-4)


def test_abstract_quantize_matches_real_quantize_structure():
    """Dry-run SDS tree must mirror a real quantized tree (leaf shapes)."""
    cfg = reduce_config(get_config("deepseek-7b"))
    sds = abstract_quantize(abstract_params(cfg), cfg, bits=4, packed=False)
    params = init_params(KEY, cfg)
    data = MarkovStream(cfg.vocab_size, batch=1, seq=16, seed=0)
    batch = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}
    qparams, _ = quantize_model_ptq(
        params, cfg, batch, QuantConfig(bits=4, iters=1), "ganq")
    # codes leaves have identical shapes in both trees
    def codes_shapes(tree):
        out = []
        def visit(p, x):
            if hasattr(x, "shape") and getattr(x, "dtype", None) == jnp.uint8:
                out.append((jax.tree_util.keystr(p), tuple(x.shape)))
        jax.tree_util.tree_map_with_path(visit, tree)
        return sorted(out)
    s1 = codes_shapes(sds)
    s2 = codes_shapes(qparams)
    assert [s for _, s in s1] == [s for _, s in s2]
