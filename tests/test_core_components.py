"""Property/unit tests: packing, outliers, codebooks, RTN, GPTQ, pipeline."""
import numpy as np
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (HCollector, QuantConfig, apply_sparse, compute_h,
                        extract_outliers_percentile, extract_outliers_topk,
                        gptq_quantize, init_codebook, layer_objective,
                        pack_bits_np, pack_nibbles, quantize_linear,
                        rtn_dequantize, rtn_quantize, storage_bytes,
                        unpack_bits_np, unpack_nibbles)
from repro.core.types import QuantizedLinear, put_rows_sparse


# -------------------------------------------------------------------- packing

@given(st.integers(0, 10_000), st.integers(1, 7), st.integers(1, 40),
       st.sampled_from([2, 3, 4]))
@settings(max_examples=40, deadline=None)
def test_pack_bits_roundtrip(seed, m, n, bits):
    rng = np.random.default_rng(seed)
    codes = rng.integers(0, 1 << bits, size=(m, n)).astype(np.uint8)
    packed = pack_bits_np(codes, bits)
    assert packed.shape == (m, (n * bits + 7) // 8)
    np.testing.assert_array_equal(unpack_bits_np(packed, bits, n), codes)


@given(st.integers(0, 10_000), st.integers(1, 9), st.integers(1, 33))
@settings(max_examples=40, deadline=None)
def test_pack_nibbles_roundtrip(seed, m, n):
    rng = np.random.default_rng(seed)
    codes = rng.integers(0, 16, size=(m, n)).astype(np.uint8)
    packed = pack_nibbles(jnp.asarray(codes))
    assert packed.shape == (m, (n + 1) // 2)
    np.testing.assert_array_equal(np.asarray(unpack_nibbles(packed, n)), codes)


def test_storage_accounting_matches_paper_table1():
    """Paper Table 1: LUT-based 4-bit differs from uniform by <0.2% of fp16."""
    for mn, lut_pct in [(2048, 25.78), (4096, 25.39), (8192, 25.20)]:
        s = storage_bytes(mn, mn, bits=4)
        assert abs(s["lut_pct_of_fp16"] - lut_pct) < 0.02, (mn, s)
        assert s["lut_pct_of_fp16"] - s["uniform_pct_of_fp16"] < 0.8


# -------------------------------------------------------------------- outliers

@given(st.integers(0, 5000), st.floats(0.005, 0.1))
@settings(max_examples=25, deadline=None)
def test_outlier_topk_reconstruction(seed, ratio):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.standard_t(df=3, size=(9, 40)).astype(np.float32))
    w_dense, idx, val = extract_outliers_topk(w, ratio)
    w_rec = put_rows_sparse(w_dense, idx, val)
    np.testing.assert_allclose(np.asarray(w_rec), np.asarray(w), atol=1e-6)
    # dense range shrank (or stayed equal) per row
    assert float(jnp.max(jnp.abs(w_dense))) <= float(jnp.max(jnp.abs(w))) + 1e-6


def test_outlier_percentile_mask_ratio():
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(16, 1000)).astype(np.float32))
    mask = extract_outliers_percentile(w, 0.02)
    frac = float(jnp.mean(mask.astype(jnp.float32)))
    assert 0.01 <= frac <= 0.04, frac


def test_apply_sparse_matches_dense():
    rng = np.random.default_rng(1)
    w = jnp.asarray(rng.normal(size=(8, 32)).astype(np.float32))
    x = jnp.asarray(rng.normal(size=(32, 5)).astype(np.float32))
    w_dense, idx, val = extract_outliers_topk(w, 0.1)
    y_sparse = apply_sparse(idx, val, x)
    y_ref = (w - w_dense) @ x
    np.testing.assert_allclose(np.asarray(y_sparse), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-5)


# -------------------------------------------------------------------- codebook

@given(st.integers(0, 5000), st.sampled_from([3, 4]),
       st.sampled_from(["quantile", "kmeans", "uniform"]))
@settings(max_examples=15, deadline=None)
def test_codebook_shapes_and_order(seed, bits, method):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=(6, 50)).astype(np.float32))
    t = init_codebook(w, bits, method)
    assert t.shape == (6, 1 << bits)
    assert bool(jnp.all(jnp.isfinite(t)))
    if method in ("quantile", "uniform"):
        assert bool(jnp.all(jnp.diff(t, axis=1) >= 0))  # sorted grids


def test_kmeans_reduces_weight_mse():
    rng = np.random.default_rng(3)
    w = jnp.asarray((rng.standard_t(df=3, size=(12, 256)) * 0.1).astype(np.float32))
    from repro.core import assign_nearest
    t_u = init_codebook(w, 3, "uniform")
    t_k = init_codebook(w, 3, "kmeans")
    def mse(t):
        wq = jnp.take_along_axis(t, assign_nearest(w, t), 1)
        return float(jnp.mean((w - wq) ** 2))
    assert mse(t_k) < mse(t_u)


# ------------------------------------------------------------------------ RTN

@given(st.integers(0, 5000), st.sampled_from([3, 4]))
@settings(max_examples=20, deadline=None)
def test_rtn_error_bound(seed, bits):
    """|w - w~| <= s/2 elementwise (round-to-nearest on an affine grid)."""
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=(5, 64)).astype(np.float32))
    codes, s, z = rtn_quantize(w, bits)
    wq = rtn_dequantize(codes, s, z)
    assert bool(jnp.all(jnp.abs(w - wq) <= s / 2 + 1e-6))


def test_rtn_groupwise_tighter_than_per_channel():
    rng = np.random.default_rng(5)
    w = np.repeat(rng.normal(size=(4, 4)), 32, axis=1).astype(np.float32)
    w += 0.01 * rng.normal(size=w.shape).astype(np.float32)
    w = jnp.asarray(w)
    from repro.core import rtn_reconstruct
    e_pc = float(jnp.sum((w - rtn_reconstruct(w, 3)) ** 2))
    e_g = float(jnp.sum((w - rtn_reconstruct(w, 3, group_size=32)) ** 2))
    assert e_g <= e_pc


# ----------------------------------------------------------------------- GPTQ

def test_gptq_codes_valid_and_better_than_rtn():
    rng = np.random.default_rng(7)
    w = jnp.asarray((rng.standard_t(df=4, size=(24, 32)) * 0.05).astype(np.float32))
    u = rng.normal(size=(32, 6)).astype(np.float32)
    x = jnp.asarray(u @ rng.normal(size=(6, 128)).astype(np.float32))
    h = compute_h(x)
    codes, wq = gptq_quantize(w, h, 4)
    assert int(codes.max()) <= 15
    from repro.core import rtn_reconstruct
    e_gptq = float(layer_objective(w, wq, h))
    e_rtn = float(layer_objective(w, rtn_reconstruct(w, 4), h))
    assert e_gptq < e_rtn


# -------------------------------------------------------------------- pipeline

def test_hcollector_streaming_equals_batch():
    rng = np.random.default_rng(9)
    xs = [rng.normal(size=(4, 7, 12)).astype(np.float32) for _ in range(3)]
    col = HCollector()
    for x in xs:
        col.add("l", jnp.asarray(x))
    flat = np.concatenate([x.reshape(-1, 12) for x in xs], 0)
    np.testing.assert_allclose(np.asarray(col.get("l")), flat.T @ flat,
                               rtol=1e-4, atol=1e-3)
    assert col.count["l"] == flat.shape[0]


def test_quantize_linear_dispatch_all_methods():
    rng = np.random.default_rng(11)
    w = jnp.asarray((rng.standard_t(df=4, size=(16, 24)) * 0.05).astype(np.float32))
    u = rng.normal(size=(24, 4)).astype(np.float32)
    h = compute_h(jnp.asarray(u @ rng.normal(size=(4, 96)).astype(np.float32)))
    cfg = QuantConfig(bits=4, iters=3, precondition="fixed")
    errs = {}
    for method in ("rtn", "gptq", "ganq"):
        res = quantize_linear(w, h, cfg, method)
        assert isinstance(res.layer, QuantizedLinear)
        errs[method] = float(layer_objective(w, res.layer.dequantize(), h))
    assert errs["ganq"] <= errs["gptq"] <= errs["rtn"] * 1.05, errs


def test_squeezellm_and_awq_baselines_rank_correctly():
    """Paper Table 5 ordering on heavy-tailed W + outlier-feature H:
    GANQ <= SqueezeLLM (full-H beats diagonal-H LUT) and AWQ <= RTN."""
    rng = np.random.default_rng(42)
    w = jnp.asarray((rng.standard_t(df=4, size=(64, 128)) * 0.02)
                    .astype(np.float32))
    x = rng.normal(size=(128, 512)).astype(np.float32)
    x[rng.choice(128, 4, replace=False)] *= 30.0
    h = compute_h(jnp.asarray(x))
    cfg = QuantConfig(bits=3, iters=6, precondition="fixed")
    errs = {m: float(quantize_linear(w, h, cfg, m).err_history[-1])
            for m in ("rtn", "awq", "squeezellm", "ganq")}
    assert errs["ganq"] <= errs["squeezellm"], errs
    assert errs["awq"] <= errs["rtn"] * 1.05, errs
    assert errs["squeezellm"] <= errs["rtn"], errs


def test_weighted_kmeans_prefers_sensitive_features():
    """Centroids should track high-sensitivity columns' values."""
    from repro.core.codebook import weighted_kmeans, assign_nearest
    rng = np.random.default_rng(7)
    w = jnp.asarray(rng.normal(size=(4, 64)).astype(np.float32))
    weights = jnp.ones((64,)).at[:8].set(100.0)    # first 8 cols sensitive
    t = weighted_kmeans(w, weights, 3, iters=10)
    codes = assign_nearest(w, t)
    wq = jnp.take_along_axis(t, codes, 1)
    err_sens = float(jnp.mean((w[:, :8] - wq[:, :8]) ** 2))
    err_rest = float(jnp.mean((w[:, 8:] - wq[:, 8:]) ** 2))
    assert err_sens < err_rest, (err_sens, err_rest)
