"""Serving engine: batched generation, queue grouping, stop conditions."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config, reduce_config
from repro.data.synthetic import MarkovStream
from repro.models import init_params
from repro.serve.engine import GenRequest, GenResult, ServeEngine


def _setup():
    cfg = reduce_config(get_config("deepseek-7b"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    data = MarkovStream(cfg.vocab_size, batch=4, seq=32, seed=0)
    return cfg, params, data


def test_generate_batch_shapes_and_determinism():
    cfg, params, data = _setup()
    engine = ServeEngine(params, cfg, max_len=64)
    prompts = data.batch_at(0)["tokens"][:, :8].tolist()
    reqs = [GenRequest(prompt=p, max_new=6, temperature=0.0)
            for p in prompts]
    r1 = engine.generate_batch(reqs)
    r2 = engine.generate_batch(reqs)
    assert all(len(r.tokens) == 6 for r in r1)
    for a, b in zip(r1, r2):            # greedy => deterministic
        assert a.tokens == b.tokens


def test_eos_stops_early():
    cfg, params, data = _setup()
    engine = ServeEngine(params, cfg, max_len=64)
    prompts = data.batch_at(1)["tokens"][:2, :8].tolist()
    # run once greedy to learn the first generated token, then set it as eos
    probe = engine.generate_batch([GenRequest(prompt=p, max_new=4)
                                   for p in prompts])
    eos = probe[0].tokens[0]
    reqs = [GenRequest(prompt=prompts[0], max_new=8, eos_id=eos),
            GenRequest(prompt=prompts[1], max_new=8)]
    res = engine.generate_batch(reqs)
    assert res[0].tokens[-1] == eos and len(res[0].tokens) <= 8
    assert len(res[1].tokens) == 8


def test_queue_groups_by_length():
    cfg, params, data = _setup()
    engine = ServeEngine(params, cfg, max_len=64)
    toks = data.batch_at(2)["tokens"]
    reqs = ([GenRequest(prompt=toks[i, :8].tolist(), max_new=3)
             for i in range(3)] +
            [GenRequest(prompt=toks[i, :12].tolist(), max_new=3)
             for i in range(2)])
    res = engine.serve_queue(reqs, batch_size=2)
    assert all(isinstance(r, GenResult) and len(r.tokens) == 3 for r in res)


def test_temperature_sampling_varies():
    cfg, params, data = _setup()
    engine = ServeEngine(params, cfg, max_len=64)
    p = data.batch_at(3)["tokens"][:1, :8].tolist()
    r1 = engine.generate_batch([GenRequest(prompt=p[0], max_new=8,
                                           temperature=1.5)], seed=0)
    r2 = engine.generate_batch([GenRequest(prompt=p[0], max_new=8,
                                           temperature=1.5)], seed=1)
    assert r1[0].tokens != r2[0].tokens  # different seeds, hot sampling
