"""Fault tolerance: chaos injection, quarantine/requeue, graceful valves.

The robustness invariant everything here circles: under ANY injected
fault schedule (transient step exceptions, NaN logits, retired KV pages,
stragglers, client cancels, overload), the engine never deadlocks or
crashes, the page allocator's partition invariant closes, and every
SURVIVING request's greedy tokens are bitwise identical to a fault-free
run — quarantine requeues replay through the same deterministic
PRNG-stream machinery as page-pressure eviction, and watchdog retries
fire before any state mutates.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduce_config
from repro.data.synthetic import MarkovStream
from repro.models import init_params
from repro.serve.engine import GenRequest, ServeEngine
from repro.serve.faults import ServeFaultInjector, StepFault, chaos_injector
from repro.serve.metrics import SLO, meets_slo
from repro.serve.scheduler import GenResult, PageAllocator


def _setup():
    cfg = reduce_config(get_config("deepseek-7b"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _reqs(cfg, n=3, max_new=8, seed=3, timeout_s=None):
    rng = np.random.default_rng(seed)
    toks = MarkovStream(cfg.vocab_size, batch=1, seq=32,
                        seed=2).batch_at(1)["tokens"][0]
    return [GenRequest(prompt=toks[:int(rng.integers(4, 12))].tolist(),
                       max_new=max_new, timeout_s=timeout_s)
            for _ in range(n)]


@pytest.fixture(scope="module")
def engine():
    cfg, params = _setup()
    return ServeEngine(params, cfg, max_len=64, n_slots=3, prefill_chunk=8)


@pytest.fixture(scope="module")
def paged_engine():
    cfg, params = _setup()
    cfg = dataclasses.replace(cfg, kv_format="paged", kv_page_size=8,
                              kv_pages=24)
    return ServeEngine(params, cfg, max_len=64, n_slots=3, prefill_chunk=8)


# ------------------------------------------------------- injector alone

def test_injector_deterministic():
    """Same seed -> identical schedule regardless of retry timing or
    which other kinds ran; a step fault fires at most once per step
    (the watchdog's retry must be able to succeed)."""
    def schedule(seed):
        inj = ServeFaultInjector(seed=seed, step_fault_rate=0.4,
                                 nan_rate=0.4, cancel_rate=0.4)
        fired, nans, cancels = [], [], []
        for step in range(30):
            try:
                inj.begin_step(step)
            except StepFault:
                fired.append(step)
                inj.begin_step(step)          # retry: must NOT re-raise
            nans.append(tuple(inj.nan_targets(step, [0, 1, 2])))
            cancels.append(inj.cancel_victim(step, [10, 11, 12]))
        return fired, nans, cancels
    assert schedule(7) == schedule(7)
    assert schedule(7) != schedule(8)
    fired, _, _ = schedule(7)
    assert fired, "rate 0.4 over 30 steps fired nothing"


def test_injector_explicit_schedules():
    inj = ServeFaultInjector(seed=0, fail_steps=(2, 5),
                             nan_steps=((3, 1), (3, 7)))
    for step in range(7):
        if step in (2, 5):
            with pytest.raises(StepFault):
                inj.begin_step(step)
            inj.begin_step(step)              # once per step index
        else:
            inj.begin_step(step)
    # only slots that are actually active are targeted
    assert inj.nan_targets(3, [0, 1, 2]) == [1]
    assert inj.nan_targets(4, [0, 1, 2]) == []
    assert inj.counts["step_faults"] == 2


def test_page_allocator_quarantine_partition():
    """Retired pages are a third partition class: free + quarantined +
    owned must always tile the pool, and restore returns exactly what
    was taken."""
    alloc = PageAllocator(8, 4, 2, 4)
    assert alloc.alloc(0, 3)
    got = alloc.quarantine_free_pages(3)
    assert got == 3 and len(alloc.free) == 2
    alloc.check()                             # partition holds mid-retire
    assert alloc.quarantine_free_pages(99) == 2   # capped at free pool
    assert alloc.free == []
    alloc.check()
    assert alloc.restore_quarantined() == 5
    assert alloc.quarantined == [] and len(alloc.free) == 5
    alloc.check()


# ----------------------------------------------- engine recovery paths

def test_step_fault_retry_token_identity(engine):
    """Transient step faults raise BEFORE the jit runs, so the watchdog
    retry is token-safe: no state mutated, same tokens as fault-free."""
    cfg = engine.cfg
    oracle = engine.serve(_reqs(cfg))
    faults = ServeFaultInjector(seed=1, fail_steps=(1, 3))
    res = engine.serve(_reqs(cfg), faults=faults)
    assert [r.tokens for r in res] == [r.tokens for r in oracle]
    flt = engine.last_stats["faults"]
    assert flt["step_retries"] == 2
    assert flt["watchdog_exhausted"] == 0
    assert all(r.finish_reason == "length" for r in res)


def test_nan_quarantine_requeues_and_replays(engine):
    """A NaN'd logits row quarantines the slot BEFORE the garbage token
    is recorded; the requeued request replays deterministically and ends
    with exactly the fault-free tokens."""
    cfg = engine.cfg
    oracle = engine.serve(_reqs(cfg))
    faults = ServeFaultInjector(seed=1, nan_steps=((2, 0),))
    res = engine.serve(_reqs(cfg), faults=faults)
    assert [r.tokens for r in res] == [r.tokens for r in oracle]
    flt = engine.last_stats["faults"]
    assert flt["quarantines"] == 1 and flt["requeues"] == 1
    assert flt["poisoned"] == 0


def test_poison_threshold_aborts(engine):
    """A request that keeps faulting must abort with
    finish_reason='error' rather than requeue-livelock; the healthy
    neighbours are untouched (bitwise)."""
    cfg = engine.cfg
    reqs = _reqs(cfg)
    oracle = engine.serve(reqs)
    sess = engine.start(poison_threshold=1,
                        faults=ServeFaultInjector(seed=1,
                                                  nan_steps=((2, 0),)))
    for i, r in enumerate(reqs):
        sess.submit(r, stream_id=i)
    steps = 0
    while not sess.done():
        sess.step()
        steps += 1
        assert steps < 500, "poisoned request livelocked the session"
    results = [sess.results[r.uid] for r in reqs]
    poisoned = [r for r in results if r.finish_reason == "error"]
    assert len(poisoned) == 1 and poisoned[0].tokens == []
    for got, ref in zip(results, oracle):
        if got.finish_reason == "length":
            assert got.tokens == ref.tokens


def test_nan_storm_terminates(engine):
    """nan_rate=1.0 poisons a slot every step: every request eventually
    strikes out at the poison threshold and the session drains — no
    deadlock, no crash, every result terminal."""
    cfg = engine.cfg
    faults = ServeFaultInjector(seed=3, nan_rate=1.0)
    res = engine.serve(_reqs(cfg, max_new=4), faults=faults)
    assert all(r.finish_reason in ("error", "length") for r in res)
    assert engine.last_stats["faults"]["poisoned"] >= 1


def test_watchdog_exhaustion_quarantines(engine):
    """Every retry failing (fail range >> retry budget) must quarantine
    the active slots, strike them out, and still drain the session."""
    cfg = engine.cfg

    # ServeFaultInjector fires once per step index (so retries succeed);
    # exhausting the watchdog needs the SAME step to keep failing:
    class AlwaysFail(ServeFaultInjector):
        def begin_step(self, step, alloc=None):
            self.counts["step_faults"] += 1
            raise StepFault(f"hard fault at step {step}")

    res = engine.serve(_reqs(cfg, max_new=4),
                       faults=AlwaysFail(seed=0))
    assert all(r.finish_reason == "error" for r in res)
    flt = engine.last_stats["faults"]
    assert flt["watchdog_exhausted"] >= 1
    assert flt["poisoned"] == len(res)


def test_cache_recovery_after_mid_jit_failure(engine):
    """A failure AFTER the donated jit consumed the cache leaves deleted
    buffers behind; the watchdog rebuilds the cache, quarantines the
    active slots, and the replay still matches the fault-free run."""
    cfg = engine.cfg
    oracle = engine.serve(_reqs(cfg))
    real = engine._mixed
    state = {"armed": False, "fired": False}

    def boom(params, cache, tb):
        out = real(params, cache, tb)   # donates + deletes `cache`
        if state["armed"] and not state["fired"]:
            state["fired"] = True
            raise RuntimeError("simulated crash after cache donation")
        return out

    engine._mixed = boom
    try:
        sess = engine.start(faults=None)
        reqs = _reqs(cfg)
        for i, r in enumerate(reqs):
            sess.submit(r, stream_id=i)
        sess.step()                     # healthy first round
        state["armed"] = True
        steps = 0
        while not sess.done():
            sess.step()
            steps += 1
            assert steps < 500
    finally:
        engine._mixed = real
    assert state["fired"]
    assert sess.cache_recoveries == 1
    results = [sess.results[r.uid] for r in reqs]
    assert [r.tokens for r in results] == [r.tokens for r in oracle]


# ------------------------------------------------------ overload valves

def test_queue_cap_sheds_edf_last(engine):
    """Overflow past queue_cap sheds with finish_reason='shed'; the
    survivors' tokens are bitwise the uncapped run's."""
    cfg = engine.cfg
    reqs = _reqs(cfg, n=5)
    oracle = engine.serve(reqs, n_slots=1)
    res = engine.serve(reqs, n_slots=1, queue_cap=1)
    flt = engine.last_stats["faults"]
    assert flt["sheds"] >= 1
    shed = [r for r in res if r.finish_reason == "shed"]
    assert len(shed) == flt["sheds"] and all(r.tokens == [] for r in shed)
    for got, ref in zip(res, oracle):
        if got.finish_reason == "length":
            assert got.tokens == ref.tokens


def test_timeout_queued_and_active(engine):
    """timeout_s counts from ARRIVAL: requests stuck behind a single
    slot time out in the queue, and a too-slow active request times out
    mid-decode; either way finish_reason='timeout' and the session
    drains."""
    cfg = engine.cfg
    res = engine.serve(_reqs(cfg, n=4, max_new=16, timeout_s=1e-4),
                       n_slots=1)
    assert engine.last_stats["faults"]["timeouts"] >= 1
    assert all(r.finish_reason in ("timeout", "length") for r in res)
    assert any(r.finish_reason == "timeout" for r in res)


def test_cancel_mid_flight_frees_slot(engine):
    """Cancelling an active request keeps its partial tokens, frees the
    slot immediately, and leaves the other streams bitwise untouched."""
    cfg = engine.cfg
    reqs = _reqs(cfg)
    oracle = engine.serve(reqs)
    sess = engine.start()
    for i, r in enumerate(reqs):
        sess.submit(r, stream_id=i)
    for _ in range(4):
        sess.step()
    assert sess.cancel(reqs[1].uid)
    assert not sess.cancel(reqs[1].uid)       # idempotent
    steps = 0
    while not sess.done():
        sess.step()
        steps += 1
        assert steps < 500
    got = [sess.results[r.uid] for r in reqs]
    assert got[1].finish_reason == "cancelled"
    assert got[1].tokens == oracle[1].tokens[:len(got[1].tokens)]
    assert got[0].tokens == oracle[0].tokens
    assert got[2].tokens == oracle[2].tokens


def test_meets_slo_excludes_faulted_finishes():
    slo = SLO(ttft_s=100.0, itl_s=100.0)
    ok = GenResult(tokens=[1, 2], finish_reason="length",
                   prefill_s=0.1, token_times=[0.1, 0.2])
    assert meets_slo(ok, slo)
    for reason in ("shed", "error", "timeout", "cancelled", "deadline"):
        bad = dataclasses.replace(ok, finish_reason=reason)
        assert not meets_slo(bad, slo)


# ------------------------------------------------- chaos property sweep

@pytest.mark.parametrize("which", ["contiguous", "paged"])
@pytest.mark.parametrize("seed", [1, 2])
def test_chaos_survivors_bitwise_identical(engine, paged_engine,
                                           which, seed):
    """The headline property, over both cache layouts: a full chaos mix
    (step faults + NaN + page retirement + stragglers + cancels) may
    kill requests, but every request that finishes cleanly emits
    exactly the fault-free tokens, and the allocator partition closes
    (serve() runs alloc.check() after every chaos run)."""
    eng = engine if which == "contiguous" else paged_engine
    reqs = _reqs(eng.cfg, n=4)
    oracle = eng.serve(reqs)
    faults = chaos_injector(seed, rate=0.15, paged=eng.paged)
    res = eng.serve(reqs, faults=faults)
    assert all(r.finish_reason in
               ("length", "eos", "error", "timeout", "cancelled", "shed")
               for r in res)
    survivors = [i for i, r in enumerate(res)
                 if r.finish_reason in ("eos", "length")]
    for i in survivors:
        assert res[i].tokens == oracle[i].tokens, f"survivor {i} diverged"
    assert sum(eng.last_stats["faults"]["injected"].values()) > 0


def test_chaos_paged_exercises_page_path(paged_engine):
    """At a page-heavy rate the retirement path actually fires and the
    pool still closes clean."""
    reqs = _reqs(paged_engine.cfg, n=4, max_new=10)
    oracle = paged_engine.serve(reqs)
    faults = ServeFaultInjector(seed=5, page_rate=0.6, page_frac=0.5,
                                page_hold_steps=2)
    res = paged_engine.serve(reqs, faults=faults)
    assert faults.counts["page_quarantines"] >= 1
    survivors = [i for i, r in enumerate(res)
                 if r.finish_reason in ("eos", "length")]
    assert survivors, "page churn alone should not kill everything"
    for i in survivors:
        assert res[i].tokens == oracle[i].tokens
