"""Pallas kernel validation: shape/dtype sweeps vs pure-jnp oracles
(interpret mode — kernel bodies execute in Python on CPU)."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import compute_h
from repro.core.packing import pack_nibbles
from repro.core.precondition import safe_cholesky
from repro.kernels import ref
from repro.kernels.backsub import backsub
from repro.kernels.lut_mpgemm import lut_matmul, lut_matmul_packed
from repro.kernels.ops import lut_linear, s_step_blocked, vmem_plan


def _mk(seed, m, n, p, bits, xdtype=np.float32):
    rng = np.random.default_rng(seed)
    codes = rng.integers(0, 1 << bits, size=(m, n)).astype(np.uint8)
    t = (rng.normal(size=(m, 1 << bits)) * 0.05).astype(np.float32)
    x = rng.normal(size=(n, p)).astype(xdtype)
    return jnp.asarray(codes), jnp.asarray(t), jnp.asarray(x)


SHAPES = [(128, 256, 64), (96, 130, 33), (8, 16, 4), (64, 512, 128),
          (130, 96, 17), (1, 64, 1)]


@pytest.mark.parametrize("m,n,p", SHAPES)
@pytest.mark.parametrize("bits", [3, 4])
def test_lut_matmul_unpacked_matches_ref(m, n, p, bits):
    codes, t, x = _mk(0, m, n, p, bits)
    y = lut_matmul(codes, t, x, bits=bits, interpret=True)
    yref = ref.lut_matmul_ref(codes, t, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("m,n,p", SHAPES)
def test_lut_matmul_packed_matches_ref(m, n, p):
    codes, t, x = _mk(1, m, n, p, 4)
    packed = pack_nibbles(codes)
    y = lut_matmul_packed(packed, t, x, bits=4, interpret=True)
    yref = ref.lut_matmul_ref(codes, t, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yref),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("xdtype", [np.float32, jnp.bfloat16, np.float16])
def test_lut_matmul_dtypes(xdtype):
    codes, t, x = _mk(2, 64, 96, 32, 4)
    x = x.astype(xdtype)
    y = lut_matmul(codes, t, x, bits=4, interpret=True)
    assert y.dtype == x.dtype
    yref = ref.lut_matmul_ref(codes, t, x)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yref, np.float32),
                               rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("bm,bk,bp", [(32, 64, 16), (128, 512, 128),
                                      (16, 32, 8)])
def test_lut_matmul_block_invariance(bm, bk, bp):
    codes, t, x = _mk(3, 70, 150, 40, 4)
    y = lut_matmul(codes, t, x, bits=4, block_m=bm, block_k=bk, block_p=bp,
                   interpret=True)
    yref = ref.lut_matmul_ref(codes, t, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yref),
                               rtol=1e-5, atol=1e-5)


# ------------------------------------------------------------------- backsub

def _mk_backsub(seed, m, n, bits):
    rng = np.random.default_rng(seed)
    w = jnp.asarray((rng.standard_t(df=4, size=(m, n)) * 0.05)
                    .astype(np.float32))
    u = rng.normal(size=(n, 8)).astype(np.float32)
    x = jnp.asarray((u @ rng.normal(size=(8, 4 * n)) +
                     0.1 * rng.normal(size=(n, 4 * n))).astype(np.float32))
    l = safe_cholesky(compute_h(x), "fixed")
    t = jnp.sort(jnp.asarray((rng.normal(size=(m, 1 << bits)) * 0.05)
                             .astype(np.float32)), axis=1)
    return w, t, l


@pytest.mark.parametrize("m,n,bm,bn", [(32, 64, 16, 16), (33, 50, 16, 16),
                                       (16, 128, 16, 128), (48, 96, 32, 32)])
@pytest.mark.parametrize("bits", [3, 4])
def test_backsub_matches_scan_oracle(m, n, bm, bn, bits):
    w, t, l = _mk_backsub(7, m, n, bits)
    codes_k, wq_k = backsub(w, t, l, block_m=bm, block_n=bn, interpret=True)
    codes_r, wq_r = ref.backsub_ref(w, t, l)
    np.testing.assert_array_equal(np.asarray(codes_k), np.asarray(codes_r))
    np.testing.assert_allclose(np.asarray(wq_k), np.asarray(wq_r),
                               rtol=1e-5, atol=1e-6)


def test_backsub_block_boundary_feedback():
    """Cross-column-block residual propagation must be exact: compare a
    two-block run against the single-block run."""
    w, t, l = _mk_backsub(11, 24, 64, 4)
    c1, _ = backsub(w, t, l, block_m=24, block_n=64, interpret=True)
    c2, _ = backsub(w, t, l, block_m=24, block_n=16, interpret=True)
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))


# ----------------------------------------------------------------------- ops

def test_lut_linear_dispatch_paths_agree():
    codes, t, x = _mk(5, 40, 60, 10, 4)
    y_pallas = lut_linear(codes, t, x, bits=4, use_pallas=True)
    y_ref = lut_linear(codes, t, x, bits=4, use_pallas=False)
    np.testing.assert_allclose(np.asarray(y_pallas), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)
    packed = pack_nibbles(codes)
    y_p = lut_linear(packed, t, x, bits=4, packed=True, use_pallas=True)
    np.testing.assert_allclose(np.asarray(y_p), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)


def test_s_step_blocked_matches_core():
    w, t, l = _mk_backsub(13, 20, 40, 4)
    c1, _ = s_step_blocked(w, t, l, block_m=16, block_n=16, use_pallas=True)
    c2, _ = s_step_blocked(w, t, l, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))


def test_vmem_plan_fits_budget():
    plan = vmem_plan(m=4096, n=4096, p=256, bits=4)
    assert plan["vmem_bytes"] < 16 * 2**20   # well under v5e VMEM
    # packed codes dominate HBM traffic at decode-like p
    assert plan["codes_bytes"] == 4096 * 4096 * 0.5
