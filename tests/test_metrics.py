"""Serving observability: percentile math, SLO goodput, tracker, policy."""
import math

import pytest

from repro.serve.metrics import (DEVICE_DB, SLO, AdaptiveDraftPolicy,
                                 DeviceSpec, StepTracker, goodput_report,
                                 latency_summary, meets_slo, percentile,
                                 request_itls, resolve_device)
from repro.serve.scheduler import GenRequest, GenResult, SlotScheduler


# ------------------------------------------------------------- percentile

def test_percentile_degenerate_inputs():
    assert percentile([], 50) == 0.0
    assert percentile([], 99) == 0.0
    assert percentile([7.0], 0) == 7.0
    assert percentile([7.0], 50) == 7.0
    assert percentile([7.0], 100) == 7.0


def test_percentile_interpolation_and_ties():
    xs = [1.0, 2.0, 3.0, 4.0]
    assert percentile(xs, 0) == 1.0
    assert percentile(xs, 100) == 4.0
    assert percentile(xs, 50) == pytest.approx(2.5)
    # numpy linear method: pos = 3 * 0.99 = 2.97
    assert percentile(xs, 99) == pytest.approx(3.97)
    assert percentile([5.0, 5.0, 5.0, 5.0], 99) == 5.0   # ties
    assert percentile([2.0, 1.0], 50) == pytest.approx(1.5)  # unsorted in
    with pytest.raises(ValueError):
        percentile(xs, 101)
    with pytest.raises(ValueError):
        percentile(xs, -1)


# ---------------------------------------------------------------- goodput

def _res(tokens=4, ttft=0.5, itl=0.25, reason="length"):
    times = [ttft + i * itl for i in range(tokens)]
    return GenResult(tokens=list(range(tokens)), prefill_s=ttft,
                     finish_reason=reason, token_times=times,
                     done_s=times[-1])


def test_meets_slo_boundaries():
    # power-of-two budgets so the constructed gaps are float-exact and
    # the boundary case really sits ON the boundary
    slo = SLO(ttft_s=0.5, itl_s=0.25)
    assert meets_slo(_res(ttft=0.5, itl=0.25), slo)       # exactly on: good
    assert not meets_slo(_res(ttft=0.500001, itl=0.25), slo)  # TTFT overrun
    assert not meets_slo(_res(ttft=0.5, itl=0.250001), slo)   # slow gap
    assert not meets_slo(_res(reason="deadline"), slo)    # engine killed it
    assert meets_slo(_res(tokens=1), SLO(ttft_s=0.5))     # no gaps, no itl
    assert meets_slo(_res(ttft=9.9, itl=9.9), SLO())      # inf disables


def test_goodput_counts_only_slo_meeting_tokens():
    slo = SLO(ttft_s=0.5, itl_s=0.25)
    good, bad = _res(tokens=6), _res(tokens=4, ttft=1.5)
    rep = goodput_report([good, bad], slo, wall_s=2.0)
    assert rep["n_requests"] == 2 and rep["n_good"] == 1
    assert rep["slo_attainment"] == 0.5
    assert rep["tokens"] == 10 and rep["good_tokens"] == 6
    assert rep["throughput_tok_per_s"] == pytest.approx(5.0)
    assert rep["goodput_tok_per_s"] == pytest.approx(3.0)
    empty = goodput_report([], slo, wall_s=1.0)
    assert empty["slo_attainment"] == 0.0


def test_latency_summary_shapes():
    lat = latency_summary([_res(tokens=3), _res(tokens=1)])
    assert lat["ttft_s"]["n"] == 2
    assert lat["itl_s"]["n"] == 2          # 2 gaps from the 3-token result
    assert lat["itl_s"]["p50"] == pytest.approx(0.25)


# ---------------------------------------- speculative timestamp honesty

def test_record_speculative_interpolates_timestamps():
    """Regression: a speculative round emits k tokens at one wall-clock
    instant; naive timestamping collapses their ITL gaps to zero and the
    p50 lies. The scheduler interpolates across the round's span."""
    sched = SlotScheduler(1, max_len=64)
    req = GenRequest(prompt=[1, 2], max_new=8)
    sched.submit(req)
    assert sched.next_ready(0.0, slot=0) is req
    sched.admit(0, req, first_token=5, now_s=1.0, prefill_s=0.1)
    n = sched.record_speculative(0, [6, 7, 8], now_s=1.3)
    assert n == 3
    st = sched.slots[0]
    assert st.times == pytest.approx([1.0, 1.1, 1.2, 1.3])
    gaps = [b - a for a, b in zip(st.times, st.times[1:])]
    assert min(gaps) > 0.0                 # no zero-gap runs
    # a second round keeps interpolating from the previous timestamp
    sched.record_speculative(0, [9, 10], now_s=1.5)
    assert st.times == pytest.approx([1.0, 1.1, 1.2, 1.3, 1.4, 1.5])
    res_itls = request_itls(GenResult(tokens=st.tokens,
                                      token_times=st.times))
    assert all(g > 0 for g in res_itls)


# ----------------------------------------------------------- device + hw

def test_device_db_mirrors_roofline_constants():
    from repro.roofline import analysis
    spec = DEVICE_DB["tpu-v5e"]
    assert spec.peak_flops == analysis.PEAK_FLOPS
    assert spec.hbm_bw == analysis.HBM_BW
    assert resolve_device("rtx-4090").name == "rtx-4090"
    assert resolve_device(DeviceSpec("x", 1.0, 1.0)).name == "x"
    assert resolve_device(None).name == "host-cpu"   # CPU container


class _Cost:
    def __init__(self, flops, bytes_):
        self.flops, self.bytes = flops, bytes_


def test_step_tracker_achieved_vs_peak():
    dev = DeviceSpec("toy", peak_flops=1e12, hbm_bw=1e9)
    tr = StepTracker(dev, {"mixed": _Cost(1e9, 1e6),
                           "draft": _Cost(4e8, 5e5),
                           "verify": _Cost(2e9, 2e6)})
    tr.record("mixed", dt_s=0.01, tokens=8)     # 1e8 B/s, 1e11 FLOP/s
    tr.record_spec_round(dt_s=0.02, draft_passes=2, tokens=6)
    s = tr.summary()
    assert s["steps"] == 2 and s["tokens"] == 14
    assert s["step_bytes"]["mixed"] == 1e6
    # spec round bytes: 2 drafts * 5e5 + 2e6 = 3e6 over 0.02s = 1.5e8 B/s
    bws = sorted([1e6 / 0.01, 3e6 / 0.02])
    assert s["achieved_hbm_gbps"]["p50"] == pytest.approx(
        (bws[0] + bws[1]) / 2 / 1e9)
    assert s["hbm_util_pct"]["p50"] == pytest.approx(
        100.0 * (bws[0] + bws[1]) / 2 / dev.hbm_bw)
    assert s["mfu_pct"]["p50"] > 0


# ------------------------------------------------------- adaptive policy

def test_adaptive_policy_hysteresis():
    p = AdaptiveDraftPolicy(queue_hi=2, queue_lo=0, wait_hi_s=1.0,
                            wait_lo_s=0.25)
    assert not p.update(1, 0.0)            # below both thresholds
    assert p.update(2, 0.0)                # queue depth trips it on
    assert p.flips == 1
    assert p.update(1, 0.3)                # above lo: stays on (hysteresis)
    assert not p.update(0, 0.1)            # both cleared -> off
    assert p.flips == 2
    assert p.update(0, 1.5)                # wait alone can trip it
    assert p.flips == 3
    p.reset()
    assert not p.on and p.flips == 0


def test_adaptive_policy_requires_speculation():
    import jax
    from repro.configs import get_config, reduce_config
    from repro.models import init_params
    from repro.serve.engine import ServeEngine
    cfg = reduce_config(get_config("deepseek-7b"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError):
        ServeEngine(params, cfg, max_len=32, n_slots=2,
                    adaptive=AdaptiveDraftPolicy())
