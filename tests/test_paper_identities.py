"""Mathematical identities from the paper, verified numerically."""
import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import compute_h, layer_objective, precondition
from repro.core.ganq import s_step, t_step
from repro.core.codebook import init_codebook, assign_nearest


def _problem(seed, m=8, n=12, p=48):
    rng = np.random.default_rng(seed)
    w = jnp.asarray((rng.standard_t(df=4, size=(m, n)) * 0.05)
                    .astype(np.float32))
    x = jnp.asarray(rng.normal(size=(n, p)).astype(np.float32))
    return w, compute_h(x)


@given(st.integers(0, 2000))
@settings(max_examples=15, deadline=None)
def test_eq13_cholesky_rotation_identity(seed):
    """||WX - W~X||^2 = ||WL - W~L||^2 with H = X X^T = L L^T (eq. 9-13)."""
    w, h = _problem(seed)
    hp = precondition(h, "fixed", 0.01)
    l = jnp.linalg.cholesky(hp)
    t = init_codebook(w, 3, "quantile")
    codes = assign_nearest(w, t)
    wq = jnp.take_along_axis(t, codes, 1)
    lhs = float(layer_objective(w, wq, hp))
    e = (w - wq) @ l
    rhs = float(jnp.sum(e * e))
    np.testing.assert_allclose(lhs, rhs, rtol=1e-4)


@given(st.integers(0, 2000))
@settings(max_examples=10, deadline=None)
def test_s_step_per_term_greedy_optimality(seed):
    """Eq. 16: the back-substitution choice minimizes each squared term of
    the rotated objective given the already-committed later columns —
    verify column n-1's term is exactly min over the codebook."""
    w, h = _problem(seed, m=4, n=6)
    hp = precondition(h, "fixed", 0.01)
    l = jnp.linalg.cholesky(hp)
    t = init_codebook(w, 3, "quantile")
    codes, wq = s_step(w, t, l)
    n = w.shape[1]
    # last column (processed first): residual = (W[:,n-1]-w~)*L[n-1,n-1]
    term = ((w[:, n - 1] - wq[:, n - 1]) * l[n - 1, n - 1]) ** 2
    # brute force over codebook entries
    cand = ((w[:, n - 1][:, None] - t) * l[n - 1, n - 1]) ** 2
    np.testing.assert_allclose(np.asarray(term),
                               np.asarray(jnp.min(cand, axis=1)), rtol=1e-5)


def test_alternating_improves_over_one_shot():
    """K iterations of (S, T) beat the K=1 result (paper's Algorithm 1
    rationale) on a correlated-H ensemble."""
    from repro.core import QuantConfig, ganq_quantize
    wins = 0
    for seed in range(5):
        rng = np.random.default_rng(seed + 300)
        w = jnp.asarray((rng.standard_t(df=4, size=(32, 48)) * 0.05)
                        .astype(np.float32))
        u = rng.normal(size=(48, 6)).astype(np.float32)
        x = jnp.asarray((u @ rng.normal(size=(6, 192))).astype(np.float32))
        h = compute_h(x)
        e1 = float(layer_objective(w, ganq_quantize(
            w, h=h, cfg=QuantConfig(iters=1, precondition="fixed")
        ).layer.dequantize(), h))
        e8 = float(layer_objective(w, ganq_quantize(
            w, h=h, cfg=QuantConfig(iters=8, precondition="fixed")
        ).layer.dequantize(), h))
        wins += e8 <= e1 * 1.001
    assert wins >= 4, wins


def test_codebook_init_ablation_kmeans_vs_quantile():
    """T^0 robustness: with either init the solver lands far below the RTN
    floor (absolute gaps between inits are noise on the near-singular
    correlated H; the solver is what matters)."""
    from repro.core import QuantConfig, ganq_quantize, rtn_reconstruct
    rng = np.random.default_rng(9)
    w = jnp.asarray((rng.standard_t(df=4, size=(32, 48)) * 0.05)
                    .astype(np.float32))
    u = rng.normal(size=(48, 6)).astype(np.float32)
    x = jnp.asarray((u @ rng.normal(size=(6, 192))).astype(np.float32))
    h = compute_h(x)
    e_rtn = float(layer_objective(w, rtn_reconstruct(w, 4), h))
    for init in ("quantile", "kmeans"):
        res = ganq_quantize(w, h=h, cfg=QuantConfig(
            iters=8, codebook_init=init, precondition="fixed"))
        err = float(layer_objective(w, res.layer.dequantize(), h))
        assert err < 0.2 * e_rtn, (init, err, e_rtn)
