"""Continuous-batching subsystem: scheduler, sampler, engine equivalence."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduce_config
from repro.data.synthetic import MarkovStream
from repro.models import init_params
from repro.serve.engine import GenRequest, GenResult, ServeEngine
from repro.serve.sampler import apply_top_k, sample_tokens
from repro.serve.scheduler import SlotScheduler


def _setup(arch="deepseek-7b"):
    cfg = reduce_config(get_config(arch))
    params = init_params(jax.random.PRNGKey(0), cfg)
    data = MarkovStream(cfg.vocab_size, batch=4, seq=32, seed=0)
    return cfg, params, data


# ---------------------------------------------------------------- scheduler

def test_scheduler_slot_lifecycle():
    s = SlotScheduler(n_slots=2, max_len=32)
    r1 = GenRequest(prompt=[1, 2, 3], max_new=2)
    r2 = GenRequest(prompt=[4, 5], max_new=3)
    r3 = GenRequest(prompt=[6], max_new=1)
    for r in (r1, r2, r3):
        s.submit(r)
    assert s.free_slots() == [0, 1]
    assert not s.admit(0, s.next_ready(0.0), first_token=7, now_s=0.0,
                       prefill_s=0.0)
    assert not s.admit(1, s.next_ready(0.0), first_token=8, now_s=0.0,
                       prefill_s=0.0)
    assert s.free_slots() == []             # r3 waits in the queue
    toks, pos, act, *_ = s.batch_arrays()
    assert act.all() and pos[0] == 3 and pos[1] == 2
    freed = s.record_step(np.asarray([9, 10]), now_s=0.1)
    assert freed == [0]                     # r1 hit max_new=2
    assert s.results[r1.uid].tokens == [7, 9]
    # r3 admits into the freed slot and finishes immediately (max_new=1)
    req = s.next_ready(0.0)
    assert req is r3
    assert s.admit(0, req, first_token=11, now_s=0.2, prefill_s=0.0)
    assert s.results[r3.uid].tokens == [11]
    assert s.record_step(np.asarray([0, 12]), now_s=0.3) == [1]
    assert s.done()
    assert s.results[r2.uid].tokens == [8, 10, 12]
    assert s.slot_reuses == 1


def test_scheduler_arrivals_and_deadline():
    s = SlotScheduler(n_slots=1, max_len=32)
    s.submit(GenRequest(prompt=[1], max_new=100, deadline_s=0.0,
                        arrival_s=5.0))
    assert s.next_ready(1.0) is None        # not arrived yet
    assert s.next_arrival() == 5.0
    req = s.next_ready(6.0)
    assert req is not None
    s.admit(0, req, first_token=2, now_s=6.0, prefill_s=0.0)
    s.record_step(np.asarray([3]), now_s=7.0)   # exceeds 0-second deadline
    assert s.done()
    assert s.results[req.uid].finish_reason == "deadline"


# ------------------------------------------------------------------ sampler

def test_top_k_masks_tail():
    logits = jnp.asarray([[0.0, 1.0, 2.0, 3.0], [3.0, 2.0, 1.0, 0.0]])
    out = apply_top_k(logits, jnp.asarray([2, 0]))
    assert np.isneginf(np.asarray(out[0, :2])).all()
    assert np.isfinite(np.asarray(out[0, 2:])).all()
    assert np.isfinite(np.asarray(out[1])).all()   # 0 = no truncation


def test_per_sequence_temperature():
    logits = jnp.tile(jnp.arange(8.0)[None], (2, 1))
    keys = jax.random.split(jax.random.PRNGKey(0), 2)
    toks = sample_tokens(logits, jnp.asarray([0.0, 5.0]),
                         jnp.zeros(2, jnp.int32), keys)
    assert int(toks[0]) == 7                # greedy row takes argmax
    hot = {int(sample_tokens(logits, jnp.asarray([0.0, 5.0]),
                             jnp.zeros(2, jnp.int32),
                             jax.random.split(jax.random.PRNGKey(s), 2))[1])
           for s in range(12)}
    assert len(hot) > 1                     # hot row actually samples


# ------------------------------------------------------- continuous engine

def test_mixed_length_prompts_continuous():
    cfg, params, data = _setup()
    engine = ServeEngine(params, cfg, max_len=64, n_slots=2)
    toks = data.batch_at(2)["tokens"]
    reqs = ([GenRequest(prompt=toks[i, :8].tolist(), max_new=3)
             for i in range(3)] +
            [GenRequest(prompt=toks[i, :12].tolist(), max_new=3)
             for i in range(2)])
    res = engine.serve(reqs)
    assert all(isinstance(r, GenResult) and len(r.tokens) == 3 for r in res)
    assert engine.last_stats["slot_reuses"] >= 1   # 5 requests over 2 slots


def test_eos_frees_slot_mid_decode():
    """An eos early-exit must free the slot while the other slot keeps
    decoding, and the freed slot must be reused by a queued request."""
    cfg, params, data = _setup()
    engine = ServeEngine(params, cfg, max_len=64, n_slots=2)
    prompts = [data.batch_at(1)["tokens"][i, :8].tolist() for i in range(3)]
    probe = engine.generate_batch([GenRequest(prompt=prompts[0], max_new=4)])
    eos = probe[0].tokens[1]                # hits after 2 generated tokens
    reqs = [GenRequest(prompt=prompts[0], max_new=16, eos_id=eos),
            GenRequest(prompt=prompts[1], max_new=6),
            GenRequest(prompt=prompts[2], max_new=4)]
    res = engine.serve(reqs)
    assert res[0].finish_reason == "eos" and res[0].tokens[-1] == eos
    assert len(res[0].tokens) < 16
    assert len(res[1].tokens) == 6 and len(res[2].tokens) == 4
    assert engine.last_stats["slot_reuses"] >= 1


def test_continuous_greedy_matches_static_reference():
    """Token-level equivalence: mixed-length continuous batching == the seed
    per-request static path, request by request (greedy)."""
    cfg, params, data = _setup()
    engine = ServeEngine(params, cfg, max_len=64, n_slots=3)
    toks = data.batch_at(4)["tokens"]
    reqs = [GenRequest(prompt=toks[i, :l].tolist(), max_new=m)
            for i, (l, m) in enumerate([(8, 5), (12, 4), (6, 6), (10, 3)])]
    cont = engine.serve(reqs)
    for r, c in zip(reqs, cont):
        ref = engine.generate_batch(
            [GenRequest(prompt=r.prompt, max_new=r.max_new)])
        assert c.tokens == ref[0].tokens, (c.tokens, ref[0].tokens)


def test_continuous_greedy_equivalence_int8_kv():
    """Slot insertion + masked decode also hold for the int8 KV cache."""
    import dataclasses
    cfg, params, data = _setup()
    cfg = dataclasses.replace(cfg, kv_quant_bits=8)
    engine = ServeEngine(params, cfg, max_len=64, n_slots=2)
    toks = data.batch_at(5)["tokens"]
    reqs = [GenRequest(prompt=toks[0, :8].tolist(), max_new=4),
            GenRequest(prompt=toks[1, :11].tolist(), max_new=4),
            GenRequest(prompt=toks[2, :8].tolist(), max_new=4)]
    cont = engine.serve(reqs)
    for r, c in zip(reqs, cont):
        ref = engine.generate_batch(
            [GenRequest(prompt=r.prompt, max_new=r.max_new)])
        assert c.tokens == ref[0].tokens


def test_mixed_precision_policy_serves_continuous():
    """A 3-bit-MLP / 4-bit-attention / fp-kept `PrecisionPolicy` model
    serves end-to-end through the slot engine, token-identical to its own
    static reference path (greedy)."""
    from repro.core import LayerRule, PrecisionPolicy, QuantConfig
    from repro.models.quantized import model_storage_report, quantize_model_ptq
    cfg, params, data = _setup()
    calib = {"tokens": jnp.asarray(data.batch_at(0)["tokens"])}
    policy = PrecisionPolicy(
        qcfg=QuantConfig(bits=4, iters=2, precondition="fixed"),
        rules=(LayerRule(pattern="*/mlp/w_down", keep_fp=True),
               LayerRule(pattern="*/mlp/*", bits=3)))
    qparams, report = quantize_model_ptq(params, cfg, calib, policy=policy)
    rep = model_storage_report(qparams, report)
    assert {r["bits"] for r in rep["per_layer"].values()} == {3, 4, None}
    engine = ServeEngine(qparams, cfg, max_len=64, n_slots=2)
    toks = data.batch_at(6)["tokens"]
    reqs = [GenRequest(prompt=toks[0, :8].tolist(), max_new=5),
            GenRequest(prompt=toks[1, :12].tolist(), max_new=4),
            GenRequest(prompt=toks[2, :6].tolist(), max_new=4)]
    cont = engine.serve(reqs)
    assert all(len(c.tokens) > 0 for c in cont)
    for r, c in zip(reqs, cont):
        ref = engine.generate_batch(
            [GenRequest(prompt=r.prompt, max_new=r.max_new)])
        assert c.tokens == ref[0].tokens


def test_sampled_serve_reproducible_across_fresh_requests():
    """Same seed + same prompts (fresh GenRequest objects) => same sampled
    tokens: PRNG streams key on submission index, not the global uid."""
    cfg, params, data = _setup()
    engine = ServeEngine(params, cfg, max_len=64, n_slots=2)
    p = data.batch_at(7)["tokens"][0, :8].tolist()
    mk = lambda: [GenRequest(prompt=p, max_new=6, temperature=1.3)]
    a = engine.serve(mk(), seed=0)
    b = engine.serve(mk(), seed=0)
    c = engine.serve(mk(), seed=1)
    assert a[0].tokens == b[0].tokens
    assert a[0].tokens != c[0].tokens


def test_unsorted_arrival_times_no_head_of_line_block():
    """A request that arrived early must not queue behind a later arrival:
    it completes before the late request even arrives."""
    cfg, params, data = _setup()
    engine = ServeEngine(params, cfg, max_len=64, n_slots=1)
    toks = data.batch_at(8)["tokens"]
    reqs = [GenRequest(prompt=toks[0, :8].tolist(), max_new=2),
            GenRequest(prompt=toks[1, :8].tolist(), max_new=2)]
    engine.serve(reqs)                   # warm jit caches off the clock
    late = 1.5
    res = engine.serve(reqs, arrival_times=[late, 0.0])
    assert [len(r.tokens) for r in res] == [2, 2]
    assert res[1].done_s < late          # early request served first
    assert res[0].done_s >= late


def test_init_serve_cache_slot_reset():
    """cache= + slot= zeroes exactly that slot row, every cache variant."""
    from repro.models import init_serve_cache
    cfg, params, _ = _setup()
    cache = init_serve_cache(params, {}, 3, 16, cfg)
    dirty = jax.tree.map(jnp.ones_like, cache)
    reset = init_serve_cache(params, {}, 3, 16, cfg, cache=dirty,
                             slot=jnp.int32(1))
    for leaf in jax.tree.leaves(reset["tail"]):
        assert not np.asarray(leaf[1]).any()
        assert np.asarray(leaf[0]).all() and np.asarray(leaf[2]).all()
    for leaf in jax.tree.leaves([u for u in reset["units"] if u is not None]):
        assert not np.asarray(leaf[:, 1]).any()
        assert np.asarray(leaf[:, 0]).all() and np.asarray(leaf[:, 2]).all()


def test_continuous_greedy_equivalence_recurrent():
    """Recurrent state (RG-LRU pattern incl. sliding-window attn) survives
    slot insertion and the active-mask freeze."""
    cfg, params, data = _setup("recurrentgemma-2b")
    engine = ServeEngine(params, cfg, max_len=48, n_slots=2)
    toks = data.batch_at(6)["tokens"]
    reqs = [GenRequest(prompt=toks[0, :7].tolist(), max_new=3),
            GenRequest(prompt=toks[1, :10].tolist(), max_new=3),
            GenRequest(prompt=toks[2, :5].tolist(), max_new=3)]
    cont = engine.serve(reqs)
    for r, c in zip(reqs, cont):
        ref = engine.generate_batch(
            [GenRequest(prompt=r.prompt, max_new=r.max_new)])
        assert c.tokens == ref[0].tokens


def test_continuous_greedy_equivalence_rwkv():
    """RWKV-6 state (tm_shift / wkv / cm_shift) survives slot insertion and
    the active-mask freeze — the attention-free cache variant."""
    cfg, params, data = _setup("rwkv6-7b")
    engine = ServeEngine(params, cfg, max_len=48, n_slots=2)
    toks = data.batch_at(9)["tokens"]
    reqs = [GenRequest(prompt=toks[0, :6].tolist(), max_new=3),
            GenRequest(prompt=toks[1, :9].tolist(), max_new=3),
            GenRequest(prompt=toks[2, :6].tolist(), max_new=3)]
    cont = engine.serve(reqs)
    for r, c in zip(reqs, cont):
        ref = engine.generate_batch(
            [GenRequest(prompt=r.prompt, max_new=r.max_new)])
        assert c.tokens == ref[0].tokens
