"""Fault tolerance: straggler detection, failure injection, elastic re-mesh.

In a single-process container the *mechanisms* are real (the monitor, the
restart path, the resharding restore); the failures themselves are injected
(a real pod wires `HostFailure` to the platform's health service instead).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import numpy as np


class HostFailure(RuntimeError):
    """Raised when a (simulated) host dies mid-step."""


@dataclasses.dataclass
class FailureInjector:
    """Deterministic failure schedule for tests: fail at given steps."""

    fail_at: tuple = ()
    _raised: set = dataclasses.field(default_factory=set)

    def check(self, step: int) -> None:
        if step in self.fail_at and step not in self._raised:
            self._raised.add(step)
            raise HostFailure(f"injected host failure at step {step}")


class StragglerMonitor:
    """EWMA per-host step-time monitor.

    flag(host) when its step time exceeds `threshold` x the fleet median
    EWMA for `patience` consecutive steps — the mitigation hook then
    requests that host's eviction (elastic re-mesh) or enables backup
    execution for its shard.
    """

    def __init__(self, n_hosts: int, alpha: float = 0.3,
                 threshold: float = 1.8, patience: int = 3):
        self.ewma = np.zeros(n_hosts)
        self.alpha = alpha
        self.threshold = threshold
        self.patience = patience
        self.strikes = np.zeros(n_hosts, np.int32)
        self.flagged: List[int] = []

    def record(self, host_times: np.ndarray) -> List[int]:
        """host_times: seconds per host for this step. Returns newly flagged
        hosts."""
        m = self.ewma == 0
        self.ewma = np.where(m, host_times,
                             self.alpha * host_times
                             + (1 - self.alpha) * self.ewma)
        med = np.median(self.ewma)
        slow = self.ewma > self.threshold * med
        self.strikes = np.where(slow, self.strikes + 1, 0)
        newly = [int(h) for h in np.nonzero(self.strikes == self.patience)[0]
                 if h not in self.flagged]
        self.flagged.extend(newly)
        return newly


@dataclasses.dataclass
class ElasticPlan:
    """Re-mesh decision after losing hosts: shrink the DP axis (TP stays —
    model-parallel groups are atomic), keep global batch by raising the
    per-shard microbatch."""

    old_dp: int
    lost_hosts: int
    hosts_per_dp_shard: int = 1

    @property
    def new_dp(self) -> int:
        usable = self.old_dp - self.lost_hosts * self.hosts_per_dp_shard
        # largest divisor of old_dp that fits (keeps batch divisible)
        for cand in range(usable, 0, -1):
            if self.old_dp % cand == 0:
                return cand
        return 1

    @property
    def accumulation_factor(self) -> int:
        return self.old_dp // self.new_dp
