"""Sharded checkpointing: per-shard files, manifest with integrity hashes,
atomic publication, async save, keep-k retention, resharding restore.

Layout:
  <dir>/step_%08d.tmp/...   (written)
  <dir>/step_%08d/          (atomic rename after fsync)
      manifest.json         {step, leaves: {path: {shape, dtype, sha256}},
                             mesh_shape, keep of config hash}
      <leaf-path>.npy       full array (single-host container) — production
                            pods write one file per addressable shard; the
                            restore path already handles resharding to ANY
                            mesh via device_put with the target sharding.

Restart contract: `latest_step` + `restore` reconstruct (params, opt_state)
under a possibly DIFFERENT mesh (elastic DP rescale) — tests/test_train_fault.py.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _leaf_paths(tree) -> Dict[str, Any]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        parts = []
        for k in path:
            if hasattr(k, "key"):
                parts.append(str(k.key))
            elif hasattr(k, "idx"):
                parts.append(f"_{k.idx}")
            elif hasattr(k, "name"):
                parts.append(str(k.name))
        out["/".join(parts)] = leaf
    return out


def _sha256(arr: np.ndarray) -> str:
    return hashlib.sha256(arr.tobytes()).hexdigest()[:16]


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------ save

    def save(self, step: int, tree, extra: Optional[Dict] = None) -> None:
        """Snapshot `tree` at `step`. Fetches to host, then (optionally)
        writes asynchronously; atomic rename publishes the checkpoint."""
        host = {k: np.asarray(v) for k, v in _leaf_paths(tree).items()}
        self.wait()
        if self.async_save:
            self._thread = threading.Thread(
                target=self._write, args=(step, host, extra or {}))
            self._thread.start()
        else:
            self._write(step, host, extra or {})

    def _write(self, step: int, host: Dict[str, np.ndarray],
               extra: Dict) -> None:
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {"step": step, "leaves": {}, "extra": extra}
        for name, arr in host.items():
            fn = name.replace("/", "__") + ".npy"
            np.save(os.path.join(tmp, fn), arr)
            manifest["leaves"][name] = {
                "file": fn, "shape": list(arr.shape), "dtype": str(arr.dtype),
                "sha256": _sha256(arr)}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # --------------------------------------------------------------- restore

    def all_steps(self):
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(".tmp"):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like_tree, shardings=None,
                verify: bool = True):
        """Rebuild `like_tree`-structured pytree; placement follows
        `shardings` (same structure) — this is the elastic-reshard path."""
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        leaves = _leaf_paths(like_tree)
        shard_leaves = _leaf_paths(shardings) if shardings is not None else {}
        out = {}
        for name, like in leaves.items():
            info = manifest["leaves"][name]
            arr = np.load(os.path.join(d, info["file"]))
            if verify and _sha256(arr) != info["sha256"]:
                raise IOError(f"checkpoint corruption in {name}")
            if shardings is not None:
                out[name] = jax.device_put(arr, shard_leaves[name])
            else:
                out[name] = jax.numpy.asarray(arr)
        # reassemble tree (same path naming as _leaf_paths)
        treedef = jax.tree_util.tree_structure(like_tree)
        rebuilt = [out[name] for name in _leaf_paths(like_tree)]
        return jax.tree_util.tree_unflatten(treedef, rebuilt)
