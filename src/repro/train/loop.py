"""Training loop with checkpoint/restart, straggler monitoring, elastic
re-mesh, and gradient accumulation.

`Trainer.run` is restart-safe: kill it at any step (or let FailureInjector
raise), call `run` again, and it resumes from the latest checkpoint with
bit-identical data order (the synthetic pipeline is step-keyed).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.data.synthetic import MarkovStream
from repro.models import init_params, train_loss
from repro.sharding.context import ShardCtx
from .checkpoint import CheckpointManager
from .fault import FailureInjector, HostFailure, StragglerMonitor
from .optimizer import OptConfig, OptState, adamw_update, init_opt_state


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 2
    log_every: int = 10
    accum: int = 1              # gradient accumulation microbatches
    sync_ckpt: bool = False     # synchronous checkpoint writes (tests)
    remat: str = "none"
    seed: int = 0


class Trainer:
    def __init__(self, cfg: ModelConfig, data: MarkovStream,
                 tcfg: TrainerConfig, opt_cfg: OptConfig = OptConfig(),
                 ctx: ShardCtx = ShardCtx(),
                 injector: Optional[FailureInjector] = None):
        self.cfg = cfg
        self.data = data
        self.tcfg = tcfg
        self.opt_cfg = opt_cfg
        self.ctx = ctx
        self.injector = injector
        self.ckpt = CheckpointManager(tcfg.ckpt_dir, keep=tcfg.keep,
                                      async_save=not tcfg.sync_ckpt)
        self.monitor = StragglerMonitor(n_hosts=1)
        self.metrics_log: list = []
        self._step_fn = jax.jit(self._make_step())

    def _make_step(self):
        cfg, ctx, opt_cfg, tcfg = self.cfg, self.ctx, self.opt_cfg, self.tcfg

        def step(params, opt_state: OptState, batch):
            if tcfg.accum == 1:
                loss, grads = jax.value_and_grad(train_loss)(
                    params, batch, cfg, ctx, remat=tcfg.remat)
            else:
                def micro(carry, mb):
                    acc_loss, acc_g = carry
                    l, g = jax.value_and_grad(train_loss)(
                        params, mb, cfg, ctx, remat=tcfg.remat)
                    return (acc_loss + l,
                            jax.tree.map(jnp.add, acc_g, g)), None
                zeros = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                mbs = jax.tree.map(
                    lambda x: x.reshape(tcfg.accum, -1, *x.shape[1:]), batch)
                (loss, grads), _ = jax.lax.scan(micro, (0.0, zeros), mbs)
                loss = loss / tcfg.accum
                grads = jax.tree.map(lambda g: g / tcfg.accum, grads)
            params, opt_state, m = adamw_update(params, grads, opt_state,
                                                opt_cfg)
            m["loss"] = loss
            return params, opt_state, m
        return step

    def run(self) -> Dict:
        """Returns summary dict. Resumable after HostFailure."""
        params, opt_state, start = self.init_or_restore()
        losses = []
        for step in range(start, self.tcfg.steps):
            t0 = time.time()
            if self.injector is not None:
                self.injector.check(step)
            batch = {k: jnp.asarray(v)
                     for k, v in self.data.batch_at(step).items()}
            params, opt_state, m = self._step_fn(params, opt_state, batch)
            dt = time.time() - t0
            self.monitor.record(np.array([dt]))
            losses.append(float(m["loss"]))
            if (step + 1) % self.tcfg.log_every == 0:
                self.metrics_log.append(
                    {"step": step + 1, "loss": losses[-1],
                     "lr": float(m["lr"]), "sec": dt})
            if (step + 1) % self.tcfg.ckpt_every == 0 \
                    or step + 1 == self.tcfg.steps:
                self.ckpt.save(step + 1,
                               {"params": params, "opt": opt_state})
        self.ckpt.wait()
        return {"final_loss": losses[-1] if losses else None,
                "first_loss": losses[0] if losses else None,
                "steps_run": len(losses), "resumed_from": start}

    def init_or_restore(self):
        params = init_params(jax.random.PRNGKey(self.tcfg.seed), self.cfg)
        opt_state = init_opt_state(params)
        latest = self.ckpt.latest_step()
        if latest is not None:
            tree = self.ckpt.restore(latest, {"params": params,
                                              "opt": opt_state})
            return tree["params"], tree["opt"], latest
        return params, opt_state, 0
