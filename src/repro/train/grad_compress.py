"""Gradient compression for the DP all-reduce (PowerSGD, Vogels et al. '19).

Rank-r compression with error feedback: per 2-D gradient G (m, n),
  P = psum(G_err @ Q);  P <- orthonormalize(P);  R = psum(G_err^T @ P)
  G_hat = P @ R^T;      err <- G_err - G_hat        (kept local)
Collective bytes drop from m*n to r*(m+n) per tensor — on the slow `pod`
axis of the multi-pod mesh this is the dominant gradient-sync win.
Small/1-D leaves psum uncompressed.

Integration: the compressed train step runs the model under GSPMD auto
sharding on the `model` axis while the DP axes are MANUAL (shard_map with
auto={'model'}), so the backward pass produces LOCAL gradients that we
compress before the explicit psum. See steps in make_compressed_train_step.
"""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.sharding.compat import shard_map

from repro.configs.base import ModelConfig
from repro.models import train_loss
from repro.sharding.context import ShardCtx
from repro.train.optimizer import OptConfig, adamw_update


def _orthonormalize(p: jnp.ndarray) -> jnp.ndarray:
    q, _ = jnp.linalg.qr(p.astype(jnp.float32))
    return q


def powersgd_psum(grads, err, axis_names, rank: int, key):
    """Compress+psum every 2-D leaf; returns (synced grads, new error)."""
    flat, treedef = jax.tree_util.tree_flatten(grads)
    flat_err = jax.tree_util.tree_leaves(err)
    out_g, out_e = [], []
    keys = jax.random.split(key, len(flat))
    for g, e, k in zip(flat, flat_err, keys):
        g = g.astype(jnp.float32) + e
        if g.ndim == 2 and min(g.shape) > 4 * rank:
            m, n = g.shape
            q0 = jax.random.normal(k, (n, rank), jnp.float32) / jnp.sqrt(n)
            p = jax.lax.psum(g @ q0, axis_names)
            p = _orthonormalize(p)
            r = jax.lax.psum(g.T @ p, axis_names)      # (n, rank)
            g_hat_local = p @ r.T / jax.lax.psum(1, axis_names)
            # the reconstruction is already the *mean* of shard grads
            out_g.append(g_hat_local)
            out_e.append(g - g_hat_local)
        else:
            out_g.append(jax.lax.pmean(g, axis_names))
            out_e.append(jnp.zeros_like(g))
    return (jax.tree_util.tree_unflatten(treedef, out_g),
            jax.tree_util.tree_unflatten(treedef, out_e))


def compressed_bytes_ratio(shapes, rank: int) -> float:
    """Analytic wire-bytes ratio vs dense all-reduce (for §Perf)."""
    dense = comp = 0
    for s in shapes:
        n = 1
        for d in s:
            n *= d
        dense += n
        if len(s) == 2 and min(s) > 4 * rank:
            comp += rank * (s[0] + s[1])
        else:
            comp += n
    return comp / dense


def make_compressed_train_step(cfg: ModelConfig, mesh, opt_cfg: OptConfig,
                               rank: int = 8, remat: str = "full"):
    """Train step with PowerSGD-compressed DP gradient sync.

    Manual over DP axes, auto over 'model' (GSPMD keeps TP). MoE archs use
    the dense local path inside (EP+compression composition is future work).
    """
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    inner_ctx = ShardCtx(mesh=None)   # constraints handled by outer jit

    def local_loss(params, batch):
        return train_loss(params, batch, cfg, inner_ctx, remat=remat)

    def inner(params, opt_state, err, key, batch_l):
        loss, g = jax.value_and_grad(local_loss)(params, batch_l)
        g, err = powersgd_psum(g, err, dp_axes, rank, key)
        loss = jax.lax.pmean(loss, dp_axes)
        params, opt_state, metrics = adamw_update(params, g, opt_state,
                                                  opt_cfg)
        metrics["loss"] = loss
        return params, opt_state, err, metrics

    batch_spec = {"tokens": P(dp_axes, None), "labels": P(dp_axes, None)}

    def step(params, opt_state, err, key, batch):
        return shard_map(
            inner, mesh=mesh,
            in_specs=(P(), P(), P(), P(), batch_spec),
            out_specs=(P(), P(), P(), P()),
            axis_names=set(dp_axes),   # manual over DP; 'model' stays auto
            check_vma=False,
        )(params, opt_state, err, key, batch)

    return step


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
