"""AdamW + schedule + clipping, written directly on pytrees (no optax here).

Supports ZeRO-1-style sharded optimizer states: the caller simply passes
opt-state shardings that place m/v on the DP axis; the update math is
elementwise so GSPMD inserts the reduce-scatter/all-gather pair.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    betas: Tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


class OptState(NamedTuple):
    step: jnp.ndarray           # () int32
    m: Any                      # pytree like params (f32)
    v: Any


def init_opt_state(params) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(step=jnp.zeros((), jnp.int32), m=zeros,
                    v=jax.tree.map(jnp.copy, zeros))


def lr_schedule(step: jnp.ndarray, cfg: OptConfig) -> jnp.ndarray:
    """Linear warmup + cosine decay to min_lr_ratio."""
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((s - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(s < cfg.warmup_steps, warm, cos)


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), gn


def adamw_update(params, grads, state: OptState, cfg: OptConfig):
    """Returns (new_params, new_state, metrics)."""
    grads, gn = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = lr_schedule(step, cfg)
    b1, b2 = cfg.betas
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        new_p = p.astype(jnp.float32) - lr * (
            delta + cfg.weight_decay * p.astype(jnp.float32))
        return new_p.astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, OptState(step, new_m, new_v), {"lr": lr, "grad_norm": gn}
