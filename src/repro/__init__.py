"""repro: GANQ (ICML 2025) — LUT-based non-uniform quantization on TPU.

Layers: core (the paper's algorithm), kernels (Pallas TPU), models (10-arch
zoo), sharding/train/serve/launch (distributed runtime), roofline (analysis).
"""
__version__ = "0.1.0"
