"""Synthetic data pipeline (offline container: no C4/WikiText).

`MarkovStream` generates a learnable corpus: a sparse order-1 Markov chain
with Zipf-weighted transitions. A model trained on it shows real perplexity
reduction, and quantization-induced ppl gaps behave like on natural text
(heavy-tailed token statistics) — this drives the Table-2-style benchmarks.

The pipeline is deterministic per (seed, step) — restart-safe: after a
checkpoint restore at step k, batch k+1 is identical to the run that never
failed (exactly how a production loader must behave).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Tuple

import numpy as np


@dataclasses.dataclass
class MarkovStream:
    vocab_size: int
    batch: int
    seq: int
    seed: int = 0
    branching: int = 8          # out-degree per state
    frontend: str = "tokens"
    d_model: int = 0            # for stub frontends (patches/frames)

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        v, b = self.vocab_size, self.branching
        self.next_tok = rng.integers(0, v, size=(v, b)).astype(np.int32)
        w = 1.0 / np.arange(1, b + 1) ** 1.2          # Zipf over branches
        self.next_p = (w / w.sum()).astype(np.float64)
        self._emb_rng = np.random.default_rng(self.seed + 1)

    def _walk(self, rng: np.random.Generator, n: int) -> np.ndarray:
        out = np.empty(n + 1, np.int32)
        out[0] = rng.integers(0, self.vocab_size)
        choices = rng.choice(self.branching, size=n, p=self.next_p)
        for i in range(n):
            out[i + 1] = self.next_tok[out[i], choices[i]]
        return out

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Deterministic batch for a given step (restart-safe)."""
        rng = np.random.default_rng((self.seed, step))
        toks = np.stack([self._walk(rng, self.seq) for _ in range(self.batch)])
        batch = {"tokens": toks[:, :-1].astype(np.int32),
                 "labels": toks[:, 1:].astype(np.int32)}
        if self.frontend == "patches":
            emb = rng.standard_normal(
                (self.batch, self.seq, self.d_model)).astype(np.float32)
            batch["embeds"] = emb
            batch["positions"] = np.tile(
                np.arange(self.seq, dtype=np.int32)[None, None],
                (3, self.batch, 1))
        elif self.frontend == "frames":
            batch["frames"] = rng.standard_normal(
                (self.batch, self.seq, self.d_model)).astype(np.float32)
        return batch

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1

    def entropy_floor(self) -> float:
        """Exact per-token entropy of the chain (nats) — the loss floor."""
        p = self.next_p
        return float(-(p * np.log(p)).sum())


def calibration_tokens(vocab: int, n_seq: int, seq: int,
                       seed: int = 123) -> np.ndarray:
    """Paper §4.1-style calibration sample (n_seq sequences of `seq` toks)."""
    ms = MarkovStream(vocab, n_seq, seq, seed=seed)
    return ms.batch_at(0)["tokens"]
