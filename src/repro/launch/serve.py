"""Serving launcher: GANQ-quantize a model and serve batched requests.

CPU demo (reduced config):
  PYTHONPATH=src python -m repro.launch.serve --arch deepseek-7b --reduced \\
      --bits 4 --requests 8

Mixed-precision policy (3-bit MLPs, 4-bit attention, fp-kept w_down):
  PYTHONPATH=src python -m repro.launch.serve --arch deepseek-7b --reduced \\
      --policy "mlp=3,attn=4" --requests 8

Automatic precision search (per-width sensitivity profile -> budgeted
per-layer allocation -> servable spec; the printed spec passed back via
--policy reproduces the run token-for-token):
  PYTHONPATH=src python -m repro.launch.serve --arch deepseek-7b --reduced \\
      --auto-policy "budget=3.0" --profile-out prof.json --requests 8

Paged KV cache (slot count decoupled from max_len; pool sized in pages):
  PYTHONPATH=src python -m repro.launch.serve --arch deepseek-7b --reduced \\
      --method none --kv-format paged --page-size 16 --requests 8

Chunked prefill on the token-budget step (a 2048-token arrival never
stalls in-flight decode for more than one step; one jit, no per-length
prefill compiles):
  PYTHONPATH=src python -m repro.launch.serve --arch deepseek-7b --reduced \\
      --method none --max-len 2112 --prompt-lens 32,2048,128 \\
      --prefill-chunk 64 --requests 6 --slots 2

Async SSE streaming server (POST /v1/generate streams tokens as SSE
frames, GET /v1/metrics reports TTFT/ITL percentiles + SLO goodput +
achieved-vs-peak MFU/HBM; Ctrl-C to stop):
  PYTHONPATH=src python -m repro.launch.serve --arch deepseek-7b --reduced \\
      --method none --serve-http 8777 --track --slo-ttft 2 --slo-itl 0.5

Load-adaptive draft precision (speculative 3-bit-prefix rounds only
while the queue is backed up; greedy tokens unchanged):
  PYTHONPATH=src python -m repro.launch.serve --arch deepseek-7b --reduced \\
      --bits 4 --speculate 3 --draft-bits 3 --adaptive-draft --rate 16

Production decode-step compile check (the paper's deployment on a pod):
  python -m repro.launch.serve --arch granite-3-8b --dry-run-only \\
      --bits 4 --kv8
"""
from __future__ import annotations

import argparse
import sys
import time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--bits", type=int, default=4, choices=[2, 3, 4])
    ap.add_argument("--method", default="ganq",
                    choices=["ganq", "gptq", "rtn", "none"])
    ap.add_argument("--policy", default=None,
                    help="per-layer precision spec, e.g. 'mlp=3,attn=4,"
                         "head=fp' or 'mlp=3@lut3_packed' (see "
                         "core.policy.parse_policy); default uniform --bits")
    ap.add_argument("--auto-policy", default=None, metavar="SPEC",
                    help="search a per-layer precision policy under a "
                         "bits/weight budget and serve it: 'budget=3.4"
                         "[,cost=bits|storage|bytes|measured]"
                         "[,cands=2+3+4][,fp=0][,kv=<fmt>][,draft=N]' "
                         "(core.bitsearch); prints the emitted spec, "
                         "which served via --policy reproduces this run "
                         "token-for-token")
    ap.add_argument("--profile", default=None, metavar="JSON",
                    help="warm-start --auto-policy from a saved "
                         "sensitivity profile (skips per-width PTQ "
                         "passes it already covers)")
    ap.add_argument("--profile-out", default=None, metavar="JSON",
                    help="save the sensitivity profile measured by "
                         "--auto-policy")
    ap.add_argument("--report-out", default=None, metavar="JSON",
                    help="write the per-layer LayerQuantReport dict of "
                         "the quantization pass (err, bits/weight, fmt, "
                         "method per layer) as JSON")
    ap.add_argument("--tokens-out", default=None, metavar="JSON",
                    help="write served greedy tokens per request as JSON "
                         "(closed-loop mode) for offline identity checks")
    ap.add_argument("--lut-backend", default="xla",
                    choices=["xla", "pallas"],
                    help="LUT-matmul backend (ExecPolicy threaded through "
                         "ShardCtx; no global state)")
    ap.add_argument("--autotune", action="store_true",
                    help="sweep LUT-mpGEMM tile sizes for every quantized "
                         "layer shape at the decode width before serving "
                         "(kernels.tune; cached on disk per shape/backend, "
                         "so later runs start tuned)")
    ap.add_argument("--kv8", action="store_true",
                    help="int8 KV cache (beyond-paper; alias for "
                         "--kv-format int8)")
    ap.add_argument("--kv-format", default=None,
                    choices=["full", "int8", "paged", "paged_int8"],
                    help="KV-cache layout (core.cache_formats registry); "
                         "overrides --kv8 and a policy's kv= rule")
    ap.add_argument("--page-size", type=int, default=64,
                    help="tokens per KV page (paged formats)")
    ap.add_argument("--kv-pages", type=int, default=0,
                    help="KV page-pool size per layer (paged formats); "
                         "0 = dense equivalent slots*ceil(max_len/page)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="page-granular prefix caching on the paged pool: "
                         "requests sharing a prompt prefix map the same "
                         "physical pages (refcounted, copy-on-write) and "
                         "admission skips straight past the cached run; "
                         "needs a paged --kv-format")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--max-len", type=int, default=128,
                    help="per-slot cache length (prompt + generation)")
    ap.add_argument("--prompt-lens", default=None,
                    help="comma-separated prompt lengths cycled over "
                         "requests (e.g. '32,2048,128'); default: random "
                         "8..24")
    ap.add_argument("--slots", type=int, default=4,
                    help="decode slots for continuous batching")
    ap.add_argument("--prefill-chunk", type=int, default=32,
                    help="prompt tokens admitted per token-budget step "
                         "(chunked prefill piggybacked on decode; 0 = "
                         "legacy whole-prompt prefill with per-length "
                         "jits and decode stalls)")
    ap.add_argument("--token-budget", type=int, default=0,
                    help="lanes per unified serving step (0 = slots + "
                         "prefill-chunk); one static shape bounds the "
                         "compile count regardless of prompt lengths")
    ap.add_argument("--speculate", type=int, default=0, metavar="K",
                    help="self-speculative decoding: draft K greedy tokens "
                         "per slot per round at --draft-bits prefix width, "
                         "verify all K+1 positions in one mixed step, roll "
                         "rejected cache writes back bitwise; greedy output "
                         "is token-identical to --speculate 0")
    ap.add_argument("--draft-bits", type=int, default=0, choices=[0, 2, 3],
                    help="draft prefix width: the draft pass streams only "
                         "the leading b bit-planes of each 4-bit nested "
                         "bitstream (quantization switches to the "
                         "lut4_nested layout); 0 = full-width drafts")
    ap.add_argument("--rate", type=float, default=0.0,
                    help="Poisson arrival rate (req/s); 0 = all at once")
    ap.add_argument("--serve-http", type=int, default=None, metavar="PORT",
                    help="start the asyncio SSE front end on PORT (0 = "
                         "ephemeral) instead of the closed-loop demo; "
                         "endpoints: POST /v1/generate (SSE stream), "
                         "GET /v1/metrics, GET /healthz")
    ap.add_argument("--host", default="127.0.0.1",
                    help="bind address for --serve-http")
    ap.add_argument("--track", action="store_true",
                    help="per-step MFU/HBM tracker: roofline HLO cost of "
                         "the serving jits vs measured step wall times, "
                         "reported as achieved-vs-peak percentages")
    ap.add_argument("--slo-ttft", type=float, default=2.0,
                    help="TTFT SLO seconds for the goodput report")
    ap.add_argument("--slo-itl", type=float, default=0.5,
                    help="max inter-token-latency SLO seconds")
    ap.add_argument("--adaptive-draft", action="store_true",
                    help="load-adaptive draft precision: run speculative "
                         "low-bit-prefix rounds only while queue/SLO "
                         "pressure is on (needs --speculate K)")
    ap.add_argument("--sse-queue-max", type=int, default=256,
                    help="per-request SSE event-queue bound: a client "
                         "this many events behind is disconnected and "
                         "its request cancelled (slot + pages freed)")
    ap.add_argument("--queue-cap", type=int, default=0,
                    help="arrived-queue depth before overload shedding "
                         "(503 on the front end, finish_reason='shed' "
                         "in the engine); 0 = unbounded. The adaptive "
                         "draft policy's thresholds sit below the cap: "
                         "precision degrades before admission does")
    ap.add_argument("--timeout", type=float, default=0.0,
                    help="default per-request wall-clock timeout seconds "
                         "(arrival -> finish_reason='timeout'); 0 = off")
    ap.add_argument("--chaos", type=int, default=None, metavar="SEED",
                    help="inject a deterministic fault schedule (step "
                         "faults, NaN logits, page quarantine, "
                         "stragglers, client cancels) seeded by SEED; "
                         "surviving requests' greedy tokens are bitwise "
                         "the fault-free run's")
    ap.add_argument("--chaos-rate", type=float, default=0.1,
                    help="per-step fault probability for --chaos")
    ap.add_argument("--dry-run-only", action="store_true")
    args = ap.parse_args(argv)

    if args.dry_run_only:
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
        from .cells import build_cell, lower_cell
        from .mesh import make_production_mesh
        mesh = make_production_mesh()
        cell = build_cell(args.arch, "decode_32k", mesh,
                          quantized_serve=args.method != "none",
                          bits=args.bits, policy_spec=args.policy)
        comp = lower_cell(cell, mesh).compile()
        ma = comp.memory_analysis()
        peak = (ma.argument_size_in_bytes + ma.output_size_in_bytes
                + ma.temp_size_in_bytes - ma.alias_size_in_bytes)
        print(f"decode step compiled OK; peak HBM/device {peak / 1e9:.2f} GB")
        return 0

    import dataclasses
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.configs import get_config, reduce_config
    from repro.core import QuantConfig, parse_policy
    from repro.data.synthetic import MarkovStream
    from repro.models import init_params
    from repro.models.quantized import model_storage_report, quantize_model_ptq
    from repro.serve.engine import GenRequest, ServeEngine
    from repro.sharding.context import LOCAL

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_config(cfg)
    if args.kv8:
        cfg = dataclasses.replace(cfg, kv_quant_bits=8)
    cfg = dataclasses.replace(cfg, kv_page_size=args.page_size,
                              kv_pages=args.kv_pages)
    ctx = LOCAL.with_lut_backend(args.lut_backend)
    params = init_params(jax.random.PRNGKey(0), cfg)
    data = MarkovStream(cfg.vocab_size, batch=4, seq=32, seed=0)
    qcfg = QuantConfig(bits=args.bits, iters=4, precondition="fixed")
    if args.auto_policy:
        if args.policy:
            ap.error("--auto-policy and --policy are mutually exclusive "
                     "(serve the emitted spec via --policy instead)")
        if args.method == "none":
            ap.error("--auto-policy needs a quantizing --method")
        from repro.core import (SensitivityProfile, parse_auto_spec,
                                profile_sensitivity, search_policy)
        auto = parse_auto_spec(args.auto_policy)
        warm = SensitivityProfile.load(args.profile) if args.profile else None
        calib = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}
        prof = profile_sensitivity(
            params, cfg, calib, widths=auto.widths or (2, 3, 4), qcfg=qcfg,
            method=args.method, ctx=ctx, include_fp=auto.include_fp,
            warm=warm, arch=args.arch)
        if args.profile_out:
            prof.save(args.profile_out)
            print(f"sensitivity profile saved to {args.profile_out}")
        res = search_policy(prof, auto.budget, cost=auto.cost,
                            widths=auto.widths, include_fp=auto.include_fp,
                            kv=auto.kv, draft=auto.draft)
        print(f"auto-policy: budget {auto.budget:g} b/w ({auto.cost}) -> "
              f"{res.bits_per_weight:.3f} code bits/weight "
              f"({res.storage_bits_per_weight:.2f} with codebooks), "
              f"summed layer err {res.total_err:.4f}")
        print(f"auto-policy spec: {res.spec}")
        args.policy = res.spec
    # parse the policy unconditionally: its kv= cache rule applies even to
    # fp serving (--method none); --draft-bits rides in as the reserved
    # draft= entry so quantization emits the nested bitstream layout
    pol_spec = args.policy
    if args.draft_bits and args.method != "none":
        assert args.bits == 4, "--draft-bits nests a 4-bit stream"
        entry = f"draft={args.draft_bits}"
        pol_spec = f"{pol_spec},{entry}" if pol_spec else entry
    policy = parse_policy(pol_spec, qcfg, args.method) \
        if pol_spec else None
    if args.method != "none":
        calib = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}
        params, report = quantize_model_ptq(
            params, cfg, calib, qcfg, args.method, policy=policy)
        rep = model_storage_report(params, report)
        pol_str = f" policy '{args.policy}'" if args.policy else ""
        print(f"quantized with {args.method} @{args.bits}-bit{pol_str}: "
              f"{rep['bits_per_weight']:.2f} bits/weight over "
              f"{rep['quantized_weights']} weights")
        if args.report_out:
            from repro.core import save_report
            save_report(report, args.report_out,
                        extra={"arch": args.arch, "method": args.method,
                               "policy": args.policy,
                               "bits_per_weight": rep["bits_per_weight"]})
            print(f"per-layer report written to {args.report_out}")
        if args.autotune:
            from repro.kernels.tune import cache_path, tune_model
            plans = tune_model(params, p=args.slots)
            for key, plan in sorted(plans.items()):
                print(f"  tuned {key}: ({plan.block_m}, {plan.block_k}, "
                      f"{plan.block_p}) {plan.us:.0f}us")
            print(f"tile plans cached at {cache_path()}")
    # cache-format precedence: explicit --kv-format > policy kv= rule >
    # --kv8 / config default — weight and cache layouts compose in one spec
    if policy is not None:
        cfg = policy.apply_kv_format(cfg)
    if args.kv_format:
        cfg = dataclasses.replace(cfg, kv_format=args.kv_format)
    adaptive = None
    if args.adaptive_draft:
        from repro.serve.metrics import AdaptiveDraftPolicy
        adaptive = AdaptiveDraftPolicy(queue_hi=2, queue_lo=0,
                                       wait_hi_s=args.slo_ttft / 2,
                                       wait_lo_s=args.slo_ttft / 8)
    engine = ServeEngine(params, cfg, ctx=ctx, max_len=args.max_len,
                         n_slots=args.slots,
                         prefill_chunk=args.prefill_chunk,
                         token_budget=args.token_budget,
                         spec_k=args.speculate,
                         draft_bits=args.draft_bits,
                         adaptive=adaptive,
                         prefix_cache=args.prefix_cache)
    if args.speculate and engine.spec_k != args.speculate:
        reason = engine.spec_fallback or "cache-width cap"
        print(f"speculation capped: spec_k {args.speculate} -> "
              f"{engine.spec_k} ({reason})")

    faults = None
    if args.chaos is not None:
        from repro.serve.faults import chaos_injector
        faults = chaos_injector(args.chaos, rate=args.chaos_rate,
                                paged=engine.paged)
        print(f"chaos injection on: seed {args.chaos}, "
              f"rate {args.chaos_rate}")
    queue_cap = args.queue_cap or None
    timeout_s = args.timeout or None

    if args.serve_http is not None:
        import asyncio
        import json
        from repro.serve.frontend import AsyncServeFrontend
        from repro.serve.metrics import SLO

        async def run_server():
            fe = AsyncServeFrontend(
                engine, host=args.host, port=args.serve_http,
                slo=SLO(ttft_s=args.slo_ttft, itl_s=args.slo_itl),
                track=args.track or None,
                sse_queue_max=args.sse_queue_max,
                queue_cap=queue_cap, timeout_s=timeout_s,
                faults=faults)
            async with fe:
                print(f"serving on http://{args.host}:{fe.port} — "
                      f"POST /v1/generate (SSE), GET /v1/metrics, "
                      f"GET /healthz; Ctrl-C to stop", flush=True)
                try:
                    while True:
                        await asyncio.sleep(3600)
                except asyncio.CancelledError:
                    pass
                finally:
                    print("final metrics:",
                          json.dumps(fe.metrics(), default=str)[:2000])

        try:
            asyncio.run(run_server())
        except KeyboardInterrupt:
            pass
        return 0
    # mixed-length traffic: continuous batching needs no length grouping,
    # and chunked admission needs no length bucketing either — prompts of
    # any mix of lengths ride the one fixed-shape token-budget step
    rng = np.random.default_rng(0)
    if args.prompt_lens:
        lens = [int(v) for v in args.prompt_lens.split(",")]
    else:
        lens = [int(rng.integers(8, 24)) for _ in range(args.requests)]
    assert max(lens) < args.max_len, (max(lens), args.max_len)
    long_seq = max(32, max(lens))
    data_long = MarkovStream(cfg.vocab_size, batch=1, seq=long_seq, seed=2)
    toks = data_long.batch_at(1)["tokens"]
    reqs = [GenRequest(prompt=toks[0, :lens[i % len(lens)]].tolist(),
                       max_new=args.max_new, timeout_s=timeout_s)
            for i in range(args.requests)]
    arrivals = None
    if args.rate > 0:
        arrivals = np.cumsum(rng.exponential(1.0 / args.rate,
                                             size=len(reqs))).tolist()
    t0 = time.time()
    results = engine.serve(reqs, arrival_times=arrivals,
                           track=args.track or None,
                           faults=faults, queue_cap=queue_cap)
    dt = time.time() - t0
    if args.tokens_out:
        import json
        with open(args.tokens_out, "w") as f:
            json.dump({"tokens": [list(map(int, r.tokens))
                                  for r in results],
                       "finish_reasons": [r.finish_reason
                                          for r in results]}, f)
        print(f"served tokens written to {args.tokens_out}")
    n_tok = sum(len(r.tokens) for r in results)
    st = engine.last_stats
    extra = ""
    if engine.paged:
        extra = (f", paged KV: {st['peak_pages_in_use']}/{st['n_pages']} "
                 f"pages x {st['page_size']} tok peak, "
                 f"{st['evictions']} evictions")
    if engine.spec_k:
        extra += (f", speculative: {st['spec_rounds']} rounds, "
                  f"accept rate {st['accept_rate']:.2f}, "
                  f"{st['accepted_tok_per_s']:.1f} accepted tok/s")
    gap = st.get("max_decode_gap_steps", 0)
    print(f"served {len(reqs)} requests / {n_tok} tokens in {dt:.2f}s "
          f"({n_tok / dt:.1f} tok/s wall, "
          f"{st['decode_tok_per_s']:.1f} decode tok/s, "
          f"{st.get('chunk_tokens', 0)} chunked prefill tokens, "
          f"max decode gap {gap} step(s), "
          f"{st['slot_reuses']} slot reuses, "
          f"{st['kv_cache_bytes'] / 1e6:.2f} MB KV{extra}, 1 CPU core)")
    if adaptive is not None:
        print(f"adaptive draft: {st['adaptive_rounds']} low-bit rounds, "
              f"{st['adaptive_flips']} policy flips")
    from repro.serve.metrics import prefix_cache_report
    pc = prefix_cache_report(st)
    if pc is not None:
        print(f"prefix cache: {pc['prefix_hits']} hits / "
              f"{pc['prefix_misses']} misses "
              f"({pc['hit_rate']:.0%} hit rate), "
              f"{pc['prefix_hit_tokens']} prompt tokens from cache "
              f"({pc['prefill_tokens_from_cache']:.0%} of prefill), "
              f"{pc['pages_shared']} pages shared, "
              f"{pc['cow_copies']} COW copies, "
              f"{pc['cache_evictions']} cache evictions, "
              f"{pc['cached_pages']} pages held")
    flt = st["faults"]
    if faults is not None or any(
            v for k, v in flt.items() if isinstance(v, int)):
        reasons = {}
        for r in results:
            reasons[r.finish_reason] = reasons.get(r.finish_reason, 0) + 1
        print(f"faults: {flt['step_retries']} step retries, "
              f"{flt['quarantines']} quarantines "
              f"({flt['requeues']} requeued, {flt['poisoned']} poisoned), "
              f"{flt['sheds']} shed, {flt['timeouts']} timeouts, "
              f"{flt['cancels']} cancels; finish reasons {reasons}"
              + (f"; injected {flt['injected']}" if faults is not None
                 else ""))
    from repro.serve.metrics import SLO, goodput_report, latency_summary
    lat = latency_summary(results)
    good = goodput_report(results,
                          SLO(ttft_s=args.slo_ttft, itl_s=args.slo_itl),
                          wall_s=st["wall_s"])
    print(f"latency: TTFT p50/p99 {lat['ttft_s']['p50']:.3f}/"
          f"{lat['ttft_s']['p99']:.3f}s, ITL p50/p99 "
          f"{lat['itl_s']['p50']:.3f}/{lat['itl_s']['p99']:.3f}s; "
          f"goodput {good['goodput_tok_per_s']:.1f} tok/s at "
          f"{good['slo_attainment']:.0%} SLO attainment")
    if args.track:
        hw = st["hw"]
        print(f"hw [{hw['device']}]: achieved "
              f"{hw['achieved_hbm_gbps']['p50']:.2f} GB/s HBM "
              f"({hw['hbm_util_pct']['p50']:.2f}% of peak), "
              f"{hw['achieved_tflops']['p50']:.4f} TFLOP/s "
              f"(MFU {hw['mfu_pct']['p50']:.3f}%)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
