"""Production meshes (per the assignment's MULTI-POD DRY-RUN contract).

`make_production_mesh` is a FUNCTION (importing this module never touches
jax device state). Single-pod: (16, 16) data x model = 256 chips. Multi-pod:
(2, 16, 16) pod x data x model = 512 chips; the `pod` axis is the slow
(DCN/ICI-bridge) dimension and carries only DP gradient traffic.
"""
from __future__ import annotations

from typing import Tuple

from repro.sharding.compat import make_mesh as _make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_test_mesh(shape: Tuple[int, ...] = (2, 4),
                   axes: Tuple[str, ...] = ("data", "model")):
    """Small mesh for CPU multi-device tests (8 fake devices)."""
    return _make_mesh(shape, axes)


def dp_axes_of(mesh) -> Tuple[str, ...]:
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


def dp_size_of(mesh) -> int:
    n = 1
    for a in dp_axes_of(mesh):
        n *= mesh.shape[a]
    return n
