import os
os.environ["XLA_FLAGS"] = (os.environ.get("_DRYRUN_EXTRA_XLA", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile EVERY (architecture x input shape) on
the 16x16 single-pod mesh AND the 2x16x16 multi-pod mesh, recording
memory_analysis / cost_analysis / the collective schedule per cell.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                # all cells
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-1b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --multi-pod --out results.jsonl

The FIRST two lines above set XLA_FLAGS before any jax import — jax locks
the device count on first init (assignment contract).
"""
import argparse
import json
import re
import sys
import time
import traceback

import jax

from repro.configs import list_archs, get_config
from .cells import SHAPES, applicable, build_cell, lower_cell
from .mesh import make_production_mesh

_COLL_RE = re.compile(
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)")


def collective_summary(hlo_text: str) -> dict:
    """Count collective ops by kind in compiled HLO (top-level; in-loop ops
    are scaled by trip count in roofline/analysis.py)."""
    counts = {}
    for m in _COLL_RE.finditer(hlo_text):
        kind = m.group(1)
        counts[kind] = counts.get(kind, 0) + 1
    return counts


def run_cell(arch: str, shape_name: str, mesh, multi_pod: bool,
             remat: str = "full", zero1: bool = False,
             quantized_serve: bool = False, bits: int = 4,
             policy_spec: str = None) -> dict:
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16"}
    cfg = get_config(arch)
    ok, why = applicable(cfg, shape_name)
    if not ok:
        rec.update(status="skipped", reason=why)
        return rec
    t0 = time.time()
    try:
        cell = build_cell(arch, shape_name, mesh, remat=remat, zero1=zero1,
                          quantized_serve=quantized_serve, bits=bits,
                          policy_spec=policy_spec)
        lowered = lower_cell(cell, mesh)
        compiled = lowered.compile()
        ma = compiled.memory_analysis()
        from repro.sharding.compat import cost_analysis
        ca = cost_analysis(compiled)
        hlo = compiled.as_text()
        rec.update(
            status="ok",
            compile_s=round(time.time() - t0, 2),
            kind=cell.kind,
            flops_per_device=ca.get("flops"),
            bytes_accessed_per_device=ca.get("bytes accessed"),
            argument_bytes=ma.argument_size_in_bytes,
            output_bytes=ma.output_size_in_bytes,
            temp_bytes=ma.temp_size_in_bytes,
            alias_bytes=ma.alias_size_in_bytes,
            peak_hbm_bytes=(ma.argument_size_in_bytes + ma.output_size_in_bytes
                            + ma.temp_size_in_bytes - ma.alias_size_in_bytes),
            collectives=collective_summary(hlo),
            meta=cell.meta,
        )
    except Exception as e:  # noqa: BLE001 — dry-run reports all failures
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:],
                   compile_s=round(time.time() - t0, 2))
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="single arch (default: all)")
    ap.add_argument("--shape", default=None, choices=list(SHAPES),
                    help="single shape (default: all)")
    ap.add_argument("--multi-pod", action="store_true",
                    help="2x16x16 mesh instead of 16x16")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--remat", default="full",
                    choices=["none", "full", "dots"])
    ap.add_argument("--zero1", action="store_true")
    ap.add_argument("--quantized-serve", action="store_true",
                    help="lower prefill/decode cells on LUT-quantized "
                         "weight containers (WeightFormat registry)")
    ap.add_argument("--bits", type=int, default=4,
                    help="bit width for --quantized-serve")
    ap.add_argument("--policy", default=None,
                    help="mixed-precision spec for --quantized-serve "
                         "(core.policy.parse_policy syntax)")
    ap.add_argument("--auto-policy", default=None, metavar="SPEC",
                    help="search a precision policy from a saved "
                         "sensitivity profile (needs --profile) and "
                         "dry-run the emitted spec: 'budget=3.4[,cost=..."
                         "][,cands=2+3+4][,fp=0][,kv=..][,draft=N]'; "
                         "implies --quantized-serve")
    ap.add_argument("--profile", default=None, metavar="JSON",
                    help="sensitivity profile for --auto-policy (written "
                         "by serve.py --profile-out); the search runs "
                         "offline, no weights or calibration needed")
    ap.add_argument("--out", default=None, help="append JSONL here")
    args = ap.parse_args(argv)

    if args.auto_policy:
        if not args.profile:
            ap.error("--auto-policy needs --profile (saved sensitivity "
                     "profile; dry-run has no weights to measure one)")
        if args.policy:
            ap.error("--auto-policy and --policy are mutually exclusive")
        from repro.core import (SensitivityProfile, parse_auto_spec,
                                search_policy)
        auto = parse_auto_spec(args.auto_policy)
        prof = SensitivityProfile.load(args.profile)
        if args.arch and prof.arch and prof.arch != args.arch:
            print(f"warning: profile measured on {prof.arch!r}, "
                  f"dry-running {args.arch!r}", file=sys.stderr)
        res = search_policy(prof, auto.budget, cost=auto.cost,
                            widths=auto.widths, include_fp=auto.include_fp,
                            kv=auto.kv, draft=auto.draft)
        print(f"auto-policy spec: {res.spec}", file=sys.stderr)
        args.policy = res.spec
        args.quantized_serve = True

    archs = [args.arch] if args.arch else list_archs()
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    n_fail = 0
    for multi_pod in meshes:
        mesh = make_production_mesh(multi_pod=multi_pod)
        for arch in archs:
            for shape_name in shapes:
                rec = run_cell(arch, shape_name, mesh, multi_pod,
                               remat=args.remat, zero1=args.zero1,
                               quantized_serve=args.quantized_serve,
                               bits=args.bits, policy_spec=args.policy)
                line = json.dumps(rec)
                print(line, flush=True)
                if args.out:
                    with open(args.out, "a") as f:
                        f.write(line + "\n")
                if rec["status"] == "error":
                    n_fail += 1
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
