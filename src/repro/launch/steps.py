"""Step functions (train / prefill / serve) + their sharding specs.

These are the functions the multi-pod dry-run lowers and compiles, and the
same functions launch/train.py and launch/serve.py run for real.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import decode_step, train_loss
from repro.models.model import (_dtype, _embed, _hidden, _logits_head,
                                init_serve_cache, abstract_params)
from repro.models import whisper as W
from repro.models.transformer import init_stack_cache
from repro.sharding.context import ShardCtx
from repro.sharding.partition import param_shardings
from repro.train.optimizer import OptConfig, OptState, adamw_update, \
    init_opt_state
from .mesh import dp_axes_of


def make_ctx(mesh: Optional[Mesh], cfg: ModelConfig,
             ep: Optional[bool] = None) -> ShardCtx:
    if mesh is None:
        return ShardCtx()
    return ShardCtx(mesh=mesh, dp_axes=dp_axes_of(mesh), tp_axis="model",
                    ep=(cfg.n_experts > 0) if ep is None else ep)


# ------------------------------------------------------------------- steps

def make_train_step(cfg: ModelConfig, ctx: ShardCtx, opt_cfg: OptConfig,
                    remat: str = "full", ce_chunk: int = 512,
                    accum: int = 1):
    """accum > 1: microbatch gradient accumulation (scan over the batch dim)
    — divides activation memory by `accum` at the cost of re-streaming the
    weights per microbatch."""
    def train_step(params, opt_state: OptState, batch):
        if accum == 1:
            loss, grads = jax.value_and_grad(train_loss)(
                params, batch, cfg, ctx, remat=remat, ce_chunk=ce_chunk)
        else:
            def micro(carry, mb):
                acc_l, acc_g = carry
                l, g = jax.value_and_grad(train_loss)(
                    params, mb, cfg, ctx, remat=remat, ce_chunk=ce_chunk)
                return (acc_l + l, jax.tree.map(jnp.add, acc_g, g)), None
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def split_mb(key_path, x):
                # positions are (3, B, S); everything else is batch-major
                name = str(key_path[-1].key) if key_path else ""
                if name == "positions":
                    r = x.reshape(3, accum, -1, *x.shape[2:])
                    return jnp.moveaxis(r, 1, 0)
                return x.reshape(accum, -1, *x.shape[1:])
            mbs = jax.tree_util.tree_map_with_path(split_mb, batch)
            (loss, grads), _ = jax.lax.scan(micro, (0.0, zeros), mbs)
            loss = loss / accum
            grads = jax.tree.map(lambda g: g / accum, grads)
        params, opt_state, metrics = adamw_update(params, grads, opt_state,
                                                  opt_cfg)
        metrics["loss"] = loss
        return params, opt_state, metrics
    return train_step


def make_serve_step(cfg: ModelConfig, ctx: ShardCtx):
    def serve_step(params, cache, tokens, pos):
        return decode_step(params, cache, tokens, pos, cfg, ctx)
    return serve_step


def make_prefill_step(cfg: ModelConfig, ctx: ShardCtx,
                      chunk: Optional[int] = 2048):
    """Prompt pass: last-token logits + per-layer states (unit-stacked)."""
    from repro.models.transformer import stack_apply
    from repro.models.common import apply_norm

    def prefill_step(params, batch):
        cd = _dtype(cfg.compute_dtype)
        if cfg.is_encoder_decoder:
            enc_out = W.encode(params["stacks"], batch["frames"].astype(cd),
                               cfg, ctx, None, chunk)
            tok_emb = _embed(params, batch["tokens"], cfg, cd)
            h = W.decode_train(params["stacks"], tok_emb, enc_out, cfg, ctx,
                               None, chunk)
            logits = _logits_head(params, h[:, -1, :], cfg, ctx)
            return logits, enc_out
        if cfg.frontend == "patches":
            x = batch["embeds"].astype(cd)
            positions = batch["positions"]
        else:
            x = _embed(params, batch["tokens"], cfg, cd)
            b, s = batch["tokens"].shape
            positions = jnp.broadcast_to(
                jnp.arange(s, dtype=jnp.int32)[None], (b, s))
            if cfg.mrope_sections:
                positions = jnp.broadcast_to(positions[None], (3, b, s))
        x = ctx.constrain(x, "dp", None, None)
        x, _aux, states = stack_apply(params["stack"], x, positions, cfg, ctx,
                                      None, chunk, collect_state=True)
        x = apply_norm(params["final_ln"], x, cfg.norm, cfg.norm_eps)
        logits = _logits_head(params, x[:, -1, :], cfg, ctx)
        return logits, states
    return prefill_step


# --------------------------------------------------------------- input specs

def batch_struct(cfg: ModelConfig, batch: int, seq: int) -> Dict:
    """ShapeDtypeStruct stand-ins for a train/prefill batch."""
    i32 = jnp.int32
    bf16 = jnp.bfloat16
    if cfg.frontend == "patches":
        return {"embeds": jax.ShapeDtypeStruct((batch, seq, cfg.d_model), bf16),
                "positions": jax.ShapeDtypeStruct((3, batch, seq), i32),
                "labels": jax.ShapeDtypeStruct((batch, seq), i32)}
    if cfg.frontend == "frames":
        return {"frames": jax.ShapeDtypeStruct((batch, seq, cfg.d_model), bf16),
                "tokens": jax.ShapeDtypeStruct((batch, seq), i32),
                "labels": jax.ShapeDtypeStruct((batch, seq), i32)}
    return {"tokens": jax.ShapeDtypeStruct((batch, seq), i32),
            "labels": jax.ShapeDtypeStruct((batch, seq), i32)}


def batch_shardings(cfg: ModelConfig, mesh: Mesh) -> Dict:
    dp = dp_axes_of(mesh)
    ns = lambda *spec: NamedSharding(mesh, P(*spec))
    if cfg.frontend == "patches":
        return {"embeds": ns(dp, None, None), "positions": ns(None, dp, None),
                "labels": ns(dp, None)}
    if cfg.frontend == "frames":
        return {"frames": ns(dp, None, None), "tokens": ns(dp, None),
                "labels": ns(dp, None)}
    return {"tokens": ns(dp, None), "labels": ns(dp, None)}


def abstract_cache(cfg: ModelConfig, batch: int, cache_len: int):
    cd = _dtype(cfg.compute_dtype)
    if cfg.is_encoder_decoder:
        enc = jax.ShapeDtypeStruct((batch, cache_len, cfg.d_model), cd)
        params = abstract_params(cfg)
        return jax.eval_shape(
            lambda p, e: init_serve_cache(
                p, {"frames": e}, batch, cache_len, cfg),
            params, enc)
    return jax.eval_shape(
        lambda: init_stack_cache(batch, cache_len, cfg, cd))


def cache_shardings(cache_sds, cfg: ModelConfig, mesh: Mesh, batch: int):
    """Sharding rules for serve caches (DESIGN.md §4): each cache entry's
    `CacheFormat` owns its leaf layout — the per-name rules live on the
    formats (`core.cache_formats`), `sharding.partition.cache_specs` maps
    them over the tree; this wraps the specs in NamedShardings."""
    from repro.sharding.partition import cache_specs
    specs = cache_specs(cache_sds, mesh, dp_axes_of(mesh), tp_axis="model")
    return jax.tree.map(lambda spec: NamedSharding(mesh, spec), specs,
                        is_leaf=lambda x: isinstance(x, P))


def opt_state_struct(params_sds) -> OptState:
    return jax.eval_shape(init_opt_state, params_sds)


def opt_state_shardings(params_sds, mesh: Mesh, zero1: bool = False):
    """m/v shard like their parameters; ZeRO-1 additionally shards them over
    the DP axis dim 0 when divisible (optimizer-state partitioning)."""
    base = param_shardings(params_sds, mesh)
    if zero1:
        dp = dp_axes_of(mesh)
        dp_size = 1
        for a in dp:
            dp_size *= mesh.shape[a]

        def shard_over_dp(sharding, leaf):
            """ZeRO-1: place m/v on the DP axis along the first unsharded
            dim it divides (optimizer math is elementwise, so any dim works;
            GSPMD turns the grad all-reduce into reduce-scatter+all-gather)."""
            spec = list(sharding.spec) + [None] * (len(leaf.shape)
                                                   - len(sharding.spec))
            for i, dim in enumerate(leaf.shape):
                if spec[i] is None and dim % dp_size == 0:
                    spec[i] = dp
                    return NamedSharding(mesh, P(*spec))
            return sharding
        mv = jax.tree.map(shard_over_dp, base, params_sds)
    else:
        mv = base
    return OptState(step=NamedSharding(mesh, P()), m=mv,
                    v=jax.tree.map(lambda x: x, mv))
