"""Dry-run cells: (architecture x input shape) -> lowerable jit closure.

The 4 assigned shapes (LM-family):
  train_4k    seq 4096,   global_batch 256   -> train_step
  prefill_32k seq 32768,  global_batch 32    -> prefill_step
  decode_32k  seq 32768,  global_batch 128   -> serve_step (1 token, KV 32k)
  long_500k   seq 524288, global_batch 1     -> serve_step (sub-quadratic only)

Applicability (DESIGN.md §6): long_500k runs only for subquadratic archs
(rwkv6, recurrentgemma, gemma3); pure full-attention archs skip it.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.configs.base import ModelConfig
from repro.models.model import abstract_params
from repro.sharding.partition import param_shardings
from repro.sharding.compat import set_mesh
from repro.train.optimizer import OptConfig
from . import steps
from .mesh import dp_axes_of

SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}


def applicable(cfg: ModelConfig, shape_name: str) -> Tuple[bool, str]:
    if shape_name == "long_500k" and not cfg.subquadratic:
        return False, ("pure full-attention arch: 500k KV cache has no "
                       "sub-quadratic path (DESIGN.md §6)")
    return True, ""


@dataclasses.dataclass
class Cell:
    arch: str
    shape_name: str
    kind: str
    fn: object               # callable to jit
    args: tuple              # ShapeDtypeStruct pytrees
    in_shardings: tuple
    out_shardings: object    # or None for auto
    donate_argnums: tuple = ()
    meta: Dict = dataclasses.field(default_factory=dict)


def build_cell(arch: str, shape_name: str, mesh: Mesh,
               remat: str = "full", zero1: bool = False,
               quantized_serve: bool = False, bits: int = 4,
               policy_spec: str = None,
               ce_chunk: int = 512, accum: int = 1) -> Cell:
    cfg = get_config(arch)
    shp = SHAPES[shape_name]
    ok, why = applicable(cfg, shape_name)
    if not ok:
        raise ValueError(f"{arch} x {shape_name} skipped: {why}")
    if shp["kind"] in ("prefill", "decode"):
        # serving always runs bf16 master weights (+ optional GANQ LUT)
        cfg = dataclasses.replace(cfg, param_dtype="bfloat16")
    ctx = steps.make_ctx(mesh, cfg)
    params_sds = abstract_params(cfg)
    if quantized_serve and shp["kind"] in ("prefill", "decode"):
        from repro.models.quantized import abstract_quantize
        policy = None
        if policy_spec:
            from repro.core import QuantConfig, parse_policy
            from repro.core.formats import packed_linear_fmt
            policy = parse_policy(policy_spec, QuantConfig(bits=bits),
                                  fmt=packed_linear_fmt(bits))
        params_sds = abstract_quantize(params_sds, cfg, bits=bits,
                                       policy=policy)
    p_shard = param_shardings(params_sds, mesh)
    seq, batch = shp["seq"], shp["batch"]

    if shp["kind"] == "train":
        opt_cfg = OptConfig()
        fn = steps.make_train_step(cfg, ctx, opt_cfg, remat=remat,
                                   ce_chunk=ce_chunk, accum=accum)
        batch_sds = steps.batch_struct(cfg, batch, seq)
        b_shard = steps.batch_shardings(cfg, mesh)
        opt_sds = steps.opt_state_struct(params_sds)
        o_shard = steps.opt_state_shardings(params_sds, mesh, zero1=zero1)
        return Cell(arch, shape_name, "train", fn,
                    (params_sds, opt_sds, batch_sds),
                    (p_shard, o_shard, b_shard),
                    (p_shard, o_shard, None),
                    donate_argnums=(0, 1),
                    meta={"tokens": batch * seq, "remat": remat,
                          "zero1": zero1, "accum": accum})

    if shp["kind"] == "prefill":
        fn = steps.make_prefill_step(cfg, ctx)
        batch_sds = steps.batch_struct(cfg, batch, seq)
        b_shard = steps.batch_shardings(cfg, mesh)
        return Cell(arch, shape_name, "prefill", fn,
                    (params_sds, batch_sds), (p_shard, b_shard), None,
                    meta={"tokens": batch * seq})

    # decode
    fn = steps.make_serve_step(cfg, ctx)
    cache_sds = steps.abstract_cache(cfg, batch, seq)
    c_shard = steps.cache_shardings(cache_sds, cfg, mesh, batch)
    dp = dp_axes_of(mesh)
    tok_spec = NamedSharding(mesh, P(dp) if batch > 1 else P())
    tok_sds = jax.ShapeDtypeStruct((batch,), jnp.int32)
    pos_sds = jax.ShapeDtypeStruct((batch,), jnp.int32)
    return Cell(arch, shape_name, "decode", fn,
                (params_sds, cache_sds, tok_sds, pos_sds),
                (p_shard, c_shard, tok_spec, tok_spec), None,
                donate_argnums=(1,),
                meta={"tokens": batch, "cache_len": seq})


def lower_cell(cell: Cell, mesh: Mesh):
    with set_mesh(mesh):
        jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                         out_shardings=cell.out_shardings,
                         donate_argnums=cell.donate_argnums)
        return jitted.lower(*cell.args)
