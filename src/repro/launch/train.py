"""Training launcher.

Local smoke (CPU, reduced config):
  PYTHONPATH=src python -m repro.launch.train --arch deepseek-7b --reduced \\
      --steps 50 --batch 8 --seq 64

Production pod (TPU; sharding/mesh identical to the dry-run):
  python -m repro.launch.train --arch qwen3-14b --mesh 32x8 --zero1 \\
      --accum 8 --steps 10000 --ckpt-dir gs://...

On this CPU container the production path is exercised via
`--dry-run-only`, which lowers+compiles the exact step and prints the
memory/cost analyses (the multi-pod contract lives in launch/dryrun.py).
"""
from __future__ import annotations

import argparse
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="reduced same-family config (CPU smoke)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--remat", default="none",
                    choices=["none", "full", "dots"])
    ap.add_argument("--zero1", action="store_true")
    ap.add_argument("--mesh", default=None, help="e.g. 16x16 / 32x8")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--dry-run-only", action="store_true")
    args = ap.parse_args(argv)

    if args.dry_run_only:
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
        from repro.sharding import compat
        from repro.sharding.compat import make_mesh
        from .cells import build_cell, lower_cell
        dims = tuple(int(x) for x in (args.mesh or "16x16").split("x"))
        mesh = make_mesh(dims, ("data", "model"))
        cell = build_cell(args.arch, "train_4k", mesh, remat=args.remat,
                          zero1=args.zero1, accum=args.accum)
        comp = lower_cell(cell, mesh).compile()
        ma = comp.memory_analysis()
        peak = (ma.argument_size_in_bytes + ma.output_size_in_bytes
                + ma.temp_size_in_bytes - ma.alias_size_in_bytes)
        print(f"compiled OK; peak HBM/device {peak / 1e9:.2f} GB; "
              f"flops/device "
              f"{compat.cost_analysis(comp).get('flops', 0.0):.3e}")
        return 0

    from repro.configs import get_config, reduce_config
    from repro.data.synthetic import MarkovStream
    from repro.train.loop import Trainer, TrainerConfig
    from repro.train.optimizer import OptConfig

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_config(cfg)
    data = MarkovStream(cfg.vocab_size, batch=args.batch, seq=args.seq,
                        seed=0, frontend=cfg.frontend, d_model=cfg.d_model)
    tcfg = TrainerConfig(steps=args.steps, ckpt_every=args.ckpt_every,
                         ckpt_dir=args.ckpt_dir, accum=args.accum,
                         remat=args.remat, log_every=10)
    trainer = Trainer(cfg, data, tcfg,
                      opt_cfg=OptConfig(lr=args.lr,
                                        warmup_steps=max(args.steps // 10, 1),
                                        total_steps=args.steps))
    res = trainer.run()
    for m in trainer.metrics_log:
        print(f"step {m['step']:6d}  loss {m['loss']:.4f}  "
              f"{m['sec'] * 1e3:.1f} ms")
    print(f"done: loss {res['first_loss']:.3f} -> {res['final_loss']:.3f} "
          f"(resumed from {res['resumed_from']})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
