"""whisper-medium [audio] — (arXiv:2212.04356). Enc-dec; conv frontend is a
STUB (input_specs provides precomputed frame embeddings).
24L(+24 enc) d_model=1024 16H (MHA kv=16) d_ff=4096 vocab=51865."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium", family="audio",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab_size=51865,
    is_encoder_decoder=True, n_encoder_layers=24, frontend="frames",
    layer_pattern=("attn",), act="gelu", norm="layernorm",
    tie_embeddings=True, norm_eps=1e-5,
)
