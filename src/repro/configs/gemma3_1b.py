"""gemma3-1b [dense/hybrid-attention] — (hf:google/gemma-3-1b-pt).
26L d_model=1152 4H (GQA kv=1) d_ff=6912 vocab=262144; 5 local : 1 global,
window 1024, head_dim 256 (official gemma3 value; q_dim != d_model).
Runs long_500k: local layers are O(window); global KV is sequence-sharded."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b", family="dense",
    n_layers=26, d_model=1152, n_heads=4, n_kv_heads=1, head_dim=256,
    d_ff=6912, vocab_size=262144,
    layer_pattern=("local", "local", "local", "local", "local", "attn"),
    sliding_window=1024, rope_theta=1e6, tie_embeddings=True,
    act="gelu", subquadratic=True,
)
