"""qwen2-vl-7b [vlm] — (arXiv:2409.12191). Backbone only; the vision
frontend is a STUB (input_specs provides precomputed patch embeddings).
28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064, M-RoPE (16,24,24)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b", family="vlm",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4,
    d_ff=18944, vocab_size=152064,
    mrope_sections=(16, 24, 24), frontend="patches",
    layer_pattern=("attn",), act="silu",
)
