"""Model configuration schema covering all 10 assigned architectures."""
from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture description (exact assigned specs live in configs/<id>.py).

    layer_pattern is cycled over n_layers and names each block kind:
      'attn'  — full (global) causal attention + MLP/MoE
      'local' — sliding-window attention + MLP
      'rwkv'  — RWKV-6 time-mix + channel-mix (attention-free)
      'rglru' — RG-LRU recurrent block + MLP (Griffin/RecurrentGemma)
    """

    name: str
    family: str                     # dense | moe | vlm | ssm | audio | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // n_heads
    layer_pattern: Tuple[str, ...] = ("attn",)
    sliding_window: int = 4096
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # attention details
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    mrope_sections: Tuple[int, ...] = ()      # qwen2-vl M-RoPE half-dim split
    # embeddings / head
    tie_embeddings: bool = False
    # encoder-decoder (whisper)
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    # modality frontend: 'tokens' (embedding table) | 'frames' | 'patches'
    # (frames/patches are STUBS: input_specs provides precomputed embeddings)
    frontend: str = "tokens"
    # RWKV-6
    rwkv_head_size: int = 64
    # RG-LRU
    lru_width: int = 0              # 0 -> d_model
    conv_width: int = 4
    # norms / activations
    act: str = "silu"               # silu | gelu
    norm: str = "rmsnorm"           # rmsnorm | layernorm
    norm_eps: float = 1e-6
    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    # serve-time KV-cache quantization (0 = off, 8 = int8 per-token/head
    # scales) — beyond-paper extension of weight-only quantization to the
    # decode-dominant KV traffic (EXPERIMENTS.md §Perf cell A)
    kv_quant_bits: int = 0
    # serve-time KV-cache layout: a `core.cache_formats.CacheFormat` name
    # ('full' / 'int8' / 'paged' / 'paged_int8'); "" resolves from
    # kv_quant_bits ('int8' when 8, else 'full')
    kv_format: str = ""
    # paged-cache pool geometry (used when kv_format is a paged format):
    # tokens per page, and total pool pages per layer (0 = the dense
    # equivalent n_slots * ceil(max_len / page_size) — no HBM saving, but
    # always sufficient)
    kv_page_size: int = 64
    kv_pages: int = 0

    # whether GANQ's long_500k cell applies (sub-quadratic decode path)
    subquadratic: bool = False

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // max(self.n_heads, 1))
        if self.lru_width == 0:
            object.__setattr__(self, "lru_width", self.d_model)

    @property
    def layer_kinds(self) -> Tuple[str, ...]:
        p = self.layer_pattern
        return tuple(p[i % len(p)] for i in range(self.n_layers))

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def param_count(self) -> int:
        """Total parameter count (embeddings included)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        total = v * d                                   # embedding
        if not self.tie_embeddings:
            total += v * d                              # head
        for kind in self.layer_kinds:
            if kind in ("attn", "local"):
                attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
                if self.n_experts:
                    ffn = d * self.n_experts + self.n_experts * 3 * d * f
                else:
                    ffn = 3 * d * f
                total += attn + ffn + 2 * d
            elif kind == "rwkv":
                total += 5 * d * d + d * d              # r,k,v,g,o + lora-ish
                total += 2 * d * f + d * d + 2 * d      # channel mix
            elif kind == "rglru":
                r = self.lru_width
                total += 2 * d * r + r * d              # in/gate/out projections
                total += 2 * r * r                      # input & recurrence gates
                total += 3 * d * f + 2 * d              # MLP
        if self.is_encoder_decoder:
            for _ in range(self.n_encoder_layers):
                total += 2 * (d * self.q_dim + 2 * d * self.kv_dim
                              + self.q_dim * d) + 2 * d * f + 2 * d
        return total

    def active_param_count(self) -> int:
        """Activated params per token (MoE: top_k of n_experts)."""
        if not self.n_experts:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        total = self.param_count()
        dense_ffn = self.n_experts * 3 * d * f
        active_ffn = self.top_k * 3 * d * f
        n_moe = sum(1 for k in self.layer_kinds if k in ("attn", "local"))
        return total - n_moe * (dense_ffn - active_ffn)
