"""Architecture registry: the 10 assigned configs + reduced smoke variants."""
from __future__ import annotations

import dataclasses
from typing import Dict, List

from .base import ModelConfig

from .moonshot_v1_16b_a3b import CONFIG as _moonshot
from .qwen3_moe_30b_a3b import CONFIG as _qwen3moe
from .granite_3_8b import CONFIG as _granite
from .gemma3_1b import CONFIG as _gemma3
from .deepseek_7b import CONFIG as _deepseek
from .qwen3_14b import CONFIG as _qwen3
from .qwen2_vl_7b import CONFIG as _qwen2vl
from .rwkv6_7b import CONFIG as _rwkv6
from .whisper_medium import CONFIG as _whisper
from .recurrentgemma_2b import CONFIG as _rgemma

_REGISTRY: Dict[str, ModelConfig] = {c.name: c for c in [
    _moonshot, _qwen3moe, _granite, _gemma3, _deepseek, _qwen3, _qwen2vl,
    _rwkv6, _whisper, _rgemma,
]}


def list_archs() -> List[str]:
    return sorted(_REGISTRY)


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; available: {list_archs()}")
    return _REGISTRY[name]


def reduce_config(cfg: ModelConfig, seq_budget: int = 64) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests: small width/depth/
    vocab/experts, same layer pattern (tail layers included)."""
    p = len(cfg.layer_pattern)
    n_layers = min(cfg.n_layers, 2 * p + (1 if cfg.n_layers % p else 0))
    n_heads = min(4, cfg.n_heads)
    n_kv = max(1, min(cfg.n_kv_heads, n_heads))
    head_dim = 16
    d_model = 64
    sections = ()
    if cfg.mrope_sections:
        sections = (4, 2, 2)  # sums to head_dim // 2
    changes = dict(
        n_layers=n_layers, d_model=d_model, n_heads=n_heads,
        n_kv_heads=n_kv, head_dim=head_dim, d_ff=128,
        vocab_size=512, sliding_window=min(cfg.sliding_window, 16),
        lru_width=d_model, rwkv_head_size=16,
        mrope_sections=sections,
        n_experts=min(8, cfg.n_experts) if cfg.n_experts else 0,
        top_k=min(2, cfg.top_k) if cfg.top_k else 0,
        n_encoder_layers=min(2, cfg.n_encoder_layers),
        # CPU test numerics: f32 compute for crisp parity asserts; ample MoE
        # capacity so decode-vs-forward parity is not broken by token drops
        compute_dtype="float32",
        capacity_factor=8.0,
    )
    if cfg.family == "ssm":
        changes["n_heads"] = d_model // 16
        changes["n_kv_heads"] = d_model // 16
    return dataclasses.replace(cfg, **changes)
