"""qwen3-14b [dense] — (hf:Qwen/Qwen3-14B family).
40L d_model=5120 40H (GQA kv=8) d_ff=17408 vocab=151936, qk_norm."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-14b", family="dense",
    n_layers=40, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=17408, vocab_size=151936, qk_norm=True,
    layer_pattern=("attn",), act="silu", rope_theta=1e6,
)
