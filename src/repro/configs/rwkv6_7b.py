"""rwkv6-7b [ssm] — RWKV-6 Finch (arXiv:2404.05892). Attention-free.
32L d_model=4096 d_ff=14336 vocab=65536, head_size 64.
Runs long_500k: O(1) state per token."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b", family="ssm",
    n_layers=32, d_model=4096, n_heads=64, n_kv_heads=64,  # heads = d/head_size
    d_ff=14336, vocab_size=65536, rwkv_head_size=64,
    layer_pattern=("rwkv",), act="silu", subquadratic=True,
)
