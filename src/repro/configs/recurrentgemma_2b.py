"""recurrentgemma-2b [hybrid] — Griffin (arXiv:2402.19427).
26L d_model=2560 10H (GQA kv=1) d_ff=7680 vocab=256000; RG-LRU : local attn
= 2 : 1, window 2048, head_dim 256 (official), lru_width 2560.
Runs long_500k: recurrence is O(1); local attn is O(window)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1, head_dim=256,
    d_ff=7680, vocab_size=256000, lru_width=2560, conv_width=4,
    layer_pattern=("rglru", "rglru", "local"),
    sliding_window=2048, tie_embeddings=True,
    act="gelu", subquadratic=True,
)
