import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Roofline sweep driver: component-accounted three-term roofline for every
(arch x shape) on the single-pod 16x16 mesh (per the assignment, the
roofline table is single-pod; the multi-pod pass in launch/dryrun.py proves
the pod axis shards).

  PYTHONPATH=src python -m repro.roofline.run --out results/roofline.jsonl
  PYTHONPATH=src python -m repro.roofline.run --arch gemma3-1b --shape train_4k
"""
import argparse
import json
import sys
import time
import traceback

from repro.configs import get_config, list_archs
from repro.launch.cells import SHAPES, applicable
from repro.launch.mesh import make_production_mesh


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--quantized", action="store_true",
                    help="GANQ LUT-quantized serving variant (decode cells)")
    ap.add_argument("--bits", type=int, default=4)
    ap.add_argument("--remat", default="full", choices=["none", "full", "dots"])
    ap.add_argument("--variant", default=None)
    ap.add_argument("--mesh-shape", default=None,
                    help="override logical mesh, e.g. 64x4 (256 chips)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    from repro.roofline.analysis import cell_roofline

    if args.mesh_shape:
        from repro.sharding.compat import make_mesh
        dims = tuple(int(x) for x in args.mesh_shape.split("x"))
        assert len(dims) == 2 and dims[0] * dims[1] == 256, dims
        mesh = make_mesh(dims, ("data", "model"))
        mesh_name = args.mesh_shape
    else:
        mesh = make_production_mesh(multi_pod=False)
        mesh_name = "16x16"
    archs = [args.arch] if args.arch else list_archs()
    shapes = [args.shape] if args.shape else list(SHAPES)
    variant = args.variant or ("q%d-lut" % args.bits if args.quantized
                               else "baseline")
    n_fail = 0
    for arch in archs:
        for shape in shapes:
            cfg = get_config(arch)
            ok, why = applicable(cfg, shape)
            rec = {"arch": arch, "shape": shape, "variant": variant}
            if not ok:
                rec.update(status="skipped", reason=why)
            else:
                t0 = time.time()
                try:
                    r = cell_roofline(arch, shape, mesh, mesh_name,
                                      variant=variant,
                                      quantized=args.quantized,
                                      bits=args.bits, remat=args.remat)
                    rec.update(status="ok", analyze_s=round(time.time() - t0, 1),
                               **r.to_dict())
                except Exception as e:  # noqa: BLE001
                    rec.update(status="error",
                               error=f"{type(e).__name__}: {e}",
                               traceback=traceback.format_exc()[-1500:])
                    n_fail += 1
            print(json.dumps({k: v for k, v in rec.items()
                              if k != "per_layer"}), flush=True)
            if args.out:
                with open(args.out, "a") as f:
                    f.write(json.dumps(rec) + "\n")
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
