"""Three-term roofline from compiled dry-run artifacts (TPU v5e target).

    compute_s    = HLO_FLOPs / peak_FLOPs            (per chip)
    memory_s     = HLO_bytes / HBM_bw                (per chip)
    collective_s = wire_bytes / ICI_bw               (per chip)

Methodology (DESIGN.md §5): `cost_analysis()` counts a scan/while body ONCE
(verified in this container), so totals use COMPONENT ACCOUNTING — the
per-layer block is compiled separately per pattern position (fwd+bwd for
train), scaled by layer count, plus an embed+head+loss "edges" compile and
an analytic optimizer term. Collective wire bytes are parsed from each
component's post-SPMD HLO (per-device shapes) with a ring model:
all-reduce 2(g-1)/g, all-gather/reduce-scatter/all-to-all (g-1)/g,
collective-permute 1x.

The full-graph compile from launch/dryrun.py supplies the FIT proof
(memory_analysis) and the compile-success bit; this module supplies the
scaled cost terms.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.configs.base import ModelConfig
from repro.launch.cells import SHAPES, applicable
from repro.sharding.compat import set_mesh
from repro.launch.mesh import dp_axes_of
from repro.launch import steps as steps_mod
from repro.models.model import _dtype, abstract_params
from repro.models.transformer import (block_apply, block_decode, init_block,
                                      init_layer_cache, pattern_split)
from repro.sharding.partition import param_shardings

# ------------------------------------------------------------------- hardware

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (per chip, flat model)

_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
                "u8": 1, "pred": 1, "s64": 8, "u64": 8, "f64": 8, "s16": 2,
                "u16": 2, "f8e4m3fn": 1, "f8e5m2": 1}

_COLL_OP_RE = re.compile(
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([0-9, ]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_wire_bytes(hlo_text: str) -> Tuple[float, Dict[str, float]]:
    """Per-device wire bytes by collective kind (ring model). Post-SPMD HLO
    shapes are per-device. Async (-start/-done) pairs count once; -start
    tuple types (operand, result) are halved."""
    by_kind: Dict[str, float] = {}
    for line in hlo_text.splitlines():
        m = _COLL_OP_RE.search(line)
        if not m or "-done(" in line:
            continue
        kind = m.group(1)
        eq = line.find("=")
        if eq < 0:
            continue
        type_str = line[eq + 1:m.start()]
        out_bytes = _shape_bytes(type_str)
        if m.group(2) and type_str.strip().startswith("("):
            out_bytes //= 2                      # (operand, result) tuple
        g = 2
        gm = _GROUPS_RE.search(line)
        gi = _GROUPS_IOTA_RE.search(line)
        if gm:
            g = max(2, len(gm.group(1).split(",")))
        elif gi:
            g = max(2, int(gi.group(2)))   # [num_groups, group_size]<=[N]
        if kind == "all-reduce":
            wire = 2.0 * (g - 1) / g * out_bytes
        elif kind in ("all-gather", "reduce-scatter", "all-to-all"):
            wire = (g - 1) / g * out_bytes
        else:  # collective-permute
            wire = float(out_bytes)
        by_kind[kind] = by_kind.get(kind, 0.0) + wire
    return sum(by_kind.values()), by_kind


# ------------------------------------------------------------ component cost

@dataclasses.dataclass
class CompCost:
    flops: float
    bytes: float
    coll: float
    coll_by_kind: Dict[str, float]


_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*([a-z0-9]+\[[0-9,]*\]|\([^)]*\))\S*\s+([\w\-]+)\(")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_PARAM_RE = re.compile(r"([\w.\-]+)\s*:\s*([a-z0-9]+\[[0-9,]*\])")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_TO_APPLY_RE = re.compile(r"to_apply=%?([\w.\-]+)")

# ops that are free / fused on TPU (layout, precision, metadata plumbing)
_FREE_OPS = {"convert", "copy", "transpose", "bitcast", "bitcast-convert",
             "reshape", "tuple", "get-tuple-element", "parameter",
             "constant", "iota", "broadcast", "after-all", "partition-id",
             "replica-id", "copy-start", "copy-done"}
_INPLACE_ROOTS = {"scatter", "dynamic-update-slice"}


def tpu_bytes_accessed(hlo_text: str) -> float:
    """Re-derive per-device HBM bytes from post-SPMD HLO with TPU-reality
    rules (methodology, EXPERIMENTS.md §Roofline):

    * fusion-granularity accounting: each ENTRY op charges outputs +
      operands, with an EFFECTIVE-SIZE map: free ops (convert / copy /
      transpose / reshape / broadcast / bitcast) forward their input's
      effective size, so a dot that XLA:CPU feeds through a bf16->f32
      emulation chain charges the bf16 read a TPU MXU would issue;
    * fusions rooted in scatter / dynamic-update-slice are IN-PLACE on TPU
      (read-modify-write of the update slice only);
    * while/conditional bodies count once (same basis as cost_analysis
      FLOPs; trip counts are applied by the component scaler).
    """
    comps: Dict[str, List] = {}
    types: Dict[str, str] = {}
    roots: Dict[str, str] = {}
    cur = None
    entry = None
    for line in hlo_text.splitlines():
        ls = line.strip()
        header = (not line.startswith("  ")) and ("{" in line) and \
            ("= " not in ls.split("(")[0])
        if header and ("(" in ls):
            cur = ls.split("(")[0].replace("ENTRY", "").strip().lstrip("%")
            comps[cur] = []
            if ls.startswith("ENTRY"):
                entry = cur
            for pname, ptype in _PARAM_RE.findall(ls):
                types[f"{cur}/{pname}"] = ptype
            continue
        m = _DEF_RE.match(line)
        if not m or cur is None:
            continue
        dname, dtype, op = m.groups()
        types[f"{cur}/{dname}"] = dtype
        comps[cur].append((dname, dtype, op, line[m.end():]))
        if ls.startswith("ROOT"):
            roots[cur] = op

    if entry is None:
        return 0.0

    eff: Dict[str, float] = {}

    def operand_names(rest: str):
        return _OPERAND_RE.findall(rest.split(")", 1)[0])

    def eff_of(name: str) -> float:
        if name in eff:
            return eff[name]
        t = types.get(f"{entry}/{name}")
        return float(_shape_bytes(t)) if t else 0.0

    total = 0.0
    for dname, dtype, op, rest in comps[entry]:
        out_b = float(_shape_bytes(dtype))
        opnds = operand_names(rest)
        callee = None
        if op == "fusion":
            cm = _CALLS_RE.search(rest)
            callee = cm.group(1) if cm else None
            root = roots.get(callee, "")
        elif op == "call":
            # XLA:CPU (older versions) wraps parallelized converts/copies in
            # `call(...), to_apply=%computation` instead of fusions
            cm = _TO_APPLY_RE.search(rest)
            callee = cm.group(1) if cm else None
            root = roots.get(callee, "")
        else:
            root = op
        if op in _FREE_OPS or (op in ("fusion", "call")
                               and root in _FREE_OPS):
            # free: forward the SUM of operand effective sizes (a fused
            # dequant reads codes+scales; a convert reads its one input),
            # capped at the declared output size
            ine = sum(eff_of(o) for o in opnds)
            eff[dname] = min(ine if ine > 0 else out_b, out_b)
            continue
        if root in _INPLACE_ROOTS:
            # in-place update: charge r-m-w of the update slice (approx by
            # the smallest positive operand) + index reads
            sizes = sorted(x for x in (eff_of(o) for o in opnds) if x > 0)
            upd = sizes[0] if sizes else 0.0
            total += 3.0 * upd
            big = max((eff_of(o) for o in opnds), default=out_b)
            eff[dname] = min(big, out_b)
            continue
        total += out_b + sum(eff_of(o) for o in opnds)
        eff[dname] = out_b
    return total


def _analyze(compiled) -> CompCost:
    from repro.sharding.compat import cost_analysis
    ca = cost_analysis(compiled)
    hlo = compiled.as_text()
    coll, by_kind = collective_wire_bytes(hlo)
    tpu_bytes = tpu_bytes_accessed(hlo)
    raw = float(ca.get("bytes accessed", 0.0))
    # fall back to raw cost-analysis bytes if the parser finds nothing
    return CompCost(flops=float(ca.get("flops", 0.0)),
                    bytes=tpu_bytes if tpu_bytes > 0 else raw,
                    coll=coll, coll_by_kind=by_kind)


def compiled_cost(compiled) -> CompCost:
    """Public component analyzer: per-device FLOPs / TPU-reality HBM bytes
    / collective wire bytes of one compiled executable. The serving
    observability layer (`serve.metrics.StepTracker` via
    `ServeEngine.step_costs`) prices each fixed-shape serving step with
    this, so per-step wall times become achieved-vs-peak percentages."""
    return _analyze(compiled)


def _abstract_block(cfg: ModelConfig, kind: str):
    dtype = _dtype(cfg.param_dtype)
    return jax.eval_shape(
        lambda k: init_block(k, kind, cfg, dtype),
        jax.ShapeDtypeStruct((2,), jnp.uint32))


def _positions_sds(cfg: ModelConfig, b: int, s: int):
    if cfg.mrope_sections:
        return jax.ShapeDtypeStruct((3, b, s), jnp.int32)
    return jax.ShapeDtypeStruct((b, s), jnp.int32)


def block_cost_train(cfg: ModelConfig, kind: str, mesh: Mesh, b: int, s: int,
                     ctx, remat: str = "full") -> CompCost:
    """fwd+bwd cost of one block at global (b, s); remat matches the
    baseline train_step (recompute flops are counted)."""
    bp = _abstract_block(cfg, kind)
    cd = _dtype(cfg.compute_dtype)
    x_sds = jax.ShapeDtypeStruct((b, s, cfg.d_model), cd)
    pos_sds = _positions_sds(cfg, b, s)
    dp = dp_axes_of(mesh)
    x_sh = NamedSharding(mesh, P(dp, None, None))
    pos_sh = NamedSharding(mesh, P(None, dp, None) if cfg.mrope_sections
                           else P(dp, None))
    p_sh = param_shardings(bp, mesh)

    def f(bp, x, positions):
        def fwd(bp, x):
            y, aux, _ = block_apply(kind, bp, x, positions, cfg, ctx,
                                    chunk=8192)
            return y, aux
        if remat == "full":
            fwd = jax.checkpoint(fwd, prevent_cse=False)
        elif remat == "dots":
            fwd = jax.checkpoint(fwd, prevent_cse=False,
                                 policy=jax.checkpoint_policies.checkpoint_dots)
        def loss(bp, x):
            y, aux = fwd(bp, x)
            return jnp.sum(y.astype(jnp.float32)) + 0.0 * aux
        gb, gx = jax.grad(loss, argnums=(0, 1))(bp, x)
        return gb, gx

    with set_mesh(mesh):
        comp = jax.jit(f, in_shardings=(p_sh, x_sh, pos_sh)).lower(
            bp, x_sds, pos_sds).compile()
    return _analyze(comp)


def block_cost_forward(cfg: ModelConfig, kind: str, mesh: Mesh, b: int,
                       s: int, ctx, chunk: int = 2048) -> CompCost:
    bp = _abstract_block(cfg, kind)
    cd = _dtype(cfg.compute_dtype)
    x_sds = jax.ShapeDtypeStruct((b, s, cfg.d_model), cd)
    pos_sds = _positions_sds(cfg, b, s)
    dp = dp_axes_of(mesh)
    x_sh = NamedSharding(mesh, P(dp, None, None))
    pos_sh = NamedSharding(mesh, P(None, dp, None) if cfg.mrope_sections
                           else P(dp, None))
    p_sh = param_shardings(bp, mesh)

    def f(bp, x, positions):
        y, _, _ = block_apply(kind, bp, x, positions, cfg, ctx, chunk=chunk)
        return y

    with set_mesh(mesh):
        comp = jax.jit(f, in_shardings=(p_sh, x_sh, pos_sh)).lower(
            bp, x_sds, pos_sds).compile()
    return _analyze(comp)


def block_cost_decode(cfg: ModelConfig, kind: str, mesh: Mesh, b: int,
                      cache_len: int, ctx, quantized: bool = False,
                      bits: int = 4) -> CompCost:
    bp = _abstract_block(cfg, kind)
    if quantized:
        from repro.models.quantized import abstract_quantize
        bp = abstract_quantize(bp, cfg, bits=bits)
    cd = _dtype(cfg.compute_dtype)
    cache_sds = jax.eval_shape(
        lambda: init_layer_cache(kind, b, cache_len, cfg, cd))
    c_sh = steps_mod.cache_shardings(cache_sds, cfg, mesh, b)
    x_sds = jax.ShapeDtypeStruct((b, 1, cfg.d_model), cd)
    pos_sds = jax.ShapeDtypeStruct((b,), jnp.int32)
    dp = dp_axes_of(mesh)
    x_sh = NamedSharding(mesh, P(dp if b > 1 else None, None, None))
    pos_sh = NamedSharding(mesh, P(dp if b > 1 else None))
    p_sh = param_shardings(bp, mesh)

    def f(bp, x, pos, cache):
        return block_decode(kind, bp, x, pos, cache, cfg, ctx)

    with set_mesh(mesh):
        comp = jax.jit(f, in_shardings=(p_sh, x_sh, pos_sh, c_sh),
                       donate_argnums=(3,)).lower(
            bp, x_sds, pos_sds, cache_sds).compile()
    return _analyze(comp)


def edges_cost(cfg: ModelConfig, mesh: Mesh, b: int, s: int, ctx,
               train: bool, ce_chunk: int = 512) -> CompCost:
    """Embed + final head/loss cost (train: with grads; serve: last token)."""
    from repro.models.model import chunked_ce_loss
    cd = _dtype(cfg.compute_dtype)
    pdt = _dtype(cfg.param_dtype)
    emb_sds = jax.ShapeDtypeStruct((cfg.vocab_size, cfg.d_model), pdt)
    emb_sh = param_shardings({"embed": emb_sds}, mesh)["embed"]
    toks_sds = jax.ShapeDtypeStruct((b, s), jnp.int32)
    dp = dp_axes_of(mesh)
    t_sh = NamedSharding(mesh, P(dp if b > 1 else None, None))
    params_mini = {"embed": emb_sds}
    if train:
        def f(p, tokens, labels):
            def loss(p):
                h = p["embed"][tokens].astype(cd)
                return chunked_ce_loss(
                    {"embed": p["embed"]} | {"head": None}, h, labels,
                    dataclasses.replace(cfg, tie_embeddings=True), ctx,
                    ce_chunk)
            return jax.grad(loss)(p)
        with set_mesh(mesh):
            comp = jax.jit(f, in_shardings=({"embed": emb_sh}, t_sh, t_sh)
                           ).lower(params_mini, toks_sds, toks_sds).compile()
    else:
        def f(p, tokens):
            h = p["embed"][tokens].astype(cd)
            return h[:, -1, :] @ p["embed"].T.astype(cd)
        with set_mesh(mesh):
            comp = jax.jit(f, in_shardings=({"embed": emb_sh}, t_sh)).lower(
                params_mini, toks_sds).compile()
    return _analyze(comp)


# --------------------------------------------------------------- aggregation

def optimizer_flops(cfg: ModelConfig, mesh: Mesh) -> float:
    """AdamW elementwise update ~15 flops/param, params sharded over tp."""
    tp = mesh.shape.get("model", 1)
    return 15.0 * cfg.param_count() / tp


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    variant: str
    flops_dev: float
    bytes_dev: float
    coll_dev: float
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    useful_ratio: float
    per_layer: Optional[List[Dict]] = None
    coll_by_kind: Optional[Dict[str, float]] = None

    def to_dict(self):
        return dataclasses.asdict(self)


def cell_roofline(arch: str, shape_name: str, mesh: Mesh, mesh_name: str,
                  variant: str = "baseline", quantized: bool = False,
                  bits: int = 4, remat: str = "full",
                  kv_quant: bool = False) -> Roofline:
    cfg = get_config(arch)
    shp = SHAPES[shape_name]
    seq, batch = shp["seq"], shp["batch"]
    if shp["kind"] in ("prefill", "decode"):
        cfg = dataclasses.replace(cfg, param_dtype="bfloat16",
                                  kv_quant_bits=8 if kv_quant else 0)
    ctx = steps_mod.make_ctx(mesh, cfg)
    pattern, n_units, n_tail = pattern_split(cfg)
    n_chips = 1
    for v in mesh.shape.values():
        n_chips *= v

    per_layer = []
    tot = CompCost(0.0, 0.0, 0.0, {})

    def add(c: CompCost, times: int, label: str):
        nonlocal tot
        merged = dict(tot.coll_by_kind)
        for k, v in c.coll_by_kind.items():
            merged[k] = merged.get(k, 0.0) + v * times
        tot = CompCost(tot.flops + c.flops * times,
                       tot.bytes + c.bytes * times,
                       tot.coll + c.coll * times, merged)
        per_layer.append({"label": label, "times": times,
                          "flops": c.flops, "bytes": c.bytes, "coll": c.coll})

    kinds_counted: Dict[str, int] = {}
    for pos, kind in enumerate(pattern):
        kinds_counted[kind] = kinds_counted.get(kind, 0) + n_units
    for i in range(n_tail):
        kinds_counted[pattern[i]] = kinds_counted.get(pattern[i], 0) + 1
    if cfg.is_encoder_decoder:
        kinds_counted = {"attn": cfg.n_layers}      # decoder blocks
        enc_layers = cfg.n_encoder_layers

    if shp["kind"] == "train":
        for kind, count in kinds_counted.items():
            c = block_cost_train(cfg, kind, mesh, batch, seq, ctx, remat)
            add(c, count, f"block/{kind} (fwd+bwd)")
        if cfg.is_encoder_decoder:
            c = block_cost_train(cfg, "attn", mesh, batch, seq, ctx, remat)
            add(c, enc_layers, "enc-block approx (fwd+bwd)")
        e = edges_cost(cfg, mesh, batch, seq, ctx, train=True)
        add(e, 1, "embed+loss (fwd+bwd)")
        opt_f = optimizer_flops(cfg, mesh)
        add(CompCost(opt_f, 12.0 * cfg.param_count() / mesh.shape["model"],
                     0.0, {}), 1, "optimizer (analytic)")
        model_flops = 6.0 * cfg.active_param_count() * batch * seq
    elif shp["kind"] == "prefill":
        for kind, count in kinds_counted.items():
            c = block_cost_forward(cfg, kind, mesh, batch, seq, ctx)
            add(c, count, f"block/{kind} (fwd)")
        if cfg.is_encoder_decoder:
            c = block_cost_forward(cfg, "attn", mesh, batch, seq, ctx)
            add(c, enc_layers, "enc-block approx (fwd)")
        e = edges_cost(cfg, mesh, batch, seq, ctx, train=False)
        add(e, 1, "embed+head")
        model_flops = 2.0 * cfg.active_param_count() * batch * seq
    else:  # decode
        for kind, count in kinds_counted.items():
            c = block_cost_decode(cfg, kind, mesh, batch, seq, ctx,
                                  quantized=quantized, bits=bits)
            add(c, count, f"block/{kind} (decode)")
        if cfg.is_encoder_decoder:
            # cross-attention reads a (B, S_enc) cache — approx with self blk
            c = block_cost_decode(cfg, "attn", mesh, batch, seq, ctx,
                                  quantized=quantized, bits=bits)
            add(c, cfg.n_layers, "xattn approx (decode)")
        e = edges_cost(cfg, mesh, batch, 1, ctx, train=False)
        add(e, 1, "embed+head")
        model_flops = 2.0 * cfg.active_param_count() * batch

    compute_s = tot.flops / PEAK_FLOPS
    memory_s = tot.bytes / HBM_BW
    coll_s = tot.coll / ICI_BW
    dom = max((("compute", compute_s), ("memory", memory_s),
               ("collective", coll_s)), key=lambda kv: kv[1])[0]
    useful = model_flops / max(tot.flops * n_chips, 1.0)
    return Roofline(arch=arch, shape=shape_name, mesh=mesh_name,
                    variant=variant,
                    flops_dev=tot.flops, bytes_dev=tot.bytes,
                    coll_dev=tot.coll, compute_s=compute_s,
                    memory_s=memory_s, collective_s=coll_s, dominant=dom,
                    model_flops=model_flops, useful_ratio=useful,
                    per_layer=per_layer, coll_by_kind=tot.coll_by_kind)
