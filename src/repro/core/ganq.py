"""GANQ Algorithm 1: GPU-adaptive layer-wise LUT-based non-uniform quantization.

Implements the paper's alternating-direction solver of

    min_{Q, T}  || W X - W~ X ||_F^2,   W~[i, j] = T[i, Q[i, j]]        (eq. 1)

with:
  * S-step (eq. 14-22): back-substitution over columns j = n-1 .. 0 against
    the Cholesky factor L of H = X X^T, rows processed in parallel (a scan
    over columns carrying the committed-error matrix E; the residual feedback
    r = E @ L[:, j] is a matrix-vector product — MXU-friendly on TPU).
  * T-step (eq. 7): batched closed-form least squares with a tiny
    2^N x 2^N pseudo-inverse per row.

The per-column argmin over the 2^N codebook entries and the triangular
residual feedback are exactly Algorithm 1 in the paper; `kernels/backsub.py`
provides the blocked Pallas TPU version of the S-step (VPU column loop +
MXU cross-block propagation) and this module is its numerical oracle.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .codebook import assign_nearest, init_codebook
from .outliers import extract_outliers_topk, select_full_rows
from .precondition import precondition
from .types import QuantConfig, QuantResult, QuantizedLinear


def compute_h(x: jnp.ndarray) -> jnp.ndarray:
    """H = X X^T for X (n, p) activations (columns = calibration tokens)."""
    x = x.astype(jnp.float32)
    return x @ x.T


def h_from_tokens(acts: jnp.ndarray) -> jnp.ndarray:
    """H from (tokens..., n) activation batches (row-major token layout)."""
    a = acts.reshape(-1, acts.shape[-1]).astype(jnp.float32)
    return a.T @ a


def layer_objective(w: jnp.ndarray, wq: jnp.ndarray, h: jnp.ndarray) -> jnp.ndarray:
    """||W X - W~ X||_F^2 = tr(E H E^T), E = W - W~  (eq. 9)."""
    e = (w - wq).astype(jnp.float32)
    return jnp.sum((e @ h.astype(jnp.float32)) * e)


def s_step(w: jnp.ndarray, t: jnp.ndarray, l: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Back-substitution code assignment (paper eq. 16-22, Algorithm 1 inner loop).

    Args:
      w: (m, n) weights (fp32).
      t: (m, 2^N) current codebook.
      l: (n, n) lower-triangular Cholesky factor of preconditioned H.

    Returns:
      codes (m, n) int32, wq (m, n) quantized weights.

    Complexity O(m n^2) — identical order to GPTQ. The scan carries the
    committed-error matrix E whose column j is only populated once column j
    has been quantized, so `E @ L[:, j]` realizes r = sum_{u>j} e_u L[u, j].
    """
    m, n = w.shape
    w = w.astype(jnp.float32)
    t = t.astype(jnp.float32)
    l = l.astype(jnp.float32)
    diag = jnp.diag(l)

    def body(e, j):
        r = e @ l[:, j]                                   # (m,) residual feedback
        target = w[:, j] + r / diag[j]
        idx = jnp.argmin(jnp.abs(target[:, None] - t), axis=1)
        wq_j = jnp.take_along_axis(t, idx[:, None], axis=1)[:, 0]
        e = e.at[:, j].set(w[:, j] - wq_j)
        return e, idx.astype(jnp.int32)

    cols = jnp.arange(n - 1, -1, -1)
    e, codes_rev = jax.lax.scan(body, jnp.zeros((m, n), jnp.float32), cols)
    codes = jnp.flip(codes_rev, axis=0).T                  # (m, n), natural order
    wq = w - e
    return codes, wq


def t_step(w: jnp.ndarray, h: jnp.ndarray, codes: jnp.ndarray, t_old: jnp.ndarray,
           wh: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Closed-form codebook update (paper eq. 7), batched over rows.

    T_i = W_i H S_i^T (S_i H S_i^T)^+ ; codebook entries with no assigned
    weight keep their previous value (the pinv would park them at 0).
    """
    levels = t_old.shape[1]
    w = w.astype(jnp.float32)
    h = h.astype(jnp.float32)
    onehot = jax.nn.one_hot(codes, levels, dtype=jnp.float32)   # (m, n, L) == S_i^T
    if wh is None:
        wh = w @ h
    c = jnp.einsum("mn,mnl->ml", wh, onehot)                    # W_i H S_i^T
    sh = jnp.einsum("mnk,nv->mkv", onehot, h)                   # S_i H
    g = jnp.einsum("mkv,mvl->mkl", sh, onehot)                  # S_i H S_i^T
    g_pinv = jnp.linalg.pinv(g)                                 # (m, L, L)
    t_ls = jnp.einsum("mk,mkl->ml", c, g_pinv)
    counts = jnp.sum(onehot, axis=1)                            # (m, L)
    return jnp.where(counts > 0, t_ls, t_old.astype(jnp.float32))


@partial(jax.jit, static_argnames=("bits", "iters", "codebook_init",
                                   "precond_mode", "kmeans_iters"))
def _ganq_core(w: jnp.ndarray, h: jnp.ndarray, *, bits: int, iters: int,
               codebook_init: str, precond_mode: str, damp: float,
               kmeans_iters: int):
    """Jitted alternating loop on the dense (post-outlier-split) weights."""
    w = w.astype(jnp.float32)
    hp = precondition(h, precond_mode, damp)
    l = jnp.linalg.cholesky(hp)
    t = init_codebook(w, bits, codebook_init, kmeans_iters).astype(jnp.float32)
    wh = w @ hp

    codes0 = assign_nearest(w, t)
    wq0 = jnp.take_along_axis(t, codes0, axis=1)
    err0 = layer_objective(w, wq0, hp)

    def step(carry, _):
        t, _codes = carry
        codes, wq = s_step(w, t, l)
        t = t_step(w, hp, codes, t, wh)
        wq_t = jnp.take_along_axis(t, codes, axis=1)
        err = layer_objective(w, wq_t, hp)
        return (t, codes), err

    (t, codes), errs = jax.lax.scan(step, (t, codes0), None, length=iters)
    err_history = jnp.concatenate([err0[None], errs])
    return codes.astype(jnp.uint8), t, err_history


def ganq_quantize(w: jnp.ndarray, h: Optional[jnp.ndarray] = None,
                  x: Optional[jnp.ndarray] = None,
                  cfg: QuantConfig = QuantConfig(),
                  bias: Optional[jnp.ndarray] = None) -> QuantResult:
    """Quantize one linear layer W (m, n) with GANQ (Algorithm 1 + Alg. 2 split).

    Exactly one of `h` (= X X^T, (n, n)) or `x` ((n, p) calibration
    activations) must be given. Returns a `QuantResult` whose `layer` is a
    serving-ready `QuantizedLinear` (codes + per-row LUT + optional sparse
    outliers / full-precision rows).
    """
    if (h is None) == (x is None):
        raise ValueError("provide exactly one of h= or x=")
    if h is None:
        h = compute_h(x)
    w = jnp.asarray(w, jnp.float32)
    m, n = w.shape

    full_row_idx = full_row_val = None
    w_work = w
    if cfg.full_rows > 0:
        full_row_idx, full_row_val = select_full_rows(w, h, cfg.full_rows)
        # zero sensitive rows out of the quantization problem
        w_work = w_work.at[full_row_idx].set(0.0)

    sparse_idx = sparse_val = None
    if cfg.outlier_ratio > 0.0:
        w_work, sparse_idx, sparse_val = extract_outliers_topk(w_work, cfg.outlier_ratio)

    perm = None
    h_used = h
    if cfg.act_order:
        perm = jnp.argsort(-jnp.diag(h))
        w_work = w_work[:, perm]
        h_used = h[perm][:, perm]

    codes, t, err_history = _ganq_core(
        w_work, h_used, bits=cfg.bits, iters=cfg.iters,
        codebook_init=cfg.codebook_init, precond_mode=cfg.precondition,
        damp=cfg.damp, kmeans_iters=cfg.kmeans_iters)

    if perm is not None:
        inv = jnp.argsort(perm)
        codes = codes[:, inv]

    fmt = ("lut_sparse" if sparse_val is not None or full_row_val is not None
           else "lut")
    layer = QuantizedLinear(codes=codes, codebook=t, bits=cfg.bits, fmt=fmt,
                            sparse_idx=sparse_idx, sparse_val=sparse_val,
                            full_row_idx=full_row_idx, full_row_val=full_row_val,
                            bias=bias)
    return QuantResult(layer=layer, err_history=err_history)
