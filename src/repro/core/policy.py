"""Per-layer precision policy + execution policy for mixed-precision PTQ.

`PrecisionPolicy` maps layer names to (QuantConfig, quantizer method,
WeightFormat) so one PTQ pass can emit e.g. 3-bit MLPs / 4-bit attention /
fp lm-head and the result serves unchanged through the slot engine. Rules
are first-match-wins fnmatch globs over the per-linear capture names the
pipeline already uses ("layer3/mlp/w_up", "layer0/attn/wq",
"layer1/moe/w_down", "dec0/xattn/wq"); `abstract_quantize` resolves the
same rules against param-tree paths ("stack/units/0/mlp/w_up"), so write
patterns that match both — sublayer-type globs like "*/mlp/*" do.

Note: pattern-unit stacking (models/transformer.py) stacks the same
position across units, so rules must be *depth-uniform* (keyed on sublayer
type, not "layer7/..."), or the per-unit containers cannot be stacked —
exactly the mixed-precision shapes related LUT-serving work (Any-Precision
LLM, FineQuant) deploys.

`ExecPolicy` carries backend switches that used to be module globals
(`models.linears._LUT_BACKEND`); it is threaded through `ShardCtx` so the
choice is explicit per call tree instead of ambient mutable state.
"""
from __future__ import annotations

import dataclasses
import fnmatch
from typing import Optional, Tuple

from .types import QuantConfig


@dataclasses.dataclass(frozen=True)
class ExecPolicy:
    """Execution knobs threaded through ShardCtx (no module globals).

    lut_backend: 'xla' (take_along_axis dequant + dot; dry-run / SPMD path)
      or 'pallas' (fused LUT-mpGEMM kernel; interpret mode off-TPU).
    draft_bits: > 0 runs every quantized linear at the speculative prefix
      width — nested formats stream only the leading ceil(n*db/8) code
      bytes; all other formats serve full width (an exact draft). The
      engine flips this per forward pass: draft passes set it, the verify
      pass leaves it 0.
    """

    lut_backend: str = "xla"
    draft_bits: int = 0

    def __post_init__(self):
        assert self.lut_backend in ("xla", "pallas"), self.lut_backend
        assert self.draft_bits in (0, 2, 3), self.draft_bits


@dataclasses.dataclass(frozen=True)
class LayerRule:
    """One policy rule: fnmatch `pattern` -> precision/format override.

    Exactly one of {keep_fp, bits, qcfg} decides the precision:
      keep_fp=True  leave the weight in full precision (skip quantization)
      bits=N        quantize with the policy default QuantConfig at N bits
      qcfg=...      fully custom QuantConfig for matching layers
    `method` / `fmt` override the quantizer and serving format when set.
    """

    pattern: str
    bits: Optional[int] = None
    qcfg: Optional[QuantConfig] = None
    method: Optional[str] = None
    fmt: Optional[str] = None
    keep_fp: bool = False
    # segment=True: `pattern` must equal one whole "/"-separated path
    # component ('attn' matches 'layer0/attn/wq' but NOT 'dec0/xattn/wq');
    # False: ordinary fnmatch glob over the full name.
    segment: bool = False

    def matches(self, name: str) -> bool:
        if self.segment:
            return self.pattern in name.split("/")
        return fnmatch.fnmatchcase(name, self.pattern)


@dataclasses.dataclass(frozen=True)
class ResolvedQuant:
    """Policy decision for one layer; qcfg=None means keep full precision."""

    qcfg: Optional[QuantConfig]
    method: str
    fmt: str

    @property
    def keep_fp(self) -> bool:
        return self.qcfg is None


@dataclasses.dataclass(frozen=True)
class PrecisionPolicy:
    """First-match-wins layer rules over a uniform default.

    qcfg/method/fmt are the defaults for every layer no rule matches —
    `PrecisionPolicy(qcfg=QuantConfig(bits=4))` is exactly the old uniform
    behaviour. `fmt` must name a linear `WeightFormat` ('lut',
    'lut4_packed', 'lut3_packed', 'lut_sparse'); MoE expert weights map to
    the stacked-experts counterpart automatically.
    """

    qcfg: QuantConfig = QuantConfig()
    method: str = "ganq"
    fmt: str = "lut"
    rules: Tuple[LayerRule, ...] = ()
    # KV-cache layout ('full' / 'int8' / 'paged' / 'paged_int8' — a
    # `core.cache_formats.CacheFormat` name); None = leave the config's
    # cache format alone. Weight and cache layouts compose in ONE policy:
    # `parse_policy("mlp=3,attn=4,kv=int8", ...)`.
    kv_fmt: Optional[str] = None
    # speculative draft width (0 = off). Set via the reserved `draft=b`
    # policy entry; it defaults the weight format to the nested layout so
    # the draft pass actually reads fewer bytes.
    draft_bits: int = 0

    @classmethod
    def uniform(cls, qcfg: QuantConfig, method: str = "ganq",
                fmt: str = "lut") -> "PrecisionPolicy":
        return cls(qcfg=qcfg, method=method, fmt=fmt)

    def apply_kv_format(self, cfg):
        """Return cfg with this policy's cache format applied (no-op when
        the policy does not pin one)."""
        if self.kv_fmt is None:
            return cfg
        return dataclasses.replace(cfg, kv_format=self.kv_fmt)

    def resolve(self, name: str) -> ResolvedQuant:
        for r in self.rules:
            if not r.matches(name):
                continue
            if r.keep_fp:
                return ResolvedQuant(None, r.method or self.method, "dense")
            qcfg = r.qcfg
            if qcfg is None:
                qcfg = (dataclasses.replace(self.qcfg, bits=r.bits)
                        if r.bits is not None else self.qcfg)
            return ResolvedQuant(qcfg, r.method or self.method,
                                 r.fmt or self.fmt)
        return ResolvedQuant(self.qcfg, self.method, self.fmt)


def parse_policy(spec: str, qcfg: QuantConfig, method: str = "ganq",
                 fmt: str = "lut") -> PrecisionPolicy:
    """Build a PrecisionPolicy from a CLI spec string.

    spec: comma-separated `pattern=value` entries, value one of
      fp          keep full precision
      N           bits (default QuantConfig rebased to N bits)
      N@format    bits + serving-format override
    A pattern without glob characters matches a whole path segment
    ('attn' hits 'layer0/attn/wq' but not 'dec0/xattn/wq'); glob
    patterns fnmatch the full layer name.

    The reserved pattern `kv` selects the KV-*cache* format instead of a
    weight rule: `kv=int8`, `kv=paged`, `kv=paged_int8`, `kv=full`
    (`core.cache_formats` registry) — so one spec string carries the whole
    serving memory layout. The reserved pattern `draft` sets the
    speculative prefix width (`draft=3` / `draft=2`) and, when the caller
    left the default format, switches it to the matching nested layout.

    Example: "mlp=3,attn=4,kv=int8"  — 3-bit MLPs, 4-bit attention,
    int8 KV cache; everything else uses the default `qcfg`.
    """
    rules = []
    kv_fmt = None
    draft_bits = 0
    for entry in filter(None, (e.strip() for e in spec.split(","))):
        if "=" not in entry:
            raise ValueError(f"policy entry {entry!r} is not pattern=value")
        pat, val = (s.strip() for s in entry.split("=", 1))
        if pat == "kv":
            from .cache_formats import get_cache_format
            f = get_cache_format(val)           # loud on typos
            assert f.kv and f.selectable, \
                f"{val!r} is not a selectable attention-cache format"
            kv_fmt = val
            continue
        if pat == "draft":
            from .formats import nested_linear_fmt
            draft_bits = int(val)
            if fmt in ("lut", "lut4_packed"):   # caller kept the default:
                fmt = nested_linear_fmt(draft_bits)   # nest it
            continue
        segment = not any(c in pat for c in "*?[/")
        if not segment and "/" in pat and not any(c in pat for c in "*?["):
            pat = f"*{pat}*"           # glob-free subpath: substring match
        if val == "fp":
            rules.append(LayerRule(pattern=pat, keep_fp=True,
                                   segment=segment))
            continue
        rule_fmt = None
        if "@" in val:
            val, rule_fmt = (s.strip() for s in val.split("@", 1))
        rules.append(LayerRule(pattern=pat, bits=int(val), fmt=rule_fmt,
                               segment=segment))
    return PrecisionPolicy(qcfg=qcfg, method=method, fmt=fmt,
                           rules=tuple(rules), kv_fmt=kv_fmt,
                           draft_bits=draft_bits)


@dataclasses.dataclass
class LayerQuantReport:
    """Per-linear PTQ report entry: error AND storage, per layer.

    `float(entry)` returns the layer objective ||WX - W~X||_F^2 so scalar
    consumers keep working.
    """

    err: float
    bits_per_weight: float
    bits: Optional[int]          # codebook bit width; None = kept fp
    fmt: str
    method: str
    n_weights: int = 0           # weight count (0 on pre-existing reports)
    shape: Optional[Tuple[int, int]] = None   # (m=out, n=in) GANQ layout

    def __float__(self) -> float:
        return float(self.err)

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        if self.shape is not None:
            d["shape"] = list(self.shape)
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "LayerQuantReport":
        d = dict(d)
        if d.get("shape") is not None:
            d["shape"] = tuple(d["shape"])
        return cls(**{k: d[k] for k in
                      ("err", "bits_per_weight", "bits", "fmt", "method",
                       "n_weights", "shape") if k in d})
