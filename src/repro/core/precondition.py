"""Positive-definiteness preconditioning of H = X X^T (paper Appendix A).

GANQ's S-step needs a Cholesky factor of H. H is PSD by construction but can
be singular (e.g. dead input features, p < n calibration). Two strategies:

  * 'fixed'    — Remark 3.1: H + lambda * mean(diag(H)) * I.
  * 'adaptive' — Appendix A (eq. 23-24): add a per-row offset enforcing
                 diagonal dominance:  delta_i = max(sum_j |H_ij| - 2*H_ii, eps).

Both return an SPD matrix; Table 7 of the paper (reproduced in
benchmarks.run::bench_precondition) shows the method is insensitive to the
choice, with 'adaptive' slightly best.
"""
from __future__ import annotations

import jax.numpy as jnp


_EPS = 1e-8


def precondition_fixed(h: jnp.ndarray, damp: float = 0.01) -> jnp.ndarray:
    """H + lambda*I with lambda relative to mean(diag(H)) (GPTQ-style damping)."""
    n = h.shape[0]
    lam = damp * jnp.mean(jnp.diag(h)) + _EPS
    return h + lam * jnp.eye(n, dtype=h.dtype)


def precondition_adaptive(h: jnp.ndarray) -> jnp.ndarray:
    """Appendix A: enforce diagonal dominance with a per-row adaptive offset.

    delta_i = max(sum_j |H_ij| - 2*H_ii, 1e-8);  H <- H + Diag(delta).
    A symmetric diagonally dominant matrix with positive diagonal is SPD.
    """
    abs_row = jnp.sum(jnp.abs(h), axis=1)
    delta = jnp.maximum(abs_row - 2.0 * jnp.diag(h), _EPS)
    return h + jnp.diag(delta)


def precondition(h: jnp.ndarray, mode: str = "adaptive", damp: float = 0.01) -> jnp.ndarray:
    h = h.astype(jnp.float32)
    h = 0.5 * (h + h.T)  # symmetrize against accumulation noise
    if mode == "adaptive":
        return precondition_adaptive(h)
    if mode == "fixed":
        return precondition_fixed(h, damp)
    raise ValueError(f"unknown precondition mode: {mode!r}")


def safe_cholesky(h: jnp.ndarray, mode: str = "adaptive", damp: float = 0.01) -> jnp.ndarray:
    """Precondition then factor; returns lower-triangular L with H' = L L^T."""
    return jnp.linalg.cholesky(precondition(h, mode, damp))
