"""Code packing: in-graph nibble + bitstream containers.

In-graph (serving) containers:
  * 4-bit nibbles, two codes per uint8 ('lut4_packed') — `pack_nibbles`.
  * true `ceil(n*bits/8)`-byte bitstream ('lut3_packed') — `pack_bits` /
    `unpack_bits`, the jnp twins of the numpy checkpoint packers below,
    so serving HBM bytes equal checkpoint bytes.

Both layouts are streamed directly by the Pallas LUT-mpGEMM kernels
(`kernels.lut_mpgemm`); which one a served layer uses is the
`WeightFormat` tag on its container (`core.formats`).

Bit order is little-endian within each byte (numpy
``packbits(bitorder="little")``): code j occupies bits
[j*bits, (j+1)*bits) of the row bitstream. For bits=4 this coincides
exactly with the nibble layout (low nibble = even code).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------- nibble (jnp)

def pack_nibbles(codes: jnp.ndarray) -> jnp.ndarray:
    """(m, n) uint8 codes < 16 -> (m, ceil(n/2)) uint8. Pads odd n with 0."""
    m, n = codes.shape
    if n % 2:
        codes = jnp.pad(codes, ((0, 0), (0, 1)))
    lo = codes[:, 0::2]
    hi = codes[:, 1::2]
    return (lo | (hi << 4)).astype(jnp.uint8)


def unpack_nibbles(packed: jnp.ndarray, n: int) -> jnp.ndarray:
    """(m, ceil(n/2)) uint8 -> (m, n) uint8 codes."""
    lo = packed & 0xF
    hi = packed >> 4
    out = jnp.stack([lo, hi], axis=-1).reshape(packed.shape[0], -1)
    return out[:, :n].astype(jnp.uint8)


# ----------------------------------------------------------- bitstream (jnp)

def code_stream_bytes(n: int, bits: int) -> int:
    """Per-row container bytes for n codes at `bits` stream width:
    ceil(n * bits / 8) — the true checkpoint/serving byte count."""
    return (n * bits + 7) // 8


def pack_bits(codes: jnp.ndarray, bits: int) -> jnp.ndarray:
    """(m, n) uint8 codes < 2**bits -> (m, ceil(n*bits/8)) uint8 bitstream.

    In-graph twin of `pack_bits_np` (little-endian bit order), so the
    serving container is byte-identical to the checkpoint stream.
    """
    m, n = codes.shape
    shifts = jnp.arange(bits, dtype=jnp.uint8)
    bitmat = ((codes[..., None] >> shifts) & 1).astype(jnp.uint8)  # (m,n,bits)
    flat = bitmat.reshape(m, n * bits)
    pad = (-flat.shape[1]) % 8
    if pad:
        flat = jnp.pad(flat, ((0, 0), (0, pad)))
    by = flat.reshape(m, -1, 8)
    weights = (jnp.uint8(1) << jnp.arange(8, dtype=jnp.uint8))
    return jnp.sum(by * weights, axis=-1).astype(jnp.uint8)


def unpack_bits(packed: jnp.ndarray, bits: int, n: int) -> jnp.ndarray:
    """(m, ceil(n*bits/8)) uint8 bitstream -> (m, n) uint8 codes."""
    m = packed.shape[0]
    shifts8 = jnp.arange(8, dtype=jnp.uint8)
    bitmat = ((packed[..., None] >> shifts8) & 1).reshape(m, -1)
    bitmat = bitmat[:, :n * bits].reshape(m, n, bits)
    shifts = jnp.arange(bits, dtype=jnp.uint8)
    return jnp.sum(bitmat.astype(jnp.uint8) << shifts, axis=-1) \
        .astype(jnp.uint8)


# ------------------------------------------------------ nested bitstream (jnp)

def nested_stream_cols(n: int, bits: int, draft_bits: int):
    """(hi_cols, lo_cols) byte widths of the two sub-streams of a nested
    row: the `draft_bits`-wide prefix stream holding the high bits of each
    code, then the (bits - draft_bits)-wide remainder stream. A draft pass
    reads only the leading hi_cols = ceil(n * draft_bits / 8) bytes."""
    assert 0 < draft_bits < bits, (draft_bits, bits)
    return (code_stream_bytes(n, draft_bits),
            code_stream_bytes(n, bits - draft_bits))


def pack_bits_nested(codes: jnp.ndarray, bits: int,
                     draft_bits: int) -> jnp.ndarray:
    """(m, n) uint8 codes -> (m, hi_cols + lo_cols) nested bitstream.

    Row layout = [pack_bits(codes >> rb, db) | pack_bits(codes & mask, rb)]
    with db = draft_bits, rb = bits - db: the high db bits of every code
    form a contiguous plain `pack_bits` prefix sub-stream, so a b-bit
    draft pass streams exactly the leading ceil(n*db/8) bytes through the
    existing bitstream kernel — no second weight buffer in HBM.
    """
    rb = bits - draft_bits
    hi = pack_bits((codes >> rb).astype(jnp.uint8), draft_bits)
    lo = pack_bits((codes & ((1 << rb) - 1)).astype(jnp.uint8), rb)
    return jnp.concatenate([hi, lo], axis=1)


def unpack_bits_nested(packed: jnp.ndarray, bits: int, draft_bits: int,
                       n: int) -> jnp.ndarray:
    """Inverse of pack_bits_nested: (m, hi+lo cols) -> (m, n) full codes."""
    rb = bits - draft_bits
    hi_cols, _ = nested_stream_cols(n, bits, draft_bits)
    hi = unpack_bits(packed[:, :hi_cols], draft_bits, n)
    lo = unpack_bits(packed[:, hi_cols:], rb, n)
    return ((hi << rb) | lo).astype(jnp.uint8)


# ------------------------------------------------------------ bitstream (np)

def pack_bits_np(codes: np.ndarray, bits: int) -> np.ndarray:
    """(m, n) uint8 -> (m, ceil(n*bits/8)) uint8 true bitstream (storage)."""
    m, n = codes.shape
    shifts = np.arange(bits, dtype=np.uint8)
    bitmat = ((codes[..., None] >> shifts) & 1).astype(np.uint8)  # (m, n, bits)
    return np.packbits(bitmat.reshape(m, n * bits), axis=1, bitorder="little")


def unpack_bits_np(packed: np.ndarray, bits: int, n: int) -> np.ndarray:
    """Inverse of pack_bits_np."""
    m = packed.shape[0]
    bitmat = np.unpackbits(packed, axis=1, count=n * bits, bitorder="little")
    bitmat = bitmat.reshape(m, n, bits)
    shifts = np.arange(bits, dtype=np.uint8)
    return np.sum(bitmat.astype(np.uint8) << shifts, axis=-1).astype(np.uint8)


def storage_bytes(m: int, n: int, bits: int, levels: int = None,
                  sparse_k: int = 0, full_rows: int = 0,
                  book_bytes: int = 2) -> dict:
    """Theoretical storage accounting (paper Table 1).

    Codebook at `book_bytes` per entry (paper assumes fp16; pass 4 for the
    fp32 codebooks the quantizer actually emits), true-packed codes at the
    per-row container width `code_stream_bytes` (shared with
    `kernels.ops.vmem_plan`, so roofline and storage accounting agree),
    optional structured sparse (fp16 value + int32 index) and full fp16
    rows.
    """
    levels = levels if levels is not None else (1 << bits)
    codes = m * code_stream_bytes(n, bits)
    lut = m * levels * book_bytes
    sparse = m * sparse_k * (2 + 4)
    full = full_rows * n * 2
    fp16 = m * n * 2
    uniform = m * n * bits / 8 + 4 * m  # per-channel scale+zero fp16
    total = codes + lut + sparse + full
    return {
        "fp16_bytes": fp16,
        "uniform_bytes": uniform,
        "lut_bytes": total,
        "lut_pct_of_fp16": 100.0 * total / fp16,
        "uniform_pct_of_fp16": 100.0 * uniform / fp16,
    }
