"""Code packing: in-graph nibble container + true bitstream storage.

In-graph (serving) container: 4-bit nibbles, two codes per uint8 — the
layout the Pallas LUT-mpGEMM kernel consumes. 3-bit codes also ride the
nibble container in-graph (TPU alignment; 1 wasted bit), while checkpoints
store the true 3/8-bytes-per-weight bitstream via numpy packbits.

These are the low-level primitives; which layout a served layer actually
uses is the `WeightFormat` tag on its container (`core.formats` — e.g.
'lut4_packed' / 'lut3_packed' call `pack_nibbles` in `encode`, and
storage accounting counts the bitstream width).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------- nibble (jnp)

def pack_nibbles(codes: jnp.ndarray) -> jnp.ndarray:
    """(m, n) uint8 codes < 16 -> (m, ceil(n/2)) uint8. Pads odd n with 0."""
    m, n = codes.shape
    if n % 2:
        codes = jnp.pad(codes, ((0, 0), (0, 1)))
    lo = codes[:, 0::2]
    hi = codes[:, 1::2]
    return (lo | (hi << 4)).astype(jnp.uint8)


def unpack_nibbles(packed: jnp.ndarray, n: int) -> jnp.ndarray:
    """(m, ceil(n/2)) uint8 -> (m, n) uint8 codes."""
    lo = packed & 0xF
    hi = packed >> 4
    out = jnp.stack([lo, hi], axis=-1).reshape(packed.shape[0], -1)
    return out[:, :n].astype(jnp.uint8)


# ------------------------------------------------------------ bitstream (np)

def pack_bits_np(codes: np.ndarray, bits: int) -> np.ndarray:
    """(m, n) uint8 -> (m, ceil(n*bits/8)) uint8 true bitstream (storage)."""
    m, n = codes.shape
    shifts = np.arange(bits, dtype=np.uint8)
    bitmat = ((codes[..., None] >> shifts) & 1).astype(np.uint8)  # (m, n, bits)
    return np.packbits(bitmat.reshape(m, n * bits), axis=1, bitorder="little")


def unpack_bits_np(packed: np.ndarray, bits: int, n: int) -> np.ndarray:
    """Inverse of pack_bits_np."""
    m = packed.shape[0]
    bitmat = np.unpackbits(packed, axis=1, count=n * bits, bitorder="little")
    bitmat = bitmat.reshape(m, n, bits)
    shifts = np.arange(bits, dtype=np.uint8)
    return np.sum(bitmat.astype(np.uint8) << shifts, axis=-1).astype(np.uint8)


def storage_bytes(m: int, n: int, bits: int, levels: int = None,
                  sparse_k: int = 0, full_rows: int = 0) -> dict:
    """Theoretical storage accounting (paper Table 1).

    fp16 codebook (m * 2^bits entries), true-packed codes, optional
    structured sparse (fp16 value + int32 index) and full fp16 rows.
    """
    levels = levels if levels is not None else (1 << bits)
    codes = m * n * bits / 8
    lut = m * levels * 2
    sparse = m * sparse_k * (2 + 4)
    full = full_rows * n * 2
    fp16 = m * n * 2
    uniform = m * n * bits / 8 + 4 * m  # per-channel scale+zero fp16
    total = codes + lut + sparse + full
    return {
        "fp16_bytes": fp16,
        "uniform_bytes": uniform,
        "lut_bytes": total,
        "lut_pct_of_fp16": 100.0 * total / fp16,
        "uniform_pct_of_fp16": 100.0 * uniform / fp16,
    }
