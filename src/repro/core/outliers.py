"""Outlier extraction (paper Algorithm 2 + full-row retention, GANQ*).

Two forms:

  * `extract_outliers_percentile` — the literal Algorithm 2: per-row symmetric
    percentile cutoffs produce a boolean mask (data-dependent count). Used in
    tests to pin the semantics.
  * `extract_outliers_topk` — static-shape equivalent used in the JAX
    pipeline: exactly k = round(n*r) entries per row (k/2 largest, k/2
    smallest by value), which coincides with the percentile mask in the
    absence of ties. Returns structured (m, k) indices/values, which the
    serving path applies as a per-row k-sparse matvec (TPU-friendly: a
    static gather + small einsum instead of CSR).

`select_full_rows` retains the most sensitive rows in fp16 (SqueezeLLM's
"full rows" knob used for the paper's Table 5 comparison); sensitivity of
row i is the output-error weight w_i^T H w_i.
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp


def extract_outliers_percentile(w: jnp.ndarray, ratio: float) -> jnp.ndarray:
    """Boolean outlier mask per Algorithm 2 (reference semantics)."""
    m, n = w.shape
    p = 1.0 - 0.5 * ratio
    w_sorted = jnp.sort(w, axis=1)
    upper = min(int(jnp.floor(n * p)), n - 1)
    lower = int(jnp.ceil(n * (1.0 - p)))
    c_upper = w_sorted[:, upper][:, None]
    c_lower = w_sorted[:, lower][:, None]
    return (w >= c_upper) | (w <= c_lower)


def outlier_k(n: int, ratio: float) -> int:
    """Static per-row outlier count of `extract_outliers_topk` — the single
    definition shared with the abstract (ShapeDtypeStruct) transform so
    dry-run sparse leaves are sized exactly as the quantizer emits them."""
    return max(2, int(round(n * ratio)))


def extract_outliers_topk(w: jnp.ndarray, ratio: float
                          ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Static-shape Algorithm 2: returns (w_dense, idx (m,k), val (m,k)).

    w_dense has the outlier slots zeroed (W_dense = W - W_sparse), shrinking
    the per-row range the codebook must cover.
    """
    m, n = w.shape
    k = outlier_k(n, ratio)
    k_hi = k // 2
    k_lo = k - k_hi
    order = jnp.argsort(w, axis=1)
    idx = jnp.concatenate([order[:, :k_lo], order[:, n - k_hi:]], axis=1)  # (m, k)
    rows = jnp.broadcast_to(jnp.arange(m)[:, None], idx.shape)
    val = w[rows, idx]
    w_dense = w.at[rows, idx].set(0.0)
    return w_dense, idx.astype(jnp.int32), val


def apply_sparse(idx: jnp.ndarray, val: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """y_i = sum_k val[i,k] * x[idx[i,k], ...] — the W_sparse @ X branch.

    x: (n, p) activations; returns (m, p).
    """
    gathered = x[idx]                       # (m, k, p)
    return jnp.einsum("mk,mkp->mp", val.astype(x.dtype), gathered)


def select_full_rows(w: jnp.ndarray, h: jnp.ndarray, num_rows: int
                     ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Top rows by sensitivity w_i^T H w_i, kept in full precision."""
    sens = jnp.einsum("mn,nv,mv->m", w, h.astype(w.dtype), w)
    idx = jnp.argsort(-sens)[:num_rows]
    return idx.astype(jnp.int32), w[idx]
