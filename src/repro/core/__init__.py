"""GANQ core: the paper's contribution as a composable JAX module."""
from .types import (QuantConfig, QuantizedLinear, QuantizedExperts,
                    QuantResult)
from .formats import (WeightFormat, register_format, get_format,
                      available_formats, packed_linear_fmt)
from .cache_formats import (CacheFormat, CacheState, register_cache_format,
                            get_cache_format, available_cache_formats,
                            kv_format_of, layer_cache_format, contiguous_cfg,
                            pages_for, kv_cache_bytes, insert_slot,
                            quantize_kv, dequantize_kv)
from .policy import (ExecPolicy, LayerRule, LayerQuantReport,
                     PrecisionPolicy, parse_policy)
from .precondition import precondition, safe_cholesky
from .codebook import init_codebook, assign_nearest
from .rtn import rtn_quantize, rtn_dequantize, rtn_reconstruct, rtn_codebook
from .gptq import gptq_quantize, gptq_reconstruct
from .ganq import (ganq_quantize, compute_h, h_from_tokens, layer_objective,
                   s_step, t_step)
from .outliers import (extract_outliers_topk, extract_outliers_percentile,
                       apply_sparse, select_full_rows)
from .packing import (pack_nibbles, unpack_nibbles, pack_bits_np,
                      unpack_bits_np, storage_bytes)
from .pipeline import (HCollector, quantize_linear, register_quantizer,
                       available_quantizers, SequentialPTQ)
from .bitsearch import (PROVEN_WIDTHS, AllocGroup, AutoSpec, SearchResult,
                        SensitivityProfile, allocation_groups, candidate_fmt,
                        emit_policy_spec, escape_pattern, load_report,
                        model_layer_names, parse_auto_spec,
                        profile_sensitivity, save_report, search_policy)

__all__ = [
    "QuantConfig", "QuantizedLinear", "QuantizedExperts", "QuantResult",
    "WeightFormat", "register_format", "get_format", "available_formats",
    "packed_linear_fmt",
    "CacheFormat", "CacheState", "register_cache_format", "get_cache_format",
    "available_cache_formats", "kv_format_of", "layer_cache_format",
    "contiguous_cfg", "pages_for", "kv_cache_bytes", "insert_slot",
    "quantize_kv", "dequantize_kv",
    "ExecPolicy", "LayerRule", "LayerQuantReport", "PrecisionPolicy",
    "parse_policy",
    "precondition", "safe_cholesky",
    "init_codebook", "assign_nearest",
    "rtn_quantize", "rtn_dequantize", "rtn_reconstruct", "rtn_codebook",
    "gptq_quantize", "gptq_reconstruct",
    "ganq_quantize", "compute_h", "h_from_tokens", "layer_objective",
    "s_step", "t_step",
    "extract_outliers_topk", "extract_outliers_percentile", "apply_sparse",
    "select_full_rows",
    "pack_nibbles", "unpack_nibbles", "pack_bits_np", "unpack_bits_np",
    "storage_bytes",
    "HCollector", "quantize_linear", "register_quantizer",
    "available_quantizers", "SequentialPTQ",
    "PROVEN_WIDTHS", "AllocGroup", "AutoSpec", "SearchResult",
    "SensitivityProfile", "allocation_groups", "candidate_fmt",
    "emit_policy_spec", "escape_pattern", "load_report",
    "model_layer_names", "parse_auto_spec", "profile_sensitivity",
    "save_report", "search_policy",
]
