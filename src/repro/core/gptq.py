"""GPTQ baseline (Frantar et al., 2022) — uniform-grid OBS quantization.

Implemented directly from the optimal-brain-surgeon recursion: quantize
columns left-to-right; after committing column j with error e_j, compensate
the not-yet-quantized columns

    W[:, u] -= e_j * Hinv[j, u] / Hinv[j, j]   (u > j)

and eliminate index j from the active inverse via the rank-1 downdate

    Hinv <- Hinv - Hinv[:, j] Hinv[j, :] / Hinv[j, j].

This is the exact (unblocked) form; O(n^3 + m n^2), same order as the
Cholesky formulation used by the reference CUDA code. Scales/zero-points are
per-channel (or per-group) affine grids precomputed from the original
weights.

Serves as the principal baseline for paper Tables 2/5/8/9/10.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .precondition import precondition_fixed
from .rtn import _affine_params


@partial(jax.jit, static_argnames=("bits", "group_size"))
def gptq_quantize(w: jnp.ndarray, h: jnp.ndarray, bits: int = 4,
                  group_size: Optional[int] = None, damp: float = 0.01
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (codes uint8 (m, n), w_hat fp32 (m, n))."""
    m, n = w.shape
    w = w.astype(jnp.float32)
    qmax = (1 << bits) - 1

    # per-column scale/zero broadcast maps (precomputed from original W)
    if group_size is not None and group_size < n:
        assert n % group_size == 0
        wg = w.reshape(m, n // group_size, group_size)
        s, z = _affine_params(wg, bits)            # (m, g, 1)
        s_cols = jnp.repeat(s[:, :, 0], group_size, axis=1)
        z_cols = jnp.repeat(z[:, :, 0], group_size, axis=1)
    else:
        s, z = _affine_params(w, bits)             # (m, 1)
        s_cols = jnp.broadcast_to(s, (m, n))
        z_cols = jnp.broadcast_to(z, (m, n))

    hp = precondition_fixed(h.astype(jnp.float32), damp)
    hinv0 = jax.scipy.linalg.cho_solve(
        (jnp.linalg.cholesky(hp), True), jnp.eye(n, dtype=jnp.float32))

    def body(carry, j):
        w_work, hinv = carry
        col = w_work[:, j]
        q = jnp.clip(jnp.round(col / s_cols[:, j]) + z_cols[:, j], 0, qmax)
        wq_j = s_cols[:, j] * (q - z_cols[:, j])
        d = jnp.maximum(hinv[j, j], 1e-10)
        err = (col - wq_j) / d
        row = hinv[j, :]
        mask = (jnp.arange(n) > j).astype(jnp.float32)
        w_work = w_work - err[:, None] * (row * mask)[None, :]
        hinv = hinv - jnp.outer(hinv[:, j], row) / d
        return (w_work, hinv), (q.astype(jnp.uint8), wq_j)

    (_, _), (codes_t, wq_t) = jax.lax.scan(
        body, (w, hinv0), jnp.arange(n))
    return codes_t.T, wq_t.T


def gptq_reconstruct(w: jnp.ndarray, h: jnp.ndarray, bits: int = 4,
                     group_size: Optional[int] = None, damp: float = 0.01
                     ) -> jnp.ndarray:
    """One-call W -> W~ for benchmarking."""
    _, wq = gptq_quantize(w, h, bits, group_size, damp)
    return wq.astype(w.dtype)
