"""WeightFormat registry: every serving weight layout as one object.

The deployment story of the paper is "same network, LUT-mpGEMM instead of
GEMM"; in practice a served model mixes *several* layouts — dense fp
embeddings, unpacked LUT for debugging, nibble-packed LUT-4/LUT-3 for HBM
bandwidth, LUT+sparse-outlier (GANQ*), stacked-experts LUT for MoE. Each
layout is a `WeightFormat` registered here and owns the full vertical:

  encode(layer)        canonical (unpacked) container -> this layout
  apply(layer, x2, backend)   y = x2 @ W~^T   (x2 is (N, d_in))
  dequantize(layer)    materialize W~ in GANQ layout ((m, n) / (E, m, n))
  abstract(shape, ...) ShapeDtypeStruct container for dry-runs
  storage_bits(layer)  (total_bits, n_weights) from the REAL dtypes

`models.linears.linear_apply`, `kernels.ops.lut_linear`,
`models.quantized.abstract_quantize` and `model_storage_report` all route
through this registry, so adding a layout is one class here — no flag
threading through model code.

Each LUT format also owns its *container layout* — `stream_bits` (bits
per code in the in-graph byte stream: 8 unpacked, 4 nibble, 3 true
bitstream), `code_cols`, `pack_codes`/`unpack_codes` — which is what
`kernels.ops.lut_linear` routes on and `vmem_plan` accounts with.
'lut3_packed' stores the true ceil(n*3/8)-byte bitstream in-graph
(`core.packing.pack_bits`), so serving HBM bytes equal checkpoint bytes;
storage accounting counts the same stream width. Codebook / sparse /
full-row bits derive from the actual array dtypes. `groupable` marks
formats whose layers may fuse into one multi-projection kernel launch
(`kernels.ops.lut_linear_grouped`); dense and sparse-carrying layers
fall back to sequential applies.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .codebook import nested_codebooks, nested_order
from .outliers import outlier_k
from .packing import (code_stream_bytes, nested_stream_cols,
                      pack_bits, pack_bits_nested, pack_nibbles,
                      unpack_bits, unpack_bits_nested, unpack_nibbles)
from .types import QuantizedExperts, QuantizedLinear, put_rows_sparse

_FORMATS: Dict[str, "WeightFormat"] = {}


def register_format(cls):
    """Class decorator: instantiate and register under cls.name."""
    inst = cls()
    assert inst.name and inst.name not in _FORMATS, inst.name
    _FORMATS[inst.name] = inst
    return cls


def get_format(name: str) -> "WeightFormat":
    try:
        return _FORMATS[name]
    except KeyError:
        raise KeyError(f"unknown weight format {name!r}; "
                       f"available: {available_formats()}") from None


def available_formats():
    return sorted(_FORMATS)


def dtype_bits(dtype) -> int:
    return jnp.dtype(dtype).itemsize * 8


def pad_spec(base, rank: int):
    """PartitionSpec from a base-rank rule tuple: leading dims of a
    higher-rank leaf (stacked pattern units) pad with None; a leaf too
    small for the rule replicates. The one padding/clamping rule for dense
    leaves (`sharding.partition.spec_for_param`) and quantized-container
    children (`WeightFormat.partition_spec`) alike."""
    from jax.sharding import PartitionSpec as P
    if base is None or rank < len(base):
        return P()
    return P(*((None,) * (rank - len(base)) + tuple(base)))


def _index_bits(idx) -> int:
    return dtype_bits(idx.dtype) if idx is not None else 32


class WeightFormat:
    """Base class; subclasses register with @register_format.

    `packed` marks sub-byte code layouts; `stream_bits` is the container
    bits-per-code the serving kernel streams (8 = unpacked uint8,
    4 = nibble, 3 = true bitstream; None = no LUT code stream, e.g.
    dense). `groupable` allows fusing same-format layers into one
    multi-projection kernel launch. `expert_fmt` names the
    stacked-experts counterpart a policy maps MoE expert weights to (None
    = this format cannot represent expert stacks — quantizing an MoE
    model under it is a loud error).
    """

    name: str = ""
    packed: bool = False
    stream_bits: Optional[int] = None
    groupable: bool = False
    expert_fmt: Optional[str] = None
    # nested (self-speculative) formats: width of the bit-prefix draft
    # sub-stream (0 = not nested — a draft pass serves full precision)
    draft_bits: int = 0

    # ------------------------------------------------------ container layout
    def code_cols(self, n: int) -> int:
        """Container columns (bytes) holding n codes per row."""
        assert self.stream_bits is not None, self.name
        return code_stream_bytes(n, self.stream_bits)

    def pack_codes(self, codes: jnp.ndarray) -> jnp.ndarray:
        """(m, n) uint8 canonical codes -> this container's layout."""
        raise NotImplementedError(self.name)

    def unpack_codes(self, codes: jnp.ndarray, n: int) -> jnp.ndarray:
        """Inverse of pack_codes; identity for unpacked layouts."""
        return codes

    # --------------------------------------------------------------- encode
    def encode(self, layer: QuantizedLinear) -> QuantizedLinear:
        """Re-layout a canonical (unpacked, fmt='lut'/'lut_sparse') layer."""
        raise NotImplementedError(self.name)

    # ---------------------------------------------------------------- apply
    def apply(self, layer, x2: jnp.ndarray, *, backend: str = "xla",
              draft_bits: int = 0) -> jnp.ndarray:
        """y = x2 @ W~^T for x2 (N, d_in); returns (N, d_out), no bias.

        `draft_bits` > 0 requests the speculative draft read: nested
        formats stream only their bit-prefix sub-stream and decode with
        the in-graph coarse codebook; every other format serves full
        precision (the draft is then exact — still a valid draft).
        """
        raise NotImplementedError(self.name)

    # ----------------------------------------------------------- dequantize
    def dequantize(self, layer) -> jnp.ndarray:
        raise NotImplementedError(self.name)

    # ------------------------------------------------------------- abstract
    def abstract(self, shape: Tuple[int, ...], bits: int, book_dtype,
                 code_dtype=jnp.uint8, qcfg=None):
        """ShapeDtypeStruct container for a dense param of `shape`
        ((*lead, d_in, d_out) — model layout, as stored in param trees).
        `qcfg` lets sparse-carrying formats size their outlier/full-row
        leaves exactly as the quantizer will emit them."""
        raise NotImplementedError(self.name)

    # ---------------------------------------------------------------- bits
    def storage_bits(self, layer) -> Tuple[float, int]:
        """(total storage bits, number of represented weights)."""
        raise NotImplementedError(self.name)

    # ------------------------------------------------------------- sharding
    def partition_spec(self, child: str, base, rank: int):
        """PartitionSpec for one container leaf, given the dense rule.

        `child` names the container field ('codes', 'codebook',
        'sparse_idx', ...), `base` is the dense parameter's rule spec tuple
        (None replicates everything) and `rank` the leaf's actual rank
        (stacked pattern-unit leaves carry extra leading dims, padded with
        None). The format owns its layout, so it owns how the dense rule
        maps onto each leaf — mirroring `CacheFormat.partition_spec` for
        serve caches. Default: apply the dense rule as-is (the layout
        matches the dense parameter)."""
        return pad_spec(base, rank)


# ---------------------------------------------------------------- dense fp

@register_format
class DenseFormat(WeightFormat):
    """Raw fp weights in model layout (d_in, d_out) — the fallthrough for
    everything the policy keeps in full precision."""

    name = "dense"

    def encode(self, layer):
        return layer

    def apply(self, w, x2, *, backend: str = "xla", draft_bits: int = 0):
        return x2 @ w.astype(x2.dtype)

    def dequantize(self, w):
        return w

    def abstract(self, shape, bits, book_dtype, code_dtype=jnp.uint8,
                 qcfg=None):
        return jax.ShapeDtypeStruct(shape, book_dtype)

    def storage_bits(self, w):
        return float(dtype_bits(w.dtype) * w.size), int(w.size)


# --------------------------------------------------------------- LUT family

def _sparse_full_bits(layer: QuantizedLinear) -> float:
    extra = 0.0
    if layer.sparse_val is not None:
        extra += layer.sparse_val.size * (dtype_bits(layer.sparse_val.dtype)
                                          + _index_bits(layer.sparse_idx))
    if layer.full_row_val is not None:
        extra += layer.full_row_val.size * dtype_bits(layer.full_row_val.dtype)
        extra += layer.full_row_idx.size * _index_bits(layer.full_row_idx)
    return extra


class _LUTBase(WeightFormat):
    """Shared apply/dequantize/abstract for per-row LUT layouts;
    subclasses set `stream_bits` and the pack/unpack pair."""

    def partition_spec(self, child: str, base, rank: int):
        """GANQ containers store (m=out, n=in) — TRANSPOSED vs the dense
        (in, out) weight — so the 2-D rule swaps for the code stream; the
        codebook / sparse-outlier / bias leaves carry the out (row) dim
        first and shard on it only; full fp rows replicate. Specs are
        written at the container's base rank; stacked pattern-unit leaves
        pad with leading Nones (the old path-index switch in
        `sharding.partition` silently never fired — FlattenedIndexKey
        carries `.key`, not `.idx` — so quantized leaves fell through to
        the dense-orientation rule; this is the fixed, format-owned
        mapping)."""
        from jax.sharding import PartitionSpec as P
        if base is None or len(base) != 2:
            return P()
        in_spec, out_spec = base
        if child == "codes":
            spec = (out_spec, in_spec)
        elif child in ("codebook", "sparse_idx", "sparse_val"):
            spec = (out_spec, None)
        elif child == "bias":
            spec = (out_spec,)
        else:                               # full_row_idx / full_row_val
            return P()
        return pad_spec(spec, rank)

    def draft_view(self, layer: QuantizedLinear):
        """(prefix codes (m, n) uint8, draft codebook (m, 2**db)) — the
        coarse model nested in this layer. Only meaningful for formats
        with `draft_bits` > 0."""
        db = self.draft_bits
        assert db > 0, self.name
        hi_cols = code_stream_bytes(layer.n_cols, db)
        codes = unpack_bits(layer.codes[..., :hi_cols], db, layer.n_cols)
        return codes, nested_codebooks(layer.codebook, db)

    def apply(self, layer: QuantizedLinear, x2, *, backend: str = "xla",
              draft_bits: int = 0):
        from repro.kernels.ops import lut_linear       # lazy: avoids cycle
        # non-nested layouts have no coarser prefix: their draft pass IS
        # the full-width read (an exact draft — correct, just not cheaper)
        db = draft_bits if self.draft_bits else 0
        assert db in (0, self.draft_bits), (db, self.draft_bits, self.name)
        if backend == "pallas":
            y = lut_linear(layer.codes, layer.codebook.astype(x2.dtype),
                           x2.T, bits=layer.bits, fmt=layer.fmt,
                           draft_bits=db).T
        elif db:
            codes, dbook = self.draft_view(layer)
            wd = jnp.take_along_axis(dbook, codes.astype(jnp.int32), axis=1)
            y = x2 @ wd.astype(x2.dtype).T
        else:
            wd = jnp.take_along_axis(layer.codebook,
                                     layer.unpacked_codes().astype(jnp.int32),
                                     axis=1)
            y = x2 @ wd.astype(x2.dtype).T
        if layer.sparse_val is not None:
            from .outliers import apply_sparse
            y = y + apply_sparse(layer.sparse_idx, layer.sparse_val,
                                 x2.T).T.astype(y.dtype)
        if layer.full_row_val is not None:
            y_full = x2 @ layer.full_row_val.astype(x2.dtype).T
            y = y.at[:, layer.full_row_idx].set(y_full)
        return y

    def dequantize(self, layer: QuantizedLinear) -> jnp.ndarray:
        w = jnp.take_along_axis(layer.codebook,
                                layer.unpacked_codes().astype(jnp.int32),
                                axis=1)
        if layer.sparse_val is not None:
            w = put_rows_sparse(w, layer.sparse_idx, layer.sparse_val)
        if layer.full_row_val is not None:
            w = w.at[layer.full_row_idx].set(
                layer.full_row_val.astype(w.dtype))
        return w

    def storage_bits(self, layer: QuantizedLinear):
        shape = layer.codes.shape          # possibly unit-stacked (*lead, m, nc)
        lead = 1
        for d in shape[:-1]:
            lead *= d
        n = layer.n_cols if self.packed else shape[-1]
        count = lead * n
        total = layer.bits * count \
            + layer.codebook.size * dtype_bits(layer.codebook.dtype) \
            + _sparse_full_bits(layer)
        return float(total), int(count)

    def abstract(self, shape, bits, book_dtype, code_dtype=jnp.uint8,
                 qcfg=None):
        *lead, din, dout = shape
        return QuantizedLinear(
            codes=jax.ShapeDtypeStruct((*lead, dout, self.code_cols(din)),
                                       code_dtype),
            codebook=jax.ShapeDtypeStruct((*lead, dout, 1 << bits),
                                          book_dtype),
            bits=bits, fmt=self.name, n_cols=din)


@register_format
class LUTFormat(_LUTBase):
    """Unpacked per-row LUT: codes (m, n) uint8, any bit width. The
    canonical in-graph form every quantizer emits."""

    name = "lut"
    packed = False
    stream_bits = 8
    groupable = True
    expert_fmt = "experts"

    def pack_codes(self, codes):
        return codes

    def encode(self, layer):
        assert not layer.packed, "already packed; decode first"
        return dataclasses.replace(layer, fmt=self.name,
                                   n_cols=layer.codes.shape[-1])


@register_format
class LUTSparseFormat(LUTFormat):
    """Unpacked LUT + structured sparse outliers / full fp rows (GANQ*,
    Algorithm 2). Same apply/dequantize as `lut` — the sparse fields are
    simply populated — but declared as its own format so policies can
    request it and storage accounting names it. Not groupable: the sparse
    correction is a per-layer side payload the fused launch cannot carry.
    """

    name = "lut_sparse"
    groupable = False

    def abstract(self, shape, bits, book_dtype, code_dtype=jnp.uint8,
                 qcfg=None):
        base = super().abstract(shape, bits, book_dtype, code_dtype)
        *lead, din, dout = shape
        if qcfg is not None and qcfg.outlier_ratio > 0:
            k = outlier_k(din, qcfg.outlier_ratio)
            base.sparse_idx = jax.ShapeDtypeStruct((*lead, dout, k),
                                                   jnp.int32)
            base.sparse_val = jax.ShapeDtypeStruct((*lead, dout, k),
                                                   book_dtype)
        if qcfg is not None and qcfg.full_rows > 0:
            base.full_row_idx = jax.ShapeDtypeStruct(
                (*lead, qcfg.full_rows), jnp.int32)
            base.full_row_val = jax.ShapeDtypeStruct(
                (*lead, qcfg.full_rows, din), book_dtype)
        return base


class _PackedLUT(_LUTBase):
    """Shared encode for sub-byte code containers; subclasses fix
    `stream_bits` and the pack/unpack pair."""

    packed = True
    groupable = True
    bits: int = 4

    def encode(self, layer):
        assert layer.bits <= self.bits, (layer.bits, self.bits)
        assert layer.sparse_val is None and layer.full_row_val is None, \
            "packed formats carry no sparse/full-row fields; use 'lut_sparse'"
        if layer.packed:
            assert get_format(layer.fmt).stream_bits == self.stream_bits, \
                (layer.fmt, self.name, "re-pack via decode first")
            return dataclasses.replace(layer, fmt=self.name)
        n = layer.codes.shape[-1]
        return dataclasses.replace(layer,
                                   codes=self.pack_codes(layer.codes),
                                   fmt=self.name, n_cols=n)


@register_format
class LUT4PackedFormat(_PackedLUT):
    """Nibble-packed codes (m, ceil(n/2)): two codes per uint8, streamed
    at 0.5 B/weight by the Pallas LUT-mpGEMM kernel."""

    name = "lut4_packed"
    bits = 4
    stream_bits = 4
    expert_fmt = "experts_packed"

    def pack_codes(self, codes):
        return pack_nibbles(codes)

    def unpack_codes(self, codes, n):
        return unpack_nibbles(codes, n)


@register_format
class LUT3PackedFormat(_PackedLUT):
    """True 3-bit bitstream: codes (m, ceil(n*3/8)) uint8
    (`core.packing.pack_bits` layout, byte-identical to the checkpoint
    stream), streamed at 3/8 B/weight by the phase-decomposed Pallas
    kernel — serving HBM bytes equal checkpoint bytes, no nibble
    alignment waste."""

    name = "lut3_packed"
    bits = 3
    stream_bits = 3
    expert_fmt = "experts3_packed"

    def pack_codes(self, codes):
        return pack_bits(codes, self.stream_bits)

    def unpack_codes(self, codes, n):
        return unpack_bits(codes, self.stream_bits, n)


@register_format
class LUT2PackedFormat(_PackedLUT):
    """True 2-bit bitstream: codes (m, ceil(n/4)) uint8 — four codes per
    byte, streamed at 1/4 B/weight. Same phase-decomposed kernel as
    'lut3_packed' (sb=2 -> g=1 byte plane, ph=4 phases), so the most
    aggressive width the precision search can allocate streams at its
    true container width too."""

    name = "lut2_packed"
    bits = 2
    stream_bits = 2
    expert_fmt = "experts2_packed"

    def pack_codes(self, codes):
        return pack_bits(codes, self.stream_bits)

    def unpack_codes(self, codes, n):
        return unpack_bits(codes, self.stream_bits, n)


# ----------------------------------------------------------------- nested

class _NestedLUT(_LUTBase):
    """4-bit nested bitstream — the self-speculative weight layout.

    Codes are stored as TWO concatenated `pack_bits` sub-streams per row:
    the high `draft_bits` of every (sorted-codebook) code as a contiguous
    prefix stream, then the low (4 - draft_bits) bits as the remainder:

        row = [ pack_bits(code >> rb, db) | pack_bits(code & mask, rb) ]

    so the db-bit draft model IS the leading ceil(n*db/8) bytes of the
    ONE weight buffer — a draft pass streams db/4 of the full read's code
    bytes through the existing bitstream kernel, and the verify pass
    reads both sub-streams and recombines (`lut_matmul_nested`). `encode`
    is the in-graph re-encoder: it sorts each row's codebook ascending
    (`nested_order`) so bit-prefix truncation yields a valid coarse
    codebook (Any-Precision LLM nesting), remaps codes, and dual-packs.
    Not groupable: the dual-stream layout has no fused multi-projection
    kernel (nested layers fall back to per-layer launches).
    """

    packed = True
    groupable = False
    bits = 4
    stream_bits = 4            # total bits/weight; code_cols is exact below

    def code_cols(self, n: int) -> int:
        hi, lo = nested_stream_cols(n, self.bits, self.draft_bits)
        return hi + lo

    def pack_codes(self, codes):
        return pack_bits_nested(codes, self.bits, self.draft_bits)

    def unpack_codes(self, codes, n):
        return unpack_bits_nested(codes, self.bits, self.draft_bits, n)

    def encode(self, layer):
        assert layer.bits == self.bits, (layer.bits, self.bits)
        assert layer.sparse_val is None and layer.full_row_val is None, \
            "nested formats carry no sparse/full-row fields"
        if layer.packed:
            if get_format(layer.fmt).draft_bits:
                assert layer.fmt == self.name, \
                    (layer.fmt, self.name, "re-encode via decode first")
                return layer
            # existing packed checkpoint: unpack in-graph, then nest
            layer = dataclasses.replace(
                layer, codes=get_format(layer.fmt).unpack_codes(
                    layer.codes, layer.n_cols), fmt="lut")
        n = layer.codes.shape[-1]
        book, codes = nested_order(layer.codebook, layer.codes)
        return dataclasses.replace(layer, codes=self.pack_codes(codes),
                                   codebook=book, fmt=self.name, n_cols=n)


@register_format
class Lut4NestedFormat(_NestedLUT):
    """4-bit nested, 3-bit draft prefix (draft reads 0.75x code bytes)."""

    name = "lut4_nested"
    draft_bits = 3
    expert_fmt = "experts4_nested"


@register_format
class Lut4NestedD2Format(_NestedLUT):
    """4-bit nested, 2-bit draft prefix (draft reads 0.5x code bytes)."""

    name = "lut4_nested_d2"
    draft_bits = 2
    expert_fmt = "experts4_nested_d2"


# ------------------------------------------------------------------ experts

class _ExpertsBase(WeightFormat):
    """Stacked per-expert LUTs: codes (E, m, n[/2]), codebook (E, m, L),
    optional GANQ* sparse outliers / full rows applied per expert.
    Applied via dequantize + batched einsum in models.moe (dispatch is
    token-routed; there is no single (N, d_in) matmul to intercept)."""

    def apply(self, layer, x2, *, backend: str = "xla",
              draft_bits: int = 0):
        raise NotImplementedError(
            "expert weights apply inside moe_apply via dequantize()")

    def dequantize(self, layer: QuantizedExperts) -> jnp.ndarray:
        codes = layer.codes
        if self.packed:
            e, m, cb = codes.shape
            codes = self.unpack_codes(codes.reshape(e * m, cb),
                                      layer.n_cols).reshape(e, m,
                                                            layer.n_cols)
        w = jnp.take_along_axis(layer.codebook, codes.astype(jnp.int32),
                                axis=2)                       # (E, m, n)
        if layer.sparse_val is not None:
            w = jax.vmap(put_rows_sparse)(w, layer.sparse_idx,
                                          layer.sparse_val)
        if layer.full_row_val is not None:
            w = jax.vmap(lambda we, idx, val:
                         we.at[idx].set(val.astype(we.dtype)))(
                             w, layer.full_row_idx, layer.full_row_val)
        return w

    def encode(self, layer: QuantizedExperts) -> QuantizedExperts:
        if self.packed and not layer.packed:
            assert layer.bits <= (self.stream_bits
                                  if self.stream_bits < 8 else 8), \
                (layer.bits, self.name)
            e, m, n = layer.codes.shape
            packed = self.pack_codes(layer.codes.reshape(e * m, n))
            return dataclasses.replace(layer,
                                       codes=packed.reshape(e, m, -1),
                                       fmt=self.name, n_cols=n)
        assert layer.packed == self.packed and (
            not self.packed
            or get_format(layer.fmt).stream_bits == self.stream_bits), \
            "container layout mismatch; decode first"   # no silent relabel
        return dataclasses.replace(layer, fmt=self.name,
                                   n_cols=layer.n_cols
                                   or layer.codes.shape[-1])

    def abstract(self, shape, bits, book_dtype, code_dtype=jnp.uint8,
                 qcfg=None):
        *lead, e, din, dout = shape
        nc = self.code_cols(din) if self.packed else din
        out = QuantizedExperts(
            codes=jax.ShapeDtypeStruct((*lead, e, dout, nc), code_dtype),
            codebook=jax.ShapeDtypeStruct((*lead, e, dout, 1 << bits),
                                          book_dtype),
            bits=bits, fmt=self.name, n_cols=din)
        if qcfg is not None and qcfg.outlier_ratio > 0:
            k = outlier_k(din, qcfg.outlier_ratio)
            out.sparse_idx = jax.ShapeDtypeStruct((*lead, e, dout, k),
                                                  jnp.int32)
            out.sparse_val = jax.ShapeDtypeStruct((*lead, e, dout, k),
                                                  book_dtype)
        if qcfg is not None and qcfg.full_rows > 0:
            out.full_row_idx = jax.ShapeDtypeStruct(
                (*lead, e, qcfg.full_rows), jnp.int32)
            out.full_row_val = jax.ShapeDtypeStruct(
                (*lead, e, qcfg.full_rows, din), book_dtype)
        return out

    def storage_bits(self, layer: QuantizedExperts):
        shape = layer.codes.shape
        lead = 1
        for d in shape[:-1]:
            lead *= d
        n = layer.n_cols if self.packed else shape[-1]
        count = lead * n
        total = layer.bits * count \
            + layer.codebook.size * dtype_bits(layer.codebook.dtype) \
            + _sparse_full_bits(layer)
        return float(total), int(count)


@register_format
class ExpertsFormat(_ExpertsBase):
    name = "experts"
    packed = False
    stream_bits = 8
    expert_fmt = "experts"

    def pack_codes(self, codes):
        return codes


@register_format
class ExpertsPackedFormat(_ExpertsBase):
    name = "experts_packed"
    packed = True
    stream_bits = 4
    expert_fmt = "experts_packed"

    def pack_codes(self, codes):
        return pack_nibbles(codes)

    def unpack_codes(self, codes, n):
        return unpack_nibbles(codes, n)


@register_format
class Experts3PackedFormat(_ExpertsBase):
    """Stacked per-expert true 3-bit bitstream: codes (E, m, ceil(n*3/8))
    — the experts counterpart of 'lut3_packed', so MoE expert weights
    under a 3-bit policy also hold checkpoint bytes in HBM."""

    name = "experts3_packed"
    packed = True
    stream_bits = 3
    expert_fmt = "experts3_packed"

    def pack_codes(self, codes):
        return pack_bits(codes, self.stream_bits)

    def unpack_codes(self, codes, n):
        return unpack_bits(codes, self.stream_bits, n)


@register_format
class Experts2PackedFormat(_ExpertsBase):
    """Stacked per-expert 2-bit bitstream: codes (E, m, ceil(n/4)) —
    'lut2_packed' for MoE expert weights."""

    name = "experts2_packed"
    packed = True
    stream_bits = 2
    expert_fmt = "experts2_packed"

    def pack_codes(self, codes):
        return pack_bits(codes, self.stream_bits)

    def unpack_codes(self, codes, n):
        return unpack_bits(codes, self.stream_bits, n)


class _NestedExperts(_ExpertsBase):
    """Stacked per-expert nested bitstream — `lut4_nested`'s MoE
    counterpart: codes (E, m, hi+lo cols), per-expert sorted codebooks.
    Decode routes through the shared `_ExpertsBase.dequantize`; the
    coarse books for a draft decode derive in-graph (`draft_books`)."""

    packed = True
    bits = 4
    stream_bits = 4

    def code_cols(self, n: int) -> int:
        hi, lo = nested_stream_cols(n, self.bits, self.draft_bits)
        return hi + lo

    def pack_codes(self, codes):
        return pack_bits_nested(codes, self.bits, self.draft_bits)

    def unpack_codes(self, codes, n):
        return unpack_bits_nested(codes, self.bits, self.draft_bits, n)

    def encode(self, layer: QuantizedExperts) -> QuantizedExperts:
        if layer.packed:
            assert layer.fmt == self.name, \
                (layer.fmt, self.name, "re-encode nested experts from "
                                       "unpacked; decode first")
            return layer
        assert layer.bits == self.bits, (layer.bits, self.bits)
        assert layer.sparse_val is None and layer.full_row_val is None, \
            "nested formats carry no sparse/full-row fields"
        book, codes = nested_order(layer.codebook, layer.codes)
        e, m, n = codes.shape
        packed = self.pack_codes(codes.reshape(e * m, n))
        return dataclasses.replace(layer, codes=packed.reshape(e, m, -1),
                                   codebook=book, fmt=self.name, n_cols=n)

    def draft_dequantize(self, layer: QuantizedExperts) -> jnp.ndarray:
        """(E, m, n) coarse weights from the prefix sub-stream only."""
        db = self.draft_bits
        e, m, cb = layer.codes.shape
        hi_cols = code_stream_bytes(layer.n_cols, db)
        codes = unpack_bits(layer.codes.reshape(e * m, cb)[:, :hi_cols],
                            db, layer.n_cols).reshape(e, m, layer.n_cols)
        books = nested_codebooks(layer.codebook, db)
        return jnp.take_along_axis(books, codes.astype(jnp.int32), axis=2)


@register_format
class Experts4NestedFormat(_NestedExperts):
    name = "experts4_nested"
    draft_bits = 3
    expert_fmt = "experts4_nested"


@register_format
class Experts4NestedD2Format(_NestedExperts):
    name = "experts4_nested_d2"
    draft_bits = 2
    expert_fmt = "experts4_nested_d2"


def nested_linear_fmt(draft_bits: int) -> str:
    """The nested (self-speculative) 4-bit linear format for a draft
    prefix width."""
    if draft_bits == 3:
        return "lut4_nested"
    if draft_bits == 2:
        return "lut4_nested_d2"
    raise ValueError(f"nested formats support draft_bits in {{2, 3}}, "
                     f"got {draft_bits}")


def packed_linear_fmt(bits: int) -> str:
    """The packed linear format for a bit width. 2- and 3-bit have their
    own true bitstream containers; 4-bit rides the nibble container."""
    if bits == 2:
        return "lut2_packed"
    if bits == 3:
        return "lut3_packed"
    if bits <= 4:
        return "lut4_packed"
    raise ValueError(f"no packed format for {bits}-bit codes")
