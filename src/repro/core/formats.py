"""WeightFormat registry: every serving weight layout as one object.

The deployment story of the paper is "same network, LUT-mpGEMM instead of
GEMM"; in practice a served model mixes *several* layouts — dense fp
embeddings, unpacked LUT for debugging, nibble-packed LUT-4/LUT-3 for HBM
bandwidth, LUT+sparse-outlier (GANQ*), stacked-experts LUT for MoE. Each
layout is a `WeightFormat` registered here and owns the full vertical:

  encode(layer)        canonical (unpacked) container -> this layout
  apply(layer, x2, backend)   y = x2 @ W~^T   (x2 is (N, d_in))
  dequantize(layer)    materialize W~ in GANQ layout ((m, n) / (E, m, n))
  abstract(shape, ...) ShapeDtypeStruct container for dry-runs
  storage_bits(layer)  (total_bits, n_weights) from the REAL dtypes

`models.linears.linear_apply`, `kernels.ops.lut_linear`,
`models.quantized.abstract_quantize` and `model_storage_report` all route
through this registry, so adding a layout is one class here — no flag
threading through model code.

Storage accounting counts codes at the true checkpoint bitstream width
(`bits` per weight — `core.packing.pack_bits_np`); the in-graph nibble
container of 3-bit codes spends 4 bits/weight for TPU alignment but is
not what hits the serving checkpoint. Codebook / sparse / full-row bits
derive from the actual array dtypes.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .outliers import outlier_k
from .packing import pack_nibbles, unpack_nibbles
from .types import QuantizedExperts, QuantizedLinear, put_rows_sparse

_FORMATS: Dict[str, "WeightFormat"] = {}


def register_format(cls):
    """Class decorator: instantiate and register under cls.name."""
    inst = cls()
    assert inst.name and inst.name not in _FORMATS, inst.name
    _FORMATS[inst.name] = inst
    return cls


def get_format(name: str) -> "WeightFormat":
    try:
        return _FORMATS[name]
    except KeyError:
        raise KeyError(f"unknown weight format {name!r}; "
                       f"available: {available_formats()}") from None


def available_formats():
    return sorted(_FORMATS)


def dtype_bits(dtype) -> int:
    return jnp.dtype(dtype).itemsize * 8


def _index_bits(idx) -> int:
    return dtype_bits(idx.dtype) if idx is not None else 32


class WeightFormat:
    """Base class; subclasses register with @register_format.

    `packed` marks nibble-packed code layouts. `expert_fmt` names the
    stacked-experts counterpart a policy maps MoE expert weights to (None
    = this format cannot represent expert stacks — quantizing an MoE
    model under it is a loud error).
    """

    name: str = ""
    packed: bool = False
    expert_fmt: Optional[str] = None

    # --------------------------------------------------------------- encode
    def encode(self, layer: QuantizedLinear) -> QuantizedLinear:
        """Re-layout a canonical (unpacked, fmt='lut'/'lut_sparse') layer."""
        raise NotImplementedError(self.name)

    # ---------------------------------------------------------------- apply
    def apply(self, layer, x2: jnp.ndarray, *,
              backend: str = "xla") -> jnp.ndarray:
        """y = x2 @ W~^T for x2 (N, d_in); returns (N, d_out), no bias."""
        raise NotImplementedError(self.name)

    # ----------------------------------------------------------- dequantize
    def dequantize(self, layer) -> jnp.ndarray:
        raise NotImplementedError(self.name)

    # ------------------------------------------------------------- abstract
    def abstract(self, shape: Tuple[int, ...], bits: int, book_dtype,
                 code_dtype=jnp.uint8, qcfg=None):
        """ShapeDtypeStruct container for a dense param of `shape`
        ((*lead, d_in, d_out) — model layout, as stored in param trees).
        `qcfg` lets sparse-carrying formats size their outlier/full-row
        leaves exactly as the quantizer will emit them."""
        raise NotImplementedError(self.name)

    # ---------------------------------------------------------------- bits
    def storage_bits(self, layer) -> Tuple[float, int]:
        """(total storage bits, number of represented weights)."""
        raise NotImplementedError(self.name)


# ---------------------------------------------------------------- dense fp

@register_format
class DenseFormat(WeightFormat):
    """Raw fp weights in model layout (d_in, d_out) — the fallthrough for
    everything the policy keeps in full precision."""

    name = "dense"

    def encode(self, layer):
        return layer

    def apply(self, w, x2, *, backend: str = "xla"):
        return x2 @ w.astype(x2.dtype)

    def dequantize(self, w):
        return w

    def abstract(self, shape, bits, book_dtype, code_dtype=jnp.uint8,
                 qcfg=None):
        return jax.ShapeDtypeStruct(shape, book_dtype)

    def storage_bits(self, w):
        return float(dtype_bits(w.dtype) * w.size), int(w.size)


# --------------------------------------------------------------- LUT family

def _sparse_full_bits(layer: QuantizedLinear) -> float:
    extra = 0.0
    if layer.sparse_val is not None:
        extra += layer.sparse_val.size * (dtype_bits(layer.sparse_val.dtype)
                                          + _index_bits(layer.sparse_idx))
    if layer.full_row_val is not None:
        extra += layer.full_row_val.size * dtype_bits(layer.full_row_val.dtype)
        extra += layer.full_row_idx.size * _index_bits(layer.full_row_idx)
    return extra


class _LUTBase(WeightFormat):
    """Shared apply/dequantize for per-row LUT layouts; subclasses set
    `packed` and the encode/abstract layout."""

    def apply(self, layer: QuantizedLinear, x2, *, backend: str = "xla"):
        from repro.kernels.ops import lut_linear       # lazy: avoids cycle
        if backend == "pallas":
            y = lut_linear(layer.codes, layer.codebook.astype(x2.dtype),
                           x2.T, bits=layer.bits, fmt=layer.fmt).T
        else:
            wd = jnp.take_along_axis(layer.codebook,
                                     layer.unpacked_codes().astype(jnp.int32),
                                     axis=1)
            y = x2 @ wd.astype(x2.dtype).T
        if layer.sparse_val is not None:
            from .outliers import apply_sparse
            y = y + apply_sparse(layer.sparse_idx, layer.sparse_val,
                                 x2.T).T.astype(y.dtype)
        if layer.full_row_val is not None:
            y_full = x2 @ layer.full_row_val.astype(x2.dtype).T
            y = y.at[:, layer.full_row_idx].set(y_full)
        return y

    def dequantize(self, layer: QuantizedLinear) -> jnp.ndarray:
        w = jnp.take_along_axis(layer.codebook,
                                layer.unpacked_codes().astype(jnp.int32),
                                axis=1)
        if layer.sparse_val is not None:
            w = put_rows_sparse(w, layer.sparse_idx, layer.sparse_val)
        if layer.full_row_val is not None:
            w = w.at[layer.full_row_idx].set(
                layer.full_row_val.astype(w.dtype))
        return w

    def storage_bits(self, layer: QuantizedLinear):
        shape = layer.codes.shape          # possibly unit-stacked (*lead, m, nc)
        lead = 1
        for d in shape[:-1]:
            lead *= d
        n = layer.n_cols if self.packed else shape[-1]
        count = lead * n
        total = layer.bits * count \
            + layer.codebook.size * dtype_bits(layer.codebook.dtype) \
            + _sparse_full_bits(layer)
        return float(total), int(count)


@register_format
class LUTFormat(_LUTBase):
    """Unpacked per-row LUT: codes (m, n) uint8, any bit width. The
    canonical in-graph form every quantizer emits."""

    name = "lut"
    packed = False
    expert_fmt = "experts"

    def encode(self, layer):
        assert not layer.packed, "already packed; decode first"
        return dataclasses.replace(layer, fmt=self.name,
                                   n_cols=layer.codes.shape[-1])

    def abstract(self, shape, bits, book_dtype, code_dtype=jnp.uint8,
                 qcfg=None):
        *lead, din, dout = shape
        return QuantizedLinear(
            codes=jax.ShapeDtypeStruct((*lead, dout, din), code_dtype),
            codebook=jax.ShapeDtypeStruct((*lead, dout, 1 << bits),
                                          book_dtype),
            bits=bits, fmt=self.name, n_cols=din)


@register_format
class LUTSparseFormat(LUTFormat):
    """Unpacked LUT + structured sparse outliers / full fp rows (GANQ*,
    Algorithm 2). Same apply/dequantize as `lut` — the sparse fields are
    simply populated — but declared as its own format so policies can
    request it and storage accounting names it."""

    name = "lut_sparse"

    def abstract(self, shape, bits, book_dtype, code_dtype=jnp.uint8,
                 qcfg=None):
        base = super().abstract(shape, bits, book_dtype, code_dtype)
        *lead, din, dout = shape
        if qcfg is not None and qcfg.outlier_ratio > 0:
            k = outlier_k(din, qcfg.outlier_ratio)
            base.sparse_idx = jax.ShapeDtypeStruct((*lead, dout, k),
                                                   jnp.int32)
            base.sparse_val = jax.ShapeDtypeStruct((*lead, dout, k),
                                                   book_dtype)
        if qcfg is not None and qcfg.full_rows > 0:
            base.full_row_idx = jax.ShapeDtypeStruct(
                (*lead, qcfg.full_rows), jnp.int32)
            base.full_row_val = jax.ShapeDtypeStruct(
                (*lead, qcfg.full_rows, din), book_dtype)
        return base


class _NibblePackedLUT(_LUTBase):
    """Nibble-packed codes (m, ceil(n/2)): two codes per uint8, the HBM
    layout the Pallas LUT-mpGEMM kernel streams at 0.5 B/weight."""

    packed = True
    expert_fmt = "experts_packed"
    bits: int = 4

    def encode(self, layer):
        assert layer.bits <= self.bits, (layer.bits, self.bits)
        assert layer.sparse_val is None and layer.full_row_val is None, \
            "packed formats carry no sparse/full-row fields; use 'lut_sparse'"
        if layer.packed:
            return dataclasses.replace(layer, fmt=self.name)
        n = layer.codes.shape[-1]
        return dataclasses.replace(layer, codes=pack_nibbles(layer.codes),
                                   fmt=self.name, n_cols=n)

    def abstract(self, shape, bits, book_dtype, code_dtype=jnp.uint8,
                 qcfg=None):
        *lead, din, dout = shape
        return QuantizedLinear(
            codes=jax.ShapeDtypeStruct((*lead, dout, (din + 1) // 2),
                                       code_dtype),
            codebook=jax.ShapeDtypeStruct((*lead, dout, 1 << bits),
                                          book_dtype),
            bits=bits, fmt=self.name, n_cols=din)


@register_format
class LUT4PackedFormat(_NibblePackedLUT):
    name = "lut4_packed"
    bits = 4


@register_format
class LUT3PackedFormat(_NibblePackedLUT):
    """3-bit codes riding the nibble container in-graph (TPU alignment;
    1 wasted bit); checkpoints store the true 3 bits/weight bitstream,
    which is what `storage_bits` counts."""

    name = "lut3_packed"
    bits = 3


# ------------------------------------------------------------------ experts

class _ExpertsBase(WeightFormat):
    """Stacked per-expert LUTs: codes (E, m, n[/2]), codebook (E, m, L),
    optional GANQ* sparse outliers / full rows applied per expert.
    Applied via dequantize + batched einsum in models.moe (dispatch is
    token-routed; there is no single (N, d_in) matmul to intercept)."""

    def apply(self, layer, x2, *, backend: str = "xla"):
        raise NotImplementedError(
            "expert weights apply inside moe_apply via dequantize()")

    def dequantize(self, layer: QuantizedExperts) -> jnp.ndarray:
        codes = layer.codes
        if self.packed:
            e, m, half = codes.shape
            codes = unpack_nibbles(codes.reshape(e * m, half),
                                   layer.n_cols).reshape(e, m, layer.n_cols)
        w = jnp.take_along_axis(layer.codebook, codes.astype(jnp.int32),
                                axis=2)                       # (E, m, n)
        if layer.sparse_val is not None:
            w = jax.vmap(put_rows_sparse)(w, layer.sparse_idx,
                                          layer.sparse_val)
        if layer.full_row_val is not None:
            w = jax.vmap(lambda we, idx, val:
                         we.at[idx].set(val.astype(we.dtype)))(
                             w, layer.full_row_idx, layer.full_row_val)
        return w

    def encode(self, layer: QuantizedExperts) -> QuantizedExperts:
        if self.packed and not layer.packed:
            assert layer.bits <= 4, (layer.bits, "nibble container")
            e, m, n = layer.codes.shape
            packed = pack_nibbles(layer.codes.reshape(e * m, n))
            return dataclasses.replace(layer,
                                       codes=packed.reshape(e, m, -1),
                                       fmt=self.name, n_cols=n)
        assert layer.packed == self.packed, \
            "already packed; decode first"          # no silent relabel
        return dataclasses.replace(layer, fmt=self.name,
                                   n_cols=layer.n_cols
                                   or layer.codes.shape[-1])

    def abstract(self, shape, bits, book_dtype, code_dtype=jnp.uint8,
                 qcfg=None):
        *lead, e, din, dout = shape
        nc = (din + 1) // 2 if self.packed else din
        out = QuantizedExperts(
            codes=jax.ShapeDtypeStruct((*lead, e, dout, nc), code_dtype),
            codebook=jax.ShapeDtypeStruct((*lead, e, dout, 1 << bits),
                                          book_dtype),
            bits=bits, fmt=self.name, n_cols=din)
        if qcfg is not None and qcfg.outlier_ratio > 0:
            k = outlier_k(din, qcfg.outlier_ratio)
            out.sparse_idx = jax.ShapeDtypeStruct((*lead, e, dout, k),
                                                  jnp.int32)
            out.sparse_val = jax.ShapeDtypeStruct((*lead, e, dout, k),
                                                  book_dtype)
        if qcfg is not None and qcfg.full_rows > 0:
            out.full_row_idx = jax.ShapeDtypeStruct(
                (*lead, e, qcfg.full_rows), jnp.int32)
            out.full_row_val = jax.ShapeDtypeStruct(
                (*lead, e, qcfg.full_rows, din), book_dtype)
        return out

    def storage_bits(self, layer: QuantizedExperts):
        shape = layer.codes.shape
        lead = 1
        for d in shape[:-1]:
            lead *= d
        n = layer.n_cols if self.packed else shape[-1]
        count = lead * n
        total = layer.bits * count \
            + layer.codebook.size * dtype_bits(layer.codebook.dtype) \
            + _sparse_full_bits(layer)
        return float(total), int(count)


@register_format
class ExpertsFormat(_ExpertsBase):
    name = "experts"
    packed = False
    expert_fmt = "experts"


@register_format
class ExpertsPackedFormat(_ExpertsBase):
    name = "experts_packed"
    packed = True
    expert_fmt = "experts_packed"


def packed_linear_fmt(bits: int) -> str:
    """The nibble-packed linear format for a bit width. 3-bit has its own
    name (true-bitstream storage accounting); other widths <= 4 ride the
    4-bit nibble container."""
    if bits == 3:
        return "lut3_packed"
    if bits <= 4:
        return "lut4_packed"
    raise ValueError(f"no packed format for {bits}-bit codes")
