"""Core containers for GANQ quantization.

Everything is a plain pytree (dataclass of arrays) so it composes with
jit/shard_map/checkpointing without a framework dependency.

`QuantizedLinear` / `QuantizedExperts` are *thin carriers*: arrays plus a
`fmt` tag naming a `WeightFormat` in `core.formats`. All behaviour —
matmul dispatch, dequantize, packing, storage accounting, abstract
(ShapeDtypeStruct) construction — lives in the format registry; the
methods here are convenience wrappers that delegate to it.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """Configuration of the GANQ quantizer (Algorithm 1 + Appendix A/B).

    Attributes:
      bits: target bit-width N (codebook size 2**N). Paper uses 3 and 4.
      iters: K, number of alternating (S-step, T-step) iterations.
      codebook_init: initial T^0: 'quantile' (per-row quantiles — default),
        'kmeans' (per-row 1-D k-means), or 'uniform' (per-row min/max grid,
        i.e. the RTN grid — useful for ablation).
      precondition: 'adaptive' (Appendix A diagonal dominance, eq. 23-24)
        or 'fixed' (Remark 3.1, H + lambda*I).
      damp: relative lambda for 'fixed' preconditioning (scaled by mean diag).
      outlier_ratio: r in Algorithm 2 (0 disables GANQ* outlier split).
      full_rows: number of highest-sensitivity rows kept in full precision
        (SqueezeLLM-compatible setting used for the Table-5 comparison).
      kmeans_iters: Lloyd iterations for 'kmeans' init.
      act_order: process columns in descending diag(H) order (GPTQ-style
        permutation; beyond-paper option, default off = paper-faithful).
    """

    bits: int = 4
    iters: int = 10
    codebook_init: str = "quantile"
    precondition: str = "adaptive"
    damp: float = 0.01
    outlier_ratio: float = 0.0
    full_rows: int = 0
    kmeans_iters: int = 10
    act_order: bool = False

    @property
    def levels(self) -> int:
        return 1 << self.bits


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QuantizedLinear:
    """LUT-quantized linear layer: W~[i, j] = codebook[i, codes[i, j]].

    Layout convention: rows are *output* channels (m = d_out), columns are
    input features (n = d_in), matching the paper's W (m x n) acting as W @ x.

    Fields:
      codes: (m, n) uint8 codebook indices, or the owning format's packed
        container — (m, ceil(n/2)) nibble-packed ('lut4_packed') or the
        true (m, ceil(n*bits/8)) bitstream ('lut3_packed'); values <
        2**bits.
      codebook: (m, 2**bits) fp values (the per-row LUT T).
      bits: static bit width.
      fmt: name of the owning `WeightFormat` ('lut', 'lut4_packed',
        'lut3_packed', 'lut_sparse', ...). The registry entry defines how
        codes are laid out, applied, dequantized and accounted.
      n_cols: original n (always set for packed formats; 0 means
        codes.shape[-1]).
      sparse_idx/sparse_val: optional structured outliers (m, k) — Algorithm 2
        residual kept in fp; applied as a per-row k-sparse matvec.
      full_row_idx/full_row_val: optional rows kept entirely in fp.
      bias: optional (m,).
    """

    codes: jax.Array
    codebook: jax.Array
    bits: int
    fmt: str = "lut"
    n_cols: int = 0               # original n when the format packs codes
    sparse_idx: Optional[jax.Array] = None
    sparse_val: Optional[jax.Array] = None
    full_row_idx: Optional[jax.Array] = None
    full_row_val: Optional[jax.Array] = None
    bias: Optional[jax.Array] = None

    # pytree child order — the single source consumers that pair children
    # with field names positionally (sharding.partition) must read
    CHILDREN = ("codes", "codebook", "sparse_idx", "sparse_val",
                "full_row_idx", "full_row_val", "bias")

    def tree_flatten(self):
        return tuple(getattr(self, f) for f in self.CHILDREN), \
            (self.bits, self.fmt, self.n_cols)

    @classmethod
    def tree_unflatten(cls, aux, children):
        bits, fmt, n_cols = aux
        return cls(bits=bits, fmt=fmt, n_cols=n_cols,
                   **dict(zip(cls.CHILDREN, children)))

    def _format(self):
        from .formats import get_format   # lazy: formats imports this module
        return get_format(self.fmt)

    @property
    def packed(self) -> bool:
        return self._format().packed

    @property
    def shape(self):
        n = self.n_cols if self.packed else self.codes.shape[-1]
        return (self.codes.shape[0], n)

    def unpacked_codes(self) -> jax.Array:
        if not self.packed:
            return self.codes
        return self._format().unpack_codes(self.codes, self.n_cols)

    def dequantize(self) -> jax.Array:
        """Materialize W~ (m, n) — reference/debug path."""
        return self._format().dequantize(self)

    def storage_bits_per_weight(self) -> float:
        total, count = self._format().storage_bits(self)
        return total / count


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QuantizedExperts:
    """Stacked per-expert LUT weights: codes (E, m, n[/2]), codebook (E, m, L).

    `fmt` names the owning format ('experts' unpacked / 'experts_packed'
    nibble-packed); decode and storage accounting route through it.
    Optional GANQ* fields ride alongside either layout: sparse outliers
    (E, m, k) and full-precision rows ((E, r) idx / (E, r, n) val), applied
    per expert at decode.
    """

    codes: jax.Array
    codebook: jax.Array
    bits: int
    fmt: str = "experts"
    n_cols: int = 0
    sparse_idx: Optional[jax.Array] = None
    sparse_val: Optional[jax.Array] = None
    full_row_idx: Optional[jax.Array] = None
    full_row_val: Optional[jax.Array] = None

    CHILDREN = ("codes", "codebook", "sparse_idx", "sparse_val",
                "full_row_idx", "full_row_val")

    def tree_flatten(self):
        return tuple(getattr(self, f) for f in self.CHILDREN), \
            (self.bits, self.fmt, self.n_cols)

    @classmethod
    def tree_unflatten(cls, aux, children):
        bits, fmt, n_cols = aux
        return cls(bits=bits, fmt=fmt, n_cols=n_cols,
                   **dict(zip(cls.CHILDREN, children)))

    def _format(self):
        from .formats import get_format
        return get_format(self.fmt)

    @property
    def packed(self) -> bool:
        return self._format().packed

    def dequantize(self, dtype) -> jax.Array:
        """(E, n, m) dense weights in the einsum layout (x @ w)."""
        w = self._format().dequantize(self)               # (E, m, n)
        return jnp.swapaxes(w, 1, 2).astype(dtype)

    def storage_bits_per_weight(self) -> float:
        total, count = self._format().storage_bits(self)
        return total / count


def put_rows_sparse(w: jax.Array, idx: jax.Array, val: jax.Array) -> jax.Array:
    """Scatter per-row sparse values: w[i, idx[i, k]] += val[i, k]."""
    m = w.shape[0]
    rows = jnp.broadcast_to(jnp.arange(m)[:, None], idx.shape)
    return w.at[rows, idx].add(val.astype(w.dtype))


@dataclasses.dataclass
class QuantResult:
    """Output of a layer quantization run."""

    layer: QuantizedLinear
    err_history: jax.Array  # (iters+1,) objective ||WX - W~X||_F^2 per iteration
    err_rtn: float | jax.Array | None = None  # same objective for RTN baseline
