"""Auto-precision search: sensitivity-profiled per-layer bit allocation
that emits servable `--policy` specs (ROADMAP item 5).

Closes the quality/speed loop training-free, in three stages:

1. **Sensitivity profiler** (`profile_sensitivity`): one
   `quantize_model_ptq` pass per candidate width — the per-layer
   `LayerQuantReport` dict that pass already produces IS the profile
   entry, so error/storage accounting can never drift from what the
   quantizer actually emitted. Entries tabulate
   `(group, width) -> (err, bits/weight, weight-bytes-read)`, the last
   from `kernels.ops.vmem_plan` (codes + codebook stream bytes).

2. **Budget-constrained allocator** (`search_policy`):
   sensitivity-ranked greedy (best err-reduction per cost at every
   step) followed by a Lagrangian refinement pass (bisect the price
   lambda; each group independently picks argmin(err + lambda*cost));
   the better of the two solutions is topped up greedily with any
   remaining slack. Cost modes: "bits" (code bits/weight — the
   checkpoint-stream accounting, default), "storage" (includes
   codebooks/sparse payloads, i.e. `LayerQuantReport.bits_per_weight`),
   "bytes" (decode-time HBM bytes from `vmem_plan`), "measured"
   (autotuner-cache microseconds via `kernels.tune.lookup`, normalized
   to a bits/weight-equivalent scale, byte-cost fallback for untimed
   shapes; `roofline.analysis.compiled_cost` gives the same signal for
   whole-graph costs).

3. **Spec emitter** (`emit_policy_spec`): serializes an allocation to
   the exact string `parse_policy` accepts, with `kv=`/`draft=`
   passthrough. Guarantee: `parse_policy(emit(alloc))` resolves every
   capture name AND every param-tree path to the original allocation
   (tests/test_bitsearch.py proves this over all registered configs).
   fnmatch metacharacters in layer names are escaped ("*" -> "[*]"),
   and literal rules are anchored by wrapping their first character in
   a character class ("layer3/..." -> "[l]ayer3/...") so
   `parse_policy` treats them as full-path fnmatch patterns rather
   than substring/segment shorthands.

Allocation granularity respects the stacking constraint
(models/transformer.py): pattern-unit layers are stacked per position,
so unit layers are grouped by (position-in-pattern, sublayer) across
all units; tail layers are free per layer; whisper stacks each side
whole, so enc/dec group per (side, sublayer).

Candidate widths are gated on kernel-parity proof: {2, 3, 4} serve
packed bitstream containers, {5, 6, 8} the unpacked byte stream
(tests/test_kernels_bitstream.py covers all six); anything else is
rejected with a ValueError naming the proven set.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional, Sequence, Tuple

from .formats import packed_linear_fmt
from .policy import LayerQuantReport, LayerRule, PrecisionPolicy
from .types import QuantConfig

PROFILE_SCHEMA = 1
#: widths with committed kernel parity tests — the allocator's universe
PROVEN_WIDTHS = (2, 3, 4, 5, 6, 8)
_PACKED_WIDTHS = (2, 3, 4)
FP_KEY = "fp"


def candidate_fmt(bits: int) -> str:
    """Serving format for a candidate width: true-bitstream packed
    containers for {2, 3, 4}, the unpacked byte stream for {5, 6, 8}.
    Unproven widths are rejected — the allocator must never emit a spec
    the kernels have no parity proof for."""
    if bits not in PROVEN_WIDTHS:
        raise ValueError(
            f"{bits}-bit has no kernel parity proof; proven widths are "
            f"{sorted(PROVEN_WIDTHS)} (tests/test_kernels_bitstream.py)")
    return packed_linear_fmt(bits) if bits in _PACKED_WIDTHS else "lut"


# ------------------------------------------------------------- escaping

def escape_pattern(name: str) -> str:
    """Escape a literal layer name into a `parse_policy` pattern that
    full-matches exactly that name.

    fnmatch metacharacters are neutralized via character classes
    ("*" -> "[*]", "?" -> "[?]", "[" -> "[[]"); if the result contains
    no "[", the first character is wrapped in one ("layer3/mlp/w_up" ->
    "[l]ayer3/mlp/w_up") — `parse_policy` would otherwise treat a bare
    subpath as a substring pattern (wrapping it in "*...*"), under
    which "layer3/mlp/w_up" also matches "layer13/mlp/w_up".
    """
    if "," in name or "=" in name:
        raise ValueError(f"layer name {name!r} cannot be spelled in the "
                         f"policy spec grammar (contains ',' or '=')")
    out = []
    for c in name:
        if c == "[":
            out.append("[[]")
        elif c in "*?":
            out.append(f"[{c}]")
        else:
            out.append(c)
    pat = "".join(out)
    if "[" not in pat:
        pat = f"[{pat[0]}]{pat[1:]}"
    return pat


# ---------------------------------------------------- allocation groups

@dataclasses.dataclass
class AllocGroup:
    """One independently-allocatable precision decision.

    Stacked positions must be depth-uniform (containers with different
    widths cannot stack into one leaf), so a group spans every layer
    that shares the stacked leaf."""

    key: str                 # stable id, e.g. "unit0:attn/wq"
    suffix: str              # sublayer subpath, e.g. "attn/wq"
    members: List[str]       # capture names ("layer3/attn/wq", ...)
    param_paths: List[str]   # param-tree literals ("stack/units/0/attn/wq")


def _decoder_layer_specs(cfg) -> List[Tuple[int, str, List[str]]]:
    """[(layer index, kind, [sublayer suffixes])] for decoder stacks."""
    from repro.models.quantized import QUANT_MOE, block_linear_specs
    from repro.models.transformer import pattern_split
    pattern, _, _ = pattern_split(cfg)
    out = []
    for li in range(cfg.n_layers):
        kind = pattern[li % len(pattern)]
        sfx = [cap for _, cap in block_linear_specs(kind, cfg)]
        if kind in ("attn", "local") and cfg.n_experts:
            sfx += list(QUANT_MOE)
        out.append((li, kind, sfx))
    return out


def model_layer_names(cfg) -> List[str]:
    """Every quantizable capture name of a config, in pipeline order."""
    if cfg.is_encoder_decoder:
        from repro.models.quantized import _BLOCK_LINEARS, _XATTN_LINEARS
        names = []
        for side, n in (("enc", cfg.n_encoder_layers), ("dec", cfg.n_layers)):
            specs = _BLOCK_LINEARS["attn"] + _BLOCK_LINEARS["mlp_gelu"] + (
                _XATTN_LINEARS if side == "dec" else [])
            for i in range(n):
                names += [f"{side}{i}/{cap}" for _, cap in specs]
        return names
    return [f"layer{li}/{s}" for li, _, sfx in _decoder_layer_specs(cfg)
            for s in sfx]


def allocation_groups(cfg) -> List[AllocGroup]:
    """Group capture names into independently-allocatable units under
    the stacking constraint."""
    groups: List[AllocGroup] = []
    if cfg.is_encoder_decoder:
        from repro.models.quantized import _BLOCK_LINEARS, _XATTN_LINEARS
        for side, n in (("enc", cfg.n_encoder_layers), ("dec", cfg.n_layers)):
            specs = _BLOCK_LINEARS["attn"] + _BLOCK_LINEARS["mlp_gelu"] + (
                _XATTN_LINEARS if side == "dec" else [])
            for _, cap in specs:
                groups.append(AllocGroup(
                    key=f"{side}:{cap}", suffix=cap,
                    members=[f"{side}{i}/{cap}" for i in range(n)],
                    param_paths=[f"stacks/{side}/{cap}"]))
        return groups
    from repro.models.transformer import pattern_split
    pattern, n_units, _ = pattern_split(cfg)
    P = len(pattern)
    specs = _decoder_layer_specs(cfg)
    by_pos: Dict[Tuple[int, str], AllocGroup] = {}
    for li, _, sfx in specs:
        if li < n_units * P:                       # stacked unit layer
            pos = li % P
            for s in sfx:
                g = by_pos.get((pos, s))
                if g is None:
                    g = AllocGroup(key=f"unit{pos}:{s}", suffix=s,
                                   members=[],
                                   param_paths=[f"stack/units/{pos}/{s}"])
                    by_pos[(pos, s)] = g
                    groups.append(g)
                g.members.append(f"layer{li}/{s}")
        else:                                      # tail layer: free
            ti = li - n_units * P
            for s in sfx:
                groups.append(AllocGroup(
                    key=f"tail{ti}:{s}", suffix=s,
                    members=[f"layer{li}/{s}"],
                    param_paths=[f"stack/tail/{ti}/{s}"]))
    return groups


# -------------------------------------------------- sensitivity profile

@dataclasses.dataclass
class SensitivityProfile:
    """`(group, width) -> cost/error` table plus the group structure it
    was measured over; JSON round-trips for offline inspection and
    warm-started searches."""

    arch: str
    groups: Dict[str, Dict]           # key -> {suffix, members,
                                      #   param_paths, n_weights, shape}
    entries: Dict[str, Dict[str, Dict]]   # key -> width key -> {err,
                                      #   bits_per_weight, fmt, bits,
                                      #   weight_bytes}
    meta: Dict = dataclasses.field(default_factory=dict)

    def widths(self) -> List[str]:
        ws = set()
        for per in self.entries.values():
            ws |= set(per)
        return sorted(ws, key=lambda w: -1 if w == FP_KEY else int(w))

    def total_weights(self) -> int:
        return sum(g["n_weights"] for g in self.groups.values())

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump({"schema": PROFILE_SCHEMA, "arch": self.arch,
                       "groups": self.groups, "entries": self.entries,
                       "meta": self.meta}, f, indent=1, sort_keys=True)

    @classmethod
    def load(cls, path: str) -> "SensitivityProfile":
        with open(path) as f:
            d = json.load(f)
        if d.get("schema") != PROFILE_SCHEMA:
            raise ValueError(f"unsupported profile schema "
                             f"{d.get('schema')!r} in {path}")
        return cls(arch=d.get("arch", ""), groups=d["groups"],
                   entries=d["entries"], meta=d.get("meta", {}))


def _stream_bytes(m: int, n: int, bits: int, fmt: str, decode_p: int,
                  n_weights: int) -> float:
    """Decode-time HBM bytes for one group's weights (codes + codebook
    stream), scaled to the group's total weight count (covers stacked
    units and MoE expert leading dims)."""
    from repro.kernels.ops import vmem_plan
    plan = vmem_plan(m, n, decode_p, bits, fmt=fmt)
    per_layer = plan["codes_bytes"] + plan["lut_bytes"]
    return per_layer * (n_weights / (m * n))


def profile_sensitivity(params, cfg, batch, widths: Sequence[int] = (2, 3, 4),
                        qcfg: Optional[QuantConfig] = None,
                        method: str = "ganq", ctx=None,
                        include_fp: bool = True, decode_p: int = 8,
                        warm: Optional[SensitivityProfile] = None,
                        arch: str = "") -> SensitivityProfile:
    """Quantize the model once per candidate width and tabulate the
    per-group (err, bits/weight, weight-bytes-read) surface.

    Reuses `quantize_model_ptq`'s report path: each width is one
    ordinary uniform-policy PTQ pass whose `LayerQuantReport` dict is
    aggregated per allocation group. `warm=` (a previously saved
    profile over the same group structure) skips widths it already
    covers, so a saved profile makes re-search free."""
    from repro.models.quantized import quantize_model_ptq
    from repro.sharding.context import LOCAL
    if ctx is None:
        ctx = LOCAL
    qcfg = qcfg or QuantConfig(bits=4, iters=4, precondition="fixed")
    groups = allocation_groups(cfg)
    gdesc: Dict[str, Dict] = {
        g.key: {"suffix": g.suffix, "members": g.members,
                "param_paths": g.param_paths, "n_weights": 0, "shape": None}
        for g in groups}
    by_member = {m: g.key for g in groups for m in g.members}
    entries: Dict[str, Dict[str, Dict]] = {g.key: {} for g in groups}

    warm_ok = (warm is not None
               and set(warm.groups) == set(gdesc)
               and all(warm.groups[k]["members"] == gdesc[k]["members"]
                       for k in gdesc))
    if warm_ok:
        for k, per in warm.entries.items():
            entries[k].update(per)
        for k in gdesc:
            if warm.groups[k].get("n_weights"):
                gdesc[k]["n_weights"] = warm.groups[k]["n_weights"]
                gdesc[k]["shape"] = warm.groups[k]["shape"]

    def ingest(report: Dict[str, LayerQuantReport], wkey: str,
               fmt: str, bits: Optional[int]) -> None:
        agg: Dict[str, Dict] = {}
        for name, rep in report.items():
            gkey = by_member.get(name)
            if gkey is None:
                continue
            a = agg.setdefault(gkey, {"err": 0.0, "bits": 0.0, "w": 0})
            a["err"] += float(rep.err)
            a["bits"] += rep.bits_per_weight * rep.n_weights
            a["w"] += rep.n_weights
            if gdesc[gkey]["shape"] is None and rep.shape is not None:
                gdesc[gkey]["shape"] = list(rep.shape)
        for gkey, a in agg.items():
            if not gdesc[gkey]["n_weights"]:
                gdesc[gkey]["n_weights"] = a["w"]
            m, n = gdesc[gkey]["shape"] or (1, 1)
            if bits is None:
                wb = a["bits"] / a["w"] / 8.0 * a["w"]
            else:
                wb = _stream_bytes(m, n, bits, fmt, decode_p, a["w"])
            entries[gkey][wkey] = {
                "err": a["err"], "bits_per_weight": a["bits"] / a["w"],
                "fmt": fmt if bits is not None else "dense",
                "bits": bits, "weight_bytes": wb}

    for b in widths:
        wkey = str(int(b))
        fmt = candidate_fmt(int(b))
        if all(wkey in entries[g.key] for g in groups):
            continue
        pol = PrecisionPolicy(
            qcfg=dataclasses.replace(qcfg, bits=int(b)), method=method,
            fmt=fmt)
        _, report = quantize_model_ptq(params, cfg, batch, ctx=ctx,
                                       policy=pol)
        ingest(report, wkey, fmt, int(b))

    if include_fp and not all(FP_KEY in entries[g.key] for g in groups):
        pol = PrecisionPolicy(qcfg=qcfg, method=method, fmt="lut",
                              rules=(LayerRule(pattern="*", keep_fp=True),))
        _, report = quantize_model_ptq(params, cfg, batch, ctx=ctx,
                                       policy=pol)
        ingest(report, FP_KEY, "dense", None)

    return SensitivityProfile(
        arch=arch, groups=gdesc, entries=entries,
        meta={"method": method, "decode_p": decode_p,
              "widths": [int(b) for b in widths], "include_fp": include_fp,
              "qcfg_bits": qcfg.bits, "qcfg_iters": qcfg.iters})


# ------------------------------------------------------------ allocator

@dataclasses.dataclass
class SearchResult:
    choice: Dict[str, str]       # group key -> width key
    spec: str                    # servable --policy string
    bits_per_weight: float       # achieved, code-bits accounting
    storage_bits_per_weight: float   # achieved, incl. codebooks
    total_err: float             # summed layer objective
    budget: float
    cost_mode: str
    predicted: Dict[str, float] = dataclasses.field(default_factory=dict)


def _group_costs(profile: SensitivityProfile, cost: str,
                 widths: Optional[Sequence[int]],
                 include_fp: bool) -> Dict[str, Dict[str, float]]:
    """cost[group][width key] in *bits* (all modes normalized so a
    budget is always expressed as bits/weight)."""
    from repro.kernels import tune
    decode_p = int(profile.meta.get("decode_p", 8))
    allowed = None
    if widths is not None:
        for b in widths:
            candidate_fmt(int(b))              # reject unproven widths
        allowed = {str(int(b)) for b in widths}
    costs: Dict[str, Dict[str, float]] = {}
    measured: Dict[Tuple[str, str], float] = {}
    for gkey, per in profile.entries.items():
        w = profile.groups[gkey]["n_weights"]
        shape = profile.groups[gkey]["shape"] or (1, 1)
        costs[gkey] = {}
        for wkey, e in per.items():
            if wkey == FP_KEY:
                if not include_fp:
                    continue
            elif allowed is not None and wkey not in allowed:
                continue
            if cost == "bits":
                bpw = (e["bits_per_weight"] if e["bits"] is None
                       else float(e["bits"]))
                c = bpw * w
            elif cost == "storage":
                c = e["bits_per_weight"] * w
            elif cost in ("bytes", "measured"):
                c = 8.0 * e["weight_bytes"]
                if cost == "measured" and e["bits"] is not None:
                    m, n = shape
                    plan = tune.lookup(int(m), int(n), decode_p,
                                       int(e["bits"]), e["fmt"])
                    if plan is not None and plan.us > 0:
                        measured[(gkey, wkey)] = plan.us * (
                            w / (int(m) * int(n)))
            else:
                raise ValueError(f"unknown cost mode {cost!r}; use "
                                 f"bits|storage|bytes|measured")
            costs[gkey][wkey] = c
        if not costs[gkey]:
            raise ValueError(f"group {gkey!r} has no candidate widths "
                             f"under widths={widths} include_fp="
                             f"{include_fp}")
    if cost == "measured" and measured:
        # normalize tuner microseconds onto the byte-cost scale so timed
        # and untimed (byte-fallback) groups share one budget axis
        ref_c = sum(costs[g][k] for (g, k) in measured)
        ref_us = sum(measured.values())
        scale = ref_c / ref_us if ref_us > 0 else 0.0
        for (g, k), us in measured.items():
            if scale > 0:
                costs[g][k] = us * scale
    return costs


def _err_of(profile: SensitivityProfile, gkey: str, wkey: str) -> float:
    return float(profile.entries[gkey][wkey]["err"])


def search_policy(profile: SensitivityProfile, budget: float,
                  cost: str = "bits",
                  widths: Optional[Sequence[int]] = None,
                  include_fp: bool = True, kv: Optional[str] = None,
                  draft: int = 0) -> SearchResult:
    """Pick per-group widths minimizing summed layer error under a
    bits/weight budget.

    Greedy phase: start every group at its cheapest candidate, then
    repeatedly apply the affordable upgrade with the best error
    reduction per extra bit. Lagrangian refinement: bisect a price
    lambda where each group independently picks
    argmin(err + lambda * cost); the cheapest feasible pricing is kept
    if it beats greedy, and any remaining slack is consumed by one more
    greedy pass. Infeasible budgets raise with the minimum achievable
    bits/weight."""
    costs = _group_costs(profile, cost, widths, include_fp)
    total_w = profile.total_weights()
    budget_bits = budget * total_w

    def total_cost(ch):
        return sum(costs[g][k] for g, k in ch.items())

    def total_err(ch):
        return sum(_err_of(profile, g, k) for g, k in ch.items())

    def greedy_fill(ch):
        """Upgrade toward lower error while the budget allows."""
        while True:
            slack = budget_bits - total_cost(ch)
            best = None
            for g, cur in ch.items():
                ce, cc = _err_of(profile, g, cur), costs[g][cur]
                for k, kc in costs[g].items():
                    ke = _err_of(profile, g, k)
                    if ke >= ce or kc - cc > slack:
                        continue
                    gain = (ce - ke) / max(kc - cc, 1e-9)
                    if best is None or gain > best[0]:
                        best = (gain, g, k)
            if best is None:
                return ch
            ch[best[1]] = best[2]

    # -- greedy from the cheapest feasible point
    choice = {g: min(per, key=lambda k: (per[k], _err_of(profile, g, k)))
              for g, per in costs.items()}
    min_cost = total_cost(choice)
    if min_cost > budget_bits + 1e-6:
        raise ValueError(
            f"budget {budget:g} bits/weight infeasible: minimum "
            f"achievable is {min_cost / total_w:.3f} with the given "
            f"candidate set")
    greedy = greedy_fill(dict(choice))

    # -- Lagrangian pricing, bisected to the cheapest feasible lambda
    def priced(lam):
        return {g: min(per, key=lambda k: (
            _err_of(profile, g, k) + lam * per[k], per[k]))
            for g, per in costs.items()}

    lo, hi = 0.0, 1.0
    for _ in range(60):                      # find an upper bracket
        if total_cost(priced(hi)) <= budget_bits:
            break
        hi *= 4.0
    lagr = None
    if total_cost(priced(hi)) <= budget_bits:
        for _ in range(64):
            mid = 0.5 * (lo + hi)
            if total_cost(priced(mid)) <= budget_bits:
                hi = mid
            else:
                lo = mid
        lagr = greedy_fill(priced(hi))

    best = greedy
    if lagr is not None and (total_err(lagr), total_cost(lagr)) < (
            total_err(best), total_cost(best)):
        best = lagr

    spec = emit_policy_spec(profile, best, kv=kv, draft=draft)
    code_bits = 0.0
    storage_bits = 0.0
    for g, k in best.items():
        e = profile.entries[g][k]
        w = profile.groups[g]["n_weights"]
        code_bits += (e["bits_per_weight"] if e["bits"] is None
                      else float(e["bits"])) * w
        storage_bits += e["bits_per_weight"] * w
    return SearchResult(
        choice=best, spec=spec,
        bits_per_weight=code_bits / total_w,
        storage_bits_per_weight=storage_bits / total_w,
        total_err=total_err(best), budget=budget, cost_mode=cost,
        predicted={"cost_bits_per_weight": total_cost(best) / total_w})


# -------------------------------------------------------------- emitter

def _choice_value(entry: Dict) -> str:
    if entry["bits"] is None:
        return FP_KEY
    return f"{entry['bits']}@{entry['fmt']}"


def emit_policy_spec(profile: SensitivityProfile,
                     choice: Dict[str, str], kv: Optional[str] = None,
                     draft: int = 0) -> str:
    """Serialize an allocation to the exact `--policy` grammar.

    Compaction: when every group sharing a sublayer suffix picked the
    same value, one `*/suffix=value` wildcard covers them all (it
    matches capture names and param-tree paths alike — fnmatch `*`
    crosses `/`). Disagreeing suffixes fall back to escaped literal
    rules for every member name plus the groups' param-tree paths, so
    `abstract_quantize` (dry-run) resolves identically to the live
    pipeline. Literal rules precede wildcards; wildcard suffixes are
    ordered longest-first so e.g. `*/xattn/wq` wins over `*/attn/wq`.
    """
    by_suffix: Dict[str, List[str]] = {}
    for gkey in choice:
        by_suffix.setdefault(profile.groups[gkey]["suffix"], []).append(gkey)

    literal, wildcard = [], []
    for suffix, gkeys in by_suffix.items():
        vals = {_choice_value(profile.entries[g][choice[g]]) for g in gkeys}
        if len(vals) == 1:
            wildcard.append((suffix, vals.pop()))
            continue
        for g in gkeys:
            val = _choice_value(profile.entries[g][choice[g]])
            for name in (profile.groups[g]["members"]
                         + profile.groups[g]["param_paths"]):
                literal.append((escape_pattern(name), val))
    wildcard.sort(key=lambda sv: (-len(sv[0]), sv[0]))
    parts = [f"{p}={v}" for p, v in literal]
    parts += [f"*/{s}={v}" for s, v in wildcard]
    if kv:
        parts.append(f"kv={kv}")
    if draft:
        parts.append(f"draft={draft}")
    return ",".join(parts)


# -------------------------------------------------------- CLI front end

@dataclasses.dataclass
class AutoSpec:
    budget: float
    cost: str = "bits"
    widths: Optional[Tuple[int, ...]] = None
    include_fp: bool = True
    kv: Optional[str] = None
    draft: int = 0


def parse_auto_spec(spec: str) -> AutoSpec:
    """Parse `--auto-policy` strings:
    ``budget=3.4[,cost=bits|storage|bytes|measured][,cands=2+3+4]
    [,fp=0|1][,kv=<fmt>][,draft=N]`` (candidate widths are
    "+"-separated because "," separates entries)."""
    budget = None
    kw: Dict[str, object] = {}
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        if "=" not in part:
            raise ValueError(f"auto-policy entry {part!r} is not key=value")
        key, val = part.split("=", 1)
        key, val = key.strip(), val.strip()
        if key == "budget":
            budget = float(val)
        elif key == "cost":
            kw["cost"] = val
        elif key == "cands":
            kw["widths"] = tuple(int(b) for b in val.split("+") if b)
        elif key == "fp":
            kw["include_fp"] = bool(int(val))
        elif key == "kv":
            kw["kv"] = val
        elif key == "draft":
            kw["draft"] = int(val)
        else:
            raise ValueError(f"unknown auto-policy key {key!r}")
    if budget is None:
        raise ValueError("auto-policy spec needs budget=<bits/weight>")
    return AutoSpec(budget=budget, **kw)


# ----------------------------------------------------------- report IO

def save_report(report: Dict[str, LayerQuantReport], path: str,
                extra: Optional[Dict] = None) -> None:
    """Serialize a per-layer `LayerQuantReport` dict to JSON."""
    out = {"schema": 1,
           "layers": {name: rep.to_dict() for name, rep in report.items()}}
    if extra:
        out.update(extra)
    with open(path, "w") as f:
        json.dump(out, f, indent=1, sort_keys=True)


def load_report(path: str) -> Dict[str, LayerQuantReport]:
    with open(path) as f:
        d = json.load(f)
    return {name: LayerQuantReport.from_dict(rep)
            for name, rep in d["layers"].items()}
