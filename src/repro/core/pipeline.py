"""Sequential layer-wise PTQ driver (paper §3 protocol).

The paper quantizes decoder layers sequentially: each layer's H = X X^T is
accumulated from calibration activations produced by the *already-quantized*
prefix, then its linears are quantized and the (quantized) outputs propagate
forward. This module provides the model-agnostic machinery:

  * `HCollector` — streaming accumulation of per-linear H (and token counts),
    fed by model forward passes run in "capture mode" (models/*.py blocks
    call `collector.add(name, x)` on the 2-D inputs of every linear).
  * `quantize_linear` — dispatch on (W, H) to any quantizer registered with
    `@register_quantizer` (ganq / gptq / rtn / squeezellm / awq built in;
    out-of-tree methods register the same way — no string chain to edit).
  * `SequentialPTQ` — the per-block loop: capture -> quantize -> propagate.

The model-facing half (walking a concrete parameter tree) lives in
models/quantized.py; this file holds the reusable numerics so it is testable
without any model.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from .ganq import compute_h, ganq_quantize, h_from_tokens, layer_objective
from .gptq import gptq_quantize
from .rtn import rtn_codebook, rtn_quantize
from .types import QuantConfig, QuantResult, QuantizedLinear


class HCollector:
    """Accumulates H = sum_t x_t x_t^T per named linear, streaming over batches."""

    def __init__(self):
        self.h: Dict[str, jnp.ndarray] = {}
        self.count: Dict[str, int] = {}

    def add(self, name: str, x: jnp.ndarray) -> None:
        """x: (..., n) activations entering linear `name`."""
        x2 = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
        h = x2.T @ x2
        if name in self.h:
            self.h[name] = self.h[name] + h
            self.count[name] += x2.shape[0]
        else:
            self.h[name] = h
            self.count[name] = x2.shape[0]

    def get(self, name: str) -> jnp.ndarray:
        return self.h[name]

    def names(self):
        return list(self.h.keys())


# ------------------------------------------------------- quantizer registry

_QUANTIZERS: Dict[str, Callable] = {}


def register_quantizer(name: str):
    """Decorator: register fn(w, h, cfg, bias) -> QuantResult under `name`.

    Every registered method must emit a `QuantizedLinear` so every baseline
    runs on the same LUT-mpGEMM deployment path (the paper's
    apples-to-apples setting) and composes with `PrecisionPolicy` rules.
    """
    def deco(fn: Callable) -> Callable:
        assert name not in _QUANTIZERS, name
        _QUANTIZERS[name] = fn
        return fn
    return deco


def available_quantizers():
    return sorted(_QUANTIZERS)


def quantize_linear(w: jnp.ndarray, h: jnp.ndarray, cfg: QuantConfig,
                    method: str = "ganq",
                    bias: Optional[jnp.ndarray] = None) -> QuantResult:
    """Quantize one (m, n) weight with a registered method."""
    try:
        fn = _QUANTIZERS[method]
    except KeyError:
        raise ValueError(f"unknown method {method!r}; "
                         f"available: {available_quantizers()}") from None
    return fn(w, h, cfg, bias)


@register_quantizer("ganq")
def _ganq(w, h, cfg, bias) -> QuantResult:
    return ganq_quantize(w, h=h, cfg=cfg, bias=bias)


@register_quantizer("gptq")
def _gptq(w, h, cfg, bias) -> QuantResult:
    codes, wq = gptq_quantize(w, h, cfg.bits, damp=max(cfg.damp, 0.01))
    # express the affine grid as a per-row LUT so serving is uniform
    t = rtn_codebook(w, cfg.bits)
    layer = QuantizedLinear(codes=codes, codebook=t, bits=cfg.bits, bias=bias)
    err = layer_objective(jnp.asarray(w, jnp.float32), wq, h)
    return QuantResult(layer=layer, err_history=err[None])


@register_quantizer("rtn")
def _rtn(w, h, cfg, bias) -> QuantResult:
    codes, _, _ = rtn_quantize(w, cfg.bits)
    t = rtn_codebook(w, cfg.bits)
    layer = QuantizedLinear(codes=codes, codebook=t, bits=cfg.bits, bias=bias)
    wq = layer.dequantize()
    err = layer_objective(jnp.asarray(w, jnp.float32), wq, h)
    return QuantResult(layer=layer, err_history=err[None])


@register_quantizer("squeezellm")
def _squeezellm(w, h, cfg, bias) -> QuantResult:
    # sensitivity-weighted k-means codebook + nearest assignment
    # (SqueezeLLM, the paper's Table-5 LUT baseline; diagonal-H proxy
    # for the Fisher sensitivity, no cross-column error feedback)
    from .codebook import assign_nearest, weighted_kmeans
    wf = jnp.asarray(w, jnp.float32)
    t = weighted_kmeans(wf, jnp.diag(h), cfg.bits, cfg.kmeans_iters)
    codes = assign_nearest(wf, t).astype(jnp.uint8)
    layer = QuantizedLinear(codes=codes, codebook=t, bits=cfg.bits,
                            bias=bias)
    err = layer_objective(wf, layer.dequantize(), h)
    return QuantResult(layer=layer, err_history=err[None])


@register_quantizer("awq")
def _awq(w, h, cfg, bias) -> QuantResult:
    # AWQ-style (Lin et al. '24): activation-aware per-input-channel
    # scaling folded around a group-128 RTN grid; layer-level baseline
    # (the runtime scale-folding into the previous op is assumed, as in
    # the reference implementation)
    wf = jnp.asarray(w, jnp.float32)
    act_scale = jnp.sqrt(jnp.maximum(jnp.diag(h), 1e-12))
    s = jnp.power(act_scale / jnp.mean(act_scale), 0.5)
    n = wf.shape[1]
    gs = 128 if n % 128 == 0 else None
    from .rtn import rtn_reconstruct
    wq = rtn_reconstruct(wf * s[None, :], cfg.bits, group_size=gs) \
        / s[None, :]
    # store via per-row LUT of the scaled grid for uniform serving
    codes, _, _ = rtn_quantize(wf * s[None, :], cfg.bits)
    t = rtn_codebook(wf * s[None, :], cfg.bits)
    layer = QuantizedLinear(codes=codes, codebook=t, bits=cfg.bits,
                            bias=bias)
    err = layer_objective(wf, wq, h)
    return QuantResult(layer=layer, err_history=err[None])


@dataclasses.dataclass
class SequentialPTQ:
    """Block-by-block PTQ: capture H under the quantized prefix, quantize,
    propagate quantized activations.

    Args:
      block_forward: fn(block_params, acts, collector|None) -> acts. When a
        collector is passed the block must record every linear input.
      quantize_block: fn(block_params, {name: H}, cfg) -> quantized params.
      cfg: quantizer config.
      method: any name in `available_quantizers()`.
    """

    block_forward: Callable
    quantize_block: Callable
    cfg: QuantConfig
    method: str = "ganq"

    def run(self, blocks_params: list, acts: jnp.ndarray):
        """blocks_params: list of per-block param trees; acts: embedded calib
        activations (batch, seq, d). Returns (quantized blocks, final acts)."""
        out_blocks = []
        for bp in blocks_params:
            col = HCollector()
            self.block_forward(bp, acts, col)              # capture pass
            qbp = self.quantize_block(bp, col, self.cfg, self.method)
            acts = self.block_forward(qbp, acts, None)     # propagate quantized
            out_blocks.append(qbp)
        return out_blocks, acts
