"""Distributed GANQ quantization (shard_map).

The paper's central scalability claim is that the MIQP decomposes across the
m output rows (eq. 2) — on a pod this means the quantization itself shards:

  * rows of W over the 'model' axis (embarrassingly parallel S/T steps —
    zero collectives in the solver);
  * calibration tokens over the 'data' axis for H accumulation
    (one psum of an (n, n) Gram matrix per linear).

`quantize_layer_sharded` quantizes a 7B-scale layer across a full pod with
per-device row blocks; this is also how expert FFNs are quantized under EP
(each expert's rows live with its shard).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.sharding.compat import shard_map

from .ganq import _ganq_core
from .types import QuantConfig


def compute_h_sharded(mesh: Mesh, x_local_spec: P = P("data", None)):
    """Returns a jitted fn: activations (tokens, n) sharded over 'data'
    -> replicated H (n, n) via psum."""

    @partial(shard_map, mesh=mesh, in_specs=(x_local_spec,),
             out_specs=P(), check_vma=False)
    def _h(x):
        x = x.astype(jnp.float32)
        h_local = x.T @ x
        return jax.lax.psum(h_local, axis_name="data")

    return jax.jit(_h)


def quantize_layer_sharded(mesh: Mesh, w: jnp.ndarray, h: jnp.ndarray,
                           cfg: QuantConfig, row_axis: str = "model"):
    """GANQ on W (m, n) with rows sharded over `row_axis`; H replicated.

    Returns (codes (m, n) uint8, codebook (m, 2^bits) f32, err_history) with
    the same sharding as W's rows. No inter-device communication inside the
    solver — the paper's row-decomposability realized at pod scale.
    """

    @partial(shard_map, mesh=mesh,
             in_specs=(P(row_axis, None), P()),
             out_specs=(P(row_axis, None), P(row_axis, None), P(row_axis)),
             check_vma=False)
    def _q(w_blk, h_full):
        codes, t, errs = _ganq_core(
            w_blk, h_full, bits=cfg.bits, iters=cfg.iters,
            codebook_init=cfg.codebook_init, precond_mode=cfg.precondition,
            damp=cfg.damp, kmeans_iters=cfg.kmeans_iters)
        # keep the per-shard error trace; callers psum if they want a total
        return codes, t, errs[None if errs.ndim == 0 else slice(None)]

    return jax.jit(_q)(w, h)


def shard_layer_weights(mesh: Mesh, w: jnp.ndarray,
                        row_axis: str = "model") -> jax.Array:
    """Place W with rows sharded for quantization."""
    return jax.device_put(w, NamedSharding(mesh, P(row_axis, None)))
