"""Per-row codebook initialization T^0 for GANQ (paper §3.2, Algorithm 1 input).

The paper takes an "initial codebook T^0" as given. We provide three
initializers, all vectorized over the m rows:

  * quantile — codebook entries at evenly spaced per-row quantiles. Adapts to
    the (heavy-tailed, Fig. 1b) weight distribution; our default.
  * kmeans   — per-row 1-D Lloyd's k-means (SqueezeLLM-style, unweighted).
  * uniform  — per-row min/max linspace == the RTN uniform grid, for ablation.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


def init_uniform(w: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Per-row [min, max] uniform grid; (m, 2**bits)."""
    levels = 1 << bits
    lo = jnp.min(w, axis=1, keepdims=True)
    hi = jnp.max(w, axis=1, keepdims=True)
    t = jnp.linspace(0.0, 1.0, levels, dtype=w.dtype)[None, :]
    return lo + (hi - lo) * t


def init_quantile(w: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Codebook at per-row quantiles (k + 0.5) / 2**bits; (m, 2**bits)."""
    levels = 1 << bits
    qs = (jnp.arange(levels, dtype=jnp.float32) + 0.5) / levels
    t = jnp.quantile(w.astype(jnp.float32), qs, axis=1).T  # (m, levels)
    # guarantee strictly increasing entries so argmin assignment is sane
    eps = 1e-8 * (1.0 + jnp.max(jnp.abs(w), axis=1, keepdims=True))
    t = t + eps * jnp.arange(levels, dtype=jnp.float32)[None, :]
    return t.astype(w.dtype)


def assign_nearest(w: jnp.ndarray, t: jnp.ndarray) -> jnp.ndarray:
    """codes[i, j] = argmin_s |w[i, j] - t[i, s]|; (m, n) int32.

    Memory-lean form: one (m, n, levels) broadcast per call — callers with
    huge n should chunk columns (pipeline does).
    """
    d = jnp.abs(w[:, :, None] - t[:, None, :])
    return jnp.argmin(d, axis=-1).astype(jnp.int32)


@partial(jax.jit, static_argnames=("bits", "iters"))
def init_kmeans(w: jnp.ndarray, bits: int, iters: int = 10) -> jnp.ndarray:
    """Per-row 1-D k-means, Lloyd iterations, quantile-seeded; (m, 2**bits).

    Update step avoids the (m, n, levels) one-hot by looping over the (small)
    number of levels: per level, a masked mean over n.
    """
    levels = 1 << bits
    w = w.astype(jnp.float32)
    t0 = init_quantile(w, bits)

    def step(t, _):
        codes = assign_nearest(w, t)  # (m, n)
        new_cols = []
        for s in range(levels):
            mask = (codes == s).astype(jnp.float32)
            cnt = jnp.sum(mask, axis=1)
            tot = jnp.sum(w * mask, axis=1)
            mean = tot / jnp.maximum(cnt, 1.0)
            new_cols.append(jnp.where(cnt > 0, mean, t[:, s]))
        return jnp.stack(new_cols, axis=1), None

    t, _ = jax.lax.scan(step, t0, None, length=iters)
    return t


@partial(jax.jit, static_argnames=("bits", "iters"))
def weighted_kmeans(w: jnp.ndarray, weights: jnp.ndarray, bits: int,
                    iters: int = 10) -> jnp.ndarray:
    """Sensitivity-weighted per-row 1-D k-means (SqueezeLLM, Kim et al. '24).

    weights (n,) — per-input-feature sensitivity; SqueezeLLM uses the
    diagonal Fisher, approximated here by diag(H) = sum_t x_t^2 (the same
    second-moment signal). Centroid update is the weighted mean.
    """
    levels = 1 << bits
    w = w.astype(jnp.float32)
    wt = jnp.maximum(weights.astype(jnp.float32), 1e-12)[None, :]
    t0 = init_quantile(w, bits)

    def step(t, _):
        codes = assign_nearest(w, t)
        cols = []
        for s in range(levels):
            mask = (codes == s).astype(jnp.float32) * wt
            tot = jnp.sum(w * mask, axis=1)
            cnt = jnp.sum(mask, axis=1)
            cols.append(jnp.where(cnt > 0, tot / jnp.maximum(cnt, 1e-12),
                                  t[:, s]))
        return jnp.stack(cols, axis=1), None

    t, _ = jax.lax.scan(step, t0, None, length=iters)
    return t


# ------------------------------------------------- nested (prefix) codebooks

def nested_order(codebook: jnp.ndarray, codes: jnp.ndarray):
    """Reorder a per-row codebook ascending and remap codes to match.

    Sorting is what makes bit-prefix nesting valid (Any-Precision LLM):
    after it, the high `db` bits of a code index one of 2**db groups of
    2**rb *consecutive* codebook entries — a contiguous value range — so
    dropping the low bits degrades each weight to its group's
    representative instead of an arbitrary entry. Dequantization is
    unchanged (same (entry, weight) pairing, permuted indices).

    codebook: (..., m, L); codes: (..., m, n) uint8 indices into the last
    codebook axis. Returns (sorted_codebook, remapped_codes).
    """
    order = jnp.argsort(codebook, axis=-1)
    rank = jnp.argsort(order, axis=-1)          # rank[s] = new index of s
    new_codes = jnp.take_along_axis(rank, codes.astype(jnp.int32), axis=-1)
    return jnp.sort(codebook, axis=-1), new_codes.astype(jnp.uint8)


def nested_codebooks(codebook: jnp.ndarray, draft_bits: int) -> jnp.ndarray:
    """Coarse 2**draft_bits-entry codebook nested in a sorted fine one.

    Entry d of the draft book represents the group of consecutive sorted
    entries whose codes share high bits d — its mean, i.e. the centroid a
    draft pass decodes when it streams only the code prefix. Derived
    in-graph from the full codebook: the draft model costs zero extra HBM.

    codebook: (..., L) sorted ascending (nested_order / nested encode);
    returns (..., 2**draft_bits).
    """
    levels = codebook.shape[-1]
    rest = levels >> draft_bits
    assert rest << draft_bits == levels, (levels, draft_bits)
    grouped = codebook.reshape(*codebook.shape[:-1], 1 << draft_bits, rest)
    return jnp.mean(grouped, axis=-1)


def init_codebook(w: jnp.ndarray, bits: int, method: str = "quantile",
                  kmeans_iters: int = 10) -> jnp.ndarray:
    if method == "quantile":
        return init_quantile(w, bits)
    if method == "kmeans":
        return init_kmeans(w, bits, kmeans_iters)
    if method == "uniform":
        return init_uniform(w, bits)
    raise ValueError(f"unknown codebook init: {method!r}")
