"""CacheFormat registry: every KV/state cache layout as one object.

The serving twin of `core.formats.WeightFormat`: a decode step mixes several
*cache* layouts — full fp K/V rings, int8 KV with per-(token, head) scales,
sliding-window rings, RWKV-6 / RG-LRU recurrent state, whisper's precomputed
cross-attention K/V, and a paged K/V pool whose slot count is decoupled from
`max_len`. Each layout is a `CacheFormat` registered here and owns the full
vertical:

  init(batch, width, cfg, dtype)  allocate one layer's cache container
  write(cache, k, v, pos, ...)    one decode step's K/V write
  write_at(cache, rows, ...)      scatter n>1 tokens at arbitrary (slot, pos)
  step_rows(k1, v1)               one step's K/V in this layout's row form
  gather_rows(cache, slots, ...)  per-token slot-row view (backing layout)
  read(cache, dtype, ...)         dense (B, W, K, hd) K/V view (dequantized)
  visible(cache, pos, kind, ...)  (B, W) attendable-entry mask
  from_prefill(k, v, width, ...)  fresh prompt K/V -> this layout (batch 1)
  insert(big, small, slot, ...)   slot-row insertion for continuous batching
  partition_spec(name, shape, ..) sharding rule for each container leaf
  storage_bits(cache)             honest bits from the real dtypes

The token-budget serving step (`models.model.mixed_step`) drives the
multi-token vertical: `token_write_view` below builds, for a flat batch of
tokens at arbitrary (slot, position) pairs, each token's attention view —
the cache as that token's sequence sees it after every same-slot write at a
position <= its own — and persists the step's K/V with `write_at`. For
single-token runs (pure decode) the view is bitwise identical to the
write-then-read decode path.

Model code (`models/{attention,transformer,model,whisper}.py`) and the serve
engine route through this registry only — there is no `"k_scale" in cache`
key-presence dispatch or isinstance branching outside `core/`. Containers
are `CacheState` pytrees tagged with the format name, mirroring how
`QuantizedLinear.fmt` tags weight containers.

Paged formats ('paged', 'paged_int8') store `(num_pages + 1, page_size, K,
hd)` pools (the +1 row is a scratch page absorbing writes from inactive /
unmapped slots) and read/write through a per-slot page table passed down the
decode step (`pages` argument) — the table itself is host-side state owned
by `serve.scheduler.PageAllocator`, so the jitted step stays fixed-shape.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .formats import dtype_bits

_CACHE_FORMATS: Dict[str, "CacheFormat"] = {}


def register_cache_format(cls):
    """Class decorator: instantiate and register under cls.name."""
    inst = cls()
    assert inst.name and inst.name not in _CACHE_FORMATS, inst.name
    _CACHE_FORMATS[inst.name] = inst
    return cls


def get_cache_format(name: str) -> "CacheFormat":
    try:
        return _CACHE_FORMATS[name]
    except KeyError:
        raise KeyError(f"unknown cache format {name!r}; "
                       f"available: {available_cache_formats()}") from None


def available_cache_formats():
    return sorted(_CACHE_FORMATS)


# ------------------------------------------------------------------ carrier

@jax.tree_util.register_pytree_with_keys_class
class CacheState:
    """Thin pytree carrier: one layer's cache arrays + a static `fmt` tag.

    The tag is what model code dispatches on (via `get_cache_format`), the
    way `QuantizedLinear.fmt` routes `linear_apply` — no key-presence or
    isinstance probing of the array dict. Dict keys ride the pytree paths
    (register_pytree_with_keys) so sharding rules and tree surgery keep
    seeing names.
    """

    def __init__(self, fmt: str, data: Dict[str, jnp.ndarray]):
        self.fmt = fmt
        self.data = dict(data)

    def __getitem__(self, key: str):
        return self.data[key]

    def __contains__(self, key: str) -> bool:
        return key in self.data

    def replace(self, **kw) -> "CacheState":
        return CacheState(self.fmt, {**self.data, **kw})

    def __repr__(self):
        return f"CacheState({self.fmt!r}, {sorted(self.data)})"

    def tree_flatten_with_keys(self):
        keys = tuple(sorted(self.data))
        children = [(jax.tree_util.DictKey(k), self.data[k]) for k in keys]
        return children, (self.fmt, keys)

    @classmethod
    def tree_unflatten(cls, aux, children):
        fmt, keys = aux
        return cls(fmt, dict(zip(keys, children)))


# ------------------------------------------------------------- cfg routing

def kv_format_of(cfg) -> str:
    """Resolve a ModelConfig to its attention-cache format name.

    `cfg.kv_format` wins when set; the legacy `kv_quant_bits == 8` knob maps
    to 'int8'; default 'full'.
    """
    name = getattr(cfg, "kv_format", "") or ""
    if name:
        f = get_cache_format(name)     # loud on typos
        assert f.kv and f.selectable, \
            f"{name!r} cannot serve as the attention-cache format"
        return name
    return "int8" if getattr(cfg, "kv_quant_bits", 0) == 8 else "full"


def layer_cache_format(kind: str, cfg) -> str:
    """Cache format for one layer kind ('attn'/'local'/'rwkv'/'rglru')."""
    if kind in ("attn", "local"):
        return kv_format_of(cfg)
    if kind == "rwkv":
        return "rwkv_state"
    if kind == "rglru":
        return "rglru_state"
    raise ValueError(kind)


def contiguous_cfg(cfg):
    """The contiguous-cache twin of a (possibly paged) config — the layout
    the reference decode path and paged prefill sub-caches use."""
    f = get_cache_format(kv_format_of(cfg))
    if not f.paged:
        return cfg
    return dataclasses.replace(cfg, kv_format=f.backing)


def pages_for(n_tokens: int, page_size: int) -> int:
    """Pages needed to hold positions 0..n_tokens-1."""
    return -(-max(n_tokens, 0) // page_size)


# ----------------------------------------------------------- kv quant math

def quantize_kv(x: jnp.ndarray):
    """(…, hd) -> (int8 codes, bf16 scale over the last dim)."""
    scale = jnp.maximum(jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1),
                        1e-6) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.bfloat16)


def dequantize_kv(q: jnp.ndarray, scale: jnp.ndarray, dtype) -> jnp.ndarray:
    return (q.astype(jnp.float32) * scale.astype(jnp.float32)[..., None]
            ).astype(dtype)


def cache_slot_positions(pos: jnp.ndarray, w: int) -> jnp.ndarray:
    """(B, W) absolute position held by each ring slot (negative = empty)."""
    slots = jnp.arange(w)[None, :]
    cur = (pos % w)[:, None]
    diff = (cur - slots) % w
    return pos[:, None] - diff


def _window_mask(logical_pos: jnp.ndarray, pos: jnp.ndarray, kind: str,
                 window: int) -> jnp.ndarray:
    """(B, W) attendable mask from (B, W) logical positions."""
    ok = (logical_pos >= 0) & (logical_pos <= pos[:, None])
    if kind == "sliding":
        ok &= logical_pos > (pos[:, None] - window)
    return ok


# -------------------------------------------------------------- base class

class CacheFormat:
    """Base class; subclasses register with @register_cache_format.

    `kv` marks attention K/V layouts (counted by `kv_cache_bytes`, served by
    read/visible/write); recurrent-state formats only use init / insert /
    partition_spec — their per-step update lives in the model blocks and the
    inactive-slot freeze is tree-generic. `paged` formats read/write through
    a page table; `backing` names the contiguous format their prefill
    sub-caches are built in.
    """

    name: str = ""
    kv: bool = True
    paged: bool = False
    backing: Optional[str] = None
    # may a config/policy select this as THE attention-cache layout?
    # (cross_kv is internal: read-only, allocated by the whisper path)
    selectable: bool = True

    # ------------------------------------------------------------ lifecycle
    def init(self, batch: int, width: int, cfg, dtype) -> CacheState:
        raise NotImplementedError(self.name)

    def blank(self, batch: int, width: int, cfg, dtype) -> CacheState:
        """A zero container in the layout `insert` consumes (slot reset)."""
        return self.init(batch, width, cfg, dtype)

    # ----------------------------------------------------------- decode ops
    def write(self, cache: CacheState, k_new, v_new, pos,
              active=None, pages=None) -> CacheState:
        """Write one step; k_new/v_new (B, 1, K, hd), pos (B,)."""
        raise NotImplementedError(self.name)

    def read(self, cache: CacheState, dtype,
             pages=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Dense (B, W, K, hd) K/V views (dequantized / page-gathered)."""
        raise NotImplementedError(self.name)

    # ------------------------------------------------------ token-batch ops
    def step_rows(self, k1: jnp.ndarray, v1: jnp.ndarray) -> Dict:
        """One step's K/V (T, K, hd) in this layout's row form (quantized
        formats emit codes + scales) — the unit `write_at` scatters and
        `token_write_view` overlays."""
        raise NotImplementedError(self.name)

    def gather_rows(self, cache: CacheState, slots: jnp.ndarray,
                    pages=None) -> CacheState:
        """Per-token view rows: a CacheState in the *contiguous* layout
        whose batch axis is the flat token axis — entry t is slot
        `slots[t]`'s row (paged formats gather the slot's pages into their
        backing sequence layout). `view_width` positions per row."""
        raise NotImplementedError(self.name)

    def view_index(self, pos: jnp.ndarray, width: int) -> jnp.ndarray:
        """Where position `pos` lands on the `gather_rows` width axis."""
        return pos % width

    def write_at(self, cache: CacheState, rows: Dict, slots: jnp.ndarray,
                 pos: jnp.ndarray, keep: jnp.ndarray,
                 pages=None) -> CacheState:
        """Scatter a flat token batch (rows from `step_rows`) at arbitrary
        (slots[t], pos[t]); tokens with keep[t] == False are dropped
        (inactive lanes, or ring writes superseded by a later same-step
        token at the same ring slot)."""
        raise NotImplementedError(self.name)

    def read_rows(self, cache: CacheState, slots: jnp.ndarray,
                  pos: jnp.ndarray, pages=None) -> Dict:
        """Inverse of `write_at`: the container rows currently stored at
        (slots[t], pos[t]) — bitwise, in `step_rows` form, so
        `write_at(cache, read_rows(...), slots, pos, keep)` restores those
        cells exactly. The speculative engine snapshots the cells its draft
        lanes will clobber and rolls rejected writes back through this
        round trip."""
        raise NotImplementedError(self.name)

    def visible(self, cache: CacheState, pos, kind: str, window: int,
                pages=None) -> jnp.ndarray:
        """(B, W) bool: which entries of the `read` view may be attended."""
        raise NotImplementedError(self.name)

    def copy_page(self, cache: CacheState, src, dst) -> CacheState:
        """Device-side physical page copy (copy-on-write for shared
        prefix pages); only paged layouts have pages to copy."""
        raise NotImplementedError(self.name)

    # -------------------------------------------------------- prefill paths
    def from_prefill(self, k, v, width: int, cfg, dtype) -> CacheState:
        """Fresh prompt K/V (B, S, K, hd) -> this layout, positioned after
        the prompt."""
        raise NotImplementedError(self.name)

    def insert(self, big: CacheState, small: CacheState, slot,
               pages=None, stacked: bool = False) -> CacheState:
        """Insert batch-1 `small` into row `slot` of slot-batched `big`.

        `stacked` marks unit-stacked leaves (U, B, ...) whose batch rides
        axis 1. Default: pure tree surgery (layouts match); paged formats
        scatter `small`'s sequence layout into the slot's pages.
        """
        def put(b, s_):
            if stacked:
                return b.at[:, slot].set(s_[:, 0].astype(b.dtype))
            return b.at[slot].set(s_[0].astype(b.dtype))

        return CacheState(big.fmt, {key: put(big.data[key], small.data[key])
                                    for key in big.data})

    # ------------------------------------------------------------- sharding
    def partition_spec(self, name: str, shape, dp, tp, size_of) -> P:
        """PartitionSpec for one container leaf; `dp` is the DP axis (or
        tuple), `tp` the TP axis name, `size_of(axes)` the mesh size of an
        axis (or tuple of axes). Default: replicate."""
        return P()

    # ------------------------------------------------------------- storage
    def storage_bits(self, cache: CacheState) -> float:
        return float(sum(leaf.size * dtype_bits(leaf.dtype)
                         for leaf in cache.data.values()))


def insert_slot(big: CacheState, small: CacheState, slot,
                pages=None, stacked: bool = False) -> CacheState:
    """Registry-dispatched slot insertion (the continuous-batching admission
    primitive `models.transformer.cache_insert` maps over layer entries)."""
    return get_cache_format(big.fmt).insert(big, small, slot, pages=pages,
                                            stacked=stacked)


def token_write_view(cache: CacheState, k_new: jnp.ndarray,
                     v_new: jnp.ndarray, slots: jnp.ndarray,
                     pos: jnp.ndarray, active: jnp.ndarray, kind: str,
                     window: int, pages=None):
    """Multi-token write + per-token attention view over one cache layer.

    `k_new`/`v_new` (T, K, hd) are the fresh K/V of a flat token batch at
    arbitrary (slots[t], pos[t]) — decode lanes and prompt-chunk lanes
    alike, any number of tokens per slot (contiguous position runs).
    Returns (new_cache, view, visible): `view` is a contiguous-layout
    CacheState whose batch axis is the token axis, holding for token t the
    cache as its sequence sees it once every same-slot write at a position
    <= pos[t] is applied — so intra-chunk causal attention needs no second
    score path and a single-token run reproduces the write-then-read decode
    view bitwise. `visible` is the (T, Wv) attendable mask. The returned
    cache persists every kept lane; a ring cell written twice in one step
    keeps only the final (highest-position) write, inactive lanes are
    dropped.
    """
    f = get_cache_format(cache.fmt)
    rows = f.step_rows(k_new, v_new)
    view = f.gather_rows(cache, slots, pages=pages)
    wv = view["k"].shape[1]
    widx = f.view_index(pos, wv)
    t = pos.shape[0]
    ti = jnp.arange(t)
    same = active[None, :] & (slots[None, :] == slots[:, None])
    ov = same & (pos[None, :] <= pos[:, None])
    # latest same-step writer of each view cell, per query token: scatter-max
    # of the lane index (within a slot, a later lane is a later position)
    sel = jnp.full((t, wv), -1, jnp.int32).at[
        jnp.broadcast_to(ti[:, None], (t, t)),
        jnp.broadcast_to(widx[None, :], (t, t))].max(
        jnp.where(ov, ti[None, :], -1))
    hit = sel >= 0
    selc = jnp.maximum(sel, 0)
    data = {}
    for key, leaf in view.data.items():
        fresh = rows[key][selc]
        m = hit.reshape(hit.shape + (1,) * (leaf.ndim - 2))
        data[key] = jnp.where(m, fresh.astype(leaf.dtype), leaf)
    view = CacheState(view.fmt, data)
    if f.paged:
        keep = active                      # distinct (page, offset) per lane
        tok_pages = pages[slots]
    else:
        clobbered = (same & (pos[None, :] > pos[:, None])
                     & (widx[None, :] == widx[:, None])).any(axis=1)
        keep = active & ~clobbered
        tok_pages = None
    visible = f.visible(cache, pos, kind, window, pages=tok_pages)
    cache = f.write_at(cache, rows, slots, pos, keep, pages=pages)
    return cache, view, visible


def _state_cells(st: CacheState, slots, pos, pages, stacked: bool):
    """Snapshot one layer entry's cells at (slots[t], pos[t]) in step_rows
    form; None for recurrent state (no addressable cells to roll back)."""
    f = get_cache_format(st.fmt)
    if not f.kv:
        return None
    if stacked:                       # unit-stacked leaves (U, B/P, ...)
        return jax.vmap(lambda data: f.read_rows(
            CacheState(st.fmt, data), slots, pos, pages=pages))(st.data)
    return f.read_rows(st, slots, pos, pages=pages)


def _state_restore(st: CacheState, rows, slots, pos, keep, pages,
                   stacked: bool) -> CacheState:
    if rows is None:
        return st
    f = get_cache_format(st.fmt)
    if stacked:
        return CacheState(st.fmt, jax.vmap(
            lambda data, r: f.write_at(CacheState(st.fmt, data), r, slots,
                                       pos, keep, pages=pages).data)(
            st.data, rows))
    return f.write_at(st, rows, slots, pos, keep, pages=pages)


def snapshot_cells(cache_tree, slots: jnp.ndarray, pos: jnp.ndarray,
                   pages=None):
    """Bitwise snapshot of every attention-KV cell a flat (slots[t],
    pos[t]) token batch would write, across a whole stack cache tree
    ({"units": [...], "tail": [...]}). Paired with `restore_cells` this is
    the speculative-decoding rollback primitive: snapshot before the
    draft/verify round, restore the rejected lanes after — the cache ends
    bitwise identical to having only ever written the accepted tokens.
    Entries for recurrent-state layers are None (not rollback-capable; the
    engine refuses to speculate on such stacks)."""
    units = [None if st is None else _state_cells(st, slots, pos, pages, True)
             for st in cache_tree["units"]]
    tail = [_state_cells(st, slots, pos, pages, False)
            for st in cache_tree["tail"]]
    return {"units": units, "tail": tail}


def restore_cells(cache_tree, snap, slots: jnp.ndarray, pos: jnp.ndarray,
                  keep: jnp.ndarray, pages=None):
    """Write snapshot rows back at (slots[t], pos[t]) where keep[t] — the
    inverse of the speculative round's writes for rejected lanes."""
    units = [st if st is None else
             _state_restore(st, rows, slots, pos, keep, pages, True)
             for st, rows in zip(cache_tree["units"], snap["units"])]
    tail = [_state_restore(st, rows, slots, pos, keep, pages, False)
            for st, rows in zip(cache_tree["tail"], snap["tail"])]
    return {"units": units, "tail": tail}


def copy_page_cells(cache_tree, src, dst):
    """Physical page copy pool[dst] <- pool[src] across a whole stack
    cache tree ({"units": [...], "tail": [...]}) — every paged attention
    layer copies the page in each of its pools (unit-stacked entries copy
    it in every unit's pool). Non-paged and recurrent-state entries pass
    through untouched: copy-on-write is only defined for the paged pools.
    Note shared-prefix ADMISSION needs no data movement at all — mapping
    a cached page into a slot's table row IS the insert; this op runs
    only when a slot must write into a page other holders still share."""
    def one(st, stacked):
        if st is None:
            return st
        f = get_cache_format(st.fmt)
        if not (f.kv and f.paged):
            return st
        if stacked:
            return CacheState(st.fmt, jax.vmap(
                lambda data: f.copy_page(
                    CacheState(st.fmt, data), src, dst).data)(st.data))
        return f.copy_page(st, src, dst)

    return {"units": [one(st, True) for st in cache_tree["units"]],
            "tail": [one(st, False) for st in cache_tree["tail"]]}


def kv_cache_bytes(cache_tree) -> int:
    """Total bytes held by attention-KV containers in a cache tree (paged
    pools count their allocation incl. the scratch page; recurrent state is
    excluded — it does not scale with max_len)."""
    total = 0.0
    for st in _iter_states(cache_tree):
        f = get_cache_format(st.fmt)
        if f.kv:
            total += f.storage_bits(st)
    return int(total // 8)


def _iter_states(tree):
    is_state = lambda x: isinstance(x, CacheState)
    return [s for s in jax.tree.leaves(tree, is_leaf=is_state)
            if isinstance(s, CacheState)]


# ------------------------------------------------------- contiguous K/V

def _kv_spec(name, shape, dp, tp, size_of):
    """Contiguous K/V + scale sharding rules (moved verbatim from
    launch/steps.cache_shardings): batch over DP when batch > 1; at batch 1
    the *sequence* dim of attention caches shards over DP (context
    parallelism for long decode); kv-heads over TP when divisible."""
    rank = len(shape)
    tp_size = size_of(tp)
    if name in ("k", "v"):
        lead = (None,) * (rank - 4)
        b_, w_, kh, hd = shape[-4:]
        k_div = kh % tp_size == 0
        if b_ == 1:
            w_axes = dp if k_div else (tuple(dp) if isinstance(dp, tuple)
                                       else (dp,)) + (tp,)
            w_spec = w_axes if w_ % size_of(w_axes) == 0 else None
            return P(*lead, None, w_spec, tp if k_div else None, None)
        if k_div:
            return P(*lead, dp, None, tp, None)
        w_spec = tp if w_ % tp_size == 0 else None
        return P(*lead, dp, w_spec, None, None)
    if name in ("k_scale", "v_scale"):
        # (…, B, W, K) — mirror the k/v rule minus the head_dim axis
        lead = (None,) * (rank - 3)
        b_, w_, kh = shape[-3:]
        k_div = kh % tp_size == 0
        if b_ == 1:
            return P(*lead, None, dp, tp if k_div else None)
        if k_div:
            return P(*lead, dp, None, tp)
        w_spec = tp if w_ % tp_size == 0 else None
        return P(*lead, dp, w_spec, None)
    return P()


@register_cache_format
class FullKVFormat(CacheFormat):
    """Full-precision K/V ring buffer (B, W, K, hd); 'attn' layers size W =
    cache_len, 'local' layers W = min(cache_len, window) — ring writes at
    pos % W make the same container serve both."""

    name = "full"

    def init(self, batch, width, cfg, dtype):
        shape = (batch, width, cfg.n_kv_heads, cfg.head_dim)
        return CacheState(self.name, {"k": jnp.zeros(shape, dtype),
                                      "v": jnp.zeros(shape, dtype)})

    def _rows(self, k1, v1, cfg, dtype):
        """One-step (B, K, hd) K/V -> container rows dict."""
        return {"k": k1, "v": v1}

    def write(self, cache, k_new, v_new, pos, active=None, pages=None):
        w = cache["k"].shape[1]
        slot = pos % w
        b = jnp.arange(k_new.shape[0])

        def put(buf, row):
            row = row.astype(buf.dtype)
            if active is not None:
                a = active.reshape((-1,) + (1,) * (row.ndim - 1))
                row = jnp.where(a, row, buf[b, slot])
            return buf.at[b, slot].set(row)

        rows = self._rows(k_new[:, 0], v_new[:, 0], None, None)
        return CacheState(self.name, {key: put(cache.data[key], rows[key])
                                      for key in cache.data})

    def step_rows(self, k1, v1):
        return self._rows(k1, v1, None, None)

    def gather_rows(self, cache, slots, pages=None):
        return CacheState(self.name, {key: leaf[slots]
                                      for key, leaf in cache.data.items()})

    def write_at(self, cache, rows, slots, pos, keep, pages=None):
        w = cache["k"].shape[1]
        b = jnp.where(keep, slots, cache["k"].shape[0])   # OOB row: dropped
        return CacheState(self.name, {
            key: cache.data[key].at[b, pos % w].set(
                rows[key].astype(cache.data[key].dtype), mode="drop")
            for key in cache.data})

    def read_rows(self, cache, slots, pos, pages=None):
        w = cache["k"].shape[1]
        return {key: cache.data[key][slots, pos % w]
                for key in cache.data}

    def read(self, cache, dtype, pages=None):
        return cache["k"].astype(dtype), cache["v"].astype(dtype)

    def visible(self, cache, pos, kind, window, pages=None):
        w = cache["k"].shape[1]
        return _window_mask(cache_slot_positions(pos, w), pos, kind, window)

    def from_prefill(self, k, v, width, cfg, dtype):
        b, s = k.shape[:2]
        cache = self.init(b, width, cfg, dtype)
        keep = min(s, width)
        slots = jnp.arange(s - keep, s) % width
        rows = self._rows(k[:, s - keep:], v[:, s - keep:], cfg, dtype)
        return CacheState(self.name, {
            key: cache.data[key].at[:, slots].set(
                rows[key].astype(cache.data[key].dtype))
            for key in cache.data})

    def partition_spec(self, name, shape, dp, tp, size_of):
        return _kv_spec(name, shape, dp, tp, size_of)


@register_cache_format
class Int8KVFormat(FullKVFormat):
    """int8 K/V ring with per-(token, head) bf16 scales — halves decode HBM
    traffic vs bf16 (beyond-paper; EXPERIMENTS.md §Perf cell A)."""

    name = "int8"

    def init(self, batch, width, cfg, dtype):
        shape = (batch, width, cfg.n_kv_heads, cfg.head_dim)
        sshape = shape[:-1]
        return CacheState(self.name, {
            "k": jnp.zeros(shape, jnp.int8),
            "v": jnp.zeros(shape, jnp.int8),
            "k_scale": jnp.zeros(sshape, jnp.bfloat16),
            "v_scale": jnp.zeros(sshape, jnp.bfloat16)})

    def _rows(self, k1, v1, cfg, dtype):
        kq, ks = quantize_kv(k1)
        vq, vs = quantize_kv(v1)
        return {"k": kq, "v": vq, "k_scale": ks, "v_scale": vs}

    def read(self, cache, dtype, pages=None):
        return (dequantize_kv(cache["k"], cache["k_scale"], dtype),
                dequantize_kv(cache["v"], cache["v_scale"], dtype))


# ------------------------------------------------------------ paged K/V

class _PagedBase(CacheFormat):
    """Paged K/V pool: (num_pages + 1, page_size, K, hd) per layer, indexed
    through a per-slot page table (B, max_pages) int32 with -1 = unmapped.
    The +1 row is a scratch page: writes from inactive slots or unmapped
    positions land there instead of corrupting a live page. Slot count is
    decoupled from max_len — long and short requests share the pool, pages
    allocate lazily as sequences grow (serve/scheduler.PageAllocator owns
    the free list on the host).

    Sliding-window ('local') layers share the pool and page table; the
    window is enforced by `visible`'s position mask rather than a shorter
    ring, trading some pool generosity for one page-id space per slot."""

    paged = True

    def _pool_geometry(self, batch, width, cfg):
        ps = cfg.kv_page_size
        n_pages = cfg.kv_pages or batch * pages_for(width, ps)
        return n_pages, ps

    def init(self, batch, width, cfg, dtype):
        n_pages, ps = self._pool_geometry(batch, width, cfg)
        back = get_cache_format(self.backing)
        sub = back.init(1, ps, cfg, dtype)          # dtype template per key
        return CacheState(self.name, {
            key + "_pages": jnp.zeros((n_pages + 1, ps) + leaf.shape[2:],
                                      leaf.dtype)
            for key, leaf in sub.data.items()})

    def blank(self, batch, width, cfg, dtype):
        # insert-layout zeros: the backing format's sequence-form rows
        back = get_cache_format(self.backing)
        rows = back._rows(
            jnp.zeros((batch, width, cfg.n_kv_heads, cfg.head_dim), dtype),
            jnp.zeros((batch, width, cfg.n_kv_heads, cfg.head_dim), dtype),
            cfg, dtype)
        return CacheState(self.name, rows)

    def _safe_pages(self, cache, pages):
        scratch = cache["k_pages"].shape[0] - 1
        return jnp.where(pages >= 0, pages, scratch), scratch

    def write(self, cache, k_new, v_new, pos, active=None, pages=None):
        assert pages is not None, "paged cache write needs a page table"
        ps = cache["k_pages"].shape[1]
        pg = jnp.take_along_axis(pages, (pos // ps)[:, None], axis=1)[:, 0]
        pg, scratch = self._safe_pages(cache, pg)
        if active is not None:
            pg = jnp.where(active, pg, scratch)
        off = pos % ps
        rows = get_cache_format(self.backing)._rows(
            k_new[:, 0], v_new[:, 0], None, None)
        return CacheState(self.name, {
            key + "_pages": cache.data[key + "_pages"].at[pg, off].set(
                rows[key].astype(cache.data[key + "_pages"].dtype))
            for key in rows})

    def step_rows(self, k1, v1):
        return get_cache_format(self.backing)._rows(k1, v1, None, None)

    def gather_rows(self, cache, slots, pages=None):
        assert pages is not None, "paged row gather needs a page table"
        pg, _ = self._safe_pages(cache, pages[slots])     # (T, MP)
        t, mp = pg.shape
        ps = cache["k_pages"].shape[1]
        return CacheState(self.backing, {
            key[:-len("_pages")]: cache.data[key][pg].reshape(
                (t, mp * ps) + cache.data[key].shape[2:])
            for key in cache.data})

    def view_index(self, pos, width):
        return pos                          # logical positions; pages never wrap

    def write_at(self, cache, rows, slots, pos, keep, pages=None):
        assert pages is not None, "paged cache write needs a page table"
        ps = cache["k_pages"].shape[1]
        pt = pages[slots]                                 # (T, MP)
        pg = jnp.take_along_axis(pt, (pos // ps)[:, None], axis=1)[:, 0]
        pg, scratch = self._safe_pages(cache, pg)
        pg = jnp.where(keep, pg, scratch)
        off = pos % ps
        return CacheState(self.name, {
            key + "_pages": cache.data[key + "_pages"].at[pg, off].set(
                rows[key].astype(cache.data[key + "_pages"].dtype))
            for key in rows})

    def read_rows(self, cache, slots, pos, pages=None):
        assert pages is not None, "paged cache read needs a page table"
        ps = cache["k_pages"].shape[1]
        pt = pages[slots]                                 # (T, MP)
        pg = jnp.take_along_axis(pt, (pos // ps)[:, None], axis=1)[:, 0]
        pg, _ = self._safe_pages(cache, pg)
        off = pos % ps
        return {key[:-len("_pages")]: cache.data[key][pg, off]
                for key in cache.data}

    def visible(self, cache, pos, kind, window, pages=None):
        assert pages is not None, "paged cache read needs a page table"
        ps = cache["k_pages"].shape[1]
        wv = pages.shape[1] * ps
        logical = jnp.broadcast_to(jnp.arange(wv)[None],
                                   (pos.shape[0], wv))
        mapped = jnp.repeat(pages >= 0, ps, axis=1)
        return _window_mask(jnp.where(mapped, logical, -1), pos, kind,
                            window)

    def from_prefill(self, k, v, width, cfg, dtype):
        # keep the raw (quantized) sequence layout; `insert` scatters it
        # into the slot's pages by logical position
        rows = get_cache_format(self.backing)._rows(k, v, cfg, dtype)
        return CacheState(self.name, rows)

    def insert(self, big, small, slot, pages=None, stacked=False):
        """Scatter `small`'s sequence layout (logical positions 0..S-1) into
        the pages mapped for this slot; `pages` is the slot's (max_pages,)
        table row. Unmapped positions land on the scratch page."""
        assert pages is not None, "paged slot insertion needs a page table"
        ps = big["k_pages"].shape[-3]
        s = small["k"].shape[-3]
        j = jnp.arange(s)
        scratch = big["k_pages"].shape[-4] - 1
        pg = jnp.where(pages[j // ps] >= 0, pages[j // ps], scratch)
        off = j % ps

        def put(pool, rows):
            rows = rows[:, 0] if stacked else rows[0]       # drop batch 1
            if stacked:
                return pool.at[:, pg, off].set(rows.astype(pool.dtype))
            return pool.at[pg, off].set(rows.astype(pool.dtype))

        return CacheState(big.fmt, {
            key + "_pages": put(big.data[key + "_pages"], small.data[key])
            for key in small.data})

    def copy_page(self, cache, src, dst):
        """Copy physical page `src`'s rows into page `dst` across every
        pool leaf — codes AND scale pages alike, so both 'paged' and
        'paged_int8' copy bit-exactly. This is the device half of
        copy-on-write: the allocator remaps a slot's shared logical page
        to `dst`, and this op makes `dst` a byte-identical private copy
        before the step that writes into it runs. `src`/`dst` are int32
        scalars, so the op jits once per cache shape."""
        return CacheState(self.name, {
            key: pool.at[dst].set(pool[src])
            for key, pool in cache.data.items()})

    def read(self, cache, dtype, pages=None):
        assert pages is not None, "paged cache read needs a page table"
        pg, _ = self._safe_pages(cache, pages)          # (B, MP)
        b, mp = pg.shape
        ps = cache["k_pages"].shape[1]

        def gather(pool):
            g = pool[pg]                                 # (B, MP, ps, ...)
            return g.reshape((b, mp * ps) + pool.shape[2:])

        return self._dequant(cache, gather, dtype)

    def _dequant(self, cache, gather, dtype):
        return (gather(cache["k_pages"]).astype(dtype),
                gather(cache["v_pages"]).astype(dtype))

    def partition_spec(self, name, shape, dp, tp, size_of):
        # pool: pages replicated (the table is host-side), kv-heads over TP
        tp_size = size_of(tp)
        if name in ("k_pages", "v_pages"):
            lead = (None,) * (len(shape) - 4)
            kh = shape[-2]
            return P(*lead, None, None, tp if kh % tp_size == 0 else None,
                     None)
        if name in ("k_scale_pages", "v_scale_pages"):
            lead = (None,) * (len(shape) - 3)
            kh = shape[-1]
            return P(*lead, None, None, tp if kh % tp_size == 0 else None)
        return P()


@register_cache_format
class PagedKVFormat(_PagedBase):
    name = "paged"
    backing = "full"


@register_cache_format
class PagedInt8KVFormat(_PagedBase):
    name = "paged_int8"
    backing = "int8"

    def _dequant(self, cache, gather, dtype):
        return (dequantize_kv(gather(cache["k_pages"]),
                              gather(cache["k_scale_pages"]), dtype),
                dequantize_kv(gather(cache["v_pages"]),
                              gather(cache["v_scale_pages"]), dtype))


# -------------------------------------------------------- recurrent state

class _StateFormat(CacheFormat):
    """Recurrent-state containers: no K/V read/write — the model block
    advances the state and `transformer._freeze_inactive` gates inactive
    slots; the registry owns allocation, slot insertion, and sharding."""

    kv = False


@register_cache_format
class RWKVStateFormat(_StateFormat):
    """RWKV-6 per-layer state: token-shift vectors + (H, hs, hs) wkv."""

    name = "rwkv_state"

    def init(self, batch, width, cfg, dtype):
        d = cfg.d_model
        hs = cfg.rwkv_head_size
        h = d // hs
        return CacheState(self.name, {
            "tm_shift": jnp.zeros((batch, d), dtype),
            "wkv": jnp.zeros((batch, h, hs, hs), jnp.float32),
            "cm_shift": jnp.zeros((batch, d), dtype)})

    def partition_spec(self, name, shape, dp, tp, size_of):
        tp_size = size_of(tp)
        rank = len(shape)
        if name == "wkv":
            lead = (None,) * (rank - 4)
            b_, h_, _, _ = shape[-4:]
            h_spec = tp if h_ % tp_size == 0 else None
            return P(*lead, dp if b_ > 1 else None, h_spec, None, None)
        if name in ("tm_shift", "cm_shift"):
            lead = (None,) * (rank - 2)
            b_, d_ = shape[-2:]
            return P(*lead, dp if b_ > 1 else None,
                     tp if d_ % tp_size == 0 else None)
        return P()


@register_cache_format
class RGLRUStateFormat(_StateFormat):
    """RG-LRU per-layer state: conv tail (B, cw-1, r) + hidden (B, r)."""

    name = "rglru_state"

    def init(self, batch, width, cfg, dtype):
        r = cfg.lru_width
        return CacheState(self.name, {
            "conv": jnp.zeros((batch, cfg.conv_width - 1, r), dtype),
            "h": jnp.zeros((batch, r), jnp.float32)})

    def partition_spec(self, name, shape, dp, tp, size_of):
        tp_size = size_of(tp)
        rank = len(shape)
        if name == "h":
            lead = (None,) * (rank - 2)
            b_, d_ = shape[-2:]
            return P(*lead, dp if b_ > 1 else None,
                     tp if d_ % tp_size == 0 else None)
        if name == "conv":
            lead = (None,) * (rank - 3)
            b_, _, r_ = shape[-3:]
            return P(*lead, dp if b_ > 1 else None, None,
                     tp if r_ % tp_size == 0 else None)
        return P()


@register_cache_format
class CrossKVFormat(CacheFormat):
    """Whisper cross-attention K/V: precomputed from the encoder output at
    admission, read-only during decode (write is identity). Not a
    selectable serving layout — a policy/config picking it would decode
    against a never-written cache."""

    name = "cross_kv"
    selectable = False

    def init(self, batch, width, cfg, dtype):       # pragma: no cover
        shape = (batch, width, cfg.n_kv_heads, cfg.head_dim)
        return CacheState(self.name, {"k": jnp.zeros(shape, dtype),
                                      "v": jnp.zeros(shape, dtype)})

    def write(self, cache, k_new, v_new, pos, active=None, pages=None):
        return cache

    def read(self, cache, dtype, pages=None):
        return cache["k"].astype(dtype), cache["v"].astype(dtype)

    def visible(self, cache, pos, kind, window, pages=None):
        b, w = cache["k"].shape[:2]
        return jnp.ones((b, w), bool)

    def partition_spec(self, name, shape, dp, tp, size_of):
        return _kv_spec(name, shape, dp, tp, size_of)
