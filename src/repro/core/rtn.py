"""Round-to-nearest (RTN) uniform quantization baseline (paper Tables 2/5).

Asymmetric per-channel (per output row) affine quantization
    q = clamp(round(w/s) + z, 0, 2^N - 1),   w~ = s * (q - z)
optionally group-wise along the input dim (g128 rows in Table 5).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp


def _affine_params(w: jnp.ndarray, bits: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Scale/zero over the last axis. Returns s, z with keepdims."""
    qmax = (1 << bits) - 1
    lo = jnp.minimum(jnp.min(w, axis=-1, keepdims=True), 0.0)
    hi = jnp.maximum(jnp.max(w, axis=-1, keepdims=True), 0.0)
    s = jnp.maximum((hi - lo) / qmax, 1e-10)
    z = jnp.round(-lo / s)
    return s, z


def rtn_quantize(w: jnp.ndarray, bits: int,
                 group_size: Optional[int] = None) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Quantize (m, n) -> (codes uint8, scale, zero).

    With group_size, scale/zero have shape (m, n/group_size, 1) and codes are
    reshaped back to (m, n).
    """
    m, n = w.shape
    wf = w.astype(jnp.float32)
    if group_size is not None and group_size < n:
        assert n % group_size == 0, (n, group_size)
        wg = wf.reshape(m, n // group_size, group_size)
        s, z = _affine_params(wg, bits)
        q = jnp.clip(jnp.round(wg / s) + z, 0, (1 << bits) - 1)
        return q.reshape(m, n).astype(jnp.uint8), s, z
    s, z = _affine_params(wf, bits)
    q = jnp.clip(jnp.round(wf / s) + z, 0, (1 << bits) - 1)
    return q.astype(jnp.uint8), s, z


def rtn_dequantize(codes: jnp.ndarray, s: jnp.ndarray, z: jnp.ndarray,
                   group_size: Optional[int] = None) -> jnp.ndarray:
    m, n = codes.shape
    q = codes.astype(jnp.float32)
    if group_size is not None and s.ndim == 3:
        q = q.reshape(m, -1, group_size)
        return (s * (q - z)).reshape(m, n)
    return s * (q - z)


def rtn_reconstruct(w: jnp.ndarray, bits: int,
                    group_size: Optional[int] = None) -> jnp.ndarray:
    """One-call W -> W~ for baselines."""
    codes, s, z = rtn_quantize(w, bits, group_size)
    return rtn_dequantize(codes, s, z, group_size).astype(w.dtype)


def rtn_codebook(w: jnp.ndarray, bits: int) -> jnp.ndarray:
    """The RTN grid expressed as a per-row LUT codebook (m, 2**bits).

    Lets RTN run on the same LUT-mpGEMM serving path for apples-to-apples
    deployment comparisons.
    """
    s, z = _affine_params(w.astype(jnp.float32), bits)
    levels = jnp.arange(1 << bits, dtype=jnp.float32)[None, :]
    return s * (levels - z)
