"""Serving observability: latency percentiles, SLO goodput, MFU/HBM tracker.

Three layers, all consuming data the serving stack already records:

  request side — TTFT (admission -> first token, `GenResult.prefill_s`)
      and ITL (inter-token latency, successive `GenResult.token_times`
      gaps) percentile summaries, plus SLO-attainment *goodput*: tokens/s
      counted only over requests that met their `SLO` (the metric the
      open-loop harness optimizes for — raw tok/s rewards starving the
      tail, goodput does not).
  step side — `StepTracker`: every jitted serving step has a FIXED shape,
      so its HLO FLOPs / HBM bytes are compile-time constants; dividing by
      the measured step wall time gives achieved FLOP/s and bytes/s, and a
      device DB entry turns those into achieved-vs-peak percentages (MFU
      and HBM-bandwidth utilization). The per-step costs come from
      `roofline.analysis`'s component analyzer over the engine's own
      compiled executables (`ServeEngine.step_costs`), so a regression in
      the bandwidth-bound LUT decode path shows up as % of hardware, not
      raw microseconds.
  policy side — `AdaptiveDraftPolicy`: hysteresis controller that flips
      whole slots to speculative prefix-width decode (3-bit drafts +
      4-bit verify, PR 6's nested bitstreams) while queue depth / SLO
      pressure is high and back when it clears.

The device DB follows the mfu-tracker discipline (SNIPPETS.md): named
entries with peak dense FLOP/s and HBM bandwidth; `tpu-v5e` mirrors the
roofline target constants (cross-checked in tests/test_metrics.py).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Iterable, List, Optional, Sequence, Union

__all__ = ["percentile", "latency_summary", "SLO", "meets_slo",
           "goodput_report", "prefix_cache_report", "DeviceSpec",
           "DEVICE_DB", "detect_device", "resolve_device", "StepTracker",
           "AdaptiveDraftPolicy"]


# ------------------------------------------------------------- percentiles

def percentile(xs: Sequence[float], q: float) -> float:
    """Linear-interpolated percentile (numpy's default method), defined on
    degenerate inputs: [] -> 0.0, a single sample -> that sample. `q` in
    [0, 100]."""
    if not 0.0 <= q <= 100.0:
        raise ValueError(f"percentile q={q} outside [0, 100]")
    s = sorted(float(x) for x in xs)
    if not s:
        return 0.0
    if len(s) == 1:
        return s[0]
    pos = (len(s) - 1) * q / 100.0
    lo = math.floor(pos)
    hi = min(lo + 1, len(s) - 1)
    return s[lo] + (s[hi] - s[lo]) * (pos - lo)


def _dist(xs: List[float]) -> Dict[str, float]:
    return {"p50": percentile(xs, 50), "p99": percentile(xs, 99),
            "mean": sum(xs) / len(xs) if xs else 0.0,
            "max": max(xs, default=0.0), "n": len(xs)}


def request_itls(result) -> List[float]:
    """Inter-token gaps of one GenResult (empty when <2 timestamps)."""
    ts = result.token_times or []
    return [b - a for a, b in zip(ts, ts[1:])]


def latency_summary(results: Iterable) -> Dict[str, Dict[str, float]]:
    """TTFT / ITL percentile summary over a set of GenResults."""
    results = list(results)
    ttfts = [r.prefill_s for r in results]
    itls = [g for r in results for g in request_itls(r)]
    e2e = [r.done_s for r in results if r.done_s]
    return {"ttft_s": _dist(ttfts), "itl_s": _dist(itls),
            "e2e_done_s": _dist(e2e)}


# ------------------------------------------------------------ SLO goodput

@dataclasses.dataclass(frozen=True)
class SLO:
    """Per-request latency deadlines. A request meets its SLO when its
    TTFT and its *worst* inter-token gap are both within budget (<=, so a
    request exactly on the boundary is good) and it was not killed by its
    own deadline. `inf` disables a term."""
    ttft_s: float = math.inf
    itl_s: float = math.inf

    def as_dict(self) -> Dict[str, float]:
        return {"ttft_s": self.ttft_s, "itl_s": self.itl_s}


def meets_slo(result, slo: SLO) -> bool:
    # only cleanly completed requests can count toward goodput: anything
    # the fault/overload machinery terminated (deadline, timeout, shed,
    # error, cancelled) is by definition not served within SLO
    if result.finish_reason not in ("eos", "length"):
        return False
    if result.prefill_s > slo.ttft_s:
        return False
    return max(request_itls(result), default=0.0) <= slo.itl_s


def goodput_report(results: Iterable, slo: SLO,
                   wall_s: float) -> Dict[str, float]:
    """Goodput = tokens/s over SLO-meeting requests only, next to the raw
    throughput the closed-loop benches used to report."""
    results = list(results)
    good = [r for r in results if meets_slo(r, slo)]
    tok = sum(len(r.tokens) for r in results)
    good_tok = sum(len(r.tokens) for r in good)
    w = max(wall_s, 1e-9)
    return {"slo": SLO(slo.ttft_s, slo.itl_s).as_dict(),
            "n_requests": len(results), "n_good": len(good),
            "slo_attainment": len(good) / len(results) if results else 0.0,
            "tokens": tok, "good_tokens": good_tok,
            "throughput_tok_per_s": tok / w,
            "goodput_tok_per_s": good_tok / w}


def prefix_cache_report(engine_stats: Dict) -> Optional[Dict[str, float]]:
    """Derived prefix-cache figures from an engine stats() block: the raw
    counters plus hit rate over admissions and the token fraction whose
    prefill was served from cache instead of recomputed. None when the
    session ran without a prefix cache."""
    pc = engine_stats.get("prefix_cache")
    if pc is None:
        return None
    adm = pc["prefix_hits"] + pc["prefix_misses"]
    fed = pc["prefix_hit_tokens"] + engine_stats.get("chunk_tokens", 0)
    return {**pc,
            "hit_rate": pc["prefix_hits"] / adm if adm else 0.0,
            "prefill_tokens_from_cache":
            pc["prefix_hit_tokens"] / fed if fed else 0.0}


# -------------------------------------------------------------- device DB

@dataclasses.dataclass(frozen=True)
class DeviceSpec:
    """Peak envelope of one accelerator: dense bf16/fp16 FLOP/s and HBM
    bytes/s (the two roofline axes the serving steps are measured
    against)."""
    name: str
    peak_flops: float
    hbm_bw: float


# tpu-v5e mirrors roofline.analysis.{PEAK_FLOPS, HBM_BW} — the repo's
# compile target; the GPU rows cover the paper's measurement hardware
# (RTX 4090) and the usual suspects. host-cpu is the honest entry for
# this container's harness runs (DDR-class bandwidth, no MXU).
DEVICE_DB: Dict[str, DeviceSpec] = {
    "tpu-v5e": DeviceSpec("tpu-v5e", 197e12, 819e9),
    "tpu-v5p": DeviceSpec("tpu-v5p", 459e12, 2765e9),
    "tpu-v4": DeviceSpec("tpu-v4", 275e12, 1228e9),
    "tpu-v6e": DeviceSpec("tpu-v6e", 918e12, 1640e9),
    "a100-sxm-80gb": DeviceSpec("a100-sxm-80gb", 312e12, 2039e9),
    "h100-sxm": DeviceSpec("h100-sxm", 989e12, 3352e9),
    "rtx-4090": DeviceSpec("rtx-4090", 165e12, 1008e9),
    "host-cpu": DeviceSpec("host-cpu", 2e11, 40e9),
}

_KIND_MAP = (
    ("v5 lite", "tpu-v5e"), ("v5e", "tpu-v5e"), ("v5p", "tpu-v5p"),
    ("v6 lite", "tpu-v6e"), ("v6e", "tpu-v6e"), ("v4", "tpu-v4"),
    ("h100", "h100-sxm"), ("a100", "a100-sxm-80gb"), ("4090", "rtx-4090"),
)


def detect_device() -> DeviceSpec:
    """Map jax's visible device to a DB entry; unknown kinds fall back to
    host-cpu (CPU harness) or tpu-v5e (unrecognized accelerator)."""
    import jax
    dev = jax.devices()[0]
    if dev.platform == "cpu":
        return DEVICE_DB["host-cpu"]
    kind = getattr(dev, "device_kind", "").lower()
    for needle, key in _KIND_MAP:
        if needle in kind:
            return DEVICE_DB[key]
    return DEVICE_DB["tpu-v5e"]


def resolve_device(spec: Union[bool, str, DeviceSpec, None]) -> DeviceSpec:
    """True -> autodetect; str -> DB lookup; DeviceSpec passes through."""
    if isinstance(spec, DeviceSpec):
        return spec
    if isinstance(spec, str):
        return DEVICE_DB[spec]
    return detect_device()


# ------------------------------------------------------------ step tracker

class StepTracker:
    """Achieved-vs-peak accounting per serving step.

    `costs` maps a step kind ('mixed' / 'draft' / 'verify') to an object
    with `.flops` and `.bytes` attributes (roofline.analysis.CompCost from
    `ServeEngine.step_costs`) — valid for every step of that kind because
    the serving jits are fixed-shape. `record` logs one step's wall time;
    `record_spec_round` logs one speculative round as its composite
    (m draft passes + 1 verify). The summary reports step-time
    percentiles and the achieved FLOP/s / HBM-bytes/s distributions as
    percentages of the device's peak (MFU and HBM utilization)."""

    def __init__(self, device: DeviceSpec, costs: Dict[str, object]):
        self.device = device
        self.costs = costs
        # (kind, dt_s, tokens, bytes, flops) per recorded step
        self.records: List = []

    def record(self, kind: str, dt_s: float, tokens: int = 0) -> None:
        c = self.costs[kind]
        self.records.append((kind, dt_s, tokens, c.bytes, c.flops))

    def record_spec_round(self, dt_s: float, draft_passes: int,
                          tokens: int = 0) -> None:
        d, v = self.costs["draft"], self.costs["verify"]
        self.records.append(
            ("spec_round", dt_s, tokens,
             draft_passes * d.bytes + v.bytes,
             draft_passes * d.flops + v.flops))

    def summary(self) -> Dict[str, object]:
        dts = [r[1] for r in self.records]
        bws = [r[3] / max(r[1], 1e-12) for r in self.records]
        fls = [r[4] / max(r[1], 1e-12) for r in self.records]
        tot_dt = sum(dts)
        tot_bytes = sum(r[3] for r in self.records)
        tot_flops = sum(r[4] for r in self.records)
        tot_tok = sum(r[2] for r in self.records)
        dev = self.device
        out = {
            "device": dev.name,
            "peak_tflops": dev.peak_flops / 1e12,
            "peak_hbm_gbps": dev.hbm_bw / 1e9,
            "steps": len(self.records),
            "tokens": tot_tok,
            "step_time_s": _dist(dts),
            "step_bytes": {k: c.bytes for k, c in self.costs.items()},
            "step_flops": {k: c.flops for k, c in self.costs.items()},
            "achieved_hbm_gbps": {"p50": percentile(bws, 50) / 1e9,
                                  "p99": percentile(bws, 99) / 1e9,
                                  "mean": tot_bytes / max(tot_dt, 1e-12)
                                  / 1e9},
            "achieved_tflops": {"p50": percentile(fls, 50) / 1e12,
                                "mean": tot_flops / max(tot_dt, 1e-12)
                                / 1e12},
            "hbm_util_pct": {
                "p50": 100.0 * percentile(bws, 50) / dev.hbm_bw,
                "p99": 100.0 * percentile(bws, 99) / dev.hbm_bw,
                "mean": 100.0 * tot_bytes / max(tot_dt, 1e-12) / dev.hbm_bw},
            "mfu_pct": {
                "p50": 100.0 * percentile(fls, 50) / dev.peak_flops,
                "mean": 100.0 * tot_flops / max(tot_dt, 1e-12)
                / dev.peak_flops},
        }
        return out


# --------------------------------------------------------- adaptive drafts

@dataclasses.dataclass
class AdaptiveDraftPolicy:
    """Load-adaptive draft precision (ROADMAP item 2 follow-on).

    While traffic pressure is high — arrived-but-unadmitted queue depth at
    or above `queue_hi`, or the oldest queued request waiting longer than
    `wait_hi_s` — the engine flips whole slots into speculative prefix
    decode: k tokens drafted at the nested bitstream's 3-bit prefix width
    and verified in one 4-bit pass (greedy output unchanged, ~0.8x weight
    bytes per emitted token at the measured accept rates). Pressure must
    fall to `queue_lo` or below AND the wait under `wait_lo_s` before it
    flips back (hysteresis, so the mode does not thrash at the
    threshold). `flips` counts mode transitions; the engine counts rounds
    executed while on."""
    queue_hi: int = 2
    queue_lo: int = 0
    wait_hi_s: float = math.inf
    wait_lo_s: float = math.inf
    on: bool = False
    flips: int = 0

    def reset(self) -> None:
        self.on = False
        self.flips = 0

    def update(self, queue_depth: int, oldest_wait_s: float) -> bool:
        """Feed the scheduler's current pressure; returns draft mode."""
        if not self.on:
            if queue_depth >= self.queue_hi or oldest_wait_s > self.wait_hi_s:
                self.on = True
                self.flips += 1
        else:
            clear = queue_depth <= self.queue_lo and (
                math.isinf(self.wait_lo_s) or oldest_wait_s <= self.wait_lo_s)
            if clear:
                self.on = False
                self.flips += 1
        return self.on
