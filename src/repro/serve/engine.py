"""Continuous-batching serving engine over a slot-addressed KV cache.

This is the paper's deployment scenario (§4.3 profiling) made traffic-
shaped: weight-only LUT-quantized model, memory-bound batched decode. The
subsystem is split three ways:

  scheduler.py — host-side request queue + slot table (`SlotScheduler`):
      admission into any free slot, per-request eos / length / deadline
      finish tracking, dense per-slot arrays for the device step.
  sampler.py   — per-sequence temperature / top-k sampling with stable
      per-request PRNG streams (results independent of co-scheduling).
  engine.py    — this file: owns the slot-batched cache (one row per
      scheduler slot, every cache variant behind the CacheFormat registry:
      full + ring attention, int8 KV, paged / paged_int8 K/V pools,
      RWKV / RG-LRU recurrent state) and drives ONE jitted fixed-shape
      decode step with an active mask. New requests are prefilled into free
      slots mid-flight (`prefill(..., cache=, slot=)` inserts the prompt's
      per-layer states into the slot row) while other slots keep decoding —
      no drain barrier, which is what keeps the LUT-mpGEMM decode path busy
      under mixed-length Poisson traffic.

Paged serving (`cfg.kv_format` in {'paged', 'paged_int8'}): the cache is a
per-layer page *pool* sized by `kv_pages` x `kv_page_size` tokens instead
of n_slots x max_len, a host-side `PageAllocator` hands pages to slots
lazily as sequences grow, and the (n_slots, max_pages) page table rides
into the jitted step as a plain array argument — slot count decouples from
max_len, so long and short requests share HBM and the pool can be sized
below the dense equivalent (under pressure the scheduler preempts the
lowest-priority slot by recompute).

`generate_batch` keeps the seed engine's static equal-length group path as
a reference implementation; greedy continuous batching is token-identical
to it per request (see tests/test_serve_scheduler.py).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.cache_formats import (contiguous_cfg, get_cache_format,
                                      kv_cache_bytes, kv_format_of,
                                      pages_for)
from repro.models import decode_step, init_serve_cache, prefill
from repro.sharding.context import ShardCtx, LOCAL
from .sampler import request_key, sample_tokens
from .scheduler import GenRequest, GenResult, PageAllocator, SlotScheduler

__all__ = ["GenRequest", "GenResult", "ServeEngine"]


class ServeEngine:
    def __init__(self, params, cfg: ModelConfig, ctx: ShardCtx = LOCAL,
                 max_len: int = 512, n_slots: int = 4):
        if cfg.is_encoder_decoder:
            raise NotImplementedError("serving is decoder-only")
        self.params = params
        self.ctx = ctx
        self.max_len = max_len
        self.n_slots = n_slots
        fmt = get_cache_format(kv_format_of(cfg))
        self.paged = fmt.paged
        if self.paged:
            ps = cfg.kv_page_size
            self.page_size = ps
            self.max_pages_per_slot = pages_for(max_len, ps)
            self.n_pages = cfg.kv_pages or n_slots * self.max_pages_per_slot
            # pin the pool geometry the cache init reads off the config
            cfg = dataclasses.replace(cfg, kv_pages=self.n_pages)
        self.cfg = cfg
        # the static reference path (generate_batch) always decodes on the
        # contiguous twin of the cache format — it IS the token-equivalence
        # oracle the paged path is tested against
        self.ref_cfg = contiguous_cfg(cfg)
        # the cache is donated: each step/admission rebinds it, and without
        # donation XLA copies the whole slot-batched KV cache per call
        if self.paged:
            self._decode = jax.jit(
                lambda p, c, t, pos, act, pg: decode_step(
                    p, c, t, pos, cfg, ctx, act, pg),
                donate_argnums=(1,))
        else:
            self._decode = jax.jit(
                lambda p, c, t, pos, act: decode_step(p, c, t, pos, cfg, ctx,
                                                      act),
                donate_argnums=(1,))
        self._decode_legacy = jax.jit(
            lambda p, c, t, pos: decode_step(p, c, t, pos, self.ref_cfg,
                                             ctx),
            donate_argnums=(1,))

        def _sample(logits, temps, top_ks, base_keys, nsamp):
            keys = jax.vmap(jax.random.fold_in)(base_keys, nsamp)
            return sample_tokens(logits, temps, top_ks, keys)

        self._sample = jax.jit(_sample)
        self._prefill_jits: Dict[int, object] = {}
        self.last_stats: Dict[str, float] = {}

    # -------------------------------------------------- continuous batching

    def _prefill_insert(self, cache, tokens: jnp.ndarray, slot: int,
                        pages=None):
        """Jitted per prompt length: prefill one sequence into a slot row
        (paged formats additionally take the slot's page-table row)."""
        plen = tokens.shape[1]
        fn = self._prefill_jits.get(plen)
        if fn is None:
            if self.paged:
                fn = jax.jit(lambda p, c, t, s, pg: prefill(
                    p, {"tokens": t}, self.cfg, self.ctx,
                    cache_len=self.max_len, cache=c, slot=s, pages=pg),
                    donate_argnums=(1,))
            else:
                fn = jax.jit(lambda p, c, t, s: prefill(
                    p, {"tokens": t}, self.cfg, self.ctx,
                    cache_len=self.max_len, cache=c, slot=s),
                    donate_argnums=(1,))
            self._prefill_jits[plen] = fn
        if self.paged:
            return fn(self.params, cache, tokens, jnp.int32(slot),
                      jnp.asarray(pages))
        return fn(self.params, cache, tokens, jnp.int32(slot))

    def serve(self, requests: List[GenRequest], seed: int = 0,
              arrival_times: Optional[List[float]] = None,
              n_slots: Optional[int] = None) -> List[GenResult]:
        """Continuous batching: admit on any free slot, decode a fixed slot
        batch with an active mask, results in submission order.

        `arrival_times` (seconds from call start, per request) simulates an
        open-loop arrival process; requests are not admitted before their
        arrival. Without it, everything is admittable immediately.
        """
        ns = n_slots or self.n_slots
        alloc = None
        if self.paged:
            alloc = PageAllocator(self.n_pages, self.page_size, ns,
                                  self.max_pages_per_slot)
        sched = SlotScheduler(ns, self.max_len, alloc=alloc)
        submitted = []
        for i, r in enumerate(requests):
            if arrival_times is not None:
                r = dataclasses.replace(r, arrival_s=float(arrival_times[i]))
            submitted.append(r)
        uids = [r.uid for r in submitted]
        # admission keys the PRNG stream on submission index (seed-stable
        # across calls); the FIFO queue must be arrival-ordered or an early
        # request queued behind a late one head-of-line blocks
        stream_ids = {r.uid: i for i, r in enumerate(submitted)}
        for r in sorted(submitted, key=lambda r: r.arrival_s):
            sched.submit(r)

        cache = init_serve_cache(self.params, {}, ns, self.max_len, self.cfg,
                                 self.ctx)
        base_keys = np.zeros((ns, 2), np.uint32)
        t_start = time.perf_counter()
        now = lambda: time.perf_counter() - t_start
        decode_s = 0.0
        decode_steps = 0
        decode_tokens = 0
        prefills = 0

        peak_pages = 0
        while not sched.done():
            for slot in sched.free_slots():
                req = sched.next_ready(now(), slot=slot)
                if req is None:
                    break
                t0 = time.perf_counter()
                toks = jnp.asarray([req.prompt], jnp.int32)
                pages_row = None if alloc is None else alloc.table()[slot]
                logits, cache = self._prefill_insert(cache, toks, slot,
                                                     pages_row)
                bkey = np.asarray(
                    request_key(seed, stream_ids[req.uid]), np.uint32)
                first = self._sample(
                    logits, jnp.asarray([req.temperature], jnp.float32),
                    jnp.asarray([req.top_k], jnp.int32),
                    jnp.asarray(bkey[None]), jnp.zeros((1,), jnp.int32))
                first = int(jax.block_until_ready(first)[0])
                base_keys[slot] = bkey
                prefills += 1
                sched.admit(slot, req, first, now(),
                            time.perf_counter() - t0)

            if sched.n_active == 0:
                nxt = sched.next_arrival()
                if nxt is None:
                    break
                time.sleep(max(0.0, min(nxt - now(), 0.05)))
                continue

            sched.grow_pages(now())     # map next-token pages, evict if dry
            toks, pos, act, temps, top_ks, nsamp = sched.batch_arrays()
            t0 = time.perf_counter()
            if alloc is not None:
                peak_pages = max(peak_pages, alloc.in_use)
                logits, cache = self._decode(
                    self.params, cache, jnp.asarray(toks), jnp.asarray(pos),
                    jnp.asarray(act), jnp.asarray(sched.page_table()))
            else:
                logits, cache = self._decode(
                    self.params, cache, jnp.asarray(toks), jnp.asarray(pos),
                    jnp.asarray(act))
            samp = self._sample(logits, jnp.asarray(temps),
                                jnp.asarray(top_ks), jnp.asarray(base_keys),
                                jnp.asarray(nsamp))
            samp = np.asarray(jax.block_until_ready(samp))
            decode_s += time.perf_counter() - t0
            decode_steps += 1
            decode_tokens += int(act.sum())
            sched.record_step(samp, now())

        wall = now()
        self.last_stats = {
            "wall_s": wall, "decode_s": decode_s,
            "decode_steps": decode_steps, "decode_tokens": decode_tokens,
            "decode_tok_per_s": decode_tokens / decode_s if decode_s else 0.0,
            "prefills": prefills, "slot_reuses": sched.slot_reuses,
            "kv_cache_bytes": kv_cache_bytes(cache),
            "evictions": sched.evictions,
        }
        if alloc is not None:
            self.last_stats.update(
                n_pages=self.n_pages, page_size=self.page_size,
                peak_pages_in_use=peak_pages)
            alloc.check()
        return [sched.results[u] for u in uids]

    def serve_queue(self, requests: List[GenRequest],
                    batch_size: Optional[int] = None,
                    seed: int = 0) -> List[GenResult]:
        """Legacy entry point — now continuous batching over `batch_size`
        slots (mixed prompt lengths welcome; no length grouping needed)."""
        return self.serve(requests, seed=seed, n_slots=batch_size)

    # ------------------------------------------------- static reference path

    def generate_batch(self, requests: List[GenRequest],
                       seed: int = 0) -> List[GenResult]:
        """Seed engine's static group path (equal-length prompts, drain the
        whole batch): kept as the equivalence reference for the continuous
        path and for offline batch jobs. Sampling is per-sequence. Always
        decodes on the contiguous twin of the cache format — which makes it
        the token-equivalence oracle for the paged path."""
        assert len({len(r.prompt) for r in requests}) == 1, \
            "static path processes equal-length prompt groups"
        b = len(requests)
        plen = len(requests[0].prompt)
        max_new = max(r.max_new for r in requests)
        toks = jnp.asarray([r.prompt for r in requests], jnp.int32)
        temps = jnp.asarray([r.temperature for r in requests], jnp.float32)
        top_ks = jnp.asarray([r.top_k for r in requests], jnp.int32)
        base_keys = jnp.stack([request_key(seed, j)
                               for j in range(len(requests))])

        t0 = time.perf_counter()
        logits, cache = prefill(self.params, {"tokens": toks}, self.ref_cfg,
                                self.ctx, cache_len=self.max_len)
        jax.block_until_ready(logits)
        prefill_s = time.perf_counter() - t0

        outs = [[] for _ in range(b)]
        done = np.zeros(b, bool)
        cur = self._sample(logits, temps, top_ks, base_keys,
                           jnp.zeros((b,), jnp.int32))
        cur = jax.block_until_ready(cur)
        t1 = time.perf_counter()
        steps = 0
        for i in range(max_new):
            cur_np = np.asarray(cur)
            for j in range(b):
                if not done[j]:
                    outs[j].append(int(cur_np[j]))
                    r = requests[j]
                    if (r.eos_id is not None and int(cur_np[j]) == r.eos_id) \
                            or len(outs[j]) >= r.max_new:
                        done[j] = True
            if done.all() or plen + i + 1 >= self.max_len:
                break
            pos = jnp.full((b,), plen + i, jnp.int32)
            logits, cache = self._decode_legacy(self.params, cache, cur, pos)
            cur = self._sample(logits, temps, top_ks, base_keys,
                               jnp.full((b,), i + 1, jnp.int32))
            cur = jax.block_until_ready(cur)
            steps += 1
        decode_s = time.perf_counter() - t1
        return [GenResult(tokens=outs[j], prefill_s=prefill_s,
                          decode_s=decode_s, steps=steps,
                          finish_reason="eos" if (requests[j].eos_id is not None
                                                  and outs[j] and outs[j][-1]
                                                  == requests[j].eos_id)
                          else "length")
                for j in range(b)]
