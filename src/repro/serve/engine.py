"""Continuous-batching serving engine on ONE token-budget mixed step.

This is the paper's deployment scenario (§4.3 profiling) made traffic-
shaped: weight-only LUT-quantized model, memory-bound batched decode. The
subsystem is split three ways:

  scheduler.py — host-side request queue + slot table (`SlotScheduler`):
      EDF admission into any free slot, chunk scheduling into token-budget
      lanes, per-request eos / length / deadline finish tracking.
  sampler.py   — per-sequence temperature / top-k sampling with stable
      per-request PRNG streams (results independent of co-scheduling).
  engine.py    — this file: owns the slot-batched cache (one row per
      scheduler slot, every cache variant behind the CacheFormat registry:
      full + ring attention, int8 KV, paged / paged_int8 K/V pools,
      RWKV / RG-LRU recurrent state) and drives ONE jitted fixed-shape
      `models.mixed_step`.

The execution surface is a single jit: each step consumes up to
`token_budget` lanes — one decode token per live slot plus prompt chunks
of at most `prefill_chunk` tokens for admissions — described by a flat
`TokenBatch` (LUT-GEMM-style kernels stay efficient as the token dimension
grows, so prompt chunks and decode tokens share the very same quantized-
kernel launches). Admitting a 2048-token prompt therefore never stalls
in-flight decode for more than one budget step, and the compile count is
bounded by the one static lane shape — there are no per-prompt-length
prefill compiles. `prefill_chunk=0` keeps the legacy whole-prompt-prefill
admission (a separate jit per prompt length, decode frozen for the whole
prefill) as the measured "before" of benchmarks/run.py's TTFT scenario.

Paged serving (`cfg.kv_format` in {'paged', 'paged_int8'}): the cache is a
per-layer page *pool* sized by `kv_pages` x `kv_page_size` tokens instead
of n_slots x max_len, a host-side `PageAllocator` hands pages to slots
chunk by chunk as prompts feed and sequences grow, and the (n_slots,
max_pages) page table rides inside the TokenBatch — slot count decouples
from max_len, so long and short requests share HBM and the pool can be
sized below the dense equivalent (under pressure the scheduler preempts
the lowest-priority slot by recompute). Models whose attention is all
sliding-window additionally release pages that slid fully out of the
window back to the pool mid-flight.

`generate_batch` keeps the seed engine's static equal-length group path as
a reference implementation; greedy continuous batching is token-identical
to it per request (see tests/test_serve_scheduler.py and
tests/test_mixed_step.py) — it IS the whole-prompt-prefill equivalence
oracle for the chunked path.
"""
from __future__ import annotations

import dataclasses
import hashlib
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.cache_formats import (contiguous_cfg, copy_page_cells,
                                      get_cache_format, kv_cache_bytes,
                                      kv_format_of, pages_for,
                                      restore_cells, snapshot_cells)
from repro.models import (TokenBatch, decode_step, init_serve_cache,
                          mixed_step, prefill)
from repro.sharding.context import ShardCtx, LOCAL
from .sampler import request_key, sample_tokens
from .scheduler import (GenRequest, GenResult, PageAllocator, PrefixCache,
                        PrefixHasher, SlotScheduler, TokenEvent)

__all__ = ["GenRequest", "GenResult", "ServeEngine", "ServeSession",
           "TokenEvent"]


class ServeEngine:
    def __init__(self, params, cfg: ModelConfig, ctx: ShardCtx = LOCAL,
                 max_len: int = 512, n_slots: int = 4,
                 prefill_chunk: int = 32, token_budget: int = 0,
                 spec_k: int = 0, draft_bits: int = 0, adaptive=None,
                 prefix_cache: bool = False):
        if cfg.is_encoder_decoder:
            raise NotImplementedError("serving is decoder-only")
        self.params = params
        self.ctx = ctx
        self.max_len = max_len
        self.n_slots = n_slots
        # per-step token budget: every live slot's decode token plus up to
        # `prefill_chunk` prompt-chunk lanes. 0 restores the legacy
        # whole-prompt-prefill admission (per-length jits, decode stalls).
        self.prefill_chunk = prefill_chunk
        self.token_budget = token_budget or \
            n_slots + max(prefill_chunk, 0 if prefill_chunk else 1)
        assert self.token_budget >= n_slots + min(prefill_chunk, 1), \
            "token budget must cover every slot's decode lane + a chunk"
        fmt = get_cache_format(kv_format_of(cfg))
        self.paged = fmt.paged
        if self.paged and prefill_chunk == 0:
            raise ValueError(
                "prefill_chunk=0 (legacy whole-prompt admission) is the "
                "contiguous stall baseline only; paged serving admits "
                "through the chunked token-budget step — pass a chunk "
                "size >= 1 or a contiguous --kv-format")
        if self.paged:
            ps = cfg.kv_page_size
            self.page_size = ps
            self.max_pages_per_slot = pages_for(max_len, ps)
            self.n_pages = cfg.kv_pages or n_slots * self.max_pages_per_slot
            # pin the pool geometry the cache init reads off the config
            cfg = dataclasses.replace(cfg, kv_pages=self.n_pages)
        self.cfg = cfg
        # --- page-granular prefix caching (shared-prompt KV reuse) ---
        # requests sharing a prompt prefix map the same physical pages;
        # admission skips straight past the cached run. Needs the paged
        # pool (page-table surgery IS the reuse mechanism) and is gated
        # off for recurrent layers: rwkv/rglru state folds every token,
        # so prefill cannot skip chunks (their reset fires at fed==0 and
        # there is no per-position state to map in).
        self.prefix_cache = bool(prefix_cache)
        if self.prefix_cache:
            if not self.paged:
                raise ValueError(
                    "prefix caching shares pages of the paged KV pool; "
                    "serve with kv_format 'paged' or 'paged_int8'")
            bad_kinds = set(cfg.layer_kinds) - {"attn", "local"}
            if bad_kinds:
                raise ValueError(
                    f"prefix caching skips prompt chunks, which recurrent "
                    f"layers {sorted(bad_kinds)} cannot — their state "
                    f"folds every token in order")
            # one fixed-shape jitted device copy serves every COW: src/dst
            # are traced scalars, the donated cache rebinds in place
            self._copy_page = jax.jit(
                lambda c, s, d: copy_page_cells(c, s, d),
                donate_argnums=(0,))
            self.cache_fingerprint = self._fingerprint(params, cfg, ctx)
        # --- self-speculative decoding (nested-bitstream draft weights) ---
        # k greedy draft tokens per slot per round, drafted at draft_bits
        # prefix width (0 = full-width "exact" drafts); the verify pass
        # scores all k+1 positions in one mixed_step and rejected cache
        # writes are rolled back bitwise, so greedy outputs stay
        # token-identical to spec_k=0.
        assert spec_k >= 0
        assert draft_bits in (0, 2, 3), "draft_bits must be 0, 2 or 3"
        if adaptive is not None and spec_k == 0:
            raise ValueError("load-adaptive draft precision gates the "
                             "speculative rounds — it needs spec_k > 0")
        self.draft_bits = draft_bits
        self.spec_fallback = ""
        kinds_all = set(cfg.layer_kinds)
        if spec_k and kinds_all & {"rwkv", "rglru"}:
            # recurrent state folds every token irreversibly — there is no
            # cell-level rollback, so these stacks serve non-speculatively
            spec_k, self.spec_fallback = 0, "recurrent state (no rollback)"
        if spec_k and "local" in kinds_all and not self.paged:
            # a contiguous sliding-window ring aliases position p to cell
            # p % w; a round's k+1 in-flight positions must stay distinct
            # or accepted writes and rollbacks would collide on one cell
            spec_k = min(spec_k, min(max_len, cfg.sliding_window) - 1)
        if spec_k and cfg.n_experts > 0:
            self._moe_spec_guard(n_slots, spec_k)
        self.spec_k = spec_k
        # load-adaptive draft precision (AdaptiveDraftPolicy): speculative
        # low-bit-prefix rounds only while queue/SLO pressure is on; if a
        # fallback zeroed spec_k the policy can never fire, so drop it
        self.adaptive = adaptive if spec_k else None
        # sliding-window page release is sound only when NO attention layer
        # keeps whole-history reach (every attn layer is 'local')
        kinds = {k for k in cfg.layer_kinds if k in ("attn", "local")}
        self.release_window = cfg.sliding_window \
            if self.paged and kinds == {"local"} else None
        # the static reference path (generate_batch) always decodes on the
        # contiguous twin of the cache format — it IS the token-equivalence
        # oracle the paged path is tested against
        self.ref_cfg = contiguous_cfg(cfg)
        # THE serving jit: one fixed-shape token-budget step for decode AND
        # chunked prefill; the cache is donated — each step rebinds it, and
        # without donation XLA copies the whole slot-batched KV per call
        self._mixed = jax.jit(
            lambda p, c, tb: mixed_step(p, c, tb, cfg, ctx),
            donate_argnums=(1,))
        self._decode_legacy = jax.jit(
            lambda p, c, t, pos: decode_step(p, c, t, pos, self.ref_cfg,
                                             ctx),
            donate_argnums=(1,))
        # speculative jits: draft steps run the SAME mixed step under a
        # draft-pass policy (nested formats stream their prefix planes
        # only), the verify step scores k+1 lanes per slot via
        # emit_groups, and snapshot/restore bracket each round so
        # rejected cache writes disappear bitwise
        dctx = ctx.with_draft_bits(draft_bits) if draft_bits else ctx
        self._mixed_draft = self._mixed if not draft_bits else jax.jit(
            lambda p, c, tb: mixed_step(p, c, tb, cfg, dctx),
            donate_argnums=(1,))
        if self.spec_k:
            eg = self.spec_k + 1
            self._verify = jax.jit(
                lambda p, c, tb: mixed_step(p, c, tb, cfg, ctx,
                                            emit_groups=eg),
                donate_argnums=(1,))
            self._snapshot = jax.jit(
                lambda c, s, q, pg: snapshot_cells(c, s, q, pages=pg))
            self._restore = jax.jit(
                lambda c, sn, s, q, keep, pg: restore_cells(
                    c, sn, s, q, keep, pages=pg),
                donate_argnums=(0,))
            self._argmax = jax.jit(lambda l: jnp.argmax(l, axis=-1))
            self._finite = jax.jit(
                lambda l: jnp.all(jnp.isfinite(l), axis=-1))

        def _sample(logits, temps, top_ks, base_keys, nsamp):
            keys = jax.vmap(jax.random.fold_in)(base_keys, nsamp)
            toks = sample_tokens(logits, temps, top_ks, keys)
            # per-row finite flag folded into the same jit: the NaN/Inf
            # guard costs no extra device round-trip, and a poisoned
            # logits row is detected the step it appears (the session
            # quarantines the slot before the garbage token is recorded)
            return toks, jnp.all(jnp.isfinite(logits), axis=-1)

        self._sample = jax.jit(_sample)
        self._prefill_jits: Dict[int, object] = {}   # legacy admission only
        self.last_stats: Dict[str, float] = {}
        self.last_session: Optional["ServeSession"] = None

    # ------------------------------------------------- prefix-cache keying

    @staticmethod
    def _fingerprint(params, cfg, ctx) -> bytes:
        """Seed for the prefix hash chain: model config + precision
        policy context + every weight leaf's path/shape/dtype. Two
        engines whose KV bytes could differ for the same token prefix —
        different weights, quantization, cache format, page size — get
        different chains, so their cache entries can never alias. Leaf
        VALUES are not hashed (device pulls would stall construction);
        the cache is per-session anyway, so the fingerprint only needs to
        separate configurations, not checkpoint revisions."""
        h = hashlib.blake2b(digest_size=16)
        h.update(repr(cfg).encode())
        h.update(repr(ctx).encode())
        leaves = jax.tree_util.tree_flatten_with_path(params)[0]
        for path, leaf in leaves:
            h.update(jax.tree_util.keystr(path).encode())
            h.update(f"{getattr(leaf, 'shape', ())}"
                     f"{getattr(leaf, 'dtype', '')}".encode())
        return h.digest()

    # ---------------------------------------------- speculative decoding

    def _moe_spec_guard(self, ns: int, k: int) -> None:
        """Dropping-MoE + speculation guard: the verify step routes up to
        ns*(k+1) lanes through the experts in ONE dispatch, and capacity
        ranks are computed across the whole step — a token dropped there
        would silently diverge from the sequential baseline. Require
        per-expert capacity that absorbs the worst case (every assignment
        landing on one expert) or refuse at construction."""
        from repro.models.moe import capacity
        t_v = ns * (k + 1)
        need = t_v * self.cfg.top_k
        cap = capacity(t_v, self.cfg.top_k, self.cfg.n_experts,
                       self.cfg.capacity_factor)
        if cap < need:
            raise ValueError(
                f"speculative decoding (spec_k={k}) over a dropping-MoE "
                f"config: verify-step per-expert capacity {cap} cannot "
                f"absorb the worst-case {need} routed assignments, so "
                f"tokens could drop and break greedy token-identity; "
                f"raise capacity_factor to >= n_experts "
                f"({self.cfg.n_experts}) or serve with spec_k=0")

    def _spec_round(self, cache, sched: SlotScheduler, budget: int,
                    now):
        """One speculative round replacing up to spec_k+1 sequential
        decode steps: k chained draft passes at prefix width propose one
        greedy token per slot each, ONE verify pass at full width scores
        all k+1 positions per slot (lane groups via emit_groups), the
        longest draft prefix matching the verify argmaxes is accepted
        (plus the verify token itself as the bonus/correction), and
        every cell a rejected — or merely drafted — token touched is
        restored bitwise from a pre-round snapshot. Returns
        (cache, drafted, accepted_drafts, emitted, draft_passes,
        bad_slots) — bad_slots are slots whose verify logits went
        NaN/Inf: they accept nothing (their cells roll back with the
        rejects) and the caller quarantines them."""
        k = self.spec_k
        ns = sched.n_slots
        lanes_v = ns * (k + 1)
        part = []
        for i, st in enumerate(sched.slots):
            if st is None:
                continue
            # per-slot draft depth: stay inside the cache row and the
            # request's token budget (ke=0 slots still ride the verify
            # lane j=0 — for them the round IS a plain decode step)
            ke = min(k, self.max_len - st.pos - 2,
                     st.req.max_new - len(st.tokens) - 1)
            part.append((i, st, max(ke, 0)))
        pages = None if sched.alloc is None \
            else jnp.asarray(sched.page_table())

        # fixed-shape cell coordinates for the whole round: lane i*(k+1)+j
        # is slot i's position pos_i+1+j; unoccupied slots keep the OOB
        # slot index (clamped reads, keep=False on every restore)
        s_slots = np.full(lanes_v, ns, np.int32)
        s_pos = np.zeros(lanes_v, np.int32)
        touched = np.zeros(lanes_v, bool)
        for i, st, ke in part:
            for j in range(k + 1):
                lane = i * (k + 1) + j
                s_slots[lane] = i
                s_pos[lane] = min(st.pos + 1 + j, self.max_len - 1)
                touched[lane] = j <= ke
        j_slots, j_pos = jnp.asarray(s_slots), jnp.asarray(s_pos)
        snap = self._snapshot(cache, j_slots, j_pos, pages)

        # k chained draft passes: drafts[i, 0] is the slot's pending
        # (already sampled, not yet fed) token, drafts[i, m+1] the greedy
        # pick of draft pass m. Draft lanes reuse the budget-shaped
        # TokenBatch so no new mixed-step shape compiles.
        drafts = np.zeros((ns, k + 1), np.int64)
        for i, st, ke in part:
            drafts[i, 0] = st.cur_token
        reset = jnp.zeros(ns, bool)
        ran_draft = False
        draft_passes = 0
        for m in range(k):
            live = [(i, st, ke) for (i, st, ke) in part if ke > m]
            if not live:
                break
            ran_draft = True
            draft_passes += 1
            tok = np.zeros(budget, np.int32)
            slt = np.zeros(budget, np.int32)
            pos = np.zeros(budget, np.int32)
            act = np.zeros(budget, bool)
            for lane, (i, st, ke) in enumerate(live):
                tok[lane] = drafts[i, m]
                slt[lane] = i
                pos[lane] = st.pos + 1 + m
                act[lane] = True
            tb = TokenBatch(
                tokens=jnp.asarray(tok), slots=jnp.asarray(slt),
                positions=jnp.asarray(pos), horizon=jnp.asarray(pos),
                emit=jnp.asarray(act), active=jnp.asarray(act),
                reset=reset, pages=pages)
            logits, cache = self._mixed_draft(self.params, cache, tb)
            d = np.asarray(self._argmax(logits))
            for i, st, ke in live:
                drafts[i, m + 1] = int(d[i])

        # clear draft residue before verifying: a draft pass wrote
        # prefix-width KV at its position, and on a contiguous
        # sliding-window ring that cell aliases live history the verify
        # queries still need — restore puts the pre-round bytes back;
        # the verify step re-writes all k+1 positions at full width
        # through its own in-step overlay (token_write_view)
        if ran_draft:
            cache = self._restore(cache, snap, j_slots, j_pos,
                                  jnp.asarray(touched), pages)

        tok = np.zeros(lanes_v, np.int32)
        slt = np.zeros(lanes_v, np.int32)
        pos = np.zeros(lanes_v, np.int32)
        hor = np.zeros(lanes_v, np.int32)
        act = np.zeros(lanes_v, bool)
        for i, st, ke in part:
            for j in range(ke + 1):
                lane = i * (k + 1) + j
                tok[lane] = drafts[i, j]
                slt[lane] = i
                pos[lane] = st.pos + 1 + j
                hor[lane] = st.pos + 1
                act[lane] = True
        tb = TokenBatch(
            tokens=jnp.asarray(tok), slots=jnp.asarray(slt),
            positions=jnp.asarray(pos), horizon=jnp.asarray(hor),
            emit=jnp.asarray(act), active=jnp.asarray(act),
            reset=reset, pages=pages)
        logits, cache = self._verify(self.params, cache, tb)
        v = np.asarray(self._argmax(logits)).reshape(ns, k + 1)
        fin = np.asarray(self._finite(logits)).reshape(ns, k + 1)

        # accept-prefix: verify lane j is the model's true greedy token
        # AFTER consuming drafts[i, 0..j]; accept drafts while they match,
        # emit the first mismatching verify token as the free correction
        keep_post = np.zeros(lanes_v, bool)
        drafted = accepted = emitted = 0
        bad: List[int] = []
        tstamp = now()
        for i, st, ke in part:
            if not fin[i, :ke + 1].all():
                # poisoned verify logits: accept nothing — keep_post
                # stays False so the round rolls back bitwise, and the
                # session quarantines the slot for replay
                bad.append(i)
                drafted += ke
                continue
            n_acc = 0
            while n_acc < ke and drafts[i, n_acc + 1] == v[i, n_acc]:
                n_acc += 1
            toks = [int(v[i, j]) for j in range(n_acc + 1)]
            # the scheduler may append fewer than offered (eos / length /
            # deadline); cells past what it kept are rolled back too
            n_app = sched.record_speculative(i, toks, tstamp)
            keep_post[i * (k + 1):i * (k + 1) + n_app] = True
            drafted += ke
            accepted += max(n_app - 1, 0)
            emitted += n_app
        cache = self._restore(cache, snap, j_slots, j_pos,
                              jnp.asarray(touched & ~keep_post), pages)
        jax.block_until_ready(cache)
        return cache, drafted, accepted, emitted, draft_passes, bad

    # -------------------------------------------------- continuous batching

    def _prefill_insert(self, cache, tokens: jnp.ndarray, slot: int):
        """Legacy admission only (`prefill_chunk=0`): jitted per prompt
        length — the compile-count and stall profile the unified
        token-budget step exists to remove."""
        plen = tokens.shape[1]
        fn = self._prefill_jits.get(plen)
        if fn is None:
            fn = jax.jit(lambda p, c, t, s: prefill(
                p, {"tokens": t}, self.cfg, self.ctx,
                cache_len=self.max_len, cache=c, slot=s),
                donate_argnums=(1,))
            self._prefill_jits[plen] = fn
        return fn(self.params, cache, tokens, jnp.int32(slot))

    # ------------------------------------------------ per-step cost models

    def step_costs(self, n_slots: Optional[int] = None,
                   budget: Optional[int] = None) -> Dict[str, object]:
        """HLO cost (FLOPs / TPU-reality HBM bytes) per serving-step kind.

        Every serving jit is fixed-shape, so one abstract lowering prices
        EVERY step of its kind: 'mixed' (the token-budget step), and with
        speculation 'draft' (prefix-width pass — reads 0.75x code bytes at
        draft_bits=3, visible here as smaller step bytes) and 'verify'
        (the k+1-lane scoring pass). The analyzer is
        `roofline.analysis.compiled_cost`, the same component accounting
        the roofline harness uses — this is the wiring that turns measured
        step wall times into achieved-vs-peak percentages
        (`serve.metrics.StepTracker`)."""
        from repro.roofline.analysis import compiled_cost
        ns = n_slots or self.n_slots
        legacy = self.prefill_chunk == 0
        budget = budget or max(self.token_budget,
                               ns + (0 if legacy else 1))
        p_sds = jax.eval_shape(lambda p: p, self.params)
        cache_sds = jax.eval_shape(
            lambda p: init_serve_cache(p, {}, ns, self.max_len, self.cfg,
                                       self.ctx), p_sds)

        def tb_sds(lanes: int) -> TokenBatch:
            i32 = lambda *s: jax.ShapeDtypeStruct(s, jnp.int32)
            b8 = lambda *s: jax.ShapeDtypeStruct(s, jnp.bool_)
            return TokenBatch(
                tokens=i32(lanes), slots=i32(lanes), positions=i32(lanes),
                horizon=i32(lanes), emit=b8(lanes), active=b8(lanes),
                reset=b8(ns),
                pages=i32(ns, self.max_pages_per_slot) if self.paged
                else None)

        costs = {"mixed": compiled_cost(
            self._mixed.lower(p_sds, cache_sds, tb_sds(budget)).compile())}
        if self.spec_k:
            costs["draft"] = costs["mixed"] if not self.draft_bits else \
                compiled_cost(self._mixed_draft.lower(
                    p_sds, cache_sds, tb_sds(budget)).compile())
            costs["verify"] = compiled_cost(self._verify.lower(
                p_sds, cache_sds,
                tb_sds(ns * (self.spec_k + 1))).compile())
        return costs

    # ----------------------------------------------------- session driving

    def start(self, n_slots: Optional[int] = None, seed: int = 0,
              track=None, adaptive=None, faults=None,
              queue_cap: Optional[int] = None,
              poison_threshold: int = 3, max_step_retries: int = 3,
              retry_backoff_s: float = 0.005) -> "ServeSession":
        """Open a reentrant serving session: `submit` requests any time,
        pump `step()` (one admission + one jitted round each call, token
        events returned per call), read `stats()` whenever. The closed-loop
        `serve()` and the async SSE front end both drive this same API.

        `track`: enable the achieved-vs-peak StepTracker — True
        (autodetect device), a device-DB key ('tpu-v5e'), or a DeviceSpec.
        `adaptive`: an AdaptiveDraftPolicy overriding the engine's.
        `faults`: a ServeFaultInjector for chaos runs. `queue_cap` bounds
        the arrived-but-unadmitted queue (overflow sheds with
        finish_reason='shed'); `poison_threshold` / `max_step_retries` /
        `retry_backoff_s` tune the fault watchdog."""
        return ServeSession(self, n_slots=n_slots, seed=seed, track=track,
                            adaptive=adaptive if adaptive is not None
                            else self.adaptive, faults=faults,
                            queue_cap=queue_cap,
                            poison_threshold=poison_threshold,
                            max_step_retries=max_step_retries,
                            retry_backoff_s=retry_backoff_s)

    def serve(self, requests: List[GenRequest], seed: int = 0,
              arrival_times: Optional[List[float]] = None,
              n_slots: Optional[int] = None,
              track=None, faults=None,
              queue_cap: Optional[int] = None) -> List[GenResult]:
        """Continuous batching on the unified token-budget step: admit on
        any free slot, lane decode tokens + prompt chunks into ONE jitted
        fixed-shape `mixed_step`, results in submission order. A thin
        closed-loop driver over the `start()`/`step()` session API.

        `arrival_times` (seconds from call start, per request) simulates an
        open-loop arrival process; requests are not admitted before their
        arrival. Without it, everything is admittable immediately.
        `track` enables the per-step MFU/HBM tracker, `faults` injects a
        chaos schedule, `queue_cap` sheds overload (see `start`).
        """
        sess = self.start(n_slots=n_slots, seed=seed, track=track,
                          faults=faults, queue_cap=queue_cap)
        submitted = []
        for i, r in enumerate(requests):
            if arrival_times is not None:
                r = dataclasses.replace(r, arrival_s=float(arrival_times[i]))
            submitted.append(r)
        uids = [r.uid for r in submitted]
        # admission keys the PRNG stream on submission index (seed-stable
        # across calls); the FIFO queue must be arrival-ordered or an early
        # request queued behind a late one head-of-line blocks
        stream_ids = {r.uid: i for i, r in enumerate(submitted)}
        for r in sorted(submitted, key=lambda r: r.arrival_s):
            sess.submit(r, stream_id=stream_ids[r.uid])
        while not sess.done():
            sess.step()
        if faults is not None:
            faults.finish(sess.sched.alloc)
        self.last_stats = sess.stats()
        self.last_session = sess
        if sess.sched.alloc is not None:
            sess.sched.alloc.check()
        return [sess.results[u] for u in uids]

    def serve_queue(self, requests: List[GenRequest],
                    batch_size: Optional[int] = None,
                    seed: int = 0) -> List[GenResult]:
        """Legacy entry point — now continuous batching over `batch_size`
        slots (mixed prompt lengths welcome; no length grouping needed)."""
        return self.serve(requests, seed=seed, n_slots=batch_size)

    # ------------------------------------------------- static reference path

    def generate_batch(self, requests: List[GenRequest],
                       seed: int = 0) -> List[GenResult]:
        """Seed engine's static group path (equal-length prompts, drain the
        whole batch): kept as the equivalence reference for the continuous
        path and for offline batch jobs. Sampling is per-sequence. Always
        decodes on the contiguous twin of the cache format — which makes it
        the token-equivalence oracle for the paged path."""
        assert len({len(r.prompt) for r in requests}) == 1, \
            "static path processes equal-length prompt groups"
        b = len(requests)
        plen = len(requests[0].prompt)
        max_new = max(r.max_new for r in requests)
        toks = jnp.asarray([r.prompt for r in requests], jnp.int32)
        temps = jnp.asarray([r.temperature for r in requests], jnp.float32)
        top_ks = jnp.asarray([r.top_k for r in requests], jnp.int32)
        base_keys = jnp.stack([request_key(seed, j)
                               for j in range(len(requests))])

        t0 = time.perf_counter()
        logits, cache = prefill(self.params, {"tokens": toks}, self.ref_cfg,
                                self.ctx, cache_len=self.max_len)
        jax.block_until_ready(logits)
        prefill_s = time.perf_counter() - t0

        outs = [[] for _ in range(b)]
        done = np.zeros(b, bool)
        cur, _ = self._sample(logits, temps, top_ks, base_keys,
                              jnp.zeros((b,), jnp.int32))
        cur = jax.block_until_ready(cur)
        t1 = time.perf_counter()
        steps = 0
        for i in range(max_new):
            cur_np = np.asarray(cur)
            for j in range(b):
                if not done[j]:
                    outs[j].append(int(cur_np[j]))
                    r = requests[j]
                    if (r.eos_id is not None and int(cur_np[j]) == r.eos_id) \
                            or len(outs[j]) >= r.max_new:
                        done[j] = True
            if done.all() or plen + i + 1 >= self.max_len:
                break
            pos = jnp.full((b,), plen + i, jnp.int32)
            logits, cache = self._decode_legacy(self.params, cache, cur, pos)
            cur, _ = self._sample(logits, temps, top_ks, base_keys,
                                  jnp.full((b,), i + 1, jnp.int32))
            cur = jax.block_until_ready(cur)
            steps += 1
        decode_s = time.perf_counter() - t1
        return [GenResult(tokens=outs[j], prefill_s=prefill_s,
                          decode_s=decode_s, steps=steps,
                          finish_reason="eos" if (requests[j].eos_id is not None
                                                  and outs[j] and outs[j][-1]
                                                  == requests[j].eos_id)
                          else "length")
                for j in range(b)]


class ServeSession:
    """Reentrant serving session: the engine's continuous-batching loop
    unrolled into submit / step / drain, so ANY driver — the closed-loop
    `ServeEngine.serve()`, the asyncio SSE front end's driver thread, the
    open-loop load generator — pumps the identical control flow and gets
    identical greedy tokens.

    One `step()` call performs at most one admission sweep plus one jitted
    round (a token-budget mixed step, a speculative round, or an idle
    wait), and returns the `TokenEvent`s produced since the last call —
    first token on admission, one event per decode token, interpolated
    events for speculative batches, and a terminal `done` event per
    request. The scheduler is NOT thread-safe: all calls must come from
    one driver thread; concurrent producers marshal submissions to it
    (see serve/frontend.py).
    """

    def __init__(self, engine: ServeEngine, n_slots: Optional[int] = None,
                 seed: int = 0, track=None, adaptive=None, faults=None,
                 queue_cap: Optional[int] = None,
                 poison_threshold: int = 3, max_step_retries: int = 3,
                 retry_backoff_s: float = 0.005):
        self.engine = engine
        self.seed = seed
        ns = n_slots or engine.n_slots
        self.n_slots = ns
        self.legacy = engine.prefill_chunk == 0
        self.budget = max(engine.token_budget,
                          ns + (0 if self.legacy else 1))
        # chunks must fit the lanes left after every decode slot's token —
        # clamped once per session so a prompt's chunk boundaries (and
        # therefore its greedy output) never depend on co-scheduling
        self.chunk_cap = engine.max_len if self.legacy \
            else min(engine.prefill_chunk, self.budget - ns)
        alloc = None
        if engine.paged:
            alloc = PageAllocator(engine.n_pages, engine.page_size, ns,
                                  engine.max_pages_per_slot)
        prefix = None
        if engine.prefix_cache:
            prefix = PrefixCache(alloc, PrefixHasher(
                engine.page_size, engine.cache_fingerprint))
        self.sched = SlotScheduler(ns, engine.max_len, alloc=alloc,
                                   window=engine.release_window,
                                   queue_cap=queue_cap,
                                   poison_threshold=poison_threshold,
                                   prefix_cache=prefix)
        # fault watchdog state (see step()): a failed round retries with
        # exponential backoff; past the budget every active slot is
        # quarantined (requeue-or-abort) so the session cannot livelock
        self.faults = faults
        self.max_step_retries = max_step_retries
        self.retry_backoff_s = retry_backoff_s
        self.step_seq = 0               # fault-schedule clock (attempted rounds)
        self.step_retries = 0
        self.cache_recoveries = 0
        self.watchdog_exhausted = 0
        self.last_fault = ""
        if engine.spec_k and engine.cfg.n_experts > 0 \
                and ns != engine.n_slots:
            engine._moe_spec_guard(ns, engine.spec_k)  # verify width changed
        self.cache = init_serve_cache(engine.params, {}, ns, engine.max_len,
                                      engine.cfg, engine.ctx)
        self.base_keys = np.zeros((ns, 2), np.uint32)
        # admission keys the PRNG stream on submission index, so a
        # request's samples are independent of co-scheduling AND of which
        # driver (closed loop / async front end) submitted it
        self.stream_ids: Dict[int, int] = {}
        self._n_submitted = 0
        self.adaptive = adaptive
        if self.adaptive is not None:
            self.adaptive.reset()
        self.tracker = None
        if track:
            from .metrics import StepTracker, resolve_device
            self.tracker = StepTracker(
                resolve_device(None if track is True else track),
                engine.step_costs(ns, self.budget))
        self._t0 = time.perf_counter()
        # step/counter state mirrored from the old monolithic serve() loop
        self.step_s = 0.0
        self.steps = 0
        self.decode_tokens = 0
        self.chunk_tokens = 0
        self.pure_decode_s = 0.0        # steps carrying no chunk lanes
        self.pure_decode_tokens = 0
        self.prefills = 0
        self.spec_rounds = 0
        self.spec_s = 0.0
        self.drafted_tokens = 0
        self.accepted_tokens = 0
        self.spec_emitted = 0
        self.adaptive_rounds = 0
        self.peak_pages = 0
        self.cow_applied = 0            # device page copies executed
        self.prefix_invalidations = 0   # cache clears after recovery

    # ------------------------------------------------------------- intake

    def now(self) -> float:
        """Seconds since session start — the session's event clock."""
        return time.perf_counter() - self._t0

    def submit(self, req: GenRequest, stream_id: Optional[int] = None,
               at: Optional[float] = None) -> int:
        """Queue a request. `at` overrides its arrival time (session
        clock); `stream_id` pins the PRNG stream (defaults to submission
        order). Returns the request uid."""
        if at is not None:
            req = dataclasses.replace(req, arrival_s=float(at))
        sid = self._n_submitted if stream_id is None else stream_id
        self._n_submitted += 1
        self.stream_ids[req.uid] = sid
        self.sched.submit(req)
        return req.uid

    def cancel(self, uid: int) -> bool:
        """Drop a request the client abandoned: from the queue, or from
        its active slot (slot + pages free immediately, partial tokens
        kept in the result, finish_reason='cancelled'). Driver-thread
        only, like every other scheduler-touching call. Idempotent."""
        return self.sched.cancel(uid, self.now())

    def done(self) -> bool:
        """True when nothing is queued or in flight (more `submit`s may
        still arrive — the async driver idles on this, it doesn't exit)."""
        return self.sched.done()

    @property
    def results(self) -> Dict[int, GenResult]:
        return self.sched.results

    # -------------------------------------------------------------- pump

    def step(self) -> List[TokenEvent]:
        """One scheduling round: admit whatever is ready, then run ONE
        jitted round (mixed token-budget step or speculative round) — or
        sleep briefly if every slot is empty and the next arrival is in
        the future. Returns the token events produced by this call.

        The round runs under a fault watchdog: a transient failure
        (injected StepFault or a real RuntimeError out of the jit)
        retries with exponential backoff up to `max_step_retries` times;
        if the failure interrupted a donated jit the consumed cache is
        rebuilt and every active slot quarantined for deterministic
        replay; past the retry budget all active slots quarantine rather
        than livelock. Overload and expiry valves (queue_cap shedding,
        queued-request timeouts, injected client cancels) run around the
        round."""
        eng = self.engine
        sched = self.sched
        for slot in sched.free_slots():
            req = sched.next_ready(self.now(), slot=slot)
            if req is None:
                break
            bkey = np.asarray(
                request_key(self.seed, self.stream_ids[req.uid]), np.uint32)
            if self.legacy:
                # whole-prompt prefill: one jit per prompt length, the
                # entire decode stream frozen while it runs (the stall
                # the chunked path exists to remove)
                t0 = time.perf_counter()
                toks = jnp.asarray([req.prompt], jnp.int32)
                logits, self.cache = eng._prefill_insert(
                    self.cache, toks, slot)
                first, _ = eng._sample(
                    logits, jnp.asarray([req.temperature], jnp.float32),
                    jnp.asarray([req.top_k], jnp.int32),
                    jnp.asarray(bkey[None]), jnp.zeros((1,), jnp.int32))
                first = int(jax.block_until_ready(first)[0])
                sched.admit(slot, req, first, self.now(),
                            time.perf_counter() - t0)
            else:
                sched.admit_chunked(slot, req, self.now())
            self.base_keys[slot] = bkey
            self.prefills += 1

        # overload + expiry valves: shed the arrived queue past queue_cap
        # (the adaptive policy has already had its chance to absorb the
        # pressure with low-bit draft rounds — its thresholds sit below
        # the cap), expire queued requests whose timeout elapsed
        sched.expire_queued(self.now())
        sched.shed_overflow(self.now())

        if sched.n_active == 0:
            nxt = sched.next_arrival()
            if nxt is not None:
                time.sleep(max(0.0, min(nxt - self.now(), 0.05)))
            if self.faults is not None:
                # keep the fault clock moving while idle, or quarantined
                # pages could never return and admission would starve
                self.faults.tick_idle(self.step_seq, sched.alloc)
                self.step_seq += 1
            return sched.take_events()

        if self.faults is not None:
            uids = [st.req.uid for st in sched.slots if st is not None]
            victim = self.faults.cancel_victim(self.step_seq, uids)
            if victim is not None:
                sched.cancel(victim, self.now())
            if sched.n_active == 0:
                self.step_seq += 1
                return sched.take_events()

        for attempt in range(self.max_step_retries + 1):
            try:
                if self.faults is not None:
                    self.faults.begin_step(self.step_seq, sched.alloc)
                self._round()
                break
            except RuntimeError as e:   # StepFault or a real device error
                self.step_retries += 1
                self.last_fault = repr(e)
                time.sleep(self.retry_backoff_s * (2 ** attempt))
                self._recover_cache()
        else:
            # persistent failure: quarantine every active slot (requeue
            # below the poison threshold, error-abort at it) instead of
            # retrying forever
            self.watchdog_exhausted += 1
            for i, st in enumerate(sched.slots):
                if st is not None:
                    sched.quarantine(i, self.now())
        self.step_seq += 1
        return sched.take_events()

    def _recover_cache(self) -> None:
        """Post-failure repair: if the exception interrupted a donated
        jit, the step consumed (deleted) the cache buffers — rebuild a
        blank cache and quarantine every active slot so their requests
        replay deterministically. A failure BEFORE the jit (the injected
        kind) leaves the cache intact and this is a no-op: the plain
        retry is token-safe because no state was mutated."""
        leaves = jax.tree_util.tree_leaves(self.cache)
        if not any(getattr(l, "is_deleted", lambda: False)()
                   for l in leaves):
            return
        eng = self.engine
        self.cache = init_serve_cache(eng.params, {}, self.n_slots,
                                      eng.max_len, eng.cfg, eng.ctx)
        self.cache_recoveries += 1
        for i, st in enumerate(self.sched.slots):
            if st is not None:
                self.sched.quarantine(i, self.now())
        if self.sched.prefix_cache is not None:
            # the rebuilt pool is blank: every cached page's bytes are
            # gone, so the whole prefix index is invalid — and so are any
            # registered-but-unapplied COW copies
            self.sched.pending_copies = []
            if self.sched.prefix_cache.clear():
                self.prefix_invalidations += 1

    def _apply_cow(self) -> None:
        """Execute the device half of every copy-on-write the scheduler
        registered since the last round: page dst becomes a byte-exact
        private copy of shared page src BEFORE the jitted step whose
        writes land in it. Device page contents are immutable between
        steps, so the copies commute with host-side remapping/eviction
        that happened after registration (stale pairs were dropped by
        take_pending_copies)."""
        sched = self.sched
        if sched.prefix_cache is None:
            return
        for src, dst in sched.take_pending_copies():
            self.cache = self.engine._copy_page(
                self.cache, jnp.int32(src), jnp.int32(dst))
            self.cow_applied += 1

    def _round(self) -> None:
        """The jitted part of one step: a speculative round or a mixed
        token-budget step (events accumulate in the scheduler; `step()`
        drains them)."""
        eng = self.engine
        sched = self.sched
        spec_want = eng.spec_k > 0
        if spec_want and self.adaptive is not None:
            # load-adaptive draft precision: speculative low-bit-prefix
            # rounds only while the queue is backed up / requests are
            # aging past the SLO knobs; pressure cleared -> plain decode.
            # Greedy outputs are identical either way (verified rounds),
            # only the step mix changes.
            depth, wait = sched.queue_pressure(self.now())
            spec_want = self.adaptive.update(depth, wait)
        if spec_want and sched.spec_ready():
            # pure-greedy-decode step: run a speculative round instead
            # (k draft passes + 1 verify emitting up to k+1 tokens/slot)
            sched.grow_pages(self.now(), lookahead=eng.spec_k + 1)
            self._apply_cow()
            if sched.spec_ready():      # eviction can re-queue a slot
                t0 = time.perf_counter()
                if sched.alloc is not None:
                    self.peak_pages = max(self.peak_pages,
                                          sched.alloc.in_use)
                self.cache, dk, ak, ek, dp, bad = eng._spec_round(
                    self.cache, sched, self.budget, self.now)
                dt = time.perf_counter() - t0
                self.step_s += dt
                self.spec_s += dt
                self.steps += 1
                self.spec_rounds += 1
                if self.adaptive is not None:
                    self.adaptive_rounds += 1
                self.drafted_tokens += dk
                self.accepted_tokens += ak
                self.spec_emitted += ek
                self.decode_tokens += ek
                if self.tracker is not None:
                    self.tracker.record_spec_round(dt, dp, ek)
                for i in bad:           # NaN verify logits: replay
                    if sched.slots[i] is not None:
                        sched.quarantine(i, self.now())
                return

        sched.grow_pages(self.now())    # map next-token pages, evict if dry
        lanes = sched.schedule_step(self.budget, self.chunk_cap, self.now())
        # COW copies must land even on a lane-less pass: the remap already
        # happened, so dst needs src's bytes before anything reads it
        self._apply_cow()
        if lanes is None:               # transiently page-starved
            return
        tb = TokenBatch(
            tokens=jnp.asarray(lanes["tokens"]),
            slots=jnp.asarray(lanes["slots"]),
            positions=jnp.asarray(lanes["positions"]),
            horizon=jnp.asarray(lanes["horizon"]),
            emit=jnp.asarray(lanes["emit"]),
            active=jnp.asarray(lanes["active"]),
            reset=jnp.asarray(lanes["reset"]),
            pages=None if sched.alloc is None
            else jnp.asarray(sched.page_table()))
        temps, top_ks, nsamp = sched.slot_sample_arrays()
        t0 = time.perf_counter()
        if sched.alloc is not None:
            self.peak_pages = max(self.peak_pages, sched.alloc.in_use)
        logits, self.cache = eng._mixed(eng.params, self.cache, tb)
        if self.faults is not None:
            # poison the chosen slots' logits rows post-jit (a NaN'd
            # activation); other slots' rows and KV are untouched, and
            # the quarantined slot's KV is discarded by the requeue
            active = [i for i, s in enumerate(sched.slots)
                      if s is not None]
            for t in self.faults.nan_targets(self.step_seq, active):
                logits = logits.at[t].set(jnp.nan)
        samp, finite = eng._sample(
            logits, jnp.asarray(temps), jnp.asarray(top_ks),
            jnp.asarray(self.base_keys), jnp.asarray(nsamp))
        samp = np.asarray(jax.block_until_ready(samp))
        finite = np.asarray(finite)
        dt = time.perf_counter() - t0
        n_tok = int(lanes["n_decode"]) + int(lanes["n_chunk"])
        self.step_s += dt
        self.steps += 1
        self.decode_tokens += int(lanes["n_decode"])
        self.chunk_tokens += int(lanes["n_chunk"])
        if lanes["n_chunk"] == 0:
            self.pure_decode_s += dt
            self.pure_decode_tokens += int(lanes["n_decode"])
        if self.tracker is not None:
            self.tracker.record("mixed", dt, n_tok)
        # NaN/Inf guard: quarantine a slot whose emitting logits row went
        # non-finite BEFORE the garbage token is recorded — the slot
        # empties, so record_scheduled skips it and its request replays
        for i in sched.step_emits:
            if sched.slots[i] is not None and not bool(finite[i]):
                sched.quarantine(i, self.now())
        sched.record_scheduled(samp, self.now())

    # ------------------------------------------------------------- stats

    def stats(self) -> Dict[str, object]:
        """The engine's serving stat block (same keys `serve()` always
        published as `last_stats`), computed over the session so far."""
        eng = self.engine
        sched = self.sched
        # decode_tok_per_s is measured over chunk-free steps only, so it
        # stays comparable with the pre-chunking engine's decode-only
        # stepping; step_tok_per_s is the mixed-lane throughput
        stats = {
            "wall_s": self.now(), "decode_s": self.step_s,
            "decode_steps": self.steps, "decode_tokens": self.decode_tokens,
            "decode_tok_per_s": self.pure_decode_tokens / self.pure_decode_s
            if self.pure_decode_s else 0.0,
            "step_tok_per_s":
            (self.decode_tokens + self.chunk_tokens) / self.step_s
            if self.step_s else 0.0,
            "chunk_tokens": self.chunk_tokens, "token_budget": self.budget,
            "max_decode_gap_steps": sched.max_decode_gap,
            "prefills": self.prefills, "slot_reuses": sched.slot_reuses,
            "kv_cache_bytes": kv_cache_bytes(self.cache),
            "evictions": sched.evictions,
            # speculative decoding: accepted_tok_per_s is the emitted-token
            # throughput of the speculative rounds alone (drafts + verify +
            # rollback all inside the denominator), reported separately
            # from step_tok_per_s on purpose
            "spec_k": eng.spec_k, "spec_draft_bits": eng.draft_bits,
            "spec_rounds": self.spec_rounds,
            "drafted_tokens": self.drafted_tokens,
            "accepted_tokens": self.accepted_tokens,
            "accept_rate": self.accepted_tokens / self.drafted_tokens
            if self.drafted_tokens else 0.0,
            "accepted_tok_per_s": self.spec_emitted / self.spec_s
            if self.spec_s else 0.0,
            "spec_emitted_tokens": self.spec_emitted,
        }
        stats["faults"] = {
            "step_retries": self.step_retries,
            "watchdog_exhausted": self.watchdog_exhausted,
            "cache_recoveries": self.cache_recoveries,
            "quarantines": sched.quarantines,
            "requeues": sched.requeues,
            "poisoned": sched.poisoned,
            "sheds": sched.sheds,
            "timeouts": sched.timeouts,
            "cancels": sched.cancels,
            "degrade_rounds": self.adaptive_rounds,
            "queue_cap": sched.queue_cap,
        }
        if self.faults is not None:
            stats["faults"]["injected"] = self.faults.summary()
        if self.adaptive is not None:
            stats.update(adaptive_rounds=self.adaptive_rounds,
                         adaptive_flips=self.adaptive.flips,
                         adaptive_on=self.adaptive.on)
        if sched.alloc is not None:
            stats.update(
                n_pages=eng.n_pages, page_size=eng.page_size,
                peak_pages_in_use=self.peak_pages,
                pages_released_by_window=sched.pages_released_by_window)
        pc = sched.prefix_cache
        if pc is not None:
            stats["prefix_cache"] = {
                "prefix_hits": pc.hits,
                "prefix_misses": pc.misses,
                "prefix_hit_tokens": pc.hit_tokens,
                "pages_shared": pc.pages_shared,
                "cow_copies": pc.cow_copies,
                "cow_applied": self.cow_applied,
                "cache_deposits": pc.deposits,
                "cache_evictions": pc.evictions,
                "cached_pages": pc.pages,
                "invalidations": self.prefix_invalidations,
            }
        if self.tracker is not None:
            stats["hw"] = self.tracker.summary()
        return stats
