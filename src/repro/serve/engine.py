"""Batched serving engine: prefill + decode with LUT-quantized weights.

This is the paper's deployment scenario (§4.3 profiling): weight-only
quantized model, batched generation, memory-bound decode. The engine
processes a queue of prompts in equal-length groups (batched prefill),
decodes with per-sequence positions and stop conditions, and admits the
next group when a batch drains (static batching with group scheduling —
the continuous-batching upgrade slot is the `admit` hook).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import decode_step, prefill
from repro.sharding.context import ShardCtx, LOCAL


@dataclasses.dataclass
class GenRequest:
    prompt: List[int]
    max_new: int = 32
    temperature: float = 0.0
    eos_id: Optional[int] = None


@dataclasses.dataclass
class GenResult:
    tokens: List[int]
    prefill_s: float = 0.0
    decode_s: float = 0.0
    steps: int = 0


def sample_token(logits: jnp.ndarray, temperature: float,
                 key) -> jnp.ndarray:
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature).astype(jnp.int32)


class ServeEngine:
    def __init__(self, params, cfg: ModelConfig, ctx: ShardCtx = LOCAL,
                 max_len: int = 512):
        self.params = params
        self.cfg = cfg
        self.ctx = ctx
        self.max_len = max_len
        self._decode = jax.jit(
            lambda p, c, t, pos: decode_step(p, c, t, pos, cfg, ctx))

    def generate_batch(self, requests: List[GenRequest],
                       seed: int = 0) -> List[GenResult]:
        """All prompts in a call must share a length (group scheduling)."""
        assert len({len(r.prompt) for r in requests}) == 1, \
            "engine processes equal-length prompt groups"
        b = len(requests)
        plen = len(requests[0].prompt)
        max_new = max(r.max_new for r in requests)
        toks = jnp.asarray([r.prompt for r in requests], jnp.int32)

        t0 = time.time()
        logits, cache = prefill(self.params, {"tokens": toks}, self.cfg,
                                self.ctx, cache_len=self.max_len)
        prefill_s = time.time() - t0

        key = jax.random.PRNGKey(seed)
        outs = [[] for _ in range(b)]
        done = np.zeros(b, bool)
        temp = requests[0].temperature
        cur = sample_token(logits, temp, key)
        t1 = time.time()
        steps = 0
        for i in range(max_new):
            for j in range(b):
                if not done[j]:
                    outs[j].append(int(cur[j]))
                    r = requests[j]
                    if (r.eos_id is not None and int(cur[j]) == r.eos_id) \
                            or len(outs[j]) >= r.max_new:
                        done[j] = True
            if done.all() or plen + i + 1 >= self.max_len:
                break
            pos = jnp.full((b,), plen + i, jnp.int32)
            logits, cache = self._decode(self.params, cache, cur, pos)
            key, sub = jax.random.split(key)
            cur = sample_token(logits, temp, sub)
            steps += 1
        decode_s = time.time() - t1
        return [GenResult(tokens=outs[j], prefill_s=prefill_s,
                          decode_s=decode_s, steps=steps)
                for j in range(b)]

    def serve_queue(self, requests: List[GenRequest],
                    batch_size: int = 4) -> List[GenResult]:
        """Group queue by prompt length, process in batches."""
        groups: Dict[int, List[int]] = {}
        for i, r in enumerate(requests):
            groups.setdefault(len(r.prompt), []).append(i)
        results: List[Optional[GenResult]] = [None] * len(requests)
        for _, idxs in sorted(groups.items()):
            for k in range(0, len(idxs), batch_size):
                chunk = idxs[k:k + batch_size]
                res = self.generate_batch([requests[i] for i in chunk])
                for i, r in zip(chunk, res):
                    results[i] = r
        return results  # type: ignore[return-value]
