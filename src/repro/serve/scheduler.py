"""Slot scheduler for continuous batching: queue, chunk scheduling, completion.

The scheduler is the host-side half of the serving engine. It owns the
request queue and a fixed table of `n_slots` decode slots; the device-side
half (engine.py) owns the slot-batched KV cache whose row i mirrors slot i
here. Admission is per-slot: whenever a slot frees (eos / length budget /
deadline), the next arrived request binds to it mid-flight — no barrier on
the rest of the batch.

Admission order is EDF (earliest deadline first) over the *arrived* part of
the queue — requests without a deadline sort last, ties break by arrival
then submission order, so pure-FIFO workloads behave exactly as before.

Chunk scheduling (`schedule_step`) fills each token-budget step's lanes:
every decoding slot gets exactly one lane first (an in-flight stream never
skips a step while the budget covers the slot count), then the remaining
lanes carry prompt chunks of prefilling slots in EDF order. Chunk
boundaries are fixed multiples of the chunk cap counted from position 0 —
never "whatever budget is left" — so a prompt's chunk split (and therefore
its greedy output) is deterministic regardless of what it is co-scheduled
with, including after an eviction replay.

For paged KV caches the scheduler also owns the `PageAllocator`: a
host-side free list over the device page pool. Pages are reserved per
CHUNK (not per prompt) as chunks are scheduled, decode grows a slot's page
list lazily as its sequence crosses page boundaries, and when the pool
runs dry the lowest-priority (then least-progress) slot is evicted — its
pages return to the pool and its request requeues for a fresh chunked
prefill (preemption by recompute). When every attention layer is sliding-
window ('local'), pages that slide fully out of the window are released
back to the pool mid-flight (`window=`).

All bookkeeping is numpy/python (one dict lookup per slot per step); the
dense per-lane arrays handed to the jitted token-budget step are assembled
in `schedule_step` / `page_table` (`batch_arrays` serves the legacy
one-token-per-slot step).
"""
from __future__ import annotations

import dataclasses
import hashlib
import itertools
from collections import OrderedDict, deque
from typing import Dict, List, Optional, Tuple

import numpy as np

_UID = itertools.count()


@dataclasses.dataclass
class GenRequest:
    prompt: List[int]
    max_new: int = 32
    temperature: float = 0.0
    eos_id: Optional[int] = None
    top_k: int = 0                     # 0 = no truncation
    deadline_s: Optional[float] = None  # decode wall-clock budget, None = off
    timeout_s: Optional[float] = None  # hard wall-clock cap from ARRIVAL
    arrival_s: float = 0.0             # offset from serve() start (Poisson)
    priority: int = 0                  # higher = evicted later under pressure
    uid: int = dataclasses.field(default_factory=lambda: next(_UID))


@dataclasses.dataclass
class TokenEvent:
    """One emitted token (or completion) as seen by a streaming consumer.

    The scheduler appends these as it folds samples back in; the engine
    session drains them per step (`SlotScheduler.take_events`) and the SSE
    front end relays them to the request's open stream. `token == -1`
    marks the terminal event (no token payload — `finish_reason` is set
    and the full `GenResult` is in `results[uid]`)."""
    uid: int
    token: int
    t_s: float                         # offset from serve()/session start
    index: int                         # token index within the request
    done: bool = False
    finish_reason: str = ""


@dataclasses.dataclass
class GenResult:
    tokens: List[int]
    prefill_s: float = 0.0             # admission -> first token (TTFT)
    decode_s: float = 0.0
    steps: int = 0
    # length | eos | deadline | timeout | error | shed | cancelled
    finish_reason: str = "length"
    done_s: float = 0.0                # completion time, offset from serve()
    evictions: int = 0                 # page-pressure preemptions (restarts)
    token_times: Optional[List[float]] = None  # per-token sample times


@dataclasses.dataclass
class _Slot:
    req: GenRequest
    pos: int                           # position of the latest written token
    cur_token: int                     # latest sampled token (next step input)
    tokens: List[int]
    started_s: float
    prefill_s: float
    steps: int = 0
    evictions: int = 0                 # times this request was preempted
    fed: int = 0                       # prompt tokens scheduled so far
    written: int = 0                   # prompt tokens whose KV is on device
    gap: int = 0                       # steps since this stream last sampled
    times: List[float] = dataclasses.field(default_factory=list)

    @property
    def prefilling(self) -> bool:
        return self.fed < len(self.req.prompt)


class PageAllocator:
    """Host-side free list over the device KV page pool.

    Page ids index the per-layer `(n_pages + 1, page_size, ...)` pools of
    the paged CacheFormats (id `n_pages` is the device-side scratch page
    and is never handed out). Every slot owns a list of *logical* pages —
    entry j of a slot's list holds token positions [j*page_size,
    (j+1)*page_size) — mapped to arbitrary physical ids. A leading run of
    entries may be `None`: pages released mid-flight by `release_window`
    once they slid fully out of a sliding-window model's reach (the table
    maps them to -1, so reads route to the scratch page and the window
    mask hides them).

    Pages are refcounted: `refs[p]` counts every holder of page p — each
    slot whose owned list maps it, plus one if the prefix cache indexes
    it (`cache_hold`/`cache_drop`). A page frees back to the pool only
    when its last holder drops it, so one physical page can back the
    shared prompt prefix of many slots at once; `cow` swaps a slot's
    mapping of a shared page for a fresh private one (the device-side
    byte copy is the engine's job).

    Invariants (property-tested): free (refs 0, on the free list),
    uniquely-owned (refs 1), shared (refs >= 2), and quarantined (refs 0,
    retired) are always a disjoint partition of range(n_pages), and
    refs[p] always equals the number of slot mappings of p plus its
    cache hold — no page is leaked, double-owned, or double-freed across
    admit/share/COW/evict/quarantine churn.
    """

    def __init__(self, n_pages: int, page_size: int, n_slots: int,
                 max_pages_per_slot: int):
        assert n_pages >= 1 and page_size >= 1
        self.n_pages = n_pages
        self.page_size = page_size
        self.n_slots = n_slots
        self.max_pages_per_slot = max_pages_per_slot
        self.free: List[int] = list(range(n_pages))
        self.owned: List[List[int]] = [[] for _ in range(n_slots)]
        self.quarantined: List[int] = []   # retired (ECC-style) free pages
        self.refs: List[int] = [0] * n_pages   # holders: slot maps + cache
        self.cache_held: set = set()       # pages the prefix cache indexes
        # device page table, kept incrementally: only rows whose owned
        # list changed since the last table() call are rebuilt
        self._table = np.full((n_slots, max_pages_per_slot), -1, np.int32)
        self._dirty: set = set()

    def pages_for(self, n_tokens: int) -> int:
        return -(-max(n_tokens, 0) // self.page_size)

    @property
    def available(self) -> int:
        return len(self.free)

    @property
    def in_use(self) -> int:
        return self.n_pages - len(self.free)

    def _decref(self, page: int) -> bool:
        """Drop one hold on `page`; frees it to the pool when the last
        holder is gone. Returns True if the page was actually freed."""
        assert self.refs[page] >= 1, (page, self.refs[page])
        self.refs[page] -= 1
        if self.refs[page] == 0:
            self.free.append(page)
            return True
        return False

    def release_window(self, slot: int, pos: int, window: int) -> int:
        """Drop this slot's hold on pages that slid fully out of the
        sliding window of every present-or-future query (positions
        <= pos - window can never be attended again once the next token
        sits at `pos`). Only valid when ALL attention layers are windowed
        — a single global layer keeps whole-history pages live. A shared
        page merely loses this slot's reference; it frees only when the
        prefix cache and every other slot have dropped it too. Returns
        pages freed back to the pool."""
        freed = 0
        for j, pg in enumerate(self.owned[slot]):
            if pg is None:
                continue
            if (j + 1) * self.page_size - 1 > pos - window:
                break                   # logical pages are position-ordered
            freed += self._decref(pg)
            self.owned[slot][j] = None
            self._dirty.add(slot)
        return freed

    def alloc(self, slot: int, n: int) -> bool:
        """Grow slot's page list by n pages; False (no change) if the free
        list cannot cover it or the slot would exceed max_pages_per_slot."""
        if n > len(self.free) or \
                len(self.owned[slot]) + n > self.max_pages_per_slot:
            return False
        for _ in range(n):
            pg = self.free.pop()
            assert self.refs[pg] == 0, (pg, self.refs[pg])
            self.refs[pg] = 1
            self.owned[slot].append(pg)
        self._dirty.add(slot)
        return True

    def ensure(self, slot: int, pos: int) -> bool:
        """Ensure the page holding token position `pos` is mapped."""
        need = pos // self.page_size + 1 - len(self.owned[slot])
        return True if need <= 0 else self.alloc(slot, need)

    def release(self, slot: int) -> int:
        """Drop the slot's hold on all its pages; returns how many went
        back to the pool (shared / cache-held pages stay out)."""
        freed = 0
        for p in self.owned[slot]:
            if p is not None:
                freed += self._decref(p)
        self.owned[slot] = []
        self._dirty.add(slot)
        return freed

    def share(self, slot: int, pages: List[int]) -> None:
        """Map an already-held page run as the slot's leading logical
        pages (prefix-cache admission): entry j serves positions
        [j*page_size, (j+1)*page_size) out of a page some other holder
        (the cache, possibly other slots) also references."""
        assert not self.owned[slot], "share() must precede any alloc"
        assert len(pages) <= self.max_pages_per_slot
        for p in pages:
            assert self.refs[p] >= 1, (p, self.refs[p])
            self.refs[p] += 1
        self.owned[slot] = list(pages)
        self._dirty.add(slot)

    def cow(self, slot: int, j: int) -> Optional[Tuple[int, int]]:
        """Copy-on-write logical page `j` of `slot`: remap it from the
        shared physical page to a fresh private one and return (src, dst)
        for the device-side byte copy. Returns None when the page is
        already exclusively owned (no copy needed). The caller must have
        checked `available > 0`."""
        src = self.owned[slot][j]
        assert src is not None and self.refs[src] >= 1
        if self.refs[src] == 1:
            return None
        assert self.free, "cow() needs a free page; evict first"
        dst = self.free.pop()
        assert self.refs[dst] == 0, (dst, self.refs[dst])
        self.refs[dst] = 1
        self.refs[src] -= 1            # still >= 1: other holders remain
        self.owned[slot][j] = dst
        self._dirty.add(slot)
        return src, dst

    def cache_hold(self, page: int) -> None:
        """Add the prefix cache's hold on a slot-owned page (deposit)."""
        assert page not in self.cache_held and self.refs[page] >= 1
        self.cache_held.add(page)
        self.refs[page] += 1

    def cache_drop(self, page: int) -> bool:
        """Drop the prefix cache's hold (entry eviction / invalidation);
        True if that freed the page back to the pool."""
        self.cache_held.remove(page)
        return self._decref(page)

    def table(self) -> np.ndarray:
        """(n_slots, max_pages_per_slot) int32 page table; -1 = unmapped.
        Rebuilds only rows dirtied since the last call — a steady-state
        decode step with no page growth pays O(1) host work, not
        O(slots x pages). Returns a write-protected view of the
        allocator's live buffer (the engine copies it to device), so a
        caller that mutates it or writes through a stale reference gets
        a ValueError instead of silent page-table corruption."""
        for i in self._dirty:
            row = self._table[i]
            row[:] = -1
            for j, p in enumerate(self.owned[i]):
                if p is not None:
                    row[j] = p
        self._dirty.clear()
        view = self._table.view()
        view.setflags(write=False)
        return view

    def quarantine_free_pages(self, n: int) -> int:
        """Retire up to `n` FREE pages from circulation (simulated ECC
        retirement / a neighbor stealing HBM). Quarantined pages are
        neither free nor owned — allocation pressure rises and the
        scheduler's ordinary eviction valve absorbs it. Returns the
        number actually retired."""
        n = min(n, len(self.free))
        for _ in range(n):
            self.quarantined.append(self.free.pop())
        return n

    def restore_quarantined(self) -> int:
        """Return every quarantined page to the free list."""
        n = len(self.quarantined)
        self.free.extend(self.quarantined)
        self.quarantined = []
        return n

    def partition(self) -> Dict[str, List[int]]:
        """The four-way page partition: free / uniquely-owned (refs 1) /
        shared (refs >= 2) / quarantined."""
        held = [p for p in range(self.n_pages) if self.refs[p] >= 1]
        return {"free": sorted(self.free),
                "unique": [p for p in held if self.refs[p] == 1],
                "shared": [p for p in held if self.refs[p] >= 2],
                "quarantined": sorted(self.quarantined)}

    def check(self) -> None:
        """Assert the no-leak / no-double-own invariant: free +
        uniquely-owned + shared + quarantined partition range(n_pages),
        and every page's refcount equals its slot mappings + cache hold."""
        want = [0] * self.n_pages
        for pages in self.owned:
            for p in pages:
                if p is not None:
                    want[p] += 1
        for p in self.cache_held:
            want[p] += 1
        assert want == self.refs, (want, self.refs)
        part = self.partition()
        for p in self.free:
            assert self.refs[p] == 0, (p, self.refs[p])
        for p in self.quarantined:
            assert self.refs[p] == 0, (p, self.refs[p])
        assert not set(self.free) & set(self.quarantined)
        seen = sorted(part["free"] + part["unique"] + part["shared"]
                      + part["quarantined"])
        assert seen == list(range(self.n_pages)), (seen, self.n_pages)


class PrefixHasher:
    """Rolling prefix hashes at page granularity.

    Page j's KV contents depend causally on tokens[0 : (j+1)*page_size]
    and nothing else (PR 5 made chunk boundaries fixed, and per-lane
    numerics are independent of how positions are grouped into lanes), so
    a chain of blake2b digests over page-sized token blocks keys page
    contents exactly. The chain is seeded with a fingerprint of the
    model / weights / precision policy / cache format — two sessions with
    different weights or KV layouts can never alias each other's pages.
    Only FULL pages hash: a partial tail page is never shared.
    """

    def __init__(self, page_size: int, fingerprint: bytes = b""):
        assert page_size >= 1
        self.page_size = page_size
        self.root = hashlib.blake2b(fingerprint, digest_size=16).digest()

    def page_hashes(self, tokens: List[int]) -> List[bytes]:
        """Digest chain h_j keying the KV page of positions
        [j*page_size, (j+1)*page_size), for every full page of `tokens`."""
        out: List[bytes] = []
        h = self.root
        ps = self.page_size
        for j in range(len(tokens) // ps):
            block = np.asarray(tokens[j * ps:(j + 1) * ps],
                               np.int64).tobytes()
            h = hashlib.blake2b(h + block, digest_size=16).digest()
            out.append(h)
        return out


class PrefixCache:
    """Host-side index of reusable KV pages: prefix digest -> physical page.

    Entries are deposited when a slot's computed pages become reusable
    (prefill completion, request finish, and eviction — eviction-into-
    cache turns preempted work into cache hits instead of recompute) and
    hold one refcount on their page via the allocator. Lookup walks the
    digest chain from page 0 and returns the longest fully-cached leading
    run; admission maps those pages shared into the slot's table and
    skips prefill straight to the tail. The cache is the FIRST eviction
    tier under page pressure: LRU entries whose page no live slot
    references are reclaimed before any live slot is touched.
    """

    def __init__(self, alloc: PageAllocator, hasher: PrefixHasher,
                 capacity_pages: Optional[int] = None):
        self.alloc = alloc
        self.hasher = hasher
        self.capacity_pages = capacity_pages   # None: pool pressure only
        self.entries: "OrderedDict[bytes, int]" = OrderedDict()  # LRU order
        self.hits = 0            # admissions that reused >= 1 cached page
        self.misses = 0          # admissions with no cached prefix
        self.hit_tokens = 0      # prompt tokens whose prefill was skipped
        self.pages_shared = 0    # page mappings served from the cache
        self.cow_copies = 0      # device page copies (write into shared)
        self.deposits = 0        # pages newly indexed
        self.evictions = 0       # entries reclaimed under page pressure

    @property
    def pages(self) -> int:
        return len(self.entries)

    def lookup(self, hashes: List[bytes]) -> List[int]:
        """Longest leading run of cached pages for a prompt's digest
        chain; touches each hit entry's LRU recency."""
        run: List[int] = []
        for h in hashes:
            pg = self.entries.get(h)
            if pg is None:
                break
            self.entries.move_to_end(h)
            run.append(pg)
        return run

    def deposit(self, hashes: List[bytes], pages: List[Optional[int]]
                ) -> int:
        """Index a slot's computed pages under their prefix digests.
        Stops at the first unmapped entry (window-released leading pages
        break the chain) and dedupes against existing entries — the
        digest keys page CONTENT, so the first deposit wins and later
        identical pages just refresh recency. Returns pages indexed."""
        n = 0
        for h, pg in zip(hashes, pages):
            if pg is None:
                break
            cur = self.entries.get(h)
            if cur is not None:
                self.entries.move_to_end(h)
                continue
            if (self.capacity_pages is not None
                    and len(self.entries) >= self.capacity_pages
                    and not self.evict_lru(1)):
                break
            self.alloc.cache_hold(pg)
            self.entries[h] = pg
            n += 1
        self.deposits += n
        return n

    def evict_lru(self, n: int = 1) -> int:
        """Reclaim up to `n` LRU entries whose page has no live slot
        reference (the cache is its only holder — refs exactly 1), freeing
        their pages to the pool. Entries still shared with live slots are
        skipped: evicting them would free nothing. Returns pages freed."""
        freed = 0
        while freed < n:
            victim = None
            for h, pg in self.entries.items():     # oldest first
                if self.alloc.refs[pg] == 1:
                    victim = h
                    break
            if victim is None:
                break
            self.alloc.cache_drop(self.entries.pop(victim))
            self.evictions += 1
            freed += 1
        return freed

    def clear(self) -> int:
        """Drop every entry (cache recovery rebuilt the device pool, so
        all cached page contents are invalid). Returns entries dropped."""
        n = len(self.entries)
        for pg in self.entries.values():
            self.alloc.cache_drop(pg)
        self.entries.clear()
        return n


class SlotScheduler:
    """Request queue + slot table; the engine drives it step by step.

    `alloc` (a PageAllocator) switches on paged-cache bookkeeping: chunk
    scheduling reserves each chunk's pages as it is laned (evicting
    strictly-lower-priority slots to make room), and `grow_pages` extends
    each live slot's mapping ahead of every step. `window` (token count)
    enables mid-flight release of pages that slid fully out of a sliding
    window — only pass it when every attention layer is 'local'.
    `prefix_cache` (a PrefixCache over the same allocator) switches on
    shared-prompt KV reuse: admissions map cached prefix pages shared and
    skip straight to the tail chunks, finished/evicted slots deposit
    their pages, and under page pressure refcount-1 cache entries are the
    first eviction tier. Copy-on-write pairs land in `pending_copies` for
    the engine to apply on device BEFORE the step that writes them.
    """

    def __init__(self, n_slots: int, max_len: int,
                 alloc: Optional[PageAllocator] = None,
                 window: Optional[int] = None,
                 queue_cap: Optional[int] = None,
                 poison_threshold: int = 3,
                 prefix_cache: Optional[PrefixCache] = None):
        assert n_slots >= 1
        assert prefix_cache is None or alloc is not None
        self.n_slots = n_slots
        self.max_len = max_len
        self.alloc = alloc
        self.window = window
        self.prefix_cache = prefix_cache
        # COW copies registered this pass: (slot, logical j, src, dst)
        self.pending_copies: List[Tuple[int, int, int, int]] = []
        self.queue_cap = queue_cap     # arrived-queue depth before shedding
        self.poison_threshold = poison_threshold  # quarantines before abort
        self.queue: deque = deque()
        self.slots: List[Optional[_Slot]] = [None] * n_slots
        self.results: Dict[int, GenResult] = {}
        self.slot_reuses = 0           # admissions into a previously used slot
        self.evictions = 0             # page-pressure preemptions
        self.max_decode_gap = 0        # worst steps-between-samples, any stream
        self.pages_released_by_window = 0
        self.quarantines = 0           # fault preemptions (NaN / watchdog)
        self.requeues = 0              # quarantines that replayed the request
        self.poisoned = 0              # requests aborted after N strikes
        self.sheds = 0                 # overload rejections (queue_cap)
        self.timeouts = 0              # per-request wall-clock expiries
        self.cancels = 0               # client-abandoned requests
        self._evicted: Dict[int, int] = {}   # uid -> times preempted
        self._strikes: Dict[int, int] = {}   # uid -> fault quarantines
        self._used = [False] * n_slots
        self._step_emits: List[int] = []
        self._step_reset: List[int] = []
        # chunks laned into the in-flight step: (slot, slot object, new
        # fed). record_scheduled advances each slot's `written` watermark
        # from these once the step has actually run on device.
        self._step_fed: List[Tuple[int, _Slot, int]] = []
        self.events: List[TokenEvent] = []   # drained via take_events()

    # ------------------------------------------------------------ queue side

    def submit(self, req: GenRequest) -> None:
        """Validate and enqueue. Raises ValueError (never an assert — the
        SSE front end turns it into a 400, and a bad request must not
        take down the shared driver thread)."""
        if len(req.prompt) < 1:
            raise ValueError("empty prompt")
        if len(req.prompt) >= self.max_len:
            raise ValueError(f"prompt ({len(req.prompt)}) must fit the "
                             f"cache ({self.max_len})")
        if req.max_new < 1:
            raise ValueError(f"max_new must be >= 1, got {req.max_new}")
        if self.alloc is not None:
            # a request whose full trajectory cannot fit the pool would
            # evict-thrash forever; refuse it up front
            worst = min(len(req.prompt) + req.max_new, self.max_len)
            if self.alloc.pages_for(worst) > self.alloc.n_pages:
                raise ValueError(
                    f"request needs {self.alloc.pages_for(worst)} pages, "
                    f"pool holds {self.alloc.n_pages}")
        self.queue.append(req)

    @property
    def pending(self) -> int:
        return len(self.queue)

    @property
    def n_active(self) -> int:
        return sum(s is not None for s in self.slots)

    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    @staticmethod
    def _edf_key(req: GenRequest, tie: int) -> Tuple:
        """EDF ordering key shared by queue admission and chunk-lane
        scheduling: earliest deadline first, deadline-free last, ties FIFO
        by arrival then a caller-supplied index."""
        return (req.deadline_s if req.deadline_s is not None
                else float("inf"), req.arrival_s, tie)

    def _edf_order(self, now_s: float) -> List[int]:
        """Arrived-request indices in admission order (EDF)."""
        arrived = [i for i, r in enumerate(self.queue)
                   if r.arrival_s <= now_s]
        return sorted(arrived, key=lambda i: self._edf_key(self.queue[i], i))

    def _evictable_pages(self, below: int) -> int:
        """Pages reclaimable by evicting every active slot with priority
        strictly below `below`."""
        return sum(len(self.alloc.owned[i]) for i, st in
                   enumerate(self.slots)
                   if st is not None and st.req.priority < below)

    def next_ready(self, now_s: float,
                   slot: Optional[int] = None) -> Optional[GenRequest]:
        """Pop the next admittable request (EDF over arrived requests).

        Admission binds a request to a slot without touching the page
        pool: pages are reserved chunk by chunk as `schedule_step` lanes
        the prompt (evicting strictly-lower-priority slots under
        pressure), so a page-starved request occupies a slot but never
        blocks co-scheduled streams. `slot` is accepted for API
        compatibility and unused.
        """
        del slot
        for i in self._edf_order(now_s):
            req = self.queue[i]
            del self.queue[i]
            return req
        return None

    def next_arrival(self) -> Optional[float]:
        return min(r.arrival_s for r in self.queue) if self.queue else None

    def queue_pressure(self, now_s: float) -> Tuple[int, float]:
        """(arrived-but-unadmitted queue depth, oldest such request's wait
        in seconds) — the load signal adaptive policies key on."""
        waits = [now_s - r.arrival_s for r in self.queue
                 if r.arrival_s <= now_s]
        return len(waits), max(waits, default=0.0)

    def take_events(self) -> List[TokenEvent]:
        """Drain the token-event stream accumulated since the last call."""
        out, self.events = self.events, []
        return out

    @property
    def step_emits(self) -> List[int]:
        """Slots the in-flight step will sample for (set by
        `schedule_step`, consumed by `record_scheduled`); the engine's
        NaN guard reads it to know whose logits rows matter."""
        return list(self._step_emits)

    # ------------------------------------------------------------- slot side

    def admit(self, slot: int, req: GenRequest, first_token: int,
              now_s: float, prefill_s: float) -> bool:
        """Bind req to slot with its prefill-sampled first token (the
        legacy whole-prompt-prefill admission). Returns True if the
        request finished immediately (it still occupied the slot for zero
        decode steps)."""
        assert self.slots[slot] is None
        if self._used[slot]:
            self.slot_reuses += 1
        self._used[slot] = True
        st = _Slot(req=req, pos=len(req.prompt) - 1, cur_token=first_token,
                   tokens=[first_token], started_s=now_s, prefill_s=prefill_s,
                   evictions=self._evicted.get(req.uid, 0),
                   fed=len(req.prompt), written=len(req.prompt),
                   times=[now_s])
        self.slots[slot] = st
        self.events.append(TokenEvent(req.uid, first_token, now_s, 0))
        return self._maybe_finish(slot, now_s)

    def admit_chunked(self, slot: int, req: GenRequest, now_s: float) -> None:
        """Bind req to slot for chunked prefill: its prompt will be laned
        into the token-budget steps by `schedule_step`; the first token
        samples when the final prompt chunk emits. With a prefix cache,
        the longest cached leading page run maps shared into the slot and
        prefill skips straight past it."""
        assert self.slots[slot] is None
        if self._used[slot]:
            self.slot_reuses += 1
        self._used[slot] = True
        st = _Slot(
            req=req, pos=-1, cur_token=-1, tokens=[], started_s=now_s,
            prefill_s=0.0, evictions=self._evicted.get(req.uid, 0), fed=0)
        self.slots[slot] = st
        if self.prefix_cache is not None:
            self._admit_prefix(slot, st, now_s)

    # ------------------------------------------------------- prefix cache

    def _admit_prefix(self, slot: int, st: _Slot, now_s: float) -> None:
        """Skip-ahead admission: map the longest cached leading page run
        shared into the slot and start prefill at its end. A fully-cached
        prompt still feeds its FINAL token (the first sample needs that
        lane's logits), whose write lands inside the last shared page —
        that page is copy-on-written so the cached original stays
        pristine for other holders."""
        pc = self.prefix_cache
        ps = self.alloc.page_size
        hashes = pc.hasher.page_hashes(st.req.prompt)
        run = pc.lookup(hashes)[:self.alloc.max_pages_per_slot]
        if not run:
            pc.misses += 1
            return
        plen = len(st.req.prompt)
        skip = len(run) * ps
        cow_j = None
        if skip >= plen:               # every prompt page cached
            skip = plen - 1
            cow_j = skip // ps
        self.alloc.share(slot, run)
        if cow_j is not None and not self._cow_range(
                slot, skip, skip, now_s, below=st.req.priority):
            # no page for the copy even after cache-tier eviction: fall
            # back to recomputing the last page instead of stalling
            self.alloc.release(slot)
            run = run[:-1]
            skip = len(run) * ps
            if not run:
                pc.misses += 1
                return
            self.alloc.share(slot, run)
        st.fed = skip
        st.written = skip              # shared pages hold real KV already
        st.pos = skip - 1
        pc.hits += 1
        pc.hit_tokens += skip
        pc.pages_shared += len(run)

    def _deposit(self, slot: int, st: _Slot) -> None:
        """Index the slot's fully-written pages in the prefix cache. The
        written positions are exactly prompt[:written] before the first
        sample and prompt + tokens[:-1] once decoding (the latest sampled
        token is an input of the NEXT step, its KV not yet written).
        `fed` must NOT stand in for `written` here: a chunk laned THIS
        scheduling pass has bumped `fed` but its step has not run — if
        the slot is evicted mid-pass its lanes write to scratch, and
        depositing prompt[:fed] would index pages of garbage KV that a
        later shared-prefix admission silently reads."""
        if self.prefix_cache is None or self.alloc is None:
            return
        seq = (st.req.prompt + st.tokens[:-1] if st.tokens
               else st.req.prompt[:st.written])
        hashes = self.prefix_cache.hasher.page_hashes(seq)
        if hashes:
            self.prefix_cache.deposit(
                hashes, self.alloc.owned[slot][:len(hashes)])

    def _evict_cache_tier(self, n: int = 1) -> bool:
        """First eviction tier under page pressure: reclaim LRU prefix-
        cache entries no live slot references before any live slot is
        touched. True if at least one page was freed."""
        if self.prefix_cache is None:
            return False
        return self.prefix_cache.evict_lru(n) > 0

    def _cow_range(self, slot: int, first_pos: int, last_pos: int,
                   now_s: float, below: Optional[int] = None) -> bool:
        """Copy-on-write every shared page the write range [first_pos,
        last_pos] touches, registering (src, dst) pairs for the engine's
        device copy. Frees pages for the copies through the standard
        pressure ladder (cache tier first, then strictly-lower-priority
        eviction). False if a needed copy page could not be found."""
        if self.prefix_cache is None or self.alloc is None:
            return True
        ps = self.alloc.page_size
        for j in range(first_pos // ps, last_pos // ps + 1):
            owned = self.alloc.owned[slot]
            if j >= len(owned) or owned[j] is None:
                continue
            pg = owned[j]
            while self.alloc.refs[pg] >= 2:
                if self.alloc.available > 0:
                    src, dst = self.alloc.cow(slot, j)
                    self.pending_copies.append((slot, j, src, dst))
                    self.prefix_cache.cow_copies += 1
                    break
                if self._evict_cache_tier():
                    continue
                victim = self._eviction_candidate(below=below)
                if victim is None or victim == slot:
                    return False
                self.evict(victim, now_s)
        return True

    def take_pending_copies(self) -> List[Tuple[int, int]]:
        """Drain the (src, dst) device page-copy pairs registered this
        scheduling pass. Pairs whose mapping was torn down in the
        meantime (the COW'd slot was evicted and dst possibly handed to
        a new owner) are dropped — their writes route to scratch, and the
        copy must not clobber dst's new contents."""
        out = []
        for slot, j, src, dst in self.pending_copies:
            owned = self.alloc.owned[slot]
            if j < len(owned) and owned[j] == dst:
                out.append((src, dst))
        self.pending_copies = []
        return out

    # ------------------------------------------------------ paged eviction

    def _eviction_candidate(self, below: Optional[int] = None
                            ) -> Optional[int]:
        """Active slot to preempt: lowest priority, then least computed
        work (fed prompt tokens + decoded tokens — the recompute an
        eviction throws away; a nearly-chunked-in long prompt is NOT the
        cheap victim its empty token list would suggest). `below`
        restricts to slots with priority strictly below it (chunk
        reservation never evicts peers)."""
        best, best_key = None, None
        for i, st in enumerate(self.slots):
            if st is None:
                continue
            if below is not None and st.req.priority >= below:
                continue
            key = (st.req.priority, st.fed + len(st.tokens))
            if best_key is None or key < best_key:
                best, best_key = i, key
        return best

    def evict(self, slot: int, now_s: float) -> None:
        """Preempt a slot: release its pages and requeue its request for a
        fresh prefill (greedy and seeded sampling replay identically
        because PRNG streams key on the submission index). With a prefix
        cache this is eviction-INTO-cache, not eviction-by-recompute: the
        slot's fully-written pages are deposited first, so re-admission
        maps them back shared and skips the recompute entirely (the
        carried checkpointed-preemption item, closed by refcounts)."""
        st = self.slots[slot]
        assert st is not None
        if self.alloc is not None:
            self._deposit(slot, st)     # eviction-into-cache
            self.alloc.release(slot)
        self.slots[slot] = None
        self.evictions += 1
        self._evicted[st.req.uid] = self._evicted.get(st.req.uid, 0) + 1
        self.queue.append(st.req)

    # ------------------------------------------------------ fault handling

    def _abort(self, req: GenRequest, reason: str, now_s: float,
               tokens: Optional[List[int]] = None,
               times: Optional[List[float]] = None) -> None:
        """Terminate a request that will NOT produce (more) output:
        record a GenResult with an explicit finish_reason and emit the
        terminal TokenEvent so a streaming client unblocks."""
        toks = tokens or []
        self.results[req.uid] = GenResult(
            tokens=toks, finish_reason=reason, done_s=now_s,
            evictions=self._evicted.get(req.uid, 0), token_times=times)
        self.events.append(TokenEvent(req.uid, -1, now_s, len(toks),
                                      done=True, finish_reason=reason))

    def quarantine(self, slot: int, now_s: float) -> str:
        """Preempt a FAULTED slot (NaN logits, watchdog exhaustion): its
        pages return to the pool and its generated tokens are discarded.
        Below `poison_threshold` strikes the request requeues for a
        deterministic replay (PRNG streams key on submission index, so a
        surviving replay's greedy tokens are bitwise the fault-free
        run's); at the threshold it aborts with finish_reason='error'
        instead of livelocking. Returns 'requeued' or 'error'."""
        st = self.slots[slot]
        assert st is not None
        if self.alloc is not None:
            # NO cache deposit: a faulted step may have written garbage.
            # release() only drops this slot's refs — pages the prefix
            # cache or other slots still hold stay mapped for them.
            self.alloc.release(slot)
        self.slots[slot] = None
        self.quarantines += 1
        uid = st.req.uid
        self._strikes[uid] = self._strikes.get(uid, 0) + 1
        if self._strikes[uid] >= self.poison_threshold:
            self.poisoned += 1
            self._abort(st.req, "error", now_s)
            return "error"
        self._evicted[uid] = self._evicted.get(uid, 0) + 1
        self.requeues += 1
        self.queue.append(st.req)
        return "requeued"

    def cancel(self, uid: int, now_s: float) -> bool:
        """Drop a request the client abandoned: from the queue, or from
        its active slot (freeing the slot and its pages mid-flight).
        Partial tokens are kept in the result. Idempotent — returns
        False if the uid is not live (already finished/cancelled)."""
        for i, r in enumerate(self.queue):
            if r.uid == uid:
                del self.queue[i]
                self.cancels += 1
                self._abort(r, "cancelled", now_s)
                return True
        for i, st in enumerate(self.slots):
            if st is not None and st.req.uid == uid:
                if self.alloc is not None:
                    self.alloc.release(i)
                self.slots[i] = None
                self.cancels += 1
                self._abort(st.req, "cancelled", now_s,
                            tokens=st.tokens, times=st.times)
                return True
        return False

    def shed_overflow(self, now_s: float) -> int:
        """Overload valve: when the ARRIVED-but-unadmitted queue depth
        exceeds `queue_cap`, shed the least-urgent overflow (EDF-last)
        with finish_reason='shed'. Requests with future arrivals (the
        closed-loop pre-submitted workload) don't count until they
        arrive — shedding is decided at arrival pressure, not submit
        time. Returns the number shed."""
        if self.queue_cap is None:
            return 0
        order = self._edf_order(now_s)
        n_over = len(order) - self.queue_cap
        if n_over <= 0:
            return 0
        for i in sorted(order[self.queue_cap:], reverse=True):
            req = self.queue[i]
            del self.queue[i]
            self.sheds += 1
            self._abort(req, "shed", now_s)
        return n_over

    def expire_queued(self, now_s: float) -> int:
        """Time out queued requests whose `timeout_s` elapsed before they
        ever reached a slot (active slots time out in `_maybe_finish`)."""
        expired = [i for i, r in enumerate(self.queue)
                   if r.timeout_s is not None
                   and now_s - r.arrival_s > r.timeout_s]
        for i in sorted(expired, reverse=True):
            req = self.queue[i]
            del self.queue[i]
            self.timeouts += 1
            self._abort(req, "timeout", now_s)
        return len(expired)

    def grow_pages(self, now_s: float, lookahead: int = 1) -> None:
        """Map the page each active slot's next token will land on,
        processing high-priority slots first and evicting under pressure
        (a slot that is itself the lowest-priority one self-evicts).
        Prefilling slots are skipped — their pages reserve per chunk in
        `schedule_step`. With `window` set, pages that slid fully out of
        the sliding window are released back to the pool first.
        `lookahead` > 1 maps pages through position pos + lookahead — a
        speculative round writes k+1 positions ahead in one step."""
        if self.alloc is None:
            return
        order = sorted((i for i, st in enumerate(self.slots)
                        if st is not None),
                       key=lambda i: -self.slots[i].req.priority)
        for i in order:
            st = self.slots[i]
            if st is None:              # evicted by an earlier iteration
                continue
            if st.prefilling:
                continue
            if self.window is not None:
                self.pages_released_by_window += \
                    self.alloc.release_window(i, st.pos + 1, self.window)
            last = min(st.pos + lookahead, self.max_len - 1)
            while not self.alloc.ensure(i, last):
                if self._evict_cache_tier():
                    continue            # cache entries go before live slots
                victim = self._eviction_candidate()
                assert victim is not None, "no active slot to evict"
                self.evict(victim, now_s)
                if victim == i:
                    break
            if self.slots[i] is st:
                # decode writes land past every shared prefix page, but a
                # COW here guards the invariant if that ever changes; a
                # failed COW must never let the write proceed into a
                # shared page (corrupting other holders' bytes) — evict
                # the slot instead, the standard self-evict valve
                if not self._cow_range(i, st.pos + 1, last, now_s):
                    self.evict(i, now_s)


    def _reserve_chunk(self, slot: int, st: _Slot, last_pos: int,
                       now_s: float) -> bool:
        """Reserve the pages covering a chunk ending at `last_pos`,
        evicting strictly-lower-priority slots under pressure. Chunks are
        all-or-nothing (a partial chunk would make the prompt's chunk
        split, and so its greedy tokens, depend on co-scheduling)."""
        if self.alloc is None:
            return True
        if self.window is not None and st.fed > 0:
            self.pages_released_by_window += \
                self.alloc.release_window(slot, st.fed, self.window)
        while not self.alloc.ensure(slot, last_pos):
            if self._evict_cache_tier():
                continue                # cache entries go before live slots
            victim = self._eviction_candidate(below=st.req.priority)
            if victim is None:
                return False            # stall this slot; others proceed
            self.evict(victim, now_s)
        # chunk writes into a page another holder shares (a fully-cached
        # admission's final token) must not mutate the shared bytes
        return self._cow_range(slot, st.fed, last_pos, now_s,
                               below=st.req.priority)

    # ------------------------------------------------ token-budget stepping

    def schedule_step(self, budget: int, chunk_cap: int,
                      now_s: float) -> Optional[Dict[str, np.ndarray]]:
        """Fill one token-budget step's lanes.

        Every decoding slot gets exactly one lane first — an in-flight
        stream never skips a step while `budget >= n_slots` (asserted in
        `max_decode_gap`). Remaining lanes carry prompt chunks of
        prefilling slots in EDF order, in fixed `chunk_cap`-aligned pieces
        reserved page-by-chunk. Returns dense (budget,) arrays for the
        jitted `mixed_step` (`None` when nothing could be laned) plus the
        (n_slots,) reset mask; emit bookkeeping is held until
        `record_scheduled` folds the step's samples back in.
        """
        assert chunk_cap >= 1
        lanes: List[Tuple[int, int, int, int, bool]] = []
        reset = np.zeros(self.n_slots, bool)
        self._step_emits = []
        self._step_fed = []
        for i, st in enumerate(self.slots):     # decode lanes
            if st is None or st.prefilling or not st.tokens:
                continue
            st.gap += 1
            if len(lanes) >= budget:
                continue                        # budget-starved stream
            self.max_decode_gap = max(self.max_decode_gap, st.gap)
            st.gap = 0
            lanes.append((i, st.cur_token, st.pos + 1, st.pos + 1, True))
            self._step_emits.append(i)
        n_decode = len(lanes)
        prefilling = [i for i, st in enumerate(self.slots)
                      if st is not None and st.prefilling]
        prefilling.sort(key=lambda i: self._edf_key(self.slots[i].req, i))
        for i in prefilling:                    # chunk lanes
            st = self.slots[i]
            if st is None:                      # evicted reserving a peer
                continue
            plen = len(st.req.prompt)
            c = min(chunk_cap, plen - st.fed)
            if budget - len(lanes) < c:
                continue                        # whole chunk or nothing
            if not self._reserve_chunk(i, st, st.fed + c - 1, now_s):
                continue
            if self.slots[i] is not st:         # evicted itself? (paranoia)
                continue
            if st.fed == 0:
                reset[i] = True
            for j in range(st.fed, st.fed + c):
                lanes.append((i, st.req.prompt[j], j, st.fed,
                              j == plen - 1))
            if c and lanes[-1][4]:
                self._step_emits.append(i)
            st.fed += c
            st.pos = st.fed - 1
            self._step_fed.append((i, st, st.fed))
        if not lanes:
            # every lane-less slot is page-starved mid-prefill: force the
            # standard pressure valve so the system cannot livelock
            if self.alloc is not None and self.n_active > 0:
                victim = self._eviction_candidate()
                if victim is not None:
                    self.evict(victim, now_s)
                    if self.n_active > 0:
                        return self.schedule_step(budget, chunk_cap, now_s)
            return None
        out = {k: np.zeros(budget, dt) for k, dt in (
            ("tokens", np.int32), ("slots", np.int32),
            ("positions", np.int32), ("horizon", np.int32),
            ("emit", bool), ("active", bool))}
        for lane, (slot, tok, pos, hor, emit) in enumerate(lanes):
            out["tokens"][lane] = tok
            out["slots"][lane] = slot
            out["positions"][lane] = pos
            out["horizon"][lane] = hor
            out["emit"][lane] = emit
            out["active"][lane] = True
        out["reset"] = reset
        out["n_decode"] = n_decode
        out["n_chunk"] = len(lanes) - n_decode
        return out

    def record_scheduled(self, sampled: np.ndarray,
                         now_s: float) -> List[int]:
        """Fold the step's per-slot samples back in: decode lanes append
        their next token, a slot whose final prompt chunk emitted records
        its FIRST token (TTFT). Returns slots freed this step."""
        freed = []
        # the step ran: its chunk writes are on device, so the written
        # watermark catches up to fed. The identity check drops slots
        # evicted/quarantined after laning (their writes routed to
        # scratch — nothing real was written).
        for i, st, fed in self._step_fed:
            if self.slots[i] is st:
                st.written = fed
        self._step_fed = []
        emits, self._step_emits = self._step_emits, []
        for i in emits:
            st = self.slots[i]
            if st is None:
                continue
            tok = int(sampled[i])
            if not st.tokens:                   # prefill completed
                st.prefill_s = now_s - st.started_s
                if self.prefix_cache is not None:
                    self._deposit(i, st)        # prompt pages now reusable
            else:
                st.pos += 1
                st.steps += 1
            st.cur_token = tok
            st.tokens.append(tok)
            st.times.append(now_s)
            self.events.append(TokenEvent(st.req.uid, tok, now_s,
                                          len(st.tokens) - 1))
            if self._maybe_finish(i, now_s):
                freed.append(i)
        return freed

    # ------------------------------------------------- speculative decoding

    def spec_ready(self) -> bool:
        """True when a speculative round may replace this step: every
        active slot is a greedy decode stream.  Prefilling slots need
        chunk lanes (the round is pure decode), and sampled (temperature
        > 0) slots would break the PRNG stream-index bookkeeping that
        keeps serving reproducible, so any such slot gates the whole
        step back to the plain path."""
        if self.n_active == 0:
            return False
        for st in self.slots:
            if st is None:
                continue
            if st.prefilling or not st.tokens:
                return False
            if st.req.temperature > 0:
                return False
        return True

    def record_speculative(self, slot: int, toks: List[int],
                           now_s: float) -> int:
        """Append one speculative round's accepted tokens for `slot` —
        the decode-lane bookkeeping of `record_scheduled`, repeated once
        per token, stopping at the first finish condition (eos / length
        / deadline).  Returns the number of tokens actually appended;
        the caller rolls back cache cells beyond that count.

        Timestamps: the round emits up to k+1 tokens at one wall-clock
        instant, but stamping them all `now_s` would collapse ITL
        percentiles computed from `token_times` to zero-gap runs.  The
        tokens were produced *throughout* the round (k draft passes + one
        verify), so each appended token gets a timestamp linearly
        interpolated between the slot's previous sample time and `now_s` —
        monotone, summing to the true round span, and honest about the
        per-token latency a streaming client would observe."""
        st = self.slots[slot]
        assert st is not None and st.tokens, \
            "speculative record on a non-decoding slot"
        t_prev = st.times[-1] if st.times else now_s
        span = max(now_s - t_prev, 0.0)
        n = 0
        for tok in toks:
            st.pos += 1
            st.steps += 1
            st.cur_token = int(tok)
            st.tokens.append(int(tok))
            t_tok = t_prev + span * (n + 1) / len(toks)
            st.times.append(t_tok)
            self.events.append(TokenEvent(st.req.uid, int(tok), t_tok,
                                          len(st.tokens) - 1))
            n += 1
            if self._maybe_finish(slot, now_s):
                break
        return n

    def slot_sample_arrays(self) -> Tuple[np.ndarray, ...]:
        """(temps, top_ks, n_sampled) dense (n_slots,) for the sampler;
        n_sampled feeds each request's PRNG stream index (0 = the prompt's
        first token, exactly as the legacy prefill-time sample)."""
        temps = np.zeros(self.n_slots, np.float32)
        top_ks = np.zeros(self.n_slots, np.int32)
        nsamp = np.zeros(self.n_slots, np.int32)
        for i, st in enumerate(self.slots):
            if st is None:
                continue
            temps[i] = st.req.temperature
            top_ks[i] = st.req.top_k
            nsamp[i] = len(st.tokens)
        return temps, top_ks, nsamp

    def _maybe_finish(self, slot: int, now_s: float) -> bool:
        st = self.slots[slot]
        reason = None
        if st.req.eos_id is not None and st.tokens[-1] == st.req.eos_id:
            reason = "eos"
        elif len(st.tokens) >= st.req.max_new:
            reason = "length"
        elif st.pos + 2 >= self.max_len:   # next token would overflow cache
            reason = "length"
        elif (st.req.deadline_s is not None
                and now_s - st.started_s > st.req.deadline_s):
            reason = "deadline"
        elif (st.req.timeout_s is not None
                and now_s - st.req.arrival_s > st.req.timeout_s):
            reason = "timeout"
            self.timeouts += 1
        if reason is None:
            return False
        self.results[st.req.uid] = GenResult(
            tokens=st.tokens, prefill_s=st.prefill_s,
            decode_s=now_s - st.started_s, steps=st.steps,
            finish_reason=reason, done_s=now_s, evictions=st.evictions,
            token_times=st.times)
        self.events.append(TokenEvent(st.req.uid, -1, now_s,
                                      len(st.tokens), done=True,
                                      finish_reason=reason))
        if self.alloc is not None:
            self._deposit(slot, st)     # full history reusable (multi-turn)
            self.alloc.release(slot)
        self.slots[slot] = None
        return True

    def record_step(self, sampled: np.ndarray, now_s: float) -> List[int]:
        """Fold one decode step's sampled tokens (n_slots,) back in.
        Returns slots freed this step."""
        freed = []
        for i, st in enumerate(self.slots):
            if st is None:
                continue
            st.pos += 1
            st.steps += 1
            st.cur_token = int(sampled[i])
            st.tokens.append(st.cur_token)
            if self._maybe_finish(i, now_s):
                freed.append(i)
        return freed

    # ------------------------------------------------- arrays for the device

    def batch_arrays(self) -> Tuple[np.ndarray, ...]:
        """(tokens, pos, active, temps, top_ks, n_sampled) dense over slots;
        inactive rows hold harmless values (token 0 at pos 0, masked in the
        model). n_sampled feeds the per-request PRNG stream index."""
        toks = np.zeros(self.n_slots, np.int32)
        pos = np.zeros(self.n_slots, np.int32)
        act = np.zeros(self.n_slots, bool)
        temps = np.zeros(self.n_slots, np.float32)
        top_ks = np.zeros(self.n_slots, np.int32)
        nsamp = np.zeros(self.n_slots, np.int32)
        for i, st in enumerate(self.slots):
            if st is None:
                continue
            toks[i] = st.cur_token
            pos[i] = st.pos + 1        # position the next token will occupy
            act[i] = True
            temps[i] = st.req.temperature
            top_ks[i] = st.req.top_k
            nsamp[i] = len(st.tokens)
        return toks, pos, act, temps, top_ks, nsamp

    def page_table(self) -> Optional[np.ndarray]:
        """(n_slots, max_pages) int32 device page table (None if unpaged)."""
        return None if self.alloc is None else self.alloc.table()

    def done(self) -> bool:
        return not self.queue and self.n_active == 0
