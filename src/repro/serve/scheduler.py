"""Slot scheduler for continuous batching: queue, chunk scheduling, completion.

The scheduler is the host-side half of the serving engine. It owns the
request queue and a fixed table of `n_slots` decode slots; the device-side
half (engine.py) owns the slot-batched KV cache whose row i mirrors slot i
here. Admission is per-slot: whenever a slot frees (eos / length budget /
deadline), the next arrived request binds to it mid-flight — no barrier on
the rest of the batch.

Admission order is EDF (earliest deadline first) over the *arrived* part of
the queue — requests without a deadline sort last, ties break by arrival
then submission order, so pure-FIFO workloads behave exactly as before.

Chunk scheduling (`schedule_step`) fills each token-budget step's lanes:
every decoding slot gets exactly one lane first (an in-flight stream never
skips a step while the budget covers the slot count), then the remaining
lanes carry prompt chunks of prefilling slots in EDF order. Chunk
boundaries are fixed multiples of the chunk cap counted from position 0 —
never "whatever budget is left" — so a prompt's chunk split (and therefore
its greedy output) is deterministic regardless of what it is co-scheduled
with, including after an eviction replay.

For paged KV caches the scheduler also owns the `PageAllocator`: a
host-side free list over the device page pool. Pages are reserved per
CHUNK (not per prompt) as chunks are scheduled, decode grows a slot's page
list lazily as its sequence crosses page boundaries, and when the pool
runs dry the lowest-priority (then least-progress) slot is evicted — its
pages return to the pool and its request requeues for a fresh chunked
prefill (preemption by recompute). When every attention layer is sliding-
window ('local'), pages that slide fully out of the window are released
back to the pool mid-flight (`window=`).

All bookkeeping is numpy/python (one dict lookup per slot per step); the
dense per-lane arrays handed to the jitted token-budget step are assembled
in `schedule_step` / `page_table` (`batch_arrays` serves the legacy
one-token-per-slot step).
"""
from __future__ import annotations

import dataclasses
import itertools
from collections import deque
from typing import Dict, List, Optional, Tuple

import numpy as np

_UID = itertools.count()


@dataclasses.dataclass
class GenRequest:
    prompt: List[int]
    max_new: int = 32
    temperature: float = 0.0
    eos_id: Optional[int] = None
    top_k: int = 0                     # 0 = no truncation
    deadline_s: Optional[float] = None  # decode wall-clock budget, None = off
    timeout_s: Optional[float] = None  # hard wall-clock cap from ARRIVAL
    arrival_s: float = 0.0             # offset from serve() start (Poisson)
    priority: int = 0                  # higher = evicted later under pressure
    uid: int = dataclasses.field(default_factory=lambda: next(_UID))


@dataclasses.dataclass
class TokenEvent:
    """One emitted token (or completion) as seen by a streaming consumer.

    The scheduler appends these as it folds samples back in; the engine
    session drains them per step (`SlotScheduler.take_events`) and the SSE
    front end relays them to the request's open stream. `token == -1`
    marks the terminal event (no token payload — `finish_reason` is set
    and the full `GenResult` is in `results[uid]`)."""
    uid: int
    token: int
    t_s: float                         # offset from serve()/session start
    index: int                         # token index within the request
    done: bool = False
    finish_reason: str = ""


@dataclasses.dataclass
class GenResult:
    tokens: List[int]
    prefill_s: float = 0.0             # admission -> first token (TTFT)
    decode_s: float = 0.0
    steps: int = 0
    # length | eos | deadline | timeout | error | shed | cancelled
    finish_reason: str = "length"
    done_s: float = 0.0                # completion time, offset from serve()
    evictions: int = 0                 # page-pressure preemptions (restarts)
    token_times: Optional[List[float]] = None  # per-token sample times


@dataclasses.dataclass
class _Slot:
    req: GenRequest
    pos: int                           # position of the latest written token
    cur_token: int                     # latest sampled token (next step input)
    tokens: List[int]
    started_s: float
    prefill_s: float
    steps: int = 0
    evictions: int = 0                 # times this request was preempted
    fed: int = 0                       # prompt tokens scheduled so far
    gap: int = 0                       # steps since this stream last sampled
    times: List[float] = dataclasses.field(default_factory=list)

    @property
    def prefilling(self) -> bool:
        return self.fed < len(self.req.prompt)


class PageAllocator:
    """Host-side free list over the device KV page pool.

    Page ids index the per-layer `(n_pages + 1, page_size, ...)` pools of
    the paged CacheFormats (id `n_pages` is the device-side scratch page
    and is never handed out). Every slot owns a list of *logical* pages —
    entry j of a slot's list holds token positions [j*page_size,
    (j+1)*page_size) — mapped to arbitrary physical ids. A leading run of
    entries may be `None`: pages released mid-flight by `release_window`
    once they slid fully out of a sliding-window model's reach (the table
    maps them to -1, so reads route to the scratch page and the window
    mask hides them).

    Invariants (property-tested): the free list and the per-slot owned
    (non-None) entries are always a disjoint partition of range(n_pages) —
    no page is leaked or double-owned across admit/grow/release churn.
    """

    def __init__(self, n_pages: int, page_size: int, n_slots: int,
                 max_pages_per_slot: int):
        assert n_pages >= 1 and page_size >= 1
        self.n_pages = n_pages
        self.page_size = page_size
        self.n_slots = n_slots
        self.max_pages_per_slot = max_pages_per_slot
        self.free: List[int] = list(range(n_pages))
        self.owned: List[List[int]] = [[] for _ in range(n_slots)]
        self.quarantined: List[int] = []   # retired (ECC-style) free pages

    def pages_for(self, n_tokens: int) -> int:
        return -(-max(n_tokens, 0) // self.page_size)

    @property
    def available(self) -> int:
        return len(self.free)

    @property
    def in_use(self) -> int:
        return self.n_pages - len(self.free)

    def release_window(self, slot: int, pos: int, window: int) -> int:
        """Free this slot's pages that slid fully out of the sliding window
        of every present-or-future query (positions <= pos - window can
        never be attended again once the next token sits at `pos`). Only
        valid when ALL attention layers are windowed — a single global
        layer keeps whole-history pages live. Returns pages freed."""
        freed = 0
        for j, pg in enumerate(self.owned[slot]):
            if pg is None:
                continue
            if (j + 1) * self.page_size - 1 > pos - window:
                break                   # logical pages are position-ordered
            self.free.append(pg)
            self.owned[slot][j] = None
            freed += 1
        return freed

    def alloc(self, slot: int, n: int) -> bool:
        """Grow slot's page list by n pages; False (no change) if the free
        list cannot cover it or the slot would exceed max_pages_per_slot."""
        if n > len(self.free) or \
                len(self.owned[slot]) + n > self.max_pages_per_slot:
            return False
        for _ in range(n):
            self.owned[slot].append(self.free.pop())
        return True

    def ensure(self, slot: int, pos: int) -> bool:
        """Ensure the page holding token position `pos` is mapped."""
        need = pos // self.page_size + 1 - len(self.owned[slot])
        return True if need <= 0 else self.alloc(slot, need)

    def release(self, slot: int) -> int:
        """Return all of a slot's pages to the pool; returns the count."""
        live = [p for p in self.owned[slot] if p is not None]
        self.free.extend(live)
        self.owned[slot] = []
        return len(live)

    def table(self) -> np.ndarray:
        """(n_slots, max_pages_per_slot) int32 page table; -1 = unmapped."""
        t = np.full((self.n_slots, self.max_pages_per_slot), -1, np.int32)
        for i, pages in enumerate(self.owned):
            for j, p in enumerate(pages):
                if p is not None:
                    t[i, j] = p
        return t

    def quarantine_free_pages(self, n: int) -> int:
        """Retire up to `n` FREE pages from circulation (simulated ECC
        retirement / a neighbor stealing HBM). Quarantined pages are
        neither free nor owned — allocation pressure rises and the
        scheduler's ordinary eviction valve absorbs it. Returns the
        number actually retired."""
        n = min(n, len(self.free))
        for _ in range(n):
            self.quarantined.append(self.free.pop())
        return n

    def restore_quarantined(self) -> int:
        """Return every quarantined page to the free list."""
        n = len(self.quarantined)
        self.free.extend(self.quarantined)
        self.quarantined = []
        return n

    def check(self) -> None:
        """Assert the no-leak / no-double-own invariant: free + owned +
        quarantined partition range(n_pages)."""
        seen = list(self.free) + list(self.quarantined)
        for pages in self.owned:
            seen.extend(p for p in pages if p is not None)
        assert sorted(seen) == list(range(self.n_pages)), \
            (sorted(seen), self.n_pages)


class SlotScheduler:
    """Request queue + slot table; the engine drives it step by step.

    `alloc` (a PageAllocator) switches on paged-cache bookkeeping: chunk
    scheduling reserves each chunk's pages as it is laned (evicting
    strictly-lower-priority slots to make room), and `grow_pages` extends
    each live slot's mapping ahead of every step. `window` (token count)
    enables mid-flight release of pages that slid fully out of a sliding
    window — only pass it when every attention layer is 'local'.
    """

    def __init__(self, n_slots: int, max_len: int,
                 alloc: Optional[PageAllocator] = None,
                 window: Optional[int] = None,
                 queue_cap: Optional[int] = None,
                 poison_threshold: int = 3):
        assert n_slots >= 1
        self.n_slots = n_slots
        self.max_len = max_len
        self.alloc = alloc
        self.window = window
        self.queue_cap = queue_cap     # arrived-queue depth before shedding
        self.poison_threshold = poison_threshold  # quarantines before abort
        self.queue: deque = deque()
        self.slots: List[Optional[_Slot]] = [None] * n_slots
        self.results: Dict[int, GenResult] = {}
        self.slot_reuses = 0           # admissions into a previously used slot
        self.evictions = 0             # page-pressure preemptions
        self.max_decode_gap = 0        # worst steps-between-samples, any stream
        self.pages_released_by_window = 0
        self.quarantines = 0           # fault preemptions (NaN / watchdog)
        self.requeues = 0              # quarantines that replayed the request
        self.poisoned = 0              # requests aborted after N strikes
        self.sheds = 0                 # overload rejections (queue_cap)
        self.timeouts = 0              # per-request wall-clock expiries
        self.cancels = 0               # client-abandoned requests
        self._evicted: Dict[int, int] = {}   # uid -> times preempted
        self._strikes: Dict[int, int] = {}   # uid -> fault quarantines
        self._used = [False] * n_slots
        self._step_emits: List[int] = []
        self._step_reset: List[int] = []
        self.events: List[TokenEvent] = []   # drained via take_events()

    # ------------------------------------------------------------ queue side

    def submit(self, req: GenRequest) -> None:
        """Validate and enqueue. Raises ValueError (never an assert — the
        SSE front end turns it into a 400, and a bad request must not
        take down the shared driver thread)."""
        if len(req.prompt) < 1:
            raise ValueError("empty prompt")
        if len(req.prompt) >= self.max_len:
            raise ValueError(f"prompt ({len(req.prompt)}) must fit the "
                             f"cache ({self.max_len})")
        if req.max_new < 1:
            raise ValueError(f"max_new must be >= 1, got {req.max_new}")
        if self.alloc is not None:
            # a request whose full trajectory cannot fit the pool would
            # evict-thrash forever; refuse it up front
            worst = min(len(req.prompt) + req.max_new, self.max_len)
            if self.alloc.pages_for(worst) > self.alloc.n_pages:
                raise ValueError(
                    f"request needs {self.alloc.pages_for(worst)} pages, "
                    f"pool holds {self.alloc.n_pages}")
        self.queue.append(req)

    @property
    def pending(self) -> int:
        return len(self.queue)

    @property
    def n_active(self) -> int:
        return sum(s is not None for s in self.slots)

    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    @staticmethod
    def _edf_key(req: GenRequest, tie: int) -> Tuple:
        """EDF ordering key shared by queue admission and chunk-lane
        scheduling: earliest deadline first, deadline-free last, ties FIFO
        by arrival then a caller-supplied index."""
        return (req.deadline_s if req.deadline_s is not None
                else float("inf"), req.arrival_s, tie)

    def _edf_order(self, now_s: float) -> List[int]:
        """Arrived-request indices in admission order (EDF)."""
        arrived = [i for i, r in enumerate(self.queue)
                   if r.arrival_s <= now_s]
        return sorted(arrived, key=lambda i: self._edf_key(self.queue[i], i))

    def _evictable_pages(self, below: int) -> int:
        """Pages reclaimable by evicting every active slot with priority
        strictly below `below`."""
        return sum(len(self.alloc.owned[i]) for i, st in
                   enumerate(self.slots)
                   if st is not None and st.req.priority < below)

    def next_ready(self, now_s: float,
                   slot: Optional[int] = None) -> Optional[GenRequest]:
        """Pop the next admittable request (EDF over arrived requests).

        Admission binds a request to a slot without touching the page
        pool: pages are reserved chunk by chunk as `schedule_step` lanes
        the prompt (evicting strictly-lower-priority slots under
        pressure), so a page-starved request occupies a slot but never
        blocks co-scheduled streams. `slot` is accepted for API
        compatibility and unused.
        """
        del slot
        for i in self._edf_order(now_s):
            req = self.queue[i]
            del self.queue[i]
            return req
        return None

    def next_arrival(self) -> Optional[float]:
        return min(r.arrival_s for r in self.queue) if self.queue else None

    def queue_pressure(self, now_s: float) -> Tuple[int, float]:
        """(arrived-but-unadmitted queue depth, oldest such request's wait
        in seconds) — the load signal adaptive policies key on."""
        waits = [now_s - r.arrival_s for r in self.queue
                 if r.arrival_s <= now_s]
        return len(waits), max(waits, default=0.0)

    def take_events(self) -> List[TokenEvent]:
        """Drain the token-event stream accumulated since the last call."""
        out, self.events = self.events, []
        return out

    @property
    def step_emits(self) -> List[int]:
        """Slots the in-flight step will sample for (set by
        `schedule_step`, consumed by `record_scheduled`); the engine's
        NaN guard reads it to know whose logits rows matter."""
        return list(self._step_emits)

    # ------------------------------------------------------------- slot side

    def admit(self, slot: int, req: GenRequest, first_token: int,
              now_s: float, prefill_s: float) -> bool:
        """Bind req to slot with its prefill-sampled first token (the
        legacy whole-prompt-prefill admission). Returns True if the
        request finished immediately (it still occupied the slot for zero
        decode steps)."""
        assert self.slots[slot] is None
        if self._used[slot]:
            self.slot_reuses += 1
        self._used[slot] = True
        st = _Slot(req=req, pos=len(req.prompt) - 1, cur_token=first_token,
                   tokens=[first_token], started_s=now_s, prefill_s=prefill_s,
                   evictions=self._evicted.get(req.uid, 0),
                   fed=len(req.prompt), times=[now_s])
        self.slots[slot] = st
        self.events.append(TokenEvent(req.uid, first_token, now_s, 0))
        return self._maybe_finish(slot, now_s)

    def admit_chunked(self, slot: int, req: GenRequest, now_s: float) -> None:
        """Bind req to slot for chunked prefill: its prompt will be laned
        into the token-budget steps by `schedule_step`; the first token
        samples when the final prompt chunk emits."""
        assert self.slots[slot] is None
        if self._used[slot]:
            self.slot_reuses += 1
        self._used[slot] = True
        self.slots[slot] = _Slot(
            req=req, pos=-1, cur_token=-1, tokens=[], started_s=now_s,
            prefill_s=0.0, evictions=self._evicted.get(req.uid, 0), fed=0)

    # ------------------------------------------------------ paged eviction

    def _eviction_candidate(self, below: Optional[int] = None
                            ) -> Optional[int]:
        """Active slot to preempt: lowest priority, then least computed
        work (fed prompt tokens + decoded tokens — the recompute an
        eviction throws away; a nearly-chunked-in long prompt is NOT the
        cheap victim its empty token list would suggest). `below`
        restricts to slots with priority strictly below it (chunk
        reservation never evicts peers)."""
        best, best_key = None, None
        for i, st in enumerate(self.slots):
            if st is None:
                continue
            if below is not None and st.req.priority >= below:
                continue
            key = (st.req.priority, st.fed + len(st.tokens))
            if best_key is None or key < best_key:
                best, best_key = i, key
        return best

    def evict(self, slot: int, now_s: float) -> None:
        """Preempt a slot: release its pages and requeue its request for a
        fresh prefill (preemption by recompute — generated tokens are
        discarded and regenerated after re-admission; greedy and seeded
        sampling replay identically because PRNG streams key on the
        submission index)."""
        st = self.slots[slot]
        assert st is not None
        if self.alloc is not None:
            self.alloc.release(slot)
        self.slots[slot] = None
        self.evictions += 1
        self._evicted[st.req.uid] = self._evicted.get(st.req.uid, 0) + 1
        self.queue.append(st.req)

    # ------------------------------------------------------ fault handling

    def _abort(self, req: GenRequest, reason: str, now_s: float,
               tokens: Optional[List[int]] = None,
               times: Optional[List[float]] = None) -> None:
        """Terminate a request that will NOT produce (more) output:
        record a GenResult with an explicit finish_reason and emit the
        terminal TokenEvent so a streaming client unblocks."""
        toks = tokens or []
        self.results[req.uid] = GenResult(
            tokens=toks, finish_reason=reason, done_s=now_s,
            evictions=self._evicted.get(req.uid, 0), token_times=times)
        self.events.append(TokenEvent(req.uid, -1, now_s, len(toks),
                                      done=True, finish_reason=reason))

    def quarantine(self, slot: int, now_s: float) -> str:
        """Preempt a FAULTED slot (NaN logits, watchdog exhaustion): its
        pages return to the pool and its generated tokens are discarded.
        Below `poison_threshold` strikes the request requeues for a
        deterministic replay (PRNG streams key on submission index, so a
        surviving replay's greedy tokens are bitwise the fault-free
        run's); at the threshold it aborts with finish_reason='error'
        instead of livelocking. Returns 'requeued' or 'error'."""
        st = self.slots[slot]
        assert st is not None
        if self.alloc is not None:
            self.alloc.release(slot)
        self.slots[slot] = None
        self.quarantines += 1
        uid = st.req.uid
        self._strikes[uid] = self._strikes.get(uid, 0) + 1
        if self._strikes[uid] >= self.poison_threshold:
            self.poisoned += 1
            self._abort(st.req, "error", now_s)
            return "error"
        self._evicted[uid] = self._evicted.get(uid, 0) + 1
        self.requeues += 1
        self.queue.append(st.req)
        return "requeued"

    def cancel(self, uid: int, now_s: float) -> bool:
        """Drop a request the client abandoned: from the queue, or from
        its active slot (freeing the slot and its pages mid-flight).
        Partial tokens are kept in the result. Idempotent — returns
        False if the uid is not live (already finished/cancelled)."""
        for i, r in enumerate(self.queue):
            if r.uid == uid:
                del self.queue[i]
                self.cancels += 1
                self._abort(r, "cancelled", now_s)
                return True
        for i, st in enumerate(self.slots):
            if st is not None and st.req.uid == uid:
                if self.alloc is not None:
                    self.alloc.release(i)
                self.slots[i] = None
                self.cancels += 1
                self._abort(st.req, "cancelled", now_s,
                            tokens=st.tokens, times=st.times)
                return True
        return False

    def shed_overflow(self, now_s: float) -> int:
        """Overload valve: when the ARRIVED-but-unadmitted queue depth
        exceeds `queue_cap`, shed the least-urgent overflow (EDF-last)
        with finish_reason='shed'. Requests with future arrivals (the
        closed-loop pre-submitted workload) don't count until they
        arrive — shedding is decided at arrival pressure, not submit
        time. Returns the number shed."""
        if self.queue_cap is None:
            return 0
        order = self._edf_order(now_s)
        n_over = len(order) - self.queue_cap
        if n_over <= 0:
            return 0
        for i in sorted(order[self.queue_cap:], reverse=True):
            req = self.queue[i]
            del self.queue[i]
            self.sheds += 1
            self._abort(req, "shed", now_s)
        return n_over

    def expire_queued(self, now_s: float) -> int:
        """Time out queued requests whose `timeout_s` elapsed before they
        ever reached a slot (active slots time out in `_maybe_finish`)."""
        expired = [i for i, r in enumerate(self.queue)
                   if r.timeout_s is not None
                   and now_s - r.arrival_s > r.timeout_s]
        for i in sorted(expired, reverse=True):
            req = self.queue[i]
            del self.queue[i]
            self.timeouts += 1
            self._abort(req, "timeout", now_s)
        return len(expired)

    def grow_pages(self, now_s: float, lookahead: int = 1) -> None:
        """Map the page each active slot's next token will land on,
        processing high-priority slots first and evicting under pressure
        (a slot that is itself the lowest-priority one self-evicts).
        Prefilling slots are skipped — their pages reserve per chunk in
        `schedule_step`. With `window` set, pages that slid fully out of
        the sliding window are released back to the pool first.
        `lookahead` > 1 maps pages through position pos + lookahead — a
        speculative round writes k+1 positions ahead in one step."""
        if self.alloc is None:
            return
        order = sorted((i for i, st in enumerate(self.slots)
                        if st is not None),
                       key=lambda i: -self.slots[i].req.priority)
        for i in order:
            st = self.slots[i]
            if st is None:              # evicted by an earlier iteration
                continue
            if st.prefilling:
                continue
            if self.window is not None:
                self.pages_released_by_window += \
                    self.alloc.release_window(i, st.pos + 1, self.window)
            while not self.alloc.ensure(
                    i, min(st.pos + lookahead, self.max_len - 1)):
                victim = self._eviction_candidate()
                assert victim is not None, "no active slot to evict"
                self.evict(victim, now_s)
                if victim == i:
                    break

    def _reserve_chunk(self, slot: int, st: _Slot, last_pos: int,
                       now_s: float) -> bool:
        """Reserve the pages covering a chunk ending at `last_pos`,
        evicting strictly-lower-priority slots under pressure. Chunks are
        all-or-nothing (a partial chunk would make the prompt's chunk
        split, and so its greedy tokens, depend on co-scheduling)."""
        if self.alloc is None:
            return True
        if self.window is not None and st.fed > 0:
            self.pages_released_by_window += \
                self.alloc.release_window(slot, st.fed, self.window)
        while not self.alloc.ensure(slot, last_pos):
            victim = self._eviction_candidate(below=st.req.priority)
            if victim is None:
                return False            # stall this slot; others proceed
            self.evict(victim, now_s)
        return True

    # ------------------------------------------------ token-budget stepping

    def schedule_step(self, budget: int, chunk_cap: int,
                      now_s: float) -> Optional[Dict[str, np.ndarray]]:
        """Fill one token-budget step's lanes.

        Every decoding slot gets exactly one lane first — an in-flight
        stream never skips a step while `budget >= n_slots` (asserted in
        `max_decode_gap`). Remaining lanes carry prompt chunks of
        prefilling slots in EDF order, in fixed `chunk_cap`-aligned pieces
        reserved page-by-chunk. Returns dense (budget,) arrays for the
        jitted `mixed_step` (`None` when nothing could be laned) plus the
        (n_slots,) reset mask; emit bookkeeping is held until
        `record_scheduled` folds the step's samples back in.
        """
        assert chunk_cap >= 1
        lanes: List[Tuple[int, int, int, int, bool]] = []
        reset = np.zeros(self.n_slots, bool)
        self._step_emits = []
        for i, st in enumerate(self.slots):     # decode lanes
            if st is None or st.prefilling or not st.tokens:
                continue
            st.gap += 1
            if len(lanes) >= budget:
                continue                        # budget-starved stream
            self.max_decode_gap = max(self.max_decode_gap, st.gap)
            st.gap = 0
            lanes.append((i, st.cur_token, st.pos + 1, st.pos + 1, True))
            self._step_emits.append(i)
        n_decode = len(lanes)
        prefilling = [i for i, st in enumerate(self.slots)
                      if st is not None and st.prefilling]
        prefilling.sort(key=lambda i: self._edf_key(self.slots[i].req, i))
        for i in prefilling:                    # chunk lanes
            st = self.slots[i]
            if st is None:                      # evicted reserving a peer
                continue
            plen = len(st.req.prompt)
            c = min(chunk_cap, plen - st.fed)
            if budget - len(lanes) < c:
                continue                        # whole chunk or nothing
            if not self._reserve_chunk(i, st, st.fed + c - 1, now_s):
                continue
            if self.slots[i] is not st:         # evicted itself? (paranoia)
                continue
            if st.fed == 0:
                reset[i] = True
            for j in range(st.fed, st.fed + c):
                lanes.append((i, st.req.prompt[j], j, st.fed,
                              j == plen - 1))
            if c and lanes[-1][4]:
                self._step_emits.append(i)
            st.fed += c
            st.pos = st.fed - 1
        if not lanes:
            # every lane-less slot is page-starved mid-prefill: force the
            # standard pressure valve so the system cannot livelock
            if self.alloc is not None and self.n_active > 0:
                victim = self._eviction_candidate()
                if victim is not None:
                    self.evict(victim, now_s)
                    if self.n_active > 0:
                        return self.schedule_step(budget, chunk_cap, now_s)
            return None
        out = {k: np.zeros(budget, dt) for k, dt in (
            ("tokens", np.int32), ("slots", np.int32),
            ("positions", np.int32), ("horizon", np.int32),
            ("emit", bool), ("active", bool))}
        for lane, (slot, tok, pos, hor, emit) in enumerate(lanes):
            out["tokens"][lane] = tok
            out["slots"][lane] = slot
            out["positions"][lane] = pos
            out["horizon"][lane] = hor
            out["emit"][lane] = emit
            out["active"][lane] = True
        out["reset"] = reset
        out["n_decode"] = n_decode
        out["n_chunk"] = len(lanes) - n_decode
        return out

    def record_scheduled(self, sampled: np.ndarray,
                         now_s: float) -> List[int]:
        """Fold the step's per-slot samples back in: decode lanes append
        their next token, a slot whose final prompt chunk emitted records
        its FIRST token (TTFT). Returns slots freed this step."""
        freed = []
        emits, self._step_emits = self._step_emits, []
        for i in emits:
            st = self.slots[i]
            if st is None:
                continue
            tok = int(sampled[i])
            if not st.tokens:                   # prefill completed
                st.prefill_s = now_s - st.started_s
            else:
                st.pos += 1
                st.steps += 1
            st.cur_token = tok
            st.tokens.append(tok)
            st.times.append(now_s)
            self.events.append(TokenEvent(st.req.uid, tok, now_s,
                                          len(st.tokens) - 1))
            if self._maybe_finish(i, now_s):
                freed.append(i)
        return freed

    # ------------------------------------------------- speculative decoding

    def spec_ready(self) -> bool:
        """True when a speculative round may replace this step: every
        active slot is a greedy decode stream.  Prefilling slots need
        chunk lanes (the round is pure decode), and sampled (temperature
        > 0) slots would break the PRNG stream-index bookkeeping that
        keeps serving reproducible, so any such slot gates the whole
        step back to the plain path."""
        if self.n_active == 0:
            return False
        for st in self.slots:
            if st is None:
                continue
            if st.prefilling or not st.tokens:
                return False
            if st.req.temperature > 0:
                return False
        return True

    def record_speculative(self, slot: int, toks: List[int],
                           now_s: float) -> int:
        """Append one speculative round's accepted tokens for `slot` —
        the decode-lane bookkeeping of `record_scheduled`, repeated once
        per token, stopping at the first finish condition (eos / length
        / deadline).  Returns the number of tokens actually appended;
        the caller rolls back cache cells beyond that count.

        Timestamps: the round emits up to k+1 tokens at one wall-clock
        instant, but stamping them all `now_s` would collapse ITL
        percentiles computed from `token_times` to zero-gap runs.  The
        tokens were produced *throughout* the round (k draft passes + one
        verify), so each appended token gets a timestamp linearly
        interpolated between the slot's previous sample time and `now_s` —
        monotone, summing to the true round span, and honest about the
        per-token latency a streaming client would observe."""
        st = self.slots[slot]
        assert st is not None and st.tokens, \
            "speculative record on a non-decoding slot"
        t_prev = st.times[-1] if st.times else now_s
        span = max(now_s - t_prev, 0.0)
        n = 0
        for tok in toks:
            st.pos += 1
            st.steps += 1
            st.cur_token = int(tok)
            st.tokens.append(int(tok))
            t_tok = t_prev + span * (n + 1) / len(toks)
            st.times.append(t_tok)
            self.events.append(TokenEvent(st.req.uid, int(tok), t_tok,
                                          len(st.tokens) - 1))
            n += 1
            if self._maybe_finish(slot, now_s):
                break
        return n

    def slot_sample_arrays(self) -> Tuple[np.ndarray, ...]:
        """(temps, top_ks, n_sampled) dense (n_slots,) for the sampler;
        n_sampled feeds each request's PRNG stream index (0 = the prompt's
        first token, exactly as the legacy prefill-time sample)."""
        temps = np.zeros(self.n_slots, np.float32)
        top_ks = np.zeros(self.n_slots, np.int32)
        nsamp = np.zeros(self.n_slots, np.int32)
        for i, st in enumerate(self.slots):
            if st is None:
                continue
            temps[i] = st.req.temperature
            top_ks[i] = st.req.top_k
            nsamp[i] = len(st.tokens)
        return temps, top_ks, nsamp

    def _maybe_finish(self, slot: int, now_s: float) -> bool:
        st = self.slots[slot]
        reason = None
        if st.req.eos_id is not None and st.tokens[-1] == st.req.eos_id:
            reason = "eos"
        elif len(st.tokens) >= st.req.max_new:
            reason = "length"
        elif st.pos + 2 >= self.max_len:   # next token would overflow cache
            reason = "length"
        elif (st.req.deadline_s is not None
                and now_s - st.started_s > st.req.deadline_s):
            reason = "deadline"
        elif (st.req.timeout_s is not None
                and now_s - st.req.arrival_s > st.req.timeout_s):
            reason = "timeout"
            self.timeouts += 1
        if reason is None:
            return False
        self.results[st.req.uid] = GenResult(
            tokens=st.tokens, prefill_s=st.prefill_s,
            decode_s=now_s - st.started_s, steps=st.steps,
            finish_reason=reason, done_s=now_s, evictions=st.evictions,
            token_times=st.times)
        self.events.append(TokenEvent(st.req.uid, -1, now_s,
                                      len(st.tokens), done=True,
                                      finish_reason=reason))
        if self.alloc is not None:
            self.alloc.release(slot)
        self.slots[slot] = None
        return True

    def record_step(self, sampled: np.ndarray, now_s: float) -> List[int]:
        """Fold one decode step's sampled tokens (n_slots,) back in.
        Returns slots freed this step."""
        freed = []
        for i, st in enumerate(self.slots):
            if st is None:
                continue
            st.pos += 1
            st.steps += 1
            st.cur_token = int(sampled[i])
            st.tokens.append(st.cur_token)
            if self._maybe_finish(i, now_s):
                freed.append(i)
        return freed

    # ------------------------------------------------- arrays for the device

    def batch_arrays(self) -> Tuple[np.ndarray, ...]:
        """(tokens, pos, active, temps, top_ks, n_sampled) dense over slots;
        inactive rows hold harmless values (token 0 at pos 0, masked in the
        model). n_sampled feeds the per-request PRNG stream index."""
        toks = np.zeros(self.n_slots, np.int32)
        pos = np.zeros(self.n_slots, np.int32)
        act = np.zeros(self.n_slots, bool)
        temps = np.zeros(self.n_slots, np.float32)
        top_ks = np.zeros(self.n_slots, np.int32)
        nsamp = np.zeros(self.n_slots, np.int32)
        for i, st in enumerate(self.slots):
            if st is None:
                continue
            toks[i] = st.cur_token
            pos[i] = st.pos + 1        # position the next token will occupy
            act[i] = True
            temps[i] = st.req.temperature
            top_ks[i] = st.req.top_k
            nsamp[i] = len(st.tokens)
        return toks, pos, act, temps, top_ks, nsamp

    def page_table(self) -> Optional[np.ndarray]:
        """(n_slots, max_pages) int32 device page table (None if unpaged)."""
        return None if self.alloc is None else self.alloc.table()

    def done(self) -> bool:
        return not self.queue and self.n_active == 0
