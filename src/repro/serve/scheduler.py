"""Slot scheduler for continuous batching: queue, admission, completion.

The scheduler is the host-side half of the serving engine. It owns the
request queue and a fixed table of `n_slots` decode slots; the device-side
half (engine.py) owns the slot-batched KV cache whose row i mirrors slot i
here. Admission is per-slot: whenever a slot frees (eos / length budget /
deadline), the next arrived request is prefillable into it mid-flight —
no barrier on the rest of the batch.

All bookkeeping is numpy/python (one dict lookup per slot per step); the
dense per-slot arrays handed to the jitted decode step are assembled in
`batch_arrays`.
"""
from __future__ import annotations

import dataclasses
import itertools
from collections import deque
from typing import Dict, List, Optional, Tuple

import numpy as np

_UID = itertools.count()


@dataclasses.dataclass
class GenRequest:
    prompt: List[int]
    max_new: int = 32
    temperature: float = 0.0
    eos_id: Optional[int] = None
    top_k: int = 0                     # 0 = no truncation
    deadline_s: Optional[float] = None  # decode wall-clock budget, None = off
    arrival_s: float = 0.0             # offset from serve() start (Poisson)
    uid: int = dataclasses.field(default_factory=lambda: next(_UID))


@dataclasses.dataclass
class GenResult:
    tokens: List[int]
    prefill_s: float = 0.0
    decode_s: float = 0.0
    steps: int = 0
    finish_reason: str = "length"      # length | eos | deadline
    done_s: float = 0.0                # completion time, offset from serve()


@dataclasses.dataclass
class _Slot:
    req: GenRequest
    pos: int                           # position of the latest token
    cur_token: int                     # latest sampled token (next step input)
    tokens: List[int]
    started_s: float
    prefill_s: float
    steps: int = 0


class SlotScheduler:
    """Request queue + slot table; the engine drives it step by step."""

    def __init__(self, n_slots: int, max_len: int):
        assert n_slots >= 1
        self.n_slots = n_slots
        self.max_len = max_len
        self.queue: deque = deque()
        self.slots: List[Optional[_Slot]] = [None] * n_slots
        self.results: Dict[int, GenResult] = {}
        self.slot_reuses = 0           # admissions into a previously used slot
        self._used = [False] * n_slots

    # ------------------------------------------------------------ queue side

    def submit(self, req: GenRequest) -> None:
        assert len(req.prompt) >= 1, "empty prompt"
        assert len(req.prompt) < self.max_len, \
            f"prompt ({len(req.prompt)}) must fit the cache ({self.max_len})"
        self.queue.append(req)

    @property
    def pending(self) -> int:
        return len(self.queue)

    @property
    def n_active(self) -> int:
        return sum(s is not None for s in self.slots)

    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    def next_ready(self, now_s: float) -> Optional[GenRequest]:
        """Pop the next request whose arrival time has passed (FIFO)."""
        if self.queue and self.queue[0].arrival_s <= now_s:
            return self.queue.popleft()
        return None

    def next_arrival(self) -> Optional[float]:
        return self.queue[0].arrival_s if self.queue else None

    # ------------------------------------------------------------- slot side

    def admit(self, slot: int, req: GenRequest, first_token: int,
              now_s: float, prefill_s: float) -> bool:
        """Bind req to slot with its prefill-sampled first token.
        Returns True if the request finished immediately (it still occupied
        the slot for zero decode steps)."""
        assert self.slots[slot] is None
        if self._used[slot]:
            self.slot_reuses += 1
        self._used[slot] = True
        st = _Slot(req=req, pos=len(req.prompt) - 1, cur_token=first_token,
                   tokens=[first_token], started_s=now_s, prefill_s=prefill_s)
        self.slots[slot] = st
        return self._maybe_finish(slot, now_s)

    def _maybe_finish(self, slot: int, now_s: float) -> bool:
        st = self.slots[slot]
        reason = None
        if st.req.eos_id is not None and st.tokens[-1] == st.req.eos_id:
            reason = "eos"
        elif len(st.tokens) >= st.req.max_new:
            reason = "length"
        elif st.pos + 2 >= self.max_len:   # next token would overflow cache
            reason = "length"
        elif (st.req.deadline_s is not None
                and now_s - st.started_s > st.req.deadline_s):
            reason = "deadline"
        if reason is None:
            return False
        self.results[st.req.uid] = GenResult(
            tokens=st.tokens, prefill_s=st.prefill_s,
            decode_s=now_s - st.started_s, steps=st.steps,
            finish_reason=reason, done_s=now_s)
        self.slots[slot] = None
        return True

    def record_step(self, sampled: np.ndarray, now_s: float) -> List[int]:
        """Fold one decode step's sampled tokens (n_slots,) back in.
        Returns slots freed this step."""
        freed = []
        for i, st in enumerate(self.slots):
            if st is None:
                continue
            st.pos += 1
            st.steps += 1
            st.cur_token = int(sampled[i])
            st.tokens.append(st.cur_token)
            if self._maybe_finish(i, now_s):
                freed.append(i)
        return freed

    # ------------------------------------------------- arrays for the device

    def batch_arrays(self) -> Tuple[np.ndarray, ...]:
        """(tokens, pos, active, temps, top_ks, n_sampled) dense over slots;
        inactive rows hold harmless values (token 0 at pos 0, masked in the
        model). n_sampled feeds the per-request PRNG stream index."""
        toks = np.zeros(self.n_slots, np.int32)
        pos = np.zeros(self.n_slots, np.int32)
        act = np.zeros(self.n_slots, bool)
        temps = np.zeros(self.n_slots, np.float32)
        top_ks = np.zeros(self.n_slots, np.int32)
        nsamp = np.zeros(self.n_slots, np.int32)
        for i, st in enumerate(self.slots):
            if st is None:
                continue
            toks[i] = st.cur_token
            pos[i] = st.pos + 1        # position the next token will occupy
            act[i] = True
            temps[i] = st.req.temperature
            top_ks[i] = st.req.top_k
            nsamp[i] = len(st.tokens)
        return toks, pos, act, temps, top_ks, nsamp

    def done(self) -> bool:
        return not self.queue and self.n_active == 0
