"""Slot scheduler for continuous batching: queue, admission, completion.

The scheduler is the host-side half of the serving engine. It owns the
request queue and a fixed table of `n_slots` decode slots; the device-side
half (engine.py) owns the slot-batched KV cache whose row i mirrors slot i
here. Admission is per-slot: whenever a slot frees (eos / length budget /
deadline), the next arrived request is prefillable into it mid-flight —
no barrier on the rest of the batch.

Admission order is EDF (earliest deadline first) over the *arrived* part of
the queue — requests without a deadline sort last, ties break by arrival
then submission order, so pure-FIFO workloads behave exactly as before.

For paged KV caches the scheduler also owns the `PageAllocator`: a
host-side free list over the device page pool. Admission reserves pages
for the prompt, decode grows a slot's page list lazily as its sequence
crosses page boundaries, and when the pool runs dry the lowest-priority
(then least-progress) slot is evicted — its pages return to the pool and
its request requeues for a fresh prefill (preemption by recompute).

All bookkeeping is numpy/python (one dict lookup per slot per step); the
dense per-slot arrays handed to the jitted decode step are assembled in
`batch_arrays` / `page_table`.
"""
from __future__ import annotations

import dataclasses
import itertools
from collections import deque
from typing import Dict, List, Optional, Tuple

import numpy as np

_UID = itertools.count()


@dataclasses.dataclass
class GenRequest:
    prompt: List[int]
    max_new: int = 32
    temperature: float = 0.0
    eos_id: Optional[int] = None
    top_k: int = 0                     # 0 = no truncation
    deadline_s: Optional[float] = None  # decode wall-clock budget, None = off
    arrival_s: float = 0.0             # offset from serve() start (Poisson)
    priority: int = 0                  # higher = evicted later under pressure
    uid: int = dataclasses.field(default_factory=lambda: next(_UID))


@dataclasses.dataclass
class GenResult:
    tokens: List[int]
    prefill_s: float = 0.0
    decode_s: float = 0.0
    steps: int = 0
    finish_reason: str = "length"      # length | eos | deadline
    done_s: float = 0.0                # completion time, offset from serve()
    evictions: int = 0                 # page-pressure preemptions (restarts)


@dataclasses.dataclass
class _Slot:
    req: GenRequest
    pos: int                           # position of the latest token
    cur_token: int                     # latest sampled token (next step input)
    tokens: List[int]
    started_s: float
    prefill_s: float
    steps: int = 0
    evictions: int = 0                 # times this request was preempted


class PageAllocator:
    """Host-side free list over the device KV page pool.

    Page ids index the per-layer `(n_pages + 1, page_size, ...)` pools of
    the paged CacheFormats (id `n_pages` is the device-side scratch page
    and is never handed out). Every slot owns a prefix-contiguous list of
    *logical* pages — entry j of a slot's list holds token positions
    [j*page_size, (j+1)*page_size) — mapped to arbitrary physical ids.

    Invariants (property-tested): the free list and the per-slot owned
    lists are always a disjoint partition of range(n_pages) — no page is
    leaked or double-owned across admit/grow/release churn.
    """

    def __init__(self, n_pages: int, page_size: int, n_slots: int,
                 max_pages_per_slot: int):
        assert n_pages >= 1 and page_size >= 1
        self.n_pages = n_pages
        self.page_size = page_size
        self.n_slots = n_slots
        self.max_pages_per_slot = max_pages_per_slot
        self.free: List[int] = list(range(n_pages))
        self.owned: List[List[int]] = [[] for _ in range(n_slots)]

    def pages_for(self, n_tokens: int) -> int:
        return -(-max(n_tokens, 0) // self.page_size)

    @property
    def available(self) -> int:
        return len(self.free)

    @property
    def in_use(self) -> int:
        return self.n_pages - len(self.free)

    def alloc(self, slot: int, n: int) -> bool:
        """Grow slot's page list by n pages; False (no change) if the free
        list cannot cover it or the slot would exceed max_pages_per_slot."""
        if n > len(self.free) or \
                len(self.owned[slot]) + n > self.max_pages_per_slot:
            return False
        for _ in range(n):
            self.owned[slot].append(self.free.pop())
        return True

    def ensure(self, slot: int, pos: int) -> bool:
        """Ensure the page holding token position `pos` is mapped."""
        need = pos // self.page_size + 1 - len(self.owned[slot])
        return True if need <= 0 else self.alloc(slot, need)

    def release(self, slot: int) -> int:
        """Return all of a slot's pages to the pool; returns the count."""
        n = len(self.owned[slot])
        self.free.extend(self.owned[slot])
        self.owned[slot] = []
        return n

    def table(self) -> np.ndarray:
        """(n_slots, max_pages_per_slot) int32 page table; -1 = unmapped."""
        t = np.full((self.n_slots, self.max_pages_per_slot), -1, np.int32)
        for i, pages in enumerate(self.owned):
            t[i, :len(pages)] = pages
        return t

    def check(self) -> None:
        """Assert the no-leak / no-double-own invariant."""
        seen = list(self.free)
        for pages in self.owned:
            seen.extend(pages)
        assert sorted(seen) == list(range(self.n_pages)), \
            (sorted(seen), self.n_pages)


class SlotScheduler:
    """Request queue + slot table; the engine drives it step by step.

    `alloc` (a PageAllocator) switches on paged-cache bookkeeping: EDF
    admission only hands out a request once its prompt's pages are
    reserved (evicting strictly-lower-priority slots to make room), and
    `grow_pages` extends each live slot's mapping ahead of every decode
    step.
    """

    def __init__(self, n_slots: int, max_len: int,
                 alloc: Optional[PageAllocator] = None):
        assert n_slots >= 1
        self.n_slots = n_slots
        self.max_len = max_len
        self.alloc = alloc
        self.queue: deque = deque()
        self.slots: List[Optional[_Slot]] = [None] * n_slots
        self.results: Dict[int, GenResult] = {}
        self.slot_reuses = 0           # admissions into a previously used slot
        self.evictions = 0             # page-pressure preemptions
        self._evicted: Dict[int, int] = {}   # uid -> times preempted
        self._used = [False] * n_slots

    # ------------------------------------------------------------ queue side

    def submit(self, req: GenRequest) -> None:
        assert len(req.prompt) >= 1, "empty prompt"
        assert len(req.prompt) < self.max_len, \
            f"prompt ({len(req.prompt)}) must fit the cache ({self.max_len})"
        if self.alloc is not None:
            # a request whose full trajectory cannot fit the pool would
            # evict-thrash forever; refuse it up front
            worst = min(len(req.prompt) + req.max_new, self.max_len)
            assert self.alloc.pages_for(worst) <= self.alloc.n_pages, \
                (f"request needs {self.alloc.pages_for(worst)} pages, pool "
                 f"holds {self.alloc.n_pages}")
        self.queue.append(req)

    @property
    def pending(self) -> int:
        return len(self.queue)

    @property
    def n_active(self) -> int:
        return sum(s is not None for s in self.slots)

    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    def _edf_order(self, now_s: float) -> List[int]:
        """Arrived-request indices in admission order (EDF): earliest
        deadline first, deadline-free requests last, ties FIFO by
        arrival then submission order."""
        arrived = [i for i, r in enumerate(self.queue)
                   if r.arrival_s <= now_s]
        return sorted(arrived, key=lambda i: (
            self.queue[i].deadline_s if self.queue[i].deadline_s is not None
            else float("inf"), self.queue[i].arrival_s, i))

    def _evictable_pages(self, below: int) -> int:
        """Pages reclaimable by evicting every active slot with priority
        strictly below `below`."""
        return sum(len(self.alloc.owned[i]) for i, st in
                   enumerate(self.slots)
                   if st is not None and st.req.priority < below)

    def next_ready(self, now_s: float,
                   slot: Optional[int] = None) -> Optional[GenRequest]:
        """Pop the next admittable request (EDF over arrived requests).

        With a PageAllocator, the pop also reserves the prompt's pages for
        `slot`, evicting strictly-lower-priority active slots when the
        free list falls short. A candidate whose pages cannot be covered
        even by eviction is skipped (stays queued) and the next EDF
        candidate is tried — a page-starved head must not block a
        higher-priority request that can make its own room.
        """
        for i in self._edf_order(now_s):
            req = self.queue[i]
            if self.alloc is not None:
                assert slot is not None, \
                    "paged admission needs the target slot"
                need = self.alloc.pages_for(len(req.prompt) + 1)
                if self.alloc.available + \
                        self._evictable_pages(req.priority) < need:
                    continue           # infeasible now; try next candidate
                while self.alloc.available < need:
                    victim = self._eviction_candidate(below=req.priority)
                    assert victim is not None   # feasibility checked above
                    self.evict(victim, now_s)
                if not self.alloc.alloc(slot, need):
                    continue           # per-slot page cap; try next
            del self.queue[i]
            return req
        return None

    def next_arrival(self) -> Optional[float]:
        return min(r.arrival_s for r in self.queue) if self.queue else None

    # ------------------------------------------------------------- slot side

    def admit(self, slot: int, req: GenRequest, first_token: int,
              now_s: float, prefill_s: float) -> bool:
        """Bind req to slot with its prefill-sampled first token.
        Returns True if the request finished immediately (it still occupied
        the slot for zero decode steps)."""
        assert self.slots[slot] is None
        if self._used[slot]:
            self.slot_reuses += 1
        self._used[slot] = True
        st = _Slot(req=req, pos=len(req.prompt) - 1, cur_token=first_token,
                   tokens=[first_token], started_s=now_s, prefill_s=prefill_s,
                   evictions=self._evicted.get(req.uid, 0))
        self.slots[slot] = st
        return self._maybe_finish(slot, now_s)

    # ------------------------------------------------------ paged eviction

    def _eviction_candidate(self, below: Optional[int] = None
                            ) -> Optional[int]:
        """Active slot to preempt: lowest priority, then least decode
        progress (least recompute wasted). `below` restricts to slots with
        priority strictly below it (admission never evicts peers)."""
        best, best_key = None, None
        for i, st in enumerate(self.slots):
            if st is None:
                continue
            if below is not None and st.req.priority >= below:
                continue
            key = (st.req.priority, len(st.tokens))
            if best_key is None or key < best_key:
                best, best_key = i, key
        return best

    def evict(self, slot: int, now_s: float) -> None:
        """Preempt a slot: release its pages and requeue its request for a
        fresh prefill (preemption by recompute — generated tokens are
        discarded and regenerated after re-admission; greedy and seeded
        sampling replay identically because PRNG streams key on the
        submission index)."""
        st = self.slots[slot]
        assert st is not None
        if self.alloc is not None:
            self.alloc.release(slot)
        self.slots[slot] = None
        self.evictions += 1
        self._evicted[st.req.uid] = self._evicted.get(st.req.uid, 0) + 1
        self.queue.append(st.req)

    def grow_pages(self, now_s: float) -> None:
        """Map the page each active slot's next token will land on,
        processing high-priority slots first and evicting under pressure
        (a slot that is itself the lowest-priority one self-evicts)."""
        if self.alloc is None:
            return
        order = sorted((i for i, st in enumerate(self.slots)
                        if st is not None),
                       key=lambda i: -self.slots[i].req.priority)
        for i in order:
            st = self.slots[i]
            if st is None:              # evicted by an earlier iteration
                continue
            while not self.alloc.ensure(i, st.pos + 1):
                victim = self._eviction_candidate()
                assert victim is not None, "no active slot to evict"
                self.evict(victim, now_s)
                if victim == i:
                    break

    def _maybe_finish(self, slot: int, now_s: float) -> bool:
        st = self.slots[slot]
        reason = None
        if st.req.eos_id is not None and st.tokens[-1] == st.req.eos_id:
            reason = "eos"
        elif len(st.tokens) >= st.req.max_new:
            reason = "length"
        elif st.pos + 2 >= self.max_len:   # next token would overflow cache
            reason = "length"
        elif (st.req.deadline_s is not None
                and now_s - st.started_s > st.req.deadline_s):
            reason = "deadline"
        if reason is None:
            return False
        self.results[st.req.uid] = GenResult(
            tokens=st.tokens, prefill_s=st.prefill_s,
            decode_s=now_s - st.started_s, steps=st.steps,
            finish_reason=reason, done_s=now_s, evictions=st.evictions)
        if self.alloc is not None:
            self.alloc.release(slot)
        self.slots[slot] = None
        return True

    def record_step(self, sampled: np.ndarray, now_s: float) -> List[int]:
        """Fold one decode step's sampled tokens (n_slots,) back in.
        Returns slots freed this step."""
        freed = []
        for i, st in enumerate(self.slots):
            if st is None:
                continue
            st.pos += 1
            st.steps += 1
            st.cur_token = int(sampled[i])
            st.tokens.append(st.cur_token)
            if self._maybe_finish(i, now_s):
                freed.append(i)
        return freed

    # ------------------------------------------------- arrays for the device

    def batch_arrays(self) -> Tuple[np.ndarray, ...]:
        """(tokens, pos, active, temps, top_ks, n_sampled) dense over slots;
        inactive rows hold harmless values (token 0 at pos 0, masked in the
        model). n_sampled feeds the per-request PRNG stream index."""
        toks = np.zeros(self.n_slots, np.int32)
        pos = np.zeros(self.n_slots, np.int32)
        act = np.zeros(self.n_slots, bool)
        temps = np.zeros(self.n_slots, np.float32)
        top_ks = np.zeros(self.n_slots, np.int32)
        nsamp = np.zeros(self.n_slots, np.int32)
        for i, st in enumerate(self.slots):
            if st is None:
                continue
            toks[i] = st.cur_token
            pos[i] = st.pos + 1        # position the next token will occupy
            act[i] = True
            temps[i] = st.req.temperature
            top_ks[i] = st.req.top_k
            nsamp[i] = len(st.tokens)
        return toks, pos, act, temps, top_ks, nsamp

    def page_table(self) -> Optional[np.ndarray]:
        """(n_slots, max_pages) int32 device page table (None if unpaged)."""
        return None if self.alloc is None else self.alloc.table()

    def done(self) -> bool:
        return not self.queue and self.n_active == 0
