"""Continuous-batching serving subsystem (scheduler / sampler / engine)."""
from .engine import ServeEngine
from .sampler import sample_token, sample_tokens
from .scheduler import GenRequest, GenResult, PageAllocator, SlotScheduler
