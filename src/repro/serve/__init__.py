"""Continuous-batching serving subsystem.

scheduler / sampler / engine: the token-budget serving core.
metrics: TTFT/ITL percentiles, SLO goodput, achieved-vs-peak MFU/HBM
    tracking, load-adaptive draft policy.
faults: deterministic chaos injection + the fault-tolerance knobs
    (watchdog retry, slot quarantine/requeue, shedding, timeouts).
frontend: asyncio SSE streaming server over the reentrant session API.
"""
from .engine import ServeEngine, ServeSession
from .faults import ServeFaultInjector, StepFault, chaos_injector
from .frontend import AsyncServeFrontend
from .metrics import (SLO, AdaptiveDraftPolicy, DeviceSpec, DEVICE_DB,
                      StepTracker, goodput_report, latency_summary,
                      percentile, prefix_cache_report, resolve_device)
from .sampler import sample_token, sample_tokens
from .scheduler import (GenRequest, GenResult, PageAllocator, PrefixCache,
                        PrefixHasher, SlotScheduler, TokenEvent)
