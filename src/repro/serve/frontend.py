"""Async streaming serve front end: raw-asyncio HTTP/1.1 with SSE tokens.

The production rim around the continuous-batching engine. Request
handlers never touch the scheduler directly — the `SlotScheduler` (and
everything jitted behind it) is single-threaded by design, so the front
end marshals work through one background *driver thread* that pumps the
reentrant `ServeSession` (engine.start()/step()):

  asyncio handler --(deque + lock)--> driver thread --submit()--> session
  asyncio handler <--(asyncio.Queue)<-- loop.call_soon_threadsafe <-- step()

Each `step()` call returns the `TokenEvent`s it produced; the driver
relays every event to the owning request's `asyncio.Queue`, and the
handler turns the queue into a Server-Sent-Events stream. Greedy streams
are token-identical to `ServeEngine.serve()` on the same seed: both are
thin drivers over the same session control flow, and PRNG streams key on
submission index either way.

Robustness contract (the driver thread is shared — nothing a single
client does may take it down or stall it):

  * malformed input is rejected with a 400 + JSON body BEFORE anything
    reaches the driver (`_parse_request`); a request the scheduler still
    refuses fails only itself (terminal `error` frame).
  * per-request SSE queues are bounded (`sse_queue_max`): a slow client
    whose socket backs up first buffers, then is disconnected and its
    request cancelled mid-flight — slot and KV pages free immediately.
  * a client that goes away (EOF / reset on its socket) has its request
    cancelled the same way instead of generating into the void.
  * overload: when the arrived queue exceeds the session's `queue_cap`,
    new POSTs get a fast 503 (and the scheduler sheds anything that
    slips past the race); the AdaptiveDraftPolicy's low-bit draft
    rounds sit BELOW the cap, so precision degrades before admission
    does.
  * `stop()` drains by default: new work gets 503, in-flight streams
    finish, then the driver halts.

No HTTP library is assumed (stdlib only): the server speaks just enough
HTTP/1.1 for POST-with-Content-Length and close-delimited responses.

Endpoints
  POST /v1/generate   body {"prompt": [int,...], "max_new": int,
                      "temperature": float, "top_k": int, "eos_id": int?,
                      "deadline_s": float?, "timeout_s": float?,
                      "priority": int?}
                      -> text/event-stream; one `data: {...}` frame per
                      token {token, index, t_s}, then a terminal frame
                      {done: true, finish_reason, n_tokens, ttft_s}.
                      If page pressure evicts a request mid-flight, its
                      replay re-streams from index 0 (at-least-once token
                      delivery; the terminal frame carries the final
                      sequence length).
                      400 {"error": ...} on malformed input, 503 when
                      draining or overloaded.
  GET  /v1/metrics    -> JSON {engine: <session stats incl. hw tracker
                      and fault counters>, latency: TTFT/ITL/E2E
                      percentiles, goodput: SLO attainment, frontend:
                      request/disconnect/reject counters, prefix_cache:
                      hit/share/COW figures when prefix caching is on}.
  GET  /healthz       -> {"ok": true}
"""
from __future__ import annotations

import asyncio
import json
import threading
from typing import Dict, List, Optional, Tuple

from .engine import ServeEngine, ServeSession
from .metrics import (SLO, goodput_report, latency_summary,
                      prefix_cache_report)
from .scheduler import GenRequest, TokenEvent

__all__ = ["AsyncServeFrontend", "sse_generate", "fetch_json", "post_json"]

_REQ_FIELDS = ("max_new", "temperature", "top_k", "eos_id", "deadline_s",
               "timeout_s", "priority")
_INT_FIELDS = ("max_new", "top_k", "eos_id", "priority")


class AsyncServeFrontend:
    """Asyncio SSE server + driver thread over one `ServeSession`.

    `port=0` binds an ephemeral port (read `self.port` after `start()`).
    `track` / `slo` feed the observability side: the per-step MFU/HBM
    tracker and the goodput report of GET /v1/metrics.

    `sse_queue_max` bounds each request's event queue (the slow-client
    disconnect threshold); `queue_cap` bounds the arrived request queue
    (503 + scheduler shedding past it); `timeout_s` is a default
    per-request wall-clock cap applied to requests that don't set their
    own. `faults` threads a ServeFaultInjector into the session for
    chaos runs."""

    def __init__(self, engine: ServeEngine, host: str = "127.0.0.1",
                 port: int = 0, seed: int = 0, slo: Optional[SLO] = None,
                 track=None, poll_s: float = 0.01,
                 sse_queue_max: int = 256,
                 queue_cap: Optional[int] = None,
                 timeout_s: Optional[float] = None,
                 drain_timeout_s: float = 30.0, faults=None):
        self.engine = engine
        self.host = host
        self.port = port
        self.seed = seed
        self.slo = slo or SLO()
        self.track = track
        self.poll_s = poll_s
        self.sse_queue_max = sse_queue_max
        self.queue_cap = queue_cap
        self.timeout_s = timeout_s
        self.drain_timeout_s = drain_timeout_s
        self.faults = faults
        self.session: Optional[ServeSession] = None
        self._server: Optional[asyncio.AbstractServer] = None
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._driver: Optional[threading.Thread] = None
        self._lock = threading.Lock()
        self._pending: List[Tuple[GenRequest, asyncio.Queue]] = []
        self._cancels: List[int] = []          # uids, handler -> driver
        self._streams: Dict[int, asyncio.Queue] = {}
        self._transports: Dict[int, object] = {}
        self._dropped: set = set()             # uids force-dropped (slow)
        self._draining = False
        self._stop = threading.Event()
        self._wake = threading.Event()
        self.counters: Dict[str, int] = {
            "requests": 0, "rejected_400": 0, "rejected_503": 0,
            "client_disconnects": 0, "slow_client_disconnects": 0,
            "submit_rejects": 0, "driver_errors": 0}

    # ---------------------------------------------------------- lifecycle

    async def start(self) -> None:
        self._loop = asyncio.get_running_loop()
        # session construction compiles the cost models when tracking —
        # do it before accepting traffic so TTFT isn't charged for it
        self.session = self.engine.start(seed=self.seed, track=self.track,
                                         faults=self.faults,
                                         queue_cap=self.queue_cap)
        self._server = await asyncio.start_server(self._handle, self.host,
                                                  self.port)
        self.port = self._server.sockets[0].getsockname()[1]
        self._driver = threading.Thread(target=self._drive, daemon=True,
                                        name="serve-driver")
        self._driver.start()

    async def stop(self, drain: bool = True,
                   drain_timeout_s: Optional[float] = None) -> None:
        """Graceful by default: stop admitting (new POSTs get 503), let
        every in-flight request finish streaming (bounded by
        `drain_timeout_s`), then halt the driver and close the server.
        `drain=False` tears down immediately."""
        self._draining = True
        if drain and self.session is not None:
            tmo = self.drain_timeout_s if drain_timeout_s is None \
                else drain_timeout_s
            t0 = self._loop.time()
            while self._loop.time() - t0 < tmo:
                with self._lock:
                    busy = bool(self._pending) or bool(self._cancels)
                if not busy and not self._streams and self.session.done():
                    break
                await asyncio.sleep(self.poll_s)
        self._stop.set()
        self._wake.set()
        if self._driver is not None:
            await self._loop.run_in_executor(None, self._driver.join)
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()

    async def __aenter__(self) -> "AsyncServeFrontend":
        await self.start()
        return self

    async def __aexit__(self, *exc) -> None:
        await self.stop()

    # ---------------------------------------------------- driver thread

    def _drive(self) -> None:
        """The ONLY thread that touches the session/scheduler: drain
        marshalled cancels and submissions, pump one step, relay its
        events into the owning asyncio queues (thread-safely, via the
        loop). One bad request — or one failed step — fails itself,
        never this thread."""
        sess = self.session
        while not self._stop.is_set():
            with self._lock:
                pending, self._pending = self._pending, []
                cancels, self._cancels = self._cancels, []
            for uid in cancels:
                sess.cancel(uid)
            for req, q in pending:
                self._streams[req.uid] = q
                try:
                    sess.submit(req, at=sess.now())
                except Exception:
                    # the handler validates, but the scheduler has the
                    # last word (e.g. page-pool infeasibility): fail the
                    # one request with a terminal frame
                    self._streams.pop(req.uid, None)
                    self.counters["submit_rejects"] += 1
                    ev = TokenEvent(req.uid, -1, sess.now(), 0, done=True,
                                    finish_reason="error")
                    self._loop.call_soon_threadsafe(q.put_nowait, ev)
            if not pending and not cancels and sess.done():
                self._publish(sess.sched.take_events())  # stragglers
                self._wake.wait(self.poll_s)
                self._wake.clear()
                continue
            try:
                self._publish(sess.step())
            except Exception:       # step()'s watchdog absorbed retries;
                self.counters["driver_errors"] += 1     # keep pumping
            self._publish(sess.sched.take_events())     # valve events

    def _publish(self, events) -> None:
        for ev in events:
            q = self._streams.get(ev.uid)
            if q is None:
                continue
            if ev.done:
                del self._streams[ev.uid]
            elif q.qsize() >= self.sse_queue_max:
                # slow client: its handler is not draining (socket backed
                # up). Backpressure has already buffered sse_queue_max
                # events; now disconnect it and cancel the request so the
                # slot and its pages serve someone who is listening.
                self.counters["slow_client_disconnects"] += 1
                self._dropped.add(ev.uid)
                del self._streams[ev.uid]
                self.session.cancel(ev.uid)   # we ARE the driver thread
                tr = self._transports.get(ev.uid)
                if tr is not None:
                    self._loop.call_soon_threadsafe(tr.abort)
                continue
            self._loop.call_soon_threadsafe(q.put_nowait, ev)

    # ------------------------------------------------------ http plumbing

    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        try:
            head = await reader.readuntil(b"\r\n\r\n")
        except (asyncio.IncompleteReadError, asyncio.LimitOverrunError):
            writer.close()
            return
        try:
            request_line, *header_lines = head.decode("latin-1").split("\r\n")
            method, path, _ = request_line.split(" ", 2)
            headers = {}
            for line in header_lines:
                if ":" in line:
                    k, v = line.split(":", 1)
                    headers[k.strip().lower()] = v.strip()
            body = b""
            clen = int(headers.get("content-length", 0))
            if clen:
                body = await reader.readexactly(clen)
            if method == "POST" and path == "/v1/generate":
                await self._generate(reader, writer, body)
            elif method == "GET" and path == "/v1/metrics":
                await self._json(writer, self.metrics())
            elif method == "GET" and path == "/healthz":
                await self._json(writer, {"ok": True})
            else:
                await self._json(writer, {"error": f"no route {method} "
                                          f"{path}"}, status="404 Not Found")
        except Exception as e:                       # malformed protocol
            try:
                await self._json(writer, {"error": str(e)},
                                 status="400 Bad Request")
            except Exception:
                pass
        finally:
            try:
                writer.close()
                await writer.wait_closed()
            except Exception:
                pass

    def _parse_request(self, body: bytes) -> GenRequest:
        """Strict request validation — every ValueError here becomes a
        400 with a JSON body, and nothing invalid ever reaches the
        shared driver thread."""
        try:
            payload = json.loads(body.decode("utf-8"))
        except (UnicodeDecodeError, ValueError) as e:
            raise ValueError(f"body is not valid JSON: {e}")
        if not isinstance(payload, dict):
            raise ValueError("body must be a JSON object")
        unknown = set(payload) - set(_REQ_FIELDS) - {"prompt"}
        if unknown:
            raise ValueError(f"unknown fields: {sorted(unknown)}")
        prompt = payload.get("prompt")
        if not isinstance(prompt, list) or not prompt:
            raise ValueError("'prompt' must be a non-empty list of "
                             "token ids")
        try:
            prompt = [int(t) for t in prompt]
        except (TypeError, ValueError):
            raise ValueError("'prompt' tokens must be integers")
        vocab = self.engine.cfg.vocab_size
        if any(t < 0 or t >= vocab for t in prompt):
            raise ValueError(f"prompt token ids must be in [0, {vocab})")
        if len(prompt) >= self.engine.max_len:
            raise ValueError(f"prompt length {len(prompt)} must be < "
                             f"max_len ({self.engine.max_len})")
        kwargs = {}
        for k in _REQ_FIELDS:
            v = payload.get(k)
            if v is None:
                continue
            try:
                kwargs[k] = int(v) if k in _INT_FIELDS else float(v)
            except (TypeError, ValueError):
                raise ValueError(f"'{k}' must be a number")
        if kwargs.get("max_new", 1) < 1:
            raise ValueError("'max_new' must be >= 1")
        if kwargs.get("temperature", 0.0) < 0:
            raise ValueError("'temperature' must be >= 0")
        for k in ("deadline_s", "timeout_s"):
            if k in kwargs and kwargs[k] <= 0:
                raise ValueError(f"'{k}' must be > 0")
        if self.timeout_s is not None:
            kwargs.setdefault("timeout_s", self.timeout_s)
        return GenRequest(prompt=prompt, **kwargs)

    async def _generate(self, reader: asyncio.StreamReader,
                        writer: asyncio.StreamWriter, body: bytes) -> None:
        try:
            req = self._parse_request(body)
        except ValueError as e:
            self.counters["rejected_400"] += 1
            await self._json(writer, {"error": str(e)},
                             status="400 Bad Request")
            return
        if self._draining:
            self.counters["rejected_503"] += 1
            await self._json(writer, {"error": "draining"},
                             status="503 Service Unavailable")
            return
        if self.queue_cap is not None:
            depth, _ = self.session.sched.queue_pressure(self.session.now())
            if depth >= self.queue_cap:
                # fast-path shed: don't even marshal it (anything racing
                # past this check is shed by the scheduler's own valve)
                self.counters["rejected_503"] += 1
                await self._json(writer, {"error": "overloaded",
                                          "queue_depth": depth},
                                 status="503 Service Unavailable")
                return
        self.counters["requests"] += 1
        q: asyncio.Queue = asyncio.Queue()
        self._transports[req.uid] = writer.transport
        with self._lock:
            self._pending.append((req, q))
        self._wake.set()

        writer.write(b"HTTP/1.1 200 OK\r\n"
                     b"Content-Type: text/event-stream\r\n"
                     b"Cache-Control: no-cache\r\n"
                     b"Connection: close\r\n\r\n")
        await writer.drain()
        # half-open watcher: an SSE client sends nothing after its POST
        # body, so ANY completion of this read (EOF included) means the
        # client went away — cancel its request instead of generating
        # into the void
        eof = asyncio.ensure_future(reader.read(1))
        try:
            while True:
                getter = asyncio.ensure_future(q.get())
                done, _ = await asyncio.wait(
                    {getter, eof}, return_when=asyncio.FIRST_COMPLETED)
                if getter not in done:
                    getter.cancel()
                    self._client_gone(req.uid)
                    return
                ev = getter.result()
                if ev.done:
                    res = self.session.results.get(req.uid)
                    frame = {"done": True,
                             "finish_reason": ev.finish_reason,
                             "n_tokens": len(res.tokens) if res else 0,
                             "ttft_s": res.prefill_s if res else 0.0,
                             "t_s": ev.t_s}
                else:
                    frame = {"token": ev.token, "index": ev.index,
                             "t_s": ev.t_s}
                writer.write(b"data: " + json.dumps(frame).encode("utf-8")
                             + b"\n\n")
                await writer.drain()
                if ev.done:
                    return
        except ConnectionError:
            self._client_gone(req.uid)
        finally:
            eof.cancel()
            self._transports.pop(req.uid, None)

    def _client_gone(self, uid: int) -> None:
        """The stream's client vanished mid-flight: marshal a cancel to
        the driver so the slot and its pages free. No-op for a uid the
        slow-client policy already dropped (that cancel happened on the
        driver thread itself)."""
        if uid in self._dropped:
            return
        self.counters["client_disconnects"] += 1
        with self._lock:
            self._cancels.append(uid)
        self._wake.set()

    async def _json(self, writer: asyncio.StreamWriter, obj,
                    status: str = "200 OK") -> None:
        data = json.dumps(obj).encode("utf-8")
        writer.write(f"HTTP/1.1 {status}\r\n"
                     f"Content-Type: application/json\r\n"
                     f"Content-Length: {len(data)}\r\n"
                     f"Connection: close\r\n\r\n".encode("latin-1") + data)
        await writer.drain()

    # ----------------------------------------------------- observability

    def metrics(self) -> Dict[str, object]:
        """Serving stats + latency percentiles + SLO goodput, over every
        request finished so far (engine block includes the hw tracker's
        achieved-vs-peak summary when tracking is on, and the fault
        counter block always), plus the frontend's own counters."""
        sess = self.session
        results = list(sess.results.values())
        engine = sess.stats()
        out = {
            "engine": engine,
            "latency": latency_summary(results),
            "goodput": goodput_report(results, self.slo,
                                      wall_s=sess.now()),
            "frontend": {**self.counters,
                         "sse_queue_max": self.sse_queue_max,
                         "queue_cap": self.queue_cap,
                         "draining": self._draining,
                         "open_streams": len(self._streams)},
        }
        pc = prefix_cache_report(engine)
        if pc is not None:              # derived hit/share/COW figures
            out["prefix_cache"] = pc
        return out


# ------------------------------------------------------------ test client

async def sse_generate(host: str, port: int, payload: Dict) -> List[Dict]:
    """Minimal SSE client: POST /v1/generate, parse every `data:` frame
    until the terminal one; returns the frame dicts in stream order."""
    reader, writer = await asyncio.open_connection(host, port)
    body = json.dumps(payload).encode("utf-8")
    writer.write(f"POST /v1/generate HTTP/1.1\r\n"
                 f"Host: {host}\r\n"
                 f"Content-Type: application/json\r\n"
                 f"Content-Length: {len(body)}\r\n"
                 f"Connection: close\r\n\r\n".encode("latin-1") + body)
    await writer.drain()
    await reader.readuntil(b"\r\n\r\n")             # response headers
    frames: List[Dict] = []
    while True:
        line = await reader.readline()
        if not line:
            break
        line = line.strip()
        if not line.startswith(b"data: "):
            continue
        frame = json.loads(line[6:].decode("utf-8"))
        frames.append(frame)
        if frame.get("done"):
            break
    writer.close()
    try:
        await writer.wait_closed()
    except Exception:
        pass
    return frames


async def post_json(host: str, port: int, path: str,
                    payload) -> Tuple[int, Dict]:
    """POST JSON (a dict) or raw bytes; returns (status_code, body dict)
    — the error-path twin of `sse_generate` for 400/503 responses."""
    reader, writer = await asyncio.open_connection(host, port)
    body = payload if isinstance(payload, (bytes, bytearray)) \
        else json.dumps(payload).encode("utf-8")
    writer.write(f"POST {path} HTTP/1.1\r\nHost: {host}\r\n"
                 f"Content-Type: application/json\r\n"
                 f"Content-Length: {len(body)}\r\n"
                 f"Connection: close\r\n\r\n".encode("latin-1") + bytes(body))
    await writer.drain()
    head = await reader.readuntil(b"\r\n\r\n")
    status = int(head.split(b" ", 2)[1])
    clen = None
    for line in head.decode("latin-1").split("\r\n"):
        if line.lower().startswith("content-length:"):
            clen = int(line.split(":", 1)[1])
    data = await (reader.readexactly(clen) if clen is not None
                  else reader.read())
    writer.close()
    try:
        await writer.wait_closed()
    except Exception:
        pass
    return status, (json.loads(data.decode("utf-8")) if data else {})


async def fetch_json(host: str, port: int, path: str) -> Dict:
    """GET a JSON endpoint (close-delimited or Content-Length body)."""
    reader, writer = await asyncio.open_connection(host, port)
    writer.write(f"GET {path} HTTP/1.1\r\nHost: {host}\r\n"
                 f"Connection: close\r\n\r\n".encode("latin-1"))
    await writer.drain()
    head = await reader.readuntil(b"\r\n\r\n")
    clen = None
    for line in head.decode("latin-1").split("\r\n"):
        if line.lower().startswith("content-length:"):
            clen = int(line.split(":", 1)[1])
    body = await (reader.readexactly(clen) if clen is not None
                  else reader.read())
    writer.close()
    try:
        await writer.wait_closed()
    except Exception:
        pass
    return json.loads(body.decode("utf-8"))
