"""Async streaming serve front end: raw-asyncio HTTP/1.1 with SSE tokens.

The production rim around the continuous-batching engine. Request
handlers never touch the scheduler directly — the `SlotScheduler` (and
everything jitted behind it) is single-threaded by design, so the front
end marshals work through one background *driver thread* that pumps the
reentrant `ServeSession` (engine.start()/step()):

  asyncio handler --(deque + lock)--> driver thread --submit()--> session
  asyncio handler <--(asyncio.Queue)<-- loop.call_soon_threadsafe <-- step()

Each `step()` call returns the `TokenEvent`s it produced; the driver
relays every event to the owning request's `asyncio.Queue`, and the
handler turns the queue into a Server-Sent-Events stream. Greedy streams
are token-identical to `ServeEngine.serve()` on the same seed: both are
thin drivers over the same session control flow, and PRNG streams key on
submission index either way.

No HTTP library is assumed (stdlib only): the server speaks just enough
HTTP/1.1 for POST-with-Content-Length and close-delimited responses.

Endpoints
  POST /v1/generate   body {"prompt": [int,...], "max_new": int,
                      "temperature": float, "top_k": int, "eos_id": int?,
                      "deadline_s": float?, "priority": int?}
                      -> text/event-stream; one `data: {...}` frame per
                      token {token, index, t_s}, then a terminal frame
                      {done: true, finish_reason, n_tokens, ttft_s}.
                      If page pressure evicts a request mid-flight, its
                      replay re-streams from index 0 (at-least-once token
                      delivery; the terminal frame carries the final
                      sequence length).
  GET  /v1/metrics    -> JSON {engine: <session stats incl. hw tracker>,
                      latency: TTFT/ITL/E2E percentiles, goodput: SLO
                      attainment} over all finished requests so far.
  GET  /healthz       -> {"ok": true}
"""
from __future__ import annotations

import asyncio
import json
import threading
from typing import Dict, List, Optional, Tuple

from .engine import ServeEngine, ServeSession
from .metrics import SLO, goodput_report, latency_summary
from .scheduler import GenRequest

__all__ = ["AsyncServeFrontend", "sse_generate", "fetch_json"]

_REQ_FIELDS = ("max_new", "temperature", "top_k", "eos_id", "deadline_s",
               "priority")


class AsyncServeFrontend:
    """Asyncio SSE server + driver thread over one `ServeSession`.

    `port=0` binds an ephemeral port (read `self.port` after `start()`).
    `track` / `slo` feed the observability side: the per-step MFU/HBM
    tracker and the goodput report of GET /v1/metrics."""

    def __init__(self, engine: ServeEngine, host: str = "127.0.0.1",
                 port: int = 0, seed: int = 0, slo: Optional[SLO] = None,
                 track=None, poll_s: float = 0.01):
        self.engine = engine
        self.host = host
        self.port = port
        self.seed = seed
        self.slo = slo or SLO()
        self.track = track
        self.poll_s = poll_s
        self.session: Optional[ServeSession] = None
        self._server: Optional[asyncio.AbstractServer] = None
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._driver: Optional[threading.Thread] = None
        self._lock = threading.Lock()
        self._pending: List[Tuple[GenRequest, asyncio.Queue]] = []
        self._streams: Dict[int, asyncio.Queue] = {}
        self._stop = threading.Event()
        self._wake = threading.Event()

    # ---------------------------------------------------------- lifecycle

    async def start(self) -> None:
        self._loop = asyncio.get_running_loop()
        # session construction compiles the cost models when tracking —
        # do it before accepting traffic so TTFT isn't charged for it
        self.session = self.engine.start(seed=self.seed, track=self.track)
        self._server = await asyncio.start_server(self._handle, self.host,
                                                  self.port)
        self.port = self._server.sockets[0].getsockname()[1]
        self._driver = threading.Thread(target=self._drive, daemon=True,
                                        name="serve-driver")
        self._driver.start()

    async def stop(self) -> None:
        self._stop.set()
        self._wake.set()
        if self._driver is not None:
            await self._loop.run_in_executor(None, self._driver.join)
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()

    async def __aenter__(self) -> "AsyncServeFrontend":
        await self.start()
        return self

    async def __aexit__(self, *exc) -> None:
        await self.stop()

    # ---------------------------------------------------- driver thread

    def _drive(self) -> None:
        """The ONLY thread that touches the session/scheduler: drain
        marshalled submissions, pump one step, relay its events into the
        owning asyncio queues (thread-safely, via the loop)."""
        sess = self.session
        while not self._stop.is_set():
            with self._lock:
                pending, self._pending = self._pending, []
            for req, q in pending:
                self._streams[req.uid] = q
                sess.submit(req, at=sess.now())
            if not pending and sess.done():
                self._publish(sess.sched.take_events())  # stragglers
                self._wake.wait(self.poll_s)
                self._wake.clear()
                continue
            self._publish(sess.step())

    def _publish(self, events) -> None:
        for ev in events:
            q = self._streams.get(ev.uid)
            if q is None:
                continue
            if ev.done:
                del self._streams[ev.uid]
            self._loop.call_soon_threadsafe(q.put_nowait, ev)

    # ------------------------------------------------------ http plumbing

    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        try:
            head = await reader.readuntil(b"\r\n\r\n")
        except (asyncio.IncompleteReadError, asyncio.LimitOverrunError):
            writer.close()
            return
        try:
            request_line, *header_lines = head.decode("latin-1").split("\r\n")
            method, path, _ = request_line.split(" ", 2)
            headers = {}
            for line in header_lines:
                if ":" in line:
                    k, v = line.split(":", 1)
                    headers[k.strip().lower()] = v.strip()
            body = b""
            clen = int(headers.get("content-length", 0))
            if clen:
                body = await reader.readexactly(clen)
            if method == "POST" and path == "/v1/generate":
                await self._generate(writer, body)
            elif method == "GET" and path == "/v1/metrics":
                await self._json(writer, self.metrics())
            elif method == "GET" and path == "/healthz":
                await self._json(writer, {"ok": True})
            else:
                await self._json(writer, {"error": f"no route {method} "
                                          f"{path}"}, status="404 Not Found")
        except Exception as e:                       # malformed request
            try:
                await self._json(writer, {"error": str(e)},
                                 status="400 Bad Request")
            except Exception:
                pass
        finally:
            try:
                writer.close()
                await writer.wait_closed()
            except Exception:
                pass

    async def _generate(self, writer: asyncio.StreamWriter,
                        body: bytes) -> None:
        payload = json.loads(body.decode("utf-8"))
        prompt = [int(t) for t in payload["prompt"]]
        kwargs = {k: payload[k] for k in _REQ_FIELDS if payload.get(k)
                  is not None}
        req = GenRequest(prompt=prompt, **kwargs)
        q: asyncio.Queue = asyncio.Queue()
        with self._lock:
            self._pending.append((req, q))
        self._wake.set()

        writer.write(b"HTTP/1.1 200 OK\r\n"
                     b"Content-Type: text/event-stream\r\n"
                     b"Cache-Control: no-cache\r\n"
                     b"Connection: close\r\n\r\n")
        await writer.drain()
        while True:
            ev = await q.get()
            if ev.done:
                res = self.session.results[req.uid]
                frame = {"done": True, "finish_reason": ev.finish_reason,
                         "n_tokens": len(res.tokens),
                         "ttft_s": res.prefill_s, "t_s": ev.t_s}
            else:
                frame = {"token": ev.token, "index": ev.index,
                         "t_s": ev.t_s}
            writer.write(b"data: " + json.dumps(frame).encode("utf-8")
                         + b"\n\n")
            await writer.drain()
            if ev.done:
                return

    async def _json(self, writer: asyncio.StreamWriter, obj,
                    status: str = "200 OK") -> None:
        data = json.dumps(obj).encode("utf-8")
        writer.write(f"HTTP/1.1 {status}\r\n"
                     f"Content-Type: application/json\r\n"
                     f"Content-Length: {len(data)}\r\n"
                     f"Connection: close\r\n\r\n".encode("latin-1") + data)
        await writer.drain()

    # ----------------------------------------------------- observability

    def metrics(self) -> Dict[str, object]:
        """Serving stats + latency percentiles + SLO goodput, over every
        request finished so far (engine block includes the hw tracker's
        achieved-vs-peak summary when tracking is on)."""
        sess = self.session
        results = list(sess.results.values())
        return {
            "engine": sess.stats(),
            "latency": latency_summary(results),
            "goodput": goodput_report(results, self.slo,
                                      wall_s=sess.now()),
        }


# ------------------------------------------------------------ test client

async def sse_generate(host: str, port: int, payload: Dict) -> List[Dict]:
    """Minimal SSE client: POST /v1/generate, parse every `data:` frame
    until the terminal one; returns the frame dicts in stream order."""
    reader, writer = await asyncio.open_connection(host, port)
    body = json.dumps(payload).encode("utf-8")
    writer.write(f"POST /v1/generate HTTP/1.1\r\n"
                 f"Host: {host}\r\n"
                 f"Content-Type: application/json\r\n"
                 f"Content-Length: {len(body)}\r\n"
                 f"Connection: close\r\n\r\n".encode("latin-1") + body)
    await writer.drain()
    await reader.readuntil(b"\r\n\r\n")             # response headers
    frames: List[Dict] = []
    while True:
        line = await reader.readline()
        if not line:
            break
        line = line.strip()
        if not line.startswith(b"data: "):
            continue
        frame = json.loads(line[6:].decode("utf-8"))
        frames.append(frame)
        if frame.get("done"):
            break
    writer.close()
    try:
        await writer.wait_closed()
    except Exception:
        pass
    return frames


async def fetch_json(host: str, port: int, path: str) -> Dict:
    """GET a JSON endpoint (close-delimited or Content-Length body)."""
    reader, writer = await asyncio.open_connection(host, port)
    writer.write(f"GET {path} HTTP/1.1\r\nHost: {host}\r\n"
                 f"Connection: close\r\n\r\n".encode("latin-1"))
    await writer.drain()
    head = await reader.readuntil(b"\r\n\r\n")
    clen = None
    for line in head.decode("latin-1").split("\r\n"):
        if line.lower().startswith("content-length:"):
            clen = int(line.split(":", 1)[1])
    body = await (reader.readexactly(clen) if clen is not None
                  else reader.read())
    writer.close()
    try:
        await writer.wait_closed()
    except Exception:
        pass
    return json.loads(body.decode("utf-8"))
