"""Serving fault tolerance: deterministic chaos injection for the engine.

The serve-side mirror of `train/fault.py`: in a single-process container
the *mechanisms* are real (the watchdog retry path, slot quarantine +
deterministic requeue, page quarantine, overload shedding) and the
failures are injected on a seedable schedule so every chaos run is
reproducible. A real deployment wires the same hooks to actual signals —
an XLA launch failure, a NaN guard on logits, ECC page retirement, a
stalled SSE client.

Fault kinds (each gated by its own rate, decisions keyed on
`(seed, kind, step)` so they are independent of wall clock and of each
other):

  step faults     — a transient exception raised BEFORE the jitted step
                    runs (a launch failure / preempted device); the
                    session watchdog retries with exponential backoff.
  NaN slots       — one active slot's logits row is overwritten with NaN
                    AFTER the jitted step (a numerically poisoned
                    activation); detection quarantines the slot and
                    requeues its request through the eviction/replay
                    path (PRNG streams key on submission index, so the
                    replay is deterministic and survivors' greedy tokens
                    are bitwise those of a fault-free run).
  page quarantine — a fraction of the free KV pages is retired for a few
                    steps (ECC-style bad-page retirement / a neighbor
                    stealing HBM); allocation pressure drives the
                    scheduler's ordinary eviction valve.
  stragglers      — an artificial sleep inside the step (a slow host);
                    degrades latency SLOs, never tokens.
  client cancels  — a random active request is cancelled mid-flight (a
                    dead SSE client); its slot and pages free
                    immediately.

`begin_step` / `corrupt_logits` / `cancel_victim` are called by
`ServeSession`; the SSE front end exercises the slow/dead-client and
malformed-request paths with real sockets (tests/test_frontend.py).
Explicit schedules (`fail_steps`, `nan_steps`) override the rates for
targeted tests, mirroring `train.fault.FailureInjector.fail_at`.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = ["StepFault", "ServeFaultInjector", "chaos_injector"]


class StepFault(RuntimeError):
    """Raised when an injected transient failure hits a serving step."""


@dataclasses.dataclass
class ServeFaultInjector:
    """Deterministic, seedable fault schedule for the serving engine.

    All decisions are drawn from `default_rng([seed, kind, step])`, so a
    given (seed, step index) always produces the same faults regardless
    of retry timing, wall clock, or which other fault kinds are enabled.
    A step fault fires at most once per step index (the retry that
    follows it must be able to succeed)."""

    seed: int = 0
    # per-step probabilities
    step_fault_rate: float = 0.0
    nan_rate: float = 0.0
    page_rate: float = 0.0
    straggle_rate: float = 0.0
    cancel_rate: float = 0.0
    # shapes of the injected faults
    page_frac: float = 0.5             # fraction of free pages retired
    page_hold_steps: int = 3           # steps before retired pages return
    straggle_s: float = 0.005          # artificial per-step delay
    # explicit schedules (override/augment the rates in targeted tests)
    fail_steps: Tuple[int, ...] = ()
    nan_steps: Tuple[Tuple[int, int], ...] = ()   # (step, slot) pairs

    def __post_init__(self) -> None:
        self._raised: set = set()
        self._page_release_step: Optional[int] = None
        self.counts: Dict[str, int] = {
            "step_faults": 0, "nan_slots": 0, "page_quarantines": 0,
            "pages_quarantined": 0, "straggles": 0, "cancels": 0}

    def _rng(self, kind: int, step: int) -> np.random.Generator:
        return np.random.default_rng([self.seed, kind, step])

    @property
    def total(self) -> int:
        return sum(self.counts.values()) - self.counts["pages_quarantined"]

    # ------------------------------------------------------------- hooks

    def begin_step(self, step: int, alloc=None) -> None:
        """Pre-step injection point: straggler delay, page quarantine
        churn, then (possibly) a transient StepFault. Called inside the
        session watchdog — a raised StepFault is retried, and because a
        step index fires at most once, the retry proceeds."""
        if self.straggle_rate and \
                self._rng(1, step).random() < self.straggle_rate:
            self.counts["straggles"] += 1
            time.sleep(self.straggle_s)
        if alloc is not None:
            if self._page_release_step is not None \
                    and step >= self._page_release_step:
                alloc.restore_quarantined()
                self._page_release_step = None
            if self.page_rate and self._page_release_step is None \
                    and self._rng(2, step).random() < self.page_rate:
                n = max(1, int(alloc.available * self.page_frac))
                got = alloc.quarantine_free_pages(n)
                if got:
                    self.counts["page_quarantines"] += 1
                    self.counts["pages_quarantined"] += got
                    self._page_release_step = step + self.page_hold_steps
        fail = step in self.fail_steps or (
            self.step_fault_rate
            and self._rng(3, step).random() < self.step_fault_rate)
        if fail and step not in self._raised:
            self._raised.add(step)
            self.counts["step_faults"] += 1
            raise StepFault(f"injected transient fault at serve step {step}")

    def tick_idle(self, step: int, alloc=None) -> None:
        """Idle-step hook: only advances the page-quarantine clock (no
        new faults — there is nothing to fault). Without it an idle
        session could starve forever waiting for retired pages."""
        if alloc is not None and self._page_release_step is not None \
                and step >= self._page_release_step:
            alloc.restore_quarantined()
            self._page_release_step = None

    def nan_targets(self, step: int, slots: Sequence[int]) -> List[int]:
        """Slots whose logits rows get poisoned this step (post-jit):
        explicit (step, slot) entries, plus at most one rate-drawn victim
        among the active slots."""
        targets = [s for (st, s) in self.nan_steps
                   if st == step and s in slots]
        if self.nan_rate and len(slots) > 0 \
                and self._rng(4, step).random() < self.nan_rate:
            pick = int(self._rng(5, step).integers(len(slots)))
            if slots[pick] not in targets:
                targets.append(slots[pick])
        self.counts["nan_slots"] += len(targets)
        return targets

    def cancel_victim(self, step: int,
                      uids: Sequence[int]) -> Optional[int]:
        """Uid of the active request a (simulated) dead client abandons
        this step, or None."""
        if self.cancel_rate and len(uids) > 0 \
                and self._rng(6, step).random() < self.cancel_rate:
            self.counts["cancels"] += 1
            return uids[int(self._rng(7, step).integers(len(uids)))]
        return None

    def finish(self, alloc=None) -> None:
        """Return any still-quarantined pages (end-of-run cleanup so the
        allocator's partition invariant closes over the whole pool)."""
        if alloc is not None:
            alloc.restore_quarantined()
        self._page_release_step = None

    def summary(self) -> Dict[str, int]:
        return dict(self.counts)


def chaos_injector(seed: int, rate: float = 0.1,
                   paged: bool = False) -> ServeFaultInjector:
    """The default chaos mix used by `loadgen --chaos` and the CI smoke:
    every fault kind on, scaled off one knob."""
    return ServeFaultInjector(
        seed=seed, step_fault_rate=rate, nan_rate=rate,
        page_rate=2 * rate if paged else 0.0, straggle_rate=rate,
        cancel_rate=rate / 2)
