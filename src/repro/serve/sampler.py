"""Per-sequence sampling for slot-batched decode.

Each slot carries its own temperature / top-k / PRNG stream, so a hot
creative-writing request and a greedy extraction request can share one
decode step. Greedy (temperature <= 0) rows take the argmax and ignore the
key, which keeps continuous-batching output bit-identical to a standalone
greedy decode regardless of what the co-scheduled slots are doing.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def apply_top_k(logits: jnp.ndarray, top_ks: jnp.ndarray) -> jnp.ndarray:
    """Mask logits outside each row's top-k. top_ks (B,) i32; <=0 = keep all."""
    v = logits.shape[-1]
    k = jnp.where(top_ks <= 0, v, jnp.minimum(top_ks, v)).astype(jnp.int32)
    sorted_desc = jnp.flip(jnp.sort(logits, axis=-1), axis=-1)
    thresh = jnp.take_along_axis(sorted_desc, (k - 1)[:, None], axis=-1)
    return jnp.where(logits >= thresh, logits, -jnp.inf)


def sample_tokens(logits: jnp.ndarray, temps: jnp.ndarray,
                  top_ks: jnp.ndarray, keys: jnp.ndarray) -> jnp.ndarray:
    """logits (B,V), temps (B,), top_ks (B,), keys (B,2) u32 -> (B,) i32."""
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    scaled = apply_top_k(logits, top_ks) / jnp.maximum(temps, 1e-6)[:, None]
    sampled = jax.vmap(jax.random.categorical)(keys, scaled).astype(jnp.int32)
    return jnp.where(temps <= 0.0, greedy, sampled)


def sample_token(logits: jnp.ndarray, temperature: float, key) -> jnp.ndarray:
    """Batch-uniform sampling (legacy static path)."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature).astype(jnp.int32)


def request_key(seed: int, stream: int):
    """Per-request stream keyed on the request's index within a serve call:
    reproducible from (seed, position) alone, decorrelated across slots."""
    return jax.random.fold_in(jax.random.PRNGKey(seed), stream)
