"""jax version-compat shims for sharding APIs.

The repo targets the modern spellings (`jax.shard_map` with `check_vma` /
`axis_names`, `jax.make_mesh(..., axis_types=...)`) but must run on older
jax (0.4.x) where shard_map lives in `jax.experimental`, `check_vma` is
`check_rep`, `axis_names` is the complementary `auto` set, and
`jax.sharding.AxisType` does not exist. Import from here, not from jax.
"""
from __future__ import annotations

import inspect

import jax

try:                                    # jax >= 0.6 exports it at top level
    from jax import shard_map as _shard_map
except ImportError:                     # pragma: no cover - version compat
    from jax.experimental.shard_map import shard_map as _shard_map

try:                                    # jax >= 0.5
    from jax.sharding import AxisType
except ImportError:                     # pragma: no cover - version compat
    AxisType = None

_PARAMS = inspect.signature(_shard_map).parameters


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None,
              axis_names=None):
    kw = {}
    if check_vma is not None:
        kw["check_vma" if "check_vma" in _PARAMS else "check_rep"] = check_vma
    if axis_names is not None:
        if "axis_names" in _PARAMS:
            kw["axis_names"] = set(axis_names)
        else:  # old jax: `auto` = the mesh axes that are NOT manual
            kw["auto"] = frozenset(mesh.axis_names) - set(axis_names)
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kw)


_real_set_mesh = getattr(jax, "set_mesh", None)


def set_mesh(mesh):
    """Ambient-mesh context: jax.set_mesh on new jax; on old jax the Mesh
    object is itself the context manager."""
    if _real_set_mesh is not None:
        return _real_set_mesh(mesh)
    return mesh


def cost_analysis(compiled) -> dict:
    """compiled.cost_analysis() as a flat dict (old jax returns a list)."""
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca


def make_mesh(shape, axes):
    """jax.make_mesh with Auto axis types where the jax version has them."""
    if AxisType is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)
