"""Sharding context threaded through model apply functions.

Keeps the model code mesh-agnostic: with ctx.mesh=None every constraint is
a no-op (single-device smoke tests); with a production mesh the same code
emits GSPMD sharding constraints and (for MoE) shard_map expert parallelism.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.policy import ExecPolicy


@dataclasses.dataclass(frozen=True)
class ShardCtx:
    mesh: Optional[Mesh] = None
    dp_axes: Tuple[str, ...] = ("data",)   # ("pod","data") on the multi-pod mesh
    tp_axis: Optional[str] = "model"
    ep: bool = False                        # expert parallelism via shard_map
    seq_shard_kv: bool = False              # SP for long-context decode KV
    # execution policy (LUT-matmul backend etc.) — explicit per call tree,
    # replacing the old models.linears._LUT_BACKEND module global
    exec_policy: ExecPolicy = ExecPolicy()

    @property
    def lut_backend(self) -> str:
        return self.exec_policy.lut_backend

    def with_lut_backend(self, name: str) -> "ShardCtx":
        return dataclasses.replace(
            self, exec_policy=dataclasses.replace(self.exec_policy,
                                                  lut_backend=name))

    def with_draft_bits(self, draft_bits: int) -> "ShardCtx":
        """Context for a speculative draft forward pass (0 = full width)."""
        return dataclasses.replace(
            self, exec_policy=dataclasses.replace(self.exec_policy,
                                                  draft_bits=draft_bits))

    @property
    def dp(self):
        return self.dp_axes if len(self.dp_axes) > 1 else self.dp_axes[0]

    def spec(self, *axes) -> P:
        return P(*axes)

    def constrain(self, x, *axes):
        """with_sharding_constraint if a mesh is active, else identity.

        `axes` entries: None, 'dp' (expands to dp_axes), or a mesh axis name.
        Axes that do not evenly divide the corresponding dim are dropped
        (avoids GSPMD padding waste, e.g. 40 heads over tp=16).
        """
        if self.mesh is None or self.tp_axis is None:
            return x
        expanded = []
        for i, a in enumerate(axes):
            a = self.dp if a == "dp" else a
            if a is not None:
                names = a if isinstance(a, tuple) else (a,)
                size = 1
                for n in names:
                    size *= self.mesh.shape[n]
                if i >= x.ndim or x.shape[i] % size != 0:
                    a = None
            expanded.append(a)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(*expanded)))


LOCAL = ShardCtx()
