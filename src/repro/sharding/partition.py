"""Parameter partitioning rules (DP/TP/EP) — DESIGN.md §4.

Megatron-style TP over the `model` axis: column-parallel in-projections,
row-parallel out-projections, vocab-sharded embedding/head, EP expert
weights sharded on the expert dim. Stacked pattern-unit parameters get
leading `None`s automatically (rules are written for the base rank).

Quantized containers (QuantizedLinear / QuantizedExperts) are mapped as
whole leaves: the dense rule resolves from the path once and each child
leaf's layout mapping lives on the owning `WeightFormat.partition_spec`
(codes transposed to (out, in), codebook/sparse on the out dim, full fp
rows replicated) — the format owns its layout here exactly as
`CacheFormat.partition_spec` owns serve-cache layouts in `cache_specs`.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# (path-substring predicate, base-rank, spec builder given tp axis)
_RULES = [
    # embeddings / head
    ("embed",           2, lambda tp: (tp, None)),
    ("head",            2, lambda tp: (None, tp)),
    # attention
    ("attn/wq",         2, lambda tp: (None, tp)),
    ("attn/wk",         2, lambda tp: (None, tp)),
    ("attn/wv",         2, lambda tp: (None, tp)),
    ("attn/wo",         2, lambda tp: (tp, None)),
    ("xattn/wq",        2, lambda tp: (None, tp)),
    ("xattn/wk",        2, lambda tp: (None, tp)),
    ("xattn/wv",        2, lambda tp: (None, tp)),
    ("xattn/wo",        2, lambda tp: (tp, None)),
    # dense MLP
    ("mlp/w_gate",      2, lambda tp: (None, tp)),
    ("mlp/w_up",        2, lambda tp: (None, tp)),
    ("mlp/w_down",      2, lambda tp: (tp, None)),
    # MoE (expert-parallel over the model axis)
    ("moe/router",      2, lambda tp: (None, None)),
    ("moe/w_gate",      3, lambda tp: (tp, None, None)),
    ("moe/w_up",        3, lambda tp: (tp, None, None)),
    ("moe/w_down",      3, lambda tp: (tp, None, None)),
    # RWKV-6
    ("tm/wr",           2, lambda tp: (None, tp)),
    ("tm/wk",           2, lambda tp: (None, tp)),
    ("tm/wv",           2, lambda tp: (None, tp)),
    ("tm/wg",           2, lambda tp: (None, tp)),
    ("tm/wo",           2, lambda tp: (tp, None)),
    ("cm/wk",           2, lambda tp: (None, tp)),
    ("cm/wv",           2, lambda tp: (tp, None)),
    ("cm/wr",           2, lambda tp: (None, tp)),
    # RG-LRU
    ("rec/w_in",        2, lambda tp: (None, tp)),
    ("rec/w_gate",      2, lambda tp: (None, tp)),
    ("rec/w_out",       2, lambda tp: (tp, None)),
    ("rec/w_a",         2, lambda tp: (None, tp)),
    ("rec/w_x",         2, lambda tp: (None, tp)),
    ("rec/conv_w",      2, lambda tp: (None, tp)),
]


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        elif hasattr(k, "name"):
            parts.append(str(k.name))
    return "/".join(parts)


def _base_rule(path, tp_axis) -> Optional[Tuple]:
    """The dense rule spec tuple matching a parameter path (None: no rule,
    replicate)."""
    pstr = _path_str(path)
    for needle, _, builder in _RULES:
        if needle in pstr:
            return tuple(builder(tp_axis))
    return None


def spec_for_param(path, leaf, tp_axis: Optional[str]) -> P:
    """PartitionSpec for one plain (dense) parameter leaf — stacked
    pattern-unit dims get leading Nones. Quantized containers
    (QuantizedLinear / QuantizedExperts) are handled as whole leaves by
    `quantized_param_specs`: each child's layout rule lives on the owning
    `WeightFormat.partition_spec`, the way serve-cache rules live on
    `CacheFormat.partition_spec`."""
    if tp_axis is None:
        return P()
    from repro.core.formats import pad_spec
    # no matching rule (norms, gates, small vectors) replicates via pad_spec
    return pad_spec(_base_rule(path, tp_axis), len(leaf.shape))


def quantized_param_specs(path, layer, tp_axis: Optional[str]):
    """A container of PartitionSpecs matching one QuantizedLinear /
    QuantizedExperts leaf: the dense rule is resolved from the path once,
    then each child leaf asks the owning `WeightFormat.partition_spec` for
    its layout's mapping (codes transposed, codebook on the out dim, ...).
    Returns the same container type with specs in the array slots, so the
    flattened tree aligns leaf-for-leaf with the parameter tree."""
    from repro.core.formats import get_format

    base = _base_rule(path, tp_axis) if tp_axis is not None else None
    fmt = get_format(layer.fmt)
    children, aux = layer.tree_flatten()
    specs = [None if c is None
             else fmt.partition_spec(name, base, len(c.shape))
             for name, c in zip(type(layer).CHILDREN, children)]
    return type(layer).tree_unflatten(aux, specs)


def _is_container(x) -> bool:
    from repro.core.types import QuantizedExperts, QuantizedLinear
    return isinstance(x, (QuantizedLinear, QuantizedExperts))


def _drop_nondividing(spec: P, shape, mesh: Mesh) -> P:
    """pjit argument shardings require exact divisibility: spec axes that
    don't divide their dim are moved to another unsharded dim that does
    (e.g. vocab 49155 % 16 != 0 -> shard the d_model dim instead), else
    dropped."""
    def axsize(a):
        names = a if isinstance(a, tuple) else (a,)
        size = 1
        for n in names:
            size *= mesh.shape[n]
        return size

    out = list(tuple(spec)) + [None] * (len(shape) - len(tuple(spec)))
    for i, a in enumerate(list(out)):
        if a is not None and shape[i] % axsize(a) != 0:
            out[i] = None
            for j in range(len(shape)):       # rescue onto a dividing dim
                if out[j] is None and shape[j] % axsize(a) == 0 and j != i:
                    out[j] = a
                    break
    return P(*out)


def param_shardings(params, mesh: Mesh, tp_axis: Optional[str] = "model"):
    """NamedSharding tree matching `params` (works on ShapeDtypeStructs)."""
    def one(path, leaf):
        if _is_container(leaf):
            specs = quantized_param_specs(path, leaf, tp_axis)
            spec_children, aux = specs.tree_flatten()
            children, _ = leaf.tree_flatten()
            out = [None if c is None else NamedSharding(
                mesh, _drop_nondividing(s, c.shape, mesh))
                for c, s in zip(children, spec_children)]
            return type(leaf).tree_unflatten(aux, out)
        spec = spec_for_param(path, leaf, tp_axis)
        return NamedSharding(mesh, _drop_nondividing(spec, leaf.shape, mesh))
    return jax.tree_util.tree_map_with_path(one, params,
                                            is_leaf=_is_container)


def param_specs(params, tp_axis: Optional[str] = "model"):
    def one(path, leaf):
        if _is_container(leaf):
            return quantized_param_specs(path, leaf, tp_axis)
        return spec_for_param(path, leaf, tp_axis)
    return jax.tree_util.tree_map_with_path(one, params,
                                            is_leaf=_is_container)


# ------------------------------------------------------------ serve caches

def cache_specs(cache_sds, mesh: Mesh, dp_axes, tp_axis: str = "model"):
    """PartitionSpec tree for a serve cache: each `CacheState` entry asks
    its own `CacheFormat.partition_spec` for the per-leaf rule — the format
    owns its layout, there is no name-based special-casing here (mirrors
    how quantized weight leaves defer to the WeightFormat layout above).
    """
    from repro.core.cache_formats import CacheState, get_cache_format

    dp = dp_axes if len(dp_axes) > 1 else dp_axes[0]

    def size_of(axes):
        names = axes if isinstance(axes, tuple) else (axes,)
        size = 1
        for n in names:
            size *= mesh.shape[n]
        return size

    def per_state(st: CacheState) -> CacheState:
        f = get_cache_format(st.fmt)
        return CacheState(st.fmt, {
            name: f.partition_spec(name, leaf.shape, dp, tp_axis, size_of)
            for name, leaf in st.data.items()})

    return jax.tree.map(per_state, cache_sds,
                        is_leaf=lambda x: isinstance(x, CacheState))
