from .context import ShardCtx, LOCAL
