"""Attention: MHA/GQA/MQA, causal + sliding-window, qk-norm, M-RoPE,
KV caches (full + ring-buffer), cross-attention, chunked-query prefill.

Layout: activations (B, S, d); heads materialized as (B, S, H, hd). GQA is
computed grouped — K/V are never repeated in memory:
scores = einsum('bskgh,btkh->bkgst').
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.cache_formats import (CacheState, get_cache_format,
                                      kv_format_of, token_write_view)
from repro.sharding.context import ShardCtx, LOCAL
from .common import apply_mrope, apply_rope, dense_init, init_norm, \
    rms_norm_headwise
from .linears import linear_apply, linear_apply_grouped

NEG_INF = -2.0 ** 30
Params = Dict


def init_attention(key, cfg: ModelConfig, dtype, cross: bool = False) -> Params:
    ks = jax.random.split(key, 6)
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    p = {
        "wq": dense_init(ks[0], d, qd, dtype),
        "wk": dense_init(ks[1], d, kvd, dtype),
        "wv": dense_init(ks[2], d, kvd, dtype),
        "wo": dense_init(ks[3], qd, d, dtype),
    }
    if cfg.qk_norm and not cross:
        p["q_norm"] = jnp.ones((cfg.head_dim,), dtype)
        p["k_norm"] = jnp.ones((cfg.head_dim,), dtype)
    return p


def _heads(x: jnp.ndarray, n: int, hd: int) -> jnp.ndarray:
    return x.reshape(*x.shape[:-1], n, hd)


def _finish_q(q, p, positions, cfg: ModelConfig, ctx: ShardCtx,
              rope: bool = True):
    q = ctx.constrain(q, "dp", None, ctx.tp_axis)
    q = _heads(q, cfg.n_heads, cfg.head_dim)
    if "q_norm" in p:
        q = rms_norm_headwise(p["q_norm"], q, cfg.norm_eps)
    if rope:
        if cfg.mrope_sections:
            q = apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        else:
            q = apply_rope(q, positions, cfg.rope_theta)
    return q


def _finish_kv(k, v, p, positions, cfg: ModelConfig, ctx: ShardCtx,
               rope: bool = True):
    k = ctx.constrain(k, "dp", None, ctx.tp_axis)
    v = ctx.constrain(v, "dp", None, ctx.tp_axis)
    k = _heads(k, cfg.n_kv_heads, cfg.head_dim)
    v = _heads(v, cfg.n_kv_heads, cfg.head_dim)
    if "k_norm" in p:
        k = rms_norm_headwise(p["k_norm"], k, cfg.norm_eps)
    if rope:
        if cfg.mrope_sections:
            k = apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
        else:
            k = apply_rope(k, positions, cfg.rope_theta)
    return k, v


def project_q(p, x, positions, cfg: ModelConfig, ctx: ShardCtx, col, prefix,
              rope: bool = True):
    q = linear_apply(p["wq"], x, col, prefix + "wq", ctx)
    return _finish_q(q, p, positions, cfg, ctx, rope)


def project_kv(p, x, positions, cfg: ModelConfig, ctx: ShardCtx, col, prefix,
               rope: bool = True):
    k = linear_apply(p["wk"], x, col, prefix + "wk", ctx)
    v = linear_apply(p["wv"], x, col, prefix + "wv", ctx)
    return _finish_kv(k, v, p, positions, cfg, ctx, rope)


def project_qkv(p, x, positions, cfg: ModelConfig, ctx: ShardCtx, col,
                prefix, rope: bool = True):
    """Q/K/V projections from one x: a single fused LUT-mpGEMM launch when
    wq/wk/wv share a groupable quantized format (X streamed once instead
    of 3x), falling back to per-projection matmuls otherwise."""
    q, k, v = linear_apply_grouped(
        [p["wq"], p["wk"], p["wv"]], x, col,
        (prefix + "wq", prefix + "wk", prefix + "wv"), ctx)
    q = _finish_q(q, p, positions, cfg, ctx, rope)
    k, v = _finish_kv(k, v, p, positions, cfg, ctx, rope)
    return q, k, v


def _grouped_scores(q: jnp.ndarray, k: jnp.ndarray) -> jnp.ndarray:
    """q (B,Sq,H,hd), k (B,Sk,K,hd) -> (B,K,G,Sq,Sk) with H = K*G."""
    b, sq, h, hd = q.shape
    kk = k.shape[2]
    g = h // kk
    qg = q.reshape(b, sq, kk, g, hd)
    return jnp.einsum("bskgh,btkh->bkgst", qg, k) / jnp.sqrt(hd).astype(q.dtype)


def _grouped_context(w: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """w (B,K,G,Sq,Sk), v (B,Sk,K,hd) -> (B,Sq,H,hd)."""
    b, kk, g, sq, sk = w.shape
    ctx = jnp.einsum("bkgst,btkh->bskgh", w, v)
    return ctx.reshape(b, sq, kk * g, -1)


def _mask_bias(qpos: jnp.ndarray, kpos: jnp.ndarray, kind: str,
               window: int) -> jnp.ndarray:
    """(Sq, Sk) additive bias; qpos/kpos (Sq,), (Sk,) absolute positions."""
    dq = qpos[:, None]
    dk = kpos[None, :]
    if kind == "none":
        allowed = jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    elif kind == "causal":
        allowed = dk <= dq
    elif kind == "sliding":
        allowed = (dk <= dq) & (dk > dq - window)
    else:
        raise ValueError(kind)
    allowed = allowed & (dk[0:1, :] >= 0 if kpos.ndim else True)
    return jnp.where(allowed, 0.0, NEG_INF)


def _softmax(scores: jnp.ndarray) -> jnp.ndarray:
    s = scores.astype(jnp.float32)
    s = s - jax.lax.stop_gradient(jnp.max(s, axis=-1, keepdims=True))
    w = jnp.exp(s)
    return w / jnp.sum(w, axis=-1, keepdims=True)


def attend_full(q, k, v, qpos, kpos, kind: str, window: int,
                chunk: Optional[int] = None) -> jnp.ndarray:
    """Full-sequence attention; optionally scanned over query chunks so the
    (Sq, Sk) logits never exceed (chunk, Sk) — the prefill-32k memory path."""
    if chunk is None or q.shape[1] <= chunk:
        bias = _mask_bias(qpos, kpos, kind, window)
        scores = _grouped_scores(q, k).astype(jnp.float32) + bias
        return _grouped_context(_softmax(scores).astype(v.dtype), v)

    b, sq, h, hd = q.shape
    assert sq % chunk == 0, (sq, chunk)
    nchunks = sq // chunk
    qc = q.reshape(b, nchunks, chunk, h, hd).transpose(1, 0, 2, 3, 4)
    pc = qpos.reshape(nchunks, chunk)

    def one(args):
        qi, pi = args
        bias = _mask_bias(pi, kpos, kind, window)
        scores = _grouped_scores(qi, k).astype(jnp.float32) + bias
        return _grouped_context(_softmax(scores).astype(v.dtype), v)

    out = jax.lax.map(one, (qc, pc))                     # (nchunks, B, chunk, H, hd)
    return out.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, hd)


# ------------------------------------------------------------------ KV cache
#
# Container layout lives in `core.cache_formats` (the CacheFormat registry);
# the functions here are the attention-math side: they dispatch on the
# cache's `fmt` tag only and never probe keys or dtypes.

def init_cache(batch: int, cache_len: int, cfg: ModelConfig,
               dtype) -> CacheState:
    """Allocate one layer's attention cache in the config's KV format."""
    return get_cache_format(kv_format_of(cfg)).init(batch, cache_len, cfg,
                                                    dtype)


def cache_write(cache: CacheState, k_new: jnp.ndarray, v_new: jnp.ndarray,
                pos: jnp.ndarray,
                active: Optional[jnp.ndarray] = None,
                pages: Optional[jnp.ndarray] = None) -> CacheState:
    """Write one step (B, 1, K, hd) at position pos; pos (B,) int32.

    `active` (B,) bool gates the write per sequence: an inactive slot's
    row is left unchanged (paged formats park it on the scratch page), so
    draining/free slots in a continuous-batching engine never corrupt
    their cache between requests. `pages` (B, max_pages) is the page
    table for paged formats.
    """
    return get_cache_format(cache.fmt).write(cache, k_new, v_new, pos,
                                             active=active, pages=pages)


def attend_decode(q, cache: CacheState, pos: jnp.ndarray, kind: str,
                  window: int,
                  active: Optional[jnp.ndarray] = None,
                  pages: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """q (B,1,H,hd) against the cache; pos (B,) position of the new token
    (already written to the cache).

    `active` (B,) bool masks whole sequences: an inactive slot attends to
    nothing (its softmax degrades to a uniform read — finite garbage the
    caller discards), so free slots in a slot-batched decode step cost no
    correctness. Paged caches gather K/V through `pages`.
    """
    f = get_cache_format(cache.fmt)
    k, v = f.read(cache, q.dtype, pages=pages)           # (B, W, K, hd)
    allowed = f.visible(cache, pos, kind, window, pages=pages)
    if active is not None:
        allowed &= active[:, None]
    bias = jnp.where(allowed, 0.0, NEG_INF)[:, None, None, None, :]
    scores = _grouped_scores(q, k).astype(jnp.float32) + bias  # (B,K,G,1,W)
    return _grouped_context(_softmax(scores).astype(v.dtype), v)


# --------------------------------------------------------------- full blocks

def attention_block(p, x, positions, cfg: ModelConfig, kind: str,
                    ctx: ShardCtx = LOCAL, col=None, prefix: str = "",
                    chunk: Optional[int] = 4096 * 2):
    """Training/prefill self-attention (returns output + fresh cache K/V)."""
    q, k, v = project_qkv(p, x, positions, cfg, ctx, col, prefix)
    pos1 = positions if positions.ndim == 2 else positions[0]
    o = attend_full(q, k, v, pos1[0], pos1[0],
                    "causal" if kind == "attn" else "sliding",
                    cfg.sliding_window, chunk)
    o = o.reshape(*x.shape[:-1], cfg.q_dim)
    y = linear_apply(p["wo"], o, col, prefix + "wo", ctx)
    return ctx.constrain(y, "dp", None, None), (k, v)


def attention_decode_block(p, x, pos, cache: CacheState, cfg: ModelConfig,
                           kind: str, ctx: ShardCtx = LOCAL,
                           active: Optional[jnp.ndarray] = None,
                           pages: Optional[jnp.ndarray] = None):
    """One-token decode; x (B,1,d), pos (B,). Returns (y, new_cache)."""
    if cfg.mrope_sections:
        positions = jnp.broadcast_to(pos[None, :, None], (3, pos.shape[0], 1))
    else:
        positions = pos[:, None]
    q, k, v = project_qkv(p, x, positions, cfg, ctx, None, "")
    cache = cache_write(cache, k, v, pos, active, pages)
    o = attend_decode(q, cache, pos,
                      "causal" if kind == "attn" else "sliding",
                      cfg.sliding_window, active, pages)
    o = o.reshape(*x.shape[:-1], cfg.q_dim)
    y = linear_apply(p["wo"], o, None, "", ctx)
    return ctx.constrain(y, "dp", None, None), cache


def attention_mixed_block(p, x, tb, cache: CacheState, cfg: ModelConfig,
                          kind: str, ctx: ShardCtx = LOCAL):
    """Token-budget step self-attention: x (T, 1, d) is a flat token batch
    (`tb` a `models.model.TokenBatch`) mixing decode lanes (one token per
    live slot) with prompt-chunk lanes (several consecutive positions of
    one slot). All lanes' K/V are scattered into the slot cache and each
    lane attends against its own per-token view — intra-chunk causality
    rides the same visibility mask as the cache, so there is no separate
    prefill score path. Returns (y, new_cache)."""
    pos = tb.positions
    if cfg.mrope_sections:
        positions = jnp.broadcast_to(pos[None, :, None], (3, pos.shape[0], 1))
    else:
        positions = pos[:, None]
    q, k, v = project_qkv(p, x, positions, cfg, ctx, None, "")
    cache, view, allowed = token_write_view(
        cache, k[:, 0], v[:, 0], tb.slots, pos, tb.active,
        "causal" if kind == "attn" else "sliding", cfg.sliding_window,
        pages=tb.pages)
    k_all, v_all = get_cache_format(view.fmt).read(view, q.dtype)
    allowed &= tb.active[:, None]
    bias = jnp.where(allowed, 0.0, NEG_INF)[:, None, None, None, :]
    scores = _grouped_scores(q, k_all).astype(jnp.float32) + bias
    o = _grouped_context(_softmax(scores).astype(v_all.dtype), v_all)
    o = o.reshape(*x.shape[:-1], cfg.q_dim)
    y = linear_apply(p["wo"], o, None, "", ctx)
    return ctx.constrain(y, "dp", None, None), cache


def cross_attention_block(p, x, enc_kv: Tuple[jnp.ndarray, jnp.ndarray],
                          cfg: ModelConfig, ctx: ShardCtx = LOCAL,
                          col=None, prefix: str = ""):
    """Decoder cross-attention against precomputed encoder K/V (no mask)."""
    b, s, _ = x.shape
    dummy_pos = jnp.zeros((b, s), jnp.int32)
    q = project_q(p, x, dummy_pos, cfg, ctx, col, prefix, rope=False)
    k, v = enc_kv
    sk = k.shape[1]
    o = attend_full(q, k, v, jnp.arange(s), jnp.arange(sk), "none", 0,
                    chunk=None)
    o = o.reshape(*x.shape[:-1], cfg.q_dim)
    y = linear_apply(p["wo"], o, col, prefix + "wo", ctx)
    return ctx.constrain(y, "dp", None, None)


def encode_cross_kv(p, enc_out: jnp.ndarray, cfg: ModelConfig,
                    ctx: ShardCtx = LOCAL, col=None, prefix: str = ""):
    """Precompute cross K/V from encoder output (whisper prefill)."""
    b, s, _ = enc_out.shape
    dummy_pos = jnp.zeros((b, s), jnp.int32)
    return project_kv(p, enc_out, dummy_pos, cfg, ctx, col, prefix,
                      rope=False)
