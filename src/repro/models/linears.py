"""Linear-layer dispatch: dense fp weights or GANQ LUT-quantized weights.

Every matmul in the model zoo goes through `linear_apply`, so swapping a
model to its quantized form is a pure parameter-tree transformation
(models/quantized.py) — the forward code is unchanged. This mirrors the
paper's deployment story: same network, mpGEMM instead of GEMM.
"""
from __future__ import annotations

from typing import Optional, Union

import jax.numpy as jnp

from repro.core.outliers import apply_sparse
from repro.core.types import QuantizedLinear

# module-level backend switch for LUT matmuls:
#   'pallas' — fused Pallas kernel (interpret mode on CPU)
#   'xla'    — take_along_axis dequant + dot (dry-run / SPMD path)
_LUT_BACKEND = "xla"


def set_lut_backend(name: str) -> None:
    global _LUT_BACKEND
    assert name in ("pallas", "xla"), name
    _LUT_BACKEND = name


def get_lut_backend() -> str:
    return _LUT_BACKEND


def cap(col, name: str, x: jnp.ndarray) -> None:
    """Record linear input for H accumulation (PTQ capture mode)."""
    if col is not None:
        col.add(name, x)


def linear_apply(w: Union[jnp.ndarray, QuantizedLinear], x: jnp.ndarray,
                 col=None, name: str = "") -> jnp.ndarray:
    """y = x @ W (dense) or x @ W~^T (LUT-quantized; W~ is (out, in)).

    x: (..., d_in) any leading shape.
    """
    cap(col, name, x)
    if isinstance(w, QuantizedLinear):
        lead = x.shape[:-1]
        x2 = x.reshape(-1, x.shape[-1])                    # (N, n)
        if _LUT_BACKEND == "pallas":
            from repro.kernels.ops import lut_linear       # lazy import
            y = lut_linear(w.codes, w.codebook.astype(x.dtype), x2.T,
                           bits=w.bits, packed=w.packed).T  # (N, m)
        else:
            wd = jnp.take_along_axis(w.codebook,
                                     w.unpacked_codes().astype(jnp.int32),
                                     axis=1)
            y = x2 @ wd.astype(x.dtype).T
        if w.sparse_val is not None:
            y = y + apply_sparse(w.sparse_idx, w.sparse_val, x2.T).T.astype(y.dtype)
        if w.full_row_val is not None:
            y_full = x2 @ w.full_row_val.astype(x.dtype).T  # (N, n_full)
            y = y.at[:, w.full_row_idx].set(y_full)
        if w.bias is not None:
            y = y + w.bias.astype(y.dtype)
        return y.reshape(*lead, -1)
    return x @ w.astype(x.dtype)


def linear_out_dim(w: Union[jnp.ndarray, QuantizedLinear]) -> int:
    if isinstance(w, QuantizedLinear):
        return w.codes.shape[0]
    return w.shape[-1]
