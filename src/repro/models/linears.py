"""Linear-layer dispatch through the WeightFormat registry.

Every matmul in the model zoo goes through `linear_apply`, so swapping a
model to its quantized form is a pure parameter-tree transformation
(models/quantized.py) — the forward code is unchanged. This mirrors the
paper's deployment story: same network, mpGEMM instead of GEMM.

Dispatch is on the container's `fmt` tag (raw arrays are 'dense'); the
LUT-matmul backend ('xla' | 'pallas') comes from `ctx.exec_policy`
(`repro.core.policy.ExecPolicy`) threaded through `ShardCtx` — there is no
module-global backend switch. Migration from the old API:

    set_lut_backend("pallas"); linear_apply(w, x)          # removed
    linear_apply(w, x, ctx=ctx.with_lut_backend("pallas"))  # now

`linear_apply_grouped` applies several projections that share one input
(Q/K/V, gate/up) in a single fused kernel launch when every weight is
LUT-quantized in the same groupable `WeightFormat` and the backend is
'pallas'; any dense / sparse / mixed-format member makes the whole group
fall back to per-layer `linear_apply` — bit-identical to the unfused
path.
"""
from __future__ import annotations

from typing import List, Sequence, Union

import jax.numpy as jnp

from repro.core.formats import get_format
from repro.core.types import QuantizedLinear
from repro.sharding.context import ShardCtx, LOCAL


def cap(col, name: str, x: jnp.ndarray) -> None:
    """Record linear input for H accumulation (PTQ capture mode)."""
    if col is not None:
        col.add(name, x)


def linear_apply(w: Union[jnp.ndarray, QuantizedLinear], x: jnp.ndarray,
                 col=None, name: str = "",
                 ctx: ShardCtx = LOCAL) -> jnp.ndarray:
    """y = x @ W (dense) or x @ W~^T (LUT-quantized; W~ is (out, in)).

    x: (..., d_in) any leading shape. `ctx.exec_policy.lut_backend` picks
    the LUT-matmul implementation for quantized weights.
    """
    cap(col, name, x)
    fmt = getattr(w, "fmt", None)
    if fmt is None:                                        # dense fp weights
        return x @ w.astype(x.dtype)
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])                        # (N, n)
    f = get_format(fmt)
    # ctx says "this is a draft pass"; each nested layer streams its OWN
    # prefix width (a mixed d2/d3 policy stays valid), others serve full
    db = f.draft_bits if ctx.exec_policy.draft_bits else 0
    y = f.apply(w, x2, backend=ctx.lut_backend, draft_bits=db)
    if w.bias is not None:
        y = y + w.bias.astype(y.dtype)
    return y.reshape(*lead, -1)


def linear_apply_grouped(ws: Sequence[Union[jnp.ndarray, QuantizedLinear]],
                         x: jnp.ndarray, col=None,
                         names: Sequence[str] = (),
                         ctx: ShardCtx = LOCAL) -> List[jnp.ndarray]:
    """[y_i = x @ W~_i^T] for projections sharing the input x.

    Projections are split into per-format sub-groups
    (`kernels.ops.split_format_groups`): each sub-group of same-format
    groupable LUT layers rides one fused LUT-mpGEMM launch (X streamed
    HBM->VMEM once for the whole sub-group), everything else — dense,
    sparse-carrying, or lone-format members — falls back to per-layer
    `linear_apply`. A mixed 4-bit-wq / 3-bit-wk/wv policy therefore still
    fuses the k/v pair instead of launching all three sequentially.
    Output list matches `ws` order; bit-identical to the unfused path.
    """
    from repro.kernels.ops import lut_linear_grouped, split_format_groups
    names = list(names) or [""] * len(ws)
    for name in names:
        cap(col, name, x)
    if ctx.lut_backend != "pallas":
        return [linear_apply(w, x, None, "", ctx) for w in ws]
    lead = x.shape[:-1]
    x2 = None
    outs: List = [None] * len(ws)
    for group in split_format_groups(ws):
        if len(group) < 2:
            i = group[0]
            outs[i] = linear_apply(ws[i], x, None, "", ctx)
            continue
        if x2 is None:
            x2 = x.reshape(-1, x.shape[-1])
        ys = lut_linear_grouped([ws[i] for i in group], x2.T)  # [(m_i, N)]
        for i, y in zip(group, ys):
            y = y.T.astype(x.dtype)              # (N, m_i)
            if ws[i].bias is not None:
                y = y + ws[i].bias.astype(y.dtype)
            outs[i] = y.reshape(*lead, -1)
    return outs


def linear_out_dim(w: Union[jnp.ndarray, QuantizedLinear]) -> int:
    if getattr(w, "fmt", None) is not None:
        return w.codes.shape[0]
    return w.shape[-1]
