"""Shared model building blocks: norms, RoPE variants, embeddings, inits.

All modules are functional: params are nested dicts of arrays; apply
functions are pure. Linear weights use the (in_dim, out_dim) layout
(x @ w); GANQ's (m=out, n=in) convention is handled at conversion time in
models/quantized.py (w.T).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

Params = dict


# ---------------------------------------------------------------------- init

def dense_init(key, d_in: int, d_out: int, dtype) -> jnp.ndarray:
    scale = 1.0 / jnp.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype) -> jnp.ndarray:
    return (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)


# --------------------------------------------------------------------- norms

def init_norm(d: int, kind: str, dtype) -> Params:
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def apply_norm(p: Params, x: jnp.ndarray, kind: str, eps: float) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if kind == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:  # rmsnorm
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def rms_norm_headwise(scale: jnp.ndarray, x: jnp.ndarray, eps: float) -> jnp.ndarray:
    """QK-norm (qwen3): RMSNorm over the head_dim of (..., H, hd)."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------- RoPE

def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (B, S, H, hd); positions: (B, S) int32."""
    freqs = rope_frequencies(x.shape[-1], theta)              # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, hd/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jnp.ndarray, positions: jnp.ndarray, theta: float,
                sections: Tuple[int, ...]) -> jnp.ndarray:
    """Multimodal RoPE (Qwen2-VL): positions (3, B, S) — temporal/height/width
    position streams; `sections` splits the hd/2 frequency bands among them."""
    hd = x.shape[-1]
    assert sum(sections) == hd // 2, (sections, hd)
    freqs = rope_frequencies(hd, theta)                        # (hd/2,)
    # pick the position stream per frequency band
    stream = jnp.concatenate([jnp.full((s,), i, jnp.int32)
                              for i, s in enumerate(sections)])
    pos_per_band = positions[stream]                           # (hd/2, B, S)
    angles = jnp.moveaxis(pos_per_band, 0, -1).astype(jnp.float32) * freqs
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq: int, d: int) -> jnp.ndarray:
    """Whisper-style fixed sinusoidal embeddings (S, d)."""
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    div = jnp.exp(-jnp.log(10000.0) * jnp.arange(0, d, 2, jnp.float32) / d)
    pe = jnp.zeros((seq, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe


def activation(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[name]
