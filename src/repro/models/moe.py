"""Mixture-of-Experts with capacity-based dispatch and expert parallelism.

Design (DESIGN.md §4): experts are sharded over the `model` axis ("EP over
TP"). Token activations are replicated across that axis at the block
boundary, so dispatch needs NO all_to_all: each expert shard gathers the
tokens routed to its local experts into a static (E_local, C, d) buffer,
runs dense batched FFNs, scatters back weighted by router probs, and the
cross-shard combine rides the same psum TP already pays for the FFN output.

Dispatch is gather/scatter-based (sort-free ranking via stable argsort +
searchsorted), NOT the GShard one-hot dispatch einsum — the einsum form
inflates FLOPs by O(E) and would poison the compute roofline.

Capacity: C = ceil(T * top_k / E * capacity_factor) tokens per expert
(static shape); overflow tokens are dropped (their residual path passes
through), matching standard dropping MoE semantics.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.sharding.compat import shard_map

from repro.configs.base import ModelConfig
from repro.sharding.context import ShardCtx, LOCAL
from .common import activation, dense_init
from .linears import linear_apply

Params = Dict


def init_moe(key, cfg: ModelConfig, dtype) -> Params:
    ks = jax.random.split(key, 4)
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    scale = 1.0 / jnp.sqrt(d)
    return {
        "router": dense_init(ks[0], d, e, jnp.float32),  # router kept fp32
        "w_gate": (jax.random.normal(ks[1], (e, d, f)) * scale).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (e, d, f)) * scale).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (e, f, d)) /
                   jnp.sqrt(f)).astype(dtype),
    }


def _dispatch_ranks(flat_e: jnp.ndarray) -> jnp.ndarray:
    """rank[i] = how many earlier slots chose the same expert (stable)."""
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    first = jnp.searchsorted(sorted_e, sorted_e, side="left")
    rank_sorted = jnp.arange(flat_e.shape[0], dtype=jnp.int32) - first
    return jnp.zeros_like(flat_e).at[order].set(rank_sorted)


def capacity(tokens: int, top_k: int, n_experts: int, cf: float) -> int:
    c = int(-(-tokens * top_k * cf // n_experts))
    return max(8, c + (-c) % 8)


def _as_dense(w, dtype, draft: bool = False):
    """Dense (E, d_in, d_out) view; dequantizes LUT expert weights (the
    `fmt` tag marks a quantized container — decode routes through the
    WeightFormat registry inside `dequantize`). draft=True decodes nested
    expert formats from their prefix sub-stream only (coarse codebooks);
    non-nested formats ignore it — their draft is the exact decode."""
    if getattr(w, "fmt", None) is not None:
        if draft:
            from repro.core.formats import get_format
            f = get_format(w.fmt)
            if f.draft_bits:             # (E, m, n) -> einsum (E, n, m)
                return jnp.swapaxes(f.draft_dequantize(w), 1, 2) \
                    .astype(dtype)
        return w.dequantize(dtype)
    return w.astype(dtype)


def _expert_ffn(x_buf: jnp.ndarray, p: Params, act, col=None,
                prefix: str = "", e0: int = 0,
                draft: bool = False) -> jnp.ndarray:
    """(E_loc, C, d) -> (E_loc, C, d) batched SwiGLU over local experts.

    In capture mode (`col`), the post-activation hidden state is recorded
    per expert as `{prefix}expert{e}/hidden` — the Gram of the true w_down
    input, so PTQ quantizes w_down against H = h h^T instead of H = I
    (capacity-padding rows are zero and contribute nothing to H).
    """
    g = jnp.einsum("ecd,edf->ecf", x_buf,
                   _as_dense(p["w_gate"], x_buf.dtype, draft))
    u = jnp.einsum("ecd,edf->ecf", x_buf,
                   _as_dense(p["w_up"], x_buf.dtype, draft))
    h = act(g) * u
    if col is not None:
        for e in range(h.shape[0]):
            col.add(f"{prefix}expert{e0 + e}/hidden", h[e])
    return jnp.einsum("ecf,efd->ecd", h,
                      _as_dense(p["w_down"], x_buf.dtype, draft))


def _moe_local(xf: jnp.ndarray, top_i: jnp.ndarray, top_p: jnp.ndarray,
               expert_p: Params, act, e0: int, e_loc: int, cap_c: int,
               col=None, prefix: str = "",
               draft: bool = False) -> jnp.ndarray:
    """Dispatch/FFN/combine for experts [e0, e0+e_loc); xf (T, d).

    Perf note (EXPERIMENTS.md §Perf, qwen3-moe hillclimb): slot->token is
    `flat_t = arange(T*k) // k`, i.e. CONTIGUOUS k-blocks per token — so the
    token gather is a broadcast and the combine scatter-add is a
    reshape+sum over k. Only the slot->capacity-buffer permutation is a
    genuine scatter/gather.
    """
    t_total, d = xf.shape
    k = top_i.shape[-1]
    flat_e = top_i.reshape(-1).astype(jnp.int32)           # (T*k,)
    flat_p = top_p.reshape(-1)
    rank = _dispatch_ranks(flat_e)
    valid = ((flat_e >= e0) & (flat_e < e0 + e_loc) & (rank < cap_c))
    be = jnp.where(valid, flat_e - e0, e_loc)              # trash row e_loc
    bc = jnp.where(valid, rank, 0)
    x_slots = jnp.broadcast_to(xf[:, None, :], (t_total, k, d)) \
        .reshape(t_total * k, d)                           # gather-free
    buf = jnp.zeros((e_loc + 1, cap_c, d), xf.dtype).at[be, bc].set(x_slots)
    if col is not None:                                    # PTQ capture
        for e in range(e_loc):
            col.add(f"{prefix}expert{e0 + e}", buf[e])
    out = _expert_ffn(buf[:e_loc], expert_p, act, col, prefix, e0, draft)
    out = jnp.concatenate([out, jnp.zeros((1, cap_c, d), out.dtype)], axis=0)
    slot_out = out[be, bc]                                 # (T*k, d)
    weight = jnp.where(valid, flat_p, 0.0).astype(xf.dtype)[:, None]
    return (weight * slot_out).reshape(t_total, k, d).sum(axis=1)


def moe_apply(p: Params, x: jnp.ndarray, cfg: ModelConfig,
              ctx: ShardCtx = LOCAL, col=None, prefix: str = ""):
    """Returns (y (B,S,d), aux_loss scalar). Router in fp32."""
    b, s, d = x.shape
    t_total = b * s
    xf = x.reshape(t_total, d)
    if col is not None:
        col.add(prefix + "router", xf)
    logits = (xf.astype(jnp.float32) @ p["router"])        # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, cfg.top_k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    # Switch-style load-balance aux loss
    me = jnp.mean(probs, axis=0)                           # (E,)
    ce = jnp.mean(jax.nn.one_hot(top_i[:, 0], cfg.n_experts, dtype=jnp.float32),
                  axis=0)
    aux = cfg.n_experts * jnp.sum(me * ce)

    act = activation(cfg.act)
    if ctx.ep and ctx.mesh is not None and ctx.tp_axis is not None:
        tp = ctx.mesh.shape[ctx.tp_axis]
        e_loc = cfg.n_experts // tp
        # per-shard token count: tokens are sharded over dp only
        dp_size = 1
        for a in ctx.dp_axes:
            dp_size *= ctx.mesh.shape[a]
        cap_c = capacity(t_total // dp_size, cfg.top_k, cfg.n_experts,
                         cfg.capacity_factor)
        dp_spec = ctx.dp

        def shard_fn(xf_l, ti_l, tp_l, wg, wu, wd):
            e0 = jax.lax.axis_index(ctx.tp_axis) * e_loc
            y_l = _moe_local(xf_l, ti_l, tp_l,
                             {"w_gate": wg, "w_up": wu, "w_down": wd},
                             act, e0, e_loc, cap_c)
            return jax.lax.psum(y_l, ctx.tp_axis)

        y = shard_map(
            shard_fn, mesh=ctx.mesh,
            in_specs=(P(dp_spec, None), P(dp_spec, None), P(dp_spec, None),
                      P(ctx.tp_axis, None, None), P(ctx.tp_axis, None, None),
                      P(ctx.tp_axis, None, None)),
            out_specs=P(dp_spec, None),
            check_vma=False,
        )(xf, top_i, top_p.astype(x.dtype), p["w_gate"], p["w_up"], p["w_down"])
    else:
        cap_c = capacity(t_total, cfg.top_k, cfg.n_experts, cfg.capacity_factor)
        y = _moe_local(xf, top_i, top_p.astype(x.dtype),
                       p, act, 0, cfg.n_experts, cap_c, col, prefix,
                       draft=bool(ctx.exec_policy.draft_bits))
    y = y.reshape(b, s, d)
    return ctx.constrain(y, "dp", None, None), aux
