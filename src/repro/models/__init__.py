"""Model zoo: 10 assigned architectures on a shared functional substrate."""
from .model import (init_params, abstract_params, train_loss, forward_logits,
                    prefill, decode_step, init_serve_cache, mixed_step,
                    TokenBatch)
from .linears import linear_apply, linear_out_dim
