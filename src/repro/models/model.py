"""Model facade: init / train_loss / prefill / decode / mixed_step.

Serving runs on ONE fixed-shape entrypoint, `mixed_step(params, cache,
TokenBatch)` — a per-step token budget of flat lanes mixing decode tokens
with chunked prompt admissions. `prefill` + `decode_step` remain the
whole-prompt two-entrypoint path: training/offline use them directly and
`ServeEngine.generate_batch` keeps them as the greedy-equivalence oracle
for the chunked path.

Batch formats by frontend:
  tokens : {"tokens": (B,S) i32, "labels": (B,S) i32}
  patches: {"embeds": (B,S,d), "positions": (3,B,S) i32, "labels": (B,S)}
  frames : {"frames": (B,S_enc,d), "tokens": (B,S_dec), "labels": (B,S_dec)}

Cross-entropy is computed CHUNKED over the sequence (the (B,S,V) logits
tensor is never materialized — with 262k vocabs it would dominate HBM).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.cache_formats import get_cache_format, layer_cache_format
from repro.sharding.context import ShardCtx, LOCAL
from .common import apply_norm, embed_init, init_norm
from .linears import linear_apply
from .transformer import (cache_insert, init_stack, init_stack_cache,
                          layer_cache_width, stack_apply, stack_decode,
                          stack_mixed, block_apply, pattern_split)
from . import whisper as W

Params = Dict
AUX_COEF = 0.01


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class TokenBatch:
    """One token-budget serving step's flat token lanes.

    A fixed number of lanes T (the step's token budget) carries any mix of
    decode tokens (one lane per live slot) and prompt-chunk tokens (several
    consecutive positions of one slot), so a single jitted `mixed_step`
    shape serves every prompt-length / traffic mix — there are no
    per-length prefill compiles. A slot's lanes within a step are
    contiguous and position-ordered; pad lanes sit at the end with
    `active` False.

    Fields (all (T,) unless noted):
      tokens    int32 token ids
      slots     int32 cache row (slot) each lane belongs to
      positions int32 absolute sequence position of each lane
      horizon   int32 position of the lane's slot's FIRST lane this step
                (run start: decode lanes have horizon == position)
      emit      bool  sample logits at this lane (each slot's last
                *scheduled* generation point: its decode lane, or the
                final prompt token when a chunk completes the prompt)
      active    bool  real lane vs padding
      reset     (n_slots,) bool — slot rows admitted this step: their
                recurrent state is zeroed in-graph before use
      pages     optional (n_slots, max_pages) int32 page table (paged KV)
    """

    tokens: jax.Array
    slots: jax.Array
    positions: jax.Array
    horizon: jax.Array
    emit: jax.Array
    active: jax.Array
    reset: jax.Array
    pages: Optional[jax.Array] = None

    def tree_flatten(self):
        return (self.tokens, self.slots, self.positions, self.horizon,
                self.emit, self.active, self.reset, self.pages), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def _dtype(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[name]


def init_params(key, cfg: ModelConfig) -> Params:
    dtype = _dtype(cfg.param_dtype)
    k_emb, k_stack, k_head = jax.random.split(key, 3)
    p: Params = {"embed": embed_init(k_emb, cfg.vocab_size, cfg.d_model, dtype),
                 "final_ln": init_norm(cfg.d_model, cfg.norm, dtype)}
    if cfg.is_encoder_decoder:
        p["stacks"] = W.init_whisper_stacks(k_stack, cfg, dtype)
    else:
        p["stack"] = init_stack(k_stack, cfg, dtype)
    if not cfg.tie_embeddings:
        p["head"] = embed_init(k_head, cfg.vocab_size, cfg.d_model, dtype).T
    return p


def abstract_params(cfg: ModelConfig) -> Params:
    """ShapeDtypeStruct tree without allocation (dry-run path)."""
    return jax.eval_shape(lambda k: init_params(k, cfg),
                          jax.ShapeDtypeStruct((2,), jnp.uint32))


def _embed(p: Params, tokens: jnp.ndarray, cfg: ModelConfig,
           compute_dtype) -> jnp.ndarray:
    return p["embed"][tokens].astype(compute_dtype)


def _logits_head(p: Params, h: jnp.ndarray, cfg: ModelConfig,
                 ctx: ShardCtx) -> jnp.ndarray:
    head = p["embed"].T if cfg.tie_embeddings else p["head"]
    logits = linear_apply(head, h, ctx=ctx)
    mid = (None,) * (logits.ndim - 2)
    return ctx.constrain(logits, "dp", *mid, ctx.tp_axis)


def _hidden(p: Params, batch: Dict, cfg: ModelConfig, ctx: ShardCtx,
            col=None, chunk: Optional[int] = 8192,
            remat: str = "none") -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Runs the backbone; returns (hidden (B,S,d), aux)."""
    cd = _dtype(cfg.compute_dtype)
    if cfg.is_encoder_decoder:
        enc_out = W.encode(p["stacks"], batch["frames"].astype(cd), cfg, ctx,
                           col, chunk)
        tok_emb = _embed(p, batch["tokens"], cfg, cd)
        h = W.decode_train(p["stacks"], tok_emb, enc_out, cfg, ctx, col, chunk)
        return h, 0.0
    if cfg.frontend == "patches":
        x = batch["embeds"].astype(cd)
        positions = batch["positions"]
    else:
        x = _embed(p, batch["tokens"], cfg, cd)
        b, s = batch["tokens"].shape
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None],
                                     (b, s))
        if cfg.mrope_sections:
            positions = jnp.broadcast_to(positions[None], (3, b, s))
    x = ctx.constrain(x, "dp", None, None)
    h, aux = stack_apply(p["stack"], x, positions, cfg, ctx, col, chunk,
                         remat=remat)
    if not cfg.is_encoder_decoder:
        h = apply_norm(p["final_ln"], h, cfg.norm, cfg.norm_eps)
    return h, aux


def chunked_ce_loss(p: Params, h: jnp.ndarray, labels: jnp.ndarray,
                    cfg: ModelConfig, ctx: ShardCtx,
                    chunk: int = 512) -> jnp.ndarray:
    """Mean token CE without materializing (B,S,V)."""
    b, s, d = h.shape
    cs = chunk if s % chunk == 0 and s > chunk else s
    nch = s // cs
    hc = h.reshape(b, nch, cs, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, nch, cs).transpose(1, 0, 2)

    def one(carry, xs):
        hi, li = xs
        logits = _logits_head(p, hi, cfg, ctx).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, li[..., None], axis=-1)[..., 0]
        return carry + jnp.sum(logz - gold), None

    total, _ = jax.lax.scan(one, jnp.float32(0.0), (hc, lc))
    return total / (b * s)


def train_loss(p: Params, batch: Dict, cfg: ModelConfig,
               ctx: ShardCtx = LOCAL, chunk: Optional[int] = 8192,
               ce_chunk: int = 512, remat: str = "none") -> jnp.ndarray:
    h, aux = _hidden(p, batch, cfg, ctx, None, chunk, remat)
    loss = chunked_ce_loss(p, h, batch["labels"], cfg, ctx, ce_chunk)
    return loss + AUX_COEF * aux


def forward_logits(p: Params, batch: Dict, cfg: ModelConfig,
                   ctx: ShardCtx = LOCAL, col=None,
                   chunk: Optional[int] = 8192) -> jnp.ndarray:
    """Full logits (B,S,V) — evaluation/debug path (small models only)."""
    h, _ = _hidden(p, batch, cfg, ctx, col, chunk)
    return _logits_head(p, h, cfg, ctx)


# ------------------------------------------------------------------- serving

def init_serve_cache(p: Params, batch: Dict, batch_size: int, cache_len: int,
                     cfg: ModelConfig, ctx: ShardCtx = LOCAL,
                     cache=None, slot: Optional[jnp.ndarray] = None,
                     pages: Optional[jnp.ndarray] = None):
    """Allocate a serve cache — or, given `cache` + `slot`, reset just that
    slot row to zeros (admission hygiene for continuous batching; paged
    formats need the slot's `pages` table row)."""
    cd = _dtype(cfg.compute_dtype)
    if cfg.is_encoder_decoder:
        enc_out = W.encode(p["stacks"], batch["frames"].astype(cd), cfg, ctx)
        return W.init_whisper_cache(p["stacks"], batch_size, cache_len,
                                    enc_out, cfg, cd)
    if cache is not None and slot is not None:
        blank = init_stack_cache(1, cache_len, cfg, cd, sub=True)
        return cache_insert(cache, blank, slot, pages=pages)
    return init_stack_cache(batch_size, cache_len, cfg, cd)


def decode_step(p: Params, cache, tokens: jnp.ndarray, pos: jnp.ndarray,
                cfg: ModelConfig, ctx: ShardCtx = LOCAL,
                active: Optional[jnp.ndarray] = None,
                pages: Optional[jnp.ndarray] = None):
    """One token for every sequence: tokens (B,) i32, pos (B,) i32.
    Returns (logits (B,V), new_cache).

    `active` (B,) bool marks live slots in a slot-batched decode step:
    inactive rows neither write their cache nor advance recurrent state, so
    a continuous-batching engine can run one fixed-shape jitted step over a
    partially occupied slot batch. `pages` (B, max_pages) i32 is the page
    table for paged KV formats (-1 = unmapped)."""
    cd = _dtype(cfg.compute_dtype)
    x = _embed(p, tokens[:, None], cfg, cd)
    x = ctx.constrain(x, "dp", None, None)
    if cfg.is_encoder_decoder:
        h, cache = W.decode_step_whisper(p["stacks"], cache, x, pos, cfg, ctx)
    else:
        h, cache = stack_decode(p["stack"], cache, x, pos, cfg, ctx, active,
                                pages)
        h = apply_norm(p["final_ln"], h, cfg.norm, cfg.norm_eps)
    logits = _logits_head(p, h[:, 0, :], cfg, ctx)
    return logits, cache


def mixed_step(p: Params, cache, tb: TokenBatch, cfg: ModelConfig,
               ctx: ShardCtx = LOCAL, emit_groups: int = 1):
    """THE serving execution surface: one fixed-shape token-budget step.

    Consumes a flat `TokenBatch` of up to T tokens drawn from live decode
    slots (one lane each) plus chunked prompt admissions (the remaining
    lanes), writes every lane's K/V / recurrent state into its slot's cache
    rows, and returns `(logits (n_slots * emit_groups, V), new_cache)`
    where each slot's logits row is gathered only at its `emit` lane (rows
    of slots with no emit lane this step are zeros — the host ignores
    them). Decode lanes reproduce the classic one-token `decode_step`
    bitwise; chunk lanes are chunked prefill riding the same kernels, so
    admitting a long prompt never stalls in-flight decode for more than
    one step.

    emit_groups > 1 (static) is the speculative-verify shape: a slot may
    emit up to `emit_groups` consecutive lanes per step, scattered to rows
    `slot * emit_groups + (position - horizon)` — one logits row per
    verified lane, preserving the fixed output shape (lanes beyond the
    group window drop).
    """
    cd = _dtype(cfg.compute_dtype)
    if cfg.is_encoder_decoder:
        raise NotImplementedError("token-budget serving is decoder-only")
    x = _embed(p, tb.tokens[:, None], cfg, cd)             # (T, 1, d)
    x = ctx.constrain(x, "dp", None, None)
    h, cache = stack_mixed(p["stack"], cache, x, tb, cfg, ctx)
    h = apply_norm(p["final_ln"], h, cfg.norm, cfg.norm_eps)
    hs = h[:, 0, :]                                        # (T, d)
    ns = tb.reset.shape[0]
    rows = ns * emit_groups
    if emit_groups == 1:
        idx = jnp.where(tb.emit & tb.active, tb.slots, rows)  # OOB: dropped
    else:
        off = tb.positions - tb.horizon
        idx = jnp.where(tb.emit & tb.active & (off >= 0)
                        & (off < emit_groups),
                        tb.slots * emit_groups + off, rows)
    emit_h = jnp.zeros((rows, hs.shape[-1]), hs.dtype).at[idx].set(
        hs, mode="drop")
    logits = _logits_head(p, emit_h, cfg, ctx)
    return logits, cache


def prefill(p: Params, batch: Dict, cfg: ModelConfig, ctx: ShardCtx = LOCAL,
            cache_len: Optional[int] = None, cache=None,
            slot: Optional[jnp.ndarray] = None,
            pages: Optional[jnp.ndarray] = None):
    """Run the prompt, build a cache positioned after the prompt.

    Implementation: forward pass for logits + per-layer recompute of K/V via
    a scan of decode steps is wasteful; instead we run block_apply capturing
    fresh K/V and scatter them into ring caches.

    With `cache` + `slot` (continuous batching admission) the prompt batch
    must be a single sequence; its freshly built per-layer states are
    inserted into row `slot` of the slot-batched `cache` and the updated
    slot cache is returned instead of a standalone one.
    """
    cd = _dtype(cfg.compute_dtype)
    if cfg.is_encoder_decoder:
        raise NotImplementedError("use init_serve_cache + decode for enc-dec")
    tokens = batch["tokens"]
    b, s = tokens.shape
    cache_len = cache_len or s
    pattern, n_units, _ = pattern_split(cfg)
    x = _embed(p, tokens, cfg, cd)
    if cfg.frontend == "patches" and "embeds" in batch:
        x = batch["embeds"].astype(cd)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    if cfg.mrope_sections:
        positions = jnp.broadcast_to(positions[None], (3, b, s))

    caches = {"units": [], "tail": []}
    li = 0
    unit_caches = [[] for _ in pattern]
    for u in range(n_units):
        for pos_i, kind in enumerate(pattern):
            blk = jax.tree.map(lambda a, u=u: a[u], p["stack"]["units"][pos_i])
            x, _, st = block_apply(kind, blk, x, positions, cfg, ctx)
            unit_caches[pos_i].append(
                _state_to_cache(kind, st, s, cache_len, cfg, cd))
            li += 1
    for i, blk in enumerate(p["stack"]["tail"]):
        kind = pattern[i]
        x, _, st = block_apply(kind, blk, x, positions, cfg, ctx)
        caches["tail"].append(_state_to_cache(kind, st, s, cache_len, cfg, cd))
    caches["units"] = [jax.tree.map(lambda *xs: jnp.stack(xs), *cs)
                       if cs else None for cs in unit_caches]
    h = apply_norm(p["final_ln"], x, cfg.norm, cfg.norm_eps)
    logits = _logits_head(p, h[:, -1, :], cfg, ctx)
    if cache is not None and slot is not None:
        assert b == 1, "slot insertion prefills one sequence at a time"
        return logits, cache_insert(cache, caches, slot, pages=pages)
    return logits, caches


def _state_to_cache(kind: str, st, s: int, cache_len: int, cfg: ModelConfig,
                    dtype):
    """Convert prefill block state into the decode cache layout (via the
    CacheFormat registry; paged formats emit their backing sequence layout
    for `cache_insert` to scatter into the slot's pages)."""
    if kind in ("attn", "local"):
        k, v = st
        f = get_cache_format(layer_cache_format(kind, cfg))
        return f.from_prefill(k, v, layer_cache_width(kind, cache_len, cfg),
                              cfg, dtype)
    return st  # rwkv / rglru states already carry everything
