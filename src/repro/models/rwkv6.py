"""RWKV-6 "Finch" block: time-mix with data-dependent decay + channel-mix.

Semantics (per head, head_size hs; i indexes key-channels, j value-channels):

    y_t[j]   = sum_i r_t[i] * ( S_t[i,j] + u[i] * k_t[i] * v_t[j] )
    S_{t+1}  = diag(w_t) S_t + k_t v_t^T,      w_t in (0, 1) data-dependent

Training/prefill uses a chunked parallel form (within-chunk attention-like
matmuls + cross-chunk state carry), the standard TPU-friendly linear-
attention evaluation: MXU-dense within chunks, one (hs x hs) state update
per chunk. Decode is the single-step recurrence on a cached state —
O(1) per token, which is why rwkv6 runs the long_500k cell.

The decay w_t follows Finch: w_t = exp(-exp(w0 + tanh(x W_a) W_b)) with a
low-rank (LoRA-style) data-dependent part; token-shift interpolation uses
static per-channel mu (the small LoRA mixers of the reference impl are
folded into mu — noted in DESIGN.md).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.sharding.context import ShardCtx, LOCAL
from .common import dense_init
from .linears import linear_apply

Params = Dict
LORA_RANK = 64


def init_rwkv_time_mix(key, cfg: ModelConfig, dtype) -> Params:
    d = cfg.d_model
    hs = cfg.rwkv_head_size
    ks = jax.random.split(key, 9)
    return {
        "wr": dense_init(ks[0], d, d, dtype),
        "wk": dense_init(ks[1], d, d, dtype),
        "wv": dense_init(ks[2], d, d, dtype),
        "wg": dense_init(ks[3], d, d, dtype),
        "wo": dense_init(ks[4], d, d, dtype),
        "mu": (jax.random.uniform(ks[5], (4, d)) * 0.5).astype(dtype),
        "decay_w0": jnp.zeros((d,), jnp.float32) + 0.5,
        "decay_a": dense_init(ks[6], d, LORA_RANK, jnp.float32),
        "decay_b": dense_init(ks[7], LORA_RANK, d, jnp.float32),
        "bonus_u": (jax.random.normal(ks[8], (d,)) * 0.1).astype(jnp.float32),
    }


def init_rwkv_channel_mix(key, cfg: ModelConfig, dtype) -> Params:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 4)
    return {
        "wk": dense_init(ks[0], d, f, dtype),
        "wv": dense_init(ks[1], f, d, dtype),
        "wr": dense_init(ks[2], d, d, dtype),
        "mu": (jax.random.uniform(ks[3], (2, d)) * 0.5).astype(dtype),
    }


def _token_shift(x: jnp.ndarray, prev: jnp.ndarray) -> jnp.ndarray:
    """(B,S,d) -> previous-token stream; prev (B,d) seeds position -1."""
    return jnp.concatenate([prev[:, None, :], x[:, :-1, :]], axis=1)


def _lerp(x, xx, mu):
    return x + (xx - x) * mu.astype(x.dtype)


def _decay(p: Params, xw: jnp.ndarray) -> jnp.ndarray:
    """w_t in (0,1): exp(-exp(...)), Finch eq.

    The upper clip bounds -log(w) <= e^0.05 ~ 1.05 per step so that the
    chunked evaluation's exp(-cumsum(log w)) stays < e^{1.05*chunk} — safely
    inside fp32 for chunk <= 64 (see _wkv_chunk factorization).
    """
    lora = jnp.tanh(xw.astype(jnp.float32) @ p["decay_a"]) @ p["decay_b"]
    log_neg = p["decay_w0"] + lora
    return jnp.exp(-jnp.exp(jnp.clip(log_neg, -8.0, 0.05)))


def _wkv_chunk(r, k, v, w, u, s0):
    """One chunk of the recurrence, parallel within-chunk.

    r,k,v,w: (B,C,H,hs) — w is the decay; u: (H,hs); s0: (B,H,hs,hs).
    Returns (y (B,C,H,hs), s_next).
    """
    bsz, c, h, hs = r.shape
    logw = jnp.log(jnp.maximum(w.astype(jnp.float32), 1e-8))
    clog = jnp.cumsum(logw, axis=1)                     # c_t = prod_{u<=t} w_u
    c_prev = jnp.concatenate([jnp.zeros_like(clog[:, :1]), clog[:, :-1]],
                             axis=1)                    # c_{t-1}, c_0 = 1
    rf = r.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    # within-chunk: A[t,s] = (r_t * c_{t-1}/c_s) . k_s  for s < t; diag u-term
    r_dec = rf * jnp.exp(c_prev)                        # (B,C,H,hs)
    k_dec = kf * jnp.exp(-clog)
    scores = jnp.einsum("bthi,bshi->bhts", r_dec, k_dec)
    tri = jnp.tril(jnp.ones((c, c), bool), k=-1)
    scores = jnp.where(tri[None, None], scores, 0.0)
    diag = jnp.einsum("bthi,bthi->bth", rf * u[None, None], kf)
    y = jnp.einsum("bhts,bshj->bthj", scores, vf)
    y += diag[..., None] * vf
    # cross-chunk: contribution of the carried state
    y += jnp.einsum("bthi,bhij->bthj", r_dec, s0)
    # state update to end of chunk
    k_tail = kf * jnp.exp(clog[:, -1:, :, :] - clog)    # prod_{u=s+1}^{C} w
    s_next = s0 * jnp.exp(clog[:, -1])[..., None] + \
        jnp.einsum("bshi,bshj->bhij", k_tail, vf)
    return y.astype(r.dtype), s_next


def rwkv_time_mix(p: Params, x: jnp.ndarray, state: Tuple, cfg: ModelConfig,
                  ctx: ShardCtx = LOCAL, col=None, prefix: str = "",
                  chunk: int = 64):
    """x (B,S,d); state = (shift (B,d), wkv (B,H,hs,hs)). Returns y, state."""
    b, s, d = x.shape
    hs = cfg.rwkv_head_size
    h = d // hs
    shift_prev, s0 = state
    xx = _token_shift(x, shift_prev)
    mu = p["mu"]
    xr, xk, xv, xg = (_lerp(x, xx, mu[i]) for i in range(4))
    r = linear_apply(p["wr"], xr, col, prefix + "wr", ctx)
    k = linear_apply(p["wk"], xk, col, prefix + "wk", ctx)
    v = linear_apply(p["wv"], xv, col, prefix + "wv", ctx)
    g = jax.nn.silu(linear_apply(p["wg"], xg, col, prefix + "wg", ctx))
    w = _decay(p, xk)
    to_h = lambda t: t.reshape(b, s, h, hs)
    u = p["bonus_u"].reshape(h, hs)

    cs = min(chunk, s)
    if s % cs:
        cs = s  # fall back to one chunk for ragged tiny shapes
    n_chunks = s // cs
    rc, kc, vc, wc = (to_h(t).reshape(b, n_chunks, cs, h, hs)
                      .transpose(1, 0, 2, 3, 4) for t in (r, k, v, w))

    def body(s_carry, args):
        ri, ki, vi, wi = args
        y, s_carry = _wkv_chunk(ri, ki, vi, wi, u, s_carry)
        return s_carry, y

    s_out, ys = jax.lax.scan(body, s0.astype(jnp.float32), (rc, kc, vc, wc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, s, d)
    y = y * g
    out = linear_apply(p["wo"], y, col, prefix + "wo", ctx)
    out = ctx.constrain(out, "dp", None, None)
    return out, (x[:, -1, :], s_out)


def _token_shift_flat(x: jnp.ndarray, shift_tab: jnp.ndarray, tb):
    """Previous-token stream for a flat token batch x (T, d): inside a
    slot's contiguous run the predecessor is the previous lane; a run's
    first token reads the slot's carried shift state."""
    run_start = (tb.positions == tb.horizon)[:, None]
    return jnp.where(run_start, shift_tab[tb.slots].astype(x.dtype),
                     jnp.roll(x, 1, axis=0))


def _last_lane_scatter(tab: jnp.ndarray, values: jnp.ndarray, tb):
    """Write each slot's final-lane value into its state-table row (lanes
    that are not their slot's last, and inactive lanes, are dropped)."""
    ns = tab.shape[0]
    slot_max = jnp.full((ns,), -1, jnp.int32).at[
        jnp.where(tb.active, tb.slots, ns)].max(tb.positions, mode="drop")
    last = tb.active & (tb.positions == slot_max[tb.slots])
    idx = jnp.where(last, tb.slots, ns)                    # OOB: dropped
    return tab.at[idx].set(values.astype(tab.dtype), mode="drop")


def rwkv_time_mix_tokens(p: Params, x: jnp.ndarray, state: Tuple, tb,
                         cfg: ModelConfig, ctx: ShardCtx = LOCAL):
    """Flat-token time-mix for the token-budget serving step: x (T, 1, d),
    `tb` a `models.model.TokenBatch` whose per-slot runs are contiguous and
    position-ordered; state = (shift_tab (B, d), wkv_tab (B, H, hs, hs))
    slot tables. Token shift and the r/k/v/g/decay projections evaluate in
    parallel over the batch; only the wkv recurrence scans lane by lane,
    gathering/scattering each lane's slot row — a single-lane run (pure
    decode) reproduces `rwkv_time_mix`'s one-step path bitwise, a multi-
    lane run is the chunk-stepped prompt prefill."""
    t, _, d = x.shape
    hs = cfg.rwkv_head_size
    h = d // hs
    shift_tab, wkv_tab = state
    x2 = x[:, 0]
    xx = _token_shift_flat(x2, shift_tab, tb)
    mu = p["mu"]
    xr, xk, xv, xg = (_lerp(x2, xx, mu[i]) for i in range(4))
    r = linear_apply(p["wr"], xr, ctx=ctx)
    k = linear_apply(p["wk"], xk, ctx=ctx)
    v = linear_apply(p["wv"], xv, ctx=ctx)
    g = jax.nn.silu(linear_apply(p["wg"], xg, ctx=ctx))
    w = _decay(p, xk)
    to_h = lambda a: a.reshape(t, 1, h, hs)
    u = p["bonus_u"].reshape(h, hs)

    def body(tab, lane):
        ri, ki, vi, wi, slot, act = lane
        y_i, s1 = _wkv_chunk(ri[None], ki[None], vi[None], wi[None], u,
                             tab[slot][None])
        tab = jnp.where(act, tab.at[slot].set(s1[0]), tab)
        return tab, y_i[0, 0]

    wkv_tab, ys = jax.lax.scan(
        body, wkv_tab, (to_h(r), to_h(k), to_h(v), to_h(w),
                        tb.slots, tb.active))
    y = ys.reshape(t, d) * g
    out = linear_apply(p["wo"], y, ctx=ctx)[:, None, :]
    out = ctx.constrain(out, "dp", None, None)
    shift_tab = _last_lane_scatter(shift_tab, x2, tb)
    return out, (shift_tab, wkv_tab)


def rwkv_channel_mix_tokens(p: Params, x: jnp.ndarray,
                            shift_tab: jnp.ndarray, tb,
                            cfg: ModelConfig, ctx: ShardCtx = LOCAL):
    """Flat-token channel-mix (no recurrent state beyond the shift): fully
    parallel over lanes."""
    x2 = x[:, 0]
    xx = _token_shift_flat(x2, shift_tab, tb)
    mu = p["mu"]
    xk = _lerp(x2, xx, mu[0])
    xr = _lerp(x2, xx, mu[1])
    k = jnp.square(jax.nn.relu(linear_apply(p["wk"], xk, ctx=ctx)))
    k = ctx.constrain(k[:, None, :], "dp", None, ctx.tp_axis)[:, 0]
    kv = linear_apply(p["wv"], k, ctx=ctx)
    r = jax.nn.sigmoid(linear_apply(p["wr"], xr, ctx=ctx))
    y = (r * kv)[:, None, :]
    shift_tab = _last_lane_scatter(shift_tab, x2, tb)
    return ctx.constrain(y, "dp", None, None), shift_tab


def rwkv_channel_mix(p: Params, x: jnp.ndarray, shift_prev: jnp.ndarray,
                     cfg: ModelConfig, ctx: ShardCtx = LOCAL, col=None,
                     prefix: str = ""):
    xx = _token_shift(x, shift_prev)
    mu = p["mu"]
    xk = _lerp(x, xx, mu[0])
    xr = _lerp(x, xx, mu[1])
    k = jnp.square(jax.nn.relu(linear_apply(p["wk"], xk, col, prefix + "wk", ctx)))
    k = ctx.constrain(k, "dp", None, ctx.tp_axis)
    kv = linear_apply(p["wv"], k, col, prefix + "wv", ctx)
    r = jax.nn.sigmoid(linear_apply(p["wr"], xr, col, prefix + "wr", ctx))
    y = r * kv
    return ctx.constrain(y, "dp", None, None), x[:, -1, :]


def init_rwkv_state(batch: int, cfg: ModelConfig, dtype):
    """Per-layer RWKV-6 state container ('rwkv_state' CacheFormat)."""
    from repro.core.cache_formats import get_cache_format
    return get_cache_format("rwkv_state").init(batch, 0, cfg, dtype)
