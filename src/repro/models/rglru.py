"""RG-LRU recurrent block (Griffin / RecurrentGemma).

Block structure (Griffin Fig. 2): input -> two branches
  (a) gate branch:      x @ W_gate -> GeLU
  (b) recurrent branch: x @ W_in -> causal depthwise conv1d -> RG-LRU
then elementwise product, then @ W_out.

RG-LRU recurrence (Griffin eq. 1-4), per channel:
  r_t = sigmoid(x_t @ W_a);  i_t = sigmoid(x_t @ W_x)
  log a_t = -c * softplus(Lambda) * r_t            (c = 8)
  h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training/prefill evaluates the linear recurrence with an associative scan
(log-depth on TPU); decode is the O(1) single-step update — this is why
recurrentgemma runs the long_500k cell. Gates W_a/W_x are full linears
(quantizable; the reference uses block-diagonal — noted in DESIGN.md).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.sharding.context import ShardCtx, LOCAL
from .common import dense_init
from .linears import linear_apply

Params = Dict
_C = 8.0


def init_rglru(key, cfg: ModelConfig, dtype) -> Params:
    d, r = cfg.d_model, cfg.lru_width
    ks = jax.random.split(key, 7)
    # Lambda init so a^c spans ~(0.9, 0.999) as in the paper
    lam = jax.random.uniform(ks[5], (r,), minval=0.9, maxval=0.999)
    lam_param = jnp.log(jnp.expm1(-jnp.log(lam) / _C))  # inverse softplus
    return {
        "w_in": dense_init(ks[0], d, r, dtype),
        "w_gate": dense_init(ks[1], d, r, dtype),
        "w_out": dense_init(ks[2], r, d, dtype),
        "w_a": dense_init(ks[3], r, r, dtype),
        "w_x": dense_init(ks[4], r, r, dtype),
        "lam": lam_param.astype(jnp.float32),
        "conv_w": (jax.random.normal(ks[6], (cfg.conv_width, r)) * 0.1
                   ).astype(dtype),
        "conv_b": jnp.zeros((r,), dtype),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 state: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Depthwise causal conv1d. x (B,S,r), w (cw,r), state (B,cw-1,r) holds
    the trailing inputs of the previous segment. Returns (y, new_state)."""
    cw = w.shape[0]
    xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :]
            for i in range(cw))
    new_state = xp[:, -(cw - 1):, :] if cw > 1 else state
    return y + b[None, None, :], new_state


def _rglru_gates(p: Params, x: jnp.ndarray):
    """x (B,S,r) -> (log_a, beta*gated_input) for the linear recurrence."""
    rt = jax.nn.sigmoid(linear_apply(p["w_a"], x)).astype(jnp.float32)
    it = jax.nn.sigmoid(linear_apply(p["w_x"], x)).astype(jnp.float32)
    log_a = -_C * jax.nn.softplus(p["lam"]) * rt              # (B,S,r)
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.square(a), 1e-12))
    return a, beta * it * x.astype(jnp.float32)


def rglru_scan(p: Params, x: jnp.ndarray, h0: jnp.ndarray):
    """Associative-scan evaluation of h_t = a_t h_{t-1} + b_t; h0 (B,r)."""
    a, b = _rglru_gates(p, x)
    # fold h0 into the first step: b_0 <- b_0 + a_0 * h0
    b = b.at[:, 0, :].add(a[:, 0, :] * h0.astype(jnp.float32))

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2

    a_sc, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h.astype(x.dtype), h[:, -1, :]


def rglru_step(p: Params, x1: jnp.ndarray, h_prev: jnp.ndarray):
    """Single decode step; x1 (B,1,r), h_prev (B,r)."""
    a, b = _rglru_gates(p, x1)
    h = a[:, 0] * h_prev.astype(jnp.float32) + b[:, 0]
    return h[:, None, :].astype(x1.dtype), h


def rglru_block(p: Params, x: jnp.ndarray, state: Dict, cfg: ModelConfig,
                ctx: ShardCtx = LOCAL, col=None, prefix: str = "",
                decode: bool = False):
    """Full recurrent block. state = {conv (B,cw-1,r), h (B,r)}."""
    gate = jax.nn.gelu(linear_apply(p["w_gate"], x, col, prefix + "w_gate",
                                    ctx))
    u = linear_apply(p["w_in"], x, col, prefix + "w_in", ctx)
    u = ctx.constrain(u, "dp", None, ctx.tp_axis)
    u, conv_state = _causal_conv(u, p["conv_w"].astype(u.dtype),
                                 p["conv_b"].astype(u.dtype), state["conv"])
    if decode:
        h_seq, h_last = rglru_step(p, u, state["h"])
    else:
        h_seq, h_last = rglru_scan(p, u, state["h"])
    y = h_seq * gate
    out = linear_apply(p["w_out"], y, col, prefix + "w_out", ctx)
    out = ctx.constrain(out, "dp", None, None)
    from repro.core.cache_formats import CacheState
    return out, CacheState("rglru_state", {"conv": conv_state, "h": h_last})


def rglru_block_tokens(p: Params, x: jnp.ndarray, state, cfg: ModelConfig,
                       tb, ctx: ShardCtx = LOCAL):
    """Flat-token recurrent block for the token-budget serving step:
    x (T, 1, d), `tb` a `models.model.TokenBatch` whose per-slot runs are
    contiguous and position-ordered; state holds (B, cw-1, r) conv tails
    and (B, r) hidden slot tables. Projections, conv taps and the gate
    nonlinearities evaluate in parallel over lanes (conv inputs that fall
    before a run's start are gathered from the slot's conv tail); only the
    h_t = a_t h_{t-1} + b_t recurrence scans lane by lane. A single-lane
    run reproduces the `decode=True` path of `rglru_block` bitwise."""
    gate = jax.nn.gelu(linear_apply(p["w_gate"], x, ctx=ctx))
    u = linear_apply(p["w_in"], x, ctx=ctx)
    u = ctx.constrain(u, "dp", None, ctx.tp_axis)
    u2 = u[:, 0]                                           # (T, r)
    cw = p["conv_w"].shape[0]
    w = p["conv_w"].astype(u2.dtype)
    b = p["conv_b"].astype(u2.dtype)
    off = tb.positions - tb.horizon                        # run offset
    conv_tab = state["conv"]                               # (B, cw-1, r)
    # input at each lane's position p - lag: from the flat batch when the
    # run covers it, else from the slot's conv tail (same gather decode's
    # concat([state, x]) performs); taps accumulate in _causal_conv order
    inps = [u2]                                            # lag 0
    for lag in range(1, cw):
        idx = jnp.clip(cw - 1 - lag + off, 0, cw - 2)
        from_tail = conv_tab[tb.slots, idx].astype(u2.dtype)
        inps.append(jnp.where((off >= lag)[:, None],
                              jnp.roll(u2, lag, axis=0), from_tail))
    y = sum(inps[cw - 1 - j] * w[j][None, :] for j in range(cw))
    u_conv = (y + b[None, :])[:, None, :]                  # (T, 1, r)
    a, bb = _rglru_gates(p, u_conv)

    def body(htab, lane):
        a_i, b_i, slot, act = lane
        h = a_i[0] * htab[slot] + b_i[0]
        htab = jnp.where(act, htab.at[slot].set(h), htab)
        return htab, h

    htab, hs = jax.lax.scan(body, state["h"],
                            (a, bb, tb.slots, tb.active))
    h_seq = hs[:, None, :].astype(x.dtype)
    out = linear_apply(p["w_out"], h_seq * gate, ctx=ctx)
    out = ctx.constrain(out, "dp", None, None)
    # new conv tail per slot: the last cw-1 inputs as of each slot's final
    # lane, scattered from that lane (drop the rest)
    from repro.models.rwkv6 import _last_lane_scatter
    new_tail = jnp.stack([inps[cw - 2 - i] for i in range(cw - 1)], axis=1) \
        if cw > 1 else conv_tab[tb.slots]
    conv_tab = _last_lane_scatter(conv_tab, new_tail, tb) if cw > 1 \
        else conv_tab
    from repro.core.cache_formats import CacheState
    return out, CacheState("rglru_state", {"conv": conv_tab, "h": htab})


def init_rglru_state(batch: int, cfg: ModelConfig, dtype):
    """Per-layer RG-LRU state container ('rglru_state' CacheFormat)."""
    from repro.core.cache_formats import get_cache_format
    return get_cache_format("rglru_state").init(batch, 0, cfg, dtype)
