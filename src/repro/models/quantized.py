"""Whole-model PTQ: convert a model's linear weights to GANQ LUT form.

Implements the paper's sequential layer-wise protocol: for each block, H is
accumulated from calibration activations produced by the ALREADY-QUANTIZED
prefix, the block's linears are quantized (GANQ / GPTQ / RTN), and the
quantized block's outputs propagate to the next block.

Quantized set (paper setting): every transformer-block GEMM — attention
projections, MLP, MoE expert FFNs (per-expert H from *dispatched* tokens;
w_down against the captured per-expert hidden-activation Gram), RWKV
r/k/v/g/o + channel-mix, RG-LRU in/gate/out projections. Kept fp:
embeddings, lm head, norms, routers, RWKV decay LoRA, RG-LRU gates/conv
(<1% of params; DESIGN.md §Arch-applicability).

Mixed precision: every entry point takes a `PrecisionPolicy`
(core/policy.py) mapping layer-name patterns to per-layer QuantConfig /
quantizer method / `WeightFormat`, so one PTQ pass can emit e.g. 3-bit
MLPs + 4-bit attention + fp-kept projections that serve unchanged through
the slot engine. The legacy `(qcfg, method)` arguments build a uniform
policy. Storage accounting and the dry-run `abstract_quantize` route
through the `WeightFormat` registry (core/formats.py), so both always
agree with what the quantizer actually emitted.

NOTE on stacking: pattern-unit params are stacked across units
(transformer.py), so policies must be depth-uniform (rules keyed on
sublayer type like "*/mlp/*", not "layer7/..."): containers with
different bit widths cannot be stacked into one leaf.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import HCollector, QuantConfig, quantize_linear
from repro.core.formats import dtype_bits, get_format
from repro.core.policy import LayerQuantReport, PrecisionPolicy, ResolvedQuant
from repro.core.types import QuantizedExperts, QuantizedLinear
from repro.sharding.context import ShardCtx, LOCAL
from .common import apply_norm
from .model import _dtype, _embed
from .transformer import block_apply, pattern_split

# per-kind quantizable weights: (param subpath, capture name suffix)
_BLOCK_LINEARS = {
    "attn": [("attn/wq", "attn/wq"), ("attn/wk", "attn/wk"),
             ("attn/wv", "attn/wv"), ("attn/wo", "attn/wo")],
    "mlp": [("mlp/w_gate", "mlp/w_gate"), ("mlp/w_up", "mlp/w_up"),
            ("mlp/w_down", "mlp/w_down")],
    "mlp_gelu": [("mlp/w_up", "mlp/w_up"), ("mlp/w_down", "mlp/w_down")],
    "rwkv": [("tm/wr", "tm/wr"), ("tm/wk", "tm/wk"), ("tm/wv", "tm/wv"),
             ("tm/wg", "tm/wg"), ("tm/wo", "tm/wo"),
             ("cm/wk", "cm/wk"), ("cm/wv", "cm/wv"), ("cm/wr", "cm/wr")],
    "rglru": [("rec/w_in", "rec/w_in"), ("rec/w_gate", "rec/w_gate"),
              ("rec/w_out", "rec/w_out")],
}

# whisper decoder cross-attention (oneshot path)
_XATTN_LINEARS = [("xattn/wq", "xattn/wq"), ("xattn/wk", "xattn/wk"),
                  ("xattn/wv", "xattn/wv"), ("xattn/wo", "xattn/wo")]

# Quantizable param subpaths, derived from the block specs above — the
# single source of truth shared by the sequential pipeline and the
# abstract (dry-run) transform; no separately-maintained path list.
QUANT_2D: Tuple[str, ...] = tuple(sorted(
    {p for specs in _BLOCK_LINEARS.values() for p, _ in specs}
    | {p for p, _ in _XATTN_LINEARS}))
QUANT_MOE: Tuple[str, ...] = ("moe/w_gate", "moe/w_up", "moe/w_down")


def _tree_get(tree, path: str):
    node = tree
    for part in path.split("/"):
        node = node[part]
    return node


def _tree_set(tree, path: str, value):
    parts = path.split("/")
    node = tree
    for part in parts[:-1]:
        node = node[part]
    node[parts[-1]] = value


def _as_policy(qcfg: Optional[QuantConfig], method: str,
               policy: Optional[PrecisionPolicy]) -> PrecisionPolicy:
    if policy is not None:
        return policy
    if qcfg is None:
        raise ValueError("provide qcfg (uniform) or policy=")
    return PrecisionPolicy.uniform(qcfg, method)


def _fp_report(w: jnp.ndarray) -> LayerQuantReport:
    # w is model-layout (..., d_in, d_out); report shape in GANQ's
    # (m=out, n=in) orientation to match quantized entries
    return LayerQuantReport(err=0.0, bits_per_weight=dtype_bits(w.dtype),
                            bits=None, fmt="dense", method="none",
                            n_weights=int(w.size),
                            shape=(int(w.shape[-1]), int(w.shape[-2])))


def _expert_fmt(linear_fmt: str) -> str:
    """Stacked-experts counterpart of a linear format, from the registry."""
    efmt = get_format(linear_fmt).expert_fmt
    if efmt is None:
        raise ValueError(
            f"format {linear_fmt!r} has no stacked-experts counterpart "
            f"(set `expert_fmt` on its WeightFormat to quantize MoE "
            f"expert weights with it)")
    return efmt


def _quantize_one(w: jnp.ndarray, h: jnp.ndarray,
                  r: ResolvedQuant) -> Tuple[QuantizedLinear,
                                             LayerQuantReport]:
    """w is (d_in, d_out) model layout -> GANQ's (m=out, n=in) via
    transpose; the resolved format re-layouts the canonical container."""
    res = quantize_linear(jnp.asarray(w, jnp.float32).T, h, r.qcfg, r.method)
    layer = res.layer
    # a quantizer emitting sparse outliers / full rows (GANQ*) stays
    # 'lut_sparse': packed containers carry no sparse fields, so a packed
    # policy format falls back rather than aborting the PTQ pass
    target = r.fmt
    if layer.fmt == "lut_sparse" and (target == "lut"
                                      or get_format(target).packed):
        target = "lut_sparse"
    layer = get_format(target).encode(layer)   # idempotent; normalizes n_cols
    total, count = get_format(layer.fmt).storage_bits(layer)
    rep = LayerQuantReport(err=float(res.err_history[-1]),
                           bits_per_weight=total / count,
                           bits=r.qcfg.bits, fmt=layer.fmt, method=r.method,
                           n_weights=count,
                           shape=(int(w.shape[-1]), int(w.shape[-2])))
    return layer, rep


def block_linear_specs(kind: str, cfg: ModelConfig) -> List[Tuple[str, str]]:
    specs = []
    if kind in ("attn", "local"):
        specs += _BLOCK_LINEARS["attn"]
        if cfg.n_experts == 0:
            specs += (_BLOCK_LINEARS["mlp_gelu"] if cfg.act == "gelu"
                      and cfg.family == "audio" else _BLOCK_LINEARS["mlp"])
    elif kind == "rwkv":
        specs += _BLOCK_LINEARS["rwkv"]
    elif kind == "rglru":
        specs += _BLOCK_LINEARS["rglru"]
        specs += (_BLOCK_LINEARS["mlp_gelu"] if cfg.act == "gelu"
                  and cfg.family == "audio" else _BLOCK_LINEARS["mlp"])
    return specs


def quantize_block(block_params: Dict, kind: str, col: HCollector,
                   cfg: ModelConfig, policy: PrecisionPolicy,
                   prefix: str) -> Tuple[Dict, Dict[str, LayerQuantReport]]:
    """Quantize all linears of one block given captured H under the policy.
    Returns (new params, {name: LayerQuantReport})."""
    qp = jax.tree.map(lambda x: x, block_params)  # shallow-ish copy
    report: Dict[str, LayerQuantReport] = {}
    for path, capname in block_linear_specs(kind, cfg):
        name = prefix + capname
        w = _tree_get(block_params, path)
        r = policy.resolve(name)
        if r.keep_fp:
            report[name] = _fp_report(w)
            continue
        layer, rep = _quantize_one(w, col.get(name), r)
        _tree_set(qp, path, layer)
        report[name] = rep
    # MoE experts: per-expert H from dispatched tokens; w_down against the
    # captured per-expert hidden-activation Gram (gate/up output)
    if "moe" in block_params:
        moe = block_params["moe"]
        e = cfg.n_experts
        for wname in ("w_gate", "w_up", "w_down"):
            name = f"{prefix}moe/{wname}"
            r = policy.resolve(name)
            if r.keep_fp:
                report[name] = _fp_report(moe[wname])
                continue
            layers, errs = [], []
            for ei in range(e):
                h = (col.get(f"{prefix}moe/expert{ei}/hidden")
                     if wname == "w_down"
                     else col.get(f"{prefix}moe/expert{ei}"))
                res = quantize_linear(
                    jnp.asarray(moe[wname][ei], jnp.float32).T, h, r.qcfg,
                    r.method)
                layers.append(res.layer)
                errs.append(float(res.err_history[-1]))

            def stack_opt(attr):
                vals = [getattr(l, attr) for l in layers]
                return None if vals[0] is None else jnp.stack(vals)
            experts = QuantizedExperts(
                codes=jnp.stack([l.codes for l in layers]),
                codebook=jnp.stack([l.codebook for l in layers]),
                bits=r.qcfg.bits, n_cols=layers[0].codes.shape[-1],
                sparse_idx=stack_opt("sparse_idx"),
                sparse_val=stack_opt("sparse_val"),
                full_row_idx=stack_opt("full_row_idx"),
                full_row_val=stack_opt("full_row_val"))
            # same fallback as _quantize_one: sparse/full-row fields ride
            # the unpacked experts container, never a packed one
            lfmt = r.fmt
            if (any(l.fmt == "lut_sparse" for l in layers)
                    and (lfmt == "lut" or get_format(lfmt).packed)):
                lfmt = "lut_sparse"
            efmt = _expert_fmt(lfmt)
            if efmt != experts.fmt:
                experts = get_format(efmt).encode(experts)
            qp["moe"][wname] = experts
            total, count = get_format(experts.fmt).storage_bits(experts)
            report[name] = LayerQuantReport(
                err=float(jnp.mean(jnp.asarray(errs))),
                bits_per_weight=total / count, bits=r.qcfg.bits,
                fmt=experts.fmt, method=r.method, n_weights=count,
                shape=(int(moe[wname].shape[-1]),
                       int(moe[wname].shape[-2])))
    return qp, report


def quantize_model_ptq(params: Dict, cfg: ModelConfig, batch: Dict,
                       qcfg: Optional[QuantConfig] = None,
                       method: str = "ganq", ctx: ShardCtx = LOCAL,
                       policy: Optional[PrecisionPolicy] = None):
    """Sequential layer-wise PTQ for decoder-only stacks.

    batch: calibration inputs (same format as train batches).
    Either `qcfg` (+ `method`) for a uniform pass or `policy=` for
    per-layer mixed precision. Returns (quantized params,
    {layer name: LayerQuantReport}) — per-layer error AND storage.
    """
    policy = _as_policy(qcfg, method, policy)
    if cfg.is_encoder_decoder:
        return quantize_whisper_oneshot(params, cfg, batch, policy=policy,
                                        ctx=ctx)
    cd = _dtype(cfg.compute_dtype)
    pattern, n_units, _ = pattern_split(cfg)
    if cfg.frontend == "patches":
        x = batch["embeds"].astype(cd)
        positions = batch["positions"]
    else:
        x = _embed(params, batch["tokens"], cfg, cd)
        b, s = batch["tokens"].shape
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None],
                                     (b, s))
        if cfg.mrope_sections:
            positions = jnp.broadcast_to(positions[None], (3, b, s))

    report: Dict[str, LayerQuantReport] = {}
    new_units: List[List[Dict]] = [[] for _ in pattern]
    new_tail: List[Dict] = []
    li = 0
    for u in range(n_units):
        for pos_i, kind in enumerate(pattern):
            blk = jax.tree.map(lambda a, u=u: a[u],
                               params["stack"]["units"][pos_i])
            col = HCollector()
            block_apply(kind, blk, x, positions, cfg, ctx, col,
                        prefix=f"layer{li}/")
            qblk, rep = quantize_block(blk, kind, col, cfg, policy,
                                       f"layer{li}/")
            report.update(rep)
            x, _, _ = block_apply(kind, qblk, x, positions, cfg, ctx)
            new_units[pos_i].append(qblk)
            li += 1
    for i, blk in enumerate(params["stack"]["tail"]):
        kind = pattern[i]
        col = HCollector()
        block_apply(kind, blk, x, positions, cfg, ctx, col,
                    prefix=f"layer{li}/")
        qblk, rep = quantize_block(blk, kind, col, cfg, policy,
                                   f"layer{li}/")
        report.update(rep)
        x, _, _ = block_apply(kind, qblk, x, positions, cfg, ctx)
        new_tail.append(qblk)
        li += 1

    qparams = dict(params)
    qparams["stack"] = {
        "units": [jax.tree.map(lambda *xs: jnp.stack(xs), *us) if us else None
                  for us in new_units],
        "tail": new_tail,
    }
    return qparams, report


def quantize_whisper_oneshot(params: Dict, cfg: ModelConfig,
                             batch: Dict,
                             qcfg: Optional[QuantConfig] = None,
                             method: str = "ganq", ctx: ShardCtx = LOCAL,
                             policy: Optional[PrecisionPolicy] = None):
    """One-pass capture for the enc-dec stacks (H from the fp model)."""
    from .model import forward_logits
    policy = _as_policy(qcfg, method, policy)
    col = HCollector()
    forward_logits(params, batch, cfg, ctx, col=col)
    report: Dict[str, LayerQuantReport] = {}
    qparams = jax.tree.map(lambda x: x, params)
    stacks = params["stacks"]
    for side, n in (("enc", cfg.n_encoder_layers), ("dec", cfg.n_layers)):
        qlayers = []
        for i in range(n):
            blk = jax.tree.map(lambda a, i=i: a[i], stacks[side])
            specs = (_BLOCK_LINEARS["attn"] + _BLOCK_LINEARS["mlp_gelu"]
                     + (_XATTN_LINEARS if side == "dec" else []))
            qblk = jax.tree.map(lambda x: x, blk)
            for path, capname in specs:
                name = f"{side}{i}/{capname}"
                w = _tree_get(blk, path)
                r = policy.resolve(name)
                if r.keep_fp:
                    report[name] = _fp_report(w)
                    continue
                layer, rep = _quantize_one(w, col.get(name), r)
                _tree_set(qblk, path, layer)
                report[name] = rep
            qlayers.append(qblk)
        qparams["stacks"][side] = jax.tree.map(lambda *xs: jnp.stack(xs),
                                               *qlayers)
    return qparams, report


def abstract_quantize(params_sds: Dict, cfg: ModelConfig, bits: int = 4,
                      packed: bool = True, book_dtype=jnp.bfloat16,
                      policy: Optional[PrecisionPolicy] = None) -> Dict:
    """ShapeDtypeStruct transform: dense linears -> LUT-quantized containers
    (no allocation — the dry-run's quantized-serving variant).

    Containers are built by the `WeightFormat` registry, so the dry-run
    tree structurally matches real `quantize_model_ptq` output for the
    same policy. Policy rules resolve against param-tree paths here
    ("stack/units/0/mlp/w_up") vs capture names in the real pipeline
    ("layer3/mlp/w_up") — sublayer-type patterns like "*/mlp/*" match
    both. Legacy (bits, packed) args build a uniform policy.
    """
    if policy is None:
        from repro.core.formats import packed_linear_fmt
        fmt = packed_linear_fmt(bits) if packed else "lut"
        policy = PrecisionPolicy(qcfg=QuantConfig(bits=bits), fmt=fmt)

    def resolved_fmt(r):
        # mirror _quantize_one: only ganq emits sparse outlier / full-row
        # fields, and they force 'lut_sparse' (packed containers carry no
        # sparse fields). Returns (fmt, qcfg-for-sparse-shapes-or-None).
        sparse = (r.method == "ganq"
                  and (r.qcfg.outlier_ratio > 0 or r.qcfg.full_rows > 0))
        fmt = r.fmt
        if sparse and (fmt == "lut" or get_format(fmt).packed):
            fmt = "lut_sparse"
        return fmt, (r.qcfg if sparse else None)

    def walk(node, prefix):
        if isinstance(node, dict):
            return {k: walk(v, f"{prefix}{k}/") for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            t = [walk(v, f"{prefix}{i}/") for i, v in enumerate(node)]
            return type(node)(t) if isinstance(node, tuple) else t
        if node is None:
            return None
        path = prefix.rstrip("/")
        shape = node.shape
        if any(q in path for q in QUANT_MOE) and len(shape) >= 3:
            r = policy.resolve(path)
            if r.keep_fp:
                return node
            fmt, sparse_qcfg = resolved_fmt(r)
            return get_format(_expert_fmt(fmt)).abstract(
                shape, r.qcfg.bits, book_dtype, qcfg=sparse_qcfg)
        if any(q in path for q in QUANT_2D) and len(shape) >= 2:
            r = policy.resolve(path)
            if r.keep_fp:
                return node
            fmt, sparse_qcfg = resolved_fmt(r)
            return get_format(fmt).abstract(
                shape, r.qcfg.bits, book_dtype, qcfg=sparse_qcfg)
        return node

    return walk(params_sds, "")


def model_storage_report(qparams: Dict,
                         report: Optional[Dict[str, LayerQuantReport]] = None
                         ) -> Dict:
    """Aggregate bits/weight over all quantized leaves, accounted by each
    leaf's `WeightFormat` from the REAL dtypes (codebook/sparse/full-row
    arrays as stored; codes at the checkpoint bitstream width) —
    `QuantizedExperts` included. Pass the per-layer `report` from
    `quantize_model_ptq` to get it merged in under "per_layer"
    (per-layer bits/weight AND quantization error)."""
    total_w = 0
    total_bits = 0.0

    def visit(node):
        nonlocal total_w, total_bits
        if isinstance(node, (QuantizedLinear, QuantizedExperts)):
            bits, count = get_format(node.fmt).storage_bits(node)
            total_bits += bits
            total_w += count
    jax.tree.map(visit, qparams,
                 is_leaf=lambda x: isinstance(x, (QuantizedLinear,
                                                  QuantizedExperts)))
    out = {"quantized_weights": total_w,
           "bits_per_weight": total_bits / max(total_w, 1)}
    if report is not None:
        out["per_layer"] = {
            name: {"err": r.err, "bits_per_weight": r.bits_per_weight,
                   "bits": r.bits, "fmt": r.fmt, "method": r.method}
            for name, r in report.items()}
    return out
