"""Whole-model PTQ: convert a model's linear weights to GANQ LUT form.

Implements the paper's sequential layer-wise protocol: for each block, H is
accumulated from calibration activations produced by the ALREADY-QUANTIZED
prefix, the block's linears are quantized (GANQ / GPTQ / RTN), and the
quantized block's outputs propagate to the next block.

Quantized set (paper setting): every transformer-block GEMM — attention
projections, MLP, MoE expert FFNs (per-expert H from *dispatched* tokens),
RWKV r/k/v/g/o + channel-mix, RG-LRU in/gate/out projections. Kept fp:
embeddings, lm head, norms, routers, RWKV decay LoRA, RG-LRU gates/conv
(<1% of params; DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import HCollector, QuantConfig, quantize_linear
from repro.core.types import QuantizedLinear
from repro.sharding.context import ShardCtx, LOCAL
from .common import apply_norm
from .model import _dtype, _embed
from .transformer import block_apply, pattern_split

# per-kind quantizable weights: (param subpath, capture name suffix)
_BLOCK_LINEARS = {
    "attn": [("attn/wq", "attn/wq"), ("attn/wk", "attn/wk"),
             ("attn/wv", "attn/wv"), ("attn/wo", "attn/wo")],
    "mlp": [("mlp/w_gate", "mlp/w_gate"), ("mlp/w_up", "mlp/w_up"),
            ("mlp/w_down", "mlp/w_down")],
    "mlp_gelu": [("mlp/w_up", "mlp/w_up"), ("mlp/w_down", "mlp/w_down")],
    "rwkv": [("tm/wr", "tm/wr"), ("tm/wk", "tm/wk"), ("tm/wv", "tm/wv"),
             ("tm/wg", "tm/wg"), ("tm/wo", "tm/wo"),
             ("cm/wk", "cm/wk"), ("cm/wv", "cm/wv"), ("cm/wr", "cm/wr")],
    "rglru": [("rec/w_in", "rec/w_in"), ("rec/w_gate", "rec/w_gate"),
              ("rec/w_out", "rec/w_out")],
}


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QuantizedExperts:
    """Stacked per-expert LUT weights: codes (E, m, n[/2]), codebook (E, m, L)."""

    codes: jax.Array
    codebook: jax.Array
    bits: int
    packed: bool = False
    n_cols: int = 0

    def tree_flatten(self):
        return (self.codes, self.codebook), (self.bits, self.packed,
                                             self.n_cols)

    @classmethod
    def tree_unflatten(cls, aux, children):
        bits, packed, n_cols = aux
        return cls(children[0], children[1], bits, packed, n_cols)

    def dequantize(self, dtype) -> jax.Array:
        """(E, n, m) dense weights in the einsum layout (x @ w)."""
        codes = self.codes
        if self.packed:
            lo = codes & 0xF
            hi = codes >> 4
            codes = jnp.stack([lo, hi], axis=-1).reshape(
                codes.shape[0], codes.shape[1], -1)[:, :, :self.n_cols]
        w = jnp.take_along_axis(self.codebook, codes.astype(jnp.int32),
                                axis=2)                       # (E, m, n)
        return jnp.swapaxes(w, 1, 2).astype(dtype)


def _tree_get(tree, path: str):
    node = tree
    for part in path.split("/"):
        node = node[part]
    return node


def _tree_set(tree, path: str, value):
    parts = path.split("/")
    node = tree
    for part in parts[:-1]:
        node = node[part]
    node[parts[-1]] = value


def _quantize_one(w: jnp.ndarray, h: jnp.ndarray, qcfg: QuantConfig,
                  method: str) -> Tuple[QuantizedLinear, float]:
    """w is (d_in, d_out) model layout -> GANQ's (m=out, n=in) via transpose."""
    res = quantize_linear(jnp.asarray(w, jnp.float32).T, h, qcfg, method)
    return res.layer, float(res.err_history[-1])


def block_linear_specs(kind: str, cfg: ModelConfig) -> List[Tuple[str, str]]:
    specs = []
    if kind in ("attn", "local"):
        specs += _BLOCK_LINEARS["attn"]
        if cfg.n_experts == 0:
            specs += (_BLOCK_LINEARS["mlp_gelu"] if cfg.act == "gelu"
                      and cfg.family == "audio" else _BLOCK_LINEARS["mlp"])
    elif kind == "rwkv":
        specs += _BLOCK_LINEARS["rwkv"]
    elif kind == "rglru":
        specs += _BLOCK_LINEARS["rglru"]
        specs += (_BLOCK_LINEARS["mlp_gelu"] if cfg.act == "gelu"
                  and cfg.family == "audio" else _BLOCK_LINEARS["mlp"])
    return specs


def quantize_block(block_params: Dict, kind: str, col: HCollector,
                   cfg: ModelConfig, qcfg: QuantConfig, method: str,
                   prefix: str) -> Tuple[Dict, Dict[str, float]]:
    """Quantize all linears of one block given captured H. Returns
    (new params, {name: final layer error})."""
    qp = jax.tree.map(lambda x: x, block_params)  # shallow-ish copy
    report: Dict[str, float] = {}
    for path, capname in block_linear_specs(kind, cfg):
        w = _tree_get(block_params, path)
        h = col.get(prefix + capname)
        layer, err = _quantize_one(w, h, qcfg, method)
        _tree_set(qp, path, layer)
        report[prefix + capname] = err
    # MoE experts: per-expert H from dispatched tokens
    if "moe" in block_params:
        moe = block_params["moe"]
        e = cfg.n_experts
        qlayers = {"w_gate": [], "w_up": [], "w_down": []}
        for ei in range(e):
            h_in = col.get(f"{prefix}moe/expert{ei}")
            for wname in ("w_gate", "w_up"):
                res = quantize_linear(
                    jnp.asarray(moe[wname][ei], jnp.float32).T, h_in, qcfg,
                    method)
                qlayers[wname].append(res.layer)
            # w_down input = hidden activations; approximate H with identity-
            # free capture: use the gate/up output Gram is not captured —
            # use weight-space (H=I) for w_down (documented approximation)
            hid = moe["w_down"].shape[1]
            res = quantize_linear(
                jnp.asarray(moe["w_down"][ei], jnp.float32).T,
                jnp.eye(hid, dtype=jnp.float32), qcfg, method)
            qlayers["w_down"].append(res.layer)
        for wname, layers in qlayers.items():
            codes = jnp.stack([l.codes for l in layers])
            books = jnp.stack([l.codebook for l in layers])
            qp["moe"][wname] = QuantizedExperts(codes, books, qcfg.bits)
        report[prefix + "moe/experts"] = float("nan")
    return qp, report


def quantize_model_ptq(params: Dict, cfg: ModelConfig, batch: Dict,
                       qcfg: QuantConfig, method: str = "ganq",
                       ctx: ShardCtx = LOCAL):
    """Sequential layer-wise PTQ for decoder-only stacks.

    batch: calibration inputs (same format as train batches).
    Returns (quantized params, per-linear error report).
    """
    if cfg.is_encoder_decoder:
        return quantize_whisper_oneshot(params, cfg, batch, qcfg, method, ctx)
    cd = _dtype(cfg.compute_dtype)
    pattern, n_units, _ = pattern_split(cfg)
    if cfg.frontend == "patches":
        x = batch["embeds"].astype(cd)
        positions = batch["positions"]
    else:
        x = _embed(params, batch["tokens"], cfg, cd)
        b, s = batch["tokens"].shape
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None],
                                     (b, s))
        if cfg.mrope_sections:
            positions = jnp.broadcast_to(positions[None], (3, b, s))

    report: Dict[str, float] = {}
    new_units: List[List[Dict]] = [[] for _ in pattern]
    new_tail: List[Dict] = []
    li = 0
    for u in range(n_units):
        for pos_i, kind in enumerate(pattern):
            blk = jax.tree.map(lambda a, u=u: a[u],
                               params["stack"]["units"][pos_i])
            col = HCollector()
            block_apply(kind, blk, x, positions, cfg, ctx, col,
                        prefix=f"layer{li}/")
            qblk, rep = quantize_block(blk, kind, col, cfg, qcfg, method,
                                       f"layer{li}/")
            report.update(rep)
            x, _, _ = block_apply(kind, qblk, x, positions, cfg, ctx)
            new_units[pos_i].append(qblk)
            li += 1
    for i, blk in enumerate(params["stack"]["tail"]):
        kind = pattern[i]
        col = HCollector()
        block_apply(kind, blk, x, positions, cfg, ctx, col,
                    prefix=f"layer{li}/")
        qblk, rep = quantize_block(blk, kind, col, cfg, qcfg, method,
                                   f"layer{li}/")
        report.update(rep)
        x, _, _ = block_apply(kind, qblk, x, positions, cfg, ctx)
        new_tail.append(qblk)
        li += 1

    qparams = dict(params)
    qparams["stack"] = {
        "units": [jax.tree.map(lambda *xs: jnp.stack(xs), *us) if us else None
                  for us in new_units],
        "tail": new_tail,
    }
    return qparams, report


def quantize_whisper_oneshot(params: Dict, cfg: ModelConfig, batch: Dict,
                             qcfg: QuantConfig, method: str,
                             ctx: ShardCtx = LOCAL):
    """One-pass capture for the enc-dec stacks (H from the fp model)."""
    from .model import forward_logits
    col = HCollector()
    forward_logits(params, batch, cfg, ctx, col=col)
    report: Dict[str, float] = {}
    qparams = jax.tree.map(lambda x: x, params)
    stacks = params["stacks"]
    for side, n in (("enc", cfg.n_encoder_layers), ("dec", cfg.n_layers)):
        qlayers = []
        for i in range(n):
            blk = jax.tree.map(lambda a, i=i: a[i], stacks[side])
            specs = [("attn/wq", "attn/wq"), ("attn/wk", "attn/wk"),
                     ("attn/wv", "attn/wv"), ("attn/wo", "attn/wo"),
                     ("mlp/w_up", "mlp/w_up"), ("mlp/w_down", "mlp/w_down")]
            if side == "dec":
                specs += [("xattn/wq", "xattn/wq"), ("xattn/wk", "xattn/wk"),
                          ("xattn/wv", "xattn/wv"), ("xattn/wo", "xattn/wo")]
            qblk = jax.tree.map(lambda x: x, blk)
            for path, capname in specs:
                w = _tree_get(blk, path)
                h = col.get(f"{side}{i}/{capname}")
                layer, err = _quantize_one(w, h, qcfg, method)
                _tree_set(qblk, path, layer)
                report[f"{side}{i}/{capname}"] = err
            qlayers.append(qblk)
        qparams["stacks"][side] = jax.tree.map(lambda *xs: jnp.stack(xs),
                                               *qlayers)
    return qparams, report


_QUANT_2D = ("attn/wq", "attn/wk", "attn/wv", "attn/wo", "xattn/wq",
             "xattn/wk", "xattn/wv", "xattn/wo", "mlp/w_gate", "mlp/w_up",
             "mlp/w_down", "tm/wr", "tm/wk", "tm/wv", "tm/wg", "tm/wo",
             "cm/wk", "cm/wv", "cm/wr", "rec/w_in", "rec/w_gate",
             "rec/w_out")
_QUANT_MOE = ("moe/w_gate", "moe/w_up", "moe/w_down")


def abstract_quantize(params_sds: Dict, cfg: ModelConfig, bits: int = 4,
                      packed: bool = True,
                      book_dtype=jnp.bfloat16) -> Dict:
    """ShapeDtypeStruct transform: dense linears -> LUT-quantized containers
    (no allocation — the dry-run's quantized-serving variant)."""
    levels = 1 << bits

    def walk(node, prefix):
        if isinstance(node, dict):
            return {k: walk(v, f"{prefix}{k}/") for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            t = [walk(v, f"{prefix}{i}/") for i, v in enumerate(node)]
            return type(node)(t) if isinstance(node, tuple) else t
        if node is None:
            return None
        path = prefix.rstrip("/")
        shape = node.shape
        if any(q in path for q in _QUANT_MOE) and len(shape) >= 3:
            *lead, e, din, dout = shape
            nc = (din + 1) // 2 if packed else din
            return QuantizedExperts(
                codes=jax.ShapeDtypeStruct((*lead, e, dout, nc), jnp.uint8),
                codebook=jax.ShapeDtypeStruct((*lead, e, dout, levels),
                                              book_dtype),
                bits=bits, packed=packed, n_cols=din)
        if any(q in path for q in _QUANT_2D) and len(shape) >= 2:
            *lead, din, dout = shape
            nc = (din + 1) // 2 if packed else din
            return QuantizedLinear(
                codes=jax.ShapeDtypeStruct((*lead, dout, nc), jnp.uint8),
                codebook=jax.ShapeDtypeStruct((*lead, dout, levels),
                                              book_dtype),
                bits=bits, packed=packed, n_cols=din)
        return node

    return walk(params_sds, "")


def model_storage_report(qparams: Dict) -> Dict[str, float]:
    """Aggregate bits/weight over all quantized leaves."""
    total_w = 0
    total_bits = 0.0
    def visit(node):
        nonlocal total_w, total_bits
        if isinstance(node, (QuantizedLinear, QuantizedExperts)):
            shape = node.codes.shape          # (possibly unit-stacked)
            lead = 1
            for d in shape[:-1]:
                lead *= d
            n = node.n_cols if node.packed else shape[-1]
            count = lead * n
            levels = node.codebook.shape[-1]
            total_w += count
            total_bits += node.bits * count + 16 * lead * levels
            if isinstance(node, QuantizedLinear) and node.sparse_val is not None:
                total_bits += node.sparse_val.size * (16 + 32)
    jax.tree.map(visit, qparams,
                 is_leaf=lambda x: isinstance(x, (QuantizedLinear,
                                                  QuantizedExperts)))
    return {"quantized_weights": total_w,
            "bits_per_weight": total_bits / max(total_w, 1)}
