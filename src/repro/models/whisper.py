"""Whisper-style encoder-decoder backbone (conv frontend is a STUB).

Per the assignment, the modality frontend is stubbed: `input_specs()`
provides precomputed mel-frame embeddings (B, S_enc, d_model) in place of
the two conv layers. Encoder: bidirectional attention + GELU MLP,
sinusoidal positions, LayerNorm. Decoder: causal self-attention +
cross-attention + GELU MLP. No RoPE (absolute positions).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.sharding.context import ShardCtx, LOCAL
from .attention import (attend_full, attention_decode_block,
                        cross_attention_block, encode_cross_kv, init_attention,
                        init_cache, attention_block)
from .common import apply_norm, init_norm, sinusoidal_positions
from .mlp import init_mlp, mlp_apply

Params = Dict


def init_enc_layer(key, cfg: ModelConfig, dtype) -> Params:
    ks = jax.random.split(key, 2)
    return {"ln1": init_norm(cfg.d_model, cfg.norm, dtype),
            "attn": init_attention(ks[0], cfg, dtype),
            "ln2": init_norm(cfg.d_model, cfg.norm, dtype),
            "mlp": init_mlp(ks[1], cfg, dtype)}


def init_dec_layer(key, cfg: ModelConfig, dtype) -> Params:
    ks = jax.random.split(key, 3)
    return {"ln1": init_norm(cfg.d_model, cfg.norm, dtype),
            "attn": init_attention(ks[0], cfg, dtype),
            "ln_x": init_norm(cfg.d_model, cfg.norm, dtype),
            "xattn": init_attention(ks[1], cfg, dtype, cross=True),
            "ln2": init_norm(cfg.d_model, cfg.norm, dtype),
            "mlp": init_mlp(ks[2], cfg, dtype)}


def init_whisper_stacks(key, cfg: ModelConfig, dtype) -> Params:
    ke, kd = jax.random.split(key)
    enc = [init_enc_layer(k, cfg, dtype)
           for k in jax.random.split(ke, cfg.n_encoder_layers)]
    dec = [init_dec_layer(k, cfg, dtype)
           for k in jax.random.split(kd, cfg.n_layers)]
    stack = lambda ls: jax.tree.map(lambda *xs: jnp.stack(xs), *ls)
    return {"enc": stack(enc), "dec": stack(dec),
            "enc_ln": init_norm(cfg.d_model, cfg.norm, dtype),
            "dec_ln": init_norm(cfg.d_model, cfg.norm, dtype)}


def _enc_layer_apply(p, x, cfg, ctx, col, prefix, chunk):
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    h = apply_norm(p["ln1"], x, cfg.norm, cfg.norm_eps)
    from .attention import project_qkv  # bidirectional attention
    q, k, v = project_qkv(p["attn"], h, positions, cfg, ctx, col,
                          prefix + "attn/", rope=False)
    o = attend_full(q, k, v, jnp.arange(s), jnp.arange(s), "none", 0, chunk)
    o = o.reshape(b, s, cfg.q_dim)
    from .linears import linear_apply
    x = x + ctx.constrain(linear_apply(p["attn"]["wo"], o, col,
                                       prefix + "attn/wo", ctx),
                          "dp", None, None)
    h = apply_norm(p["ln2"], x, cfg.norm, cfg.norm_eps)
    return x + mlp_apply(p["mlp"], h, cfg, ctx, col, prefix + "mlp/")


def encode(params, frames: jnp.ndarray, cfg: ModelConfig,
           ctx: ShardCtx = LOCAL, col=None, chunk: Optional[int] = 8192):
    """frames: precomputed (B, S_enc, d) stub embeddings -> encoder output."""
    b, s, d = frames.shape
    x = frames + sinusoidal_positions(s, d).astype(frames.dtype)[None]
    if col is not None:
        for i in range(cfg.n_encoder_layers):
            p = jax.tree.map(lambda a, i=i: a[i], params["enc"])
            x = _enc_layer_apply(p, x, cfg, ctx, col, f"enc{i}/", chunk)
    else:
        def body(h, p):
            return _enc_layer_apply(p, h, cfg, ctx, None, "", chunk), None
        x, _ = jax.lax.scan(body, x, params["enc"])
    return apply_norm(params["enc_ln"], x, cfg.norm, cfg.norm_eps)


def _dec_layer_apply(p, x, enc_out, cfg, ctx, col, prefix, chunk):
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    h = apply_norm(p["ln1"], x, cfg.norm, cfg.norm_eps)
    a, _ = attention_block(p["attn"], h, positions, cfg, "attn", ctx, col,
                           prefix + "attn/", chunk)
    x = x + a
    h = apply_norm(p["ln_x"], x, cfg.norm, cfg.norm_eps)
    enc_kv = encode_cross_kv(p["xattn"], enc_out, cfg, ctx, col,
                             prefix + "xattn/")
    x = x + cross_attention_block(p["xattn"], h, enc_kv, cfg, ctx, col,
                                  prefix + "xattn/")
    h = apply_norm(p["ln2"], x, cfg.norm, cfg.norm_eps)
    return x + mlp_apply(p["mlp"], h, cfg, ctx, col, prefix + "mlp/")


def decode_train(params, tok_emb: jnp.ndarray, enc_out: jnp.ndarray,
                 cfg: ModelConfig, ctx: ShardCtx = LOCAL, col=None,
                 chunk: Optional[int] = 8192):
    """Teacher-forced decoder pass; tok_emb (B, S_dec, d)."""
    b, s, d = tok_emb.shape
    x = tok_emb + sinusoidal_positions(s, d).astype(tok_emb.dtype)[None]
    if col is not None:
        for i in range(cfg.n_layers):
            p = jax.tree.map(lambda a, i=i: a[i], params["dec"])
            x = _dec_layer_apply(p, x, enc_out, cfg, ctx, col, f"dec{i}/",
                                 chunk)
    else:
        def body(h, p):
            return _dec_layer_apply(p, h, enc_out, cfg, ctx, None, "",
                                    chunk), None
        x, _ = jax.lax.scan(body, x, params["dec"])
    return apply_norm(params["dec_ln"], x, cfg.norm, cfg.norm_eps)


# ------------------------------------------------------------------- serving

def init_whisper_cache(params, batch: int, cache_len: int, enc_out,
                       cfg: ModelConfig, dtype):
    """Self-attn ring caches + precomputed cross K/V per decoder layer.

    Cross K/V rides the 'cross_kv' CacheFormat (read-only during decode) so
    the serve sharding rules shard it like every other cache (batch over
    DP, heads over TP) — as a bare tuple it silently replicated 400+
    GB/device.
    """
    from repro.core.cache_formats import CacheState

    def per_layer(p):
        k, v = encode_cross_kv(p["xattn"], enc_out, cfg)
        return CacheState("cross_kv", {"k": k, "v": v})
    cross = jax.vmap(per_layer, in_axes=(0,))(params["dec"]) \
        if cfg.n_layers else None
    self_caches = [init_cache(batch, cache_len, cfg, dtype)
                   for _ in range(cfg.n_layers)]
    self_stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *self_caches)
    return {"self": self_stacked, "cross": cross}


def decode_step_whisper(params, cache, tok_emb: jnp.ndarray, pos: jnp.ndarray,
                        cfg: ModelConfig, ctx: ShardCtx = LOCAL):
    """One decoder token; tok_emb (B,1,d); pos (B,)."""
    d = cfg.d_model
    pe = sinusoidal_positions(int(2 ** 15), d)
    x = tok_emb + pe[pos][:, None, :].astype(tok_emb.dtype)

    from repro.core.cache_formats import get_cache_format

    def body(h, xs):
        p, self_c, cross_kv = xs
        hh = apply_norm(p["ln1"], h, cfg.norm, cfg.norm_eps)
        a, self_c = attention_decode_block(p["attn"], hh, pos, self_c, cfg,
                                           "attn", ctx)
        h = h + a
        hh = apply_norm(p["ln_x"], h, cfg.norm, cfg.norm_eps)
        enc_kv = get_cache_format(cross_kv.fmt).read(cross_kv, h.dtype)
        h = h + cross_attention_block(p["xattn"], hh, enc_kv, cfg, ctx)
        hh = apply_norm(p["ln2"], h, cfg.norm, cfg.norm_eps)
        h = h + mlp_apply(p["mlp"], hh, cfg, ctx)
        return h, self_c

    x, new_self = jax.lax.scan(body, x,
                               (params["dec"], cache["self"], cache["cross"]))
    x = apply_norm(params["dec_ln"], x, cfg.norm, cfg.norm_eps)
    return x, {"self": new_self, "cross": cache["cross"]}
