"""Dense MLPs: SwiGLU (llama-family) and GELU (whisper)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.sharding.context import ShardCtx, LOCAL
from .common import activation, dense_init
from .linears import linear_apply, linear_apply_grouped


def init_mlp(key, cfg: ModelConfig, dtype, d_ff: int = 0):
    f = d_ff or cfg.d_ff
    d = cfg.d_model
    ks = jax.random.split(key, 3)
    if cfg.act == "gelu" and cfg.family == "audio":
        # whisper: plain 2-matmul GELU MLP
        return {"w_up": dense_init(ks[0], d, f, dtype),
                "w_down": dense_init(ks[1], f, d, dtype)}
    return {"w_gate": dense_init(ks[0], d, f, dtype),
            "w_up": dense_init(ks[1], d, f, dtype),
            "w_down": dense_init(ks[2], f, d, dtype)}


def mlp_apply(p, x, cfg: ModelConfig, ctx: ShardCtx = LOCAL, col=None,
              prefix: str = ""):
    act = activation(cfg.act)
    if "w_gate" not in p:
        h = act(linear_apply(p["w_up"], x, col, prefix + "w_up", ctx))
        h = ctx.constrain(h, "dp", None, ctx.tp_axis)
        y = linear_apply(p["w_down"], h, col, prefix + "w_down", ctx)
        return ctx.constrain(y, "dp", None, None)
    # gate/up share x: one fused LUT-mpGEMM launch when both are quantized
    # in the same groupable format (falls back to two matmuls otherwise)
    g, u = linear_apply_grouped(
        [p["w_gate"], p["w_up"]], x, col,
        (prefix + "w_gate", prefix + "w_up"), ctx)
    h = act(g) * u
    h = ctx.constrain(h, "dp", None, ctx.tp_axis)
    y = linear_apply(p["w_down"], h, col, prefix + "w_down", ctx)
    return ctx.constrain(y, "dp", None, None)
