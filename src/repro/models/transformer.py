"""Decoder-stack assembly: pattern-unit layer stacking with scan.

Heterogeneous layer patterns (gemma3's 5 local : 1 global, recurrentgemma's
2 RG-LRU : 1 local-attn) are stacked as repeating *pattern units*: params of
each position in the unit are stacked across the n_layers//P repeats and the
stack is evaluated with one `lax.scan` (compile-time O(P), not O(L)).
Remainder layers (n_layers % P) are applied unrolled.

Capture mode (PTQ H collection) iterates layers unrolled — calibration
models are small and the collector is a Python-side accumulator.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.cache_formats import (CacheState, get_cache_format,
                                      insert_slot, layer_cache_format)
from repro.sharding.context import ShardCtx, LOCAL
from .attention import (attention_block, attention_decode_block,
                        attention_mixed_block, init_attention, init_cache)
from .common import init_norm, apply_norm
from .mlp import init_mlp, mlp_apply
from .moe import init_moe, moe_apply
from .rglru import (init_rglru, init_rglru_state, rglru_block,
                    rglru_block_tokens)
from .rwkv6 import (init_rwkv_channel_mix, init_rwkv_state, init_rwkv_time_mix,
                    rwkv_channel_mix, rwkv_channel_mix_tokens, rwkv_time_mix,
                    rwkv_time_mix_tokens)

Params = Dict


# ----------------------------------------------------------------- one block

def init_block(key, kind: str, cfg: ModelConfig, dtype) -> Params:
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    if kind in ("attn", "local"):
        p = {"ln1": init_norm(d, cfg.norm, dtype),
             "attn": init_attention(ks[0], cfg, dtype),
             "ln2": init_norm(d, cfg.norm, dtype)}
        if cfg.n_experts:
            p["moe"] = init_moe(ks[1], cfg, dtype)
        else:
            p["mlp"] = init_mlp(ks[1], cfg, dtype)
        return p
    if kind == "rwkv":
        return {"ln1": init_norm(d, cfg.norm, dtype),
                "tm": init_rwkv_time_mix(ks[0], cfg, dtype),
                "ln2": init_norm(d, cfg.norm, dtype),
                "cm": init_rwkv_channel_mix(ks[1], cfg, dtype)}
    if kind == "rglru":
        return {"ln1": init_norm(d, cfg.norm, dtype),
                "rec": init_rglru(ks[0], cfg, dtype),
                "ln2": init_norm(d, cfg.norm, dtype),
                "mlp": init_mlp(ks[1], cfg, dtype)}
    raise ValueError(kind)


def _ffn(p, x, cfg, ctx, col, prefix):
    if "moe" in p:
        return moe_apply(p["moe"], x, cfg, ctx, col, prefix + "moe/")
    return mlp_apply(p["mlp"], x, cfg, ctx, col, prefix + "mlp/"), 0.0


def block_apply(kind: str, p: Params, x, positions, cfg: ModelConfig,
                ctx: ShardCtx = LOCAL, col=None, prefix: str = "",
                chunk: Optional[int] = 8192):
    """Train/prefill forward. Returns (x, aux, kv) — kv only for attn kinds."""
    aux = 0.0
    if kind in ("attn", "local"):
        h = apply_norm(p["ln1"], x, cfg.norm, cfg.norm_eps)
        a, kv = attention_block(p["attn"], h, positions, cfg, kind, ctx, col,
                                prefix + "attn/", chunk)
        x = x + a
        h = apply_norm(p["ln2"], x, cfg.norm, cfg.norm_eps)
        f, aux = _ffn(p, h, cfg, ctx, col, prefix)
        return x + f, aux, kv
    if kind == "rwkv":
        b = x.shape[0]
        st = init_rwkv_state(b, cfg, x.dtype)
        h = apply_norm(p["ln1"], x, cfg.norm, cfg.norm_eps)
        a, (tm_shift, wkv) = rwkv_time_mix(
            p["tm"], h, (st["tm_shift"], st["wkv"]), cfg, ctx, col,
            prefix + "tm/")
        x = x + a
        h = apply_norm(p["ln2"], x, cfg.norm, cfg.norm_eps)
        c, cm_shift = rwkv_channel_mix(p["cm"], h, st["cm_shift"], cfg, ctx,
                                       col, prefix + "cm/")
        return x + c, aux, CacheState("rwkv_state",
                                      {"tm_shift": tm_shift, "wkv": wkv,
                                       "cm_shift": cm_shift})
    if kind == "rglru":
        b = x.shape[0]
        st = init_rglru_state(b, cfg, x.dtype)
        h = apply_norm(p["ln1"], x, cfg.norm, cfg.norm_eps)
        a, rec_state = rglru_block(p["rec"], h, st, cfg, ctx, col,
                                   prefix + "rec/")
        x = x + a
        h = apply_norm(p["ln2"], x, cfg.norm, cfg.norm_eps)
        f, aux = _ffn(p, h, cfg, ctx, col, prefix)
        return x + f, aux, rec_state
    raise ValueError(kind)


def _freeze_inactive(active, new_state, old_state):
    """Per-slot select: inactive slots keep their previous recurrent state
    (leaves are batch-major, (B, ...))."""
    if active is None:
        return new_state

    def sel(n, o):
        a = active.reshape((-1,) + (1,) * (n.ndim - 1))
        return jnp.where(a, n, o.astype(n.dtype))

    return jax.tree.map(sel, new_state, old_state)


def block_decode(kind: str, p: Params, x, pos, cache, cfg: ModelConfig,
                 ctx: ShardCtx = LOCAL, active=None, pages=None):
    """One-token decode. cache is this layer's state; returns (x, cache).

    `active` (B,) bool marks live slots in a slot-batched decode: attention
    gates its cache write and attends-to-nothing on inactive rows; recurrent
    (rwkv / rglru) state is frozen for inactive rows. `pages` (B, max_pages)
    is the page table threaded to paged attention caches.
    """
    if kind in ("attn", "local"):
        h = apply_norm(p["ln1"], x, cfg.norm, cfg.norm_eps)
        a, cache = attention_decode_block(p["attn"], h, pos, cache, cfg, kind,
                                          ctx, active, pages)
        x = x + a
        h = apply_norm(p["ln2"], x, cfg.norm, cfg.norm_eps)
        f, _ = _ffn(p, h, cfg, ctx, None, "")
        return x + f, cache
    if kind == "rwkv":
        h = apply_norm(p["ln1"], x, cfg.norm, cfg.norm_eps)
        a, (tm_shift, wkv) = rwkv_time_mix(
            p["tm"], h, (cache["tm_shift"], cache["wkv"]), cfg, ctx)
        x = x + a
        h = apply_norm(p["ln2"], x, cfg.norm, cfg.norm_eps)
        c, cm_shift = rwkv_channel_mix(p["cm"], h, cache["cm_shift"], cfg, ctx)
        new = CacheState("rwkv_state", {"tm_shift": tm_shift, "wkv": wkv,
                                        "cm_shift": cm_shift})
        return x + c, _freeze_inactive(active, new, cache)
    if kind == "rglru":
        h = apply_norm(p["ln1"], x, cfg.norm, cfg.norm_eps)
        a, rec_state = rglru_block(p["rec"], h, cache, cfg, ctx, decode=True)
        x = x + a
        h = apply_norm(p["ln2"], x, cfg.norm, cfg.norm_eps)
        f, _ = _ffn(p, h, cfg, ctx, None, "")
        return x + f, _freeze_inactive(active, rec_state, cache)
    raise ValueError(kind)


def _reset_rows(state: CacheState, reset) -> CacheState:
    """Zero the state rows of freshly admitted slots (leaves are slot
    tables, batch-major): the recurrent-state analogue of a prompt starting
    from blank prefill state. KV caches need no reset — their visibility
    masks never reach a new occupant's unwritten positions."""
    if reset is None:
        return state

    def zero(leaf):
        r = reset.reshape((-1,) + (1,) * (leaf.ndim - 1))
        return jnp.where(r, jnp.zeros_like(leaf), leaf)

    return CacheState(state.fmt, {k: zero(v) for k, v in state.data.items()})


def block_mixed(kind: str, p: Params, x, tb, cache, cfg: ModelConfig,
                ctx: ShardCtx = LOCAL):
    """Token-budget step through one block: x (T, 1, d) flat token lanes,
    `tb` a `models.model.TokenBatch`. One path serves any mix of decode
    lanes and prompt-chunk lanes; recurrent state rows of freshly admitted
    slots are zeroed in-graph (tb.reset) before the step touches them.
    Returns (x, new_cache)."""
    if kind in ("attn", "local"):
        h = apply_norm(p["ln1"], x, cfg.norm, cfg.norm_eps)
        a, cache = attention_mixed_block(p["attn"], h, tb, cache, cfg, kind,
                                         ctx)
        x = x + a
        h = apply_norm(p["ln2"], x, cfg.norm, cfg.norm_eps)
        f, _ = _ffn(p, h, cfg, ctx, None, "")
        return x + f, cache
    if kind == "rwkv":
        st = _reset_rows(cache, tb.reset)
        h = apply_norm(p["ln1"], x, cfg.norm, cfg.norm_eps)
        a, (tm_shift, wkv) = rwkv_time_mix_tokens(
            p["tm"], h, (st["tm_shift"], st["wkv"]), tb, cfg, ctx)
        x = x + a
        h = apply_norm(p["ln2"], x, cfg.norm, cfg.norm_eps)
        c, cm_shift = rwkv_channel_mix_tokens(p["cm"], h, st["cm_shift"], tb,
                                              cfg, ctx)
        return x + c, CacheState("rwkv_state",
                                 {"tm_shift": tm_shift, "wkv": wkv,
                                  "cm_shift": cm_shift})
    if kind == "rglru":
        st = _reset_rows(cache, tb.reset)
        h = apply_norm(p["ln1"], x, cfg.norm, cfg.norm_eps)
        a, rec_state = rglru_block_tokens(p["rec"], h, st, cfg, tb, ctx)
        x = x + a
        h = apply_norm(p["ln2"], x, cfg.norm, cfg.norm_eps)
        f, _ = _ffn(p, h, cfg, ctx, None, "")
        return x + f, rec_state
    raise ValueError(kind)


def layer_cache_width(kind: str, cache_len: int, cfg: ModelConfig) -> int:
    """Token capacity of one layer's attention cache: 'local' layers ring
    over the sliding window — except under paged formats, which share one
    page-id space across all layers and enforce the window by masking."""
    f = get_cache_format(layer_cache_format(kind, cfg))
    if kind == "local" and not f.paged:
        return min(cache_len, cfg.sliding_window)
    return cache_len


def init_layer_cache(kind: str, batch: int, cache_len: int, cfg: ModelConfig,
                     dtype, sub: bool = False):
    """One layer's cache/state container via the CacheFormat registry.
    `sub=True` builds the insert-layout blank instead (slot reset)."""
    f = get_cache_format(layer_cache_format(kind, cfg))
    width = layer_cache_width(kind, cache_len, cfg)
    if sub:
        return f.blank(batch, width, cfg, dtype)
    return f.init(batch, width, cfg, dtype)


# -------------------------------------------------------------------- stacks

def pattern_split(cfg: ModelConfig) -> Tuple[Tuple[str, ...], int, int]:
    """(pattern, n_units, n_tail)."""
    p = cfg.layer_pattern
    return p, cfg.n_layers // len(p), cfg.n_layers % len(p)


def init_stack(key, cfg: ModelConfig, dtype) -> Params:
    pattern, n_units, n_tail = pattern_split(cfg)
    keys = jax.random.split(key, cfg.n_layers)
    layers: List[Params] = [init_block(keys[i], cfg.layer_kinds[i], cfg, dtype)
                            for i in range(cfg.n_layers)]
    units = []
    for pos in range(len(pattern)):
        per_pos = [layers[u * len(pattern) + pos] for u in range(n_units)]
        units.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per_pos)
                     if n_units else None)
    tail = layers[n_units * len(pattern):]
    return {"units": units, "tail": tail}


def stack_apply(params: Params, x, positions, cfg: ModelConfig,
                ctx: ShardCtx = LOCAL, col=None,
                chunk: Optional[int] = 8192, collect_state: bool = False,
                remat: str = "none"):
    """Forward through all layers (training / logits path). Returns (x, aux)
    — or (x, aux, states) with collect_state=True (prefill: fresh K/V and
    recurrent states per layer, unit-stacked).

    remat: 'none' | 'full' | 'dots' — activation checkpointing of the unit
    scan body (training memory knob; see EXPERIMENTS.md §Perf).
    Capture mode (col != None) runs unrolled.
    """
    pattern, n_units, _ = pattern_split(cfg)

    if col is not None:
        aux = 0.0
        li = 0
        for u in range(n_units):
            for pos, kind in enumerate(pattern):
                p = jax.tree.map(lambda a, u=u: a[u], params["units"][pos])
                x, a, _ = block_apply(kind, p, x, positions, cfg, ctx, col,
                                      prefix=f"layer{li}/", chunk=chunk)
                aux += a
                li += 1
        for i, p in enumerate(params["tail"]):
            x, a, _ = block_apply(pattern[i], p, x, positions, cfg, ctx, col,
                                  prefix=f"layer{li}/", chunk=chunk)
            aux += a
            li += 1
        return x, aux

    collected = None
    if n_units:
        def body(carry, unit_params):
            h, aux = carry
            states = []
            for pos, kind in enumerate(pattern):
                h, a, st = block_apply(kind, unit_params[pos], h, positions,
                                       cfg, ctx, None, chunk=chunk)
                states.append(st)
                aux += a
            return (h, aux), tuple(states) if collect_state else None

        if remat == "full":
            body = jax.checkpoint(body, prevent_cse=False)
        elif remat == "dots":
            body = jax.checkpoint(
                body, prevent_cse=False,
                policy=jax.checkpoint_policies.checkpoint_dots)
        (x, aux), collected = jax.lax.scan(body, (x, 0.0),
                                           tuple(params["units"]))
    else:
        aux = 0.0
    tail_states = []
    for i, p in enumerate(params["tail"]):
        x, a, st = block_apply(pattern[i], p, x, positions, cfg, ctx, None,
                               chunk=chunk)
        tail_states.append(st)
        aux += a
    if collect_state:
        return x, aux, {"units": list(collected) if collected else [],
                        "tail": tail_states}
    return x, aux


def init_stack_cache(batch: int, cache_len: int, cfg: ModelConfig, dtype,
                     sub: bool = False):
    pattern, n_units, n_tail = pattern_split(cfg)
    units = []
    for pos, kind in enumerate(pattern):
        per = [init_layer_cache(kind, batch, cache_len, cfg, dtype, sub=sub)
               for _ in range(n_units)]
        units.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per)
                     if n_units else None)
    tail = [init_layer_cache(pattern[i], batch, cache_len, cfg, dtype,
                             sub=sub)
            for i in range(n_tail)]
    return {"units": units, "tail": tail}


def stack_decode(params: Params, cache: Params, x, pos, cfg: ModelConfig,
                 ctx: ShardCtx = LOCAL, active=None, pages=None):
    """One-token decode through all layers. Returns (x, new_cache)."""
    pattern, n_units, _ = pattern_split(cfg)
    new_units = []
    if n_units:
        def body(h, xs):
            unit_params, unit_cache = xs
            new_caches = []
            for p_i, kind in enumerate(pattern):
                h, c = block_decode(kind, unit_params[p_i], h, pos,
                                    unit_cache[p_i], cfg, ctx, active, pages)
                new_caches.append(c)
            return h, tuple(new_caches)

        x, caches = jax.lax.scan(
            body, x, (tuple(params["units"]), tuple(cache["units"])))
        new_units = list(caches)
    new_tail = []
    for i, p in enumerate(params["tail"]):
        x, c = block_decode(pattern[i], p, x, pos, cache["tail"][i], cfg, ctx,
                            active, pages)
        new_tail.append(c)
    return x, {"units": new_units, "tail": new_tail}


def stack_mixed(params: Params, cache: Params, x, tb, cfg: ModelConfig,
                ctx: ShardCtx = LOCAL):
    """Token-budget step through all layers: the mixed-lane twin of
    `stack_decode` (same unit scan / tail split). Returns (x, new_cache)."""
    pattern, n_units, _ = pattern_split(cfg)
    new_units = []
    if n_units:
        def body(h, xs):
            unit_params, unit_cache = xs
            new_caches = []
            for p_i, kind in enumerate(pattern):
                h, c = block_mixed(kind, unit_params[p_i], h, tb,
                                   unit_cache[p_i], cfg, ctx)
                new_caches.append(c)
            return h, tuple(new_caches)

        x, caches = jax.lax.scan(
            body, x, (tuple(params["units"]), tuple(cache["units"])))
        new_units = list(caches)
    new_tail = []
    for i, p in enumerate(params["tail"]):
        x, c = block_mixed(pattern[i], p, x, tb, cache["tail"][i], cfg, ctx)
        new_tail.append(c)
    return x, {"units": new_units, "tail": new_tail}


def cache_insert(cache: Params, sub: Params, slot, pages=None) -> Params:
    """Insert a single-sequence stack cache into row `slot` of a slot-batched
    stack cache (the continuous-batching admission path).

    `cache` entries are slot-batched `CacheState`s: unit-stacked leaves
    (U, B, ...) carry the batch on axis 1, tail leaves (B, ...) on axis 0.
    `sub` is the same structure built with batch 1 (e.g. by `prefill`);
    `slot` may be a traced int32 so one jitted insert serves every slot.
    Each entry routes through its `CacheFormat.insert` — pure tree surgery
    for contiguous layouts (full + ring attention, int8 KV with scales,
    rwkv / rglru recurrent state), a page-table scatter for paged layouts
    (`pages` is the slot's (max_pages,) table row).
    """
    units = [None if cu is None else
             insert_slot(cu, su, slot, pages=pages, stacked=True)
             for cu, su in zip(cache["units"], sub["units"])]
    tail = [insert_slot(ct, st, slot, pages=pages, stacked=False)
            for ct, st in zip(cache["tail"], sub["tail"])]
    return {"units": units, "tail": tail}
